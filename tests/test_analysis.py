"""repro.analysis swarmlint: rule registry, the four rule families on
known-bad/known-good fixtures, the justified baseline, and the shipped
tree's own guarantees (ISSUE 6 acceptance surface; obs family from
ISSUE 10)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import (AnalysisContext, AnalyzerRule, Baseline,
                            Finding, collect_findings, register_rule,
                            rule_ids, scorecard, slotview_tiers,
                            split_by_baseline, write_baseline)
from repro.analysis.registry import _REGISTRY

REPO = Path(__file__).resolve().parent.parent
FIXTURES = Path(__file__).resolve().parent / "static_fixtures"


def run_on(files, families=None, assume_library=True):
    ctx = AnalysisContext(REPO, assume_library=assume_library)
    ctx.add_paths([FIXTURES / f for f in files])
    assert not ctx.errors, ctx.errors
    return collect_findings(ctx, families)


def fired(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# registry mirrors register_policy
# ---------------------------------------------------------------------------

def test_registry_rejects_non_rule_and_anonymous_rule():
    with pytest.raises(TypeError):
        register_rule(object)

    class NoId(AnalyzerRule):
        family = "rng"
    with pytest.raises(ValueError, match="non-empty"):
        register_rule(NoId)

    class BadFamily(AnalyzerRule):
        rule = "XXX999"
        family = "nope"
    with pytest.raises(ValueError, match="family"):
        register_rule(BadFamily)


def test_registry_rejects_duplicate_rule_id():
    class Clash(AnalyzerRule):
        rule = "RNG001"                     # already taken
        family = "rng"
    with pytest.raises(ValueError, match="duplicate"):
        register_rule(Clash)
    assert _REGISTRY["RNG001"] is not Clash


def test_all_four_families_registered():
    ids = rule_ids()
    assert ids == tuple(sorted(ids))
    assert {"RNG001", "RNG002", "RNG003", "RNG004", "RNG005", "RNG006",
            "RNG007", "VIS001", "JIT101", "JIT102", "JIT103",
            "OBS001", "OBS002"} <= set(ids)
    from repro.analysis import FAMILIES
    assert FAMILIES == ("rng", "visibility", "jit", "obs")


# ---------------------------------------------------------------------------
# family 1: rng discipline
# ---------------------------------------------------------------------------

def test_rng_rules_fire_on_known_bad():
    found = run_on(["rng_bad.py"], families=("rng",))
    assert fired(found) == {"RNG001", "RNG002", "RNG003", "RNG004",
                            "RNG005", "RNG006", "RNG007"}
    # the two RNG005 shapes: tainted set variable and set literal
    details = {f.detail for f in found if f.rule == "RNG005"}
    assert details == {"peers", "set-literal"}
    # the constant-seed shadow names the threaded-param function
    (shadow,) = [f for f in found if f.rule == "RNG004"]
    assert shadow.scope == "shadowed_fallback"


def test_rng_rules_silent_on_known_good():
    assert run_on(["rng_good.py"], families=("rng",)) == []


# ---------------------------------------------------------------------------
# family 2: visibility escape
# ---------------------------------------------------------------------------

def test_visibility_flags_never_executed_over_reaching_policy():
    """The acceptance fixture: PeekingFlooder is registered nowhere and
    executed never — only the lint pass can catch it, via all three
    escape routes (direct door, self-method, two-hop module helper)."""
    found = run_on(["vis_bad.py"], families=("visibility",))
    by_scope = {}
    for f in found:
        by_scope.setdefault(f.scope, set()).add(f.detail)
    assert by_scope == {
        "PeekingFlooder": {"_engine_state", "candidate_columns",
                           "supply"},
        "NosyNeighborhood": {"state"},
    }
    assert all(f.rule == "VIS001" and f.severity == "error"
               for f in found)


def test_visibility_silent_on_tier_honest_policies():
    assert run_on(["vis_good.py"], families=("visibility",)) == []


def test_slotview_tier_table_derived_from_policy_source():
    src = (REPO / "src/repro/core/policy.py").read_text()
    tiers = slotview_tiers(src)
    assert tiers["supply"] == "full"
    assert tiers["state"] == "full"
    assert tiers["candidate_columns"] == "full"
    assert tiers["_engine_state"] == "full"      # the audited door
    assert tiers["availability_union"] == "neighborhood"
    # ungated protocol facts stay at the bottom tier
    assert tiers["rng"] == "none"
    assert tiers["receivers_open"] == "none"
    assert tiers["resolve_requests"] == "none"


# ---------------------------------------------------------------------------
# family 3: jit readiness
# ---------------------------------------------------------------------------

def test_jit_rules_fire_on_known_bad():
    found = run_on(["jit_bad.py"], families=("jit",))
    assert fired(found) == {"JIT101", "JIT102", "JIT103"}
    assert all(f.severity == "warning" for f in found)
    kinds = {f.detail.split(":", 1)[0] for f in found
             if f.rule == "JIT103"}
    assert kinds == {"while", "for"}


def test_jit_rules_silent_on_known_good():
    assert run_on(["jit_good.py"], families=("jit",)) == []


def test_scorecard_separates_ready_from_worklist():
    ctx = AnalysisContext(REPO, assume_library=True)
    ctx.add_paths([FIXTURES / "jit_bad.py", FIXTURES / "jit_good.py"])
    rows = scorecard(ctx, collect_findings(ctx, ("jit",)))
    status = {(Path(p).name, q): ready for p, q, _c, ready in rows}
    assert status[("jit_bad.py", "transport")] is False
    assert status[("jit_good.py", "transport")] is True


# ---------------------------------------------------------------------------
# family 4: observability discipline
# ---------------------------------------------------------------------------

def test_obs_rules_fire_on_known_bad():
    found = run_on(["obs_bad.py"], families=("obs",))
    assert fired(found) == {"OBS001", "OBS002"}
    assert all(f.severity == "error" for f in found)
    assert len([f for f in found if f.rule == "OBS001"]) == 2
    hits = [f for f in found if f.rule == "OBS002"]
    # OBS002 reaches past RNG007's wall-clock set: sleep and strftime
    # count as inline host-time use too.
    assert {f.detail for f in hits} == {
        "time.perf_counter", "time.sleep", "time.strftime"}
    assert len(hits) == 4


def test_obs_rules_silent_on_known_good():
    """The obs-routed twins of every bad shape — including a *reference*
    to ``time.perf_counter`` (the measured_clock injection idiom), which
    must not be mistaken for a call."""
    assert run_on(["obs_good.py"], families=("obs",)) == []


# ---------------------------------------------------------------------------
# baseline mechanics
# ---------------------------------------------------------------------------

def _finding(**kw):
    base = dict(rule="RNG001", severity="error", path="a.py", line=3,
                message="m", scope="f", detail="random.random")
    base.update(kw)
    return Finding(**base)


def test_finding_key_is_line_stable():
    assert _finding(line=3).key == _finding(line=99).key


def test_baseline_requires_justification(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps(
        {"version": 1,
         "entries": [{"key": "RNG001:a.py:f:random.random",
                      "justification": "  "}]}))
    with pytest.raises(ValueError, match="justification"):
        Baseline.load(p)


def test_baseline_split_and_stale(tmp_path):
    f1, f2 = _finding(), _finding(detail="random.choice")
    p = tmp_path / "b.json"
    write_baseline(p, [f1])
    bl = Baseline.load(p)       # TODO-justify entries still load
    new, old = split_by_baseline([f1, f2], bl)
    assert old == [f1] and new == [f2]
    assert bl.unused([f2]) == [f1.key]


def test_write_baseline_preserves_justifications(tmp_path):
    p = tmp_path / "b.json"
    write_baseline(p, [_finding()])
    raw = json.loads(p.read_text())
    raw["entries"][0]["justification"] = "reviewed: fine"
    p.write_text(json.dumps(raw))
    prev = Baseline.load(p)
    write_baseline(p, [_finding(), _finding(detail="random.choice")],
                   prev)
    entries = {e["key"]: e["justification"]
               for e in json.loads(p.read_text())["entries"]}
    assert entries[_finding().key] == "reviewed: fine"
    assert entries[_finding(detail="random.choice").key].startswith(
        "TODO")


# ---------------------------------------------------------------------------
# the shipped tree's own guarantees
# ---------------------------------------------------------------------------

def _shipped_ctx():
    ctx = AnalysisContext(REPO)
    ctx.add_paths([REPO / "src", REPO / "examples"])
    return ctx


def test_shipped_tree_rng_clean():
    """After the overlay fix, the library carries zero rng-discipline
    findings — nothing hides behind the baseline."""
    assert collect_findings(_shipped_ctx(), ("rng",)) == []


def test_shipped_tree_visibility_exactly_the_engine_doors():
    """The only tier escapes are the two equivalence-locked built-in
    backends reaching the audited ``_engine_state`` door — and both
    are justified in the baseline."""
    found = collect_findings(_shipped_ctx(), ("visibility",))
    assert {(f.scope, f.detail) for f in found} == {
        ("DistributedPolicy", "_engine_state"),
        ("FloodingPolicy", "_engine_state")}
    bl = Baseline.load(REPO / "analysis_baseline.json")
    assert all(bl.covers(f) for f in found)
    assert all(bl.entries[f.key] and "TODO" not in bl.entries[f.key]
               for f in found)


def test_shipped_tree_obs_clean():
    """No print()/inline time.* survives in core/, net/, fl/ — all
    telemetry flows through repro.obs and the injectable clocks."""
    assert collect_findings(_shipped_ctx(), ("obs",)) == []


def test_cli_exits_zero_on_shipped_tree():
    """The CI/benchmark contract: ``python -m repro.analysis src
    examples`` from the repo root is clean under the baseline."""
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "src", "examples"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "jit-readiness scorecard" in proc.stdout


def test_cli_exits_nonzero_on_new_findings():
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--no-baseline",
         "--assume-library", str(FIXTURES / "rng_bad.py")],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=120)
    assert proc.returncode == 1
    assert "RNG004" in proc.stdout
