"""repro.obs (ISSUE 10): recorder semantics, JSONL schema golden,
Perfetto export, report-vs-metrics reproduction, the tracker
control-plane audit, the measured_clock leak fix, and the
zero-overhead-when-disabled bound."""
import json
import os
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from repro import obs
from repro.core import SwarmConfig, SwarmSession
from repro.core import simulator as sim_mod
from repro.core import jit_engine
from repro.core.simulator import RoundSimulator, measured_clock
from repro.net import NetConfig

REPO = Path(__file__).resolve().parent.parent

CFG = SwarmConfig(n=16, chunks_per_update=8, min_degree=4,
                  s_max=3000, seed=11)
NET = NetConfig(tracker_rtt_s=0.1, latency_lo_s=0.005,
                latency_hi_s=0.030)


def _record_round(**kw):
    """One n=16 event-engine round under a fresh recorder."""
    with obs.recording(meta={"test": "obs"}) as rec:
        res = RoundSimulator(CFG, time_engine="event", net=NET,
                             **kw).run()
    return rec, res


# ---------------------------------------------------------------------------
# recorder semantics
# ---------------------------------------------------------------------------

def test_null_recorder_is_default_and_inert():
    rec = obs.get()
    assert isinstance(rec, obs.NullRecorder) and rec.enabled is False
    # every hook is a no-op returning nothing to clean up
    rec.event("x", t=1.0)
    rec.counter("c")
    rec.gauge("g", 2.0)
    rec.hist("h", [1, 2])
    rec.flows("bt", [0], [1], [0.0], [1.0])
    with rec.span("s") as sp:
        sp.note(k=1)


def test_recording_restores_previous_recorder_on_exception():
    before = obs.get()
    with pytest.raises(RuntimeError):
        with obs.recording() as rec:
            assert obs.get() is rec
            raise RuntimeError("boom")
    assert obs.get() is before


def test_span_measures_injected_clock():
    ticks = iter([10.0, 13.5])
    rec = obs.Recorder(clock=lambda: next(ticks))
    with rec.span("work", round=2):
        pass
    (row,) = rec.rows
    assert row["name"] == "work" and row["round"] == 2
    assert row["wall_s"] == pytest.approx(3.5)


def test_time_base_shifts_simulated_instants_not_wall():
    rec = obs.Recorder()
    rec.time_base = 100.0
    rec.event("e", t=1.0)
    rec.span_at("p", 2.0, 3.0, wall_s=0.25)
    rec.flows("bt", [0], [1], [0.5], [0.75])
    ev, sp, fl = rec.rows
    assert ev["t"] == 101.0
    assert (sp["t0"], sp["t1"]) == (102.0, 103.0)
    assert sp["wall_s"] == 0.25          # wall durations are not shifted
    assert fl["t_start"][0] == 100.5 and fl["t_end"][0] == 100.75


def test_set_ctx_merges_and_removes():
    rec = obs.Recorder()
    rec.set_ctx(round=1)
    rec.event("a")
    rec.set_ctx(round=None)
    rec.event("b")
    a, b = rec.rows
    assert a["round"] == 1 and "round" not in b


def test_metrics_registry_counter_gauge_hist():
    rec = obs.Recorder()
    rec.counter("c")
    rec.counter("c", 2.5)
    rec.gauge("g", 1.0)
    rec.gauge("g", 7.0)
    rec.hist("h", 3)
    rec.hist("h", np.array([1.0, 2.0]))
    assert rec.metrics["c"] == {"metric": "counter", "value": 3.5}
    assert rec.metrics["g"] == {"metric": "gauge", "value": 7.0}
    assert rec.metrics["h"]["values"] == [3.0, 1.0, 2.0]


# ---------------------------------------------------------------------------
# measured_clock (the set_clock leak fix)
# ---------------------------------------------------------------------------

def test_measured_clock_installs_and_restores_both_modules():
    assert sim_mod._clock() == 0.0 and jit_engine._clock() == 0.0
    with measured_clock() as clk:
        assert clk is time.perf_counter
        assert sim_mod._clock is clk and jit_engine._clock is clk
    assert sim_mod._clock() == 0.0 and jit_engine._clock() == 0.0


def test_measured_clock_restores_on_exception():
    """The latent leak this replaces: an exception between paired
    set_clock calls left the host clock installed in the sim layer."""
    with pytest.raises(ValueError):
        with measured_clock():
            raise ValueError("bench blew up")
    assert sim_mod._clock is sim_mod._zero_clock
    assert jit_engine._clock() == 0.0


# ---------------------------------------------------------------------------
# JSONL schema golden (n=16 event-engine round)
# ---------------------------------------------------------------------------

def test_jsonl_golden_round_trip_and_schema(tmp_path):
    rec, res = _record_round()
    path = tmp_path / "round.jsonl"
    n = obs.write_jsonl(rec, path)
    rows = obs.read_jsonl(path)
    assert len(rows) == n
    assert obs.validate_rows(rows) == []

    # Structural golden: header first, then every phase span exactly
    # once, tracker events matching the engine's control log, and flow
    # batches on all three foreground tracks.
    assert rows[0]["kind"] == "header"
    assert rows[0]["version"] == 1 and rows[0]["meta"] == {"test": "obs"}
    spans = [r["name"] for r in rows if r["kind"] == "span"]
    assert sorted(spans) == ["round.bt", "round.emit", "round.spray",
                             "round.total", "round.warmup"]
    cycles = [r for r in rows if r.get("name") == "tracker.cycle"]
    setups = [r for r in rows if r.get("name") == "tracker.spray_setup"]
    # the tracker ledger counts spray setup as a cycle entry (slot=-1)
    assert len(cycles) + len(setups) == res.tracker_log["n_cycles"]
    assert len(setups) == 1
    tracks = {r["track"] for r in rows if r["kind"] == "flows"}
    assert {"spray", "warmup", "bt"} <= tracks
    # seq strictly increasing over the recorded (non-header/metric) rows
    seqs = [r["seq"] for r in rows if "seq" in r]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    # metric registry present and trailing
    kinds = [r["kind"] for r in rows]
    first_metric = kinds.index("metric")
    assert set(kinds[first_metric:]) == {"metric"}
    names = {r["name"] for r in rows if r["kind"] == "metric"}
    assert {"net.flows_solved", "net.chunks_moved", "net.bytes_moved",
            "tracker.control_s", "fairshare.transport_calls",
            "sched.warmup_grants_per_slot"} <= names


def test_chunk_accounting_matches_trace():
    rec, res = _record_round()
    moved = rec.metrics["net.chunks_moved"]["value"]
    assert moved == len(res.log)
    assert rec.metrics["net.bytes_moved"]["value"] == \
        moved * CFG.chunk_bytes


def test_tracker_control_plane_audit():
    """The recorded control-plane seconds equal RoundMetrics.control_s
    EXACTLY: the counter accumulates the same float sequence the
    tracker's own control_s does."""
    rec, res = _record_round()
    assert rec.metrics["tracker.control_s"]["value"] == \
        res.metrics.control_s
    # ... and the per-cycle events carry the same total
    costs = sum(r.get("cost_s", 0.0) for r in rec.rows
                if r.get("name", "").startswith("tracker."))
    assert costs == pytest.approx(res.metrics.control_s)


# ---------------------------------------------------------------------------
# Perfetto export
# ---------------------------------------------------------------------------

def test_perfetto_trace_loads_and_covers_all_tracks(tmp_path):
    rec, res = _record_round()
    out = tmp_path / "trace.json"
    n = obs.write_perfetto(rec, out)
    trace = json.loads(out.read_text())      # valid chrome-tracing JSON
    ev = trace["traceEvents"]
    assert len(ev) == n and trace["displayTimeUnit"] == "ms"
    phases = {e["ph"] for e in ev}
    assert {"X", "M"} <= phases
    # phase spans on pid 0, peer flows on pid 1, tracker on pid 2
    assert any(e["pid"] == 0 and e["ph"] == "X"
               and e["name"] == "round.warmup" for e in ev)
    flow_cats = {e["cat"] for e in ev
                 if e["pid"] == 1 and e["ph"] == "X"}
    assert {"spray", "warmup", "bt"} <= flow_cats
    assert any(e["pid"] == 2 and e["ph"] == "X" for e in ev)
    # every ts/dur is finite and non-negative
    for e in ev:
        if e["ph"] == "X":
            assert np.isfinite(e["ts"]) and e["dur"] >= 0.0
    names = {e["args"]["name"] for e in ev if e["ph"] == "M"
             and e["name"] == "process_name"}
    assert names == {"round phases", "peers (sender tracks)",
                     "tracker control plane"}


# ---------------------------------------------------------------------------
# report: metrics reproduced from the recording alone
# ---------------------------------------------------------------------------

def test_report_reproduces_round_metrics():
    rec, res = _record_round()
    s = obs.summarize(obs.to_jsonl_rows(rec))
    (r0,) = s["rounds"].values()
    m = res.metrics
    assert r0["t_warm_s"] == pytest.approx(m.t_warm_s, abs=1e-9)
    assert r0["t_round_s"] == pytest.approx(m.t_round_s, abs=1e-9)
    assert r0["warmup_share_s"] == pytest.approx(m.warmup_share_s,
                                                 abs=1e-9)
    assert s["totals"]["control_s"] == m.control_s
    assert s["slowest_peers"], "flow batches must yield peer activity"
    text = obs.format_report(s)
    assert "warmup_share" in text and "slowest peers" in text


def test_session_recording_spans_rounds_on_one_wall_clock():
    """Multi-round session: per-round rows carry the round index and
    land at the session offsets; the report reproduces every round's
    wall-clock metrics."""
    with obs.recording() as rec:
        ses = SwarmSession(CFG, churn_rate=0.15, time_engine="event",
                           net=NET)
        ses.run(3)
    rows = obs.to_jsonl_rows(rec)
    assert obs.validate_rows(rows) == []
    starts = [r for r in rows if r.get("name") == "session.round_start"]
    ends = [r for r in rows if r.get("name") == "session.round_end"]
    assert [r["round"] for r in starts] == [0, 1, 2]
    # round r's rows start at the session offset of round r
    assert [r["t"] for r in starts] == pytest.approx(ses.offsets[:3])
    assert [r["t"] for r in ends] == pytest.approx(ses.offsets[1:])
    s = obs.summarize(rows)
    wc = ses.wall_clock()
    for r in range(3):
        assert s["rounds"][r]["t_warm_s"] == pytest.approx(
            wc["t_warm_s"][r], abs=1e-9)
        assert s["rounds"][r]["t_round_s"] == pytest.approx(
            wc["t_round_s"][r], abs=1e-9)
    assert s["counters"]["session.rounds"] == 3.0


def test_async_experiment_records_merges_and_staleness():
    """The async runner's merge instants, staleness histogram, and drop
    counter in the recording mirror AsyncResult exactly."""
    from repro.fl.asyncfl import AsyncConfig, run_async_experiment
    from repro.fl.client import LocalSpec
    from repro.fl.runner import FLConfig
    tiny = FLConfig(dataset="synth-mnist", n_clients=6, rounds=3,
                    n_train=600, n_test=200, min_degree=3, seed=3,
                    local=LocalSpec(epochs=1, batch_size=32, lr=0.05))
    acfg = AsyncConfig(buffer_k=2, max_staleness=2, overlap=True,
                       round_slots=2, time_engine="event", net=NET,
                       evolve_overlay=True)
    with obs.recording() as rec:
        out = run_async_experiment(tiny, acfg)
    merges = [r for r in rec.rows if r.get("name") == "async.merge"]
    assert [e["merged"] for e in merges] == \
        [m for m in out.merged if m > 0]
    hist = rec.metrics.get("async.staleness", {"values": []})["values"]
    assert sorted(int(v) for v in hist) == sorted(
        s for s, c in out.staleness_hist.items() for _ in range(c))
    assert rec.metrics.get("async.dropped",
                           {"value": 0.0})["value"] == out.dropped


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_report_validate_perfetto(tmp_path):
    rec, _res = _record_round()
    path = tmp_path / "round.jsonl"
    obs.write_jsonl(rec, path)
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))

    def run(*argv):
        return subprocess.run(
            [sys.executable, "-m", "repro.obs", *argv], env=env,
            capture_output=True, text=True, timeout=120)

    p = run("validate", str(path))
    assert p.returncode == 0 and "0 violation(s)" in p.stdout
    p = run("report", str(path))
    assert p.returncode == 0 and "warmup_share" in p.stdout
    p = run("report", str(path), "--json")
    assert "rounds" in json.loads(p.stdout)
    out = tmp_path / "trace.json"
    p = run("perfetto", str(path), str(out))
    assert p.returncode == 0
    assert json.loads(out.read_text())["traceEvents"]
    # validate flags a corrupt recording
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"kind": "event", "name": 3}\n')
    p = run("validate", str(bad))
    assert p.returncode == 1 and "violation" in p.stdout


# ---------------------------------------------------------------------------
# the zero-overhead-when-disabled bound
# ---------------------------------------------------------------------------

def test_disabled_recorder_overhead_under_two_percent():
    """An n=100 warm-up with the NullRecorder installed must not pay
    more than 2% for the instrumentation hooks.  The disabled path per
    site is one obs.get() + one ``enabled`` attribute check; time a
    generous multiple of the sites the run executes and compare to the
    measured warm-up wall time."""
    cfg = SwarmConfig(n=100, chunks_per_update=16, min_degree=6,
                      s_max=3000, seed=5)
    sim = RoundSimulator(cfg, time_engine="event", net=NET)
    t0 = time.perf_counter()
    res = sim.run(warmup_only=True)
    wall = time.perf_counter() - t0
    assert res.metrics.t_warm_s > 0

    # Generous upper bound on disabled-path hook executions: every
    # warm-up slot touches a handful of sites; 20x the slot budget
    # covers the per-cycle engine/tracker/fairshare hooks too.
    n_sites = 20 * int(res.metrics.t_warm)
    rec = obs.get()
    assert rec.enabled is False
    t0 = time.perf_counter()
    for _ in range(max(n_sites, 1000)):
        r = obs.get()
        if r.enabled:
            r.counter("x")          # never taken on the disabled path
    hook_s = time.perf_counter() - t0
    assert hook_s < 0.02 * wall, (
        f"disabled-recorder hooks cost {hook_s:.6f}s against a "
        f"{wall:.4f}s warm-up ({hook_s / wall:.2%})")
