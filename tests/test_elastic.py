"""Elastic re-meshing: the same model code must produce valid shardings
on ANY mesh (clients join/leave across FL rounds -> pod counts and
slice shapes change; paper §III-E cross-round churn)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import init_params
from repro.sharding.api import DEFAULT_RULES, param_specs


class FakeMesh:
    def __init__(self, shape, names):
        self.devices = np.empty(shape)
        self.axis_names = names


MESHES = [
    FakeMesh((16, 16), ("data", "model")),
    FakeMesh((2, 16, 16), ("pod", "data", "model")),
    FakeMesh((4, 8), ("data", "model")),
    FakeMesh((8, 4, 2), ("pod", "data", "model")),
    FakeMesh((1, 1), ("data", "model")),
]


def _axis_size(mesh, name):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(sizes.get(name, 1))


@pytest.mark.parametrize("arch", ["gemma2-2b", "olmoe-1b-7b",
                                  "xlstm-350m", "granite-moe-1b-a400m"])
def test_specs_valid_on_every_mesh(arch):
    cfg = get_config(arch, reduced=False)
    params = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))
    for mesh in MESHES:
        specs = param_specs(params, mesh, DEFAULT_RULES)
        leaves = jax.tree_util.tree_leaves(
            params)
        spec_leaves = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        assert len(leaves) == len(spec_leaves)
        for leaf, spec in zip(leaves, spec_leaves):
            for i, part in enumerate(spec):
                if part is None:
                    continue
                names = part if isinstance(part, tuple) else (part,)
                prod = int(np.prod([_axis_size(mesh, a) for a in names]))
                assert leaf.shape[i] % prod == 0, (
                    f"{arch}: dim {i} of {leaf.shape} not divisible by "
                    f"{names} on mesh {mesh.devices.shape}")


def test_granite_vocab_never_sharded_16way():
    """vocab 49155 is indivisible by 16 — the filter must leave it
    replicated rather than erroring (elastic-mesh contract)."""
    cfg = get_config("granite-moe-1b-a400m")
    params = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))
    specs = param_specs(params, FakeMesh((16, 16), ("data", "model")),
                        DEFAULT_RULES)
    embed_spec = specs["embed"]
    assert embed_spec[0] is None
