"""Attribution attacks + ASR (paper §IV-C, §V-D): hardening ordering,
defense ablation, collusion pooling."""
import numpy as np

from repro.core import SwarmConfig, simulate_round
from repro.core.attacks import (random_guess_baseline, run_all_attacks)


def _asr(seed=0, n=24, K=24, observers=6, **overrides):
    cfg = SwarmConfig(n=n, chunks_per_update=K, s_max=5000, seed=seed,
                      **overrides)
    res = simulate_round(cfg)
    obs = np.arange(observers)
    return run_all_attacks(res.log, obs, cfg.chunks_per_update)


def test_no_defense_attribution_near_perfect():
    """Fig. 6: without hardening, Sequential Greedy wins almost always
    (early transfers are owner chunks)."""
    rep = _asr(enable_preround=False, enable_timelag=False,
               enable_gating=False, enable_nonowner_first=False)
    assert rep["sequence"].max_asr > 0.8


def test_full_defense_suppresses_sequence_attack():
    base = _asr(enable_preround=False, enable_timelag=False,
                enable_gating=False, enable_nonowner_first=False)
    full = _asr()
    assert full["sequence"].max_asr < base["sequence"].max_asr
    # paper's qualitative target: near neighborhood random guessing
    guess = random_guess_baseline(10)
    assert full["sequence"].mean_asr < 4 * guess


def test_single_defenses_insufficient_combined_strong():
    """Fig. 6's operative conclusion: no single defense suffices (each
    leaves mean Sequential ASR near-perfect under rarest-first
    scheduling); the combined stack drives it to the 1/m guessing
    regime.  (PR-alone separation is scheduler-sensitive — see
    EXPERIMENTS.md §Deviations.)"""
    singles = {
        "pr": _asr(seed=1, enable_timelag=False, enable_gating=False,
                   enable_nonowner_first=False),
        "tl": _asr(seed=1, enable_preround=False, enable_gating=False,
                   enable_nonowner_first=False),
        "k": _asr(seed=1, enable_preround=False, enable_timelag=False),
    }
    full = _asr(seed=1)
    for name, rep in singles.items():
        assert full["sequence"].mean_asr < rep["sequence"].mean_asr, name
    # full stack approaches neighborhood random guessing (~1/m = 0.1)
    assert full["sequence"].mean_asr < 0.2


def test_collusion_pooling_increases_any_correct():
    cfg = SwarmConfig(n=24, chunks_per_update=24, s_max=5000, seed=2)
    res = simulate_round(cfg)
    solo = run_all_attacks(res.log, np.arange(3), 24, pooled=False)
    pooled = run_all_attacks(res.log, np.arange(12), 24, pooled=True)
    # pooling more observers can only see more transfers
    assert pooled["count"].n_decisions >= 0
    assert 0.0 <= pooled["count"].max_asr <= 1.0
    assert 0.0 <= solo["count"].max_asr <= 1.0


def test_attacks_only_see_protocol_signals():
    """Attacks never read owner ground truth: shuffling owner labels in
    the log must not change decisions (they use chunk // K only)."""
    cfg = SwarmConfig(n=16, chunks_per_update=16, s_max=4000, seed=3)
    res = simulate_round(cfg)
    obs = np.arange(4)
    r1 = run_all_attacks(res.log, obs, 16)
    log2 = dict(res.log)
    log2["owner"] = np.zeros_like(res.log["owner"])   # corrupt labels
    r2 = run_all_attacks(log2, obs, 16)
    for k in r1:
        assert r1[k].max_asr == r2[k].max_asr


def test_density_reduces_asr():
    """Fig. 7: denser overlays reduce max ASR (more candidate senders)."""
    sparse = _asr(seed=4, min_degree=4)
    dense = _asr(seed=4, min_degree=12)
    assert (dense["sequence"].max_asr
            <= sparse["sequence"].max_asr + 0.10)
