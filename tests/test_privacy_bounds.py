"""Property tests for the unlinkability analysis (paper §IV, Eq. 1-5).

Hypothesis sweeps the mechanism knobs and asserts the system invariants:
Eq. (1) holds transfer-by-transfer in the simulator's log; the closed
forms are monotone in the directions the analysis claims; collusion can
loosen mixing but never beat the gating cap.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    # Seeded-sweep fallback so the Eq. (1) empirical checks still run
    # where hypothesis isn't installed: each strategy draws from a
    # deterministic rng and @given parametrizes over N joint samples.
    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _St:
        @staticmethod
        def integers(lo, hi):
            return _Strategy(lambda r: int(r.integers(lo, hi + 1)))

        @staticmethod
        def floats(lo, hi):
            return _Strategy(lambda r: float(lo + (hi - lo) * r.random()))

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda r: seq[int(r.integers(len(seq)))])

    st = _St()

    def given(**strategies):
        def deco(fn):
            # crc32, not hash(): PYTHONHASHSEED would make the sweep
            # non-reproducible across runs
            import zlib
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            # 10 cases matches the tightest @settings(max_examples=10)
            # in this module (the shim's settings() is a no-op, so the
            # sweep size must respect the heaviest test's budget).
            cases = [
                {k: s.draw(rng) for k, s in strategies.items()}
                for _ in range(10)
            ]
            @pytest.mark.parametrize("kw", cases)
            def wrapper(kw):
                fn(**kw)
            wrapper.__name__ = fn.__name__
            return wrapper
        return deco

    def settings(**kw):
        return lambda fn: fn

from repro.core import SwarmConfig, simulate_round
from repro.core import privacy


# ----------------------------------------------------------------------
# Closed-form bound properties
# ----------------------------------------------------------------------

@given(kappa=st.integers(1, 8), k=st.integers(1, 500))
def test_eq1_cap_range(kappa, k):
    cap = privacy.per_transfer_cap(kappa, k)
    assert 0.0 < cap <= 1.0
    if k >= kappa:
        assert cap == pytest.approx(kappa / k)


@given(kappa=st.integers(1, 4), mu=st.floats(0, 200),
       m=st.floats(0, 50), q=st.floats(0.01, 1.0),
       eps=st.floats(0.01, 0.99))
def test_eq2_tightens_with_mass(kappa, mu, m, q, eps):
    """More spray/lag mass -> smaller (tighter) posterior bound."""
    b1, e1 = privacy.high_prob_posterior_bound(kappa, mu, m, 3, q, eps)
    b2, e2 = privacy.high_prob_posterior_bound(kappa, mu + 10, m + 5, 3,
                                               q, eps)
    assert b2 <= b1 + 1e-12
    assert 0.0 <= e1 <= 1.0 and 0.0 <= e2 <= 1.0


@given(kappa=st.integers(1, 4), k=st.integers(2, 300),
       x=st.floats(0, 500), rho=st.floats(0, 1), phi=st.floats(0, 1))
def test_eq3_never_beats_gating_cap(kappa, k, x, rho, phi):
    """Collusion loosens mixing but cannot beat kappa/k (paper §IV-B)."""
    b = privacy.alliance_filter_bound(kappa, k, x, rho, phi)
    assert b <= privacy.per_transfer_cap(kappa, k) + 1e-12
    # stronger coalition (phi up) can only weaken privacy:
    b_weak = privacy.alliance_filter_bound(kappa, k, x, rho, 0.0)
    assert b_weak <= b + 1e-12


@given(s=st.integers(1, 50), kappa=st.integers(1, 3),
       k=st.integers(2, 200), x=st.floats(0, 100))
def test_eq5_union_bound(s, kappa, k, x):
    one = privacy.repeated_observation_bound(1, kappa, k, x, 0.0, 0.0)
    many = privacy.repeated_observation_bound(s, kappa, k, x, 0.0, 0.0)
    assert many <= min(1.0, s * one) + 1e-12
    assert many >= one - 1e-12


@given(t_lag=st.integers(1, 20))
def test_lead_probability(t_lag):
    p = privacy.lead_probability(t_lag)
    assert 0.0 <= p < 0.5


def test_chernoff_tail_monotone():
    taus = [privacy.chernoff_lower_tail(mu, 0.5) for mu in (1, 5, 20, 80)]
    assert all(a >= b for a, b in zip(taus, taus[1:]))


# ----------------------------------------------------------------------
# Empirical Eq. (1) on simulated rounds (the system invariant)
# ----------------------------------------------------------------------

@settings(deadline=None, max_examples=10)
@given(seed=st.integers(0, 10_000),
       scheduler=st.sampled_from(
           ["greedy_fastest_first", "random_fifo", "random_fastest_first"]))
def test_eq1_holds_in_simulation(seed, scheduler):
    cfg = SwarmConfig(n=16, chunks_per_update=24, s_max=3000, seed=seed,
                      scheduler=scheduler)
    res = simulate_round(cfg)
    assert privacy.check_eq1(res.log, cfg.owner_throttle, cfg.k_gate)


def test_eq1_violated_without_gating():
    """Ablation: with gating off, early transfers exceed the cap."""
    cfg = SwarmConfig(n=16, chunks_per_update=24, s_max=3000, seed=3,
                      enable_gating=False, enable_preround=False,
                      enable_timelag=False)
    res = simulate_round(cfg)
    post = privacy.empirical_posteriors(res.log)
    cap = privacy.per_transfer_cap(cfg.owner_throttle, cfg.k_gate)
    assert (post > cap).any()          # owner-biased early transfers


def test_spray_mean_regular_overlay():
    from repro.core.overlay import random_overlay
    rng = np.random.default_rng(0)
    adj = random_overlay(30, 8, 0.0, rng)
    mus = [privacy.spray_mean_adj(10, adj, u) for u in range(30)]
    # near-regular overlay: mu_u ~= sigma (paper §IV-A)
    assert abs(np.mean(mus) - 10) < 1.5
