"""Session-layer policy hooks: churn-aware spray budgets and geometric
rejoin delays (ROADMAP session follow-ups)."""
import numpy as np
import pytest

from repro.core import (ChurnAwareSpray, ChurnModel, SwarmConfig,
                        SwarmSession, privacy)


def _cfg(**kw):
    base = dict(n=20, chunks_per_update=16, min_degree=5, s_max=5000,
                seed=3)
    base.update(kw)
    return SwarmConfig(**base)


# ---------------------------------------------------------------------------
# churn-aware spray budgets
# ---------------------------------------------------------------------------

def _spray_session(rounds=8, seed=3, leave=0.25):
    cfg = _cfg(seed=seed)
    ses = SwarmSession(cfg, churn=ChurnModel(leave_prob=leave,
                                             rejoin_after=1),
                       spray_policy=ChurnAwareSpray())
    return ses, ses.run(rounds)


def test_churn_aware_spray_preserves_mass_and_legality():
    """Every active source still contributes sigma spray chunks per
    round (the Eq. 1 mixing input is untouched) and the plan honors
    spray legality: non-neighbor targets, gating cap still satisfied."""
    ses, recs = _spray_session()
    sigma = ses.cfg.spray_copies
    for rec in recs:
        tr = rec.result.log
        spray = tr.phase == 0
        assert int(spray.sum()) == rec.active_ids.size * sigma
        assert len(rec.spray_plan.src) == rec.active_ids.size * sigma
        # ephemeral tunnels only reach non-neighbors
        assert not rec.result.adj[tr.sender[spray],
                                  tr.receiver[spray]].any()
        assert privacy.check_eq1(tr, ses.cfg.owner_throttle,
                                 ses.cfg.k_gate)


def test_rejoiner_resprays_only_dropped_coverage():
    """Round 0 is all fresh tunnels; afterwards fresh tunnels shrink to
    the churn-induced delta, and a rejoiner re-sprays at most sigma —
    only offsets whose holder left while it was absent."""
    ses, recs = _spray_session()
    sigma = ses.cfg.spray_copies
    n0 = recs[0].active_ids.size
    assert recs[0].spray_plan.fresh.all()          # cold start
    later_fresh = sum(int(r.spray_plan.fresh.sum()) for r in recs[1:])
    later_total = sum(len(r.spray_plan.src) for r in recs[1:])
    assert later_fresh < later_total               # tunnels are reused
    # naive budgeting would open sigma * n_active fresh tunnels/round
    naive = sum(r.active_ids.size for r in recs[1:]) * sigma
    assert later_fresh < 0.8 * naive
    saw_partial_rejoin = False
    for rec in recs[1:]:
        fresh_per_src = rec.spray_plan.fresh_counts(rec.active_ids.size)
        assert (fresh_per_src <= sigma).all()
        for g in rec.rejoined:
            i = int(np.searchsorted(rec.active_ids, g))
            saw_partial_rejoin |= fresh_per_src[i] < sigma
    # some rejoiner found surviving coverage (re-sprayed a strict subset)
    assert saw_partial_rejoin


def test_churn_aware_spray_needs_evolving_overlay():
    ses = SwarmSession(_cfg(), spray_policy=ChurnAwareSpray())
    with pytest.raises(ValueError, match="evolv"):
        ses.next_round()


def test_default_spray_unchanged_without_policy():
    """No spray policy: the zero-churn session stays bit-identical to
    the historical simulate_round loop (regression guard around the
    spray_plan plumbing)."""
    from repro.core import simulate_round
    cfg = _cfg()
    ses = SwarmSession(cfg)
    rec = ses.next_round()
    ref = simulate_round(cfg.replace(seed=cfg.seed * 1000))
    for key in ("slot", "sender", "receiver", "chunk", "phase"):
        assert np.array_equal(rec.result.log[key], ref.log[key]), key


# ---------------------------------------------------------------------------
# geometric rejoin delays
# ---------------------------------------------------------------------------

def test_geometric_rejoin_varies_delays_mean_matches():
    cfg = _cfg(seed=5)
    churn = ChurnModel(leave_prob=0.3, rejoin_after=2,
                       rejoin_dist="geometric")
    ses = SwarmSession(cfg, churn=churn)
    delays = []
    for _ in range(40):
        r = ses.round_idx
        before = ses.rejoin_at.copy()
        ses.next_round()
        newly = np.flatnonzero((ses.rejoin_at >= 0) & (before < 0))
        delays += (ses.rejoin_at[newly] - r).tolist()
    delays = np.asarray(delays)
    assert delays.size >= 20
    assert (delays >= 1).all()
    assert len(set(delays.tolist())) > 1          # heterogeneous
    assert abs(delays.mean() - churn.rejoin_after) < 1.0


def test_participation_exact_under_geometric_rejoin():
    ses = SwarmSession(_cfg(seed=7), churn=ChurnModel(
        leave_prob=0.25, rejoin_after=3, rejoin_dist="geometric"))
    ses.run(8)
    part = ses.participation()
    for rec, p in zip(ses.history, part):
        assert p == rec.active_ids.size / ses._pop_at(rec)
    assert (part > 0).all() and (part <= 1).all()


def test_fixed_rejoin_stream_unperturbed():
    """rejoin_dist='fixed' (default) draws nothing extra: churn
    trajectories are bit-identical to the pre-knob behaviour."""
    def mk(dist):
        return SwarmSession(_cfg(seed=9), churn=ChurnModel(
            leave_prob=0.3, rejoin_after=2, rejoin_dist=dist))
    a, b = mk("fixed"), mk("fixed")
    ra, rb = a.run(6), b.run(6)
    for x, y in zip(ra, rb):
        assert np.array_equal(x.active_ids, y.active_ids)


def test_unknown_rejoin_dist_rejected():
    with pytest.raises(ValueError, match="rejoin_dist"):
        ChurnModel(rejoin_dist="uniform")


# ---------------------------------------------------------------------------
# FL runner wiring
# ---------------------------------------------------------------------------

def test_runner_accepts_churn_spray_and_geometric_rejoin():
    from repro.fl.client import LocalSpec
    from repro.fl.runner import FLConfig, run_experiment
    cfg = FLConfig(dataset="synth-cifar", model="mlp", dist="dir0.5",
                   n_clients=8, rounds=4,
                   local=LocalSpec(epochs=1, batch_size=32, lr=0.03),
                   n_train=1200, n_test=300, seed=0, min_degree=4,
                   churn_rate=0.3, rejoin_after=1,
                   rejoin_dist="geometric", spray_budget="churn_aware")
    res = run_experiment("fltorrent", cfg)
    assert res.agreement and res.caught_up
    assert any(p < 1.0 for p in res.participation)
    with pytest.raises(ValueError, match="spray_budget"):
        run_experiment("fltorrent",
                       FLConfig(n_clients=8, rounds=1,
                                spray_budget="nope"))
