"""Warm-up mechanics: termination threshold, ablations, K-sweep
monotonicity, fault tolerance (paper §III-B/E, Figs. 4-5)."""
import numpy as np

from repro.core import SwarmConfig, simulate_round


def test_warmup_threshold_reached():
    cfg = SwarmConfig(n=16, chunks_per_update=24, s_max=4000, seed=0)
    res = simulate_round(cfg)
    # at s_BT every active client holds >= k_term chunks: infer from log
    log = res.log
    held = np.full(cfg.n, cfg.chunks_per_update, np.int64)
    warm = log["phase"] <= 1
    np.add.at(held, log["receiver"][warm], 1)
    assert (held >= cfg.k_term).all()


def test_k_sweep_monotone():
    """Fig. 5: warm-up duration grows monotonically with K."""
    t = []
    for pct in (0.05, 0.10, 0.25):
        cfg = SwarmConfig(n=16, chunks_per_update=24, s_max=6000, seed=1,
                          warmup_threshold_pct=pct)
        t.append(simulate_round(cfg).metrics.t_warm)
    assert t[0] <= t[1] <= t[2]
    assert t[2] > t[0]


def test_ablation_toggles_run():
    """Fig. 4/6 ablations: every defense subset simulates cleanly."""
    for pr in (False, True):
        for tl in (False, True):
            for gate in (False, True):
                cfg = SwarmConfig(n=12, chunks_per_update=16, s_max=4000,
                                  seed=2, enable_preround=pr,
                                  enable_timelag=tl, enable_gating=gate)
                res = simulate_round(cfg)
                assert not res.metrics.failed_open


def test_spray_seeds_nonneighbors():
    cfg = SwarmConfig(n=16, chunks_per_update=24, s_max=4000, seed=3)
    res = simulate_round(cfg)
    log = res.log
    spray = log["phase"] == 0
    assert spray.sum() == cfg.n * cfg.spray_copies
    # spray targets are non-neighbors of the source (ephemeral tunnels)
    assert not res.adj[log["sender"][spray], log["receiver"][spray]].any()


def test_dropout_fault_tolerance():
    """§III-E: a dropped client doesn't block the round; aggregation
    proceeds over the remaining reconstructable set."""
    cfg = SwarmConfig(n=12, chunks_per_update=16, s_max=4000, seed=4)
    res = simulate_round(cfg, dropouts={2: [0, 1]})
    assert not res.active[0] and not res.active[1]
    # every *surviving* client reconstructs the same active set
    surv = np.flatnonzero(res.active)
    recon = res.reconstructable[surv]
    assert (recon[0] == recon).all()
    assert recon[0].sum() >= 1        # |A_v^r| >= 1


def test_fail_open_on_impossible_deadline():
    cfg = SwarmConfig(n=16, chunks_per_update=32, s_max=2, seed=5)
    res = simulate_round(cfg)
    assert res.metrics.failed_open    # liveness: falls open to BT


def test_timelag_within_bounds():
    cfg = SwarmConfig(n=16, chunks_per_update=24, s_max=4000, seed=6,
                      lag_slots=3)
    res = simulate_round(cfg)
    log = res.log
    warm = log["phase"] == 1
    # no sender transmits before its lag expired: earliest sends per
    # sender happen at slot >= 0 and lags < lag_slots
    first_send = {}
    for s, snd in zip(log["slot"][warm], log["sender"][warm]):
        first_send.setdefault(int(snd), int(s))
    assert min(first_send.values()) >= 0
    assert max(first_send.values()) >= 1   # some senders lagged
