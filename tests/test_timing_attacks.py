"""Timing side-channel attribution (repro.net observation surface):
ASR far above 1/m on undefended continuous-time traces, back at the
1/m floor under the full warm-up stack (ISSUE 5 acceptance)."""
import numpy as np

from repro.core import SwarmConfig, simulate_round
from repro.core.attacks import (random_guess_baseline, release_instants,
                                timing_attribution)
from repro.net import NetConfig

NET = NetConfig(tracker_rtt_s=0.1)


def _round(seed=0, **overrides):
    cfg = SwarmConfig(n=24, chunks_per_update=24, s_max=5000, seed=seed,
                      **overrides)
    return cfg, simulate_round(cfg, time_engine="event", net=NET)


def test_timing_attack_wins_without_defenses():
    """Lags disabled (and the rest of the stack off): a sender's first
    bytes are its own chunks, and arrival instants expose them."""
    cfg, res = _round(enable_preround=False, enable_timelag=False,
                      enable_gating=False, enable_nonowner_first=False)
    rep = timing_attribution(res.log, np.arange(6),
                             cfg.chunks_per_update)
    floor = random_guess_baseline(cfg.min_degree)
    assert rep.n_decisions > 0
    assert rep.mean_asr > 5 * floor          # >> 1/m
    assert rep.max_asr > 0.8


def test_timing_attack_floored_by_full_stack():
    """Spray + gating + randomized lags drive the timing channel back
    to the neighborhood guessing floor."""
    cfg, res = _round()
    rep = timing_attribution(res.log, np.arange(6),
                             cfg.chunks_per_update)
    floor = random_guess_baseline(cfg.min_degree)
    assert rep.mean_asr <= 2 * floor
    assert rep.max_asr <= 4 * floor


def test_full_stack_no_worse_than_lagless_stack():
    """Randomized lags may only help: the full stack's timing ASR does
    not exceed the same stack with lags disabled (seed-averaged)."""
    lagged, lagless = [], []
    for seed in range(3):
        cfg, res = _round(seed=seed)
        rep = timing_attribution(res.log, np.arange(6),
                                 cfg.chunks_per_update)
        lagged.append(rep.mean_asr)
        cfg2, res2 = _round(seed=seed, enable_timelag=False)
        rep2 = timing_attribution(res2.log, np.arange(6),
                                  cfg2.chunks_per_update)
        lagless.append(rep2.mean_asr)
    assert np.mean(lagged) <= np.mean(lagless) + 0.05


def test_release_instants_expose_lag_randomization():
    """The channel's existence proof: inferred release instants are
    near-degenerate without lags and spread over ~lag_slots directive
    cycles with them."""
    _, res_nolag = _round(enable_timelag=False)
    _, res_lag = _round(lag_slots=4)
    obs = np.arange(24)
    rel0 = np.array(list(release_instants(res_nolag.log, obs,
                                          24).values()))
    rel1 = np.array(list(release_instants(res_lag.log, obs,
                                          24).values()))
    assert rel0.size and rel1.size
    assert np.std(rel1) > 3 * max(np.std(rel0), 1e-6)


def test_timing_attack_runs_on_slot_traces():
    """Slot-engine traces carry boundary stamps: the attack degrades
    gracefully to slot-order attribution (no crash, valid ASR)."""
    cfg = SwarmConfig(n=16, chunks_per_update=16, s_max=4000, seed=1)
    res = simulate_round(cfg)
    rep = timing_attribution(res.log, np.arange(4),
                             cfg.chunks_per_update)
    assert 0.0 <= rep.mean_asr <= 1.0


def test_timing_attack_reads_protocol_signals_only():
    """Corrupting owner ground truth must not change decisions."""
    cfg, res = _round(seed=2)
    obs = np.arange(5)
    r1 = timing_attribution(res.log, obs, cfg.chunks_per_update)
    log2 = dict(res.log)
    log2["owner"] = np.zeros_like(res.log["owner"])
    r2 = timing_attribution(log2, obs, cfg.chunks_per_update)
    assert r1.max_asr == r2.max_asr
    assert r1.mean_asr == r2.mean_asr
