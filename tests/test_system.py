"""End-to-end behaviour: aggregation semantics (paper §II-B) — all
clients agree on the FedAvg aggregate over the reconstructable set."""
import jax.numpy as jnp
import numpy as np

from repro.core import SwarmConfig, simulate_round
from repro.core.aggregation import (agreement_check, fedavg_flat,
                                    fedavg_pytree, fedavg_weights)


def test_full_dissemination_all_agree():
    cfg = SwarmConfig(n=12, chunks_per_update=16, s_max=5000, seed=0)
    res = simulate_round(cfg)
    assert res.reconstructable.all()
    rng = np.random.default_rng(0)
    updates = jnp.asarray(rng.normal(size=(cfg.n, 64)).astype(np.float32))
    weights = jnp.asarray(rng.uniform(1, 5, cfg.n).astype(np.float32))
    aggs = [fedavg_flat(updates, weights,
                        jnp.asarray(res.reconstructable[v], jnp.float32))
            for v in range(cfg.n)]
    ref = aggs[0]
    for a in aggs[1:]:
        np.testing.assert_allclose(a, ref, atol=1e-6)


def test_partial_participation_semantics():
    """Dropped sole-holder updates leave A_v^r; survivors still agree."""
    cfg = SwarmConfig(n=10, chunks_per_update=16, s_max=5000, seed=1,
                      min_degree=5,
                      enable_preround=False)   # no spray: client 0's
    res = simulate_round(cfg, dropouts={0: [0]})  # chunks can be lost
    surv = np.flatnonzero(res.active)
    recon = res.reconstructable[surv]
    assert (recon == recon[0]).all()
    assert recon[0].sum() >= len(surv) - 1


def test_fedavg_weights_mask():
    w = jnp.array([1.0, 2.0, 3.0])
    m = jnp.array([1.0, 0.0, 1.0])
    out = fedavg_weights(w, m)
    np.testing.assert_allclose(out, [0.25, 0.0, 0.75], atol=1e-6)


def test_fedavg_pytree_matches_flat():
    rng = np.random.default_rng(2)
    trees = [{"a": jnp.asarray(rng.normal(size=(8,)).astype(np.float32)),
              "b": jnp.asarray(rng.normal(size=(3, 4)).astype(np.float32))}
             for _ in range(5)]
    w = jnp.asarray(rng.uniform(1, 2, 5).astype(np.float32))
    m = jnp.ones(5)
    agg = fedavg_pytree(trees, w, m)
    flat = jnp.stack([jnp.concatenate([t["a"], t["b"].ravel()])
                      for t in trees])
    want = fedavg_flat(flat, w, m)
    got = jnp.concatenate([agg["a"], agg["b"].ravel()])
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_agreement_check_detects_divergence():
    a = {"x": jnp.ones(4)}
    b = {"x": jnp.ones(4) * 2}
    assert agreement_check([a, a])
    assert not agreement_check([a, b])
