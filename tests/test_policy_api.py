"""SchedulerPolicy plugin API (core/policy.py): golden byte-identity of
the six built-in policies vs the historical string dispatch, registry
round-trips, visibility enforcement, phase applicability, and typed
policy-owned flooding state."""
import json
import os

import numpy as np
import pytest

from repro.core import (SwarmConfig, SchedulerPolicy, SlotView,
                        VisibilityError, get_policy, policy_names,
                        register_policy, simulate_round)
from repro.core.schedulers import (CENTRALIZED, FloodingPolicy,
                                   FloodRoundState, VanillaBTPolicy)
from repro.core.state import SwarmState
from repro.core.overlay import random_overlay

from capture_golden import IMPLS, MODES, SEEDS, log_digest

HERE = os.path.dirname(os.path.abspath(__file__))
GOLDEN = json.load(open(os.path.join(HERE, "golden_schedules.json")))


def _cfg(mode, seed, impl):
    return SwarmConfig(n=16, chunks_per_update=24, s_max=5000, seed=seed,
                       scheduler=mode, scheduler_impl=impl)


# ---------------------------------------------------------------------------
# Byte-identity: new API == old string dispatch, seed for seed
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("mode", MODES)
def test_policy_schedules_byte_identical_to_golden(mode, impl):
    """All five §III-C modes + flooding reproduce the pinned pre-policy
    schedules bit-for-bit on both slot engines, by name AND instance."""
    for seed in SEEDS:
        want = GOLDEN["schedules"][f"{mode}/{impl}/{seed}"]
        by_name = simulate_round(_cfg(mode, seed, impl))
        assert log_digest(by_name.log) == want, (mode, impl, seed)
        inst = get_policy(mode)
        by_inst = simulate_round(
            _cfg(mode, seed, impl).replace(scheduler=inst))
        assert log_digest(by_inst.log) == want, (mode, impl, seed)


# ---------------------------------------------------------------------------
# Registry round-trips
# ---------------------------------------------------------------------------

def test_registry_roundtrip_name_instance_replace():
    for name in MODES:
        pol = get_policy(name)
        assert pol.name == name
        assert get_policy(pol) is pol              # instances pass through
        assert type(get_policy(type(pol))) is type(pol)   # classes too
        cfg = SwarmConfig(scheduler=name).replace(scheduler=pol)
        assert cfg.scheduler is pol
        assert cfg.replace(scheduler=pol.name).scheduler == name
    assert set(MODES) <= set(policy_names())
    assert "bt_vanilla" in policy_names()


def test_unknown_policy_rejected():
    with pytest.raises(ValueError, match="unknown scheduler"):
        get_policy("nope")
    with pytest.raises(ValueError, match="unknown scheduler"):
        simulate_round(SwarmConfig(n=12, chunks_per_update=8, s_max=50,
                                   min_degree=4, scheduler="nope"))


def test_register_policy_validates():
    with pytest.raises(TypeError):
        register_policy(dict)
    with pytest.raises(ValueError, match="non-empty"):
        register_policy(type("Anon", (SchedulerPolicy,), {}))


def test_plugin_policy_runs_by_instance_and_name():
    class HalfFlood(FloodingPolicy):
        name = "half_flood_test"
    register_policy(HalfFlood)
    res = simulate_round(SwarmConfig(n=12, chunks_per_update=12,
                                     s_max=3000, seed=1,
                                     scheduler="half_flood_test"))
    assert not res.metrics.failed_open
    res2 = simulate_round(SwarmConfig(n=12, chunks_per_update=12,
                                      s_max=3000, seed=1,
                                      scheduler=HalfFlood()))
    assert np.array_equal(res.log["chunk"], res2.log["chunk"])


# ---------------------------------------------------------------------------
# Visibility enforcement + phase applicability
# ---------------------------------------------------------------------------

def _state(seed=0, n=10, K=8):
    cfg = SwarmConfig(n=n, chunks_per_update=K, s_max=100, seed=seed)
    rng = np.random.default_rng(seed)
    adj = random_overlay(n, 4, 0.1, rng)
    up = np.full(n, 3)
    down = np.full(n, 6)
    return SwarmState(cfg, adj, up, down, rng)


def test_slotview_gates_by_visibility():
    st = _state()
    full = SlotView(st, "full")
    full.supply()                       # ok
    full.candidate_columns()
    full.availability_union()
    _ = full.state

    nbr = SlotView(st, "neighborhood")
    nbr.availability_union()            # ok
    with pytest.raises(VisibilityError):
        nbr.supply()
    with pytest.raises(VisibilityError):
        _ = nbr.state

    none = SlotView(st, "none")
    none.my_eligible(0)                 # sender self-knowledge: always ok
    none.resolve_requests(np.array([0]), np.array([0]))
    with pytest.raises(VisibilityError):
        none.availability_union()
    with pytest.raises(VisibilityError):
        none.candidate_columns()

    with pytest.raises(ValueError):
        SlotView(st, "psychic")


def test_builtin_policies_declare_paper_visibility():
    for name in CENTRALIZED:
        assert get_policy(name).visibility == "full"
    assert get_policy("distributed").visibility == "neighborhood"
    assert get_policy("flooding").visibility == "none"


def test_bt_policy_rejected_for_warmup():
    """Phase applicability: a ("bt",)-only policy cannot drive warm-up."""
    assert VanillaBTPolicy().applies_to("bt")
    assert not VanillaBTPolicy().applies_to("warmup")
    with pytest.raises(ValueError, match="warm-up"):
        simulate_round(SwarmConfig(n=12, chunks_per_update=8, s_max=50,
                                   min_degree=4, scheduler="bt_vanilla"))


# ---------------------------------------------------------------------------
# Typed flooding state (no caller-threaded dicts)
# ---------------------------------------------------------------------------

def test_flooding_state_owned_and_reset_per_round():
    pol = get_policy("flooding")
    assert isinstance(pol.round_state, FloodRoundState)
    cfg = SwarmConfig(n=12, chunks_per_update=12, s_max=3000, seed=2,
                      scheduler=pol)
    simulate_round(cfg)
    filled = len(pol.round_state.sent)
    assert filled > 0                       # the round used the memory
    pol.reset(cfg)
    assert len(pol.round_state.sent) == 0   # fresh per round
    # no-repeat invariant recorded in the typed state: every warm-up
    # (sender, receiver, chunk) push is unique within the round
    res = simulate_round(cfg)
    for (u, v), chunks in pol.round_state.sent.items():
        assert isinstance(chunks, set)
    log = res.log
    warm = log["phase"] == 1
    triples = list(zip(log["sender"][warm].tolist(),
                       log["receiver"][warm].tolist(),
                       log["chunk"][warm].tolist()))
    assert len(triples) == len(set(triples))
