"""Per-arch REDUCED-config smoke tests (assignment deliverable (f)):
one forward/train step on CPU asserting output shapes + no NaNs, plus
prefill/decode agreement for causal archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import (decode_step, forward, init_decode_cache,
                          init_params, prefill, train_loss)


def _inputs(cfg, b=2, t=32, key=None):
    key = key or jax.random.PRNGKey(0)
    if cfg.has_embedding:
        return jax.random.randint(key, (b, t), 0, cfg.vocab)
    return jax.random.normal(key, (b, t, cfg.d_model), jnp.float32)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, reduced=True)
    p = init_params(cfg, jax.random.PRNGKey(0))
    x = _inputs(cfg)
    logits = forward(cfg, p, x)
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), "NaN/Inf in logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite_grads(arch):
    cfg = get_config(arch, reduced=True)
    p = init_params(cfg, jax.random.PRNGKey(1))
    x = _inputs(cfg)
    y = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, cfg.vocab)
    loss, g = jax.value_and_grad(
        lambda pp: train_loss(cfg, pp, x, y))(p)
    assert bool(jnp.isfinite(loss))
    gn = sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
             for l in jax.tree_util.tree_leaves(g))
    assert bool(jnp.isfinite(gn)) and float(gn) > 0.0


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_config(a, reduced=True).causal])
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch, reduced=True)
    p = init_params(cfg, jax.random.PRNGKey(3))
    x = _inputs(cfg, b=2, t=24)
    logits_pf, caches = prefill(cfg, p, x, max_len=32)
    full = forward(cfg, p, x)
    np.testing.assert_allclose(np.asarray(logits_pf, np.float32),
                               np.asarray(full[:, -1], np.float32),
                               atol=2e-2, rtol=2e-2)
    tok = jnp.argmax(logits_pf, -1).astype(jnp.int32)
    lg, caches = decode_step(cfg, p, caches, tok, jnp.int32(24))
    x2 = jnp.concatenate([x, tok[:, None]], axis=1) if cfg.has_embedding \
        else None
    if x2 is not None:
        full2 = forward(cfg, p, x2)
        np.testing.assert_allclose(np.asarray(lg, np.float32),
                                   np.asarray(full2[:, -1], np.float32),
                                   atol=2e-2, rtol=2e-2)


@pytest.mark.parametrize("arch", ["xlstm-350m", "recurrentgemma-2b"])
def test_long_context_state_bounded(arch):
    """long_500k eligibility: decode state size must not grow with the
    cache length (recurrent/windowed state only)."""
    cfg = get_config(arch, reduced=True)
    c1 = jax.eval_shape(lambda: init_decode_cache(cfg, 1, 128))
    c2 = jax.eval_shape(lambda: init_decode_cache(cfg, 1, 4096))
    s1 = sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(c1))
    s2 = sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(c2))
    assert s1 == s2, "state grew with context length"


def test_encoder_is_bidirectional():
    cfg = get_config("hubert-xlarge", reduced=True)
    p = init_params(cfg, jax.random.PRNGKey(4))
    x = _inputs(cfg, b=1, t=16)
    base = forward(cfg, p, x)
    x2 = x.at[:, -1].set(x[:, -1] + 10.0)   # perturb LAST frame
    out = forward(cfg, p, x2)
    # bidirectional: early positions change too
    assert float(jnp.abs(out[:, 0] - base[:, 0]).max()) > 1e-6


def test_causal_lm_is_causal():
    cfg = get_config("qwen3-1.7b", reduced=True)
    p = init_params(cfg, jax.random.PRNGKey(5))
    x = _inputs(cfg, b=1, t=16)
    base = forward(cfg, p, x)
    x2 = x.at[:, -1].set((x[:, -1] + 1) % cfg.vocab)
    out = forward(cfg, p, x2)
    np.testing.assert_allclose(np.asarray(out[:, :-1], np.float32),
                               np.asarray(base[:, :-1], np.float32),
                               atol=1e-4)
