"""Smoke tests for the documented example entry points: the 20-line
custom policy + adversary path (examples/custom_policy.py) must keep
running as the plugin APIs evolve."""
import os
import runpy

import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_custom_policy_example_runs(capsys):
    """Run the example end to end in-process: registers the policy,
    simulates a round by name and by instance in a churny session, and
    scores both adversaries."""
    path = os.path.join(ROOT, "examples", "custom_policy.py")
    mod = runpy.run_path(path, run_name="__main__")
    out = capsys.readouterr().out
    assert "eager_mirror (by name):" in out
    assert "4-round churn session" in out
    assert "latecomer ASR=" in out
    # the module-level policy registered and is resolvable by name
    from repro.core.policy import get_policy
    pol = get_policy("eager_mirror")
    assert pol.visibility == "neighborhood"
    assert mod["EagerMirror"].name == "eager_mirror"


def test_custom_policy_respects_visibility():
    """The example's neighborhood policy must not be able to read the
    full supply matrix (the documented contract)."""
    import runpy as _runpy
    path = os.path.join(ROOT, "examples", "custom_policy.py")
    mod = _runpy.run_path(path)
    from repro.core import SwarmConfig
    from repro.core.policy import SlotView, VisibilityError
    from repro.core.simulator import RoundSimulator
    cfg = SwarmConfig(n=12, chunks_per_update=8, min_degree=4,
                      s_max=2000, seed=0,
                      scheduler=mod["EagerMirror"]())
    sim = RoundSimulator(cfg)
    view = SlotView(sim.state, "neighborhood")
    with pytest.raises(VisibilityError):
        view.supply()
    res = sim.run()
    assert res.metrics.t_warm > 0
    assert np.isfinite(res.metrics.warmup_utilization)
