"""Validate the structural HLO cost model (roofline methodology):

1. On loop-free modules it must agree with XLA's own cost_analysis.
2. On scan modules, XLA undercounts (body counted once); the structural
   model applies the known_trip_count correction and must match the
   analytic value.  This is the justification for §Roofline numbers.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as H


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_loop_free_matches_xla():
    def f(x, w1, w2):
        h = jnp.tanh(x @ w1)
        return (h @ w2).sum()

    x = jnp.ones((128, 256))
    w1 = jnp.ones((256, 512))
    w2 = jnp.ones((512, 64))
    c = _compile(f, x, w1, w2)
    mine = H.analyze(c.as_text())
    ca = H.xla_cost_analysis(c)
    assert mine.flops == pytest.approx(ca["flops"], rel=0.02)
    assert mine.hbm_bytes == pytest.approx(ca["bytes accessed"], rel=0.1)


def test_scan_trip_count_correction():
    """XLA counts a 13-iteration scan body once; we must count 13x."""
    W = jnp.ones((13, 64, 64))

    def f(x, W):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, W)
        return y.sum()

    x = jnp.ones((64, 64))
    c = _compile(f, x, W)
    xla_flops = H.xla_cost_analysis(c)["flops"]
    mine = H.analyze(c.as_text())
    analytic = 13 * 2 * 64 ** 3
    assert xla_flops < 0.2 * analytic          # XLA undercounts
    assert mine.flops == pytest.approx(analytic, rel=0.05)


def test_nested_scan_correction():
    W = jnp.ones((4, 3, 32, 32))

    def f(x, W):
        def outer(c, wrow):
            def inner(ci, w):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, wrow)
            return c, None
        y, _ = jax.lax.scan(outer, x, W)
        return y.sum()

    x = jnp.ones((32, 32))
    c = _compile(f, x, W)
    mine = H.analyze(c.as_text())
    analytic = 12 * 2 * 32 ** 3
    assert mine.flops == pytest.approx(analytic, rel=0.05)


def test_collective_bytes_factors():
    """Ring-model byte factors per collective type."""
    line_ag = ("  %ag = f32[8,128]{1,0} all-gather(%x), channel_id=1, "
               "replica_groups=[2,4]<=[8], dimensions={0}")
    ins = H.Instr("ag", "f32[8,128]{1,0}", "all-gather", line_ag)
    nb = 8 * 128 * 4
    assert H._collective_bytes(ins) == pytest.approx(nb * 3 / 4)

    line_cp = ("  %cp = bf16[64]{0} collective-permute(%x), "
               "source_target_pairs={{0,1},{1,0}}")
    ins = H.Instr("cp", "bf16[64]{0}", "collective-permute", line_cp)
    assert H._collective_bytes(ins) == pytest.approx(64 * 2)


def test_dtype_bytes_table():
    assert H._nbytes("f32[4,4]{1,0}") == 64
    assert H._nbytes("bf16[10]") == 20
    assert H._nbytes("(s32[], f32[2,2])") == 4 + 16
    assert H._nbytes("pred[8]") == 8


def test_roofline_terms_structure():
    c = H.Costs(flops=197e12, hbm_bytes=819e9, coll_bytes=50e9)
    t = H.roofline_terms(c, model_flops_global=197e12 * 256, n_chips=256)
    assert t["t_compute_s"] == pytest.approx(1.0)
    assert t["t_memory_s"] == pytest.approx(1.0)
    assert t["t_collective_s"] == pytest.approx(1.0)
    assert t["roofline_fraction"] == pytest.approx(1.0)
    assert t["useful_flops_ratio"] == pytest.approx(1.0)
