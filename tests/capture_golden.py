"""Regenerate tests/golden_schedules.json.

The golden file pins the byte-exact transfer schedules of all six
scheduler policies (x both slot engines, x seeds) and the exact ASR
numbers of the three observation attacks, as produced by the historical
string-dispatch code path.  The SchedulerPolicy / TransferTrace API must
reproduce them bit-for-bit (tests/test_policy_api.py,
tests/test_trace.py).

    PYTHONPATH=src python tests/capture_golden.py
"""
from __future__ import annotations

import hashlib
import json
import os

import numpy as np

from repro.core import SwarmConfig, simulate_round
from repro.core.attacks import run_all_attacks

HERE = os.path.dirname(os.path.abspath(__file__))
OUT = os.path.join(HERE, "golden_schedules.json")

MODES = ["random_fifo", "random_fastest_first", "greedy_fastest_first",
         "distributed", "flooding"]
IMPLS = ["batched", "loop", "jit"]
SEEDS = [1, 9]

LOG_KEYS = ("slot", "sender", "receiver", "chunk", "owner",
            "b_size", "o_size", "phase")


def log_digest(log) -> str:
    h = hashlib.sha256()
    for key in LOG_KEYS:
        arr = np.ascontiguousarray(np.asarray(log[key], dtype=np.int64))
        h.update(key.encode())
        h.update(arr.tobytes())
    return h.hexdigest()


def main():
    golden = {"schedules": {}, "attacks": {}}
    for mode in MODES:
        for impl in IMPLS:
            for seed in SEEDS:
                cfg = SwarmConfig(n=16, chunks_per_update=24, s_max=5000,
                                  seed=seed, scheduler=mode,
                                  scheduler_impl=impl)
                res = simulate_round(cfg)
                key = f"{mode}/{impl}/{seed}"
                golden["schedules"][key] = log_digest(res.log)
                print(key, golden["schedules"][key][:16])

    # Exact attack numbers (Figs. 6-7 path): loop engine, two ablations.
    for name, kw in {
        "full": {},
        "none": dict(enable_preround=False, enable_timelag=False,
                     enable_gating=False, enable_nonowner_first=False),
    }.items():
        for seed in (0, 1):
            cfg = SwarmConfig(n=24, chunks_per_update=24, s_max=5000,
                              seed=seed, scheduler_impl="loop", **kw)
            res = simulate_round(cfg)
            reps = run_all_attacks(res.log, np.arange(6), 24)
            pooled = run_all_attacks(res.log, np.arange(12), 24,
                                     pooled=True)
            key = f"{name}/{seed}"
            golden["attacks"][key] = {
                a: {"max": reps[a].max_asr, "mean": reps[a].mean_asr,
                    "n": reps[a].n_decisions,
                    "pooled_max": pooled[a].max_asr,
                    "pooled_any": pooled[a].any_correct_rate}
                for a in reps
            }
            print(key, {a: round(v["max"], 4)
                        for a, v in golden["attacks"][key].items()})

    with open(OUT, "w") as f:
        json.dump(golden, f, indent=1, sort_keys=True)
    print("wrote", OUT)


if __name__ == "__main__":
    main()
