"""Chunkwise-parallel mLSTM == per-step recurrence (the §Perf cell-1
optimization must be an exact reformulation, not an approximation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import _mlstm_chunkwise, _mlstm_step


def _sequential(q, k, v, i_pre, f_pre, state):
    xs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
          i_pre.swapaxes(0, 1), f_pre.swapaxes(0, 1))
    state, hs = jax.lax.scan(_mlstm_step, state, xs)
    return state, hs.swapaxes(0, 1)


@pytest.mark.parametrize("b,t,h,dh,chunk", [
    (2, 64, 4, 16, 16), (1, 100, 2, 8, 32), (2, 37, 3, 4, 8),
    (1, 128, 1, 32, 128),
])
def test_chunkwise_matches_sequential(b, t, h, dh, chunk):
    ks = jax.random.split(jax.random.PRNGKey(t * 7 + chunk), 6)
    q = jax.random.normal(ks[0], (b, t, h, dh)) * (dh ** -0.5)
    k = jax.random.normal(ks[1], (b, t, h, dh)) * (dh ** -0.5)
    v = jax.random.normal(ks[2], (b, t, h, dh))
    i_pre = jax.random.normal(ks[3], (b, t, h))
    f_pre = jax.random.normal(ks[4], (b, t, h)) + 1.0
    init = (jnp.zeros((b, h, dh, dh)), jnp.zeros((b, h, dh)),
            jnp.full((b, h), -1e30))

    (C1, n1, m1), h1 = _sequential(q, k, v, i_pre, f_pre, init)
    (C2, n2, m2), h2 = _mlstm_chunkwise(q, k, v, i_pre, f_pre, init,
                                        chunk=chunk, remat=False)
    h2 = h2.reshape(b, t, h, dh)
    np.testing.assert_allclose(h2, h1, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(C2, C1, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(n2, n1, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(m2, m1, atol=2e-4, rtol=2e-4)


def test_chunkwise_carries_state_across_chunks():
    """Nonzero incoming state is honoured (prefill continuation)."""
    b, t, h, dh = 1, 32, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    q = jax.random.normal(ks[0], (b, t, h, dh))
    k = jax.random.normal(ks[1], (b, t, h, dh))
    v = jax.random.normal(ks[2], (b, t, h, dh))
    i_pre = jax.random.normal(ks[3], (b, t, h))
    f_pre = jax.random.normal(ks[4], (b, t, h))
    state = (jax.random.normal(ks[5], (b, h, dh, dh)),
             jnp.abs(jax.random.normal(ks[5], (b, h, dh))),
             jnp.zeros((b, h)))
    (C1, n1, m1), h1 = _sequential(q, k, v, i_pre, f_pre, state)
    (C2, n2, m2), h2 = _mlstm_chunkwise(q, k, v, i_pre, f_pre, state,
                                        chunk=8, remat=False)
    np.testing.assert_allclose(h2.reshape(b, t, h, dh), h1, atol=2e-4,
                               rtol=2e-4)
    np.testing.assert_allclose(C2, C1, atol=2e-4, rtol=2e-4)


def test_chunkwise_differentiable():
    b, t, h, dh = 1, 32, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    q = jax.random.normal(ks[0], (b, t, h, dh))
    init = (jnp.zeros((b, h, dh, dh)), jnp.zeros((b, h, dh)),
            jnp.full((b, h), -1e30))

    def loss(q_):
        _, hs = _mlstm_chunkwise(
            q_, q_, q_, q_.sum(-1), q_.sum(-1) * 0 + 1.0, init,
            chunk=8, remat=True)
        return (hs ** 2).sum()

    g = jax.grad(loss)(q)
    assert bool(jnp.isfinite(g).all())
