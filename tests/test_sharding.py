"""Sharding rules: divisibility filters, ZeRO, no duplicate axes,
elastic behaviour on odd dims (granite's vocab 49155)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import init_params
from repro.sharding.api import (AxisType, DEFAULT_RULES, axis_rules,
                                logical_constraint, make_mesh,
                                param_specs, spec_for_path)


@pytest.fixture(scope="module")
def mesh():
    # 1-device mesh with production axis names (trivial sizes) for rule
    # logic tests; real-mesh coverage happens in the dry-run.
    return make_mesh((1, 1), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)


def _axes_of(spec):
    out = []
    for e in spec:
        if e is None:
            continue
        out.extend(e if isinstance(e, tuple) else (e,))
    return out


def test_no_duplicate_mesh_axes_all_archs(mesh):
    for arch in ("olmoe-1b-7b", "gemma2-2b", "xlstm-350m",
                 "recurrentgemma-2b"):
        cfg = get_config(arch, reduced=True)
        params = jax.eval_shape(
            lambda: init_params(cfg, jax.random.PRNGKey(0)))
        specs = param_specs(params, mesh)
        for s in jax.tree_util.tree_leaves(
                specs, is_leaf=lambda x: isinstance(x, P)):
            axes = _axes_of(s)
            assert len(axes) == len(set(axes)), f"dup axes in {s}"


def test_divisibility_filter():
    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((4, 16))

    # vocab 49155 (granite) is not divisible by 16 -> unsharded
    s = spec_for_path("embed", (49155, 1024), FakeMesh(), DEFAULT_RULES,
                      stacked=False)
    assert s[0] is None
    # ZeRO falls to the d_model dim (1024 % 4 == 0)
    assert s[1] == "data"
    # divisible vocab shards over model
    s2 = spec_for_path("embed", (256000, 2304), FakeMesh(),
                       DEFAULT_RULES, stacked=False)
    assert s2[0] == "model"


def test_stacked_params_skip_leading_dim():
    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((4, 16))

    s = spec_for_path("cycles.slot0.w_up", (13, 2304, 9216), FakeMesh(),
                      DEFAULT_RULES, stacked=True)
    assert s[0] is None            # n_cycles stack dim never sharded
    assert s[2] == "model"         # ffn -> model
    assert s[1] == "data"          # ZeRO on the largest remaining dim


def test_moe_expert_sharding():
    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((4, 16))

    s = spec_for_path("cycles.slot0.moe_gate", (16, 64, 2048, 1024),
                      FakeMesh(), DEFAULT_RULES, stacked=True)
    assert s[1] == "model"         # expert axis -> EP over model
    assert "model" not in _axes_of(P(*s[2:]))   # no double use


def test_logical_constraint_noop_without_rules():
    x = jnp.ones((4, 4))
    y = logical_constraint(x, "batch", None)
    assert y is x


def test_logical_constraint_applies_in_context(mesh):
    with mesh, axis_rules(DEFAULT_RULES, mesh):
        @jax.jit
        def f(x):
            return logical_constraint(x, "batch", None) * 2
        out = f(jnp.ones((4, 4)))
        np.testing.assert_allclose(out, 2.0)
