"""Optimizer + checkpoint substrate tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (latest_round, load_checkpoint,
                              restore_or_init, save_checkpoint)
from repro.optim import adamw_init, adamw_update, global_norm
from repro.optim.schedules import (constant_lr, cosine_lr,
                                   linear_warmup_cosine)


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params)
    target = jnp.array([1.0, 2.0])
    for _ in range(300):
        g = {"w": 2 * (params["w"] - target)}
        params, state = adamw_update(g, state, params, lr=0.05,
                                     weight_decay=0.0)
    np.testing.assert_allclose(params["w"], target, atol=0.05)


def test_adamw_clips_gradients():
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params)
    g = {"w": jnp.full(4, 1e6)}
    p2, _ = adamw_update(g, state, params, lr=1.0, clip_norm=1.0,
                         weight_decay=0.0)
    # clipped grad norm 1 -> first adam step magnitude ~ lr
    assert float(jnp.abs(p2["w"]).max()) < 2.0


def test_adamw_bf16_master_weights():
    params = {"w": jnp.ones(8, jnp.bfloat16)}
    state = adamw_init(params)
    assert state.master["w"].dtype == jnp.float32
    g = {"w": jnp.full(8, 0.1, jnp.bfloat16)}
    p2, s2 = adamw_update(g, state, params, lr=1e-3)
    assert p2["w"].dtype == jnp.bfloat16
    assert s2.step == 1


def test_schedules():
    lr = linear_warmup_cosine(1.0, 10, 100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert float(lr(jnp.int32(10))) == pytest.approx(1.0, abs=0.01)
    assert float(lr(jnp.int32(100))) < 0.2
    assert float(cosine_lr(2.0, 50)(jnp.int32(0))) == pytest.approx(2.0)
    assert float(constant_lr(0.5)(jnp.int32(7))) == 0.5


def test_global_norm():
    t = {"a": jnp.ones(4), "b": jnp.ones((2, 2)) * 2}
    assert float(global_norm(t)) == pytest.approx(np.sqrt(4 + 16))


# ----------------------------------------------------------------------
# checkpoint
# ----------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path)
    tree = {"w": jnp.arange(8, dtype=jnp.float32),
            "nested": {"b": jnp.ones((2, 3), jnp.bfloat16)}}
    save_checkpoint(d, 3, tree, meta={"loss": 1.5})
    assert latest_round(d) == 3
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    got, meta = load_checkpoint(d, 3, like)
    assert meta["loss"] == 1.5
    np.testing.assert_allclose(got["w"], tree["w"])
    assert got["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_gc_keeps_latest(tmp_path):
    d = str(tmp_path)
    tree = {"w": jnp.zeros(2)}
    for r in range(6):
        save_checkpoint(d, r, tree, keep=2)
    assert latest_round(d) == 5
    rounds = sorted(int(f[6:14]) for f in os.listdir(d)
                    if f.endswith(".json"))
    assert rounds == [4, 5]


def test_restore_or_init(tmp_path):
    d = str(tmp_path)

    def init():
        return {"w": jnp.zeros(4)}, {"arch": "t"}

    tree, meta, start = restore_or_init(d, init)
    assert start == 0
    save_checkpoint(d, 7, {"w": jnp.full(4, 2.0)}, meta={"arch": "t"})
    tree, meta, start = restore_or_init(d, init)
    assert start == 8
    np.testing.assert_allclose(tree["w"], 2.0)


def test_checkpoint_atomic_no_partial(tmp_path):
    """A crash between payload and manifest never yields a broken
    'latest': manifest is written last, so latest_round only sees
    complete checkpoints."""
    d = str(tmp_path)
    save_checkpoint(d, 1, {"w": jnp.ones(2)})
    # simulate a torn write of a newer round: npz without manifest
    with open(os.path.join(d, "round_00000002.npz"), "wb") as f:
        f.write(b"garbage")
    assert latest_round(d) == 1
