"""Dynamic determinism twin for the rng-discipline family (ISSUE 6):
the static rules promise one threaded rng stream; this pins the
observable consequence — the same seed replays the same session
byte-for-byte, on BOTH time engines, including the continuous-time
``t_start``/``t_end`` stamps the event engine adds."""
import numpy as np
import pytest

from repro.core import SwarmConfig, SwarmSession
from repro.core.overlay import random_overlay
from repro.net import NetConfig

CFG = SwarmConfig(n=16, chunks_per_update=8, min_degree=4,
                  s_max=3000, seed=11)
NET = NetConfig(tracker_rtt_s=0.1, latency_lo_s=0.005,
                latency_hi_s=0.030)


def _session_trace(engine: str):
    ses = SwarmSession(CFG, churn_rate=0.15, time_engine=engine,
                       net=NET if engine == "event" else None)
    ses.run(3)
    return ses.trace()


@pytest.mark.parametrize("engine", ["slot", "event"])
def test_session_twin_trace_byte_identical(engine):
    a = _session_trace(engine)
    b = _session_trace(engine)
    assert len(a) == len(b) and len(a) > 0
    for k in a.keys():
        col_a, col_b = getattr(a, k), getattr(b, k)
        assert col_a.dtype == col_b.dtype, k
        assert col_a.tobytes() == col_b.tobytes(), (
            f"column {k!r} differs between twin runs at seed "
            f"{CFG.seed} on the {engine!r} engine")


def test_event_twin_time_columns_are_real_and_identical():
    a = _session_trace("event")
    assert (a.t_end >= a.t_start).all() and a.t_end.max() > 0
    b = _session_trace("event")
    assert a.t_start.tobytes() == b.t_start.tobytes()
    assert a.t_end.tobytes() == b.t_end.tobytes()


@pytest.mark.parametrize("engine", ["slot", "event"])
def test_async_twin_trace_byte_identical(engine):
    """Async extension: a deadline-cut session whose tail delivers
    LATE must still replay byte-for-byte, including the per-update
    ``generation``/``staleness`` columns the async path stamps."""
    def once():
        ses = SwarmSession(CFG, time_engine=engine,
                           net=NET if engine == "event" else None,
                           evolve_overlay=True)
        ses.run(3, quorum_k=CFG.n, tail_mode="drain", bt_budget=3)
        return ses.trace(include_late=True)
    a, b = once(), once()
    assert len(a) == len(b) and len(a) > 0
    assert (a.staleness > 0).any(), "twin must exercise the async path"
    for k in a.keys():
        col_a, col_b = getattr(a, k), getattr(b, k)
        assert col_a.dtype == col_b.dtype, k
        assert col_a.tobytes() == col_b.tobytes(), (
            f"column {k!r} differs between async twin runs at seed "
            f"{CFG.seed} on the {engine!r} engine")


@pytest.mark.parametrize("engine", ["slot", "event"])
def test_telemetry_twin_trace_byte_identical(engine):
    """ISSUE 10: telemetry is determinism-inert — recording a session
    perturbs no byte of its trace, on either engine (the recorder only
    observes: no rng draws, no feedback into simulated time)."""
    from repro import obs
    a = _session_trace(engine)
    with obs.recording() as rec:
        b = _session_trace(engine)
    assert rec.rows, "the recorded twin must actually record"
    assert obs.get().enabled is False, "recorder leaked past the scope"
    assert len(a) == len(b) and len(a) > 0
    for k in a.keys():
        col_a, col_b = getattr(a, k), getattr(b, k)
        assert col_a.dtype == col_b.dtype, k
        assert col_a.tobytes() == col_b.tobytes(), (
            f"column {k!r} differs with telemetry enabled at seed "
            f"{CFG.seed} on the {engine!r} engine")


@pytest.mark.parametrize("engine", ["slot", "event"])
def test_telemetry_twin_async_carry(engine):
    """Telemetry on/off parity through the async tail path too (quorum
    cut, boundary drain, staleness columns)."""
    from repro import obs

    def once(record: bool):
        ses = SwarmSession(CFG, time_engine=engine,
                           net=NET if engine == "event" else None,
                           evolve_overlay=True)
        if record:
            with obs.recording():
                ses.run(3, quorum_k=CFG.n, tail_mode="drain",
                        bt_budget=3)
        else:
            ses.run(3, quorum_k=CFG.n, tail_mode="drain", bt_budget=3)
        return ses.trace(include_late=True)
    a, b = once(False), once(True)
    assert len(a) == len(b) and (a.staleness > 0).any()
    for k in a.keys():
        assert getattr(a, k).tobytes() == getattr(b, k).tobytes(), (
            f"column {k!r} differs with telemetry enabled on the "
            f"{engine!r} engine (async drain path)")


def test_random_overlay_requires_threaded_rng():
    """Regression pin for the RNG004 fix: the old constant-seed
    fallback handed every un-threaded caller the SAME overlay."""
    with pytest.raises(ValueError, match="threaded np.random.Generator"):
        random_overlay(8, 3)
    rng = np.random.default_rng(3)
    adj = random_overlay(8, 3, rng=rng)
    assert adj.shape == (8, 8) and (adj.sum(1) >= 3).all()
