"""Per-kernel correctness: interpret-mode Pallas vs ref.py oracle,
swept over shapes and dtypes (assignment deliverable (c))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.attention import flash_attention

KEY = jax.random.PRNGKey(7)


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ----------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------

ATTN_CASES = [
    # b, hq, hkv, tq, tk, d, causal, window, softcap, q_off, kv_off
    (2, 4, 2, 128, 128, 64, True, None, None, 0, 0),
    (1, 8, 4, 256, 256, 128, True, 64, None, 0, 0),
    (1, 2, 2, 100, 100, 32, True, None, 50.0, 0, 0),
    (2, 4, 1, 1, 320, 64, True, None, None, 319, 0),     # decode
    (1, 4, 4, 1, 64, 32, True, 64, None, 100, 37),       # rolling decode
    (1, 4, 4, 128, 256, 64, False, None, None, 0, 0),    # encoder
    (1, 2, 1, 96, 96, 16, True, 32, 30.0, 0, 0),         # all features
]


@pytest.mark.parametrize(
    "b,hq,hkv,tq,tk,d,causal,window,softcap,qoff,kvoff", ATTN_CASES)
def test_flash_attention_vs_ref(b, hq, hkv, tq, tk, d, causal, window,
                                softcap, qoff, kvoff):
    ks = jax.random.split(jax.random.PRNGKey(b * 31 + tq), 3)
    q = _rand(ks[0], (b, hq, tq, d))
    k = _rand(ks[1], (b, hkv, tk, d))
    v = _rand(ks[2], (b, hkv, tk, d))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, q_offset=qoff,
                          kv_offset=kvoff, block_q=64, block_k=64,
                          interpret=True)
    want = ref.mha(q, k, v, causal=causal, window=window,
                   softcap=softcap, q_offset=qoff, kv_offset=kvoff)
    np.testing.assert_allclose(out, want, atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (1, 4, 64, 32), dtype)
    k = _rand(ks[1], (1, 2, 64, 32), dtype)
    v = _rand(ks[2], (1, 2, 64, 32), dtype)
    out = flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
    want = ref.mha(q, k, v)
    assert out.dtype == dtype
    tol = 1e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), atol=tol,
                               rtol=tol)


def test_xla_paths_match_ref():
    """Blocked XLA paths (qchunk + two-block SWA) match the oracle."""
    ks = jax.random.split(KEY, 3)
    for win in (None, 128):
        q = _rand(ks[0], (1, 4, 1024, 64))
        k = _rand(ks[1], (1, 2, 1024, 64))
        v = _rand(ks[2], (1, 2, 1024, 64))
        out = ops.attention(q, k, v, causal=True, window=win, impl="xla",
                            block_q=128)
        want = ref.mha(q, k, v, causal=True, window=win)
        np.testing.assert_allclose(out, want, atol=3e-5, rtol=3e-5)


def test_attention_grad_custom_vjp():
    """impl='pallas' exposes a recompute-based VJP (used on TPU)."""
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (1, 2, 32, 16))

    def f_xla(x):
        return ops.attention(x, x, x, impl="xla").sum()

    g = jax.grad(f_xla)(q)
    assert g.shape == q.shape and bool(jnp.isfinite(g).all())


# ----------------------------------------------------------------------
# RG-LRU
# ----------------------------------------------------------------------

@pytest.mark.parametrize("b,t,d,bt,bd", [
    (2, 128, 64, 32, 64), (1, 300, 100, 64, 32), (3, 64, 512, 16, 128),
    (1, 17, 9, 8, 8),
])
def test_rglru_vs_ref(b, t, d, bt, bd):
    ks = jax.random.split(jax.random.PRNGKey(t), 4)
    x = _rand(ks[0], (b, t, d))
    a = jax.nn.sigmoid(_rand(ks[1], (b, t, d))) * 0.98
    g = jax.nn.sigmoid(_rand(ks[2], (b, t, d)))
    h0 = _rand(ks[3], (b, d))
    yr, hr = ref.rglru(x, a, g, h0)
    yi, hi = ops.rglru(x, a, g, h0, impl="interpret", block_t=bt,
                       block_d=bd)
    yx, hx = ops.rglru(x, a, g, h0, impl="xla")
    np.testing.assert_allclose(yi, yr, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(hi, hr, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(yx, yr, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(hx, hr, atol=2e-5, rtol=2e-5)


def test_rglru_xla_grad():
    ks = jax.random.split(KEY, 3)
    x = _rand(ks[0], (2, 32, 16))
    a = jax.nn.sigmoid(_rand(ks[1], (2, 32, 16))) * 0.9
    g = jax.nn.sigmoid(_rand(ks[2], (2, 32, 16)))
    grad = jax.grad(lambda x_: ops.rglru(x_, a, g, impl="xla")[0].sum())(x)
    assert bool(jnp.isfinite(grad).all())


# ----------------------------------------------------------------------
# FedAvg reduction
# ----------------------------------------------------------------------

@pytest.mark.parametrize("n,d,bd", [
    (10, 5000, 512), (37, 1234, 256), (100, 65536, 2048), (3, 8, 8),
])
def test_fedavg_vs_ref(n, d, bd):
    ks = jax.random.split(jax.random.PRNGKey(n), 3)
    u = _rand(ks[0], (n, d))
    w = jax.random.uniform(ks[1], (n,)) * 10
    m = (jax.random.uniform(ks[2], (n,)) > 0.3).astype(jnp.float32)
    if not m.any():
        m = m.at[0].set(1.0)
    want = ref.fedavg_reduce(u, w, m)
    got = ops.fedavg(u, w, m, impl="interpret", block_d=bd)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_fedavg_single_active():
    u = jnp.stack([jnp.full((64,), 3.0), jnp.full((64,), 9.0)])
    w = jnp.ones(2)
    m = jnp.array([0.0, 1.0])
    out = ops.fedavg(u, w, m, impl="interpret", block_d=64)
    np.testing.assert_allclose(out, jnp.full((64,), 9.0), atol=1e-6)


# ----------------------------------------------------------------------
# Chunk quantization
# ----------------------------------------------------------------------

@pytest.mark.parametrize("n,e", [(7, 512 * 128), (1, 128), (16, 1024)])
def test_quantize_vs_ref(n, e):
    x = _rand(jax.random.PRNGKey(e), (n, e)) * 5
    q1, s1 = ref.chunk_quantize(x)
    q2, s2 = ops.quantize(x, impl="interpret")
    assert bool((q1 == q2).all())
    np.testing.assert_allclose(s1, s2, atol=1e-7)
    d2 = ops.dequantize(q2, s2, impl="interpret")
    rel = float(jnp.abs(d2 - x).max() / jnp.abs(x).max())
    assert rel < 0.01            # int8 symmetric: <1% of amax


def test_quantize_zero_chunk():
    x = jnp.zeros((2, 256))
    q, s = ops.quantize(x, impl="interpret")
    assert bool((q == 0).all())
    d = ops.dequantize(q, s, impl="interpret")
    assert bool((d == 0).all())
