"""Async FL contract (docs/INVARIANTS.md §async): sync parity, overlap
trace invariants, strict-priority background transport, and the
determinism twin extended to carry-mode sessions."""
import numpy as np
import pytest

from repro.core import SwarmConfig, SwarmSession
from repro.fl.asyncfl import (AsyncConfig, adversary_view,
                              run_async_experiment)
from repro.fl.client import LocalSpec
from repro.fl.runner import FLConfig, run_experiment
from repro.net import NetConfig
from repro.net.engine import EventEngine

NET = NetConfig(tracker_rtt_s=0.1, latency_lo_s=0.005,
                latency_hi_s=0.030)
SCFG = SwarmConfig(n=10, chunks_per_update=6, min_degree=3,
                   s_max=3000, seed=7)
TINY = FLConfig(dataset="synth-mnist", n_clients=6, rounds=2,
                n_train=600, n_test=200, min_degree=3, seed=3,
                local=LocalSpec(epochs=1, batch_size=32, lr=0.05))


def _carry_session(rounds=4, budget=2, seed=7):
    ses = SwarmSession(SCFG.replace(seed=seed), time_engine="event",
                       net=NET, evolve_overlay=True)
    recs = ses.run(rounds, quorum_k=SCFG.n, tail_mode="carry",
                   bt_budget=budget)
    return ses, recs


# -- sync parity (AsyncConfig() IS the synchronous runner) --------------

def test_sync_parity_seed_for_seed():
    ref = run_experiment("fltorrent", TINY)
    par = run_async_experiment(TINY, AsyncConfig())
    assert par.accuracy == ref.accuracy          # float-exact, no atol
    assert par.agreement == ref.agreement
    assert par.reconstruct_frac == ref.reconstruct_frac
    assert par.dropped == 0 and par.staleness_hist == {}


def test_async_config_validation():
    with pytest.raises(ValueError, match="max_staleness >= 1"):
        AsyncConfig(overlap=True)
    with pytest.raises(ValueError, match="time_engine='event'"):
        AsyncConfig(buffer_k=2, max_staleness=1, overlap=True)
    with pytest.raises(ValueError, match="buffer_k >= 1"):
        AsyncConfig(max_staleness=2)
    with pytest.raises(ValueError, match="async tail"):
        AsyncConfig(round_slots=4)
    with pytest.raises(ValueError, match="server_lr"):
        AsyncConfig(buffer_k=2, max_staleness=1, server_lr=0.0)
    with pytest.raises(ValueError, match="parity mode"):
        AsyncConfig(server_lr=0.5)


# -- overlap trace invariants (carry mode) ------------------------------

def test_carry_late_rows_stamp_generation_and_staleness():
    ses, recs = _carry_session()
    lates = [r for r in ses.history if r.late_log is not None
             and len(r.late_log)]
    assert lates, "budget=2 must leave a tail that delivers late"
    for rec in lates:
        la = rec.late_log
        assert (la.phase == 2).all()
        assert (la.round == rec.round_idx).all()
        np.testing.assert_array_equal(la.staleness,
                                      la.round - la.generation)
        assert (la.staleness >= 1).all()
        # Carried rows deliver DURING round r's swarming window, on
        # round r's engine clock.
        assert (la.t_end >= la.t_start).all() and (la.t_start >= 0).all()
        span = rec.result.metrics.t_round_s
        assert (la.t_end <= span + 1e-9).all()


def test_carry_overlaps_fresh_dissemination_on_the_wall_clock():
    ses, _ = _carry_session()
    wall = ses.wall_trace(include_late=True)
    late = wall.staleness > 0
    assert late.any()
    fresh = ~late
    # Some stale-generation delivery is in flight strictly inside the
    # time a fresh-generation transfer of the SAME round is in flight:
    # dissemination of r genuinely contends with r-1's tail.
    overlap = False
    for r in np.unique(wall.round[late]):
        lmask = late & (wall.round == r)
        fmask = fresh & (wall.round == r)
        if not fmask.any():
            continue
        lo = wall.t_start[fmask].min()
        hi = wall.t_end[fmask].max()
        if ((wall.t_end[lmask] > lo) & (wall.t_start[lmask] < hi)).any():
            overlap = True
    assert overlap


def test_carry_update_accounting_is_conservative():
    ses, recs = _carry_session(rounds=5)
    ready = sum(len(r.late_ready) for r in recs)
    dead = sum(len(r.dead_updates) for r in recs)
    still_out = len(ses._outstanding)
    tails = sum(1 for r in recs if r.result.tail is not None)
    queued = sum(len(np.unique(r.result.tail["ucols"]
                               // SCFG.chunks_per_update))
                 for r in recs if r.result.tail is not None)
    assert tails > 0 and queued > 0
    assert ready + dead + still_out == queued
    # Late-ready keys are unique and each was once outstanding.
    keys = [k for r in recs for k in r.late_ready]
    assert len(keys) == len(set(keys))


def test_drain_rows_land_before_next_round():
    ses = SwarmSession(SCFG, time_engine="event", net=NET,
                       evolve_overlay=True)
    recs = ses.run(3, quorum_k=SCFG.n, tail_mode="drain", bt_budget=2)
    lates = [r for r in ses.history if r.late_log is not None
             and len(r.late_log)]
    assert lates
    for rec in lates:
        la = rec.late_log
        # Boundary drain: next round's timeline, negative offsets.
        assert (la.round == rec.round_idx + 1).all()
        assert (la.t_end <= 1e-9).all()
        assert (la.staleness == 1).all()


# -- determinism twin (async extension) ---------------------------------

@pytest.mark.parametrize("engine", ["slot", "event"])
def test_drain_twin_trace_byte_identical_on_both_engines(engine):
    def once():
        ses = SwarmSession(SCFG, time_engine=engine,
                           net=NET if engine == "event" else None,
                           evolve_overlay=True)
        ses.run(3, quorum_k=SCFG.n, tail_mode="drain", bt_budget=2)
        return ses.trace(include_late=True)
    a, b = once(), once()
    assert len(a) == len(b) and (a.staleness > 0).any()
    for k in a.keys():
        assert getattr(a, k).tobytes() == getattr(b, k).tobytes(), (
            f"column {k!r} differs between drain-mode twin runs on "
            f"the {engine!r} engine")


def test_carry_twin_wall_trace_byte_identical():
    a = _carry_session()[0].wall_trace(include_late=True)
    b = _carry_session()[0].wall_trace(include_late=True)
    assert len(a) == len(b) and len(a) > 0
    assert (a.staleness > 0).any(), "twin must exercise the async path"
    for k in a.keys():
        col_a, col_b = getattr(a, k), getattr(b, k)
        assert col_a.dtype == col_b.dtype, k
        assert col_a.tobytes() == col_b.tobytes(), (
            f"column {k!r} differs between carry-mode twin runs")


def test_adversary_view_band_shifts_late_descriptors():
    ses, _ = _carry_session()
    view = adversary_view(ses)
    K = SCFG.chunks_per_update
    band = ses.n_peers + 1
    late = view.phase == 1
    base = ~late
    fresh_max = int(view.chunk[base].max())
    assert fresh_max < band * K
    lv = view.chunk[late]
    assert lv.size and (lv >= band * K).all()
    # Injective grading: a shifted descriptor decodes back to exactly
    # one (generation, owner-chunk) pair.
    gen = view.generation[late].astype(np.int64)
    np.testing.assert_array_equal(lv // (band * K) - 1, gen)


# -- strict-priority two-phase transport (engine level) -----------------

def _mini_engine(seed=0, bg_up=None):
    rng = np.random.default_rng(seed)
    up = rng.uniform(2e6, 4e6, size=4)
    if bg_up is not None:
        up[3] = bg_up
    down = rng.uniform(8e6, 12e6, size=4)
    return EventEngine(4, 1 << 18, up, down, NET, seed=seed)


FG = (np.array([0, 1, 0]), np.array([1, 2, 2]), np.array([0, 1, 2]))


def test_foreground_stamps_immune_to_background():
    e1 = _mini_engine()
    ts1, te1 = e1.bt_cycle(*FG)
    e2 = _mini_engine()
    e2.set_background(np.array([3, 3]), np.array([0, 1]),
                      np.array([10, 11]))
    ts2, te2 = e2.bt_cycle(*FG)
    # Strict priority: the carried tail can never dilate the current
    # generation's transfers, byte for byte.
    assert ts1.tobytes() == ts2.tobytes()
    assert te1.tobytes() == te2.tobytes()
    assert e1.t == e2.t


def test_background_banks_partial_progress_across_cycles():
    # One bg entry on a link so slow a single foreground window cannot
    # carry a whole chunk: progress must persist, not reset.
    e = _mini_engine(bg_up=2e4)
    e.set_background(np.array([3]), np.array([0]), np.array([42]))
    e.bt_cycle(*FG)
    assert e.background_remaining().tolist() == [42]
    banked = float(e._bg_rem[0])
    assert 0.0 < banked < e.chunk_bytes, "no partial progress banked"
    e.bt_cycle(*FG)
    if e.background_remaining().size:
        assert float(e._bg_rem[0]) < banked, "bank did not advance"
    meta, ts, te = e.drain_background()
    delivered = np.concatenate([e.background_log()["meta"], meta])
    assert 42 in delivered.tolist()
    assert e.background_remaining().size == 0


def test_drain_background_delivers_everything():
    e = _mini_engine()
    src = np.array([0, 1, 2, 3, 0, 1])
    dst = np.array([1, 2, 3, 0, 2, 3])
    e.set_background(src, dst, np.arange(6))
    meta, ts, te = e.drain_background()
    assert sorted(meta.tolist()) == list(range(6))
    assert (te >= ts).all() and (ts >= 0).all()
    assert e.background_remaining().size == 0
