"""Single-device numerics for the repro.dist collective layer.

The 8-device subprocess harness (test_dist_multidevice.py) proves the
lowered collective schedule; these tests exercise the same ring
arithmetic through the single-device emulation path so dist numerics
run in tier-1 on one CPU device.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.torrent import (masked_weights, ring_allgather_emulated,
                                torrent_fedavg)
from repro.kernels import ops, ref


def _oracle(ups, weights, active):
    wa = np.asarray(weights, np.float64) * np.asarray(active, np.float64)
    wn = wa / wa.sum() if wa.sum() > 0 else wa
    return jax.tree_util.tree_map(
        lambda l: np.einsum("p,p...->...", wn, np.asarray(l, np.float64)),
        ups)


def _tree():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    return {
        "layer": {"w": jax.random.normal(ks[0], (4, 16, 8)),
                  "b": jax.random.normal(ks[1], (4, 24))},
        "head": jax.random.normal(ks[2], (4, 7, 3, 2)),
        "scale": jax.random.normal(ks[3], (4,)),       # scalar per pod
    }


def test_torrent_fedavg_matches_oracle_single_device():
    ups = _tree()
    weights = jnp.array([1., 2., 3., 4.])
    active = jnp.array([1., 1., 0., 1.])
    out = torrent_fedavg(ups, weights, active, n_blocks=4)
    want = _oracle(ups, weights, active)
    for got, ref_ in zip(jax.tree_util.tree_leaves(out),
                         jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(got), ref_, atol=1e-5)


@pytest.mark.parametrize("n_blocks", [1, 3, 8])
def test_torrent_fedavg_n_blocks_invariant(n_blocks):
    """The chunking is a wire layout, not a math change."""
    ups = _tree()
    weights = jnp.array([3., 1., 2., 5.])
    active = jnp.ones(4)
    out = torrent_fedavg(ups, weights, active, n_blocks=n_blocks)
    want = _oracle(ups, weights, active)
    for got, ref_ in zip(jax.tree_util.tree_leaves(out),
                         jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(got), ref_, atol=1e-5)


def test_torrent_fedavg_pytree_structure():
    """Mixed-rank pytree in -> same treedef out, leading axis dropped,
    leaf dtypes preserved."""
    ups = _tree()
    ups["layer"]["b"] = ups["layer"]["b"].astype(jnp.bfloat16)
    out = torrent_fedavg(ups, jnp.ones(4), jnp.ones(4), n_blocks=2)
    assert (jax.tree_util.tree_structure(out)
            == jax.tree_util.tree_structure(ups))
    flat_in = jax.tree_util.tree_leaves(ups)
    flat_out = jax.tree_util.tree_leaves(out)
    for i, o in zip(flat_in, flat_out):
        assert o.shape == i.shape[1:]
        assert o.dtype == i.dtype


def test_torrent_fedavg_compress_small_relative_error():
    ups = _tree()
    weights = jnp.array([1., 2., 3., 4.])
    active = jnp.array([1., 1., 0., 1.])
    out = torrent_fedavg(ups, weights, active, n_blocks=4, compress=True)
    want = _oracle(ups, weights, active)
    for got, ref_ in zip(jax.tree_util.tree_leaves(out),
                         jax.tree_util.tree_leaves(want)):
        rel = (np.abs(np.asarray(got, np.float64) - ref_).max()
               / max(np.abs(ref_).max(), 1e-9))
        assert rel < 0.02, rel


@pytest.mark.parametrize("compress", [False, True])
def test_ring_emulation_every_dest_reconstructs_all(compress):
    """After P-1 stages every dest holds every source's blocks in
    source order — the paper's full-dissemination terminal state."""
    p, nb, db = 5, 3, 16
    blocks = jax.random.normal(jax.random.PRNGKey(1), (p, nb, db))
    gathered = ring_allgather_emulated(blocks, compress=compress)
    assert gathered.shape == (p, p, nb, db)
    tol = 2e-2 if compress else 1e-6
    for dest in range(p):
        np.testing.assert_allclose(np.asarray(gathered[dest]),
                                   np.asarray(gathered[0]), atol=1e-7)
        np.testing.assert_allclose(np.asarray(gathered[dest]),
                                   np.asarray(blocks), atol=tol)


def test_zero_active_mass_returns_zeros():
    """sum(m*w) == 0 -> zeros everywhere, never NaN (regression)."""
    ups = _tree()
    zero = jnp.zeros(4)
    out = torrent_fedavg(ups, jnp.array([1., 2., 3., 4.]), zero)
    for l in jax.tree_util.tree_leaves(out):
        assert not np.isnan(np.asarray(l, np.float32)).any()
        np.testing.assert_array_equal(np.asarray(l, np.float32), 0.0)
    # also with nonzero mask but zero weights
    out2 = torrent_fedavg(ups, zero, jnp.ones(4))
    for l in jax.tree_util.tree_leaves(out2):
        np.testing.assert_array_equal(np.asarray(l, np.float32), 0.0)
    np.testing.assert_array_equal(np.asarray(masked_weights(zero, zero)),
                                  np.zeros(4))


def test_fl_step_single_device_straggler_and_microbatch():
    """The full FL step runs through the emulated ring on one device:
    a masked pod cannot influence params, and microbatch accumulation
    matches the unsplit gradient."""
    from repro.dist.fl_step import make_fl_train_step
    from repro.models import ArchConfig, init_params
    from repro.optim import adamw_init
    from repro.optim.schedules import constant_lr

    cfg = ArchConfig(name="t", family="dense", n_layers=1, d_model=32,
                     n_heads=4, n_kv=2, head_dim=8, d_ff=64, vocab=128,
                     dtype="float32")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    opt = adamw_init(params)
    batch = {"inputs": jax.random.randint(key, (4, 4, 16), 0, 128),
             "labels": jax.random.randint(key, (4, 4, 16), 0, 128)}
    w = jnp.ones(4)
    a = jnp.array([1., 1., 1., 0.])
    step = make_fl_train_step(cfg, None, lr_schedule=constant_lr(1e-3),
                              n_pods=4)
    p_ref, _, m = jax.jit(step)(params, opt, batch, w, a)
    assert np.isfinite(float(m["loss"]))
    corrupted = dict(batch)
    corrupted["inputs"] = batch["inputs"].at[3].set(0)
    p_alt, _, _ = jax.jit(step)(params, opt, corrupted, w, a)
    for x, y in zip(jax.tree_util.tree_leaves(p_ref),
                    jax.tree_util.tree_leaves(p_alt)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    step_mb = make_fl_train_step(cfg, None, lr_schedule=constant_lr(1e-3),
                                 n_pods=4, microbatch=2)
    p_mb, _, _ = jax.jit(step_mb)(params, opt, batch, w, a)
    diff = max(float(jnp.abs(x - y).max()) for x, y in zip(
        jax.tree_util.tree_leaves(p_ref), jax.tree_util.tree_leaves(p_mb)))
    assert diff < 1e-5, diff


def test_fedavg_reduce_zero_mass_kernel_and_ref():
    u = jnp.asarray(np.random.default_rng(0).normal(size=(4, 96)),
                    jnp.float32)
    w = jnp.array([1., 2., 3., 4.])
    zero = jnp.zeros(4)
    for out in (ref.fedavg_reduce(u, w, zero),
                ops.fedavg(u, w, zero, impl="interpret", block_d=32)):
        assert not np.isnan(np.asarray(out)).any()
        np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_masked_nan_update_cannot_poison_aggregate():
    """A pod masked BECAUSE it diverged (NaN update) must be selected
    out, not multiplied (0 * NaN == NaN) — regression."""
    ups = _tree()
    ups = jax.tree_util.tree_map(
        lambda l: l.at[2].set(jnp.nan), ups)
    weights = jnp.array([1., 2., 3., 4.])
    active = jnp.array([1., 1., 0., 1.])
    for compress in (False, True):
        out = torrent_fedavg(ups, weights, active, n_blocks=4,
                             compress=compress)
        for l in jax.tree_util.tree_leaves(out):
            assert np.isfinite(np.asarray(l, np.float32)).all()
    # and through the stacked kernels
    u = jnp.ones((4, 64)).at[2].set(jnp.nan)
    for out in (ref.fedavg_reduce(u, weights, active),
                ops.fedavg(u, weights, active, impl="interpret",
                           block_d=32)):
        assert np.isfinite(np.asarray(out)).all()


def test_fl_step_zero_active_mass_is_noop():
    """No reconstructable update by the deadline -> the round leaves
    params, optimizer moments, AND the step counter untouched."""
    from repro.dist.fl_step import make_fl_train_step
    from repro.models import ArchConfig, init_params
    from repro.optim import adamw_init
    from repro.optim.schedules import constant_lr

    cfg = ArchConfig(name="t", family="dense", n_layers=1, d_model=32,
                     n_heads=4, n_kv=2, head_dim=8, d_ff=64, vocab=128,
                     dtype="float32")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    opt = adamw_init(params)
    batch = {"inputs": jax.random.randint(key, (4, 2, 8), 0, 128),
             "labels": jax.random.randint(key, (4, 2, 8), 0, 128)}
    step = make_fl_train_step(cfg, None, lr_schedule=constant_lr(1e-3),
                              n_pods=4)
    p2, o2, _ = jax.jit(step)(params, opt, batch, jnp.ones(4),
                              jnp.zeros(4))
    for x, y in zip(jax.tree_util.tree_leaves((params, opt)),
                    jax.tree_util.tree_leaves((p2, o2))):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
