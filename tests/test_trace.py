"""TransferTrace: typed observation contract (core/trace.py).

Covers dict-compatibility of the trace, golden-exact attack numbers,
vectorized-vs-reference scorer equivalence on identical seeds,
round/phase slicing + observer masking, cross-round concatenation from
SwarmSession, and the trace-based audit path."""
import itertools
import json
import os

import numpy as np
import pytest

from repro.core import (ChurnModel, SwarmConfig, SwarmSession,
                        TransferTrace, simulate_round)
from repro.core.attacks import ATTACKS, ATTACKS_REFERENCE
from repro.core.audit import directives_from_trace, verify_directives

HERE = os.path.dirname(os.path.abspath(__file__))
GOLDEN = json.load(open(os.path.join(HERE, "golden_schedules.json")))


# ---------------------------------------------------------------------------
# dict-compat + views
# ---------------------------------------------------------------------------

def test_trace_mapping_protocol_and_views():
    cfg = SwarmConfig(n=14, chunks_per_update=12, s_max=4000, seed=1)
    res = simulate_round(cfg)
    tr = res.log
    assert isinstance(tr, TransferTrace)
    assert tr.K == cfg.chunks_per_update
    # mapping protocol: legacy dict consumers keep working
    d = dict(tr)
    assert set(d) == set(tr.keys())
    assert np.array_equal(d["chunk"], tr.chunk)
    assert "slot" in tr and tr.get("nope") is None
    with pytest.raises(KeyError):
        tr["nope"]
    # round-trip through from_log
    tr2 = TransferTrace.from_log(d, K=tr.K)
    for k in tr.keys():
        assert np.array_equal(tr[k], tr2[k]), k
    assert TransferTrace.from_log(tr) is tr
    # phase slicing partitions the trace
    n_parts = sum(len(tr.phase_slice(p)) for p in ("spray", "warmup", "bt"))
    assert n_parts == len(tr)
    assert np.all(tr.warmup().phase == 1)
    # observer masking: only the coalition's rows
    obs = np.array([0, 3])
    v = tr.observed_by(obs)
    assert np.isin(v.receiver, obs).all()
    assert len(v) == int(np.isin(tr.receiver, obs).sum())
    # descriptor mapping
    assert np.array_equal(tr.desc(), tr.chunk // cfg.chunks_per_update)
    with pytest.raises(ValueError):
        TransferTrace().desc()


def test_trace_concat_and_round_column():
    cfg = SwarmConfig(n=14, chunks_per_update=10, min_degree=4,
                      s_max=4000, seed=2)
    ses = SwarmSession(cfg, churn=ChurnModel(leave_prob=0.25,
                                             rejoin_after=1))
    recs = ses.run(4)
    tr = ses.trace()
    assert np.array_equal(tr.rounds(), np.arange(4))
    for r, rec in enumerate(recs):
        part = tr.rounds_slice(r)
        glog = rec.global_log()
        assert len(part) == len(glog)
        for k in ("slot", "sender", "receiver", "chunk", "owner"):
            assert np.array_equal(part[k], glog[k]), (r, k)
        # global ids: senders within the round's active set
        assert set(np.unique(part.sender)) <= set(
            rec.active_ids.tolist())
    # grading lookup maps each round's descriptors to global owners
    grade = tr.desc_owner_lookup()
    warm = tr.warmup()
    got = grade(warm.round, warm.desc())
    assert np.array_equal(got, warm.owner)
    assert grade(np.array([99]), np.array([0]))[0] == -1


# ---------------------------------------------------------------------------
# attacks: golden exactness + vectorized == reference
# ---------------------------------------------------------------------------

def _golden_cfgs():
    yield "full", {}
    yield "none", dict(enable_preround=False, enable_timelag=False,
                       enable_gating=False, enable_nonowner_first=False)


@pytest.mark.parametrize("name,kw", list(_golden_cfgs()))
@pytest.mark.parametrize("seed", [0, 1])
def test_attack_numbers_reproduce_exactly_from_trace(name, kw, seed):
    """Figs. 6-7 inputs: ASR numbers from the TransferTrace path are
    bit-identical to the pinned pre-trace dict path."""
    cfg = SwarmConfig(n=24, chunks_per_update=24, s_max=5000, seed=seed,
                      scheduler_impl="loop", **kw)
    res = simulate_round(cfg)
    reps = {a: fn(res.log, np.arange(6), 24) for a, fn in ATTACKS.items()}
    pooled = {a: fn(res.log, np.arange(12), 24, pooled=True)
              for a, fn in ATTACKS.items()}
    for a, want in GOLDEN["attacks"][f"{name}/{seed}"].items():
        assert reps[a].max_asr == want["max"]
        assert reps[a].mean_asr == want["mean"]
        assert reps[a].n_decisions == want["n"]
        assert pooled[a].max_asr == want["pooled_max"]
        assert pooled[a].any_correct_rate == want["pooled_any"]


@pytest.mark.parametrize("seed,sched", list(itertools.product(
    (2, 5, 11), ("greedy_fastest_first", "distributed", "flooding"))))
def test_vectorized_scorers_match_reference(seed, sched):
    """Trace <-> legacy-dict equivalence: the vectorized scorers make
    the reference implementations' decisions exactly, solo and pooled,
    on both trace and raw-dict input."""
    cfg = SwarmConfig(n=20, chunks_per_update=16, s_max=5000, seed=seed,
                      min_degree=5, scheduler=sched)
    res = simulate_round(cfg)
    as_dict = dict(res.log)
    for pooled in (False, True):
        for a in ATTACKS:
            rv = ATTACKS[a](res.log, np.arange(5), 16, pooled=pooled)
            rd = ATTACKS[a](as_dict, np.arange(5), 16, pooled=pooled)
            rr = ATTACKS_REFERENCE[a](res.log, np.arange(5), 16,
                                      pooled=pooled)
            for got in (rv, rd):
                assert got.asr_per_observer == rr.asr_per_observer
                assert got.max_asr == rr.max_asr
                assert got.mean_asr == rr.mean_asr
                assert got.n_decisions == rr.n_decisions
                assert got.any_correct_rate == rr.any_correct_rate


# ---------------------------------------------------------------------------
# audit over the trace
# ---------------------------------------------------------------------------

def test_audit_verifies_simulated_trace():
    cfg = SwarmConfig(n=14, chunks_per_update=12, s_max=4000, seed=4)
    res = simulate_round(cfg)
    dirs = directives_from_trace(res.log)
    assert len(dirs) == int((res.log.phase == 1).sum())
    # the simulator's own warm-up schedule audits clean
    assert verify_directives(res.adj, dirs, res.up, res.down) == []
    assert verify_directives(res.adj, res.log, res.up, res.down) == []
    # tampering is caught: non-adjacent directive
    u, v = map(int, np.argwhere(~res.adj)[1])
    bad = dirs + [(0, u, v, 0)]
    out = verify_directives(res.adj, bad, res.up, res.down)
    assert any("non-adjacent" in msg for msg in out)
    # duplicate delivery is caught, logged retry is not (the retry goes
    # to an otherwise-empty late slot so per-stage caps stay clean)
    retry_slot = max(d[0] for d in dirs) + 1
    dup = dirs + [(retry_slot, *dirs[0][1:])]
    out = verify_directives(res.adj, dup, res.up, res.down)
    assert any("redundant" in msg for msg in out)
    out = verify_directives(res.adj, dup, res.up, res.down,
                            retries={(dirs[0][2], dirs[0][3])})
    assert out == []
