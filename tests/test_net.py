"""repro.net: fair-share allocation, event-engine cross-validation
against the slot engine, wall-clock metrics, and the capacity-clamp
warning (ISSUE 5 acceptance surface)."""
import warnings

import numpy as np
import pytest

from repro.core import SwarmConfig, SwarmSession, simulate_round
from repro.core.capacities import MBPS, LinkModel
from repro.core.maxflow import (stage_time_lower_bound,
                                warmup_time_bounds)
from repro.core.simulator import RoundSimulator
from repro.net import NetConfig, maxmin_rates, transport


# ---------------------------------------------------------------------------
# fairshare: max-min progressive filling
# ---------------------------------------------------------------------------

def test_maxmin_single_shared_uplink():
    # 3 flows out of sender 0 to uncontended receivers: equal thirds.
    src = np.array([0, 0, 0])
    dst = np.array([1, 2, 3])
    up = np.array([9.0, 9.0, 9.0, 9.0])
    down = np.array([100.0] * 4)
    r = maxmin_rates(src, dst, up, down)
    assert np.allclose(r, 3.0)


def test_maxmin_bottleneck_redistribution():
    # Flow A: 0->1 (down[1]=2 caps it); flow B: 0->2.  Uplink 10 shared:
    # progressive filling freezes A at 2, B gets the rest up to down[2].
    src = np.array([0, 0])
    dst = np.array([1, 2])
    up = np.array([10.0, 100.0, 100.0])
    down = np.array([100.0, 2.0, 100.0])
    r = maxmin_rates(src, dst, up, down)
    assert np.isclose(r[0], 2.0)
    assert np.isclose(r[1], 8.0)


def test_maxmin_never_oversubscribes():
    rng = np.random.default_rng(0)
    n, f = 12, 60
    src = rng.integers(0, n, f)
    dst = (src + 1 + rng.integers(0, n - 1, f)) % n
    up = rng.uniform(1.0, 20.0, n)
    down = rng.uniform(1.0, 20.0, n)
    r = maxmin_rates(src, dst, up, down)
    out = np.bincount(src, weights=r, minlength=n)
    inn = np.bincount(dst, weights=r, minlength=n)
    assert (out <= up * (1 + 1e-6)).all()
    assert (inn <= down * (1 + 1e-6)).all()
    assert (r > 0).all()


def test_maxmin_truncated_tail_stays_feasible():
    # Force many distinct bottleneck levels with max_passes=1: the tail
    # fill must stay feasible (no link over capacity).
    rng = np.random.default_rng(1)
    n, f = 30, 200
    src = rng.integers(0, n, f)
    dst = (src + 1 + rng.integers(0, n - 1, f)) % n
    up = rng.uniform(1.0, 50.0, n)
    down = rng.uniform(1.0, 50.0, n)
    r = maxmin_rates(src, dst, up, down, max_passes=1)
    out = np.bincount(src, weights=r, minlength=n)
    inn = np.bincount(dst, weights=r, minlength=n)
    assert (out <= up * (1 + 1e-6)).all()
    assert (inn <= down * (1 + 1e-6)).all()


def test_transport_emits_every_chunk_in_pipeline_order():
    src = np.array([0, 1])
    dst = np.array([2, 2])
    counts = np.array([5, 3])
    tm = transport(src, dst, counts, 10.0,
                   up=np.array([10.0, 10.0, 10.0]),
                   down=np.array([10.0, 10.0, 8.0]))
    emitted = np.bincount(tm.chunk_flow, minlength=2)
    assert (emitted == counts).all()
    # within each flow, completion instants are non-decreasing
    for fl in (0, 1):
        t = tm.chunk_end[tm.chunk_flow == fl]
        assert (np.diff(t) >= -1e-9).all()
    assert np.isclose(tm.makespan, np.nanmax(tm.finish))
    # total bytes / makespan cannot beat the receiver's downlink
    assert tm.makespan >= (counts.sum() * 10.0) / 8.0 - 1e-6


def test_transport_homogeneous_equal_flows_tie():
    # identical flows finish together at bytes/(cap/f)
    f = 4
    src = np.arange(f)
    dst = np.full(f, f)
    counts = np.full(f, 6)
    up = np.full(f + 1, 100.0)
    down = np.full(f + 1, 12.0)
    tm = transport(src, dst, counts, 2.0, up, down)
    assert np.allclose(tm.finish, 6 * 2.0 / (12.0 / f))


# ---------------------------------------------------------------------------
# cross-validation: event engine == slot engine schedules
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["greedy_fastest_first",
                                    "distributed"])
@pytest.mark.parametrize("seed", [0, 1])
def test_event_engine_reproduces_slot_schedule(policy, seed):
    cfg = SwarmConfig(n=24, chunks_per_update=16, min_degree=5,
                      s_max=4000, seed=seed, scheduler=policy)
    rs = simulate_round(cfg)
    re = simulate_round(cfg, time_engine="event",
                        net=NetConfig(tracker_rtt_s=0.05))
    # identical schedules: same rows, transfer for transfer
    assert len(rs.log) == len(re.log)
    assert np.array_equal(rs.log.slot, re.log.slot)
    assert np.array_equal(rs.log.sender, re.log.sender)
    assert np.array_equal(rs.log.receiver, re.log.receiver)
    assert np.array_equal(rs.log.chunk, re.log.chunk)
    assert rs.metrics.t_warm == re.metrics.t_warm
    assert rs.metrics.t_round == re.metrics.t_round


@pytest.mark.parametrize("seed", [0, 1])
def test_t_start_order_consistent_with_slot_order(seed):
    cfg = SwarmConfig(n=24, chunks_per_update=16, min_degree=5,
                      s_max=4000, seed=seed)
    res = simulate_round(cfg, time_engine="event",
                         net=NetConfig(tracker_rtt_s=0.05))
    tr = res.log
    assert (tr.t_end >= tr.t_start - 1e-12).all()
    # post-spray rows, sorted by start instant: slot indices
    # non-decreasing (cycles are sequential barriers)
    post = tr.select(tr.phase > 0)
    order = np.argsort(post.t_start, kind="stable")
    assert (np.diff(post.slot[order]) >= 0).all()


def test_slot_engine_stamps_slot_boundaries():
    cfg = SwarmConfig(n=16, chunks_per_update=16, s_max=4000, seed=3,
                      slot_seconds=2.0)
    res = simulate_round(cfg)
    tr = res.log
    assert np.allclose(tr.t_start, tr.slot * 2.0)
    assert np.allclose(tr.t_end, tr.slot * 2.0 + 2.0)
    m = res.metrics
    assert np.isclose(m.t_round_s, m.t_round * 2.0)
    assert np.isclose(m.t_warm_s, m.t_warm * 2.0)


# ---------------------------------------------------------------------------
# wall-clock metrics
# ---------------------------------------------------------------------------

def test_event_metrics_account_control_plane():
    net = NetConfig(tracker_rtt_s=0.2, tracker_solve_s=0.1,
                    spray_setup_s=0.5)
    cfg = SwarmConfig(n=20, chunks_per_update=16, min_degree=5,
                      s_max=4000, seed=0)
    res = simulate_round(cfg, time_engine="event", net=net)
    m = res.metrics
    # control time = spray setup + one (rtt + solve) per warm-up cycle
    assert np.isclose(m.control_s, 0.5 + m.t_warm * 0.3)
    assert m.t_spray_s > 0.5          # setup + spray transport
    assert m.t_warm_s >= m.t_spray_s + m.t_warm * 0.3
    assert m.t_round_s > m.t_warm_s   # BT tail exists
    assert 0.0 < m.warmup_share_s < 1.0
    assert res.tracker_log is not None
    assert res.tracker_log["n_cycles"] == m.t_warm + 1   # + spray setup


def test_event_latency_delays_first_byte():
    net = NetConfig(tracker_rtt_s=0.0, latency_lo_s=0.5,
                    latency_hi_s=0.5)
    cfg = SwarmConfig(n=16, chunks_per_update=16, min_degree=5,
                      s_max=4000, seed=1, enable_preround=False,
                      enable_timelag=False)
    res = simulate_round(cfg, time_engine="event", net=net)
    warm = res.log.warmup()
    # every transfer crosses two 0.5 s access legs
    assert (warm.t_start >= 1.0 - 1e-9).all()


def test_congestion_bound_holds_per_cycle():
    cfg = SwarmConfig(n=20, chunks_per_update=24, min_degree=5,
                      s_max=4000, seed=2)
    sim = RoundSimulator(cfg, time_engine="event",
                         net=NetConfig(tracker_rtt_s=0.0))
    res = sim.run()
    lbs, real = warmup_time_bounds(res.log, cfg.chunk_bytes,
                                   sim.up_bps, sim.down_bps)
    assert (real >= lbs - 1e-9).all()
    assert lbs.sum() > 0
    # realized transport stays within a small factor of the bound
    assert real.sum() <= 3.0 * lbs.sum()


def test_stage_time_lower_bound_simple():
    # 4 chunks of 10 B out of a 5 B/s uplink: >= 8 s regardless of fan.
    lb = stage_time_lower_bound(np.zeros(4, np.int64),
                                np.arange(1, 5), 10.0,
                                np.array([5.0, 9, 9, 9, 9]),
                                np.full(5, 100.0))
    assert np.isclose(lb, 8.0)


# ---------------------------------------------------------------------------
# session integration
# ---------------------------------------------------------------------------

def test_session_event_engine_wall_clock_across_churn():
    cfg = SwarmConfig(n=24, chunks_per_update=16, min_degree=5,
                      s_max=4000, seed=0)
    ses = SwarmSession(cfg, churn_rate=0.15,
                       time_engine="event",
                       net=NetConfig(tracker_rtt_s=0.05))
    ses.run(3)
    wc = ses.wall_clock()
    assert len(wc["t_round_s"]) == 3
    assert (wc["t_round_s"] > 0).all()
    assert (wc["t_warm_s"] > 0).all()
    assert ((wc["warmup_share_s"] > 0)
            & (wc["warmup_share_s"] < 1)).all()
    # the session trace carries the continuous-time columns
    tr = ses.trace()
    assert (tr.t_end >= tr.t_start).all()
    assert tr.t_start.max() > 0


def test_session_slot_engine_unchanged_with_rates():
    """Persisting raw rates must not perturb the evolving-overlay slot
    session (same draws, quantized identically)."""
    cfg = SwarmConfig(n=20, chunks_per_update=16, min_degree=5,
                      s_max=4000, seed=5)
    a = SwarmSession(cfg, churn_rate=0.1)
    b = SwarmSession(cfg, churn_rate=0.1, time_engine="slot")
    ra = a.run(3)
    rb = b.run(3)
    for x, y in zip(ra, rb):
        assert np.array_equal(x.active_ids, y.active_ids)
        assert np.array_equal(x.result.log.chunk, y.result.log.chunk)


# ---------------------------------------------------------------------------
# capacity clamp (satellite): warn when floor(rate * Δ / C) < 1 binds
# ---------------------------------------------------------------------------

def test_clamp_warns_when_it_binds():
    slow = LinkModel(up_lo=0.5 * MBPS, up_hi=0.6 * MBPS,
                     down_lo=50 * MBPS, down_hi=60 * MBPS)
    rng = np.random.default_rng(0)
    with pytest.warns(RuntimeWarning, match="clamp binds"):
        u, d = slow.sample_chunks_per_slot(8, 256 * 1024, 1.0, rng)
    assert (u == 1).all()          # clamped, not zero

    fast_rng = np.random.default_rng(0)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        from repro.core.capacities import RESIDENTIAL
        RESIDENTIAL.sample_chunks_per_slot(8, 256 * 1024, 1.0, fast_rng)


def test_event_engine_rejects_zero_rate_links():
    """A zero-rate link could never deliver, but the scheduling layer
    would still mark its chunks delivered (t_end = inf): reject the
    injection up front instead."""
    cfg = SwarmConfig(n=8, chunks_per_update=4, min_degree=3,
                      s_max=1000, seed=0)
    up = np.ones(8, np.int64)
    with pytest.raises(ValueError, match="positive link rates"):
        RoundSimulator(cfg, up=up, down=up,
                       up_bps=np.zeros(8), down_bps=np.ones(8) * 1e6,
                       time_engine="event").run()


def test_event_engine_honest_on_clamped_links():
    """A sub-chunk/slot uplink: the slot engine inflates it to 1
    chunk/slot; the event engine transports its real bytes/s, so its
    transfers take > 1 slot of wall clock each."""
    slow = LinkModel(up_lo=0.5 * MBPS, up_hi=0.6 * MBPS,
                     down_lo=50 * MBPS, down_hi=60 * MBPS)
    cfg = SwarmConfig(n=12, chunks_per_update=8, min_degree=4,
                      s_max=4000, seed=0, enable_preround=False,
                      enable_timelag=False, enable_gating=False)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        res = simulate_round(cfg, link_model=slow, time_engine="event",
                             net=NetConfig(tracker_rtt_s=0.0))
    m = res.metrics
    # ~3.5 s per chunk of real uplink vs 1 chunk/slot pretended: wall
    # clock must stretch well past the slot count
    assert m.t_round_s > 1.5 * m.t_round * cfg.slot_seconds
