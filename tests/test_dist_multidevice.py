"""Multi-device dist-layer tests (torrent collective, FL step, dry-run).

These need >1 XLA device, so each runs in a SUBPROCESS that sets
XLA_FLAGS before importing jax (the main pytest process must keep
seeing the single real CPU device — see dryrun.py note).
"""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.dist     # deselect with `-m "not dist"`

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, devices: int = 8, timeout: int = 900):
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={devices}")
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
        print("SUBPROC_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    res = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert res.returncode == 0, res.stderr[-4000:]
    assert "SUBPROC_OK" in res.stdout, res.stdout[-2000:]
    return res.stdout


def test_torrent_fedavg_matches_oracle():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.sharding.api import AxisType, make_mesh
    mesh = make_mesh((4, 2), ("pod", "data"),
                     axis_types=(AxisType.Auto,)*2)
    from repro.dist.torrent import torrent_fedavg
    key = jax.random.PRNGKey(0)
    ups = {"w": jax.random.normal(key, (4, 16, 8)),
           "b": jax.random.normal(key, (4, 24))}
    weights = jnp.array([1., 2., 3., 4.])
    active = jnp.array([1., 1., 0., 1.])
    with mesh:
        out = jax.jit(lambda u, w, a: torrent_fedavg(
            u, w, a, mesh=mesh, n_blocks=4))(ups, weights, active)
    wa = np.array(weights) * np.array(active); wa /= wa.sum()
    want = np.einsum("p,pij->ij", wa, np.array(ups["w"]))
    assert abs(np.array(out["w"]) - want).max() < 1e-5
    # int8 wire compression: small relative error
    with mesh:
        outc = jax.jit(lambda u, w, a: torrent_fedavg(
            u, w, a, mesh=mesh, compress=True))(ups, weights, active)
    rel = abs(np.array(outc["w"]) - want).max() / abs(want).max()
    assert rel < 0.02, rel
    """)


def test_torrent_collective_schedule_in_hlo():
    """The compiled schedule contains the explicit ppermute ring stages
    (P-1 stages x n_blocks) — the paper's dissemination schedule."""
    _run("""
    import jax, jax.numpy as jnp, re
    from repro.sharding.api import AxisType, make_mesh
    mesh = make_mesh((4, 2), ("pod", "data"),
                     axis_types=(AxisType.Auto,)*2)
    from repro.dist.torrent import torrent_fedavg
    ups = {"w": jnp.ones((4, 64))}
    w = jnp.ones(4); a = jnp.ones(4)
    with mesh:
        txt = jax.jit(lambda u, ww, aa: torrent_fedavg(
            u, ww, aa, mesh=mesh, n_blocks=4)).lower(ups, w, a).as_text()
    n_cp = len(re.findall(r"collective.permute", txt))
    assert n_cp >= 3 * 4, n_cp   # (P-1)=3 stages x 4 blocks
    """)


def test_fl_step_equals_data_parallel():
    """Full participation + equal weights: FedAvg-over-pods == DP-SGD."""
    _run("""
    import jax, jax.numpy as jnp
    from repro.sharding.api import AxisType, make_mesh
    mesh = make_mesh((4, 2), ("pod", "data"),
                     axis_types=(AxisType.Auto,)*2)
    from repro.models import ArchConfig, init_params
    from repro.optim import adamw_init
    from repro.optim.schedules import constant_lr
    from repro.dist.fl_step import make_fl_train_step
    cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=32,
                     n_heads=4, n_kv=2, head_dim=8, d_ff=64, vocab=128,
                     dtype="float32")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    opt = adamw_init(params)
    batch = {"inputs": jax.random.randint(key, (4, 4, 16), 0, 128),
             "labels": jax.random.randint(key, (4, 4, 16), 0, 128)}
    w = jnp.ones(4); a = jnp.ones(4)
    s4 = make_fl_train_step(cfg, mesh, lr_schedule=constant_lr(1e-3),
                            n_pods=4)
    s1 = make_fl_train_step(cfg, mesh, lr_schedule=constant_lr(1e-3),
                            n_pods=1)
    with mesh:
        p4, _, m4 = jax.jit(s4)(params, opt, batch, w, a)
        p1, _, m1 = jax.jit(s1)(params, opt, batch, w, a)
    diff = max(float(jnp.abs(x - y).max()) for x, y in zip(
        jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p4)))
    assert diff < 1e-4, diff
    """)


def test_fl_step_straggler_mask():
    """A masked pod (active=0) contributes nothing — fault tolerance is
    a mask, never a blocked collective."""
    _run("""
    import jax, jax.numpy as jnp
    from repro.sharding.api import AxisType, make_mesh
    mesh = make_mesh((4, 2), ("pod", "data"),
                     axis_types=(AxisType.Auto,)*2)
    from repro.models import ArchConfig, init_params
    from repro.optim import adamw_init
    from repro.optim.schedules import constant_lr
    from repro.dist.fl_step import make_fl_train_step
    cfg = ArchConfig(name="t", family="dense", n_layers=1, d_model=32,
                     n_heads=4, n_kv=2, head_dim=8, d_ff=64, vocab=128,
                     dtype="float32")
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    opt = adamw_init(params)
    b = {"inputs": jax.random.randint(key, (4, 4, 16), 0, 128),
         "labels": jax.random.randint(key, (4, 4, 16), 0, 128)}
    step = make_fl_train_step(cfg, mesh, lr_schedule=constant_lr(1e-3),
                              n_pods=4)
    w = jnp.ones(4)
    with mesh:
        # corrupting pod 3's batch has NO effect when pod 3 is masked
        a = jnp.array([1., 1., 1., 0.])
        p_ref, _, _ = jax.jit(step)(params, opt, b, w, a)
        b2 = dict(b)
        b2["inputs"] = b["inputs"].at[3].set(0)
        p_alt, _, _ = jax.jit(step)(params, opt, b2, w, a)
    diff = max(float(jnp.abs(x - y).max()) for x, y in zip(
        jax.tree_util.tree_leaves(p_ref), jax.tree_util.tree_leaves(p_alt)))
    assert diff < 1e-6, diff
    """)


@pytest.mark.slow
def test_dryrun_cell_small():
    """One real dry-run cell on 8 fake devices (mesh (2,2,2))."""
    _run("""
    import jax
    from repro.configs import get_config
    from repro.configs.base import ShapeSpec
    from repro.launch.specs import build_cell, to_shardings
    from repro.launch import hlo_analysis
    from repro.sharding.api import DEFAULT_RULES, axis_rules
    from repro.sharding.api import AxisType, make_mesh
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"),
                     axis_types=(AxisType.Auto,)*3)
    cfg = get_config("gemma2-2b", reduced=True)
    shape = ShapeSpec("t", 64, 8, "train")
    with mesh, axis_rules(DEFAULT_RULES, mesh):
        cell = build_cell(cfg, shape, mesh)
        compiled = jax.jit(
            cell["step"],
            in_shardings=to_shardings(mesh, cell["in_specs"]),
            out_shardings=to_shardings(mesh, cell["out_specs"])
        ).lower(*cell["args"]).compile()
    costs = hlo_analysis.analyze(compiled.as_text())
    assert costs.flops > 0 and costs.coll_bytes > 0
    """)


def test_moe_shardmap_matches_fallback():
    """§Perf cell-2: the shard_map expert-parallel MoE must compute the
    same outputs as the pjit scatter path (capacity high enough that
    neither drops tokens)."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.models import ArchConfig
    from repro.models.layers import _init_attn, _moe_ffn
    from repro.sharding.api import (AxisType, DEFAULT_RULES, axis_rules,
                                    make_mesh)
    cfg = ArchConfig(name="m", family="moe", n_layers=1, d_model=64,
                     n_heads=4, n_kv=4, head_dim=16, d_ff=0, vocab=128,
                     pattern=("moe",), n_experts=8, top_k=2, d_expert=32,
                     capacity_factor=8.0, dtype="float32")
    p = _init_attn(cfg, "moe", jax.random.PRNGKey(0))
    h = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64))
    ref = _moe_ffn(cfg, p, h)                      # no mesh: pjit path
    mesh = make_mesh((2, 4), ("data", "model"),
                     axis_types=(AxisType.Auto,)*2)
    with mesh, axis_rules(DEFAULT_RULES, mesh):
        out = jax.jit(lambda pp, hh: _moe_ffn(cfg, pp, hh))(p, h)
    err = float(jnp.abs(out - ref).max())
    assert err < 1e-4, err
    """)
