"""Adversary B / §III-E: Byzantine peers degrade liveness, never
integrity or honest-sender unlinkability."""
import numpy as np

from repro.core import SwarmConfig
from repro.core.byzantine import ByzantineModel, claimed_inventory
from repro.core.privacy import per_transfer_cap
from repro.core.simulator import RoundSimulator


def _run(byz, seed=0, n=16, K=24, **kw):
    cfg = SwarmConfig(n=n, chunks_per_update=K, s_max=6000, seed=seed,
                      **kw)
    return cfg, RoundSimulator(cfg, byzantine=byz).run()


def test_round_survives_byzantine_minority():
    byz = ByzantineModel(behaviours={1: "lie", 2: "withhold", 3: "slow"})
    cfg, res = _run(byz)
    assert not res.metrics.failed_open
    # every honest client reconstructs a non-trivial active set
    honest = [v for v in range(cfg.n) if v not in byz.behaviours
              and res.active[v]]
    assert all(res.reconstructable[v].sum() >= 1 for v in honest)


def test_withholder_timed_out():
    """Per-peer progress timeouts mark non-serving peers inactive for
    scheduling (§III-E (b))."""
    byz = ByzantineModel(behaviours={2: "withhold"}, timeout_slots=3)
    cfg, res = _run(byz)
    assert not res.active[2]


def test_eq1_holds_for_honest_senders():
    """The unlinkability bound applies to transfers SENT BY HONEST
    peers (§IV-A) — Byzantine presence must not break it."""
    byz = ByzantineModel(behaviours={1: "lie", 4: "slow"})
    cfg, res = _run(byz, seed=3)
    log = res.log
    warm = log["phase"] == 1
    honest = warm & ~np.isin(log["sender"], list(byz.behaviours))
    post = (log["o_size"][honest].astype(float)
            / np.maximum(log["b_size"][honest], 1))
    assert (post <= per_transfer_cap(cfg.owner_throttle, cfg.k_gate)
            + 1e-12).all()


def test_lies_never_deliver_garbage():
    """Hash verification discards tampered payloads: no delivered chunk
    in the log was sent by a peer that didn't hold it (the simulator
    models discarded garbage as a non-delivery)."""
    byz = ByzantineModel(behaviours={0: "lie", 5: "lie"},
                         lie_fraction=0.9)
    cfg, res = _run(byz, seed=4)
    # all receivers end with consistent inventories: reconstructable
    # sets agree across surviving honest clients
    surv = [v for v in range(cfg.n) if res.active[v]]
    recon = res.reconstructable[surv]
    assert (recon == recon[0]).all()


def test_claimed_inventory_overclaims_only_liars():
    cfg = SwarmConfig(n=8, chunks_per_update=8, s_max=100, seed=0,
                      min_degree=4)
    sim = RoundSimulator(cfg, byzantine=ByzantineModel(
        behaviours={3: "lie"}))
    st = sim.state
    claimed = claimed_inventory(sim.byz, st, sim.rng)
    assert (claimed[3].sum() > st.have[3].sum())
    for v in range(8):
        if v != 3:
            assert (claimed[v] == st.have[v]).all()


def test_heavy_byzantine_fails_open_but_stays_live():
    """With most neighbours withholding, warm-up cannot complete by
    s_max: the round fails open to vanilla BT (liveness preserved,
    unlinkability void — §III-E)."""
    byz = ByzantineModel(
        behaviours={i: "withhold" for i in range(1, 14)},
        timeout_slots=10_000)          # no timeouts: worst case
    cfg = SwarmConfig(n=16, chunks_per_update=24, s_max=5, seed=5)
    res = RoundSimulator(cfg, byzantine=byz).run()
    assert res.metrics.failed_open
    assert res.metrics.t_round >= res.metrics.t_warm
