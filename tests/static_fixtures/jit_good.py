"""Known-good jit-readiness fixture: the same shapes, trace-safe.

Masked arithmetic instead of value branches, bounded loops, no host
round-trips — what the slated functions look like after the kernel
rewrite.
"""
import numpy as np


def maxmin_rates(rem, rates, n_passes=8):
    for _ in range(n_passes):              # bounded, data-independent
        mask = rem > 0
        rates = np.where(mask, rates + 1, rates)
        rem = np.where(mask, rem - 1, rem)
    return rates


def transport(rem, rates, max_steps=64):
    total = np.zeros(())
    for _ in range(max_steps):             # bounded fori-style loop
        alive = rem > 0
        step = np.min(np.where(alive, rem, np.inf))
        step = np.where(np.isfinite(step), step, 0.0)
        rem = rem - step * alive
        total = total + step
    return total
