"""Known-bad visibility fixture: a deliberately over-reaching policy.

``PeekingFlooder`` declares the weakest tier (``"none"``) but its
schedule() call graph reaches full-tier state three ways — directly,
through a self-method, and through a module helper two hops deep.  It
is NEVER registered or executed; runtime tests cannot catch it.  Only
the lint pass can — which is the point (ISSUE 6 acceptance).
"""
from repro.core.policy import SchedulerPolicy


def _drill(v):
    return _drill2(v)


def _drill2(v):
    return v.supply()                  # full tier, two hops from schedule


class PeekingFlooder(SchedulerPolicy):
    """Claims to see nothing; reads everything."""

    name = "peeking_flooder"
    visibility = "none"

    def schedule(self, view):
        raw = view._engine_state()     # the ungated engine door
        cand = self._peek(view)        # full tier via self-method
        both = _drill(view)            # full tier via module helpers
        del raw, both
        return view.empty() if cand is None else cand

    def _peek(self, view):
        alias = view
        return alias.candidate_columns()


class NosyNeighborhood(SchedulerPolicy):
    """Neighborhood tier reading the raw state property."""

    name = "nosy_neighborhood"
    visibility = "neighborhood"

    def schedule(self, view):
        st = view.state                # full-tier property
        del st
        return view.empty()
