"""Known-bad rng-discipline fixture: every RNG rule fires here."""
import random
import time
from datetime import datetime

import numpy as np


def stdlib_draw(n):
    # RNG001: process-global stdlib stream
    return [random.random() for _ in range(n)]


def legacy_global(n):
    np.random.seed(0)                       # RNG002
    return np.random.choice(n, size=n)      # RNG002


def fresh_entropy():
    rng = np.random.default_rng()           # RNG003: OS entropy
    return rng.integers(0, 10)


def shadowed_fallback(n, rng=None):
    if rng is None:
        rng = np.random.default_rng(0)      # RNG004: constant seed
    return rng.integers(0, n)


def set_order(ids):
    peers = set(ids)
    out = []
    for p in peers:                         # RNG005: set iteration
        out.append(p)
    out += [q for q in {1, 2, 3}]           # RNG005: set literal
    return out


def identity_sort(objs):
    return sorted(objs, key=id)             # RNG006


def stamp_rows(rows):
    now = time.perf_counter()               # RNG007
    return [(r, now, datetime.now()) for r in rows]     # RNG007
