"""Known-good observability fixture: the same jobs routed through
repro.obs / the injectable clocks — no rule may fire."""
from repro import obs


def typed_spray_event(snd, rcv):
    rec = obs.get()
    if rec.enabled:
        rec.event("net.spray", n_snd=len(snd), n_rcv=len(rcv))
    return len(snd)


def typed_counters(rows):
    obs.get().counter("rows_seen", len(rows))


def spanned_timing(fn):
    # Host time flows through the recorder's injectable span clock
    # (or core.simulator.measured_clock) — never read inline.
    with obs.get().span("fn"):
        fn()


def referenced_clock_is_fine(clock=None):
    # Referencing (not calling) a clock attribute to inject elsewhere
    # is the measured_clock idiom, not a violation.
    import time
    return clock if clock is not None else time.perf_counter
