"""Known-good visibility fixture: tier-honest policies, no findings.

``PoliteNeighborhood`` stays inside its declared tier (neighborhood
union + ungated protocol facts + visibility-free mechanics);
``HonestCentral`` reads full-tier state but *declares* full.
"""
import numpy as np

from repro.core.policy import SchedulerPolicy


class PoliteNeighborhood(SchedulerPolicy):
    name = "polite_neighborhood"
    visibility = "neighborhood"

    def schedule(self, view):
        cand, union = view.availability_union()   # exactly its tier
        open_rx = np.flatnonzero(view.receivers_open())
        if cand.size == 0 or open_rx.size == 0:
            return view.empty()
        v = int(open_rx[0])
        ids = np.flatnonzero(union[v])[: int(view.down[v])]
        nbr = np.flatnonzero(view.adj[v])
        tgt = view.rng.choice(nbr, size=ids.size)
        ok = view.resolve_requests(tgt, cand[ids])
        return (tgt[ok], np.full(int(ok.sum()), v, np.int64),
                cand[ids[ok]])


class HonestCentral(SchedulerPolicy):
    name = "honest_central"
    visibility = "full"

    def schedule(self, view):
        cand, sup = view.supply()                 # full, declared full
        del cand, sup
        return view.empty()
