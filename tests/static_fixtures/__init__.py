"""Fixture modules for repro.analysis unit tests.

Each ``*_bad.py`` violates exactly the constructs its rule family
flags; each ``*_good.py`` does the same job the disciplined way and
must stay finding-free.  Nothing here is executed by the simulator —
``vis_bad.py`` in particular registers nothing and is never imported
at runtime; only the analyzer reads these files.
"""
