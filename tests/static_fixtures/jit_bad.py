"""Known-bad jit-readiness fixture.

Defines functions named like the kernel-slated targets (the jit rules
match on slated names under ``--assume-library``) with every
untraceable construct: value branches, host coercions, data-dependent
loops.
"""
import numpy as np


def maxmin_rates(rem, rates):
    if rem.any():                          # JIT101
        rates = rates + 1
    if float(rem.sum()) > 0:               # JIT101 + JIT102
        rates = rates * 2
    return rates


def transport(rem, rates):
    total = 0.0
    while rem.any():                       # JIT103
        step = rem.min().item()            # JIT102
        rem = rem - step
        total += step
    while True:                            # JIT103
        break
    for i in np.flatnonzero(rem):          # JIT103
        total += int(rates[i] * 2.0)       # JIT102
    return total
