"""Known-good rng-discipline fixture: the same jobs, threaded — no
rule may fire."""
import numpy as np


def threaded_draw(n, rng: np.random.Generator):
    return rng.random(n)


def entry_point(cfg_seed: int):
    # Seeding from config at an entry point is the contract, not a
    # violation; salted derived streams likewise.
    rng = np.random.default_rng(cfg_seed)
    child = np.random.default_rng(
        np.random.SeedSequence([cfg_seed, 0x5A17]))
    return rng, child


def required_param(n, rng=None):
    if rng is None:
        raise ValueError("pass a threaded Generator")
    return rng.integers(0, n)


def ordered_iteration(ids):
    peers = set(ids)
    return [p for p in sorted(peers)]


def stable_sort(objs):
    return sorted(objs, key=lambda o: o[0])
