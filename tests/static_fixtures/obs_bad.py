"""Known-bad observability fixture: every OBS rule fires here."""
import time


def debug_spray(snd, rcv):
    print(f"spray {len(snd)} -> {len(rcv)}")        # OBS001
    return len(snd)


def module_report(rows):
    print("rows:", len(rows))                       # OBS001


def inline_timing(fn):
    t0 = time.perf_counter()                        # OBS002
    fn()
    time.sleep(0.01)                                # OBS002
    return time.perf_counter() - t0                 # OBS002


def stamped(payload):
    return {"at": time.strftime("%H:%M"),           # OBS002
            "payload": payload}
