"""Slot-engine equivalence across loop, batched and jit (tentpole
invariants).

Every engine must schedule *legally* — per-slot uplink/downlink
budgets, tau concurrency, adjacency, duplicate-free delivery, cover-set
gating (Eq. 1) — and the three engines must match in *aggregate*
throughput (t_warm, utilization) within tolerance, across every
scheduler mode.  Exact per-transfer equality across engines is not
required (each consumes randomness differently); legality plus
aggregate parity is the contract.  Within one engine, a fixed seed must
replay a byte-identical trace (the determinism twins below pin this for
the jit engine under ``SwarmSession`` on both time engines).
"""
from collections import Counter

import numpy as np
import pytest

from repro.core import SwarmConfig, SwarmSession, simulate_round
from repro.core import privacy

MODES = ["random_fifo", "random_fastest_first", "greedy_fastest_first",
         "distributed", "flooding"]
CENTRALIZED = {"random_fifo", "random_fastest_first",
               "greedy_fastest_first"}


def _cfg(mode, seed, impl, **kw):
    base = dict(n=16, chunks_per_update=24, s_max=5000, seed=seed,
                scheduler=mode, scheduler_impl=impl)
    base.update(kw)
    return SwarmConfig(**base)


def _replay_legality(cfg, res, check_tau):
    """Replay the log slot by slot against reconstructed inventories."""
    n, K = cfg.n, cfg.chunks_per_update
    log = res.log
    have = np.zeros((n, cfg.total_chunks), dtype=bool)
    for v in range(n):
        have[v, v * K:(v + 1) * K] = True
    # spray (phase 0) applies before warm-up slot 0
    key = log["slot"].astype(np.int64) * 4 + log["phase"]
    order = np.argsort(key, kind="stable")
    snd = log["sender"][order]
    rcv = log["receiver"][order]
    chk = log["chunk"][order]
    ph = log["phase"][order]
    key = key[order]
    for s in np.unique(key):
        sl = key == s
        # sender must hold every chunk it sends, receiver must miss it
        assert have[snd[sl], chk[sl]].all(), "sender missing chunk"
        assert not have[rcv[sl], chk[sl]].any(), "duplicate delivery"
        have[rcv[sl], chk[sl]] = True
        if (ph[sl] == 0).any():
            continue                    # spray is tracker-tunnelled
        assert (np.bincount(snd[sl], minlength=n) <= res.up).all(), \
            "uplink budget exceeded"
        assert (np.bincount(rcv[sl], minlength=n) <= res.down).all(), \
            "downlink budget exceeded"
        assert res.adj[snd[sl], rcv[sl]].all(), "non-adjacent transfer"
        if check_tau:
            pairs = set(zip(snd[sl].tolist(), rcv[sl].tolist()))
            per_sender = Counter(u for u, _ in pairs)
            assert max(per_sender.values(), default=0) \
                <= cfg.tau_concurrent, "tau concurrency exceeded"


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("seed", [1, 9])
def test_batched_schedules_legally(mode, seed):
    cfg = _cfg(mode, seed, "batched")
    res = simulate_round(cfg)
    # tau applies to the tracker-assigned centralized modes only
    _replay_legality(cfg, res, check_tau=mode in CENTRALIZED)


@pytest.mark.parametrize("mode", MODES)
def test_batched_satisfies_eq1(mode):
    """Gating cap Eq. (1) holds on every batched-engine warm-up send."""
    cfg = _cfg(mode, 3, "batched")
    res = simulate_round(cfg)
    assert privacy.check_eq1(res.log, cfg.owner_throttle, cfg.k_gate)


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("seed", [1, 9])
def test_aggregate_parity(mode, seed):
    """t_warm and warm-up utilization agree loop-vs-batched within
    tolerance (small swarms are noisy; bands are deliberately loose but
    tight enough to catch a broken engine, which degrades >2x)."""
    rl = simulate_round(_cfg(mode, seed, "loop")).metrics
    rb = simulate_round(_cfg(mode, seed, "batched")).metrics
    assert not rb.failed_open and not rl.failed_open
    assert abs(rb.t_warm - rl.t_warm) <= max(3, 0.6 * rl.t_warm)
    assert abs(rb.warmup_utilization - rl.warmup_utilization) <= 0.2
    assert abs(rb.t_round - rl.t_round) <= max(5, 0.35 * rl.t_round)


# ---------------------------------------------------------------------------
# jit engine: legality, Eq. 1, three-way parity, determinism twins
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("seed", [1, 9])
def test_jit_schedules_legally(mode, seed):
    cfg = _cfg(mode, seed, "jit")
    res = simulate_round(cfg)
    _replay_legality(cfg, res, check_tau=mode in CENTRALIZED)


@pytest.mark.parametrize("mode", MODES)
def test_jit_satisfies_eq1(mode):
    """Gating cap Eq. (1) holds on every jit-engine warm-up send."""
    cfg = _cfg(mode, 3, "jit")
    res = simulate_round(cfg)
    assert privacy.check_eq1(res.log, cfg.owner_throttle, cfg.k_gate)


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("seed", [1, 9])
def test_three_way_aggregate_parity(mode, seed):
    """jit tracks both host engines within the loop-vs-batched bands."""
    rl = simulate_round(_cfg(mode, seed, "loop")).metrics
    rj = simulate_round(_cfg(mode, seed, "jit")).metrics
    assert not rj.failed_open
    assert abs(rj.t_warm - rl.t_warm) <= max(3, 0.6 * rl.t_warm)
    assert abs(rj.warmup_utilization - rl.warmup_utilization) <= 0.2
    assert abs(rj.t_round - rl.t_round) <= max(5, 0.35 * rl.t_round)
    rb = simulate_round(_cfg(mode, seed, "batched")).metrics
    assert abs(rj.t_warm - rb.t_warm) <= max(3, 0.6 * rb.t_warm)
    assert abs(rj.warmup_utilization - rb.warmup_utilization) <= 0.2


def _session_traces(time_engine):
    cfg = SwarmConfig(n=20, chunks_per_update=16, min_degree=5,
                      s_max=4000, seed=7, scheduler_impl="jit")
    kw = {}
    if time_engine == "event":
        from repro.net import NetConfig
        kw = dict(time_engine="event",
                  net=NetConfig(tracker_rtt_s=0.05))
    ses = SwarmSession(cfg, churn_rate=0.1, **kw)
    ses.run(2)
    return ses.trace()


@pytest.mark.parametrize("time_engine", ["slot", "event"])
def test_jit_determinism_twin(time_engine):
    """A fixed seed replays a byte-identical multi-round TransferTrace
    under SwarmSession on both time engines: the jit engine draws
    exactly two host rng values per slot and keys its kernel noise from
    the second, so schedules cannot depend on device iteration order."""
    a = _session_traces(time_engine)
    b = _session_traces(time_engine)
    for key in ("slot", "sender", "receiver", "chunk", "owner",
                "b_size", "o_size", "phase"):
        assert np.array_equal(a[key], b[key]), (time_engine, key)
    assert np.array_equal(a.t_start, b.t_start)
    assert np.array_equal(a.t_end, b.t_end)


def test_aggregate_parity_paper_scale_warm():
    """At n=64 the engines' warm-up phases track each other closely."""
    kw = dict(n=64, chunks_per_update=32, s_max=20000)
    rl = simulate_round(
        SwarmConfig(seed=0, scheduler_impl="loop", **kw),
        bt_mode="fluid").metrics
    rb = simulate_round(
        SwarmConfig(seed=0, scheduler_impl="batched", **kw),
        bt_mode="fluid").metrics
    assert abs(rb.t_warm - rl.t_warm) <= max(2, 0.25 * rl.t_warm)
    # the batched engine's fair round-robin packs slots a little better
    # than the sequential loop engine; allow it to win, bounded
    assert rb.warmup_utilization >= rl.warmup_utilization - 0.12
    assert rb.warmup_utilization <= rl.warmup_utilization + 0.16


def test_batched_nonowner_first_preference():
    """Non-owner-first lowers the owner-sent fraction for the batched
    engine, mirroring the loop-engine property test."""
    def owner_frac(flag):
        cfg = SwarmConfig(n=16, chunks_per_update=24, s_max=4000, seed=8,
                          enable_nonowner_first=flag,
                          scheduler_impl="batched")
        log = simulate_round(cfg).log
        warm = log["phase"] == 1
        return float((log["sender"][warm] == log["owner"][warm]).mean())

    assert owner_frac(True) <= owner_frac(False) + 1e-9


def test_batched_respects_maxflow_bound():
    """Per-slot batched throughput never exceeds the offline max-flow
    stage bound (legality implies this; checked end-to-end)."""
    cfg = SwarmConfig(n=14, chunks_per_update=20, s_max=3000, seed=5,
                      scheduler_impl="batched")
    res = simulate_round(cfg, collect_maxflow=True)
    sent = res.warmup_sent_per_slot[:len(res.maxflow_ub)]
    assert (sent <= res.maxflow_ub + 1e-9).all()


def test_batched_handles_ablations_and_dropouts():
    """Gating/spray/lag toggles and dropouts run clean under batched."""
    for pr in (False, True):
        for gate in (False, True):
            cfg = SwarmConfig(n=12, chunks_per_update=16, s_max=4000,
                              seed=2, enable_preround=pr,
                              enable_timelag=not pr, enable_gating=gate,
                              scheduler_impl="batched")
            res = simulate_round(cfg)
            assert not res.metrics.failed_open
    cfg = SwarmConfig(n=12, chunks_per_update=16, s_max=4000, seed=4,
                      scheduler_impl="batched")
    res = simulate_round(cfg, dropouts={2: [0, 1]})
    assert not res.active[0] and not res.active[1]


def test_loop_impl_still_selectable():
    """scheduler_impl='loop' routes to the reference engine and is the
    documented escape hatch."""
    cfg = SwarmConfig(n=12, chunks_per_update=16, s_max=3000, seed=0,
                      scheduler_impl="loop")
    res = simulate_round(cfg)
    assert not res.metrics.failed_open
    with pytest.raises(ValueError):
        simulate_round(SwarmConfig(n=8, chunks_per_update=8, s_max=50,
                                   scheduler_impl="nope"))
