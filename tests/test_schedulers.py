"""Warm-up scheduler family (paper §III-C): completion, budgets,
ordering vs the max-flow upper bound (Fig. 3 pattern)."""
import numpy as np
import pytest

from repro.core import SwarmConfig, simulate_round

SCHEDULERS = ["greedy_fastest_first", "random_fastest_first",
              "random_fifo", "distributed", "flooding"]


@pytest.mark.parametrize("sched", SCHEDULERS)
def test_warmup_completes(sched):
    cfg = SwarmConfig(n=16, chunks_per_update=24, s_max=5000, seed=1,
                      scheduler=sched)
    res = simulate_round(cfg)
    assert not res.metrics.failed_open
    assert res.metrics.t_warm > 0
    # warm-up terminates only once every client holds >= k_term chunks
    assert res.metrics.warmup_chunks_sent >= cfg.k_term - cfg.chunks_per_update


@pytest.mark.parametrize("sched", ["greedy_fastest_first", "random_fifo"])
def test_budgets_respected(sched):
    cfg = SwarmConfig(n=12, chunks_per_update=16, s_max=3000, seed=2,
                      scheduler=sched)
    sim_res = simulate_round(cfg)
    log = sim_res.log
    warm = log["phase"] == 1
    up = sim_res.up
    down = sim_res.down
    for s in np.unique(log["slot"][warm]):
        sl = warm & (log["slot"] == s)
        snd_counts = np.bincount(log["sender"][sl], minlength=cfg.n)
        rcv_counts = np.bincount(log["receiver"][sl], minlength=cfg.n)
        assert (snd_counts <= up).all(), f"uplink exceeded at slot {s}"
        assert (rcv_counts <= down).all(), f"downlink exceeded at slot {s}"
        # adjacency respected
        assert sim_res.adj[log["sender"][sl], log["receiver"][sl]].all()


def test_no_duplicate_deliveries():
    cfg = SwarmConfig(n=12, chunks_per_update=16, s_max=3000, seed=4)
    res = simulate_round(cfg)
    pairs = set()
    log = res.log
    for r, c in zip(log["receiver"], log["chunk"]):
        key = (int(r), int(c))
        assert key not in pairs, "duplicate delivery"
        pairs.add(key)


def test_maxflow_upper_bounds_throughput():
    cfg = SwarmConfig(n=14, chunks_per_update=20, s_max=3000, seed=5)
    res = simulate_round(cfg, collect_maxflow=True)
    sent = res.warmup_sent_per_slot[:len(res.maxflow_ub)]
    assert (sent <= res.maxflow_ub + 1e-9).all()


def test_greedy_beats_flooding_utilization():
    """Coordinated warm-up sustains higher utilization than flooding
    (paper §III-C.7)."""
    def util(s):
        cfg = SwarmConfig(n=16, chunks_per_update=24, s_max=4000, seed=6,
                          scheduler=s)
        return simulate_round(cfg).metrics.warmup_utilization

    assert util("greedy_fastest_first") > util("flooding")


def test_greedy_near_maxflow():
    """GreedyFastestFirst attains a high fraction of the stage-wise
    max-flow UB (paper: ~92%; we assert a conservative floor on the
    aggregate ratio at small scale)."""
    cfg = SwarmConfig(n=20, chunks_per_update=30, s_max=4000, seed=7,
                      scheduler="greedy_fastest_first")
    res = simulate_round(cfg, collect_maxflow=True)
    sent = res.warmup_sent_per_slot[:len(res.maxflow_ub)].sum()
    ub = res.maxflow_ub.sum()
    assert sent / max(ub, 1) > 0.6


def test_nonowner_first_reduces_owner_sends():
    def owner_frac(nonowner_first):
        cfg = SwarmConfig(n=16, chunks_per_update=24, s_max=4000, seed=8,
                          enable_nonowner_first=nonowner_first)
        log = simulate_round(cfg).log
        warm = log["phase"] == 1
        return float((log["sender"][warm] == log["owner"][warm]).mean())

    assert owner_frac(True) <= owner_frac(False) + 1e-9
