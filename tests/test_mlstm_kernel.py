"""Pallas chunkwise-mLSTM kernel vs the (already sequence-validated)
XLA chunkwise oracle, swept over shapes/dtypes/chunk sizes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.mlstm import mlstm_chunkwise
from repro.models.layers import _mlstm_chunkwise


def _oracle(q, k, v, ip, fp, chunk):
    b, h, t, dh = q.shape
    init = (jnp.zeros((b, h, dh, dh)), jnp.zeros((b, h, dh)),
            jnp.full((b, h), -1e30))
    (C, n, m), hs = _mlstm_chunkwise(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), ip.transpose(0, 2, 1),
        fp.transpose(0, 2, 1), init, chunk=chunk, remat=False)
    return hs.reshape(b, t, h, dh).transpose(0, 2, 1, 3), C, n, m


@pytest.mark.parametrize("b,h,t,dh,chunk", [
    (2, 4, 64, 16, 16), (1, 2, 128, 32, 32), (1, 1, 256, 128, 128),
    (2, 2, 96, 8, 16),
])
def test_mlstm_kernel_vs_oracle(b, h, t, dh, chunk):
    ks = jax.random.split(jax.random.PRNGKey(t * 13 + dh), 5)
    q = jax.random.normal(ks[0], (b, h, t, dh)) * (dh ** -0.5)
    k = jax.random.normal(ks[1], (b, h, t, dh)) * (dh ** -0.5)
    v = jax.random.normal(ks[2], (b, h, t, dh))
    ip = jax.random.normal(ks[3], (b, h, t))
    fp = jax.random.normal(ks[4], (b, h, t)) + 1.0
    h1, C1, n1, m1 = _oracle(q, k, v, ip, fp, chunk)
    h2, C2, n2, m2 = mlstm_chunkwise(q, k, v, ip, fp, chunk=chunk,
                                     interpret=True)
    np.testing.assert_allclose(h2, h1, atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(C2, C1, atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(n2, n1, atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(m2, m1, atol=5e-4, rtol=5e-4)


def test_mlstm_kernel_bf16():
    b, h, t, dh = 1, 2, 64, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = (jax.random.normal(ks[0], (b, h, t, dh)) * dh ** -0.5
         ).astype(jnp.bfloat16)
    k = (jax.random.normal(ks[1], (b, h, t, dh)) * dh ** -0.5
         ).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (b, h, t, dh)).astype(jnp.bfloat16)
    ip = jax.random.normal(ks[3], (b, h, t))
    fp = jax.random.normal(ks[4], (b, h, t)) + 1.0
    h2, *_ = mlstm_chunkwise(q, k, v, ip, fp, chunk=32, interpret=True)
    assert h2.dtype == jnp.bfloat16
    h1, *_ = _oracle(q.astype(jnp.float32), k.astype(jnp.float32),
                     v.astype(jnp.float32), ip, fp, 32)
    np.testing.assert_allclose(h2.astype(jnp.float32), h1, atol=3e-2,
                               rtol=3e-2)
