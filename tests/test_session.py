"""SwarmSession churn semantics (paper §III-E).

Covers the cross-round contracts: zero churn is bit-identical to the
historical per-round ``simulate_round`` loop, rejoining clients receive
the *current* round's params (never stale ones), a leave mid-session
never blocks a collective, capacities persist for surviving peers, the
overlay evolves by incremental repair, and elastic re-mesh P -> P-1 -> P
preserves ``torrent_fedavg`` numerics.
"""
import os
import re
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ChurnModel, SwarmConfig, SwarmSession, simulate_round

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cfg(**kw):
    base = dict(n=16, chunks_per_update=12, min_degree=4, s_max=5000,
                seed=3)
    base.update(kw)
    return SwarmConfig(**base)


# ---------------------------------------------------------------------------
# zero churn == today's per-round loop, seed for seed
# ---------------------------------------------------------------------------

def test_zero_churn_bit_identical_to_simulate_round():
    cfg = _cfg()
    ses = SwarmSession(cfg)   # churn_rate=0 default
    for r, rec in enumerate(ses.run(3)):
        ref = simulate_round(cfg.replace(seed=cfg.seed * 1000 + r))
        m, mm = rec.result.metrics, ref.metrics
        assert (m.t_warm, m.t_round, m.warmup_chunks_sent,
                m.bt_chunks_sent) == (mm.t_warm, mm.t_round,
                                      mm.warmup_chunks_sent,
                                      mm.bt_chunks_sent)
        assert np.array_equal(rec.result.adj, ref.adj)
        assert np.array_equal(rec.result.up, ref.up)
        for key in ("slot", "sender", "receiver", "chunk", "phase"):
            assert np.array_equal(rec.result.log[key], ref.log[key]), key


# ---------------------------------------------------------------------------
# churn membership semantics
# ---------------------------------------------------------------------------

def _churny_session(rounds=8, **kw):
    churn = ChurnModel(leave_prob=kw.pop("leave_prob", 0.25),
                       join_rate=kw.pop("join_rate", 0.5),
                       rejoin_after=kw.pop("rejoin_after", 2))
    ses = SwarmSession(_cfg(**kw), churn=churn)
    recs = ses.run(rounds)
    return ses, recs


def test_leave_never_blocks_collective():
    """Every round completes and aggregation proceeds over the
    reconstructable set — regardless of who left at the boundary."""
    ses, recs = _churny_session(rounds=8, leave_prob=0.35)
    assert any(r.left.size for r in recs), "churn never fired"
    for rec in recs:
        m = rec.result.metrics
        assert m.t_round < ses.cfg.s_max          # finished, not hung
        # |A_v^r| >= 1 for every active client: nobody waits forever.
        assert rec.result.reconstructable.any(axis=1).all()
        # the leave clamp keeps enough peers to mesh
        assert rec.active_ids.size >= ses.min_active


def test_rejoin_happens_at_round_boundary():
    ses, recs = _churny_session(rounds=8, leave_prob=0.3, rejoin_after=1)
    rejoined = [(rec.round_idx, v) for rec in recs
                for v in rec.rejoined.tolist()]
    assert rejoined, "no rejoin event in 8 rounds"
    for r, v in rejoined:
        # a rejoiner sat out the previous round and is back exactly at
        # this round boundary
        assert v not in recs[r - 1].active_ids
        assert v in recs[r].active_ids


def test_capacities_persist_for_surviving_peers():
    ses, recs = _churny_session(rounds=6)
    up0 = {}
    for rec in recs:
        ids = rec.active_ids
        for local, g in enumerate(ids.tolist()):
            u = int(rec.result.up[local])
            if g in up0:
                assert u == up0[g], f"peer {g} capacity re-rolled"
            else:
                up0[g] = u


def test_overlay_evolves_incrementally_with_min_degree_repair():
    ses, recs = _churny_session(rounds=6)
    for rec in recs:
        n_act = rec.active_ids.size
        deg = rec.result.adj.sum(axis=1)
        assert (deg >= min(ses.cfg.min_degree, n_act - 1)).all()
    # Persistent neighbor pairs exist across rounds (the statistic
    # topology-dependent privacy bounds grow with) — a full re-roll
    # would make multi-round exposure rare, incremental repair keeps it.
    assert ses.pair_exposure().max() >= 3
    assert 0.0 < ses.edge_persistence() <= 1.0


def test_global_log_maps_local_to_global_ids():
    ses, recs = _churny_session(rounds=4, leave_prob=0.3)
    rec = next(r for r in recs if r.active_ids.size < ses.n_peers)
    glog = rec.global_log()
    assert set(np.unique(glog["sender"])) <= set(rec.active_ids.tolist())
    # local log ids stay within the round's local index space
    assert rec.result.log["sender"].max() < rec.active_ids.size


# ---------------------------------------------------------------------------
# FL runner on the session: stale params + catch-up on rejoin
# ---------------------------------------------------------------------------

def test_rejoining_client_receives_current_round_params():
    from repro.fl.client import LocalSpec
    from repro.fl.runner import FLConfig, run_experiment
    cfg = FLConfig(dataset="synth-cifar", model="mlp", dist="dir0.5",
                   n_clients=8, rounds=6,
                   local=LocalSpec(epochs=1, batch_size=32, lr=0.03),
                   n_train=1500, n_test=400, seed=0, min_degree=4,
                   churn_rate=0.3, rejoin_after=1)
    res = run_experiment("fltorrent", cfg)
    assert res.rejoin_rounds, "no rejoin happened in 6 rounds"
    # some rejoiner really held stale params (absence had an effect) ...
    assert res.stale_seen
    # ... and every active client trained from the CURRENT global
    # params after its boundary catch-up, never the stale copy.
    assert res.caught_up
    assert res.agreement
    assert any(p < 1.0 for p in res.participation)


def test_runner_zero_churn_unchanged():
    """churn_rate=0 keeps the full-participation trajectory and its
    diagnostics trivial (everyone in, nobody stale)."""
    from repro.fl.client import LocalSpec
    from repro.fl.runner import FLConfig, run_experiment
    base = dict(dataset="synth-cifar", model="mlp", dist="dir0.5",
                n_clients=6, rounds=3,
                local=LocalSpec(epochs=1, batch_size=32, lr=0.03),
                n_train=1000, n_test=300, seed=1, min_degree=3)
    res = run_experiment("fltorrent", FLConfig(**base))
    assert res.participation == [1.0] * 3
    assert res.rejoin_rounds == []
    assert not res.stale_seen and res.caught_up and res.agreement


# ---------------------------------------------------------------------------
# elastic re-mesh numerics: P -> P-1 -> P
# ---------------------------------------------------------------------------

def test_elastic_remesh_preserves_torrent_fedavg_numerics():
    from repro.dist.torrent import take_pods, torrent_fedavg
    rng = np.random.default_rng(0)
    ups = {"w": jnp.asarray(rng.normal(size=(4, 33)).astype(np.float32)),
           "b": jnp.asarray(rng.normal(size=(4, 7)).astype(np.float32))}
    w = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    ones4 = jnp.ones(4)

    full = torrent_fedavg(ups, w, ones4, n_blocks=4)
    # P -> P-1: pod 2 leaves; the 3-ring over the survivors must equal
    # the 4-ring with pod 2 masked (weights renormalize identically).
    keep = np.array([0, 1, 3])
    masked = torrent_fedavg(ups, w, jnp.asarray([1., 1., 0., 1.]),
                            n_blocks=4)
    shrunk = torrent_fedavg(take_pods(ups, keep), w[keep], jnp.ones(3),
                            n_blocks=4)
    for k in ups:
        np.testing.assert_allclose(shrunk[k], masked[k], atol=1e-6)
    # P-1 -> P: the pod rejoins; numerics return to the full aggregate.
    back = torrent_fedavg(take_pods(ups, np.arange(4)), w, ones4,
                          n_blocks=4)
    for k in ups:
        np.testing.assert_allclose(back[k], full[k], atol=1e-6)


def test_elastic_fl_step_remesh_cycle_single_device():
    """ElasticFLStep P=4 -> 3 -> 4 on one device: ring schedule rebuilt
    per P, cache hit on return, params stay finite."""
    from repro.dist.fl_step import ElasticFLStep
    from repro.models import ArchConfig, init_params
    from repro.optim import adamw_init
    from repro.optim.schedules import constant_lr
    import jax

    cfg = ArchConfig(name="t", family="dense", n_layers=1, d_model=32,
                     n_heads=4, n_kv=2, head_dim=8, d_ff=64, vocab=64,
                     dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = ElasticFLStep(cfg, lr_schedule=constant_lr(1e-3),
                         mesh_factory=lambda p: None)
    rng = np.random.default_rng(0)

    def batch(p):
        x = rng.integers(0, 64, size=(p, 2, 8))
        return {"inputs": jnp.asarray(x, jnp.int32),
                "labels": jnp.asarray(x, jnp.int32)}

    for p in (4, 3, 4):
        params, opt, m = step(params, opt, batch(p), jnp.ones(p),
                              jnp.ones(p))
        assert np.isfinite(float(m["loss"]))
    assert step.pod_counts == [3, 4]
    _, jit4 = step.step_for(4)
    _, jit4b = step.step_for(4)
    assert jit4 is jit4b     # revisiting a pod count hits the cache
    assert all(np.isfinite(np.asarray(l)).all()
               for l in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# launch-level recovery drill: --pods 4 --drop-pod 2
# ---------------------------------------------------------------------------

@pytest.mark.dist
def test_train_drop_pod_recovery_within_10pct():
    """The acceptance drill: a 4-pod run that drops pod 2 mid-training
    re-meshes to 3 pods, finishes, and lands within 10% of the no-drop
    final loss."""
    def run(extra):
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(ROOT, "src")
        cmd = [sys.executable, "-m", "repro.launch.train",
               "--arch", "qwen3-1.7b", "--reduced", "--pods", "4",
               "--steps", "12", "--batch", "8", "--seq", "32",
               "--log-every", "4"] + extra
        res = subprocess.run(cmd, env=env, capture_output=True,
                             text=True, timeout=900)
        assert res.returncode == 0, res.stderr[-4000:]
        m = re.search(r"final loss ([0-9.]+)", res.stdout)
        assert m, res.stdout[-2000:]
        return float(m.group(1)), res.stdout

    drop_loss, drop_out = run(["--drop-pod", "2"])
    assert "re-meshing 4 -> 3 pods" in drop_out
    assert "re-mesh continuity ok" in drop_out
    base_loss, _ = run([])
    assert abs(drop_loss - base_loss) <= 0.10 * base_loss, \
        (drop_loss, base_loss)


@pytest.mark.dist
def test_train_join_pod_growth_continuity():
    """The symmetric growth drill: a 3-pod run gains a pod mid-training,
    re-meshes 3 -> 4 over the enlarged device set, asserts loss
    continuity across the re-mesh, and finishes with a finite loss."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "qwen3-1.7b", "--reduced", "--pods", "3",
           "--join-pod", "1", "--steps", "12", "--batch", "8",
           "--seq", "32", "--log-every", "4"]
    res = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=900)
    assert res.returncode == 0, res.stderr[-4000:]
    assert "re-meshing 3 -> 4 pods" in res.stdout
    assert "re-mesh continuity ok" in res.stdout
    m = re.search(r"final loss ([0-9.]+)", res.stdout)
    assert m, res.stdout[-2000:]
    assert np.isfinite(float(m.group(1)))
