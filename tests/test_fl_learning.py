"""Learning utility (paper §V-B / Table II pattern).

The paper's central semantic claim — FLTorrent computes the SAME
FedAvg aggregate as server-based CFL once all updates reconstruct by
the deadline — is asserted exactly (trajectory-identical accuracy).
The Table II accuracy-gap vs GossipDFL at 50 clients/50 rounds is
reproduced in benchmarks/table2_learning.py; here we assert the cheap
robust part (early-round gossip attenuation under heterogeneity).
"""
import numpy as np

from repro.fl.client import LocalSpec
from repro.fl.runner import FLConfig, run_experiment


def _cfg(dist, rounds=6, dataset="synth-cifar"):
    return FLConfig(dataset=dataset, model="mlp", dist=dist,
                    n_clients=8, rounds=rounds,
                    local=LocalSpec(epochs=1, batch_size=32, lr=0.03),
                    n_train=2000, n_test=500, seed=0, min_degree=4)


def test_fltorrent_identical_to_cfl():
    """Dissemination == aggregation semantics: with every update
    reconstructable by the deadline, FLTorrent's trajectory IS CFL's."""
    cfg = _cfg("dir0.1", rounds=5)
    cfl = run_experiment("cfl", cfg)
    flt = run_experiment("fltorrent", cfg)
    assert flt.agreement
    assert flt.reconstruct_frac == 1.0
    np.testing.assert_allclose(flt.accuracy, cfl.accuracy, atol=1e-3)


def test_gossip_attenuates_early_noniid():
    """Mix-and-forward sees only partially-mixed info in early rounds
    under heterogeneity (the paper's 'attenuation'); exact FedAvg does
    not."""
    cfg = _cfg("dir0.1", rounds=3)
    flt = run_experiment("fltorrent", cfg)
    gos = run_experiment("gossip", cfg)
    assert flt.accuracy[0] >= gos.accuracy[0] - 1e-6


def test_fltorrent_learning_progress():
    cfg = _cfg("dir0.5", rounds=4)
    flt = run_experiment("fltorrent", cfg)
    assert flt.accuracy[-1] > flt.accuracy[0]
    assert flt.agreement
