"""Cross-round persistent-neighbor linkage (attacks.py + session trace).

The §III-E follow-up: an observer that stays adjacent to the same
physical sender across rounds (high ``pair_exposure``) pools per-round
attributions by majority vote.  When per-round defenses are weak the
vote AMPLIFIES — linkage ASR beats single-round greedy; under the full
defense stack per-round ASR sits below the majority threshold, so
exposure does not compound (the single-round defenses also protect the
multi-round session)."""
import numpy as np
import pytest

from repro.core import ChurnModel, SwarmConfig, SwarmSession
from repro.core.attacks import (persistent_neighbor_linkage,
                                sequential_greedy)

OBS = np.arange(6)
K = 16


def _session(seed, rounds=10, **kw):
    cfg = SwarmConfig(n=24, chunks_per_update=K, min_degree=5,
                      s_max=5000, seed=seed, **kw)
    ses = SwarmSession(cfg, churn=ChurnModel(leave_prob=0.1,
                                             rejoin_after=1),
                       evolve_overlay=True)
    ses.run(rounds)
    return ses


def _per_round_greedy_asr(ses):
    """Single-round sequential greedy, averaged over rounds (decision-
    weighted) with the observers mapped to each round's local ids."""
    vals, wts = [], []
    for rec in ses.history:
        loc = np.flatnonzero(np.isin(rec.active_ids, OBS))
        rep = sequential_greedy(rec.result.log, loc, K)
        if rep.n_decisions:
            vals.append(rep.mean_asr)
            wts.append(rep.n_decisions)
    return float(np.average(vals, weights=wts))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_linkage_beats_single_round_greedy_when_exposed(seed):
    """High-exposure session, weak per-round defenses: majority-vote
    linkage ASR >= single-round greedy ASR (amplification)."""
    ses = _session(seed, enable_preround=False, enable_timelag=False)
    exp = ses.pair_exposure()
    assert exp.max() >= 5, "session not persistent enough to link"
    link = persistent_neighbor_linkage(ses.trace(), OBS, exposure=exp,
                                       min_rounds=3)
    base = _per_round_greedy_asr(ses)
    assert link.n_decisions > 0
    assert link.mean_asr >= base, (link.mean_asr, base)
    # the amplification is substantive, not a tie
    assert link.mean_asr >= base + 0.05


def test_full_defenses_stop_cross_round_amplification():
    """With the full stack, per-round ASR sits at the 1/m floor — below
    the majority threshold — so exposure cannot compound."""
    ses = _session(0)
    link = persistent_neighbor_linkage(ses.trace(), OBS,
                                       exposure=ses.pair_exposure(),
                                       min_rounds=3)
    assert link.mean_asr <= 0.2   # stays in the guessing regime


def test_exposure_filter_restricts_decisions():
    ses = _session(1, enable_preround=False, enable_timelag=False)
    tr, exp = ses.trace(), ses.pair_exposure()
    all_pairs = persistent_neighbor_linkage(tr, OBS, min_rounds=3)
    tracked = persistent_neighbor_linkage(tr, OBS, exposure=exp,
                                          min_rounds=3)
    assert 0 < tracked.n_decisions <= all_pairs.n_decisions
    # a prohibitive threshold leaves nothing to attack
    none = persistent_neighbor_linkage(tr, OBS, exposure=exp,
                                       min_rounds=99)
    assert none.n_decisions == 0 and none.max_asr == 0.0


def test_linkage_runs_on_single_round_trace():
    """Degenerate input (one round) never links: every pair is below
    min_rounds, so the adversary reports no decisions."""
    from repro.core import simulate_round
    res = simulate_round(SwarmConfig(n=16, chunks_per_update=K,
                                     min_degree=5, s_max=4000, seed=0))
    rep = persistent_neighbor_linkage(res.log, np.arange(4),
                                      min_rounds=2)
    assert rep.n_decisions == 0
