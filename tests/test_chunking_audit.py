"""Chunking/torrent-descriptor layer + commit-then-reveal audit."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chunking
from repro.core.audit import (RoundLog, TrackerCommitment,
                              adjacency_digest, verify_round)
from repro.core.overlay import random_overlay


def test_tree_chunk_roundtrip():
    tree = {"w": jnp.arange(1000, dtype=jnp.float32),
            "b": {"x": jnp.ones((3, 7))}}
    flat, spec = chunking.flatten_update(tree)
    tree2 = chunking.unflatten_update(flat, spec)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(tree2)):
        np.testing.assert_allclose(a, b)


def test_pack_unpack_chunks():
    flat = jnp.arange(1000, dtype=jnp.float32)
    chunks = chunking.pack_chunks(flat, chunk_bytes=256)  # 64 elems/chunk
    assert chunks.shape[0] == chunking.chunk_count(4000, 256)
    back = chunking.unpack_chunks(chunks, 1000)
    np.testing.assert_allclose(back, flat)


def test_torrent_roundtrip_and_verify():
    tree = {"w": jnp.arange(300, dtype=jnp.float32)}
    chunks, desc, spec = chunking.make_update_torrent(tree, weight=3.0,
                                                      chunk_bytes=256)
    assert desc.num_chunks == chunks.shape[0]
    assert desc.weight == 3.0
    for i in range(desc.num_chunks):
        assert desc.verify_chunk(i, np.asarray(chunks[i]))
    back = chunking.reassemble_update(chunks, spec)
    np.testing.assert_allclose(back["w"], tree["w"])


def test_descriptor_detects_tamper():
    """Byzantine integrity (§III-E): hash check rejects tampered pieces."""
    tree = {"w": jnp.ones(300)}
    chunks, desc, _ = chunking.make_update_torrent(tree, 1.0, 256)
    bad = np.asarray(chunks[1]).copy()
    bad[0] += 1.0
    assert not desc.verify_chunk(1, bad)
    assert desc.verify_chunk(0, np.asarray(chunks[0]))


def test_descriptor_hides_owner():
    """Descriptors carry only hashes/counts/weight (paper §II-B):
    structure is owner-independent under homogeneous sizes."""
    _, d1, _ = chunking.make_update_torrent({"w": jnp.ones(256)}, 1.0, 256)
    _, d2, _ = chunking.make_update_torrent({"w": jnp.zeros(256)}, 1.0, 256)
    assert d1.num_chunks == d2.num_chunks
    assert d1.chunk_bytes == d2.chunk_bytes
    assert not hasattr(d1, "owner")
    assert d1.desc_id != d2.desc_id     # content-derived pseudonym


# ----------------------------------------------------------------------
# audit (commit-then-reveal, paper §III-D)
# ----------------------------------------------------------------------

def _setup_round(seed=42, n=12, m=4):
    com = TrackerCommitment.commit(round_id=5, seed=seed)
    rng = np.random.default_rng(seed)
    adj = random_overlay(n, m, 0.1, rng)
    log = RoundLog(round_id=5, seed=seed, n=n, min_degree=m,
                   extra_edge_frac=0.1,
                   adjacency_digest=adjacency_digest(adj))
    up = np.full(n, 4)
    down = np.full(n, 8)
    return com, log, adj, up, down


def test_audit_commit_reveal_roundtrip():
    com, log, adj, up, down = _setup_round()
    u, v = map(int, np.argwhere(adj)[0])
    log.directives.append((0, u, v, 17))
    res = verify_round(com, log, up, down)
    assert res.ok and not res.fail_open, res.violations


def test_audit_detects_seed_swap():
    com, log, adj, up, down = _setup_round()
    log.seed += 1                       # tracker lies about randomness
    res = verify_round(com, log, up, down)
    assert not res.ok and res.fail_open


def test_audit_detects_overlay_tamper():
    com, log, adj, up, down = _setup_round()
    log.adjacency_digest = adjacency_digest(~adj)
    res = verify_round(com, log, up, down)
    assert not res.ok


def test_audit_rejects_nonadjacent_directive():
    com, log, adj, up, down = _setup_round()
    nz = np.argwhere(~adj)
    u, v = next((int(a), int(b)) for a, b in nz if a != b)
    log.directives.append((0, u, v, 3))
    res = verify_round(com, log, up, down)
    assert not res.ok


def test_audit_rejects_capacity_violation():
    com, log, adj, up, down = _setup_round()
    u, v = map(int, np.argwhere(adj)[0])
    for c in range(int(up[u]) + 1):     # one over the uplink cap
        log.directives.append((0, u, v, c))
    res = verify_round(com, log, up, down)
    assert not res.ok


def test_audit_rejects_redundant_delivery_but_allows_retry():
    com, log, adj, up, down = _setup_round()
    u, v = map(int, np.argwhere(adj)[0])
    log.directives.append((0, u, v, 9))
    log.directives.append((1, u, v, 9))         # redundant
    res = verify_round(com, log, up, down)
    assert not res.ok
    log.retries.add((v, 9))                     # logged retry is fine
    res = verify_round(com, log, up, down)
    assert res.ok, res.violations
