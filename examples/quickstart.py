"""Quickstart: one privacy-hardened FLTorrent dissemination round.

Simulates a 30-client swarm with the paper's defaults (spray R=0.2,
T_lag=3, cover-set gating, GreedyFastestFirst), runs the three
observation-only attribution attacks, and checks the Eq. (1) bound on
every warm-up transfer.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import SwarmConfig, simulate_round
from repro.core.attacks import random_guess_baseline, run_all_attacks
from repro.core.privacy import check_eq1, per_transfer_cap


def main():
    cfg = SwarmConfig(n=30, chunks_per_update=64, s_max=20_000, seed=0)
    print(f"swarm: n={cfg.n}, K={cfg.chunks_per_update} chunks/update, "
          f"k_gate={cfg.k_gate}, k_term={cfg.k_term}, "
          f"spray sigma={cfg.spray_copies}")

    res = simulate_round(cfg)
    m = res.metrics
    print(f"\nround: warm-up {m.t_warm}s + BT {m.t_round - m.t_warm}s "
          f"= {m.t_round}s  (warm-up share {m.warmup_share:.1%}, "
          f"utilization {m.warmup_utilization:.1%})")
    print(f"all updates reconstructable: {bool(res.reconstructable.all())}")

    cap = per_transfer_cap(cfg.owner_throttle, cfg.k_gate)
    print(f"\nEq.(1) cap kappa/k = {cap:.3f}; "
          f"holds on every warm-up transfer: {check_eq1(res.log, cfg.owner_throttle, cfg.k_gate)}")

    observers = np.arange(5)
    reports = run_all_attacks(res.log, observers, cfg.chunks_per_update)
    guess = random_guess_baseline(cfg.min_degree)
    print(f"\nattribution attacks (5 observers, 1/m guess = {guess:.2f}):")
    for name, rep in reports.items():
        print(f"  {name:10s} max ASR = {rep.max_asr:.3f}  "
              f"mean = {rep.mean_asr:.3f}")


if __name__ == "__main__":
    main()
