"""Write your own scheduling policy AND your own adversary in ~20 lines
each — the two halves of the §III-C / §IV-C contract.

A policy is one registered class over the visibility-scoped
:class:`SlotView` (core/policy.py); an adversary is a function over the
typed :class:`TransferTrace` (core/trace.py).  Both work unchanged in
single-round (``simulate_round``), multi-round-churn (``SwarmSession``)
and figure-reproduction paths.

    PYTHONPATH=src python examples/custom_policy.py
"""
import numpy as np

from repro.core import (ChurnModel, SchedulerPolicy, SwarmConfig,
                        SwarmSession, register_policy, simulate_round)
from repro.core.attacks import sequential_greedy


# ----------------------------------------------------------------------
# 1. A policy in ~20 lines: receivers request everything the
#    neighborhood union advertises from random neighbors (a greedier
#    cousin of the §III-C.6 distributed mode — same visibility tier).
# ----------------------------------------------------------------------

@register_policy
class EagerMirror(SchedulerPolicy):
    """Request every advertised missing chunk from a random neighbor."""

    name = "eager_mirror"
    visibility = "neighborhood"        # may NOT read the supply matrix

    def schedule(self, view):
        cand, union = view.availability_union()
        snd, rcv, chk = [], [], []
        for v in np.flatnonzero(view.receivers_open()):
            ids = np.flatnonzero(union[v])[:int(view.down[v])]
            if ids.size == 0:
                continue
            tgt = view.rng.choice(np.flatnonzero(view.adj[v]),
                                  size=ids.size)
            ok = view.resolve_requests(tgt, cand[ids])  # may miss!
            snd.append(tgt[ok])
            rcv.append(np.full(int(ok.sum()), v, np.int64))
            chk.append(cand[ids[ok]])
        if not snd:
            return view.empty()
        snd, rcv, chk = map(np.concatenate, (snd, rcv, chk))
        # uplink budgets are the policy's duty: keep each sender's
        # first up[u] grants (grouped rank over the sorted senders)
        o = np.argsort(snd, kind="stable")
        rank = np.arange(o.size) - np.searchsorted(snd[o], snd[o])
        keep = np.zeros(o.size, bool)
        keep[o] = rank < view.up[snd[o]]
        return snd[keep], rcv[keep], chk[keep]


# ----------------------------------------------------------------------
# 2. An adversary in ~20 lines: guesses each sender's LAST descriptor
#    (a deliberately bad strategy — late transfers are well mixed).
# ----------------------------------------------------------------------

def latecomer_adversary(trace, observers):
    """ASR of attributing each sender to its last-seen descriptor."""
    view = trace.warmup().observed_by(observers)
    order = np.argsort(view.slot, kind="stable")
    snd, desc = view.sender[order], view.desc()[order]
    guesses = {}
    for s, d in zip(snd.tolist(), desc.tolist()):
        guesses[s] = d                       # later rows overwrite
    if not guesses:
        return 0.0
    return float(np.mean([g == s for s, g in guesses.items()]))


def main():
    cfg = SwarmConfig(n=24, chunks_per_update=16, min_degree=5,
                      s_max=5000, seed=0, scheduler="eager_mirror")
    res = simulate_round(cfg)
    m = res.metrics
    print(f"eager_mirror (by name):     t_warm={m.t_warm} "
          f"util={m.warmup_utilization:.2f}")

    # the same policy as an INSTANCE, unchanged in a churny session
    ses = SwarmSession(cfg.replace(scheduler=EagerMirror()),
                       churn=ChurnModel(leave_prob=0.2, rejoin_after=1))
    ses.run(4)
    print(f"eager_mirror (instance, 4-round churn session): "
          f"participation={ses.participation().round(2).tolist()}")

    obs = np.arange(6)
    asr_late = latecomer_adversary(res.log, obs)
    asr_seq = sequential_greedy(res.log, obs, cfg.chunks_per_update)
    print(f"latecomer ASR={asr_late:.3f} vs sequential greedy "
          f"mean ASR={asr_seq.mean_asr:.3f} (first beats last: early "
          f"transfers carry the owner signal the defenses scrub)")


if __name__ == "__main__":
    main()
