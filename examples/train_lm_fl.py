"""Train a (reduced) LM with the full FL round step — local grads ->
torrent dissemination -> masked FedAvg -> AdamW — with round-boundary
checkpointing and a simulated mid-run pod failure (straggler masking).

This is the JAX-cluster counterpart of examples/fl_learning_e2e.py:
same FedAvg-over-reconstructable-set semantics, compiled end to end.

    PYTHONPATH=src python examples/train_lm_fl.py
"""

from repro.launch.train import main as train_main


def main():
    loss = train_main([
        "--arch", "qwen3-1.7b", "--reduced",
        "--steps", "120", "--batch", "8", "--seq", "64",
        "--lr", "3e-3", "--ckpt", "/tmp/fltorrent_ckpt",
        "--ckpt-every", "40", "--log-every", "20",
    ])
    assert loss < 3.0, f"training did not converge (loss {loss})"
    print("\nresuming from the latest checkpoint for 20 more steps "
          "(paper §III-E: rejoin at round boundary) ...")
    train_main([
        "--arch", "qwen3-1.7b", "--reduced",
        "--steps", "140", "--batch", "8", "--seq", "64",
        "--lr", "3e-3", "--ckpt", "/tmp/fltorrent_ckpt",
        "--ckpt-every", "40", "--log-every", "20",
    ])


if __name__ == "__main__":
    main()
