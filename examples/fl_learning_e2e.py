"""End-to-end FL driver (the paper's kind of experiment, §V-B):
train CFL vs GossipDFL vs FLTorrent on a synthetic non-IID dataset and
show that FLTorrent's trajectory is identical to CFL (exact FedAvg over
a real chunked/swarmed dissemination round) while Gossip attenuates.

    PYTHONPATH=src python examples/fl_learning_e2e.py
"""
from repro.fl.client import LocalSpec
from repro.fl.runner import FLConfig, run_experiment


def main():
    cfg = FLConfig(dataset="synth-cifar", model="mlp", dist="dir0.1",
                   n_clients=10, rounds=8,
                   local=LocalSpec(epochs=1, batch_size=32, lr=0.03),
                   n_train=3000, n_test=800, seed=0, min_degree=5)
    print("training 3 methods, 8 rounds, Dirichlet(0.1) non-IID ...")
    results = {m: run_experiment(m, cfg)
               for m in ("cfl", "gossip", "fltorrent")}
    print(f"\n{'round':>6}" + "".join(f"{m:>12}" for m in results))
    for r in range(cfg.rounds):
        print(f"{r:6d}" + "".join(f"{res.accuracy[r]:12.3f}"
                                  for res in results.values()))
    flt = results["fltorrent"]
    print(f"\nFLTorrent: clients agreed on every aggregate: "
          f"{flt.agreement}; reconstruction rate {flt.reconstruct_frac:.0%}")


if __name__ == "__main__":
    main()
