"""End-to-end FL driver (the paper's kind of experiment, §V-B):
train CFL vs GossipDFL vs FLTorrent on a synthetic non-IID dataset and
show that FLTorrent's trajectory is identical to CFL (exact FedAvg over
a real chunked/swarmed dissemination round) while Gossip attenuates.

Part 2 demonstrates cross-round churn (§III-E) on the persistent
``SwarmSession`` the fltorrent path runs on — the same pattern as the
``repro.core.session`` module docstring:

    from repro.core import SwarmConfig
    from repro.core.session import ChurnModel, SwarmSession

    ses = SwarmSession(SwarmConfig(n=40, chunks_per_update=16),
                       churn=ChurnModel(leave_prob=0.1, join_rate=1.0,
                                        rejoin_after=2))
    for _ in range(10):
        rec = ses.next_round()      # churn at the boundary, then a round
    ses.edge_persistence()          # evolving-topology privacy statistic

In the FL runner (`churn_rate > 0`) clients leave at round boundaries,
hold stale params while absent, and re-download the current model when
they rejoin — aggregation always proceeds over the reconstructable
active set.

    PYTHONPATH=src python examples/fl_learning_e2e.py
"""
from repro.fl.client import LocalSpec
from repro.fl.runner import FLConfig, run_experiment


def main():
    cfg = FLConfig(dataset="synth-cifar", model="mlp", dist="dir0.1",
                   n_clients=10, rounds=8,
                   local=LocalSpec(epochs=1, batch_size=32, lr=0.03),
                   n_train=3000, n_test=800, seed=0, min_degree=5)
    print("training 3 methods, 8 rounds, Dirichlet(0.1) non-IID ...")
    results = {m: run_experiment(m, cfg)
               for m in ("cfl", "gossip", "fltorrent")}
    print(f"\n{'round':>6}" + "".join(f"{m:>12}" for m in results))
    for r in range(cfg.rounds):
        print(f"{r:6d}" + "".join(f"{res.accuracy[r]:12.3f}"
                                  for res in results.values()))
    flt = results["fltorrent"]
    print(f"\nFLTorrent: clients agreed on every aggregate: "
          f"{flt.agreement}; reconstruction rate {flt.reconstruct_frac:.0%}")

    # -- cross-round churn (§III-E): same pipeline, persistent swarm --
    churn_cfg = FLConfig(dataset="synth-cifar", model="mlp", dist="dir0.1",
                         n_clients=10, rounds=8,
                         local=LocalSpec(epochs=1, batch_size=32, lr=0.03),
                         n_train=3000, n_test=800, seed=0, min_degree=5,
                         churn_rate=0.25, rejoin_after=1)
    ch = run_experiment("fltorrent", churn_cfg)
    print(f"\nFLTorrent with churn_rate=0.25 (leave/rejoin at round "
          f"boundaries):")
    print(f"  per-round participation: "
          f"{[round(p, 2) for p in ch.participation]}")
    print(f"  rejoin catch-ups at rounds {sorted(set(ch.rejoin_rounds))} "
          f"(stale params re-synced: {ch.stale_seen and ch.caught_up})")
    print(f"  final accuracy {ch.accuracy[-1]:.3f} vs no-churn "
          f"{flt.accuracy[-1]:.3f}; agreement {ch.agreement}")


if __name__ == "__main__":
    main()
