"""LLM-scale dissemination stress test (paper §V-E): swarm a 7B-class
bf16 update over datacenter links with and without unlinkability
hardening, and demonstrate the int8 chunk-compression wire format used
by the on-pod torrent collective.

    PYTHONPATH=src python examples/llm_dissemination.py
"""
import jax
import jax.numpy as jnp

from repro.core import SwarmConfig, simulate_round
from repro.core.capacities import DATACENTER
from repro.kernels import ops


def main():
    # --- swarm-level: 7B bf16 update, 4 MiB pieces, 24 peers ---
    nbytes = 7e9 * 2
    chunk = 4 * 2**20
    K = int(-(-nbytes // chunk))
    common = dict(n=24, chunks_per_update=K, chunk_bytes=chunk,
                  s_max=10**7, seed=0, min_degree=10)
    base = simulate_round(
        SwarmConfig(**common, enable_gating=False, enable_preround=False,
                    enable_timelag=False, enable_nonowner_first=False,
                    warmup_threshold_pct=0.0),
        link_model=DATACENTER, bt_mode="fluid").metrics
    full = simulate_round(SwarmConfig(**common), link_model=DATACENTER,
                          bt_mode="fluid").metrics
    ovh = (full.t_round - base.t_round) / base.t_round
    print(f"7B update, {K} pieces, 24 peers @ 7-10 Gbps:")
    print(f"  BitTorrent-only round: {base.t_round}s")
    print(f"  FLTorrent (hardened):  {full.t_round}s  ({ovh:+.1%})")

    # --- chunk-level: int8 wire compression (the dissemination
    #     collective quantizes ONCE at source, hops carry int8) ---
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 65536)) * 0.02
    q, scales = ops.quantize(x, impl="interpret")     # Pallas kernel
    deq = ops.dequantize(q, scales, impl="interpret")
    rel = float(jnp.abs(deq - x).max() / jnp.abs(x).max())
    ratio = x.nbytes / (q.nbytes + scales.nbytes)
    print(f"\nint8 chunk compression: {ratio:.2f}x fewer wire bytes, "
          f"max rel err {rel:.4f}")


if __name__ == "__main__":
    main()
