"""Serve a (reduced) LM: batched prefill + greedy KV-cache decode —
what the ``decode_32k`` / ``long_500k`` dry-run cells lower at
production scale.

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import main as serve_main


def main():
    serve_main(["--arch", "gemma2-2b", "--reduced", "--batch", "4",
                "--prompt-len", "32", "--gen", "16"])
    serve_main(["--arch", "recurrentgemma-2b", "--reduced", "--batch", "2",
                "--prompt-len", "24", "--gen", "8"])


if __name__ == "__main__":
    main()
