"""Round-level checkpoint/restart: npz payload + JSON manifest.

Fault-tolerance contract (paper §III-E mapped to the cluster setting):
training state is durable at every FL-round boundary, so a failed pod
re-joins at the next round exactly like a BitTorrent peer re-joining a
swarm — ``restore_or_init`` is the single entry point the launcher calls
on (re)start.  Writes are atomic (tmp + rename) so a crash mid-save
never corrupts the latest good round, and ``keep`` bounds disk usage.
"""
from __future__ import annotations

import json
import os
import tempfile
from collections.abc import Callable

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir: str, round_idx: int, tree, *,
                    meta: dict | None = None, keep: int = 3) -> str:
    """Atomically write ``round_<idx>.npz`` + manifest; GC old rounds.

    Leaves are stored as raw byte buffers with dtype/shape recorded in
    the manifest — npz has no native bf16/f8 support and silently
    pickles them otherwise.
    """
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays = {}
    leaf_meta = []
    for i, x in enumerate(leaves):
        a = np.asarray(x)
        arrays[f"leaf_{i}"] = np.frombuffer(a.tobytes(), np.uint8)
        leaf_meta.append({"shape": list(a.shape), "dtype": str(a.dtype)})
    payload = {
        "round": round_idx,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "leaves": leaf_meta,
        "meta": meta or {},
    }
    base = os.path.join(ckpt_dir, f"round_{round_idx:08d}")
    # NOTE: suffix must end in .npz or np.savez silently appends one and
    # the rename would move an empty file (torn checkpoint).
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp.npz")
    os.close(fd)
    np.savez(tmp, **arrays)
    os.replace(tmp, base + ".npz")
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".json.tmp")
    os.close(fd)
    with open(tmp, "w") as f:
        json.dump(payload, f)
    os.replace(tmp, base + ".json")
    _gc(ckpt_dir, keep)
    return base + ".npz"


def _gc(ckpt_dir: str, keep: int):
    rounds = sorted(_list_rounds(ckpt_dir))
    for r in rounds[:-keep] if keep > 0 else []:
        for ext in (".npz", ".json"):
            try:
                os.remove(os.path.join(ckpt_dir, f"round_{r:08d}{ext}"))
            except FileNotFoundError:
                pass


def _list_rounds(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("round_") and name.endswith(".json"):
            out.append(int(name[len("round_"):-len(".json")]))
    return out


def latest_round(ckpt_dir: str) -> int | None:
    rounds = _list_rounds(ckpt_dir)
    return max(rounds) if rounds else None


def load_checkpoint(ckpt_dir: str, round_idx: int, like_tree):
    """Restore into the structure of ``like_tree`` (shape/dtype cast)."""
    base = os.path.join(ckpt_dir, f"round_{round_idx:08d}")
    with open(base + ".json") as f:
        manifest = json.load(f)
    with np.load(base + ".npz") as z:
        raw = [z[f"leaf_{i}"] for i in range(manifest["n_leaves"])]
    leaves = [np.frombuffer(buf.tobytes(), np.dtype(lm["dtype"]))
              .reshape(lm["shape"])
              for buf, lm in zip(raw, manifest["leaves"])]
    like_leaves, treedef = _flatten(like_tree)
    assert len(leaves) == len(like_leaves), "checkpoint/model mismatch"
    cast = [np.asarray(x).astype(l.dtype).reshape(l.shape)
            for x, l in zip(leaves, like_leaves)]
    return treedef.unflatten(cast), manifest["meta"]


def restore_or_init(ckpt_dir: str, init_fn: Callable[[], tuple], *,
                    like_fn: Callable | None = None):
    """Resume from the latest round if one exists, else initialize.

    ``init_fn() -> (tree, meta)``.  Returns (tree, meta, start_round).
    """
    r = latest_round(ckpt_dir)
    if r is None:
        tree, meta = init_fn()
        return tree, meta, 0
    like, meta0 = init_fn()
    tree, meta = load_checkpoint(ckpt_dir, r, like)
    return tree, meta, r + 1
