from .store import (latest_round, load_checkpoint, restore_or_init,
                    save_checkpoint)

__all__ = ["save_checkpoint", "load_checkpoint", "latest_round",
           "restore_or_init"]
