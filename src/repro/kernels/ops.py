"""Jit'd wrappers + implementation dispatch for the kernel package.

Every op has three interchangeable implementations:

* ``impl="pallas"``     — the Pallas TPU kernel (production target).
                          Backward pass = recompute via the XLA path's
                          VJP (custom_vjp), the standard recompute
                          strategy for flash-style kernels.
* ``impl="interpret"``  — same kernel body, interpret=True (CPU tests).
* ``impl="xla"``        — pure-jnp *blocked* implementation: memory-
                          bounded like the kernel (chunked q / two-block
                          sliding window), differentiable, and what the
                          multi-pod dry-run lowers so cost_analysis sees
                          the real FLOPs.  NOT the O(T^2)-memory oracle
                          (that's ref.py, used only as a test oracle).

Models take ``impl`` from their config; dryrun/train default to "xla",
kernel tests sweep "interpret" vs ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .attention import flash_attention
from .fedavg import fedavg_reduce as _fedavg_pallas
from .quantize import chunk_dequantize as _dq_pallas
from .quantize import chunk_quantize as _q_pallas
from .rglru import rglru_scan as _rglru_pallas

NEG_INF = -1e30


# ----------------------------------------------------------------------
# Attention: XLA blocked path (chunked-q online softmax / two-block SWA)
# ----------------------------------------------------------------------

def _xla_attention_qchunk(q, k, v, *, causal, window, softcap, q_offset,
                          kv_offset, scale, block_q):
    """Chunked-over-q attention; peak memory O(block_q * Tk) per head."""
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    group = hq // hkv
    sc = (d ** -0.5) if scale is None else scale
    block_q = max(1, min(block_q, tq))
    pad_q = (-tq) % block_q
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    nq = q.shape[2] // block_q
    qb = q.reshape(b, hq, nq, block_q, d).transpose(2, 0, 1, 3, 4)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def one_block(args):
        qi, qblk = args
        qf = qblk.astype(jnp.float32)              # (b, hq, block_q, d)
        qg = qf.reshape(b, hkv, group, block_q, d)
        s = jnp.einsum("bkgqd,bktd->bkgqt", qg, kf) * sc
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = (q_offset + qi * block_q
                 + jnp.arange(block_q))[:, None]
        k_pos = kv_offset + jnp.arange(tk)[None, :]
        mask = jnp.broadcast_to(k_pos >= 0, (block_q, tk))
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        p = jnp.where(mask.any(-1)[None, None, None, :, None], p, 0.0)
        o = jnp.einsum("bkgqt,bktd->bkgqd", p, vf)
        return o.reshape(b, hq, block_q, d)

    # Flash-style backward: without this, lax.map stores every block's
    # f32 softmax matrix as a residual — (nq, b, g, block_q, Tk) f32
    # per layer per microbatch dominated chameleon train_4k's HBM
    # traffic (§Perf cell-3 iter-2).  Recompute P inside the block.
    one_block = jax.checkpoint(
        one_block, policy=jax.checkpoint_policies.nothing_saveable)

    out = jax.lax.map(one_block, (jnp.arange(nq), qb))
    out = out.transpose(1, 2, 0, 3, 4).reshape(b, hq, nq * block_q, d)
    return out[:, :, :tq].astype(q.dtype)


def _xla_attention_swa(q, k, v, *, softcap, q_offset, scale, window):
    """Two-block sliding-window attention: q block i attends to k blocks
    (i-1, i) with block size = window, so compute/memory are O(T*window)
    instead of O(T^2).  Exact for causal SWA with width <= window."""
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    assert q_offset == 0 and tq == tk, "SWA fast path is for full-seq fwd"
    group = hq // hkv
    sc = (d ** -0.5) if scale is None else scale
    bs = window
    pad = (-tq) % bs
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    t = q.shape[2]
    nb = t // bs
    qb = q.reshape(b, hq, nb, bs, d).astype(jnp.float32)
    kb = k.reshape(b, hkv, nb, bs, d).astype(jnp.float32)
    vb = v.reshape(b, hkv, nb, bs, d).astype(jnp.float32)
    # Previous k/v block (zeros for block 0).
    kprev = jnp.pad(kb[:, :, :-1], ((0, 0), (0, 0), (1, 0), (0, 0), (0, 0)))
    vprev = jnp.pad(vb[:, :, :-1], ((0, 0), (0, 0), (1, 0), (0, 0), (0, 0)))
    k2 = jnp.concatenate([kprev, kb], axis=3)       # (b, hkv, nb, 2bs, d)
    v2 = jnp.concatenate([vprev, vb], axis=3)
    qg = qb.reshape(b, hkv, group, nb, bs, d)
    s = jnp.einsum("bkgnqd,bkntd->bkgnqt", qg, k2) * sc
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    # Positions within the 2-block strip: q at bs + i, k at j.
    q_pos = bs + jnp.arange(bs)[:, None]
    k_pos = jnp.arange(2 * bs)[None, :]
    mask = (k_pos <= q_pos) & (k_pos > q_pos - window)
    # First block has no previous block (its strip's left half is pad).
    blk = jnp.arange(nb)[:, None, None]
    valid = (k_pos[None] >= bs) | (blk > 0)
    mask = mask[None] & valid
    # Padded tail keys.
    if pad:
        abs_k = blk * bs + (k_pos[None] - bs)
        mask &= abs_k < tk
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask.any(-1)[None, None, None, ..., None], p, 0.0)
    o = jnp.einsum("bkgnqt,bkntd->bkgnqd", p, v2)
    o = o.reshape(b, hq, t, d)
    return o[:, :, :tq].astype(q.dtype)


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8, 9, 10))
def _pallas_attention(q, k, v, causal, window, softcap, q_offset,
                      kv_offset, scale, block_q, block_k):
    return flash_attention(q, k, v, causal=causal, window=window,
                           softcap=softcap, q_offset=q_offset,
                           kv_offset=kv_offset, scale=scale,
                           block_q=block_q, block_k=block_k)


def _pallas_attention_fwd(q, k, v, causal, window, softcap, q_offset,
                          kv_offset, scale, block_q, block_k):
    out = _pallas_attention(q, k, v, causal, window, softcap, q_offset,
                            kv_offset, scale, block_q, block_k)
    return out, (q, k, v)


def _pallas_attention_bwd(causal, window, softcap, q_offset, kv_offset,
                          scale, block_q, block_k, res, g):
    q, k, v = res
    f = functools.partial(_xla_attention_qchunk, causal=causal,
                          window=window, softcap=softcap,
                          q_offset=q_offset, kv_offset=kv_offset,
                          scale=scale, block_q=block_q)
    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


_pallas_attention.defvjp(_pallas_attention_fwd, _pallas_attention_bwd)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True, window: int | None = None,
              softcap: float | None = None, q_offset: int = 0,
              kv_offset: int = 0,
              scale: float | None = None, impl: str = "xla",
              block_q: int = 512, block_k: int = 512) -> jnp.ndarray:
    """Dispatching multi-head attention; see module docstring."""
    if impl == "pallas":
        return _pallas_attention(q, k, v, causal, window, softcap,
                                 q_offset, kv_offset, scale, block_q,
                                 block_k)
    if impl == "interpret":
        return flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, q_offset=q_offset,
                               kv_offset=kv_offset,
                               scale=scale, block_q=block_q,
                               block_k=block_k, interpret=True)
    if impl == "xla":
        tq, tk = q.shape[2], k.shape[2]
        static_offsets = (isinstance(q_offset, int) and q_offset == 0
                          and isinstance(kv_offset, int)
                          and kv_offset == 0)
        if (window is not None and causal and static_offsets
                and tq == tk and tq > 2 * window):
            return _xla_attention_swa(q, k, v, softcap=softcap,
                                      q_offset=0, scale=scale,
                                      window=window)
        return _xla_attention_qchunk(q, k, v, causal=causal, window=window,
                                     softcap=softcap, q_offset=q_offset,
                                     kv_offset=kv_offset,
                                     scale=scale, block_q=block_q)
    if impl == "ref":
        return ref.mha(q, k, v, causal=causal, window=window,
                       softcap=softcap, q_offset=q_offset,
                       kv_offset=kv_offset, scale=scale)
    raise ValueError(f"unknown attention impl {impl!r}")


# ----------------------------------------------------------------------
# RG-LRU
# ----------------------------------------------------------------------

def _xla_rglru(x, a, gate_x, h0):
    """Associative-scan RG-LRU — O(log T) depth, differentiable."""
    xf = x.astype(jnp.float32)
    af = a.astype(jnp.float32)
    gx = gate_x.astype(jnp.float32)
    inp = jnp.sqrt(jnp.maximum(1.0 - af * af, 0.0)) * (gx * xf)
    if h0 is not None:
        # Fold h0 into the first step: h_1 = a_1 h_0 + i_1.
        inp = inp.at[:, 0].add(af[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    _, hs = jax.lax.associative_scan(combine, (af, inp), axis=1)
    return hs.astype(x.dtype), hs[:, -1].astype(jnp.float32)


def rglru(x: jnp.ndarray, a: jnp.ndarray, gate_x: jnp.ndarray,
          h0: jnp.ndarray | None = None, *, impl: str = "xla",
          block_t: int = 256, block_d: int = 512):
    """Gated diagonal linear recurrence; returns (y (B,T,D), h_T (B,D))."""
    if impl == "pallas":
        return _rglru_pallas(x, a, gate_x, h0, block_t=block_t,
                             block_d=block_d)
    if impl == "interpret":
        return _rglru_pallas(x, a, gate_x, h0, block_t=block_t,
                             block_d=block_d, interpret=True)
    if impl == "xla":
        return _xla_rglru(x, a, gate_x, h0)
    if impl == "ref":
        return ref.rglru(x, a, gate_x, h0)
    raise ValueError(f"unknown rglru impl {impl!r}")


# ----------------------------------------------------------------------
# FedAvg reduction
# ----------------------------------------------------------------------

def fedavg(updates: jnp.ndarray, weights: jnp.ndarray,
           active: jnp.ndarray, *, impl: str = "xla",
           block_d: int = 2048) -> jnp.ndarray:
    if impl == "pallas":
        return _fedavg_pallas(updates, weights, active, block_d=block_d)
    if impl == "interpret":
        return _fedavg_pallas(updates, weights, active, block_d=block_d,
                              interpret=True)
    if impl in ("xla", "ref"):
        return ref.fedavg_reduce(updates, weights, active)
    raise ValueError(f"unknown fedavg impl {impl!r}")


# ----------------------------------------------------------------------
# Chunk quantization
# ----------------------------------------------------------------------

def quantize(x: jnp.ndarray, *, impl: str = "xla"):
    if impl == "pallas":
        return _q_pallas(x)
    if impl == "interpret":
        return _q_pallas(x, interpret=True)
    if impl in ("xla", "ref"):
        return ref.chunk_quantize(x)
    raise ValueError(impl)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray, *, impl: str = "xla",
               dtype=jnp.float32):
    if impl == "pallas":
        return _dq_pallas(q, scale, dtype=dtype)
    if impl == "interpret":
        return _dq_pallas(q, scale, dtype=dtype, interpret=True)
    if impl in ("xla", "ref"):
        return ref.chunk_dequantize(q, scale).astype(dtype)
    raise ValueError(impl)


# ----------------------------------------------------------------------
# Chunkwise mLSTM
# ----------------------------------------------------------------------

def mlstm(q, k, v, i_pre, f_pre, *, chunk: int = 128,
          impl: str = "xla"):
    """Chunkwise-parallel mLSTM from zero state.

    q,k,v: (B, H, T, dh) (q,k pre-scaled); i_pre,f_pre: (B, H, T).
    Returns (h (B,H,T,dh), C, n, m).  impl="pallas"/"interpret" uses the
    fused kernel (state resident in VMEM); impl="xla" the scan form.
    """
    from repro.models.layers import _mlstm_chunkwise
    from .mlstm import mlstm_chunkwise as _k

    if impl == "pallas":
        return _k(q, k, v, i_pre, f_pre, chunk=chunk)
    if impl == "interpret":
        return _k(q, k, v, i_pre, f_pre, chunk=chunk, interpret=True)
    if impl in ("xla", "ref"):
        b, h, t, dh = q.shape
        init = (jnp.zeros((b, h, dh, dh)), jnp.zeros((b, h, dh)),
                jnp.full((b, h), -1e30))
        (C, n, m), hs = _mlstm_chunkwise(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), i_pre.transpose(0, 2, 1),
            f_pre.transpose(0, 2, 1), init, chunk=chunk, remat=False)
        return (hs.reshape(b, t, h, dh).transpose(0, 2, 1, 3), C, n, m)
    raise ValueError(impl)
