"""Pallas TPU kernel for the RG-LRU gated diagonal linear recurrence.

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (gx_t * x_t)

(De et al., "Griffin/RecurrentGemma", 2024.)  This is recurrentgemma's
hot loop at long context: elementwise (VPU) work that is purely
memory-bound, so the kernel's job on TPU is to stream each (T, D) slab
HBM->VMEM exactly once and keep the carry ``h`` resident in VMEM.

Blocking: grid = (B, D/block_d, T/block_t) with **time innermost** so the
(block_d,) carry persists in VMEM scratch across time blocks.  Inside a
block we run a sequential ``fori_loop`` over the block_t rows — the
recurrence is inherently sequential in t, but each step is a (block_d,)
vector op on the VPU.  VMEM per step = 4 slabs * block_t * block_d * 4 B
(x, a, gx in + y out) + carry; defaults (block_t=256, block_d=512) give
~2 MiB, well under budget with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(x_ref, a_ref, g_ref, h0_ref, y_ref, hT_ref, h_scr, *,
                  block_t: int):
    ti = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)     # (block_t, block_d)
    a = a_ref[0].astype(jnp.float32)
    g = g_ref[0].astype(jnp.float32)
    inp = jnp.sqrt(jnp.maximum(1.0 - a * a, 0.0)) * (g * x)

    def step(i, h):
        h = (jax.lax.dynamic_slice_in_dim(a, i, 1, axis=0)[0] * h
             + jax.lax.dynamic_slice_in_dim(inp, i, 1, axis=0)[0])
        # All indices must be slices: a raw scalar (the leading 0) makes
        # pl.store's discharge rule crash on jax 0.4.x ("'int' object
        # has no attribute 'shape'").
        pl.store(y_ref, (pl.dslice(0, 1), pl.dslice(i, 1), slice(None)),
                 h[None, None].astype(y_ref.dtype))
        return h

    h = jax.lax.fori_loop(0, block_t, step, h_scr[...])
    h_scr[...] = h

    @pl.when(ti == nt - 1)
    def _final():
        hT_ref[0] = h_scr[...].astype(hT_ref.dtype)


def rglru_scan(x: jnp.ndarray, a: jnp.ndarray, gate_x: jnp.ndarray,
               h0: jnp.ndarray | None = None, *,
               block_t: int = 256, block_d: int = 512,
               interpret: bool = False) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x, a, gate_x: (B, T, D).  Returns (y (B,T,D), h_T (B,D))."""
    b, t, d = x.shape
    if h0 is None:
        h0 = jnp.zeros((b, d), jnp.float32)
    block_t = min(block_t, t)
    block_d = min(block_d, d)
    pad_t = (-t) % block_t
    pad_d = (-d) % block_d
    if pad_t or pad_d:
        pad = ((0, 0), (0, pad_t), (0, pad_d))
        # Pad a with 1 (h_t = 1*h + sqrt(1-1)*... = h): carry stays inert
        # through padded time rows, so h_T is the true final state.
        x = jnp.pad(x, pad)
        a = jnp.pad(a, pad, constant_values=1.0)
        gate_x = jnp.pad(gate_x, pad)
        h0 = jnp.pad(h0, ((0, 0), (0, pad_d)))
    tt, dd = x.shape[1], x.shape[2]
    nt, nd = tt // block_t, dd // block_d

    kernel = functools.partial(_rglru_kernel, block_t=block_t)
    y, hT = pl.pallas_call(
        kernel,
        grid=(b, nd, nt),
        in_specs=[
            pl.BlockSpec((1, block_t, block_d),
                         lambda b_, di, ti: (b_, ti, di)),
            pl.BlockSpec((1, block_t, block_d),
                         lambda b_, di, ti: (b_, ti, di)),
            pl.BlockSpec((1, block_t, block_d),
                         lambda b_, di, ti: (b_, ti, di)),
            pl.BlockSpec((1, block_d), lambda b_, di, ti: (b_, di)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, block_d),
                         lambda b_, di, ti: (b_, ti, di)),
            pl.BlockSpec((1, block_d), lambda b_, di, ti: (b_, di)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, tt, dd), x.dtype),
            jax.ShapeDtypeStruct((b, dd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d,), jnp.float32)],
        interpret=interpret,
    )(x, a, gate_x, h0)
    if pad_t or pad_d:
        y = y[:, :t, :d]
        hT = hT[:, :d]
    return y, hT
