"""Pallas TPU kernels: per-chunk int8 symmetric quantize / dequantize.

FLTorrent disseminates updates as fixed 256 KiB chunks (§II-B); int8
chunk compression is our gradient-compression hook for the dissemination
collective (4x fewer bytes over ICI/DCN per chunk, one f32 scale per
chunk).  Per-chunk scales keep the quantization error local: a single
outlier layer only degrades its own chunks.

Each 256 KiB f32 chunk is 65 536 elements = a (512, 128) lane-aligned
tile; the quant kernel does one amax reduction + one scaled round per
tile (memory-bound, one HBM pass), grid = (n_chunks,).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)               # (1, E)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0.0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = jnp.full_like(s_ref, scale)


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[0, 0]
                  ).astype(x_ref.dtype)


def chunk_quantize(x: jnp.ndarray, *, interpret: bool = False
                   ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (n_chunks, E) f32 -> (int8 (n,E), f32 scales (n,1))."""
    n, e = x.shape
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, e), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, e), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, e), jnp.int8),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q, s


def chunk_dequantize(q: jnp.ndarray, scale: jnp.ndarray, *,
                     dtype=jnp.float32,
                     interpret: bool = False) -> jnp.ndarray:
    """(n, E) int8 + (n, 1) scales -> (n, E) dtype."""
    n, e = q.shape
    return pl.pallas_call(
        _dequant_kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, e), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, e), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, e), dtype),
        interpret=interpret,
    )(q, scale)
