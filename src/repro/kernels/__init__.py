"""Pallas TPU kernels for the compute hot spots + jit'd dispatch (ops).

Kernels (each <name>.py = pl.pallas_call + BlockSpec; ref.py = oracle):

* ``attention``  — flash attention: causal / sliding-window / logit
                   softcap / GQA (every attention arch's hot spot).
* ``rglru``      — RG-LRU diagonal gated linear recurrence
                   (recurrentgemma's hot loop at long context).
* ``fedavg``     — masked FedAvg reduction over stacked client updates
                   (the paper's aggregation step, §II-B).
* ``quantize``   — per-256KiB-chunk int8 quant/dequant (dissemination
                   compression hook).
* ``mlstm``      — fused chunkwise-parallel mLSTM: the matrix state
                   lives in VMEM scratch across the chunk loop
                   (production form of the §Perf cell-1 fix).
"""
from . import attention, fedavg, mlstm, ops, quantize, ref, rglru

__all__ = ["attention", "fedavg", "mlstm", "ops", "quantize", "ref",
           "rglru"]
