"""Pallas TPU flash attention (causal / sliding-window / softcap / GQA).

TPU-native blocking: grid = (batch, q_heads, num_q_blocks, num_kv_blocks)
with the kv-block axis innermost, so the f32 accumulator / running max /
running denominator live in VMEM scratch across kv iterations (the
standard TPU online-softmax pattern).  Block shapes are (block_q, d) for
Q and (block_k, d) for K/V — d is the full head dim (MXU-aligned, 128 or
256 for the assigned archs), so VMEM per step is
``(block_q + 2*block_k) * d * bytes + block_q * d * 4`` — e.g. ~590 KiB
at block_q=block_k=512, d=128, bf16 inputs, far below the ~16 MiB VMEM
budget, leaving room for double buffering.

Fully-masked (q-block, kv-block) tiles are skipped via ``pl.when`` —
with causal masking this halves compute; with sliding windows it reduces
the kv loop to O(window) per q block, which is what makes 32k-sequence
local-attention layers cheap.

GQA is handled in the BlockSpec index maps: the kv head index is
``q_head // group`` — no repeat/materialization of K/V.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int | None,
                  softcap: float | None, block_q: int, block_k: int,
                  q_offset: int, kv_offset: int, kv_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # --- static-shape block skip test (trace-time ints are fine; the
    # dynamic grid indices make this a traced predicate for pl.when) ---
    q_lo = q_offset + qi * block_q          # first absolute q position
    q_hi = q_lo + block_q - 1               # last absolute q position
    k_lo = kv_offset + ki * block_k         # first absolute k position
    k_hi = k_lo + block_k - 1
    live = (ki * block_k) <= (kv_len - 1)   # physical padding bound
    live &= k_hi >= 0                       # rolling-cache validity
    if causal:
        live &= k_lo <= q_hi
    if window is not None:
        live &= k_hi > q_lo - window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (block_q, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (block_k, d)
        v = v_ref[0, 0].astype(jnp.float32)          # (block_k, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)

        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_k), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_k), 1)
        phys = (ki * block_k) + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = (phys < kv_len) & (k_pos >= 0)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                          # (block_q,)
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)              # <= 1, no overflow
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)                  # kill NEG_INF rows
        l_scr[...] = alpha * l_scr[...] + p.sum(axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[...]
        l_safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / l_safe[:, None]).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int | None = None,
                    softcap: float | None = None, q_offset: int = 0,
                    kv_offset: int = 0,
                    scale: float | None = None,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B, Hq, Tq, D); k, v: (B, Hkv, Tk, D); returns (B, Hq, Tq, D)."""
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    block_q = min(block_q, tq)
    block_k = min(block_k, tk)
    # Pad sequence dims up to block multiples (masked out via kv_len).
    pad_q = (-tq) % block_q
    pad_k = (-tk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = q.shape[2] // block_q
    nk = k.shape[2] // block_k

    kernel = functools.partial(
        _flash_kernel,
        scale=(d ** -0.5) if scale is None else scale,
        causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, q_offset=q_offset,
        kv_offset=kv_offset, kv_len=tk)

    out = pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, qi, ki, g=group: (b_, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, qi, ki, g=group: (b_, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h, qi, ki: (b_, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),   # running max m_i
            pltpu.VMEM((block_q,), jnp.float32),   # running denom l_i
            pltpu.VMEM((block_q, d), jnp.float32),  # f32 accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    if pad_q:
        out = out[:, :, :tq]
    return out
