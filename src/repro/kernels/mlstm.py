"""Pallas TPU kernel: chunkwise-parallel mLSTM (xLSTM matrix memory).

This is the fused production form of ``models.layers._mlstm_chunkwise``
(§Perf cell-1): the (dh, dh) matrix state C, the normalizer n and the
stabilizer m live in VMEM scratch across the chunk loop, so the state
NEVER round-trips HBM between chunks — the XLA path still pays one
carry read+write per chunk, and per-chunk layout collectives under
SPMD; the kernel removes both by construction.

Blocking: grid = (B, H, T/L) with the chunk axis innermost (sequential
on TPU, so scratch carries persist).  Per-step working set at
L=128, dh=512, f32: 4 slabs (q,k,v,h) ~1 MiB + (L,L) gate/score tiles
~130 KiB + C scratch 1 MiB — comfortably inside VMEM.

In-kernel math (identical to the derivation in layers.py, one (b,h)):
    A = tril_ones @ f          (cumsum as an MXU matmul)
    g = rowmax(tril ? gia : -inf)       (cummax as a masked row-max)
    M = max(m0, g);   c_int = exp(m0 - M)
    W[j,s] = tril ? exp(gia_s - M_j) : 0
    h = c_int * (q @ C0^T) + (W * (q k^T)) @ v, normalized by
        max(|c_int*(n0.q) + (W @ k).q|, 1)
    C <- exp(m0-MxL) C0 + (wL*v)^T k;   n, m likewise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mlstm_kernel(q_ref, k_ref, v_ref, i_ref, f_ref,
                  h_ref, cT_ref, nT_ref, mT_ref,
                  c_scr, n_scr, m_scr, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        c_scr[...] = jnp.zeros_like(c_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.full_like(m_scr, -1e30)

    qh = q_ref[0, 0].astype(jnp.float32)          # (L, dh)
    kh = k_ref[0, 0].astype(jnp.float32)
    vh = v_ref[0, 0].astype(jnp.float32)
    ic = i_ref[0, 0].astype(jnp.float32)          # (L,)
    fc = f_ref[0, 0].astype(jnp.float32)

    L = chunk
    tril = (jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
            >= jax.lax.broadcasted_iota(jnp.int32, (L, L), 1))
    ones_tri = tril.astype(jnp.float32)
    A = jax.lax.dot_general(ones_tri, fc[:, None],
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)[:, 0]
    gia = ic - A                                   # i_s - A_s
    g = jnp.max(jnp.where(tril, gia[None, :], -1e30), axis=1)

    m0 = m_scr[0]
    C0 = c_scr[...]
    n0 = n_scr[...]
    M = jnp.maximum(m0, g)                         # (L,)
    c_int = jnp.exp(m0 - M)
    W = jnp.where(tril, jnp.exp(gia[None, :] - M[:, None]), 0.0)

    scores = jax.lax.dot_general(qh, kh, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    inter = jax.lax.dot_general(qh, C0, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    h_num = (c_int[:, None] * inter
             + jax.lax.dot_general(W * scores, vh,
                                   (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32))
    nj = (c_int[:, None] * n0[None, :]
          + jax.lax.dot_general(W, kh, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32))
    den = jnp.abs(jnp.sum(nj * qh, axis=1))
    h_ref[0, 0] = (h_num / jnp.maximum(den, 1.0)[:, None]
                   ).astype(h_ref.dtype)

    # end-of-chunk state
    MxL = jnp.maximum(m0, g[L - 1])
    wL = jnp.exp(gia - MxL)                        # (L,)
    decay = jnp.exp(m0 - MxL)
    c_scr[...] = (decay * C0
                  + jax.lax.dot_general(vh * wL[:, None], kh,
                                        (((0,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))
    n_scr[...] = decay * n0 + jnp.sum(kh * wL[:, None], axis=0)
    m_scr[0] = A[L - 1] + MxL

    @pl.when(ci == nc - 1)
    def _final():
        cT_ref[0, 0] = c_scr[...].astype(cT_ref.dtype)
        nT_ref[0, 0] = n_scr[...].astype(nT_ref.dtype)
        mT_ref[0, 0] = jnp.broadcast_to(m_scr[...], mT_ref.shape[2:])


def mlstm_chunkwise(q, k, v, i_pre, f_pre, *, chunk: int = 128,
                    interpret: bool = False):
    """q,k,v: (B, H, T, dh) (q,k pre-scaled); i_pre,f_pre: (B, H, T).

    Returns (h (B,H,T,dh), C (B,H,dh,dh), n (B,H,dh), m (B,H)) from a
    zero initial state.  T must be a multiple of ``chunk``.
    """
    b, hh, t, dh = q.shape
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    kernel = functools.partial(_mlstm_kernel, chunk=chunk)
    h, cT, nT, mT = pl.pallas_call(
        kernel,
        grid=(b, hh, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, dh), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, 1, chunk, dh), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, 1, chunk, dh), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b_, h_, c: (b_, h_, c)),
            pl.BlockSpec((1, 1, chunk), lambda b_, h_, c: (b_, h_, c)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, dh), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, 1, dh, dh), lambda b_, h_, c: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, dh), lambda b_, h_, c: (b_, h_, 0)),
            pl.BlockSpec((1, 1, 1), lambda b_, h_, c: (b_, h_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hh, t, dh), q.dtype),
            jax.ShapeDtypeStruct((b, hh, dh, dh), jnp.float32),
            jax.ShapeDtypeStruct((b, hh, dh), jnp.float32),
            jax.ShapeDtypeStruct((b, hh, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((dh, dh), jnp.float32),
            pltpu.VMEM((dh,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, i_pre, f_pre)
    return h, cT, nT, mT[..., 0]
