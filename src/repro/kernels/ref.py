"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references: small, obviously-correct, O(T^2)
where the kernels are blocked.  Tests sweep shapes/dtypes and
``assert_allclose`` kernel vs oracle (interpret=True on CPU).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from .fedavg import mask_inactive_rows, masked_normalized_weights

NEG_INF = -1e30


# ----------------------------------------------------------------------
# Attention oracle (causal / sliding-window / softcap / GQA)
# ----------------------------------------------------------------------

def attention_mask(q_len: int, kv_len: int, *, causal: bool,
                   window: int | None, q_offset: int = 0,
                   kv_offset: int = 0) -> jnp.ndarray:
    """(q_len, kv_len) bool mask. Query i sits at absolute position
    ``q_offset + i``; key j at absolute position ``kv_offset + j``
    (rolling caches use negative kv_offset; negative key positions are
    invalid).  ``window`` w keeps keys with ``q_pos - w < k_pos <=
    q_pos`` (sliding window incl. self)."""
    q_pos = q_offset + jnp.arange(q_len)[:, None]
    k_pos = kv_offset + jnp.arange(kv_len)[None, :]
    mask = jnp.ones((q_len, kv_len), dtype=bool)
    mask &= k_pos >= 0
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    return mask


def mha(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
        causal: bool = True, window: int | None = None,
        softcap: float | None = None, q_offset: int = 0,
        kv_offset: int = 0,
        scale: float | None = None) -> jnp.ndarray:
    """Reference multi-head attention.

    q: (B, Hq, Tq, D); k, v: (B, Hkv, Tk, D) with Hq % Hkv == 0 (GQA).
    Returns (B, Hq, Tq, D) in q.dtype.  All math in f32.
    """
    b, hq, tq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    qf = q.astype(jnp.float32)
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    sc = (d ** -0.5) if scale is None else scale
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * sc
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    mask = attention_mask(tq, k.shape[2], causal=causal, window=window,
                          q_offset=q_offset, kv_offset=kv_offset)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # Fully-masked rows (can happen with windows) -> zeros, not NaN.
    p = jnp.where(mask.any(-1)[None, None, :, None], p, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)


# ----------------------------------------------------------------------
# RG-LRU oracle (diagonal gated linear recurrence, De et al. 2024)
# ----------------------------------------------------------------------

def rglru(x: jnp.ndarray, a: jnp.ndarray, gate_x: jnp.ndarray,
          h0: jnp.ndarray | None = None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (gx_t * x_t).

    x, a, gate_x: (B, T, D) with a in (0, 1).  Returns (y, h_T).
    """
    xf = x.astype(jnp.float32)
    af = a.astype(jnp.float32)
    gx = gate_x.astype(jnp.float32)
    inp = jnp.sqrt(jnp.maximum(1.0 - af * af, 0.0)) * (gx * xf)
    if h0 is None:
        h0 = jnp.zeros((x.shape[0], x.shape[2]), jnp.float32)

    def step(h, ab):
        a_t, i_t = ab
        h = a_t * h + i_t
        return h, h

    hT, ys = jax.lax.scan(step, h0.astype(jnp.float32),
                          (af.swapaxes(0, 1), inp.swapaxes(0, 1)))
    return ys.swapaxes(0, 1).astype(x.dtype), hT


# ----------------------------------------------------------------------
# Masked FedAvg reduction oracle (paper §II-B aggregation)
# ----------------------------------------------------------------------

def fedavg_reduce(updates: jnp.ndarray, weights: jnp.ndarray,
                  active: jnp.ndarray) -> jnp.ndarray:
    """FedAvg over the reconstructable active set.

    updates: (n, D) flattened per-client updates; weights: (n,) scalar
    aggregation weights (sample counts); active: (n,) bool/float mask
    (A_v^r membership).  Returns (D,) = sum_u m_u w_u x_u / sum_u m_u w_u.
    """
    wn = masked_normalized_weights(weights, active)
    masked = mask_inactive_rows(updates.astype(jnp.float32), wn)
    return jnp.einsum("n,nd->d", wn, masked).astype(updates.dtype)


# ----------------------------------------------------------------------
# Chunk quantization oracle (int8 symmetric per chunk)
# ----------------------------------------------------------------------

def chunk_quantize(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (n_chunks, chunk_elems) f32 -> (int8 codes, f32 scales (n,1))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def chunk_dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale
