"""Pallas TPU kernel: masked FedAvg reduction over stacked client updates.

This is the paper's aggregation step (§II-B): every client computes

    agg = sum_u  m_u * w_u * x_u  /  sum_u m_u * w_u

over the updates ``x_u`` it reconstructed by the deadline, where ``m_u``
is the active-set mask (A_v^r membership) and ``w_u`` the published
scalar weight.  On a pod this runs after torrent dissemination with the
n updates stacked on the leading axis.

The reduction is purely memory-bound (one pass over n*D floats, D >> n),
so the kernel streams (n, block_d) slabs HBM->VMEM and issues one
(1, n) x (n, block_d) MXU matvec per slab — normalization of the mask *
weight vector happens once outside (O(n) scalar work, not a hot spot).

VMEM per step = n * block_d * bytes; defaults (n<=512, block_d=2048,
f32) stay under ~4 MiB.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fedavg_kernel(w_ref, u_ref, o_ref):
    w = w_ref[...]                                   # (1, n) f32
    u = u_ref[...].astype(jnp.float32)               # (n, block_d)
    o_ref[...] = jax.lax.dot_general(
        w, u, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def masked_normalized_weights(weights: jnp.ndarray,
                              active: jnp.ndarray) -> jnp.ndarray:
    """FedAvg weights w_u m_u / sum w_u m_u, (n,) f32.

    Zero active mass (every client masked / weightless) yields zeros,
    never 0/0 NaN.  Single implementation shared by the Pallas kernel,
    the jnp oracle (ref.py), and the torrent ring (dist/torrent.py).
    """
    w = weights.astype(jnp.float32) * active.astype(jnp.float32)
    total = jnp.sum(w)
    return jnp.where(total > 0, w / jnp.maximum(total, 1e-12),
                     jnp.zeros_like(w))


def mask_inactive_rows(updates: jnp.ndarray, wn: jnp.ndarray) -> jnp.ndarray:
    """Select-out rows with zero weight BEFORE the weighted reduction.

    A masked client's update may be the *reason* it was masked (diverged
    local step -> inf/NaN grads); 0 * NaN == NaN would poison the
    aggregate, so zero-weight rows are replaced, not multiplied.
    """
    return jnp.where((wn > 0)[:, None], updates,
                     jnp.zeros_like(updates))


def fedavg_reduce(updates: jnp.ndarray, weights: jnp.ndarray,
                  active: jnp.ndarray, *, block_d: int = 2048,
                  interpret: bool = False) -> jnp.ndarray:
    """updates (n, D); weights (n,); active (n,) -> (D,) FedAvg."""
    n, d = updates.shape
    w = masked_normalized_weights(weights, active)
    updates = mask_inactive_rows(updates, w)
    block_d = min(block_d, d)
    pad_n = (-n) % 8
    pad_d = (-d) % block_d
    if pad_n or pad_d:
        updates = jnp.pad(updates, ((0, pad_n), (0, pad_d)))
        w = jnp.pad(w, (0, pad_n))
    nn, dd = updates.shape
    out = pl.pallas_call(
        _fedavg_kernel,
        grid=(dd // block_d,),
        in_specs=[
            pl.BlockSpec((1, nn), lambda di: (0, 0)),
            pl.BlockSpec((nn, block_d), lambda di: (0, di)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda di: (0, di)),
        out_shape=jax.ShapeDtypeStruct((1, dd), updates.dtype),
        interpret=interpret,
    )(w[None], updates)
    return out[0, :d]
