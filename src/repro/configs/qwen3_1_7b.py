"""qwen3-1.7b [dense] — 28L d2048 16H (GQA kv=8, head_dim 128) ff6144
vocab 151936; qk-norm.  [hf:Qwen/Qwen3-8B family; hf]
"""
from repro.models import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv=8, head_dim=128,
    d_ff=6144, vocab=151936,
    pattern=("global",), qk_norm=True, act="silu",
    tie_embeddings=True, rope_theta=1_000_000.0,
)

REDUCED = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv=2, head_dim=16, d_ff=128,
    vocab=512, dtype="float32", remat=False)
