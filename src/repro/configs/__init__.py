"""Assigned-architecture registry: ``--arch <id>`` resolves here."""
from . import (base, chameleon_34b, deepseek_7b, gemma2_2b, gemma3_4b,
               granite_moe_1b, hubert_xlarge, olmoe_1b_7b,
               qwen3_1_7b, recurrentgemma_2b, xlstm_350m)
from .base import SHAPES, ShapeSpec, all_cells, cell_skip_reason

_MODULES = {
    "gemma2-2b": gemma2_2b,
    "qwen3-1.7b": qwen3_1_7b,
    "gemma3-4b": gemma3_4b,
    "deepseek-7b": deepseek_7b,
    "olmoe-1b-7b": olmoe_1b_7b,
    "granite-moe-1b-a400m": granite_moe_1b,
    "xlstm-350m": xlstm_350m,
    "recurrentgemma-2b": recurrentgemma_2b,
    "hubert-xlarge": hubert_xlarge,
    "chameleon-34b": chameleon_34b,
}

ARCHS = tuple(_MODULES)


def get_config(arch: str, *, reduced: bool = False):
    mod = _MODULES[arch]
    return mod.REDUCED if reduced else mod.CONFIG


__all__ = ["ARCHS", "SHAPES", "ShapeSpec", "get_config", "all_cells",
           "cell_skip_reason"]
