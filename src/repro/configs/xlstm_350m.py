"""xlstm-350m [ssm] — 24 blocks d1024, mLSTM:sLSTM 7:1 interleave,
4 heads, no external FFN (d_ff=0; blocks carry internal up/down
projections), vocab 50304.  [arXiv:2405.04517; unverified]

24 layers = 3 cycles of (7 mLSTM + 1 sLSTM).  Pure recurrent state ->
runs the long_500k shape.
"""
from repro.models import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv=4, head_dim=256,
    d_ff=0, vocab=50304,
    pattern=("mlstm",) * 7 + ("slstm",), rnn_heads=4,
    act="gelu", tie_embeddings=True,
)

REDUCED = CONFIG.replace(
    n_layers=8, d_model=64, n_heads=4, n_kv=4, head_dim=16,
    vocab=512, rnn_heads=4, dtype="float32", remat=False)
