"""chameleon-34b [vlm] — 48L d8192 64H (GQA kv=8, head_dim 128)
ff22016 vocab 65536; early-fusion decoder, VQ image tokens share the
text vocabulary; qk-norm.  [arXiv:2405.09818; unverified]

The VQ image tokenizer frontend is a STUB per the assignment: image
patches arrive as token ids in the shared 65536 vocab, so
``input_specs()`` is the ordinary (B, T) token layout.
"""
from repro.models import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv=8, head_dim=128,
    d_ff=22016, vocab=65536,
    pattern=("global",), qk_norm=True, act="silu",
    tie_embeddings=False, rope_theta=10_000.0,
)

REDUCED = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv=2, head_dim=16, d_ff=128,
    vocab=512, dtype="float32", remat=False)
