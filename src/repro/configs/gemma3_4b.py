"""gemma3-4b [dense] — 34L d2560 8H (GQA kv=4, head_dim 256) ff10240
vocab 262144; 5:1 local(1024):global interleave, qk-norm, 128k context.
[hf:google/gemma-3 family; unverified]

34 layers = 5 full (5 local + 1 global) cycles + 4 tail local layers.
"""
from repro.models import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv=4, head_dim=256,
    d_ff=10240, vocab=262144,
    pattern=("local", "local", "local", "local", "local", "global"),
    window=1024, qk_norm=True, act="gelu", tie_embeddings=True,
    rope_theta=1_000_000.0,
)

REDUCED = CONFIG.replace(
    n_layers=8, d_model=64, n_heads=4, n_kv=2, head_dim=16, d_ff=128,
    vocab=512, window=16, dtype="float32", remat=False)
