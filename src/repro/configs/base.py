"""Shape registry + per-cell skip logic for the assigned architectures.

Four input-shape sets (assignment):
    train_4k     seq 4096,   global_batch 256   -> train_step
    prefill_32k  seq 32768,  global_batch 32    -> prefill
    decode_32k   cache 32768, global_batch 128  -> serve_step
    long_500k    cache 524288, global_batch 1   -> serve_step (sub-quadratic
                                                   state only)

Skips (documented in DESIGN.md §4): encoder-only archs have no decode;
``long_500k`` runs only for archs whose state is bounded (xlstm,
recurrentgemma); pure full-attention archs skip it.
"""
from __future__ import annotations

from dataclasses import dataclass



@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# Archs whose per-token state is bounded (recurrent / windowed-only):
SUBQUADRATIC = {"xlstm-350m", "recurrentgemma-2b"}
ENCODER_ONLY = {"hubert-xlarge"}


def cell_skip_reason(arch: str, shape: str) -> str | None:
    if arch in ENCODER_ONLY and shape in ("decode_32k", "long_500k"):
        return "encoder-only: no autoregressive decode step"
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return ("pure full-attention arch: 524288-token KV cache is not "
                "sub-quadratic state (DESIGN.md §4)")
    return None


def all_cells():
    """Yield (arch_id, shape_name, skip_reason) for the 40-cell grid."""
    from . import ARCHS
    for arch in ARCHS:
        for shape in SHAPES:
            yield arch, shape, cell_skip_reason(arch, shape)
