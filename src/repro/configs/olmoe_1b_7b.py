"""olmoe-1b-7b [moe] — 16L d2048 16H (kv=16, head_dim 128), MoE FFN:
64 experts top-8, d_expert 1024, vocab 50304; qk-norm.
[arXiv:2409.02060; hf]
"""
from repro.models import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv=16, head_dim=128,
    d_ff=0, vocab=50304,
    pattern=("moe",), n_experts=64, top_k=8, d_expert=1024,
    capacity_factor=1.25, qk_norm=True, act="silu",
    tie_embeddings=False, rope_theta=10_000.0,
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv=4, head_dim=16,
    vocab=512, n_experts=8, top_k=2, d_expert=32,
    capacity_factor=8.0,   # no token drops at smoke scale
    dtype="float32", remat=False)
