"""deepseek-7b [dense] — 30L d4096 32H (MHA kv=32, head_dim 128)
ff11008 vocab 102400; llama-style architecture (SwiGLU, RoPE, RMSNorm).
[arXiv:2401.02954; hf]
"""
from repro.models import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-7b", family="dense",
    n_layers=30, d_model=4096, n_heads=32, n_kv=32, head_dim=128,
    d_ff=11008, vocab=102400,
    pattern=("global",), act="silu", tie_embeddings=False,
    rope_theta=10_000.0,
)

REDUCED = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv=4, head_dim=16, d_ff=128,
    vocab=512, dtype="float32", remat=False)
