"""gemma2-2b [dense] — 26L d2304 8H (GQA kv=4, head_dim 256) ff9216
vocab 256000; 1:1 local(4096)/global alternation, attention-logit
softcap 50, final-logit softcap 30, post-layer norms.
[arXiv:2408.00118; hf]
"""
from repro.models import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv=4, head_dim=256,
    d_ff=9216, vocab=256000,
    pattern=("local", "global"), window=4096,
    attn_softcap=50.0, final_softcap=30.0,
    post_norm=True, act="gelu", tie_embeddings=True,
    rope_theta=10_000.0,
)

REDUCED = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=4, n_kv=2, head_dim=16, d_ff=128,
    vocab=512, window=16, dtype="float32", remat=False)
