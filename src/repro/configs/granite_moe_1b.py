"""granite-moe-1b-a400m [moe] — 24L d1024 16H (GQA kv=8, head_dim 64),
MoE FFN: 32 experts top-8, d_expert 512, vocab 49155.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

Note vocab 49155 is not divisible by the 16-way model axis — the
sharding rules leave the embedding replicated (divisibility filter),
which is exactly the elastic-mesh behaviour DESIGN.md §5 describes.
"""
from repro.models import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv=8, head_dim=64,
    d_ff=0, vocab=49155,
    pattern=("moe",), n_experts=32, top_k=8, d_expert=512,
    capacity_factor=1.25, act="silu", tie_embeddings=True,
    rope_theta=10_000.0,
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
    vocab=509, n_experts=8, top_k=2, d_expert=32,   # odd vocab on purpose
    capacity_factor=8.0,   # no token drops at smoke scale
    dtype="float32", remat=False)
