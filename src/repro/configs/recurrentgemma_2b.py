"""recurrentgemma-2b [hybrid] — 26L d2560 10H (MQA kv=1, head_dim 256)
ff7680 vocab 256000; Griffin pattern 2 RG-LRU : 1 local-attn(2048).
[arXiv:2402.19427; hf]

26 layers = 8 cycles of (rglru, rglru, local) + 2 tail rglru layers.
Bounded state (RG-LRU h + 2048-window KV) -> runs the long_500k shape.
"""
from repro.models import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv=1, head_dim=256,
    d_ff=7680, vocab=256000,
    pattern=("rglru", "rglru", "local"), window=2048, d_rnn=2560,
    act="gelu", tie_embeddings=True, rope_theta=10_000.0,
)

REDUCED = CONFIG.replace(
    n_layers=5, d_model=64, n_heads=4, n_kv=1, head_dim=16, d_ff=128,
    vocab=512, window=16, d_rnn=64, dtype="float32", remat=False)
