"""hubert-xlarge [audio] — 48L encoder-only d1280 16H (kv=16,
head_dim 80) ff5120, 504 masked-prediction classes.
[arXiv:2106.07447; unverified]

The conv waveform frontend is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings (B, T, d_model);
the model applies a learned linear adapter + bidirectional encoder +
classification head.  No decode shapes (encoder-only).
"""
from repro.models import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv=16, head_dim=80,
    d_ff=5120, vocab=504,
    pattern=("global",), causal=False, has_embedding=False,
    act="gelu", tie_embeddings=False,
)

REDUCED = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv=4, head_dim=16, d_ff=128,
    vocab=32, dtype="float32", remat=False)
