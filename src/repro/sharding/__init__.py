from .api import (DEFAULT_RULES, axis_rules, logical_constraint,
                  param_specs, spec_for_path)

__all__ = ["DEFAULT_RULES", "axis_rules", "logical_constraint",
           "param_specs", "spec_for_path"]
