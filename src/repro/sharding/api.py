"""Logical-axis sharding: rules, activation constraints, param specs.

The model code never mentions mesh axes; it tags activations with
*logical* axes via ``logical_constraint(x, "batch", "seq", None)`` and
parameters get specs derived from their *path names* (``spec_for_path``).
The launcher binds logical axes to mesh axes with ``axis_rules``:

    with mesh, axis_rules(DEFAULT_RULES, mesh):
        jax.jit(train_step, in_shardings=..., ...)

Default binding (production mesh axes ``pod`` / ``data`` / ``model``):

    batch  -> (pod, data)     # DP across pods and within a pod
    vocab/heads/kv/ffn/expert/rnn -> model   # TP / EP
    ZeRO: largest remaining param dim -> data (FSDP + sharded opt state)

Every rule is divisibility-checked against the actual mesh so the same
model code lowers on any mesh (single host, 16x16 pod, 2x16x16
multi-pod) — non-divisible dims are left unsharded rather than erroring,
which is what makes elastic re-meshing across FL rounds possible.
"""
from __future__ import annotations

import contextlib
import enum
import inspect
import re
import threading
from collections.abc import Sequence

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

_CTX = threading.local()

# --- jax-version compat -----------------------------------------------
# ``jax.sharding.AxisType`` (and ``jax.make_mesh(..., axis_types=...)``)
# only exist on newer jax; 0.4.x has neither.  Export a stand-in enum
# and a mesh constructor that forwards axis_types when supported so the
# launcher and tests build meshes identically on both.
try:
    AxisType = jax.sharding.AxisType
    _HAS_AXIS_TYPES = True
except AttributeError:
    class AxisType(enum.Enum):          # type: ignore[no-redef]
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"
    _HAS_AXIS_TYPES = False


# Probe once whether jax.make_mesh takes axis_types — catching
# TypeError per call would also swallow genuine caller bugs.
try:
    _MESH_TAKES_AXIS_TYPES = (
        "axis_types" in inspect.signature(jax.make_mesh).parameters)
except (TypeError, ValueError):
    _MESH_TAKES_AXIS_TYPES = False


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` with a guarded ``axis_types`` forward."""
    kw = {} if devices is None else {"devices": devices}
    if _HAS_AXIS_TYPES and _MESH_TAKES_AXIS_TYPES and axis_types is not None:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=axis_types, **kw)
    return jax.make_mesh(axis_shapes, axis_names, **kw)


def shard_map(f, mesh, *, in_specs, out_specs, axis_names=None,
              check_rep: bool = True):
    """``shard_map`` across jax versions.

    Newer jax exposes ``jax.shard_map(..., axis_names=..., check_vma=)``;
    0.4.x has ``jax.experimental.shard_map.shard_map(..., auto=...,
    check_rep=)``.  ``axis_names`` is the set of mesh axes the body is
    *manual* over (None = all of them); the complement is forwarded as
    ``auto`` on old jax.  ``check_rep=False`` maps to ``check_vma=False``;
    the default mirrors jax's own (checking on).
    """
    if hasattr(jax, "shard_map"):
        kw = {"check_vma": check_rep}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = (frozenset(mesh.axis_names) - frozenset(axis_names)
            if axis_names is not None else frozenset())
    return _shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_rep, auto=auto)

# ZeRO/FSDP sharding applies only to params with at least this many
# elements (2M ~ a 1448^2 matrix); smaller tensors replicate.
ZERO_MIN_ELEMS = 2 ** 21

# logical axis -> mesh axis (or tuple of mesh axes)
DEFAULT_RULES: dict = {
    "batch": ("pod", "data"),
    "seq": None,
    "vocab": "model",
    "heads": "model",
    "kv": "model",
    "ffn": "model",
    "expert": "model",
    "rnn": "model",
    "d_model": None,
    "zero": "data",           # FSDP / optimizer-state axis
}


@contextlib.contextmanager
def axis_rules(rules: dict, mesh=None):
    prev = getattr(_CTX, "state", None)
    _CTX.state = (dict(rules), mesh)
    try:
        yield
    finally:
        _CTX.state = prev


def current_rules():
    return getattr(_CTX, "state", None)


def _axis_size(mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, (tuple, list)):
        return int(np.prod([_axis_size(mesh, a) for a in name]))
    return int(dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1))


def _filter_axes(mesh, name, dim_size: int):
    """Drop mesh axes that don't exist / don't divide dim_size."""
    if name is None:
        return None
    names = name if isinstance(name, (tuple, list)) else (name,)
    kept = []
    prod = 1
    for a in names:
        if a not in mesh.axis_names:
            continue
        sz = _axis_size(mesh, a)
        if dim_size % (prod * sz) == 0:
            kept.append(a)
            prod *= sz
    if not kept:
        return None
    return tuple(kept) if len(kept) > 1 else kept[0]


def logical_constraint(x, *axes):
    """with_sharding_constraint by logical axis names (no-op w/o rules)."""
    state = current_rules()
    if state is None:
        return x
    rules, mesh = state
    if mesh is None:
        return x
    parts = []
    for i, a in enumerate(axes):
        name = rules.get(a) if a else None
        parts.append(_filter_axes(mesh, name, x.shape[i]))
    if all(p is None for p in parts):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))


# ----------------------------------------------------------------------
# Parameter specs by path name
# ----------------------------------------------------------------------

# (regex on the param's dot-joined path) -> logical axes per trailing dim.
# Stacked scan params have a leading cycle dim handled separately.
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed$", ("vocab", "d_model")),
    (r"head$", ("d_model", "vocab")),
    (r"adapter_in$", ("d_model", "d_model")),
    (r"(wq|wk|wv)$", ("d_model", "heads")),     # flattened head dims
    (r"wo$", ("heads", "d_model")),
    (r"(w_gate|w_up)$", ("d_model", "ffn")),
    (r"w_down$", ("ffn", "d_model")),
    (r"router$", ("d_model", "expert")),
    (r"(moe_gate|moe_up)$", ("expert", "d_model", "ffn")),
    (r"moe_down$", ("expert", "ffn", "d_model")),
    (r"(rg_in|rg_gate)$", ("d_model", "rnn")),
    (r"rg_out$", ("rnn", "d_model")),
    (r"conv_w$", (None, "rnn")),
    (r"(lam|a_gate_w|i_gate_w)$", ("rnn",)),
    (r"(up_l|up_r)$", ("d_model", "rnn")),
    (r"(wq_i|wk_i|wv_i)$", ("rnn", "rnn")),
    (r"(wi|wf|wo_gate)$", ("rnn", "heads")),
    (r"down$", ("rnn", "d_model")),
    (r"w4$", ("d_model", "heads")),             # sLSTM fused gates
    (r"r4$", ("heads", None, None)),            # block-diag recurrent
    (r"b4$", ("heads",)),
    (r"(q_norm|k_norm|ln1|ln2|post_ln1|post_ln2|final_norm|norm)$",
     None),
]


def spec_for_path(path: str, shape: tuple, mesh, rules: dict,
                  stacked: bool, zero: bool = True) -> P:
    """PartitionSpec for one param; applies TP rules then ZeRO."""
    logical = None
    for pat, ax in _PARAM_RULES:
        if re.search(pat, path):
            logical = ax
            break
    ndim = len(shape)
    parts: list = [None] * ndim
    off = 1 if stacked else 0
    used: set = set()

    def _dedup(f):
        """Drop mesh axes already used by an earlier dim of this param."""
        if f is None:
            return None
        names = f if isinstance(f, tuple) else (f,)
        kept = tuple(a for a in names if a not in used)
        if not kept or kept != names:
            return None          # partial use would break divisibility
        used.update(kept)
        return kept if len(kept) > 1 else kept[0]

    if logical is not None:
        for i, a in enumerate(logical):
            j = off + i
            if j >= ndim or a is None:
                continue
            parts[j] = _dedup(_filter_axes(mesh, rules.get(a), shape[j]))
    if zero and int(np.prod(shape or (1,))) >= ZERO_MIN_ELEMS:
        # ZeRO only pays for big tensors; sharding a 1k-element norm
        # scale costs a per-use all-gather that XLA cannot hoist out of
        # rematerialized scan bodies (§Perf cell-1 iter-3: millions of
        # tiny in-loop all-gathers in the sLSTM step).
        zaxis = rules.get("zero")
        if zaxis is not None:
            # largest still-unsharded dim (excluding the stack dim).
            order = sorted(range(off, ndim), key=lambda i: -shape[i])
            for i in order:
                if parts[i] is None:
                    f = _dedup(_filter_axes(mesh, zaxis, shape[i]))
                    if f is not None:
                        parts[i] = f
                        break
    return P(*parts)


def param_specs(params, mesh, rules: dict | None = None, *,
                stacked_prefixes: Sequence[str] = ("cycles",),
                zero: bool = True):
    """Tree of PartitionSpec matching a params pytree, by path names."""
    rules = dict(DEFAULT_RULES if rules is None else rules)

    def leaf_spec(path_tuple, leaf):
        keys = [getattr(p, "key", getattr(p, "idx", None))
                for p in path_tuple]
        path = ".".join(str(k) for k in keys)
        stacked = any(path.startswith(pfx) for pfx in stacked_prefixes)
        return spec_for_path(path, leaf.shape, mesh, rules, stacked, zero)

    return jax.tree_util.tree_map_with_path(leaf_spec, params)
