"""Recorder: the write side of repro.obs.

Event model — every record is one flat dict ("row") with a ``kind``:

``span``    a named interval.  Wall duration (``wall_s``) is measured
            by the recorder's injectable clock when used as a context
            manager (``with rec.span("warmup"):``); simulated bounds
            (``t0``/``t1``, seconds on the session wall clock) are
            attached via :meth:`Recorder.span_at` for phases whose
            extent lives in simulated time.
``event``   a named instant, optionally at simulated time ``t``.
``flows``   a columnar batch of transport flows on one track
            (``warmup`` / ``bt`` / ``background`` / ``spray``): aligned
            ``src`` / ``dst`` / ``t_start`` / ``t_end`` lists plus any
            extra aligned columns — per-flow granularity, not
            per-chunk, so recordings stay tractable at paper scale.
``metric``  the registry snapshot, emitted at export time: one row per
            counter (sum), gauge (last value), or histogram (all
            observations).

Simulated instants (``t``, ``t0``, ``t1``, ``t_start``, ``t_end``) are
shifted by ``time_base`` at record time; wall durations are not.
"""
from __future__ import annotations

import contextlib

import numpy as np

# Keys whose values are simulated instants: shifted by ``time_base`` so
# multi-round recordings share the session wall clock.
_TIME_KEYS = ("t", "t0", "t1", "t_start", "t_end")


def _zero_clock() -> float:
    return 0.0


class _NullSpan:
    """No-op span handle (shared singleton)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def note(self, **attrs):
        pass


_NULL_SPAN = _NullSpan()


class NullRecorder:
    """Disabled telemetry: every hook is a no-op.

    This is the default active recorder — the zero-overhead-when-
    disabled contract is a single attribute load plus an empty method
    call at each instrumentation site (bounded by the overhead
    micro-test in ``tests/test_obs.py``).
    """

    enabled = False
    time_base = 0.0

    def set_ctx(self, **attrs):
        pass

    def span(self, name, **attrs):
        return _NULL_SPAN

    def span_at(self, name, t0, t1, **attrs):
        pass

    def event(self, name, t=None, **attrs):
        pass

    def counter(self, name, value=1.0, **attrs):
        pass

    def gauge(self, name, value, **attrs):
        pass

    def hist(self, name, values, **attrs):
        pass

    def flows(self, track, src, dst, t_start, t_end, **cols):
        pass


class _Span:
    """Live span handle: measures wall time between enter and exit on
    the owning recorder's injectable clock, then appends one row."""

    __slots__ = ("_rec", "name", "attrs", "_w0")

    def __init__(self, rec: "Recorder", name: str, attrs: dict):
        self._rec = rec
        self.name = name
        self.attrs = attrs
        self._w0 = 0.0

    def __enter__(self):
        self._w0 = self._rec.clock()
        return self

    def note(self, **attrs):
        self.attrs.update(attrs)

    def __exit__(self, *exc):
        wall = self._rec.clock() - self._w0
        self._rec._append(dict(kind="span", name=self.name,
                               wall_s=float(wall), **self.attrs))
        return False


class Recorder:
    """Enabled telemetry sink.

    ``clock`` is the wall-clock source behind context-manager spans —
    injectable exactly like ``core.simulator.set_clock`` (benchmarks
    pass ``time.perf_counter``); the default constant zero clock keeps
    recordings deterministic and core RNG007-clean.  ``meta`` is an
    arbitrary JSON-able dict stamped into the header row.
    """

    enabled = True

    def __init__(self, clock=None, meta: dict | None = None):
        self.clock = clock if clock is not None else _zero_clock
        self.meta = dict(meta or {})
        self.rows: list[dict] = []
        self.metrics: dict[str, dict] = {}
        # Session wall-clock offset added to simulated instants at
        # record time (SwarmSession sets this to offsets[-1] per round).
        self.time_base = 0.0
        # Ambient attributes merged into every row (e.g. round=r).
        self._ctx: dict = {}
        self._seq = 0

    # -- plumbing -------------------------------------------------------
    def set_ctx(self, **attrs):
        """Merge ambient attributes into every subsequent row (a value
        of ``None`` removes the key)."""
        for k, v in attrs.items():
            if v is None:
                self._ctx.pop(k, None)
            else:
                self._ctx[k] = v

    def _append(self, row: dict):
        if self._ctx:
            row = {**self._ctx, **row}
        base = self.time_base
        if base:
            for k in _TIME_KEYS:
                v = row.get(k)
                if v is not None:
                    row[k] = (np.asarray(v, np.float64) + base
                              if isinstance(v, np.ndarray) else
                              float(v) + base)
        row["seq"] = self._seq
        self._seq += 1
        self.rows.append(row)

    # -- spans ----------------------------------------------------------
    def span(self, name: str, **attrs) -> _Span:
        """Wall-clocked span: ``with rec.span("warmup", round=r): ...``"""
        return _Span(self, name, attrs)

    def span_at(self, name: str, t0: float, t1: float, **attrs):
        """Post-hoc span over SIMULATED time ``[t0, t1]`` (seconds on
        the session wall clock after the ``time_base`` shift); pass
        ``wall_s=`` for the host-time cost of producing it."""
        self._append(dict(kind="span", name=name, t0=float(t0),
                          t1=float(t1), **attrs))

    # -- instants -------------------------------------------------------
    def event(self, name: str, t: float | None = None, **attrs):
        row = dict(kind="event", name=name, **attrs)
        if t is not None:
            row["t"] = float(t)
        self._append(row)

    # -- metrics registry ----------------------------------------------
    def counter(self, name: str, value: float = 1.0, **attrs):
        m = self.metrics.get(name)
        if m is None:
            self.metrics[name] = m = {"metric": "counter", "value": 0.0}
        m["value"] += float(value)

    def gauge(self, name: str, value: float, **attrs):
        self.metrics[name] = {"metric": "gauge", "value": float(value)}

    def hist(self, name: str, values, **attrs):
        m = self.metrics.get(name)
        if m is None:
            self.metrics[name] = m = {"metric": "hist", "values": []}
        if np.isscalar(values):
            m["values"].append(float(values))
        else:
            m["values"].extend(float(v) for v in np.asarray(values).ravel())

    # -- flow batches ---------------------------------------------------
    def flows(self, track: str, src, dst, t_start, t_end, **cols):
        """One columnar batch of transport flows on ``track``; all
        arguments are aligned 1-d arrays.  Non-finite end stamps (dead
        zero-rate flows) are recorded as-is minus inf -> the exporter
        clamps; callers should prefer pre-filtering."""
        src = np.asarray(src, np.int64)
        if src.size == 0:
            return
        row = dict(kind="flows", track=str(track), n=int(src.size),
                   src=src, dst=np.asarray(dst, np.int64),
                   t_start=np.asarray(t_start, np.float64),
                   t_end=np.asarray(t_end, np.float64))
        for k, v in cols.items():
            row[k] = np.asarray(v)
        self._append(row)


# -- module-level active recorder ---------------------------------------
_active: NullRecorder | Recorder = NullRecorder()


def get():
    """The active recorder (a NullRecorder unless one is installed)."""
    return _active


def install(rec):
    """Install ``rec`` as the active recorder (``None`` restores the
    null recorder); returns the previously active one."""
    global _active
    prev = _active
    _active = rec if rec is not None else NullRecorder()
    return prev


@contextlib.contextmanager
def recording(rec: Recorder | None = None, *, clock=None,
              meta: dict | None = None):
    """Scoped recording: install a recorder (a fresh one by default),
    yield it, and ALWAYS restore the previous recorder on exit —
    telemetry can never leak into subsequent determinism-sensitive
    code even if the recorded block raises."""
    if rec is None:
        rec = Recorder(clock=clock, meta=meta)
    prev = install(rec)
    try:
        yield rec
    finally:
        install(prev)
