"""repro.obs — unified swarm telemetry (ISSUE 10).

One first-class stream for everything the stack used to report through
ad-hoc fragments (``RoundResult.timings``, ``tracker_log`` dicts,
``SwarmSession.wall_clock()``): structured spans, typed counters /
gauges / histograms, per-flow timeline batches — recorded by an
injectable :class:`Recorder` and consumed by the JSONL / Perfetto
exporters and the ``python -m repro.obs report`` CLI.

Design contract (see docs/OBSERVABILITY.md):

* **Zero overhead when disabled.**  The module-level active recorder
  defaults to a :class:`NullRecorder` whose every hook is a no-op and
  whose ``enabled`` flag is ``False`` — instrumentation sites guard any
  non-trivial argument construction behind ``if rec.enabled:``.
* **Determinism-inert.**  The recorder only *observes*: it draws no
  rng, never feeds back into simulated time, and its measurement clock
  is injectable (defaulting to a constant zero clock) following the
  ``core.simulator.set_clock`` idiom — so core stays RNG007-clean and
  determinism twins are byte-identical with telemetry on or off.
* **One wall clock.**  Simulated instants are recorded round-relative
  and shifted by ``Recorder.time_base`` (set per round by
  :class:`~repro.core.session.SwarmSession` to its ``offsets[-1]``), so
  a multi-round recording lands on the session's single wall clock.
"""
from .recorder import (NullRecorder, Recorder, get, install, recording)
from .export import (read_jsonl, to_jsonl_rows, to_perfetto,
                     validate_rows, write_jsonl, write_perfetto)
from .report import summarize, format_report

__all__ = [
    "NullRecorder", "Recorder", "get", "install", "recording",
    "read_jsonl", "to_jsonl_rows", "to_perfetto", "validate_rows",
    "write_jsonl", "write_perfetto",
    "summarize", "format_report",
]
