"""CLI: ``python -m repro.obs <command> recording.jsonl``.

``report``    summarize a recording (phase breakdown, warm-up share,
              top-k slowest peers, staleness distribution).
``validate``  schema-check a recording; exit 1 on violations.
``perfetto``  convert a recording to chrome-tracing JSON for
              https://ui.perfetto.dev.
"""
from __future__ import annotations

import argparse
import json
import sys

from .export import read_jsonl, validate_rows, write_perfetto
from .report import format_report, summarize


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("report", help="summarize a JSONL recording")
    p.add_argument("path")
    p.add_argument("--top", type=int, default=5,
                   help="slowest peers to list (default 5)")
    p.add_argument("--json", action="store_true",
                   help="emit the summary as JSON instead of text")

    p = sub.add_parser("validate", help="schema-check a recording")
    p.add_argument("path")

    p = sub.add_parser("perfetto",
                       help="convert a recording to chrome-tracing JSON")
    p.add_argument("path")
    p.add_argument("out", help="output trace path (.json)")

    args = ap.parse_args(argv)
    rows = read_jsonl(args.path)
    if args.cmd == "validate":
        errs = validate_rows(rows)
        for e in errs:
            sys.stderr.write(e + "\n")
        sys.stdout.write(f"{len(rows)} rows, "
                         f"{len(errs)} violation(s)\n")
        return 1 if errs else 0
    if args.cmd == "perfetto":
        n = write_perfetto(rows, args.out)
        sys.stdout.write(f"wrote {n} trace events -> {args.out}\n")
        return 0
    summary = summarize(rows, top_k=args.top)
    if args.json:
        sys.stdout.write(json.dumps(summary, indent=2, default=str)
                         + "\n")
    else:
        sys.stdout.write(format_report(summary) + "\n")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
