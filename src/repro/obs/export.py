"""Exporters: JSONL event log and Perfetto/chrome-tracing timeline.

The canonical on-disk form is JSONL — one JSON object per line, first
line a ``header`` row carrying the schema version and recorder meta,
then the recorded rows in sequence order, then one ``metric`` row per
registry entry (sorted by name).  ``validate_rows`` is a pure-python
schema check (no external jsonschema dependency) used by the tests and
the bench smoke gate.

``to_perfetto`` renders a recording as chrome-tracing JSON — load it at
https://ui.perfetto.dev (or chrome://tracing): pid 0 carries the round
phase spans, pid 1 one track per sending peer (warm-up vs BT vs carried
background vs spray flows, colored by category), pid 2 the tracker
control plane, with async merge/cut instants on the phase track.
"""
from __future__ import annotations

import json

import numpy as np

SCHEMA_VERSION = 1

_KINDS = ("header", "span", "event", "flows", "metric")
_FLOW_COLS = ("src", "dst", "t_start", "t_end")


def _jsonable(v):
    """Recursively convert numpy scalars/arrays for json.dumps."""
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, np.generic):
        return v.item()
    if isinstance(v, dict):
        return {k: _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return v


def to_jsonl_rows(rec) -> list[dict]:
    """Materialize a recorder as JSON-safe rows: header, events in
    sequence order, then the metrics registry (sorted by name)."""
    rows = [{"kind": "header", "version": SCHEMA_VERSION,
             "meta": _jsonable(rec.meta)}]
    rows.extend(_jsonable(r) for r in rec.rows)
    for name in sorted(rec.metrics):
        m = rec.metrics[name]
        rows.append({"kind": "metric", "name": name,
                     **_jsonable(m)})
    return rows


def write_jsonl(rec_or_rows, path) -> int:
    """Write a recorder (or pre-materialized rows) as JSONL; returns
    the row count."""
    rows = (rec_or_rows if isinstance(rec_or_rows, list)
            else to_jsonl_rows(rec_or_rows))
    with open(path, "w") as fh:
        for r in rows:
            fh.write(json.dumps(r) + "\n")
    return len(rows)


def read_jsonl(path) -> list[dict]:
    with open(path) as fh:
        return [json.loads(line) for line in fh if line.strip()]


# -- schema validation ---------------------------------------------------
def _num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def _numlist(v) -> bool:
    return isinstance(v, list) and all(_num(x) for x in v)


def validate_rows(rows: list[dict]) -> list[str]:
    """Schema-check materialized rows; returns a list of violation
    strings (empty == valid)."""
    errs: list[str] = []

    def bad(i, msg):
        errs.append(f"row {i}: {msg}")

    if not rows:
        return ["empty recording (no header row)"]
    if rows[0].get("kind") != "header":
        errs.append("row 0: first row must be the header")
    for i, r in enumerate(rows):
        if not isinstance(r, dict):
            bad(i, "not an object")
            continue
        kind = r.get("kind")
        if kind not in _KINDS:
            bad(i, f"unknown kind {kind!r}")
            continue
        if kind == "header":
            if i != 0:
                bad(i, "header row not first")
            if not isinstance(r.get("version"), int):
                bad(i, "header.version must be an int")
            if not isinstance(r.get("meta", {}), dict):
                bad(i, "header.meta must be an object")
            continue
        if kind != "metric" and not isinstance(r.get("seq"), int):
            bad(i, f"{kind} row missing int seq")
        name = r.get("name")
        if kind != "flows" and not isinstance(name, str):
            bad(i, f"{kind} row missing str name")
        if kind == "span":
            has_t = ("t0" in r) or ("t1" in r)
            if has_t and not (_num(r.get("t0")) and _num(r.get("t1"))):
                bad(i, "span t0/t1 must both be numbers")
            elif has_t and r["t1"] < r["t0"]:
                bad(i, f"span {name!r}: t1 < t0")
            if "wall_s" in r and not _num(r["wall_s"]):
                bad(i, "span wall_s must be a number")
            if not has_t and "wall_s" not in r:
                bad(i, f"span {name!r} has neither t0/t1 nor wall_s")
        elif kind == "event":
            if "t" in r and not _num(r["t"]):
                bad(i, "event t must be a number")
        elif kind == "flows":
            if not isinstance(r.get("track"), str):
                bad(i, "flows row missing str track")
            n = r.get("n")
            cols = {k: r.get(k) for k in _FLOW_COLS}
            if any(not isinstance(c, list) for c in cols.values()):
                bad(i, "flows src/dst/t_start/t_end must be lists")
                continue
            if not isinstance(n, int) or any(len(c) != n
                                             for c in cols.values()):
                bad(i, "flows columns must align with n")
                continue
            if any(e < s for s, e in zip(cols["t_start"], cols["t_end"])
                   if _num(s) and _num(e)):
                bad(i, "flows t_end < t_start")
        elif kind == "metric":
            mt = r.get("metric")
            if mt not in ("counter", "gauge", "hist"):
                bad(i, f"unknown metric type {mt!r}")
            elif mt == "hist":
                if not _numlist(r.get("values")):
                    bad(i, "hist values must be a number list")
            elif not _num(r.get("value")):
                bad(i, f"{mt} value must be a number")
    return errs


# -- Perfetto / chrome-tracing -------------------------------------------
_PID_PHASES = 0
_PID_PEERS = 1
_PID_TRACKER = 2

_PROC_NAMES = {_PID_PHASES: "round phases",
               _PID_PEERS: "peers (sender tracks)",
               _PID_TRACKER: "tracker control plane"}


def _us(t: float) -> float:
    return float(t) * 1e6


def to_perfetto(rows: list[dict]) -> dict:
    """Render materialized rows as a chrome-tracing JSON object.

    Only rows with simulated-time anchors land on the timeline: spans
    with ``t0``/``t1``, events with ``t``, flow batches, and tracker
    cycles (rendered as control-plane slices of their ``cost_s``).
    """
    ev: list[dict] = []
    for pid, pname in _PROC_NAMES.items():
        ev.append({"ph": "M", "pid": pid, "name": "process_name",
                   "args": {"name": pname}})
    ev.append({"ph": "M", "pid": _PID_PHASES, "tid": 0,
               "name": "thread_name", "args": {"name": "phases"}})
    seen_tids: set[int] = set()
    for r in rows:
        kind = r.get("kind")
        if kind == "span" and "t0" in r and "t1" in r:
            args = {k: v for k, v in r.items()
                    if k not in ("kind", "name", "t0", "t1", "seq")}
            ev.append({"name": r["name"], "ph": "X", "cat": "phase",
                       "pid": _PID_PHASES, "tid": 0,
                       "ts": _us(r["t0"]),
                       "dur": max(_us(r["t1"] - r["t0"]), 0.0),
                       "args": args})
        elif kind == "event" and "t" in r:
            name = r["name"]
            if name.startswith("tracker."):
                cost = r.get("cost_s", 0.0)
                ev.append({"name": name, "ph": "X", "cat": "control",
                           "pid": _PID_TRACKER, "tid": 0,
                           "ts": _us(r["t"]),
                           "dur": max(_us(cost), 0.0),
                           "args": {k: v for k, v in r.items()
                                    if k not in ("kind", "name", "t",
                                                 "seq")}})
            else:
                ev.append({"name": name, "ph": "i", "s": "g",
                           "cat": "event", "pid": _PID_PHASES, "tid": 0,
                           "ts": _us(r["t"]),
                           "args": {k: v for k, v in r.items()
                                    if k not in ("kind", "name", "t",
                                                 "seq")}})
        elif kind == "flows":
            track = r.get("track", "fg")
            rnd = r.get("round")
            for j in range(r["n"]):
                s, e = r["t_start"][j], r["t_end"][j]
                if not (_num(s) and _num(e)) or e < s:
                    continue
                src, dst = r["src"][j], r["dst"][j]
                args = {"dst": dst}
                if rnd is not None:
                    args["round"] = rnd
                ev.append({"name": f"{track} {src}->{dst}", "ph": "X",
                           "cat": track, "pid": _PID_PEERS,
                           "tid": int(src), "ts": _us(s),
                           "dur": max(_us(e - s), 0.0), "args": args})
                seen_tids.add(int(src))
    for tid in sorted(seen_tids):
        ev.append({"ph": "M", "pid": _PID_PEERS, "tid": tid,
                   "name": "thread_name",
                   "args": {"name": f"peer {tid}"}})
    return {"traceEvents": ev, "displayTimeUnit": "ms",
            "otherData": {"exporter": "repro.obs",
                          "schema_version": SCHEMA_VERSION}}


def write_perfetto(rows_or_rec, path) -> int:
    """Write the Perfetto trace JSON; returns the traceEvents count."""
    rows = (rows_or_rec if isinstance(rows_or_rec, list)
            else to_jsonl_rows(rows_or_rec))
    trace = to_perfetto(rows)
    with open(path, "w") as fh:
        json.dump(trace, fh)
    return len(trace["traceEvents"])
