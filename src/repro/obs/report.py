"""Recording summarizer behind ``python -m repro.obs report``.

Everything here is computed FROM THE RECORDING ALONE — no simulator
state: per-round phase breakdown (warm-up share, spray, BT, control
plane), top-k slowest peers (last flow finish / busy seconds per
sending peer), and the async staleness distribution.  The acceptance
check is that the per-round numbers reproduce
``RoundMetrics.t_warm_s`` / ``t_round_s`` / ``warmup_share_s``.
"""
from __future__ import annotations

from collections import defaultdict


def _spans(rows, name):
    return [r for r in rows if r.get("kind") == "span"
            and r.get("name") == name and "t0" in r]


def summarize(rows: list[dict], top_k: int = 5) -> dict:
    """Digest materialized rows into a report dict."""
    meta = rows[0].get("meta", {}) if rows and \
        rows[0].get("kind") == "header" else {}

    # Per-round phase spans (round attr defaults to 0 for bare
    # single-round recordings outside a session).
    rounds: dict[int, dict] = {}
    for name in ("round.spray", "round.warmup", "round.bt",
                 "round.total"):
        for sp in _spans(rows, name):
            r = int(sp.get("round", 0))
            rounds.setdefault(r, {})[name] = sp
    per_round = {}
    for r, sps in sorted(rounds.items()):
        tot = sps.get("round.total")
        warm = sps.get("round.warmup")
        if tot is None:
            continue
        base = tot["t0"]
        t_round_s = tot["t1"] - base
        t_warm_s = (warm["t1"] - base) if warm is not None else 0.0
        spray = sps.get("round.spray")
        per_round[r] = {
            "t_warm_s": t_warm_s,
            "t_round_s": t_round_s,
            "t_spray_s": (spray["t1"] - base) if spray else 0.0,
            "warmup_share_s": (t_warm_s / t_round_s) if t_round_s
            else 0.0,
        }

    # Phase breakdown: total simulated seconds and (when measured)
    # host wall seconds per span name.
    phases: dict[str, dict] = {}
    for r in rows:
        if r.get("kind") != "span":
            continue
        ph = phases.setdefault(r["name"], {"count": 0, "sim_s": 0.0,
                                           "wall_s": 0.0})
        ph["count"] += 1
        if "t0" in r:
            ph["sim_s"] += r["t1"] - r["t0"]
        if "wall_s" in r:
            ph["wall_s"] += r["wall_s"]

    # Per-sender activity from the flow batches.
    busy = defaultdict(float)
    last_fin = defaultdict(float)
    n_flows = defaultdict(int)
    for r in rows:
        if r.get("kind") != "flows":
            continue
        for j in range(r["n"]):
            s, e = r["t_start"][j], r["t_end"][j]
            if e < s:
                continue
            p = int(r["src"][j])
            busy[p] += e - s
            last_fin[p] = max(last_fin[p], e)
            n_flows[p] += 1
    slowest = sorted(last_fin, key=lambda p: (-last_fin[p], p))[:top_k]
    top = [{"peer": p, "last_finish_s": last_fin[p],
            "busy_s": busy[p], "n_flows": n_flows[p]}
           for p in slowest]

    # Metrics registry.
    metrics = {r["name"]: r for r in rows if r.get("kind") == "metric"}
    control_s = metrics.get("tracker.control_s", {}).get("value", 0.0)
    stale = metrics.get("async.staleness", {}).get("values", [])
    stale_dist: dict[int, int] = {}
    for v in stale:
        stale_dist[int(v)] = stale_dist.get(int(v), 0) + 1

    totals = {
        "t_round_s": sum(v["t_round_s"] for v in per_round.values()),
        "t_warm_s": sum(v["t_warm_s"] for v in per_round.values()),
        "control_s": control_s,
    }
    totals["warmup_share_s"] = (totals["t_warm_s"] / totals["t_round_s"]
                                if totals["t_round_s"] else 0.0)
    return {
        "meta": meta,
        "n_rows": len(rows),
        "rounds": per_round,
        "totals": totals,
        "phases": phases,
        "slowest_peers": top,
        "staleness": stale_dist,
        "counters": {k: v.get("value") for k, v in metrics.items()
                     if v.get("metric") == "counter"},
        "gauges": {k: v.get("value") for k, v in metrics.items()
                   if v.get("metric") == "gauge"},
    }


def format_report(summary: dict) -> str:
    """Human-readable rendering of :func:`summarize`'s dict."""
    out = []
    t = summary["totals"]
    out.append(f"recording: {summary['n_rows']} rows, "
               f"{len(summary['rounds'])} round(s)")
    if summary["meta"]:
        out.append(f"meta: {summary['meta']}")
    out.append(f"total: t_round_s={t['t_round_s']:.3f}  "
               f"t_warm_s={t['t_warm_s']:.3f}  "
               f"warmup_share={t['warmup_share_s']:.3f}  "
               f"control_s={t['control_s']:.3f}")
    for r, v in summary["rounds"].items():
        out.append(f"  round {r}: t_warm_s={v['t_warm_s']:.3f}  "
                   f"t_round_s={v['t_round_s']:.3f}  "
                   f"share={v['warmup_share_s']:.3f}  "
                   f"spray_s={v['t_spray_s']:.3f}")
    if summary["phases"]:
        out.append("phase breakdown (simulated / host wall):")
        for name, ph in sorted(summary["phases"].items()):
            out.append(f"  {name:<24} x{ph['count']:<5} "
                       f"sim={ph['sim_s']:.3f}s wall={ph['wall_s']:.4f}s")
    if summary["slowest_peers"]:
        out.append("slowest peers (by last flow finish):")
        for e in summary["slowest_peers"]:
            out.append(f"  peer {e['peer']:<5} "
                       f"last_finish={e['last_finish_s']:.3f}s "
                       f"busy={e['busy_s']:.3f}s flows={e['n_flows']}")
    if summary["staleness"]:
        dist = ", ".join(f"{k}: {v}" for k, v in
                         sorted(summary["staleness"].items()))
        out.append(f"staleness distribution: {{{dist}}}")
    if summary["counters"]:
        out.append("counters:")
        for k, v in sorted(summary["counters"].items()):
            out.append(f"  {k} = {v:g}")
    return "\n".join(out)
