"""Deterministic synthetic classification datasets.

The container is offline, so MNIST/CIFAR-10 are replaced by seeded
synthetic datasets with the same interface (images in [0,1], integer
labels).  Classes are anisotropic Gaussian clusters around class
prototypes plus structured per-class frequency patterns, which gives a
learnable-but-not-trivial problem whose accuracy ordering under
heterogeneity mirrors the paper's Table II comparison (CFL vs GossipDFL
vs FLTorrent).  See DESIGN.md §7 (deviations).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class Dataset:
    x: np.ndarray       # (N, H, W, C) float32 in [0,1]
    y: np.ndarray       # (N,) int32
    num_classes: int

    def __len__(self):
        return len(self.y)


def make_synthetic(
    name: str = "synth-mnist",
    n_train: int = 20000,
    n_test: int = 4000,
    seed: int = 0,
) -> tuple[Dataset, Dataset]:
    """Build (train, test) splits.  Shapes mirror the stand-in dataset:
    synth-mnist -> 28x28x1 / 10 classes; synth-cifar -> 32x32x3 / 10."""
    if name == "synth-mnist":
        h, w, c, ncls, noise = 28, 28, 1, 10, 0.25
    elif name == "synth-cifar":
        h, w, c, ncls, noise = 32, 32, 3, 10, 0.45
    else:
        raise ValueError(name)
    rng = np.random.default_rng(seed)
    # Class prototypes: low-frequency patterns (distinct spatial modes).
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
    protos = np.zeros((ncls, h, w, c), np.float32)
    for k in range(ncls):
        fx, fy = 1 + (k % 3), 1 + (k // 3)
        base = 0.5 + 0.5 * np.sin(2 * np.pi * (fx * xx / w + fy * yy / h)
                                  + k * 0.7)
        for ch in range(c):
            protos[k, :, :, ch] = np.roll(base, ch * 3, axis=1)
    protos += 0.15 * rng.standard_normal(protos.shape).astype(np.float32)

    def split(n):
        y = rng.integers(0, ncls, size=n).astype(np.int32)
        x = protos[y] + noise * rng.standard_normal(
            (n, h, w, c)).astype(np.float32)
        return Dataset(np.clip(x, 0, 1).astype(np.float32), y, ncls)

    return split(n_train), split(n_test)
