"""Synthetic token pipeline for LM training/serving drivers.

Deterministic Zipf-distributed token streams with simple bigram
structure (so the loss is learnable), shardable across data-parallel
hosts.  Matches the interface a real pipeline would expose: an iterator
of {tokens, targets} batches plus ``input_specs``-compatible shapes.
"""
from __future__ import annotations

import numpy as np


class TokenStream:
    def __init__(self, vocab_size: int, batch: int, seq_len: int,
                 seed: int = 0, shard: tuple[int, int] = (0, 1)):
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq_len
        self.shard_id, self.num_shards = shard
        self._rng = np.random.default_rng((seed, self.shard_id))
        # Zipf-ish unigram distribution over a capped effective vocab.
        eff = min(vocab_size, 50_000)
        ranks = np.arange(1, eff + 1, dtype=np.float64)
        p = 1.0 / ranks ** 1.1
        self._p = p / p.sum()
        self._eff = eff

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        b = self.batch // self.num_shards
        base = self._rng.choice(self._eff, size=(b, self.seq + 1),
                                p=self._p).astype(np.int32)
        # Bigram structure: with prob .5 next token = f(prev).
        nxt = (base[:, :-1] * 31 + 7) % self._eff
        mix = self._rng.random((b, self.seq)) < 0.5
        tokens = base[:, :-1]
        targets = np.where(mix, nxt, base[:, 1:]).astype(np.int32)
        return {"tokens": tokens, "targets": targets}


def batches(vocab_size: int, batch: int, seq_len: int, steps: int,
            seed: int = 0):
    it = TokenStream(vocab_size, batch, seq_len, seed)
    for _ in range(steps):
        yield next(it)
