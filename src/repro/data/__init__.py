from . import partition, synthetic, tokens
