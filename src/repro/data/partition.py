"""Client data partitioning for FL (paper §V-B).

Non-IID partitions follow a Dirichlet sampler with concentration
``alpha`` (smaller alpha => stronger heterogeneity), the standard FL
benchmark protocol; IID is uniform random splitting.
"""
from __future__ import annotations

import numpy as np

from .synthetic import Dataset


def iid_partition(ds: Dataset, n_clients: int, rng: np.random.Generator
                  ) -> list[np.ndarray]:
    idx = rng.permutation(len(ds))
    return [np.sort(s) for s in np.array_split(idx, n_clients)]


def dirichlet_partition(ds: Dataset, n_clients: int, alpha: float,
                        rng: np.random.Generator,
                        min_size: int = 2) -> list[np.ndarray]:
    """Label-distribution-skew partition: p_k ~ Dir(alpha) per class."""
    for _ in range(100):
        parts: list[list[int]] = [[] for _ in range(n_clients)]
        for k in range(ds.num_classes):
            kidx = np.flatnonzero(ds.y == k)
            rng.shuffle(kidx)
            props = rng.dirichlet(np.full(n_clients, alpha))
            cuts = (np.cumsum(props) * len(kidx)).astype(int)[:-1]
            for i, sl in enumerate(np.split(kidx, cuts)):
                parts[i].extend(sl.tolist())
        sizes = [len(p) for p in parts]
        if min(sizes) >= min_size:
            return [np.sort(np.asarray(p)) for p in parts]
    raise RuntimeError("dirichlet partition failed to satisfy min_size")


def partition(ds: Dataset, n_clients: int, dist: str,
              seed: int = 0) -> list[np.ndarray]:
    """dist in {"iid", "dir0.1", "dir0.5", "dir1.0", ...}."""
    rng = np.random.default_rng(seed)
    if dist == "iid":
        return iid_partition(ds, n_clients, rng)
    if dist.startswith("dir"):
        return dirichlet_partition(ds, n_clients, float(dist[3:]), rng)
    raise ValueError(dist)
