"""Small pure-JAX models for the FL learning-utility experiments.

The paper trains GoogLeNet-scale CNNs on MNIST/CIFAR-10; for the
synthetic stand-ins a compact CNN and MLP suffice to reproduce the
*comparison* (CFL vs GossipDFL vs FLTorrent) — the dissemination layer
is model-agnostic by construction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _dense_init(rng, fan_in, fan_out):
    k1, rng = jax.random.split(rng)
    w = jax.random.normal(k1, (fan_in, fan_out)) * np.sqrt(2.0 / fan_in)
    return {"w": w.astype(jnp.float32),
            "b": jnp.zeros((fan_out,), jnp.float32)}, rng


def init_cnn(rng, input_shape, num_classes: int):
    """3-block CNN: conv3x3(32) - conv3x3(64) - pool - dense."""
    h, w, c = input_shape
    params = {}
    k1, k2, rng = jax.random.split(rng, 3)
    params["conv1"] = {
        "w": (jax.random.normal(k1, (3, 3, c, 32)) * np.sqrt(2 / (9 * c))
              ).astype(jnp.float32),
        "b": jnp.zeros((32,), jnp.float32)}
    params["conv2"] = {
        "w": (jax.random.normal(k2, (3, 3, 32, 64)) * np.sqrt(2 / (9 * 32))
              ).astype(jnp.float32),
        "b": jnp.zeros((64,), jnp.float32)}
    flat = (h // 4) * (w // 4) * 64
    params["fc1"], rng = _dense_init(rng, flat, 128)
    params["fc2"], rng = _dense_init(rng, 128, num_classes)
    return params


def cnn_apply(params, x):
    def conv(p, x, stride):
        y = jax.lax.conv_general_dilated(
            x, p["w"], window_strides=(stride, stride), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return jax.nn.relu(y + p["b"])

    x = conv(params["conv1"], x, 2)
    x = conv(params["conv2"], x, 2)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return x @ params["fc2"]["w"] + params["fc2"]["b"]


def init_mlp(rng, input_shape, num_classes: int):
    d = int(np.prod(input_shape))
    params = {}
    params["fc1"], rng = _dense_init(rng, d, 256)
    params["fc2"], rng = _dense_init(rng, 256, 128)
    params["fc3"], rng = _dense_init(rng, 128, num_classes)
    return params


def mlp_apply(params, x):
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    x = jax.nn.relu(x @ params["fc2"]["w"] + params["fc2"]["b"])
    return x @ params["fc3"]["w"] + params["fc3"]["b"]


MODELS = {"cnn": (init_cnn, cnn_apply), "mlp": (init_mlp, mlp_apply)}


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def accuracy(apply_fn, params, x, y, batch: int = 512) -> float:
    correct = 0
    for i in range(0, len(y), batch):
        logits = apply_fn(params, jnp.asarray(x[i:i + batch]))
        correct += int((jnp.argmax(logits, -1) == jnp.asarray(y[i:i + batch])).sum())
    return correct / len(y)
