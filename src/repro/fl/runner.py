"""FL experiment runner: CFL vs GossipDFL vs FLTorrent (paper §V-B).

FLTorrent rounds run the *real* dissemination pipeline on a persistent
:class:`~repro.core.session.SwarmSession`: local updates are chunked at
256 KiB granularity, a full spray/warm-up/BT round is simulated over the
session's overlay and broadband capacities, and each client FedAvgs over
its own reconstructable set.  With deadlines set generously (the paper's
learning setup) all updates reconstruct and all clients agree — asserted
at runtime.

Partial participation (§III-E): with ``churn_rate > 0`` clients leave at
round boundaries and rejoin ``rejoin_after`` rounds later.  A client
absent in round r holds *stale* params; at its rejoin boundary it
re-downloads the current model before training (never trains from the
stale base).  Clients that drop mid-round miss that round's aggregate
and catch up the same way.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ChurnAwareSpray, ChurnModel, SwarmConfig,
                        SwarmSession)
from repro.core.aggregation import fedavg_pytree, per_client_aggregates
from repro.core.chunking import chunk_count, flatten_update
from repro.data.partition import partition
from repro.data.synthetic import make_synthetic
from . import baselines
from .client import LocalSpec, apply_aggregate, compute_update, make_local_train
from .models_small import MODELS, accuracy


@dataclass
class FLConfig:
    dataset: str = "synth-mnist"
    model: str = "mlp"
    dist: str = "dir0.5"
    n_clients: int = 20
    rounds: int = 20
    local: LocalSpec = field(default_factory=LocalSpec)
    n_train: int = 8000
    n_test: int = 2000
    seed: int = 0
    min_degree: int = 5
    # FLTorrent dissemination knobs (defaults = paper defaults)
    swarm_overrides: dict = field(default_factory=dict)
    # Cross-round churn (§III-E): per-boundary Bernoulli leave
    # probability; leavers rejoin ``rejoin_after`` rounds later.  0 =
    # the historical full-participation loop, bit-identical.
    churn_rate: float = 0.0
    rejoin_after: int = 2
    # Rejoin-delay law: "fixed" (historical) or "geometric" (mean
    # rejoin_after, heterogeneous absences).
    rejoin_dist: str = "fixed"
    # Spray budgeting under churn: "full" re-sprays sigma fresh tunnels
    # per source every round (historical); "churn_aware" re-sprays only
    # coverage lost to churn (ChurnAwareSpray; needs churn_rate > 0).
    spray_budget: str = "full"


@dataclass
class FLResult:
    accuracy: list            # per-round test accuracy
    agreement: bool = True    # FLTorrent: all clients agreed every round
    reconstruct_frac: float = 1.0
    # Churn diagnostics (fltorrent with churn_rate > 0):
    participation: list | None = None  # per-round active fraction
    rejoin_rounds: list | None = None  # rounds where a client re-synced
    stale_seen: bool = False   # some catch-up client really held stale params
    caught_up: bool = True     # every active client trained from current params


def run_experiment(method: str, cfg: FLConfig) -> FLResult:
    """method in {"cfl", "gossip", "fltorrent"}."""
    train, test = make_synthetic(cfg.dataset, cfg.n_train, cfg.n_test,
                                 seed=cfg.seed)
    parts = partition(train, cfg.n_clients, cfg.dist, seed=cfg.seed)
    weights = np.array([len(p) for p in parts], np.float64)

    init_fn, apply_fn = MODELS[cfg.model]
    rng = jax.random.PRNGKey(cfg.seed)
    params0 = init_fn(rng, train.x.shape[1:], train.num_classes)
    local_train = make_local_train(apply_fn, cfg.local)
    nprng = np.random.default_rng(cfg.seed)

    accs: list[float] = []
    agreement = True
    recon_fracs: list[float] = []

    if method == "cfl":
        params = params0
        for r in range(cfg.rounds):
            updates = []
            for v in range(cfg.n_clients):
                out = local_train(params, train.x[parts[v]],
                                  train.y[parts[v]], nprng)
                updates.append(compute_update(params, out))
            agg = baselines.fedavg_server(updates, weights)
            params = apply_aggregate(params, agg)
            accs.append(accuracy(apply_fn, params, test.x, test.y))
        return FLResult(accs)

    if method == "gossip":
        client_params = [params0 for _ in range(cfg.n_clients)]
        from repro.core.overlay import random_overlay
        for r in range(cfg.rounds):
            outs = []
            for v in range(cfg.n_clients):
                outs.append(local_train(client_params[v], train.x[parts[v]],
                                        train.y[parts[v]], nprng))
            adj = random_overlay(cfg.n_clients, cfg.min_degree,
                                 rng=np.random.default_rng((cfg.seed, r)))
            w = baselines.metropolis_weights(adj)
            client_params = baselines.gossip_mix(outs, w)
            # Evaluate what clients actually hold: each its own
            # partially-mixed model (see baselines.gossip_eval for why
            # the mean-model metric is a phantom exact FedAvg).
            accs.append(baselines.gossip_eval(
                apply_fn, client_params, test.x, test.y))
        return FLResult(accs)

    if method == "fltorrent":
        params = params0   # current global model (active clients agree)
        flat0, _ = flatten_update(params0)
        upd_bytes = flat0.size * 4
        k_chunks = max(2, chunk_count(upd_bytes, 256 * 1024))
        scfg = SwarmConfig(
            n=cfg.n_clients, chunks_per_update=k_chunks,
            min_degree=cfg.min_degree, seed=cfg.seed,
            **cfg.swarm_overrides)
        # Persistent swarm: the session carries population, overlay and
        # capacities across rounds; round_seed keeps the historical
        # seed*1000+r per-round streams, so churn_rate=0 reproduces the
        # old per-round simulate_round loop bit-identically.
        if cfg.spray_budget not in ("full", "churn_aware"):
            raise ValueError(f"unknown spray_budget {cfg.spray_budget!r}")
        session = SwarmSession(
            scfg,
            churn=ChurnModel(leave_prob=cfg.churn_rate, join_rate=0.0,
                             rejoin_after=cfg.rejoin_after,
                             rejoin_dist=cfg.rejoin_dist),
            spray_policy=(ChurnAwareSpray()
                          if cfg.spray_budget == "churn_aware" else None))
        # Per-client held model: a reference to some past global params.
        # Clients absent in a round keep a stale reference and re-sync
        # at their rejoin boundary.
        client_params = [params0] * cfg.n_clients
        in_sync = np.ones(cfg.n_clients, dtype=bool)
        participation: list[float] = []
        rejoin_rounds: list[int] = []
        stale_seen = False
        caught_up = True
        for r in range(cfg.rounds):
            ids = session.begin_round()
            # Rejoin-at-round-boundary (§III-E): a returning client
            # re-downloads the CURRENT model before training.
            catchup = ids[~in_sync[ids]]
            if catchup.size:
                cur, _ = flatten_update(params)
            for v in catchup:
                held, _ = flatten_update(client_params[v])
                stale_seen |= not bool(jnp.array_equal(held, cur))
                client_params[v] = params
                in_sync[v] = True
                rejoin_rounds.append(r)
            participation.append(ids.size / cfg.n_clients)
            updates = []
            for v in ids:
                caught_up &= client_params[v] is params
                out = local_train(params, train.x[parts[v]],
                                  train.y[parts[v]], nprng)
                updates.append(compute_update(params, out))
            # Real dissemination round at the true chunk count over the
            # active sub-swarm (local index i <-> global client ids[i]).
            rec = session.run_round()
            res = rec.result
            recon = res.reconstructable           # (n_act, n_act) bool
            recon_fracs.append(float(recon.mean()))
            w_act = weights[ids]
            surv = np.flatnonzero(res.active)
            ref = int(surv[0]) if surv.size else 0
            # Every client aggregates over its own A_v^r.  In the common
            # full-dissemination case every row of ``recon`` is the same
            # set, so all n aggregates are *definitionally* identical:
            # compute the FedAvg once instead of n pytree reductions.
            if not bool((recon == recon[ref]).all()):
                # Rows differ: verify agreement on the flat vectors with
                # ONE (n, n) x (n, D) matmul, not n pytree FedAvgs.
                flats = jnp.stack([flatten_update(u)[0] for u in updates])
                per_cl = per_client_aggregates(flats, w_act, recon)
                if not bool(jnp.allclose(per_cl[surv], per_cl[ref][None],
                                         atol=1e-6)):
                    agreement = False
            agg = fedavg_pytree(updates, w_act, recon[ref])
            params = apply_aggregate(params, agg)
            # Clients active at the deadline applied this aggregate;
            # everyone else (absent or dropped mid-round) is now stale.
            in_sync[:] = False
            got = ids[res.active]
            for v in got:
                client_params[v] = params
            in_sync[got] = True
            accs.append(accuracy(apply_fn, params, test.x, test.y))
        return FLResult(accs, agreement=agreement,
                        reconstruct_frac=float(np.mean(recon_fracs)),
                        participation=participation,
                        rejoin_rounds=rejoin_rounds,
                        stale_seen=stale_seen, caught_up=caught_up)

    raise ValueError(method)
