"""FL experiment runner: CFL vs GossipDFL vs FLTorrent (paper §V-B).

FLTorrent rounds run the *real* dissemination pipeline: local updates
are chunked at 256 KiB granularity, a full spray/warm-up/BT round is
simulated over the sampled overlay and broadband capacities, and each
client FedAvgs over its own reconstructable set.  With deadlines set
generously (the paper's learning setup) all updates reconstruct and all
clients agree — asserted at runtime.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SwarmConfig, simulate_round
from repro.core.aggregation import fedavg_pytree
from repro.core.chunking import chunk_count, flatten_update
from repro.data.partition import partition
from repro.data.synthetic import make_synthetic
from . import baselines
from .client import LocalSpec, apply_aggregate, compute_update, make_local_train
from .models_small import MODELS, accuracy


@dataclass
class FLConfig:
    dataset: str = "synth-mnist"
    model: str = "mlp"
    dist: str = "dir0.5"
    n_clients: int = 20
    rounds: int = 20
    local: LocalSpec = field(default_factory=LocalSpec)
    n_train: int = 8000
    n_test: int = 2000
    seed: int = 0
    min_degree: int = 5
    # FLTorrent dissemination knobs (defaults = paper defaults)
    swarm_overrides: dict = field(default_factory=dict)


@dataclass
class FLResult:
    accuracy: list            # per-round test accuracy
    agreement: bool = True    # FLTorrent: all clients agreed every round
    reconstruct_frac: float = 1.0


def run_experiment(method: str, cfg: FLConfig) -> FLResult:
    """method in {"cfl", "gossip", "fltorrent"}."""
    train, test = make_synthetic(cfg.dataset, cfg.n_train, cfg.n_test,
                                 seed=cfg.seed)
    parts = partition(train, cfg.n_clients, cfg.dist, seed=cfg.seed)
    weights = np.array([len(p) for p in parts], np.float64)

    init_fn, apply_fn = MODELS[cfg.model]
    rng = jax.random.PRNGKey(cfg.seed)
    params0 = init_fn(rng, train.x.shape[1:], train.num_classes)
    local_train = make_local_train(apply_fn, cfg.local)
    nprng = np.random.default_rng(cfg.seed)

    accs: list[float] = []
    agreement = True
    recon_fracs: list[float] = []

    if method == "cfl":
        params = params0
        for r in range(cfg.rounds):
            updates = []
            for v in range(cfg.n_clients):
                out = local_train(params, train.x[parts[v]],
                                  train.y[parts[v]], nprng)
                updates.append(compute_update(params, out))
            agg = baselines.fedavg_server(updates, weights)
            params = apply_aggregate(params, agg)
            accs.append(accuracy(apply_fn, params, test.x, test.y))
        return FLResult(accs)

    if method == "gossip":
        client_params = [params0 for _ in range(cfg.n_clients)]
        from repro.core.overlay import random_overlay
        for r in range(cfg.rounds):
            outs = []
            for v in range(cfg.n_clients):
                outs.append(local_train(client_params[v], train.x[parts[v]],
                                        train.y[parts[v]], nprng))
            adj = random_overlay(cfg.n_clients, cfg.min_degree,
                                 rng=np.random.default_rng((cfg.seed, r)))
            w = baselines.metropolis_weights(adj)
            client_params = baselines.gossip_mix(outs, w)
            # Evaluate what clients actually hold: each its own
            # partially-mixed model (see baselines.gossip_eval for why
            # the mean-model metric is a phantom exact FedAvg).
            accs.append(baselines.gossip_eval(
                apply_fn, client_params, test.x, test.y))
        return FLResult(accs)

    if method == "fltorrent":
        params = params0   # all clients agree each round (checked)
        flat0, _ = flatten_update(params0)
        upd_bytes = flat0.size * 4
        k_chunks = max(2, chunk_count(upd_bytes, 256 * 1024))
        for r in range(cfg.rounds):
            updates = []
            for v in range(cfg.n_clients):
                out = local_train(params, train.x[parts[v]],
                                  train.y[parts[v]], nprng)
                updates.append(compute_update(params, out))
            # Real dissemination round at the true chunk count.
            scfg = SwarmConfig(
                n=cfg.n_clients, chunks_per_update=k_chunks,
                min_degree=cfg.min_degree, seed=cfg.seed * 1000 + r,
                **cfg.swarm_overrides)
            res = simulate_round(scfg)
            recon = res.reconstructable           # (n, n) bool
            recon_fracs.append(float(recon.mean()))
            # Every client aggregates over its own A_v^r.
            aggs = []
            for v in range(cfg.n_clients):
                active = recon[v].astype(np.float32)
                aggs.append(fedavg_pytree(updates, weights, active))
            # Full dissemination => identical aggregates.
            ref_flat, _ = flatten_update(aggs[0])
            for a in aggs[1:]:
                fa, _ = flatten_update(a)
                if not bool(jnp.allclose(fa, ref_flat, atol=1e-6)):
                    agreement = False
            params = apply_aggregate(params, aggs[0])
            accs.append(accuracy(apply_fn, params, test.x, test.y))
        return FLResult(accs, agreement=agreement,
                        reconstruct_frac=float(np.mean(recon_fracs)))

    raise ValueError(method)
