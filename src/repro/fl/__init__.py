from . import asyncfl, baselines, client, models_small, runner
