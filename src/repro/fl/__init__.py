from . import baselines, client, models_small, runner
