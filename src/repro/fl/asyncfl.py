"""Deadline-free asynchronous FL: buffered staleness-weighted merges.

The paper's aggregation semantics are synchronous — FedAvg over the
updates reconstructable by a global round deadline, stragglers masked
out.  This runner removes the deadline FedBuff-style while keeping the
entire FLTorrent dissemination stack (spray, cover-set-gated warm-up,
BT swarming) underneath:

* every peer buffers updates as they become **swarm-complete** (held in
  full by every active peer — the P2P analogue of the server buffer),
  and merges once ``buffer_k`` of them are available (the quorum cut);
* stragglers are **down-weighted, not masked**: an update that misses
  the cut keeps disseminating and enters a later merge with weight
  ``w_u * (1 + s)^(-staleness_alpha)`` where ``s`` is its staleness in
  rounds (FedBuff/FedAsync-style polynomial decay);
* with ``overlap=True`` the undelivered tail becomes *background flows*
  on the next round's event engine
  (``repro.net.EventEngine.set_background``): generation r's tail
  rides the same links as r+1's dissemination at STRICT lower
  priority, soaking only the residual capacity each foreground cycle
  leaves idle — the current generation's stamps are byte-identical
  with or without a carried tail, and partial chunk progress banks
  across cycle windows.  Each round boundary the session re-plans
  every tail row's sender to the least-finish-time active holder
  (``SwarmSession._map_backlog``) and orders the queue
  generation-first then owner-major, so whole updates complete at
  staleness 1 instead of every update trickling at staleness 2+.
  With ``overlap=False`` the tail drains serially at the round
  boundary (the ablation that isolates contention from buffering);
* ``max_staleness`` bounds the merge: updates older than the bound are
  dropped (masked), so ``max_staleness=0`` *is* the synchronous
  deadline — :func:`run_async_experiment` then reproduces
  ``run_experiment("fltorrent")`` seed-for-seed, byte-identical traces
  included (``tests/test_asyncfl.py``).

Sole-writer merge consistency: the quorum requires completeness at
EVERY active peer and late tails deliver to every active peer, so all
peers assemble identical buffers and the "serverless" merge is the same
pytree everywhere — no coordination beyond the tracker the protocol
already has.  Peers that drop mid-round miss the merge and re-sync
through the stale-catch-up path, exactly like the sync runner.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import (ChurnAwareSpray, ChurnModel, SwarmConfig,
                        SwarmSession)
from repro.core.aggregation import fedavg_pytree, per_client_aggregates
from repro.core.chunking import chunk_count, flatten_update
from repro.core.trace import TransferTrace
from repro.data.partition import partition
from repro.data.synthetic import make_synthetic
from .client import apply_aggregate, compute_update, make_local_train
from .models_small import MODELS, accuracy
from .runner import FLConfig


@dataclass(frozen=True)
class AsyncConfig:
    """Asynchrony knobs on top of :class:`~repro.fl.runner.FLConfig`.

    ``buffer_k``         FedBuff buffer size K: merge once this many
                         updates are buffered (swarm-complete fresh ones
                         plus late tail completions; clamped to the
                         active count).
    ``max_staleness``    staleness bound S: an update still undelivered
                         s > S rounds after its generation is dropped.
                         0 = the synchronous deadline (exact parity
                         mode).
    ``overlap``          carry the tail as background flows into the
                         next round (event engine only) instead of
                         draining it at the boundary.
    ``round_slots``      async round deadline: BT directive-cycle budget
                         per round.  Sync rounds run the barriered cycle
                         loop to full completion — under straggler links
                         every cycle idle-waits the slowest flow; the
                         deadline cuts that and the relay-replanned tail
                         (core/session.py) delivers the rest without a
                         barrier.  None = cut on quorum/completion only.
    ``staleness_alpha``  polynomial staleness decay exponent.  Note the
                         merge normalizes weights, so the decay only
                         shifts RELATIVE mass inside a mixed-staleness
                         buffer — a uniformly-stale buffer is undamped
                         (that is what ``server_lr`` is for).
    ``server_lr``        FedBuff server learning rate: scales the merged
                         aggregate before it is applied.  Async deltas
                         are computed one merge behind the params they
                         land on, so a fast-moving model overshoots at
                         1.0; 0.5 geometrically damps the oscillation.
    ``time_engine``      "slot" | "event" — forwarded to the session.
    ``net``              event-engine NetConfig.
    ``link_model``       capacity model override (None = the session
                         default, RESIDENTIAL); pass
                         ``capacities.RESIDENTIAL_STRAGGLER`` for the
                         straggler-heavy frontier regime.
    ``evolve_overlay``   force the session's persistent-population mode
                         (sticky per-peer capacities across rounds).
                         Carry mode wants True: the relay replanner
                         routes tail rows via least-*finish-time*
                         holders, which needs stable rates to steer
                         around persistent stragglers.  None = session
                         default (parity mode must leave this unset).
    """

    buffer_k: int = 0
    max_staleness: int = 0
    overlap: bool = False
    round_slots: int | None = None
    staleness_alpha: float = 0.5
    server_lr: float = 1.0
    time_engine: str = "slot"
    net: object = None
    link_model: object = None
    evolve_overlay: bool | None = None

    def __post_init__(self):
        if self.overlap and self.max_staleness == 0:
            raise ValueError("overlap needs max_staleness >= 1 "
                             "(a tail to overlap)")
        if self.overlap and self.time_engine != "event":
            raise ValueError("overlap is a flow-level notion: needs "
                             "time_engine='event'")
        if self.max_staleness > 0 and self.buffer_k < 1:
            raise ValueError("async merges need buffer_k >= 1")
        if self.round_slots is not None and self.max_staleness == 0:
            raise ValueError("round_slots is a deadline WITHOUT masking: "
                             "it needs the async tail (max_staleness "
                             ">= 1) to recover the cut updates")
        if self.round_slots is not None and self.round_slots < 1:
            raise ValueError("round_slots must be >= 1")
        if not 0.0 < self.server_lr <= 1.0:
            raise ValueError("server_lr must be in (0, 1]")
        if self.server_lr != 1.0 and self.max_staleness == 0:
            raise ValueError("server_lr damps ASYNC merges; parity mode "
                             "applies the sync aggregate verbatim")


@dataclass
class AsyncResult:
    accuracy: list                 # per-round test accuracy
    wall_s: list                   # cumulative wall clock per round end
    merged: list                   # updates merged per round
    stale_merged: list             # of which late (staleness > 0)
    staleness_hist: dict           # staleness -> merge count
    dropped: int = 0               # updates lost (stale bound / dead)
    buffer_end: int = 0            # updates buffered, never merged
    agreement: bool = True
    reconstruct_frac: float = 1.0
    participation: list | None = None
    session: SwarmSession | None = None


def run_async_experiment(cfg: FLConfig, acfg: AsyncConfig) -> AsyncResult:
    """FedBuff-style asynchronous FLTorrent (sync-exact when
    ``acfg.max_staleness == 0``: same rng streams, same jnp op order,
    byte-identical dissemination traces)."""
    train, test = make_synthetic(cfg.dataset, cfg.n_train, cfg.n_test,
                                 seed=cfg.seed)
    parts = partition(train, cfg.n_clients, cfg.dist, seed=cfg.seed)
    weights = np.array([len(p) for p in parts], np.float64)

    init_fn, apply_fn = MODELS[cfg.model]
    rng = jax.random.PRNGKey(cfg.seed)
    params0 = init_fn(rng, train.x.shape[1:], train.num_classes)
    local_train = make_local_train(apply_fn, cfg.local)
    nprng = np.random.default_rng(cfg.seed)

    params = params0
    flat0, _ = flatten_update(params0)
    k_chunks = max(2, chunk_count(flat0.size * 4, 256 * 1024))
    scfg = SwarmConfig(n=cfg.n_clients, chunks_per_update=k_chunks,
                       min_degree=cfg.min_degree, seed=cfg.seed,
                       **cfg.swarm_overrides)
    if cfg.spray_budget not in ("full", "churn_aware"):
        raise ValueError(f"unknown spray_budget {cfg.spray_budget!r}")
    session = SwarmSession(
        scfg,
        churn=ChurnModel(leave_prob=cfg.churn_rate, join_rate=0.0,
                         rejoin_after=cfg.rejoin_after,
                         rejoin_dist=cfg.rejoin_dist),
        spray_policy=(ChurnAwareSpray()
                      if cfg.spray_budget == "churn_aware" else None),
        time_engine=acfg.time_engine, net=acfg.net,
        **({} if acfg.link_model is None
           else {"link_model": acfg.link_model}),
        **({} if acfg.evolve_overlay is None
           else {"evolve_overlay": acfg.evolve_overlay}))

    sync_mode = acfg.max_staleness == 0
    tail_mode = ("none" if sync_mode
                 else ("carry" if acfg.overlap else "drain"))

    client_params = [params0] * cfg.n_clients
    in_sync = np.ones(cfg.n_clients, dtype=bool)
    accs: list[float] = []
    agreement = True
    recon_fracs: list[float] = []
    participation: list[float] = []
    merged: list[int] = []
    stale_merged: list[int] = []
    hist: dict[int, int] = {}
    dropped = 0
    # (gen, owner_gid) -> (update pytree, raw weight): updates past the
    # cut, still disseminating.  Insertion-ordered, deterministic.
    pending: dict[tuple[int, int], tuple] = {}
    queued_ready: list = []        # drain mode: ready for NEXT merge
    # FedBuff buffer: (gen, update, weight) triples swarm-complete at
    # every peer, merged together once >= buffer_k are available.
    buffer: list[tuple] = []

    for r in range(cfg.rounds):
        ids = session.begin_round()
        # Rejoin-at-round-boundary: a returning client re-downloads the
        # CURRENT model before training (jnp-only bookkeeping in the
        # sync runner — dropping its staleness diagnostics perturbs no
        # rng stream, so parity holds).
        catchup = ids[~in_sync[ids]]
        for v in catchup:
            client_params[v] = params
            in_sync[v] = True
        participation.append(ids.size / cfg.n_clients)
        updates = []
        for v in ids:
            out = local_train(params, train.x[parts[v]],
                              train.y[parts[v]], nprng)
            updates.append(compute_update(params, out))
        if sync_mode:
            rec = session.run_round()
        else:
            k_eff = min(max(acfg.buffer_k, 1), int(ids.size))
            rec = session.run_round(quorum_k=k_eff, tail_mode=tail_mode,
                                    bt_budget=acfg.round_slots)
        res = rec.result
        recon = res.reconstructable
        recon_fracs.append(float(recon.mean()))
        w_act = weights[ids]
        surv = np.flatnonzero(res.active)
        ref = int(surv[0]) if surv.size else 0

        if sync_mode:
            # The exact sync merge (fl/runner.py), same op order.
            if not bool((recon == recon[ref]).all()):
                flats = jnp.stack([flatten_update(u)[0] for u in updates])
                per_cl = per_client_aggregates(flats, w_act, recon)
                if not bool(jnp.allclose(per_cl[surv], per_cl[ref][None],
                                         atol=1e-6)):
                    agreement = False
            agg = fedavg_pytree(updates, w_act, recon[ref])
            params = apply_aggregate(params, agg)
            merged.append(int(recon[ref].sum()))
            stale_merged.append(0)
        else:
            orec = obs.get()
            # Swarm-complete fresh updates (identical at every active
            # peer by the quorum definition — sole-writer merge) enter
            # the buffer at staleness 0; the rest go pending until the
            # tail delivers them everywhere.
            mask = (recon[res.active].all(axis=0) if res.active.any()
                    else np.zeros(ids.size, dtype=bool))
            for li in np.flatnonzero(~mask):
                pending[(r, int(ids[li]))] = (updates[li],
                                              float(w_act[li]))
            for key in rec.dead_updates:
                if pending.pop(key, None) is not None:
                    dropped += 1
                    orec.counter("async.dropped")
            if acfg.overlap:
                ready_keys = list(rec.late_ready)
            else:
                ready_keys = queued_ready
                queued_ready = list(rec.late_ready)
            for li in np.flatnonzero(mask):
                buffer.append((r, updates[li], float(w_act[li])))
            for key in ready_keys:
                ent = pending.pop(key, None)
                if ent is None:
                    continue
                if r - key[0] > acfg.max_staleness:
                    dropped += 1
                    orec.counter("async.dropped")
                    continue
                buffer.append((key[0], ent[0], ent[1]))
            # Entries that could only merge past the bound are masked.
            for key in list(pending):
                if r - key[0] >= acfg.max_staleness:
                    del pending[key]
                    dropped += 1
                    orec.counter("async.dropped")
            # FedBuff cut: merge the whole buffer once K are available,
            # each down-weighted by its staleness AT MERGE TIME.
            if len(buffer) >= k_eff:
                stale = [r - g for g, _, _ in buffer]
                all_w = np.asarray(
                    [w * (1.0 + s) ** (-acfg.staleness_alpha)
                     for (_, _, w), s in zip(buffer, stale)], np.float64)
                agg = fedavg_pytree([u for _, u, _ in buffer], all_w,
                                    np.ones(len(buffer), dtype=bool))
                if acfg.server_lr != 1.0:
                    agg = jax.tree_util.tree_map(
                        lambda u: acfg.server_lr * u, agg)
                params = apply_aggregate(params, agg)
                merged.append(len(buffer))
                stale_merged.append(sum(1 for s in stale if s > 0))
                for s in stale:
                    if s > 0:
                        hist[s] = hist.get(s, 0) + 1
                if orec.enabled:
                    # Merge instant on the session wall clock: the end
                    # of round r including any boundary drain.
                    orec.event("async.merge",
                               t=res.metrics.t_round_s + res.drain_s,
                               merged=len(buffer),
                               stale_merged=stale_merged[-1],
                               pending=len(pending))
                    orec.counter("async.merges")
                    late = [s for s in stale if s > 0]
                    if late:
                        orec.hist("async.staleness", late)
                buffer = []
            else:
                merged.append(0)
                stale_merged.append(0)

        in_sync[:] = False
        got = ids[res.active]
        for v in got:
            client_params[v] = params
        in_sync[got] = True
        accs.append(accuracy(apply_fn, params, test.x, test.y))

    return AsyncResult(
        accuracy=accs, wall_s=list(np.asarray(session.offsets[1:])),
        merged=merged, stale_merged=stale_merged, staleness_hist=hist,
        dropped=dropped, buffer_end=len(buffer), agreement=agreement,
        reconstruct_frac=float(np.mean(recon_fracs)),
        participation=participation, session=session)


def adversary_view(session: SwarmSession) -> TransferTrace:
    """The wire-level view an async session exposes to observers.

    Late-tail traffic is protocol-indistinguishable from warm-up on the
    wire (chunks of some torrent arriving from a neighbor), so the
    conservative adversary model folds the late rows into the phase-1
    observation surface.  Their descriptors are band-shifted into a
    disjoint per-generation range: each stale generation's torrent keys
    its own descriptors, so the shift keeps the ground-truth
    (round, descriptor) -> owner grading injective while *enlarging* the
    descriptor cover set the attacker must disambiguate — the mechanism
    by which overlap changes unlinkability.
    """
    K = session.cfg.chunks_per_update
    base = [rec.global_log() for rec in session.history]
    lates = [rec.late_log for rec in session.history
             if rec.late_log is not None and len(rec.late_log)]
    if not lates:
        return TransferTrace.concat(base)
    band = int(session.n_peers) + 1
    shifted = []
    for la in lates:
        l2 = TransferTrace(K=la.K, **{k: getattr(la, k).copy()
                                      for k in la.keys()})
        l2.phase = np.full(len(l2), 1, dtype=np.int8)
        l2.chunk = (l2.chunk
                    + (l2.generation.astype(np.int64) + 1) * band * K)
        shifted.append(l2)
    return TransferTrace.concat(base + shifted)
