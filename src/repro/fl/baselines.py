"""Learning baselines the paper compares against (§V-B).

* **CFL** — centralized federated learning: a server FedAvgs all client
  updates each round (pragmatic upper bound).
* **GossipDFL** — representative mix-and-forward decentralized learning:
  each round, every client averages parameters with its overlay
  neighbors through a Metropolis-Hastings mixing matrix (doubly
  stochastic), the standard gossip step of [Lian et al. 2017; Koloskova
  et al. 2019].  Under heterogeneity this *attenuates* global
  information (partial mixing), which is precisely the failure mode
  FLTorrent avoids by disseminating full updates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def fedavg_server(updates: list, weights: np.ndarray):
    """CFL aggregation over all clients."""
    w = np.asarray(weights, np.float64)
    wn = (w / w.sum()).astype(np.float32)

    def combine(*leaves):
        return jnp.einsum("n,n...->...",
                          jnp.asarray(wn), jnp.stack(leaves))

    return jax.tree_util.tree_map(combine, *updates)


def metropolis_weights(adj: np.ndarray) -> np.ndarray:
    """Doubly-stochastic mixing matrix over the overlay."""
    n = adj.shape[0]
    deg = adj.sum(1)
    w = np.zeros((n, n), np.float64)
    for i in range(n):
        for j in np.flatnonzero(adj[i]):
            w[i, j] = 1.0 / (1 + max(deg[i], deg[j]))
        w[i, i] = 1.0 - w[i].sum()
    return w


def gossip_mix(client_params: list, w: np.ndarray):
    """One gossip round: x_i <- sum_j W_ij x_j (mix-and-forward)."""
    wj = jnp.asarray(w, jnp.float32)

    def combine(*leaves):
        stacked = jnp.stack(leaves)              # (n, ...)
        return jnp.einsum("ij,j...->i...", wj, stacked)

    mixed = jax.tree_util.tree_map(combine, *client_params)
    # Unstack back into per-client pytrees.
    n = w.shape[0]
    return [jax.tree_util.tree_map(lambda l: l[i], mixed) for i in range(n)]
