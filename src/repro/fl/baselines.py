"""Learning baselines the paper compares against (§V-B).

* **CFL** — centralized federated learning: a server FedAvgs all client
  updates each round (pragmatic upper bound).
* **GossipDFL** — representative mix-and-forward decentralized learning:
  each round, every client averages parameters with its overlay
  neighbors through a Metropolis-Hastings mixing matrix (doubly
  stochastic), the standard gossip step of [Lian et al. 2017; Koloskova
  et al. 2019].  Under heterogeneity this *attenuates* global
  information (partial mixing), which is precisely the failure mode
  FLTorrent avoids by disseminating full updates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def fedavg_server(updates: list, weights: np.ndarray):
    """CFL aggregation over all clients."""
    w = np.asarray(weights, np.float64)
    wn = (w / w.sum()).astype(np.float32)

    def combine(*leaves):
        return jnp.einsum("n,n...->...",
                          jnp.asarray(wn), jnp.stack(leaves))

    return jax.tree_util.tree_map(combine, *updates)


def metropolis_weights(adj: np.ndarray) -> np.ndarray:
    """Doubly-stochastic mixing matrix over the overlay."""
    n = adj.shape[0]
    deg = adj.sum(1)
    w = np.zeros((n, n), np.float64)
    for i in range(n):
        for j in np.flatnonzero(adj[i]):
            w[i, j] = 1.0 / (1 + max(deg[i], deg[j]))
        w[i, i] = 1.0 - w[i].sum()
    return w


def gossip_mix(client_params: list, w: np.ndarray):
    """One gossip round: x_i <- sum_j W_ij x_j (mix-and-forward).

    The mix acts on the *post-local-update* params (local step first,
    then gossip — Koloskova et al. 2019) with the Metropolis matrix.
    """
    wj = jnp.asarray(w, jnp.float32)

    def combine(*leaves):
        stacked = jnp.stack(leaves)              # (n, ...)
        return jnp.einsum("ij,j...->i...", wj, stacked)

    mixed = jax.tree_util.tree_map(combine, *client_params)
    # Unstack back into per-client pytrees.
    n = w.shape[0]
    return [jax.tree_util.tree_map(lambda l: l[i], mixed) for i in range(n)]


def gossip_eval(apply_fn, client_params: list, x, y) -> float:
    """GossipDFL round accuracy: mean of the per-client accuracies.

    Each client only holds its own partially-mixed model, so that is
    what gets evaluated.  Evaluating the client-MEAN model instead (the
    previous behavior) is wrong for this baseline: the Metropolis matrix
    is doubly stochastic, so mean_i(sum_j W_ij x_j) == mean_j(x_j) — the
    metric is invariant to the mix and silently reports an exact
    *uniform FedAvg* that no gossip node possesses.  That phantom
    averaging beat exact weighted FedAvg at round 0 under dir(0.1)
    heterogeneity, inverting the attenuation the baseline exists to
    show (§V-B).
    """
    from .models_small import accuracy
    return float(np.mean([accuracy(apply_fn, p, x, y)
                          for p in client_params]))
