"""FL client: local SGD training producing a model update (paper §III-A.1).

Each round, client v computes ``g_v^r = params_local_after - params_in``
(the update that gets chunked and disseminated) with weight = local
sample count, matching FedAvg semantics.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .models_small import cross_entropy


@dataclass
class LocalSpec:
    epochs: int = 5
    batch_size: int = 32
    lr: float = 0.05
    momentum: float = 0.9


def make_local_train(apply_fn, spec: LocalSpec):
    """Returns jit'd (params, x, y, rng) -> new_params local trainer."""

    def loss_fn(params, xb, yb):
        return cross_entropy(apply_fn(params, xb), yb)

    @jax.jit
    def sgd_step(params, mom, xb, yb):
        loss, grads = jax.value_and_grad(loss_fn)(params, xb, yb)
        mom = jax.tree_util.tree_map(
            lambda m, g: spec.momentum * m + g, mom, grads)
        params = jax.tree_util.tree_map(
            lambda p, m: p - spec.lr * m, params, mom)
        return params, mom, loss

    def local_train(params, x: np.ndarray, y: np.ndarray,
                    rng: np.random.Generator):
        mom = jax.tree_util.tree_map(jnp.zeros_like, params)
        n = len(y)
        for _ in range(spec.epochs):
            order = rng.permutation(n)
            for i in range(0, n, spec.batch_size):
                sl = order[i:i + spec.batch_size]
                if len(sl) < 2:
                    continue
                params, mom, _ = sgd_step(params, mom,
                                          jnp.asarray(x[sl]),
                                          jnp.asarray(y[sl]))
        return params

    return local_train


def compute_update(params_in, params_out):
    """g_v^r: the disseminated artifact (delta, FedAvg-compatible)."""
    return jax.tree_util.tree_map(lambda a, b: b - a, params_in, params_out)


def apply_aggregate(params_in, agg_update):
    return jax.tree_util.tree_map(lambda p, u: p + u, params_in, agg_update)
