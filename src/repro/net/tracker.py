"""Tracker control plane in continuous time (repro.net).

The slot world folds all coordination into the stage index; here the
tracker is an explicit control-plane participant: every warm-up
directive cycle costs one tracker round-trip (collect availability,
compute assignments, fan directives out) *before* any data moves, and
that time is pure coordination overhead — it occupies the wall clock
but no data-path bandwidth.  BT swarming is peer-driven (no per-stage
tracker involvement), so its stages pay no RTT; this asymmetry is
exactly the "FLTorrent adds ~6-10% round-time overhead over
BitTorrent-only" accounting the paper reports (§V-E): the privacy
warm-up is tracker-clocked, the swarm tail is not.

The control plane also keeps a directive ledger (cycle index, issue
instant, directive count) — the audit surface a commit-then-reveal
tracker would sign, and the timing ground truth for calibrating
side-channel experiments.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro import obs


@dataclass
class TrackerControlPlane:
    """Per-round tracker coordination clock.

    ``rtt_s`` is the directive network round-trip per warm-up cycle
    (availability upload + directive fan-out); ``solve_s`` the
    centralized per-cycle assignment solve (negligible at K ~ 200
    pieces, seconds at LLM piece counts).  ``spray_setup_s`` is the
    one-off tunnel brokering cost of the pre-round obfuscation step
    (§III-B.1): the tracker hands every source its non-neighbor tunnel
    endpoints before any spray byte moves.
    """

    rtt_s: float = 0.1
    solve_s: float = 0.0
    spray_setup_s: float = 0.0
    cycles: list = field(default_factory=list)   # (slot, t_issue, n_dir)
    control_s: float = 0.0                       # total coordination time

    def directive_cycle(self, slot: int, t_now: float,
                        n_directives: int) -> float:
        """Charge one warm-up directive cycle; returns the instant data
        transfers may start (directives delivered)."""
        self.cycles.append((int(slot), float(t_now),
                            int(n_directives)))
        cost = self.rtt_s + self.solve_s
        self.control_s += cost
        rec = obs.get()
        if rec.enabled:
            rec.event("tracker.cycle", t=t_now, slot=int(slot),
                      n_directives=int(n_directives), cost_s=cost)
            rec.counter("tracker.control_s", cost)
        return t_now + cost

    def spray_setup(self, t_now: float, n_tunnels: int) -> float:
        """Charge the pre-round tunnel brokering; returns the spray
        start instant."""
        self.cycles.append((-1, float(t_now), int(n_tunnels)))
        self.control_s += self.spray_setup_s
        rec = obs.get()
        if rec.enabled:
            rec.event("tracker.spray_setup", t=t_now,
                      n_tunnels=int(n_tunnels),
                      cost_s=self.spray_setup_s)
            rec.counter("tracker.control_s", self.spray_setup_s)
        return t_now + self.spray_setup_s

    def as_log(self) -> dict:
        return {"rtt_s": self.rtt_s,
                "solve_s": self.solve_s,
                "spray_setup_s": self.spray_setup_s,
                "control_s": self.control_s,
                "n_cycles": len(self.cycles),
                "cycles": list(self.cycles)}
