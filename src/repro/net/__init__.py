"""repro.net — continuous-time event-driven transport (wall-clock
round times, max-min fair-share flows, tracker control plane).

The slot engines (:mod:`repro.core.schedulers`) quantize time into
integer chunks-per-slot stages; this package is the real-valued
alternative behind ``RoundSimulator(time_engine="event")``:

* :mod:`repro.net.fairshare` — progressive-filling max-min fair-share
  rate allocation over heterogeneous access links, vectorized over the
  active flow set, with pipelined per-chunk completion instants;
* :mod:`repro.net.engine` — the :class:`EventEngine` transport of each
  directive cycle's scheduled transfers (same policies, same schedules,
  real seconds) and :class:`NetConfig`;
* :mod:`repro.net.tracker` — the explicit tracker control plane:
  directive RTTs during warm-up, off the data path.

It exists for the paper's *time* claims (warm-up share, ~6-10% LLM
round-time overhead, bandwidth-optimality in seconds) and for the
timing side-channel surface (``t_start``/``t_end`` trace columns →
``repro.core.attacks.timing_attribution``).
"""
from .engine import (DATACENTER_NET, RESIDENTIAL_NET, EventEngine,
                     NetConfig)
from .fairshare import FlowTimings, maxmin_rates, transport
from .tracker import TrackerControlPlane

__all__ = [
    "EventEngine", "NetConfig", "RESIDENTIAL_NET", "DATACENTER_NET",
    "FlowTimings", "maxmin_rates", "transport", "TrackerControlPlane",
]
