"""Continuous-time event-driven transport engine (repro.net).

``EventEngine`` is the second *time engine* behind
:class:`~repro.core.simulator.RoundSimulator` (``time_engine="event"``).
The scheduling contract is untouched — the same
:class:`~repro.core.policy.SchedulerPolicy` decides, per directive
cycle, exactly the transfers the slot engine would schedule (same rng
stream, same integer budgets) — but each cycle's transfers are then
*transported*: grouped into per-(sender, receiver) flows, rated by
max-min fair share over the raw bytes/s access links
(:mod:`repro.net.fairshare`), pipelined chunk-by-chunk, and stamped
with real-valued ``t_start``/``t_end`` instants.  The wall clock
advances by each cycle's realized makespan plus the tracker directive
RTT (:mod:`repro.net.tracker`), so round times come out in honest
seconds:

* a cycle that trickles (lags, closed gates) finishes early instead of
  costing a full slot;
* a cycle whose grants oversubscribe a receiver's downlink takes longer
  than a slot — queueing the slot world cannot express;
* warm-up pays coordination RTT per cycle, BT swarming does not.

In the homogeneous-capacity, zero-latency, zero-RTT limit the engine
reproduces the slot engine's per-cycle chunk transfer counts exactly
(it *is* the same schedule) and ``t_start`` ordering is consistent
with slot order (cycles are sequential barriers) — the cross-validation
anchor in ``tests/test_net.py``.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .fairshare import pipeline_starts, transport
from .tracker import TrackerControlPlane


@dataclass(frozen=True)
class NetConfig:
    """Physical-layer knobs of the event engine.

    ``tracker_rtt_s``    warm-up directive network round-trip per cycle
                         (control plane, off the data path).
    ``tracker_solve_s``  per-cycle centralized assignment solve time:
                         the tracker collects availability and computes
                         the stage schedule before fanning directives
                         out — milliseconds at K~200 pieces, but a real
                         cost at LLM piece counts (10^4-10^5 pieces x
                         dozens of peers per cycle, §V-E).
    ``latency_lo_s``/``latency_hi_s``
                         per-peer one-way access propagation delay,
                         sampled uniformly once per round; a transfer
                         over (u, v) is delayed by ``lat[u] + lat[v]``.
    ``spray_setup_s``    one-off tunnel brokering before the spray.
    ``quantum_frac``     fair-share re-solve batching (see
                         :func:`repro.net.fairshare.transport`).
    """

    tracker_rtt_s: float = 0.1
    tracker_solve_s: float = 0.0
    latency_lo_s: float = 0.0
    latency_hi_s: float = 0.0
    spray_setup_s: float = 0.0
    quantum_frac: float = 1 / 32

    def replace(self, **kw) -> "NetConfig":
        import dataclasses
        return dataclasses.replace(self, **kw)


# Paper-flavored presets.  Residential swarms (K ~ 200 pieces): tens of
# ms of access propagation, negligible assignment solves.  Datacenter
# LLM-scale swarms (§V-E): no propagation worth modeling, but each
# directive cycle's centralized assignment over 10^4-10^5 pieces costs
# real solve time — the dominant control-plane term behind the paper's
# ~6-10% FLTorrent-over-BT round-time overhead.
RESIDENTIAL_NET = NetConfig(tracker_rtt_s=0.1, latency_lo_s=0.005,
                            latency_hi_s=0.030)
DATACENTER_NET = NetConfig(tracker_rtt_s=0.1, tracker_solve_s=0.6)


class EventEngine:
    """Wall-clock transport of one round's scheduled transfer cycles.

    The engine owns its own rng stream (derived from ``seed`` with a
    fixed salt) so sampling propagation latencies never perturbs the
    simulator's scheduling stream — schedules stay bit-identical to the
    slot engine's at the same seed.
    """

    def __init__(self, n: int, chunk_bytes: int,
                 up_bps: np.ndarray, down_bps: np.ndarray,
                 net: NetConfig, seed: int):
        self.n = int(n)
        self.chunk_bytes = float(chunk_bytes)
        self.up_bps = np.asarray(up_bps, np.float64)
        self.down_bps = np.asarray(down_bps, np.float64)
        # A zero-rate link can never deliver, but the scheduling layer
        # would still mark its chunks delivered — so a scheduled flow
        # over one would stamp t_end = inf into the trace.  Reject it
        # up front (the slot world's >=1 chunk/slot clamp means only
        # direct rate injection can produce this).
        if (self.up_bps <= 0).any() or (self.down_bps <= 0).any():
            raise ValueError(
                "event engine needs strictly positive link rates; got "
                f"{int((self.up_bps <= 0).sum())} non-positive uplinks "
                f"and {int((self.down_bps <= 0).sum())} downlinks")
        self.net = net
        rng = np.random.default_rng(
            np.random.SeedSequence([int(seed) & 0x7FFFFFFF, 0x7E71]))
        if net.latency_hi_s > 0:
            self.lat = rng.uniform(net.latency_lo_s, net.latency_hi_s,
                                   size=self.n)
        else:
            self.lat = np.zeros(self.n, np.float64)
        self.t = 0.0                      # wall clock (seconds)
        self.tracker = TrackerControlPlane(
            rtt_s=net.tracker_rtt_s, solve_s=net.tracker_solve_s,
            spray_setup_s=net.spray_setup_s)
        self.n_solves = 0
        self.data_s = 0.0                 # time with data in flight

    # ------------------------------------------------------------------
    def _transport(self, snd, rcv, t0: float):
        """Fair-share transport of one cycle's transfers from ``t0``.

        Returns aligned (t_start, t_end) arrays and the barrier instant
        (last delivery).  Transfers between the same pair are pipelined
        in emission order — the policy emits rarest-first, so the wire
        order *is* the priority order.
        """
        snd = np.asarray(snd, np.int64)
        rcv = np.asarray(rcv, np.int64)
        pair = snd * self.n + rcv
        upair, inv = np.unique(pair, return_inverse=True)
        counts = np.bincount(inv)
        fs, fd = upair // self.n, upair % self.n
        tm = transport(fs, fd, counts, self.chunk_bytes,
                       self.up_bps, self.down_bps,
                       quantum_frac=self.net.quantum_frac)
        self.n_solves += tm.n_solves
        # Guard against fp under-emission: pad each flow's tail chunks
        # with its finish instant so every transfer gets a stamp.
        emitted = np.bincount(tm.chunk_flow, minlength=len(upair))
        if (emitted < counts).any():
            miss = counts - emitted
            padf = np.repeat(np.flatnonzero(miss > 0),
                             miss[miss > 0])
            cflow = np.concatenate([tm.chunk_flow, padf])
            cend = np.concatenate([tm.chunk_end, tm.finish[padf]])
            o = np.lexsort((cend, cflow))
            cflow, cend = cflow[o], cend[o]
        else:
            cflow, cend = tm.chunk_flow, tm.chunk_end
        cstart = pipeline_starts(cflow, cend)
        # Per-transfer pipeline rank within its pair, in emission order.
        order = np.argsort(inv, kind="stable")
        inv_s = inv[order]
        first = np.searchsorted(inv_s, inv_s)
        rank = np.arange(len(inv_s)) - first
        off = np.cumsum(counts) - counts
        pos = off[inv_s] + rank
        lat_pair = self.lat[fs] + self.lat[fd]
        te = np.empty(len(snd), np.float64)
        ts = np.empty(len(snd), np.float64)
        te[order] = t0 + lat_pair[inv_s] + cend[pos]
        ts[order] = t0 + lat_pair[inv_s] + cstart[pos]
        fin = tm.finish.copy()
        fin[~np.isfinite(fin)] = 0.0
        barrier = t0 + float(np.max(fin + lat_pair, initial=0.0))
        return ts, te, barrier

    # ------------------------------------------------------------------
    def spray(self, snd, rcv, chk):
        """Pre-round obfuscation over ephemeral tunnels: tunnel setup
        (control plane) then one fair-share transport of all sprays."""
        t0 = self.tracker.spray_setup(self.t, len(snd))
        if len(snd) == 0:
            self.t = t0
            return (np.zeros(0, np.float64), np.zeros(0, np.float64))
        ts, te, barrier = self._transport(snd, rcv, t0)
        self.data_s += barrier - t0
        self.t = barrier
        return ts, te

    def warmup_cycle(self, slot: int, snd, rcv, chk):
        """One warm-up directive cycle: tracker RTT, then transport."""
        t0 = self.tracker.directive_cycle(slot, self.t, len(snd))
        if len(snd) == 0:
            self.t = t0                 # an idle cycle still ticks
            return (np.zeros(0, np.float64), np.zeros(0, np.float64))
        ts, te, barrier = self._transport(snd, rcv, t0)
        self.data_s += barrier - t0
        self.t = barrier
        return ts, te

    def bt_cycle(self, snd, rcv, chk):
        """One exact-BT swarming cycle: peer-driven, no tracker RTT."""
        if len(snd) == 0:
            return (np.zeros(0, np.float64), np.zeros(0, np.float64))
        ts, te, barrier = self._transport(snd, rcv, self.t)
        self.data_s += barrier - self.t
        self.t = barrier
        return ts, te

    def advance(self, seconds: float):
        """Advance the wall clock (fluid BT phases report durations in
        count space; the engine just books the time)."""
        self.t += float(seconds)
