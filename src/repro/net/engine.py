"""Continuous-time event-driven transport engine (repro.net).

``EventEngine`` is the second *time engine* behind
:class:`~repro.core.simulator.RoundSimulator` (``time_engine="event"``).
The scheduling contract is untouched — the same
:class:`~repro.core.policy.SchedulerPolicy` decides, per directive
cycle, exactly the transfers the slot engine would schedule (same rng
stream, same integer budgets) — but each cycle's transfers are then
*transported*: grouped into per-(sender, receiver) flows, rated by
max-min fair share over the raw bytes/s access links
(:mod:`repro.net.fairshare`), pipelined chunk-by-chunk, and stamped
with real-valued ``t_start``/``t_end`` instants.  The wall clock
advances by each cycle's realized makespan plus the tracker directive
RTT (:mod:`repro.net.tracker`), so round times come out in honest
seconds:

* a cycle that trickles (lags, closed gates) finishes early instead of
  costing a full slot;
* a cycle whose grants oversubscribe a receiver's downlink takes longer
  than a slot — queueing the slot world cannot express;
* warm-up pays coordination RTT per cycle, BT swarming does not.

In the homogeneous-capacity, zero-latency, zero-RTT limit the engine
reproduces the slot engine's per-cycle chunk transfer counts exactly
(it *is* the same schedule) and ``t_start`` ordering is consistent
with slot order (cycles are sequential barriers) — the cross-validation
anchor in ``tests/test_net.py``.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro import obs

from .fairshare import (congestion_bound, maxmin_rates, pipeline_starts,
                        transport)
from .tracker import TrackerControlPlane


def _bg_fluid(src, dst, flow_of, rem, up, down, window, quantum_frac):
    """Fluid transport of queued background entries over residual
    capacity, banking partial progress across cycle windows.

    Entries arrive grouped by flow (``flow_of[e]`` -> flow index into
    ``src``/``dst``, queue order within each flow) with ``rem[e]``
    bytes left.  Max-min rates are solved on the residual caps and the
    flows advance fluidly; an entry completes when its flow's delivered
    curve crosses its cumulative-byte threshold.  Unlike the foreground
    path, progress is BANKED: an entry cut off by ``window`` keeps its
    partial bytes for the next cycle — a background connection is
    long-lived, it does not restart because a directive cycle ended
    (chunk-whole retry here would livelock a wide backlog whose
    per-flow residual share moves less than one chunk per window).

    Returns per-entry ``(start, end)`` instants relative to the cycle
    start (``inf`` end = not finished inside ``window``), the updated
    per-entry remaining bytes, and the solve count.
    """
    nf = len(src)
    E = len(rem)
    frem = np.zeros(nf, np.float64)
    np.add.at(frem, flow_of, rem)
    cum = np.cumsum(rem)
    first_idx = np.searchsorted(flow_of, np.arange(nf))
    flow_base = (cum - rem)[first_idx]
    thr_end = cum - flow_base[flow_of]
    thr_start = thr_end - rem
    tol = 1e-6 * max(float(rem.max(initial=1.0)), 1.0)
    delivered = np.zeros(nf, np.float64)
    starts = np.full(E, np.inf, np.float64)
    ends = np.full(E, np.inf, np.float64)
    lb = congestion_bound(src, dst, frem, up, down)
    quantum = quantum_frac * lb
    alive = frem > tol
    t, nsol = 0.0, 0
    while alive.any() and t < window - 1e-12:
        idx = np.flatnonzero(alive)
        r = maxmin_rates(src[idx], dst[idx], up, down)
        nsol += 1
        dead = r <= 1e-9
        if dead.any():                # zero residual: no progress, bank
            alive[idx[dead]] = False
            idx, r = idx[~dead], r[~dead]
            if idx.size == 0:
                break
        ttf = frem[idx] / r
        dt = max(float(ttf.min()), quantum)
        if np.isfinite(window):
            dt = min(dt, window - t)
        rate_all = np.zeros(nf, np.float64)
        rate_all[idx] = r
        adv = np.minimum(rate_all * dt, frem)
        new_all = delivered + adv
        fo_new = new_all[flow_of]
        fo_rate = rate_all[flow_of]
        fo_old = delivered[flow_of]
        cs = np.isinf(starts) & (fo_new >= thr_start - tol) & (fo_rate > 0)
        starts[cs] = t + np.maximum(
            thr_start[cs] - fo_old[cs], 0.0) / fo_rate[cs]
        ce = np.isinf(ends) & (fo_new >= thr_end - tol) & (fo_rate > 0)
        ends[ce] = t + np.maximum(
            thr_end[ce] - fo_old[ce], 0.0) / fo_rate[ce]
        delivered = new_all
        frem = frem - adv
        alive = alive & (frem > tol)
        t += dt
    rem_after = np.where(
        np.isfinite(ends), 0.0,
        np.minimum(np.maximum(thr_end - delivered[flow_of], 0.0), rem))
    return starts, ends, rem_after, nsol


@dataclass(frozen=True)
class NetConfig:
    """Physical-layer knobs of the event engine.

    ``tracker_rtt_s``    warm-up directive network round-trip per cycle
                         (control plane, off the data path).
    ``tracker_solve_s``  per-cycle centralized assignment solve time:
                         the tracker collects availability and computes
                         the stage schedule before fanning directives
                         out — milliseconds at K~200 pieces, but a real
                         cost at LLM piece counts (10^4-10^5 pieces x
                         dozens of peers per cycle, §V-E).
    ``latency_lo_s``/``latency_hi_s``
                         per-peer one-way access propagation delay,
                         sampled uniformly once per round; a transfer
                         over (u, v) is delayed by ``lat[u] + lat[v]``.
    ``spray_setup_s``    one-off tunnel brokering before the spray.
    ``quantum_frac``     fair-share re-solve batching (see
                         :func:`repro.net.fairshare.transport`).
    """

    tracker_rtt_s: float = 0.1
    tracker_solve_s: float = 0.0
    latency_lo_s: float = 0.0
    latency_hi_s: float = 0.0
    spray_setup_s: float = 0.0
    quantum_frac: float = 1 / 32

    def replace(self, **kw) -> "NetConfig":
        import dataclasses
        return dataclasses.replace(self, **kw)


# Paper-flavored presets.  Residential swarms (K ~ 200 pieces): tens of
# ms of access propagation, negligible assignment solves.  Datacenter
# LLM-scale swarms (§V-E): no propagation worth modeling, but each
# directive cycle's centralized assignment over 10^4-10^5 pieces costs
# real solve time — the dominant control-plane term behind the paper's
# ~6-10% FLTorrent-over-BT round-time overhead.
RESIDENTIAL_NET = NetConfig(tracker_rtt_s=0.1, latency_lo_s=0.005,
                            latency_hi_s=0.030)
DATACENTER_NET = NetConfig(tracker_rtt_s=0.1, tracker_solve_s=0.6)


class EventEngine:
    """Wall-clock transport of one round's scheduled transfer cycles.

    The engine owns its own rng stream (derived from ``seed`` with a
    fixed salt) so sampling propagation latencies never perturbs the
    simulator's scheduling stream — schedules stay bit-identical to the
    slot engine's at the same seed.
    """

    def __init__(self, n: int, chunk_bytes: int,
                 up_bps: np.ndarray, down_bps: np.ndarray,
                 net: NetConfig, seed: int):
        self.n = int(n)
        self.chunk_bytes = float(chunk_bytes)
        self.up_bps = np.asarray(up_bps, np.float64)
        self.down_bps = np.asarray(down_bps, np.float64)
        # A zero-rate link can never deliver, but the scheduling layer
        # would still mark its chunks delivered — so a scheduled flow
        # over one would stamp t_end = inf into the trace.  Reject it
        # up front (the slot world's >=1 chunk/slot clamp means only
        # direct rate injection can produce this).
        if (self.up_bps <= 0).any() or (self.down_bps <= 0).any():
            raise ValueError(
                "event engine needs strictly positive link rates; got "
                f"{int((self.up_bps <= 0).sum())} non-positive uplinks "
                f"and {int((self.down_bps <= 0).sum())} downlinks")
        self.net = net
        rng = np.random.default_rng(
            np.random.SeedSequence([int(seed) & 0x7FFFFFFF, 0x7E71]))
        if net.latency_hi_s > 0:
            self.lat = rng.uniform(net.latency_lo_s, net.latency_hi_s,
                                   size=self.n)
        else:
            self.lat = np.zeros(self.n, np.float64)
        self.t = 0.0                      # wall clock (seconds)
        self.tracker = TrackerControlPlane(
            rtt_s=net.tracker_rtt_s, solve_s=net.tracker_solve_s,
            spray_setup_s=net.spray_setup_s)
        self.n_solves = 0
        self.data_s = 0.0                 # time with data in flight
        # Background queue (async overlap, fl/asyncfl.py): one chunk per
        # entry, carried from a previous generation's tail.  Entries run
        # at strict lower priority over the residual capacity each
        # foreground cycle leaves idle (see _transport).
        self._bg_src = np.zeros(0, np.int64)
        self._bg_dst = np.zeros(0, np.int64)
        self._bg_meta = np.zeros(0, np.int64)
        self._bg_rem = np.zeros(0, np.float64)   # banked bytes remaining
        self._bg_log: list[dict] = []     # delivered-background batches

    # ------------------------------------------------------------------
    @staticmethod
    def _stamp_grid(tm, counts):
        """Full per-chunk (flow, end) grid for a transport result.

        Guards against fp under-emission: pads each flow's missing tail
        chunks with its finish instant so every transfer gets a stamp
        (dead zero-rate flows keep ``inf`` and are filtered by the
        delivery predicate downstream)."""
        emitted = np.bincount(tm.chunk_flow, minlength=len(counts))
        if (emitted < counts).any():
            miss = counts - emitted
            padf = np.repeat(np.flatnonzero(miss > 0), miss[miss > 0])
            cflow = np.concatenate([tm.chunk_flow, padf])
            cend = np.concatenate([tm.chunk_end, tm.finish[padf]])
            o = np.lexsort((cend, cflow))
            return cflow[o], cend[o]
        return tm.chunk_flow, tm.chunk_end

    def _transport(self, snd, rcv, t0: float, deliver_all_bg: bool = False,
                   track: str = "fg"):
        """Fair-share transport of one cycle's transfers from ``t0``.

        Returns aligned (t_start, t_end) arrays and the barrier instant
        (last foreground delivery).  Transfers between the same pair are
        pipelined in emission order — the policy emits rarest-first, so
        the wire order *is* the priority order.

        Queued background chunks (:meth:`set_background`) run at strict
        LOWER priority in a two-phase solve.  Phase 1 rates the
        foreground alone, so its stamps and barrier are byte-identical
        to a cycle with no carried tail — an old generation can never
        dilate the current one.  Phase 2 water-fills the background over
        each link's *residual* capacity — the bandwidth the foreground's
        max-min allocation left idle over the cycle window (fast peers
        blocked on a straggler's barrier are exactly the idle capacity
        async aggregation recovers).  Background chunks completed inside
        the window are logged and dequeued; the rest keep their partial
        bytes BANKED for the next cycle (see :func:`_bg_fluid` — a
        background connection is long-lived and does not restart at
        directive-cycle boundaries).  ``deliver_all_bg`` lifts both the
        window and the residual cap (solo drain at full capacity).
        """
        snd = np.asarray(snd, np.int64)
        rcv = np.asarray(rcv, np.int64)
        pair = snd * self.n + rcv
        upair, inv = np.unique(pair, return_inverse=True)
        counts = np.bincount(inv, minlength=len(upair)).astype(np.int64)
        F = len(upair)
        fs, fd = upair // self.n, upair % self.n
        # --- phase 1: foreground-only fair-share solve -----------------
        if F:
            tm = transport(fs, fd, counts, self.chunk_bytes,
                           self.up_bps, self.down_bps,
                           quantum_frac=self.net.quantum_frac)
            self.n_solves += tm.n_solves
            cflow, cend = self._stamp_grid(tm, counts)
            cstart = pipeline_starts(cflow, cend)
            lat_pair = self.lat[fs] + self.lat[fd]
            # Per-transfer pipeline rank within its pair, emission order.
            order = np.argsort(inv, kind="stable")
            inv_s = inv[order]
            first = np.searchsorted(inv_s, inv_s)
            rank = np.arange(len(inv_s)) - first
            off = np.cumsum(counts) - counts
            pos = off[inv_s] + rank
            te = np.empty(len(snd), np.float64)
            ts = np.empty(len(snd), np.float64)
            te[order] = t0 + lat_pair[inv_s] + cend[pos]
            ts[order] = t0 + lat_pair[inv_s] + cstart[pos]
            fin = tm.finish.copy()
            fin[~np.isfinite(fin)] = 0.0
            window = float(np.max(fin, initial=0.0))
            barrier = t0 + float(np.max(fin + lat_pair, initial=0.0))
            rec = obs.get()
            if rec.enabled:
                # One flow per (sender, receiver) pair this cycle —
                # per-flow granularity keeps recordings tractable.
                rec.flows(track, fs, fd,
                          t0 + lat_pair, t0 + lat_pair + fin,
                          chunks=counts)
                rec.counter("net.flows_solved", F)
                rec.counter("net.chunks_moved", len(snd))
                rec.counter("net.bytes_moved",
                            len(snd) * self.chunk_bytes)
                rec.counter("net.fg_solves", tm.n_solves)
        else:
            ts = np.zeros(0, np.float64)
            te = np.zeros(0, np.float64)
            window = 0.0
            barrier = t0
        # --- phase 2: background over residual capacity ----------------
        # Idle cycles (no foreground, no drain) pause the background —
        # the tail shares the swarm's duty cycle, it gets no free
        # private channel.
        B = self._bg_src.size
        if B and (deliver_all_bg or window > 0.0):
            if F and not deliver_all_bg:
                w_bytes = counts.astype(np.float64) * self.chunk_bytes
                used_up = np.bincount(fs, weights=w_bytes,
                                      minlength=self.n)
                used_dn = np.bincount(fd, weights=w_bytes,
                                      minlength=self.n)
                res_up = np.maximum(self.up_bps - used_up / window, 0.0)
                res_dn = np.maximum(self.down_bps - used_dn / window,
                                    0.0)
            else:
                res_up, res_dn = self.up_bps, self.down_bps
            bpair = self._bg_src * self.n + self._bg_dst
            border = np.argsort(bpair, kind="stable")
            bsorted = bpair[border]
            newf = np.r_[True, bsorted[1:] != bsorted[:-1]]
            bflow_pair = bsorted[newf]
            flow_of = np.cumsum(newf) - 1      # sorted entry -> flow
            bfs = bflow_pair // self.n
            bfd = bflow_pair % self.n
            W = np.inf if deliver_all_bg else window
            bstart, bend, rem_after, nsol = _bg_fluid(
                bfs, bfd, flow_of, self._bg_rem[border],
                res_up, res_dn, W, self.net.quantum_frac)
            self.n_solves += nsol
            self._bg_rem[border] = rem_after
            oks = np.isfinite(bend)            # sorted-entry delivered
            rec = obs.get()
            if oks.any():
                blat = self.lat[bfs] + self.lat[bfd]
                q = border[oks]
                batch = {
                    "meta": self._bg_meta[q].copy(),
                    "src": self._bg_src[q].copy(),
                    "dst": self._bg_dst[q].copy(),
                    "t_start": t0 + blat[flow_of[oks]] + bstart[oks],
                    "t_end": t0 + blat[flow_of[oks]] + bend[oks]}
                self._bg_log.append(batch)
                if rec.enabled:
                    rec.flows("background", batch["src"], batch["dst"],
                              batch["t_start"], batch["t_end"])
                done = np.zeros(B, dtype=bool)
                done[q] = True
                self._bg_src = self._bg_src[~done]
                self._bg_dst = self._bg_dst[~done]
                self._bg_meta = self._bg_meta[~done]
                self._bg_rem = self._bg_rem[~done]
            if rec.enabled:
                # Residual-capacity fill for the async carry: how much
                # of the queued tail this cycle's idle bandwidth soaked.
                rec.event("net.bg_fill", t=t0,
                          window=(float(W) if np.isfinite(W) else -1.0),
                          queued=int(B), delivered=int(oks.sum()),
                          solves=int(nsol))
                rec.counter("net.bg_delivered", int(oks.sum()))
                rec.counter("net.bg_solves", int(nsol))
                rec.gauge("net.bg_backlog", int(self._bg_src.size))
        return ts, te, barrier

    # ------------------------------------------------------------------
    def spray(self, snd, rcv, chk):
        """Pre-round obfuscation over ephemeral tunnels: tunnel setup
        (control plane) then one fair-share transport of all sprays."""
        t0 = self.tracker.spray_setup(self.t, len(snd))
        if len(snd) == 0:
            self.t = t0
            return (np.zeros(0, np.float64), np.zeros(0, np.float64))
        ts, te, barrier = self._transport(snd, rcv, t0, track="spray")
        self.data_s += barrier - t0
        self.t = barrier
        return ts, te

    def warmup_cycle(self, slot: int, snd, rcv, chk):
        """One warm-up directive cycle: tracker RTT, then transport."""
        t0 = self.tracker.directive_cycle(slot, self.t, len(snd))
        if len(snd) == 0:
            self.t = t0                 # an idle cycle still ticks
            return (np.zeros(0, np.float64), np.zeros(0, np.float64))
        ts, te, barrier = self._transport(snd, rcv, t0, track="warmup")
        self.data_s += barrier - t0
        self.t = barrier
        return ts, te

    def bt_cycle(self, snd, rcv, chk):
        """One exact-BT swarming cycle: peer-driven, no tracker RTT."""
        if len(snd) == 0:
            return (np.zeros(0, np.float64), np.zeros(0, np.float64))
        ts, te, barrier = self._transport(snd, rcv, self.t, track="bt")
        self.data_s += barrier - self.t
        self.t = barrier
        return ts, te

    def advance(self, seconds: float):
        """Advance the wall clock (fluid BT phases report durations in
        count space; the engine just books the time)."""
        self.t += float(seconds)

    def control_log(self) -> dict:
        """The round's control-plane ledger plus the engine's data-path
        aggregates — the dict ``RoundResult.tracker_log`` carries (the
        merge used to live inline in the simulator; the typed obs
        events carry the same facts per cycle)."""
        return dict(self.tracker.as_log(), data_s=self.data_s,
                    n_solves=self.n_solves)

    # -- background (previous-generation) flows ------------------------
    def set_background(self, src, dst, meta):
        """Queue carried-over transfers (one CHUNK per entry) that soak
        the residual capacity of every subsequent foreground cycle at
        strict lower priority (the foreground never slows down).

        ``meta`` is an opaque per-entry id echoed back by
        :meth:`background_log` / :meth:`background_remaining` so the
        caller (the async session) can map deliveries to generation /
        owner bookkeeping.  Queue order is pipeline priority within each
        (src, dst) pair.  Background flows only progress while a
        foreground cycle is in flight (idle directive cycles pause them)
        — the tail shares the swarm's duty cycle instead of getting a
        free private channel.
        """
        self._bg_src = np.asarray(src, np.int64).copy()
        self._bg_dst = np.asarray(dst, np.int64).copy()
        self._bg_meta = np.asarray(meta, np.int64).copy()
        self._bg_rem = np.full(len(self._bg_src), self.chunk_bytes,
                               np.float64)
        if not (len(self._bg_src) == len(self._bg_dst)
                == len(self._bg_meta)):
            raise ValueError("background arrays must align")

    def drain_background(self):
        """Solo-transport the queued background to completion (no
        foreground contention): the synchronous-boundary tail drain of
        ``tail_mode="drain"``.  Advances the wall clock by the drain
        makespan and returns ``(meta, t_start, t_end)`` with stamps
        RELATIVE to the drain start."""
        t0 = self.t
        if self._bg_src.size == 0:
            z = np.zeros(0, np.float64)
            return np.zeros(0, np.int64), z, z
        mark = len(self._bg_log)
        self._transport(np.zeros(0, np.int64), np.zeros(0, np.int64),
                        t0, deliver_all_bg=True)
        batches = self._bg_log[mark:]
        meta = np.concatenate([b["meta"] for b in batches])
        ts = np.concatenate([b["t_start"] for b in batches]) - t0
        te = np.concatenate([b["t_end"] for b in batches]) - t0
        dur = float(te.max(initial=0.0))
        self.t = t0 + dur
        self.data_s += dur
        return meta, ts, te

    def background_log(self) -> dict:
        """All background deliveries so far: dict of aligned ``meta``,
        ``src``, ``dst``, ``t_start``, ``t_end`` arrays (absolute engine
        time)."""
        if not self._bg_log:
            z = np.zeros(0, np.float64)
            zi = np.zeros(0, np.int64)
            return {"meta": zi, "src": zi.copy(), "dst": zi.copy(),
                    "t_start": z, "t_end": z.copy()}
        return {k: np.concatenate([b[k] for b in self._bg_log])
                for k in ("meta", "src", "dst", "t_start", "t_end")}

    def background_remaining(self) -> np.ndarray:
        """Meta ids still queued (undelivered) — requeue for the next
        round's engine."""
        return self._bg_meta.copy()
