"""Max-min fair-share flow allocation over access links (repro.net).

The event engine models every scheduled (sender, receiver) pair of a
stage as one *flow* over two shared resources — the sender's uplink and
the receiver's downlink, both in raw bytes/s — and allocates rates by
**progressive filling**: all flows ramp together, a link saturates when
its remaining capacity divided by its unfrozen-flow count is reached,
flows crossing a saturated link freeze at the current fill level, and
the rest keep ramping.  The fixed point is the classic max-min fair
allocation (no flow's rate can grow without shrinking a smaller one).

Both solvers run as fixed-shape jitted JAX kernels (float64 under a
scoped ``enable_x64``): :func:`maxmin_rates` is one ``lax.while_loop``
over freeze masks (one bottleneck level per pass, the same truncated
feasible tail fill past ``max_passes``), and :func:`transport` stages
the whole segment loop — nested water-fill solve, flow-death masking,
quantum-batched finish events and per-chunk crossing emission over a
padded chunk grid — in a single ``lax.while_loop`` whose carry replaces
the host's ``while alive.any()``.  Flow and chunk extents pad to powers
of two so re-solves across cycles hit a handful of compiled shapes; pad
flows are dead on entry and pad chunk rows can never satisfy a crossing
predicate, so padding never changes an allocation.  The pre-jax host
implementations are kept verbatim (``_maxmin_host``/``_transport_host``)
as the fallback when jax is absent; kernels mirror their exact IEEE
operation order, so the two paths agree to rounding.

Chunk-level completion instants come from the piecewise-linear
delivered-bytes curve of each flow: chunks are pipelined back-to-back
over the flow (BitTorrent keeps a connection's pipe full), so chunk
``j`` completes when ``j * chunk_bytes`` cumulative bytes have arrived.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

from repro import obs

try:                                    # same graceful degradation as
    import jax                          # core.jit_engine: repro.net
    import jax.numpy as jnp             # stays importable without jax
    from jax import lax
    from jax.experimental import enable_x64
    _HAS_JAX = True
except Exception:                       # pragma: no cover - env-specific
    _HAS_JAX = False

_EPS = 1e-9


def _pow2(x) -> int:
    """Smallest power of two >= x (>= 1): static kernel extents."""
    x = int(x)
    return 1 if x <= 1 else 1 << (x - 1).bit_length()


# ---------------------------------------------------------------------------
# max-min progressive filling
# ---------------------------------------------------------------------------

def maxmin_rates(src: np.ndarray, dst: np.ndarray,
                 up: np.ndarray, down: np.ndarray,
                 max_passes: int = 16) -> np.ndarray:
    """Max-min fair rates (bytes/s) for flows ``src[f] -> dst[f]``.

    ``up``/``down`` are per-peer access-link capacities in bytes/s.
    Flows whose uplink or downlink has no capacity get rate 0.

    Progressive filling freezes one bottleneck *level* per pass; with
    heterogeneous links a stage can have O(#links) distinct levels, so
    after ``max_passes`` exact levels the remaining (least-constrained)
    flows are finished with one feasible min-share fill — each takes
    ``fill + min(residual_up / n_up, residual_down / n_down)``, which
    never oversubscribes a link and coincides with the exact fixpoint
    whenever one pass would have finished anyway.  Small stages and the
    homogeneous limit are always exact.
    """
    if len(src) == 0:
        return np.zeros(0, np.float64)
    s = np.asarray(src, np.int64)
    d = np.asarray(dst, np.int64)
    u = np.asarray(up, np.float64)
    w = np.asarray(down, np.float64)
    rec = obs.get()
    if rec.enabled:
        rec.counter("fairshare.maxmin_calls")
    if _HAS_JAX:
        return _maxmin_jax(s, d, u, w, max_passes)
    return _maxmin_host(s, d, u, w, max_passes)


def _maxmin_host(src, dst, up, down, max_passes):
    """Host progressive filling (pre-jax reference / no-jax fallback)."""
    f = src.size
    n = len(up)
    cap_up = up.copy()
    cap_down = down.copy()
    rates = np.zeros(f, np.float64)
    unfrozen = (cap_up[src] > _EPS) & (cap_down[dst] > _EPS)
    fill = 0.0
    slack_u = _EPS * np.maximum(up, 1.0)
    slack_d = _EPS * np.maximum(down, 1.0)
    # Each pass saturates >= 1 link, so <= 2n passes; the tail fill
    # bounds the worst case.
    for _ in range(max_passes):
        if not unfrozen.any():
            return rates
        nu = np.bincount(src[unfrozen], minlength=n).astype(np.float64)
        nd = np.bincount(dst[unfrozen], minlength=n).astype(np.float64)
        with np.errstate(divide="ignore", invalid="ignore"):
            tu = np.where(nu > 0, cap_up / nu, np.inf)
            td = np.where(nd > 0, cap_down / nd, np.inf)
        t = min(tu.min(), td.min())
        fill += t
        cap_up -= t * nu
        cap_down -= t * nd
        # Freeze flows through any just-saturated link (relative slack
        # so heterogeneous-magnitude links compare fairly).
        sat_u = (nu > 0) & (cap_up <= slack_u)
        sat_d = (nd > 0) & (cap_down <= slack_d)
        freeze = unfrozen & (sat_u[src] | sat_d[dst])
        if not freeze.any():        # numerical stall: freeze everything
            freeze = unfrozen
        rates[freeze] = fill
        unfrozen &= ~freeze
    if unfrozen.any():
        # Truncated tail: one feasible min-share fill for the rest.
        nu = np.bincount(src[unfrozen], minlength=n).astype(np.float64)
        nd = np.bincount(dst[unfrozen], minlength=n).astype(np.float64)
        with np.errstate(divide="ignore", invalid="ignore"):
            su = np.where(nu > 0, cap_up / nu, np.inf)
            sd = np.where(nd > 0, cap_down / nd, np.inf)
        share = np.minimum(su[src], sd[dst])
        rates[unfrozen] = fill + np.maximum(share[unfrozen], 0.0)
    return rates


def _maxmin_fill(src, dst, up, down, unfrozen0, max_passes: int):
    """Traced progressive filling over a fixed flow extent.

    The staged twin of :func:`_maxmin_host`: one ``lax.while_loop``
    iteration per bottleneck level, freeze masks in place of boolean
    indexing, the same stall guard and truncated feasible tail.  Also
    inlined per segment by the transport kernel.
    """
    n = up.shape[0]
    f_pad = src.shape[0]
    slack_u = _EPS * jnp.maximum(up, 1.0)
    slack_d = _EPS * jnp.maximum(down, 1.0)

    def counts(unfrozen):
        w = jnp.where(unfrozen, 1.0, 0.0)
        nu = jnp.zeros(n, jnp.float64).at[src].add(w)
        nd = jnp.zeros(n, jnp.float64).at[dst].add(w)
        return nu, nd

    def cond(c):
        i, unfrozen = c[0], c[1]
        return (i < max_passes) & jnp.any(unfrozen)

    def body(c):
        i, unfrozen, cap_up, cap_down, rates, fill = c
        nu, nd = counts(unfrozen)
        tu = jnp.where(nu > 0, cap_up / nu, jnp.inf)
        td = jnp.where(nd > 0, cap_down / nd, jnp.inf)
        t = jnp.minimum(jnp.min(tu), jnp.min(td))
        fill = fill + t
        cap_up = cap_up - t * nu
        cap_down = cap_down - t * nd
        sat_u = (nu > 0) & (cap_up <= slack_u)
        sat_d = (nd > 0) & (cap_down <= slack_d)
        freeze = unfrozen & (sat_u[src] | sat_d[dst])
        freeze = jnp.where(jnp.any(freeze), freeze, unfrozen)
        rates = jnp.where(freeze, fill, rates)
        return (i + 1, unfrozen & ~freeze, cap_up, cap_down, rates, fill)

    init = (jnp.int32(0), unfrozen0, up, down,
            jnp.zeros(f_pad, jnp.float64), jnp.float64(0.0))
    _, unfrozen, cap_up, cap_down, rates, fill = lax.while_loop(
        cond, body, init)
    nu, nd = counts(unfrozen)
    su = jnp.where(nu > 0, cap_up / nu, jnp.inf)
    sd = jnp.where(nd > 0, cap_down / nd, jnp.inf)
    share = jnp.minimum(su[src], sd[dst])
    return jnp.where(unfrozen, fill + jnp.maximum(share, 0.0), rates)


@functools.lru_cache(maxsize=None)
def _maxmin_compiled(n: int, f_pad: int, max_passes: int):
    def kern(src, dst, up, down, valid):
        unfrozen0 = valid & (up[src] > _EPS) & (down[dst] > _EPS)
        return _maxmin_fill(src, dst, up, down, unfrozen0, max_passes)
    return jax.jit(kern)


def _maxmin_jax(src, dst, up, down, max_passes):
    f = len(src)
    f_pad = _pow2(f)
    sp = np.zeros(f_pad, np.int64)
    dp = np.zeros(f_pad, np.int64)
    sp[:f] = src
    dp[:f] = dst
    valid = np.zeros(f_pad, bool)
    valid[:f] = True
    with enable_x64():
        r = _maxmin_compiled(len(up), f_pad, int(max_passes))(
            sp, dp, up, down, valid)
        return np.asarray(r)[:f]


# ---------------------------------------------------------------------------
# chunked transport
# ---------------------------------------------------------------------------

@dataclass
class FlowTimings:
    """Result of :func:`transport`.

    ``finish``      (F,) completion instant of each flow (relative to
                    the stage start, seconds).
    ``chunk_flow``  (M,) flow index of each delivered chunk, grouped by
                    flow in pipeline order (chunk rank ascending).
    ``chunk_end``   (M,) completion instant of each chunk.
    ``makespan``    instant the last flow finished.
    ``n_solves``    water-filling re-solves performed (diagnostics).
    """

    finish: np.ndarray
    chunk_flow: np.ndarray
    chunk_end: np.ndarray
    makespan: float
    n_solves: int

    def chunk_starts(self) -> np.ndarray:
        """Pipelined start instant of each chunk (see
        :func:`pipeline_starts`)."""
        return pipeline_starts(self.chunk_flow, self.chunk_end)


def pipeline_starts(chunk_flow: np.ndarray,
                    chunk_end: np.ndarray) -> np.ndarray:
    """Pipelined start instant of each chunk: the previous chunk's
    completion within the same flow (0.0 for each flow's first).
    ``chunk_flow`` must be grouped by flow with ``chunk_end``
    non-decreasing within each group."""
    starts = np.zeros_like(chunk_end)
    if len(starts) == 0:
        return starts
    same = np.zeros(len(starts), dtype=bool)
    same[1:] = chunk_flow[1:] == chunk_flow[:-1]
    starts[same] = chunk_end[:-1][same[1:]]
    return starts


def congestion_bound(src: np.ndarray, dst: np.ndarray,
                     nbytes: np.ndarray, up: np.ndarray,
                     down: np.ndarray) -> float:
    """Congestion lower bound (seconds) on moving ``nbytes[f]`` bytes
    over flows ``src[f] -> dst[f]``: no transport discipline can beat
    the busiest access link.  The canonical implementation — both the
    transport quantum sizing below and the time-domain efficiency
    denominator (:func:`repro.core.maxflow.stage_time_lower_bound`)
    use it, so the two can never desynchronize."""
    n = len(up)
    out_b = np.bincount(src, weights=nbytes, minlength=n)
    in_b = np.bincount(dst, weights=nbytes, minlength=n)
    with np.errstate(divide="ignore", invalid="ignore"):
        t_up = np.where(out_b > 0, out_b / np.maximum(
            np.asarray(up, np.float64), _EPS), 0.0)
        t_dn = np.where(in_b > 0, in_b / np.maximum(
            np.asarray(down, np.float64), _EPS), 0.0)
    return float(max(t_up.max(initial=0.0), t_dn.max(initial=0.0)))


def transport(src: np.ndarray, dst: np.ndarray, counts: np.ndarray,
              chunk_bytes: float, up: np.ndarray, down: np.ndarray,
              *, quantum_frac: float = 1 / 64) -> FlowTimings:
    """Simulate max-min fair-share transport of chunked flows.

    Flow ``f`` carries ``counts[f]`` pipelined chunks of ``chunk_bytes``
    bytes from ``src[f]`` to ``dst[f]``.  Rates are re-solved at flow
    finish events, batched on a time quantum of ``quantum_frac`` of the
    congestion lower bound so the number of solves stays bounded: a
    flow finishing mid-segment still records its *exact* finish instant
    under its current rate; only the redistribution of its freed
    capacity waits for the segment boundary.  ``quantum_frac=0`` gives
    the exact per-event progressive-filling process.
    """
    if len(src) == 0:
        return FlowTimings(np.zeros(0), np.zeros(0, np.int64),
                           np.zeros(0), 0.0, 0)
    s = np.asarray(src, np.int64)
    d = np.asarray(dst, np.int64)
    c = np.asarray(counts, np.int64)
    u = np.asarray(up, np.float64)
    w = np.asarray(down, np.float64)
    nbytes = c.astype(np.float64) * float(chunk_bytes)
    # Congestion lower bound on the makespan: the busiest access link.
    lb = congestion_bound(s, d, nbytes, u, w)
    quantum = quantum_frac * lb
    if _HAS_JAX:
        tm = _transport_jax(s, d, c, nbytes,
                            float(chunk_bytes), u, w, quantum)
    else:
        tm = _transport_host(s, d, c, nbytes,
                             float(chunk_bytes), u, w, quantum)
    rec = obs.get()
    if rec.enabled:
        rec.counter("fairshare.transport_calls")
        rec.counter("fairshare.solves", tm.n_solves)
    return tm


def _transport_host(src, dst, counts, nbytes, chunk_bytes, up, down,
                    quantum):
    """Host segment loop (pre-jax reference / no-jax fallback)."""
    f = src.size
    rem = nbytes.copy()
    delivered = np.zeros(f, np.float64)
    finish = np.full(f, np.inf, np.float64)
    alive = rem > 0
    finish[~alive] = 0.0

    cf_parts: list[np.ndarray] = []
    ce_parts: list[np.ndarray] = []
    t = 0.0
    n_solves = 0
    while alive.any():
        idx = np.flatnonzero(alive)
        r = maxmin_rates(src[idx], dst[idx], up, down)
        n_solves += 1
        dead = r <= _EPS
        if dead.any():
            # No capacity left for these flows (caller scheduled onto a
            # zero-rate link): they can never complete.
            alive[idx[dead]] = False
            idx, r = idx[~dead], r[~dead]
            if idx.size == 0:
                break
        ttf = rem[idx] / r
        dt = max(float(ttf.min()), quantum)
        adv = np.minimum(r * dt, rem[idx])
        # Chunk boundaries crossed inside this segment, per flow.
        old = delivered[idx]
        new = old + adv
        k0 = np.floor(old / chunk_bytes + _EPS).astype(np.int64)
        k1 = np.minimum(np.floor(new / chunk_bytes + _EPS), counts[idx]
                        ).astype(np.int64)
        ncross = k1 - k0
        if ncross.sum() > 0:
            which = np.flatnonzero(ncross > 0)
            reps = ncross[which]
            fl = np.repeat(idx[which], reps)
            base = np.repeat(k0[which], reps)
            off = np.arange(reps.sum()) - np.repeat(
                np.cumsum(reps) - reps, reps)
            kk = base + off + 1                     # 1-based chunk rank
            rr = np.repeat(r[which], reps)
            oo = np.repeat(old[which], reps)
            ce_parts.append(t + (kk * chunk_bytes - oo) / rr)
            cf_parts.append(fl)
        t += dt
        delivered[idx] = new
        rem[idx] -= adv
        done = rem[idx] <= _EPS * chunk_bytes
        if done.any():
            # Exact finish instants under the segment's constant rates.
            finish[idx[done]] = t - dt + ttf[done]
            alive[idx[done]] = False

    if cf_parts:
        chunk_flow = np.concatenate(cf_parts)
        chunk_end = np.concatenate(ce_parts)
        o = np.lexsort((chunk_end, chunk_flow))
        chunk_flow, chunk_end = chunk_flow[o], chunk_end[o]
    else:
        chunk_flow = np.zeros(0, np.int64)
        chunk_end = np.zeros(0, np.float64)
    fin = finish[np.isfinite(finish)]
    makespan = float(fin.max(initial=0.0))
    return FlowTimings(finish=finish, chunk_flow=chunk_flow,
                       chunk_end=chunk_end, makespan=makespan,
                       n_solves=n_solves)


@functools.lru_cache(maxsize=None)
def _transport_compiled(n: int, f_pad: int, m_pad: int,
                        max_passes: int):
    """Whole-segment-loop transport kernel over fixed extents.

    Carries the host loop's entire mutable state — wall clock, per-flow
    residual bytes, delivered curve, finish instants, alive mask, the
    padded per-chunk completion grid and the solve counter — through
    one ``lax.while_loop``.  Chunk rows record a completion instant the
    segment their 1-based rank is crossed by the flow's delivered-bytes
    curve; rows never crossed (dead flows, padding) stay ``inf`` and
    are dropped at the host boundary, reproducing the host path's
    emit-on-cross behaviour exactly.
    """

    def kern(src, dst, counts_f, nbytes, cb, up, down, quantum,
             c_flow, c_rank):

        def cond(carry):
            return jnp.any(carry[4])

        def body(carry):
            t, rem, delivered, finish, alive, cend, nsol = carry
            unfrozen0 = (alive & (up[src] > _EPS)
                         & (down[dst] > _EPS))
            r = _maxmin_fill(src, dst, up, down, unfrozen0, max_passes)
            nsol = nsol + 1
            live = alive & (r > _EPS)       # dead: no capacity, ever
            ttf = jnp.where(live, rem / jnp.where(live, r, 1.0),
                            jnp.inf)
            tmin = jnp.min(ttf)
            dt = jnp.where(jnp.isfinite(tmin),
                           jnp.maximum(tmin, quantum), 0.0)
            adv = jnp.where(live, jnp.minimum(r * dt, rem), 0.0)
            old = delivered
            new = old + adv
            k0 = jnp.floor(old / cb + _EPS).astype(jnp.int64)
            k1 = jnp.minimum(jnp.floor(new / cb + _EPS),
                             counts_f).astype(jnp.int64)
            crossed = (c_rank > k0[c_flow]) & (c_rank <= k1[c_flow])
            endv = t + (c_rank.astype(jnp.float64) * cb
                        - old[c_flow]) / jnp.where(
                            crossed, r[c_flow], 1.0)
            cend = jnp.where(crossed, endv, cend)
            rem = rem - adv
            done = live & (rem <= _EPS * cb)
            finish = jnp.where(done, t + ttf, finish)
            return (t + dt, rem, new, finish, live & ~done, cend, nsol)

        alive0 = nbytes > 0
        init = (jnp.float64(0.0), nbytes,
                jnp.zeros(f_pad, jnp.float64),
                jnp.where(alive0, jnp.inf, 0.0), alive0,
                jnp.full(m_pad, jnp.inf, jnp.float64), jnp.int32(0))
        out = lax.while_loop(cond, body, init)
        return out[3], out[5], out[6]

    return jax.jit(kern)


def _transport_jax(src, dst, counts, nbytes, chunk_bytes, up, down,
                   quantum):
    f = len(src)
    f_pad = _pow2(f)
    total = int(counts.sum())
    m_pad = _pow2(max(total, 1))
    sp = np.zeros(f_pad, np.int64)
    dp = np.zeros(f_pad, np.int64)
    cp = np.zeros(f_pad, np.float64)     # counts as float: k1 clamp
    bp = np.zeros(f_pad, np.float64)
    sp[:f] = src
    dp[:f] = dst
    cp[:f] = counts
    bp[:f] = nbytes
    # Chunk grid in (flow, rank) order — precisely the host path's
    # final lexsort((chunk_end, chunk_flow)) order, because ends are
    # strictly increasing with rank inside a flow.
    c_flow = np.zeros(m_pad, np.int64)
    c_rank = np.zeros(m_pad, np.int64)   # rank 0 pads can never cross
    c_flow[:total] = np.repeat(np.arange(f, dtype=np.int64), counts)
    c_rank[:total] = (np.arange(total, dtype=np.int64)
                      - np.repeat(np.cumsum(counts) - counts, counts)
                      + 1)
    with enable_x64():
        fin_d, cend_d, nsol_d = _transport_compiled(
            len(up), f_pad, m_pad, 16)(
                sp, dp, cp, bp, np.float64(chunk_bytes), up, down,
                np.float64(quantum), c_flow, c_rank)
        finish = np.asarray(fin_d)[:f]
        cend = np.asarray(cend_d)[:total]
        n_solves = int(np.asarray(nsol_d))
    emitted = np.isfinite(cend)
    fin = finish[np.isfinite(finish)]
    return FlowTimings(finish=finish, chunk_flow=c_flow[:total][emitted],
                       chunk_end=cend[emitted],
                       makespan=float(fin.max(initial=0.0)),
                       n_solves=n_solves)
