"""Max-min fair-share flow allocation over access links (repro.net).

The event engine models every scheduled (sender, receiver) pair of a
stage as one *flow* over two shared resources — the sender's uplink and
the receiver's downlink, both in raw bytes/s — and allocates rates by
**progressive filling**: all flows ramp together, a link saturates when
its remaining capacity divided by its unfrozen-flow count is reached,
flows crossing a saturated link freeze at the current fill level, and
the rest keep ramping.  The fixed point is the classic max-min fair
allocation (no flow's rate can grow without shrinking a smaller one).

Everything is vectorized over the active flow set: one water-filling
solve is a handful of ``np.bincount`` passes (one per saturated-link
group, at most ``O(#links)`` but typically a few), and the transport
simulation re-solves only at flow-finish events, batched on a time
quantum so the number of re-solves is bounded regardless of flow count
— there is no per-event Python re-solve over individual flows.

Chunk-level completion instants come from the piecewise-linear
delivered-bytes curve of each flow: chunks are pipelined back-to-back
over the flow (BitTorrent keeps a connection's pipe full), so chunk
``j`` completes when ``j * chunk_bytes`` cumulative bytes have arrived.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

_EPS = 1e-9


def maxmin_rates(src: np.ndarray, dst: np.ndarray,
                 up: np.ndarray, down: np.ndarray,
                 max_passes: int = 16) -> np.ndarray:
    """Max-min fair rates (bytes/s) for flows ``src[f] -> dst[f]``.

    ``up``/``down`` are per-peer access-link capacities in bytes/s.
    Flows whose uplink or downlink has no capacity get rate 0.

    Progressive filling freezes one bottleneck *level* per pass; with
    heterogeneous links a stage can have O(#links) distinct levels, so
    after ``max_passes`` exact levels the remaining (least-constrained)
    flows are finished with one feasible min-share fill — each takes
    ``fill + min(residual_up / n_up, residual_down / n_down)``, which
    never oversubscribes a link and coincides with the exact fixpoint
    whenever one pass would have finished anyway.  Small stages and the
    homogeneous limit are always exact.
    """
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    f = src.size
    if f == 0:
        return np.zeros(0, np.float64)
    n = len(up)
    up = np.asarray(up, np.float64)
    down = np.asarray(down, np.float64)
    cap_up = up.copy()
    cap_down = down.copy()
    rates = np.zeros(f, np.float64)
    unfrozen = (cap_up[src] > _EPS) & (cap_down[dst] > _EPS)
    fill = 0.0
    slack_u = _EPS * np.maximum(up, 1.0)
    slack_d = _EPS * np.maximum(down, 1.0)
    # Each pass saturates >= 1 link, so <= 2n passes; the tail fill
    # bounds the worst case.
    for _ in range(max_passes):
        if not unfrozen.any():
            return rates
        nu = np.bincount(src[unfrozen], minlength=n).astype(np.float64)
        nd = np.bincount(dst[unfrozen], minlength=n).astype(np.float64)
        with np.errstate(divide="ignore", invalid="ignore"):
            tu = np.where(nu > 0, cap_up / nu, np.inf)
            td = np.where(nd > 0, cap_down / nd, np.inf)
        t = min(tu.min(), td.min())
        fill += t
        cap_up -= t * nu
        cap_down -= t * nd
        # Freeze flows through any just-saturated link (relative slack
        # so heterogeneous-magnitude links compare fairly).
        sat_u = (nu > 0) & (cap_up <= slack_u)
        sat_d = (nd > 0) & (cap_down <= slack_d)
        freeze = unfrozen & (sat_u[src] | sat_d[dst])
        if not freeze.any():        # numerical stall: freeze everything
            freeze = unfrozen
        rates[freeze] = fill
        unfrozen &= ~freeze
    if unfrozen.any():
        # Truncated tail: one feasible min-share fill for the rest.
        nu = np.bincount(src[unfrozen], minlength=n).astype(np.float64)
        nd = np.bincount(dst[unfrozen], minlength=n).astype(np.float64)
        with np.errstate(divide="ignore", invalid="ignore"):
            su = np.where(nu > 0, cap_up / nu, np.inf)
            sd = np.where(nd > 0, cap_down / nd, np.inf)
        share = np.minimum(su[src], sd[dst])
        rates[unfrozen] = fill + np.maximum(share[unfrozen], 0.0)
    return rates


@dataclass
class FlowTimings:
    """Result of :func:`transport`.

    ``finish``      (F,) completion instant of each flow (relative to
                    the stage start, seconds).
    ``chunk_flow``  (M,) flow index of each delivered chunk, grouped by
                    flow in pipeline order (chunk rank ascending).
    ``chunk_end``   (M,) completion instant of each chunk.
    ``makespan``    instant the last flow finished.
    ``n_solves``    water-filling re-solves performed (diagnostics).
    """

    finish: np.ndarray
    chunk_flow: np.ndarray
    chunk_end: np.ndarray
    makespan: float
    n_solves: int

    def chunk_starts(self) -> np.ndarray:
        """Pipelined start instant of each chunk (see
        :func:`pipeline_starts`)."""
        return pipeline_starts(self.chunk_flow, self.chunk_end)


def pipeline_starts(chunk_flow: np.ndarray,
                    chunk_end: np.ndarray) -> np.ndarray:
    """Pipelined start instant of each chunk: the previous chunk's
    completion within the same flow (0.0 for each flow's first).
    ``chunk_flow`` must be grouped by flow with ``chunk_end``
    non-decreasing within each group."""
    starts = np.zeros_like(chunk_end)
    if len(starts) == 0:
        return starts
    same = np.zeros(len(starts), dtype=bool)
    same[1:] = chunk_flow[1:] == chunk_flow[:-1]
    starts[same] = chunk_end[:-1][same[1:]]
    return starts


def congestion_bound(src: np.ndarray, dst: np.ndarray,
                     nbytes: np.ndarray, up: np.ndarray,
                     down: np.ndarray) -> float:
    """Congestion lower bound (seconds) on moving ``nbytes[f]`` bytes
    over flows ``src[f] -> dst[f]``: no transport discipline can beat
    the busiest access link.  The canonical implementation — both the
    transport quantum sizing below and the time-domain efficiency
    denominator (:func:`repro.core.maxflow.stage_time_lower_bound`)
    use it, so the two can never desynchronize."""
    n = len(up)
    out_b = np.bincount(src, weights=nbytes, minlength=n)
    in_b = np.bincount(dst, weights=nbytes, minlength=n)
    with np.errstate(divide="ignore", invalid="ignore"):
        t_up = np.where(out_b > 0, out_b / np.maximum(
            np.asarray(up, np.float64), _EPS), 0.0)
        t_dn = np.where(in_b > 0, in_b / np.maximum(
            np.asarray(down, np.float64), _EPS), 0.0)
    return float(max(t_up.max(initial=0.0), t_dn.max(initial=0.0)))


def transport(src: np.ndarray, dst: np.ndarray, counts: np.ndarray,
              chunk_bytes: float, up: np.ndarray, down: np.ndarray,
              *, quantum_frac: float = 1 / 64) -> FlowTimings:
    """Simulate max-min fair-share transport of chunked flows.

    Flow ``f`` carries ``counts[f]`` pipelined chunks of ``chunk_bytes``
    bytes from ``src[f]`` to ``dst[f]``.  Rates are re-solved at flow
    finish events, batched on a time quantum of ``quantum_frac`` of the
    congestion lower bound so the number of solves stays bounded: a
    flow finishing mid-segment still records its *exact* finish instant
    under its current rate; only the redistribution of its freed
    capacity waits for the segment boundary.  ``quantum_frac=0`` gives
    the exact per-event progressive-filling process.
    """
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    counts = np.asarray(counts, np.int64)
    f = src.size
    if f == 0:
        return FlowTimings(np.zeros(0), np.zeros(0, np.int64),
                           np.zeros(0), 0.0, 0)
    nbytes = counts.astype(np.float64) * float(chunk_bytes)
    rem = nbytes.copy()
    delivered = np.zeros(f, np.float64)
    finish = np.full(f, np.inf, np.float64)
    alive = rem > 0
    finish[~alive] = 0.0

    # Congestion lower bound on the makespan: the busiest access link.
    lb = congestion_bound(src, dst, nbytes, up, down)
    quantum = quantum_frac * lb

    cf_parts: list[np.ndarray] = []
    ce_parts: list[np.ndarray] = []
    t = 0.0
    n_solves = 0
    while alive.any():
        idx = np.flatnonzero(alive)
        r = maxmin_rates(src[idx], dst[idx], up, down)
        n_solves += 1
        dead = r <= _EPS
        if dead.any():
            # No capacity left for these flows (caller scheduled onto a
            # zero-rate link): they can never complete.
            alive[idx[dead]] = False
            idx, r = idx[~dead], r[~dead]
            if idx.size == 0:
                break
        ttf = rem[idx] / r
        dt = max(float(ttf.min()), quantum)
        adv = np.minimum(r * dt, rem[idx])
        # Chunk boundaries crossed inside this segment, per flow.
        old = delivered[idx]
        new = old + adv
        k0 = np.floor(old / chunk_bytes + _EPS).astype(np.int64)
        k1 = np.minimum(np.floor(new / chunk_bytes + _EPS), counts[idx]
                        ).astype(np.int64)
        ncross = k1 - k0
        if ncross.sum() > 0:
            which = np.flatnonzero(ncross > 0)
            reps = ncross[which]
            fl = np.repeat(idx[which], reps)
            base = np.repeat(k0[which], reps)
            off = np.arange(reps.sum()) - np.repeat(
                np.cumsum(reps) - reps, reps)
            kk = base + off + 1                     # 1-based chunk rank
            rr = np.repeat(r[which], reps)
            oo = np.repeat(old[which], reps)
            ce_parts.append(t + (kk * chunk_bytes - oo) / rr)
            cf_parts.append(fl)
        t += dt
        delivered[idx] = new
        rem[idx] -= adv
        done = rem[idx] <= _EPS * chunk_bytes
        if done.any():
            # Exact finish instants under the segment's constant rates.
            finish[idx[done]] = t - dt + ttf[done]
            alive[idx[done]] = False

    if cf_parts:
        chunk_flow = np.concatenate(cf_parts)
        chunk_end = np.concatenate(ce_parts)
        o = np.lexsort((chunk_end, chunk_flow))
        chunk_flow, chunk_end = chunk_flow[o], chunk_end[o]
    else:
        chunk_flow = np.zeros(0, np.int64)
        chunk_end = np.zeros(0, np.float64)
    fin = finish[np.isfinite(finish)]
    makespan = float(fin.max(initial=0.0))
    return FlowTimings(finish=finish, chunk_flow=chunk_flow,
                       chunk_end=chunk_end, makespan=makespan,
                       n_solves=n_solves)
