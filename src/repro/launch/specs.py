"""Per-cell step functions, ShapeDtypeStruct inputs, and shardings.

``build_cell(cfg, shape, mesh)`` returns everything the dry-run needs:

    step        — the function to jit (train / prefill / serve)
    args        — ShapeDtypeStruct stand-ins (no device allocation)
    in_specs    — matching PartitionSpec tree
    out_specs   — or None (XLA chooses)

Input layouts per shape kind (assignment):
    train    batch = {inputs (P, B/P, T) i32, labels same}  + params/opt
    prefill  inputs (B, T) i32 (hubert: (B, T, D) f32 frames)
    decode   caches @ seq_len, tokens (B,) i32, pos () i32
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ShapeSpec
from repro.dist.fl_step import make_fl_train_step, make_serve_step
from repro.models import (ArchConfig, forward, init_decode_cache,
                          init_params, prefill)
from repro.optim import adamw_init
from repro.optim.schedules import linear_warmup_cosine
from repro.sharding.api import DEFAULT_RULES, _filter_axes, param_specs
from repro.launch.mesh import pod_axis_size


def to_shardings(mesh, tree):
    """PartitionSpec tree -> NamedSharding tree (None leaves pass)."""
    from jax.sharding import NamedSharding
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        tree, is_leaf=lambda x: isinstance(x, P))


def _batch_axes(mesh, size: int):
    """Mesh axes for a batch dim of ``size`` (pod+data, filtered)."""
    return _filter_axes(mesh, ("pod", "data"), size)


def _data_axes(mesh, size: int):
    return _filter_axes(mesh, "data", size)


def opt_state_specs(pspecs):
    from repro.optim.adamw import OptState
    return OptState(step=P(), master=pspecs, m=pspecs,
                    v=jax.tree_util.tree_map(lambda s: s, pspecs))


def cache_specs(cfg: ArchConfig, cache_shapes, mesh, batch: int):
    """PartitionSpecs for a decode-cache pytree.

    KV caches: shard batch over (pod, data); shard kv-heads over model
    when divisible, else fall back to sharding head_dim over model
    (GQA with few kv heads — attention then contracts a sharded dim and
    XLA inserts the all-reduce; memory is what matters at 32k/500k).
    Recurrent state: shard the feature dim over model.
    """
    b_ax = _batch_axes(mesh, batch)

    def spec(path, leaf):
        keys = [str(getattr(q, "key", getattr(q, "idx", ""))) for q in path]
        name = keys[-1]
        stacked = keys[0] == "cycles"
        off = 1 if stacked else 0
        shape = leaf.shape
        lead = (None,) if stacked else ()
        if name in ("k", "v"):                    # (B, kv, S, dh)
            kv_ax = _filter_axes(mesh, "model", shape[off + 1])
            dh_ax = None
            if kv_ax is None:
                dh_ax = _filter_axes(mesh, "model", shape[off + 3])
            return P(*lead, b_ax, kv_ax, None, dh_ax)
        if name == "h" and len(shape) == off + 2:  # rglru (B, dr)
            return P(*lead, b_ax, _filter_axes(mesh, "model",
                                               shape[off + 1]))
        if name == "conv":                         # (B, w-1, D)
            return P(*lead, b_ax, None,
                     _filter_axes(mesh, "model", shape[off + 2]))
        if name == "C":                            # (B, H, dh, dh)
            return P(*lead, b_ax, None, None,
                     _filter_axes(mesh, "model", shape[off + 3]))
        if name in ("n", "m", "c"):                # (B, H[, dh])
            parts = [b_ax] + [None] * (len(shape) - off - 1)
            return P(*lead, *parts)
        if name == "h":                            # slstm (B, H, dh)
            return P(*lead, b_ax, None, None)
        return P(*((None,) * len(shape)))

    return jax.tree_util.tree_map_with_path(spec, cache_shapes)


def build_cell(cfg: ArchConfig, shape: ShapeSpec, mesh, *,
               rules: dict | None = None, microbatch: int = 0,
               torrent_blocks: int = 4, compress: bool = False,
               ce_chunk: int = 512):
    """Returns dict(step, args, in_specs, out_specs, meta)."""
    rules = dict(DEFAULT_RULES if rules is None else rules)
    n_pods = pod_axis_size(mesh)
    key = jax.random.PRNGKey(0)
    params_sh = jax.eval_shape(functools.partial(init_params, cfg), key)
    pspecs = param_specs(params_sh, mesh, rules)

    if shape.kind == "train":
        b_local = shape.global_batch // n_pods
        tok_t = jnp.int32
        if cfg.has_embedding:
            inp = jax.ShapeDtypeStruct((n_pods, b_local, shape.seq_len),
                                       tok_t)
        else:
            inp = jax.ShapeDtypeStruct(
                (n_pods, b_local, shape.seq_len, cfg.d_model),
                jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
        lab = jax.ShapeDtypeStruct((n_pods, b_local, shape.seq_len),
                                   tok_t)
        opt_sh = jax.eval_shape(adamw_init, params_sh)
        ospecs = opt_state_specs(pspecs)
        batch_spec = {
            "inputs": P("pod" if n_pods > 1 else None,
                        _data_axes(mesh, b_local),
                        *([None] * (len(inp.shape) - 2))),
            "labels": P("pod" if n_pods > 1 else None,
                        _data_axes(mesh, b_local), None),
        }
        step = make_fl_train_step(
            cfg, mesh, lr_schedule=linear_warmup_cosine(3e-4, 100, 10000),
            n_pods=n_pods, rules=rules, torrent_blocks=torrent_blocks,
            compress=compress, microbatch=microbatch, ce_chunk=ce_chunk)
        args = (params_sh, opt_sh,
                {"inputs": inp, "labels": lab},
                jax.ShapeDtypeStruct((n_pods,), jnp.float32),
                jax.ShapeDtypeStruct((n_pods,), jnp.float32))
        in_specs = (pspecs, ospecs, batch_spec, P(), P())
        out_specs = (pspecs, ospecs, {"loss": P(), "lr": P()})
        return dict(step=step, args=args, in_specs=in_specs,
                    out_specs=out_specs,
                    meta=dict(kind="train", n_pods=n_pods,
                              tokens=shape.global_batch * shape.seq_len))

    if shape.kind == "prefill":
        b = shape.global_batch
        b_ax = _batch_axes(mesh, b)
        if cfg.has_embedding:
            inp = jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32)
            in_sp = P(b_ax, None)
        else:
            inp = jax.ShapeDtypeStruct(
                (b, shape.seq_len, cfg.d_model),
                jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
            in_sp = P(b_ax, None, None)
        if cfg.causal:
            def step(p, x):
                return prefill(cfg, p, x, max_len=shape.seq_len)
        else:
            def step(p, x):
                return forward(cfg, p, x)
        return dict(step=step, args=(params_sh, inp),
                    in_specs=(pspecs, in_sp), out_specs=None,
                    meta=dict(kind="prefill", n_pods=n_pods,
                              tokens=b * shape.seq_len))

    if shape.kind == "decode":
        # Serving has no optimizer state: ZeRO/FSDP sharding of weights
        # would all-gather params on every token step (§Perf global
        # lever — qwen3 decode_32k was collective-dominant because of
        # it).  Weights stay TP-sharded only — unless the TP-only
        # replica is too large next to the KV cache (chameleon-34B's
        # 4.3 GiB/device replica pushed the cell past 16 GiB), in which
        # case weight streaming stays sharded.
        tp = 1
        for ax, sz in zip(mesh.axis_names, mesh.devices.shape):
            if ax == "model":
                tp = int(sz)
        tp_replica_bytes = cfg.param_count() * 2 / max(tp, 1)
        if tp_replica_bytes <= 512 * 2**20:
            rules_serve = dict(rules)
            rules_serve["zero"] = None
            pspecs = param_specs(params_sh, mesh, rules_serve)
        b = shape.global_batch
        b_ax = _batch_axes(mesh, b)
        caches_sh = jax.eval_shape(
            lambda: init_decode_cache(cfg, b, shape.seq_len))
        cspecs = cache_specs(cfg, caches_sh, mesh, b)
        tokens = jax.ShapeDtypeStruct((b,), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        serve = make_serve_step(cfg)
        return dict(step=serve,
                    args=(params_sh, caches_sh, tokens, pos),
                    in_specs=(pspecs, cspecs, P(b_ax), P()),
                    out_specs=None,
                    meta=dict(kind="decode", n_pods=n_pods, tokens=b))

    raise ValueError(shape.kind)
