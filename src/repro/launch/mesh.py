"""Production mesh construction (a function — importing this module
never touches jax device state).

Axes:
    pod   — FL clients (FLTorrent dissemination axis; DP-outer)
    data  — within-client data parallel + ZeRO/FSDP shard axis
    model — tensor / expert parallel axis

Scaling story (DESIGN.md §5): capacity grows by adding pods (clients),
which is the paper's own scaling dimension (Table III shows flat
warm-up share from 100 to 500 peers) — ``n_pods`` is a parameter, not a
constant, and every collective in the torrent schedule is written for
general P.
"""
from __future__ import annotations

import jax

from repro.sharding.api import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False, n_pods: int = 2):
    shape = (n_pods, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes,
                     axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh over however many devices this host has (tests)."""
    return make_mesh(shape, axes,
                     axis_types=(AxisType.Auto,) * len(axes))


def make_pod_mesh(n_pods: int, *, data: int = 1, model: int = 1,
                  devices=None):
    """("pod", "data", "model") mesh over a *subset* of the host's
    devices — the elastic re-mesh entry point (§III-E).

    Dropping from P to P-1 pods keeps the first ``(P-1)*data*model``
    devices and rebuilds the mesh; the torrent ring schedule then
    re-lowers for the new pod axis automatically (its stage count is
    P-1).  ``devices`` overrides the host device list (tests).
    """
    need = n_pods * data * model
    devs = list(jax.devices() if devices is None else devices)
    if len(devs) < need:
        raise ValueError(f"{need} devices needed for pods={n_pods} x "
                         f"data={data} x model={model}; have {len(devs)}")
    return make_mesh((n_pods, data, model), ("pod", "data", "model"),
                     axis_types=(AxisType.Auto,) * 3,
                     devices=devs[:need])


def pod_axis_size(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(sizes.get("pod", 1))
