"""Production mesh construction (a function — importing this module
never touches jax device state).

Axes:
    pod   — FL clients (FLTorrent dissemination axis; DP-outer)
    data  — within-client data parallel + ZeRO/FSDP shard axis
    model — tensor / expert parallel axis

Scaling story (DESIGN.md §5): capacity grows by adding pods (clients),
which is the paper's own scaling dimension (Table III shows flat
warm-up share from 100 to 500 peers) — ``n_pods`` is a parameter, not a
constant, and every collective in the torrent schedule is written for
general P.
"""
from __future__ import annotations

from repro.sharding.api import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False, n_pods: int = 2):
    shape = (n_pods, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes,
                     axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh over however many devices this host has (tests)."""
    return make_mesh(shape, axes,
                     axis_types=(AxisType.Auto,) * len(axes))


def pod_axis_size(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(sizes.get("pod", 1))
