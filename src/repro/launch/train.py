"""End-to-end FL training driver (runnable on CPU with reduced configs).

Runs real FL rounds: per-pod local gradients -> torrent dissemination ->
masked FedAvg -> AdamW, with round-boundary checkpointing and restart
(--resume picks up at the latest checkpoint, the paper's §III-E
rejoin-at-round-boundary semantics).

``--drop-pod`` is a real recovery drill, not just a mask: at
``--drop-at`` (default steps/2) the run checkpoints, re-meshes from P to
P-1 pods (``ElasticFLStep`` rebuilds the mesh and the torrent ring
schedule for the shrunken collective), reloads the checkpoint, and
continues — asserting loss continuity across the re-mesh.  Params and
optimizer state carry over: a drop shrinks the swarm, never resets
training.

``--join-pod N`` is the symmetric growth drill (§III-E elastic P): at
``--join-at`` (default steps/2) N fresh pods join, the run checkpoints,
re-meshes from P to P+N over the enlarged device set
(``make_pod_mesh`` with the larger pod count), re-places the carried
params/optimizer state, and continues — loss continuity asserted the
same way.  A join widens the collective, never resets training.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --reduced --steps 200 --batch 8 --seq 64 --ckpt /tmp/ckpt
    PYTHONPATH=src python -m repro.launch.train --pods 4 --drop-pod 2 \
        --reduced --steps 40 --batch 8 --seq 32
    PYTHONPATH=src python -m repro.launch.train --pods 3 --join-pod 1 \
        --reduced --steps 40 --batch 8 --seq 32
"""
from __future__ import annotations

import argparse
import math
import os
import time

import numpy as np


def synthetic_batch(rng: np.random.Generator, n_pods: int, b_local: int,
                    seq: int, vocab: int, *, frames: int = 0):
    """Deterministic LM stream: next-token-predictable structured data."""
    import jax.numpy as jnp
    if frames:
        x = rng.normal(size=(n_pods, b_local, seq, frames)).astype(
            np.float32)
        y = rng.integers(0, vocab, size=(n_pods, b_local, seq))
        return {"inputs": jnp.asarray(x), "labels": jnp.asarray(y)}
    base = rng.integers(0, vocab, size=(n_pods, b_local, 1))
    step = rng.integers(1, 7, size=(n_pods, b_local, 1))
    seqs = (base + step * np.arange(seq + 1)) % vocab
    return {"inputs": jnp.asarray(seqs[..., :-1], jnp.int32),
            "labels": jnp.asarray(seqs[..., 1:], jnp.int32)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--drop-pod", type=int, default=-1,
                    help="mid-run pod failure: checkpoint, re-mesh "
                         "P->P-1, continue (loss continuity asserted)")
    ap.add_argument("--drop-at", type=int, default=-1,
                    help="step of the pod failure (default steps/2)")
    ap.add_argument("--join-pod", type=int, default=0,
                    help="mid-run pod growth: N pods join, checkpoint, "
                         "re-mesh P->P+N over the enlarged device set, "
                         "continue (loss continuity asserted)")
    ap.add_argument("--join-at", type=int, default=-1,
                    help="step of the pod join (default steps/2)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    # Multi-pod runs need one XLA device per pod — including the pods
    # that will only exist after a --join-pod re-mesh; on a plain CPU
    # host fake them BEFORE the backend initializes (no-op if the
    # operator already set a device count or real accelerators exist).
    peak_pods = args.pods + max(args.join_pod, 0)
    if peak_pods > 1 and ("xla_force_host_platform_device_count"
                          not in os.environ.get("XLA_FLAGS", "")):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={peak_pods}")

    import jax
    import jax.numpy as jnp

    from repro.checkpoint import latest_round, load_checkpoint, \
        save_checkpoint
    from repro.configs import get_config
    from repro.dist.fl_step import ElasticFLStep
    from repro.launch.mesh import make_host_mesh, make_pod_mesh
    from repro.models import init_params
    from repro.optim import adamw_init
    from repro.optim.schedules import linear_warmup_cosine

    cfg = get_config(args.arch, reduced=args.reduced)
    n_dev = jax.device_count()
    n_pods = args.pods if args.pods > 1 else 1
    peak = n_pods + max(args.join_pod, 0)
    if peak > n_dev:
        raise SystemExit(f"--pods {args.pods} --join-pod "
                         f"{max(args.join_pod, 0)} needs >= {peak} XLA "
                         f"devices (have {n_dev}); set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    dpp = max(n_dev // peak, 1)        # data-parallel devices per pod

    def mesh_factory(p: int):
        if p == 1:
            return make_host_mesh((n_dev, 1), ("data", "model"))
        return make_pod_mesh(p, data=dpp)

    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    opt = adamw_init(params)
    start = 0
    active_pods = n_pods
    if args.ckpt:
        r = latest_round(args.ckpt)
        if r is not None:
            (params, opt), meta = load_checkpoint(args.ckpt, r,
                                                  (params, opt))
            start = r + 1
            # A checkpoint written after a drop records the shrunken
            # collective; resuming must not silently re-expand it.
            active_pods = int(meta.get("pods", n_pods))
            print(f"resumed from round {r} ({active_pods} pods)",
                  flush=True)

    step_fn = ElasticFLStep(
        cfg, lr_schedule=linear_warmup_cosine(
            args.lr, 10, max(args.steps, 20)),
        mesh_factory=mesh_factory)
    rng = np.random.default_rng(0)
    b_local = max(args.batch // n_pods, 1)
    frames = cfg.d_model if not cfg.has_embedding else 0

    drop_at = args.drop_at if args.drop_at >= 0 else args.steps // 2
    join_at = args.join_at if args.join_at >= 0 else args.steps // 2
    prev_loss = None
    check_continuity = False
    t0 = time.time()
    for it in range(start, args.steps):
        if (args.drop_pod >= 0 and it == drop_at and active_pods > 1):
            # §III-E recovery drill: durable state at the boundary,
            # shrink the collective, rebuild mesh + ring, continue.
            if args.ckpt:
                save_checkpoint(args.ckpt, it - 1, (params, opt),
                                meta={"arch": args.arch,
                                      "pods": active_pods - 1})
                (params, opt), _ = load_checkpoint(args.ckpt, it - 1,
                                                   (params, opt))
            active_pods -= 1
            check_continuity = True
            print(f"step {it:5d}  pod {args.drop_pod % n_pods} dropped: "
                  f"re-meshing {active_pods + 1} -> {active_pods} pods",
                  flush=True)
        if (args.join_pod > 0 and it == join_at
                and active_pods + args.join_pod <= peak):
            # §III-E growth drill, the drop's symmetric twin: durable
            # state at the boundary, widen the collective, rebuild
            # mesh + ring over the enlarged device set, continue.
            if args.ckpt:
                save_checkpoint(args.ckpt, it - 1, (params, opt),
                                meta={"arch": args.arch,
                                      "pods": active_pods + args.join_pod})
                (params, opt), _ = load_checkpoint(args.ckpt, it - 1,
                                                   (params, opt))
            active_pods += args.join_pod
            check_continuity = True
            print(f"step {it:5d}  {args.join_pod} pod(s) joined: "
                  f"re-meshing {active_pods - args.join_pod} -> "
                  f"{active_pods} pods", flush=True)
        batch = synthetic_batch(rng, active_pods, b_local, args.seq,
                                cfg.vocab, frames=frames)
        params, opt, m = step_fn(params, opt, batch,
                                 jnp.ones((active_pods,)),
                                 jnp.ones((active_pods,)))
        loss = float(m["loss"])
        if check_continuity:
            # Continuity across the re-mesh: same params, resized
            # collective — anything beyond noise means recovery broke.
            # A re-mesh on the first executed step has no pre-re-mesh
            # loss to compare against; skip cleanly (disarm) rather
            # than grading two post-re-mesh losses next step.
            if prev_loss is not None:
                if not math.isfinite(loss) or loss > 3.0 * prev_loss + 0.5:
                    raise RuntimeError(
                        f"loss continuity broken across re-mesh: "
                        f"{prev_loss:.4f} -> {loss:.4f}")
                print(f"step {it:5d}  re-mesh continuity ok "
                      f"({prev_loss:.4f} -> {loss:.4f})", flush=True)
            check_continuity = False
        prev_loss = loss
        if it % args.log_every == 0 or it == args.steps - 1:
            print(f"step {it:5d}  loss {loss:.4f}  "
                  f"lr {float(m['lr']):.2e}  pods {active_pods}  "
                  f"({time.time() - t0:.1f}s)", flush=True)
        if args.ckpt and (it + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt, it, (params, opt),
                            meta={"arch": args.arch,
                                  "pods": active_pods})
    final_loss = float(m["loss"])
    if args.ckpt:
        save_checkpoint(args.ckpt, args.steps - 1, (params, opt),
                        meta={"arch": args.arch, "pods": active_pods,
                              "final": True})
    print(f"done: final loss {final_loss:.4f}", flush=True)
    return final_loss


if __name__ == "__main__":
    main()
