"""End-to-end FL training driver (runnable on CPU with reduced configs).

Runs real FL rounds: per-pod local gradients -> torrent dissemination ->
masked FedAvg -> AdamW, with round-boundary checkpointing and restart
(--resume picks up at the latest checkpoint, the paper's §III-E
rejoin-at-round-boundary semantics).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --reduced --steps 200 --batch 8 --seq 64 --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def synthetic_batch(rng: np.random.Generator, n_pods: int, b_local: int,
                    seq: int, vocab: int, *, frames: int = 0):
    """Deterministic LM stream: next-token-predictable structured data."""
    if frames:
        x = rng.normal(size=(n_pods, b_local, seq, frames)).astype(
            np.float32)
        y = rng.integers(0, vocab, size=(n_pods, b_local, seq))
        return {"inputs": jnp.asarray(x), "labels": jnp.asarray(y)}
    base = rng.integers(0, vocab, size=(n_pods, b_local, 1))
    step = rng.integers(1, 7, size=(n_pods, b_local, 1))
    seqs = (base + step * np.arange(seq + 1)) % vocab
    return {"inputs": jnp.asarray(seqs[..., :-1], jnp.int32),
            "labels": jnp.asarray(seqs[..., 1:], jnp.int32)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--drop-pod", type=int, default=-1,
                    help="simulate a mid-run pod failure (active mask)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    from repro.configs import get_config
    from repro.dist.fl_step import make_fl_train_step
    from repro.launch.mesh import make_host_mesh
    from repro.models import init_params
    from repro.optim import adamw_init
    from repro.optim.schedules import linear_warmup_cosine
    from repro.checkpoint import latest_round, load_checkpoint, \
        save_checkpoint

    cfg = get_config(args.arch, reduced=args.reduced)
    n_dev = jax.device_count()
    mesh = make_host_mesh((n_dev, 1), ("data", "model")) if args.pods <= 1 \
        else make_host_mesh((args.pods, n_dev // args.pods, 1),
                            ("pod", "data", "model"))
    n_pods = args.pods if args.pods > 1 else 1

    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    opt = adamw_init(params)
    start = 0
    if args.ckpt:
        r = latest_round(args.ckpt)
        if r is not None:
            (params, opt), meta = load_checkpoint(args.ckpt, r,
                                                  (params, opt))
            start = r + 1
            print(f"resumed from round {r}", flush=True)

    step_fn = make_fl_train_step(
        cfg, mesh, lr_schedule=linear_warmup_cosine(
            args.lr, 10, max(args.steps, 20)),
        n_pods=n_pods)
    rng = np.random.default_rng(0)
    weights = jnp.ones((n_pods,))
    b_local = max(args.batch // n_pods, 1)
    frames = cfg.d_model if not cfg.has_embedding else 0

    with mesh:
        jstep = jax.jit(step_fn)
        t0 = time.time()
        for it in range(start, args.steps):
            active = np.ones(n_pods, np.float32)
            if args.drop_pod >= 0 and it >= args.steps // 2:
                active[args.drop_pod % n_pods] = 0.0   # straggler masked
            batch = synthetic_batch(rng, n_pods, b_local, args.seq,
                                    cfg.vocab, frames=frames)
            params, opt, m = jstep(params, opt, batch, weights,
                                   jnp.asarray(active))
            if it % args.log_every == 0 or it == args.steps - 1:
                print(f"step {it:5d}  loss {float(m['loss']):.4f}  "
                      f"lr {float(m['lr']):.2e}  "
                      f"({time.time() - t0:.1f}s)", flush=True)
            if args.ckpt and (it + 1) % args.ckpt_every == 0:
                save_checkpoint(args.ckpt, it, (params, opt),
                                meta={"arch": args.arch})
        final_loss = float(m["loss"])
    if args.ckpt:
        save_checkpoint(args.ckpt, args.steps - 1, (params, opt),
                        meta={"arch": args.arch, "final": True})
    print(f"done: final loss {final_loss:.4f}", flush=True)
    return final_loss


if __name__ == "__main__":
    main()
