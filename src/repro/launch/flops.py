"""Analytic MODEL_FLOPS per cell (§Roofline's 'useful compute' term).

Prompt-standard accounting: MODEL_FLOPS = 6*N*D for training (fwd+bwd),
2*N*D for forward-only (prefill), 2*N*B per decoded token — with
N = active parameter count (MoE: top-k experts only).  Attention
score/value FLOPs are added explicitly since at 32k context they are a
material fraction (12*L*T^2*d_head*H per token-batch for full causal
attention, halved for the causal triangle, windowed for local layers).
"""
from __future__ import annotations

from repro.configs.base import ShapeSpec
from repro.models import ArchConfig


def _attn_flops_per_seq(cfg: ArchConfig, t: int) -> float:
    """Score+value matmul FLOPs for ONE sequence of length t (fwd)."""
    kinds = (list(cfg.pattern) * cfg.n_cycles) + list(cfg.tail_kinds)
    total = 0.0
    for k in kinds:
        if k in ("global", "moe"):
            pairs = t * t / 2 if cfg.causal else t * t
        elif k == "local":
            w = cfg.window or t
            pairs = min(w, t) * t        # banded
        else:
            continue                     # recurrent: counted via params
        total += 4.0 * pairs * cfg.n_heads * cfg.head_dim
    return total


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return (6.0 * n_active * tokens
                + 3.0 * shape.global_batch * _attn_flops_per_seq(
                    cfg, shape.seq_len))
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return (2.0 * n_active * tokens
                + shape.global_batch * _attn_flops_per_seq(
                    cfg, shape.seq_len))
    # decode: one token against a seq_len cache
    kinds = (list(cfg.pattern) * cfg.n_cycles) + list(cfg.tail_kinds)
    attn = 0.0
    for k in kinds:
        if k in ("global", "moe"):
            span = shape.seq_len
        elif k == "local":
            span = min(cfg.window or shape.seq_len, shape.seq_len)
        else:
            continue
        attn += 4.0 * span * cfg.n_heads * cfg.head_dim
    return shape.global_batch * (2.0 * n_active + attn)
