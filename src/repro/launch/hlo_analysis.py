"""Structural cost analysis of compiled (post-SPMD) HLO text.

Why this exists: ``compiled.cost_analysis()`` counts each ``while`` body
ONCE — a 13-cycle layer scan reports 1/13th of its FLOPs (verified in
tests).  The compiled HLO, however, carries
``backend_config={"known_trip_count":{"n":"13"}}`` on every scan-derived
while, so an exact correction is possible by walking the call graph and
multiplying each computation's cost by the product of enclosing trip
counts.  That is what this module does, producing the three roofline
terms per §Roofline:

* **flops**       — dot FLOPs (2*M*N*K, batch-aware) + elementwise FLOPs
                    (1/elem), counted inside fusions, loop-corrected.
* **hbm_bytes**   — HBM traffic model: operand + output bytes of every
                    *top-level* instruction (fusion internals excluded —
                    they live in registers/VMEM), loop-corrected.
* **coll_bytes**  — per-device bytes moved by collectives, with standard
                    algorithm factors (ring AG/RS move (P-1)/P of the
                    buffer; AR moves 2x that; permute moves its buffer),
                    loop-corrected.

All numbers are PER DEVICE (post-partitioning shapes are shard shapes).
Validated against XLA's own cost_analysis on loop-free modules in
tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import dataclasses
import math
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2,
    "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

# opcode -> flops per output element (approximate, matches XLA's spirit)
_ELEMWISE_1 = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "abs", "negate", "and", "or", "xor", "not", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "remainder",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "clamp", "sign", "select",
}
_TRANSCENDENTAL = {
    "exponential", "exponential-minus-one", "tanh", "log", "log-plus-one",
    "rsqrt", "sqrt", "power", "sine", "cosine", "atan2", "logistic",
    "cbrt", "erf", "expm1", "tan",
}
_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter",
                "all-to-all", "collective-permute", "all-gather-start",
                "all-reduce-start", "collective-permute-start",
                "ragged-all-to-all"}

def xla_cost_analysis(compiled) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across jax versions.

    Older jax returns a per-device LIST of dicts (one entry per
    partition); newer jax returns the dict directly.  Indexing the list
    like a dict raises ``TypeError: list indices must be integers``.
    """
    ca = compiled.cost_analysis()
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        return dict(ca[0]) if len(ca) else {}
    return dict(ca)


_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# type string may be a tuple containing /*index=N*/ comments; match the
# opcode as the first bare token followed by '(' after the '=' sign.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s([\w\-]+)\(")


def _shape_list(type_str: str):
    """All array shapes in a (possibly tuple) type string."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(x) for x in m.group(2).split(",") if x != ""]
        out.append((dt, dims))
    return out


def _nbytes(type_str: str) -> int:
    return sum(_DTYPE_BYTES[dt] * math.prod(dims or [1])
               for dt, dims in _shape_list(type_str))


def _nelems(type_str: str) -> int:
    shapes = _shape_list(type_str)
    return sum(math.prod(d or [1]) for _, d in shapes)


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list


def parse_module(txt: str) -> dict:
    """Split HLO text into computations with their instructions."""
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    # Header lines start at column 0 and end with '{'; the parameter
    # list may contain nested tuple parens, so never try to span it.
    header = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
    for line in txt.splitlines():
        if (line and not line[0].isspace()
                and line.rstrip().endswith("{")):
            m = header.match(line.strip())
            if m:
                cur = Computation(m.group(1), [])
                comps[m.group(1)] = cur
                if line.strip().startswith("ENTRY"):
                    comps["__entry__"] = cur
                continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            cur.instrs.append(Instr(m.group(1), m.group(2), m.group(3),
                                    line))
    return comps


def _operand_types(line: str) -> list:
    """Type strings of operands referenced as typed args (SPMD HLO often
    omits operand types; fall back to resolving via producers)."""
    # operands appear as %name — resolve via the caller with a name map.
    return re.findall(r"%([\w.\-]+)", line.split("(", 1)[1])


def _dot_flops(instr: Instr, name_types: dict) -> float:
    out_elems = _nelems(instr.type_str)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.line)
    ops = _operand_types(instr.line)
    if not m or not ops:
        return 2.0 * out_elems      # fallback
    lhs_type = name_types.get(ops[0])
    if lhs_type is None:
        return 2.0 * out_elems
    shapes = _shape_list(lhs_type)
    if not shapes:
        return 2.0 * out_elems
    dims = shapes[0][1]
    k = 1
    for i in (int(x) for x in m.group(1).split(",") if x != ""):
        if i < len(dims):
            k *= dims[i]
    return 2.0 * out_elems * k


def _group_size(line: str, n_default: int = 2) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return n_default


def _collective_bytes(instr: Instr) -> float:
    """Per-device bytes over the interconnect (ring-algorithm model)."""
    nb = _nbytes(instr.type_str)
    p = _group_size(instr.line)
    frac = (p - 1) / p if p > 1 else 0.0
    op = instr.opcode.replace("-start", "")
    if op == "all-gather":
        return nb * frac                       # output is the gathered buf
    if op == "all-reduce":
        return 2.0 * nb * frac                 # reduce-scatter + all-gather
    if op == "reduce-scatter":
        # output is the scattered shard; wire bytes ~ input * frac = out*p*frac
        return nb * p * frac
    if op == "all-to-all":
        return nb * frac
    if op in ("collective-permute", "ragged-all-to-all"):
        return nb
    return nb


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    transcendentals: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_counts: dict = dataclasses.field(default_factory=dict)

    def __iadd__(self, o):
        self.flops += o.flops
        self.transcendentals += o.transcendentals
        self.hbm_bytes += o.hbm_bytes
        self.coll_bytes += o.coll_bytes
        for k, v in o.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v
        return self

    def scaled(self, f: float) -> "Costs":
        return Costs(self.flops * f, self.transcendentals * f,
                     self.hbm_bytes * f, self.coll_bytes * f,
                     {k: v * f for k, v in self.coll_counts.items()})


def _fusion_flops(comp: Computation, comps: dict, name_types: dict):
    fl = tr = 0.0
    local_types = dict(name_types)
    for ins in comp.instrs:
        local_types[ins.name] = ins.type_str
    for ins in comp.instrs:
        if ins.opcode == "dot":
            fl += _dot_flops(ins, local_types)
        elif ins.opcode in _ELEMWISE_1 or ins.opcode == "compare":
            fl += _nelems(ins.type_str)
        elif ins.opcode in _TRANSCENDENTAL:
            tr += _nelems(ins.type_str)
        elif ins.opcode == "reduce":
            fl += _nelems(ins.type_str)  # ~n adds over inputs; cheap proxy
        elif ins.opcode == "fusion":
            sub = _called(ins.line, "calls")
            if sub and sub in comps:
                f2, t2 = _fusion_flops(comps[sub], comps, local_types)
                fl += f2
                tr += t2
    return fl, tr


def _called(line: str, key: str) -> str | None:
    m = re.search(key + r"=%?([\w.\-]+)", line)
    return m.group(1) if m else None


_SLICE_OPS = ("dynamic-slice", "slice", "gather")


def _fusion_read_bytes(comp: Computation, fusion_ins: Instr,
                       outer_types: dict) -> float:
    """HBM reads of a fusion: full size per operand, EXCEPT operands the
    fusion only touches through (dynamic-)slice/gather — a scan body
    slicing one layer out of a stacked (n_cycles, ...) buffer reads one
    slice per iteration, not the whole stack."""
    operand_names = _operand_types(fusion_ins.line)
    # map parameter index -> instr name inside the fusion computation
    params = {}
    for i in comp.instrs:
        if i.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", i.line)
            if m:
                params[i.name] = int(m.group(1))
    total = 0.0
    for pname, pidx in params.items():
        if pidx >= len(operand_names):
            continue
        full = _nbytes(outer_types.get(operand_names[pidx], "") or "")
        consumers = [i for i in comp.instrs
                     if re.search(r"%" + re.escape(pname) + r"\b",
                                  i.line.split("(", 1)[-1])
                     and i.name != pname]
        if consumers and all(c.opcode in _SLICE_OPS for c in consumers):
            total += sum(_nbytes(c.type_str) for c in consumers)
        else:
            total += full
    return total


def _trip_count(line: str) -> float:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"', line)
    return float(m.group(1)) if m else 1.0


def analyze(txt: str) -> Costs:
    comps = parse_module(txt)
    entry = comps.get("__entry__")
    assert entry is not None, "no ENTRY computation found"
    memo: dict[str, Costs] = {}

    def comp_cost(cname: str) -> Costs:
        if cname in memo:
            return memo[cname]
        comp = comps.get(cname)
        total = Costs()
        if comp is None:
            memo[cname] = total
            return total
        name_types = {i.name: i.type_str for i in comp.instrs}
        for ins in comp.instrs:
            op = ins.opcode
            if op == "while":
                body = _called(ins.line, "body")
                trip = _trip_count(ins.line)
                if body:
                    total += comp_cost(body).scaled(trip)
                cond = _called(ins.line, "condition")
                if cond:
                    total += comp_cost(cond).scaled(trip)
            elif op == "conditional":
                for b in re.findall(r"branch_computations=\{([^}]*)\}",
                                    ins.line):
                    for cn in b.replace("%", "").split(","):
                        total += comp_cost(cn.strip())
                m = re.search(r"true_computation=%?([\w.\-]+)", ins.line)
                if m:
                    total += comp_cost(m.group(1))
                m = re.search(r"false_computation=%?([\w.\-]+)", ins.line)
                if m:
                    total += comp_cost(m.group(1))
            elif op == "call" or op == "async-start":
                callee = _called(ins.line, "to_apply") or \
                    _called(ins.line, "calls")
                if callee:
                    total += comp_cost(callee)
            elif op == "fusion":
                sub = _called(ins.line, "calls")
                if sub and sub in comps:
                    fl, tr = _fusion_flops(comps[sub], comps, name_types)
                    total.flops += fl
                    total.transcendentals += tr
                    total.hbm_bytes += _fusion_read_bytes(
                        comps[sub], ins, name_types)
                else:
                    total.hbm_bytes += _operand_bytes(ins, name_types)
                total.hbm_bytes += _nbytes(ins.type_str)
            elif op in _COLLECTIVES:
                cb = _collective_bytes(ins)
                total.coll_bytes += cb
                key = op.replace("-start", "")
                total.coll_counts[key] = total.coll_counts.get(key, 0) + 1
            elif op in ("parameter", "constant", "get-tuple-element",
                        "tuple", "bitcast", "after-all",
                        "all-gather-done", "all-reduce-done",
                        "collective-permute-done"):
                continue
            else:
                if op == "dot":
                    total.flops += _dot_flops(ins, name_types)
                elif op in _ELEMWISE_1 or op == "compare":
                    total.flops += _nelems(ins.type_str)
                elif op in _TRANSCENDENTAL:
                    total.transcendentals += _nelems(ins.type_str)
                elif op == "reduce":
                    total.flops += _nelems(ins.type_str)
                out_b = _nbytes(ins.type_str)
                if op in ("slice", "dynamic-slice", "gather",
                          "reshape", "transpose", "copy",
                          "concatenate", "reverse", "convert"):
                    # reads ~= the bytes actually touched, not the full
                    # operand (a dynamic-slice of a stacked scan buffer
                    # reads one slice per iteration)
                    total.hbm_bytes += 2.0 * out_b
                elif op == "dynamic-update-slice":
                    ops_t = _operand_types(ins.line)
                    upd = (name_types.get(ops_t[1])
                           if len(ops_t) > 1 else None)
                    ub = _nbytes(upd) if upd else out_b
                    total.hbm_bytes += 2.0 * ub   # in-place aliased DUS
                elif op in ("broadcast", "iota", "pad"):
                    total.hbm_bytes += out_b
                else:
                    total.hbm_bytes += out_b
                    total.hbm_bytes += _operand_bytes(ins, name_types)
        memo[cname] = total
        return total

    def _operand_bytes(ins: Instr, name_types: dict) -> float:
        tot = 0.0
        for nm in _operand_types(ins.line):
            t = name_types.get(nm)
            if t is not None:
                tot += _nbytes(t)
        return tot

    return comp_cost(entry.name)


# ----------------------------------------------------------------------
# Roofline terms (TPU v5e-class constants per assignment)
# ----------------------------------------------------------------------

PEAK_FLOPS = 197e12            # bf16 / chip
HBM_BW = 819e9                 # bytes/s / chip
ICI_BW = 50e9                  # bytes/s / link


def roofline_terms(costs: Costs, *, model_flops_global: float = 0.0,
                   n_chips: int = 256) -> dict:
    """costs are per-device; model_flops_global is the analytic 6ND."""
    t_compute = costs.flops / PEAK_FLOPS
    t_memory = costs.hbm_bytes / HBM_BW
    t_coll = costs.coll_bytes / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    out = {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "hlo_flops_per_device": costs.flops,
        "hlo_bytes_per_device": costs.hbm_bytes,
        "coll_bytes_per_device": costs.coll_bytes,
        "coll_counts": costs.coll_counts,
        "roofline_fraction": (t_compute / bound) if bound > 0 else 0.0,
    }
    if model_flops_global > 0:
        out["model_flops_global"] = model_flops_global
        hlo_global = costs.flops * n_chips
        out["useful_flops_ratio"] = (model_flops_global / hlo_global
                                     if hlo_global else 0.0)
        out["useful_mfu_bound"] = (
            (model_flops_global / n_chips / PEAK_FLOPS) / bound
            if bound > 0 else 0.0)
    return out
