import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST run before any jax import — jax locks the device count on init.
# The 512 placeholder host devices exist ONLY for the dry-run; smoke
# tests and benchmarks see the real single CPU device (they never import
# this module).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each non-skipped cell this driver

    1. builds the step function + ShapeDtypeStruct inputs (specs.py),
    2. ``jax.jit(step, in_shardings, out_shardings).lower(...).compile()``
       under the production mesh — success proves the sharding config is
       coherent (no resharding errors, no unsupported collectives),
    3. records ``compiled.memory_analysis()`` (fits-per-device proof),
       ``compiled.cost_analysis()`` (XLA's numbers, scan-undercounted),
       and the loop-corrected structural costs (hlo_analysis.py), and
    4. derives the three roofline terms (§Roofline).

Usage:
    python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import json
import time
import traceback

import jax
import numpy as np


def _mem_fields(ma) -> dict:
    out = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, f, None)
        if v is not None:
            out[f] = int(v)
    out["total_per_device_bytes"] = (
        out.get("argument_size_in_bytes", 0)
        + out.get("temp_size_in_bytes", 0)
        + out.get("output_size_in_bytes", 0)
        - out.get("alias_size_in_bytes", 0))
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             rules=None, sp: bool = False,
             microbatch: int = 0, torrent_blocks: int = 4,
             compress: bool = False, verbose: bool = True,
             cfg_overrides: dict | None = None,
             save_hlo: str = "") -> dict:
    from repro.configs import SHAPES, cell_skip_reason, get_config
    from repro.launch import hlo_analysis
    from repro.launch.flops import model_flops
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import build_cell, to_shardings
    from repro.sharding.api import DEFAULT_RULES, axis_rules

    skip = cell_skip_reason(arch, shape_name)
    if skip:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skip", "reason": skip}

    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(mesh.devices.shape))
    t0 = time.time()
    use_rules = dict(DEFAULT_RULES if rules is None else rules)
    if sp:
        use_rules["seq"] = "model"   # Megatron-style sequence parallel
    with mesh, axis_rules(use_rules, mesh):
        cell = build_cell(cfg, shape, mesh, rules=use_rules,
                          microbatch=microbatch,
                          torrent_blocks=torrent_blocks,
                          compress=compress)
        jitted = jax.jit(
            cell["step"],
            in_shardings=to_shardings(mesh, cell["in_specs"]),
            out_shardings=to_shardings(mesh, cell["out_specs"]))
        lowered = jitted.lower(*cell["args"])
        compiled = lowered.compile()
    compile_s = time.time() - t0

    mem = _mem_fields(compiled.memory_analysis())
    ca = hlo_analysis.xla_cost_analysis(compiled)
    txt = compiled.as_text()
    costs = hlo_analysis.analyze(txt)
    mf = model_flops(cfg, shape)
    terms = hlo_analysis.roofline_terms(costs, model_flops_global=mf,
                                        n_chips=n_chips)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(txt)

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_chips": n_chips, "status": "ok",
        "compile_seconds": round(compile_s, 1),
        "memory": mem,
        "xla_cost_analysis": {k: float(v) for k, v in ca.items()
                              if isinstance(v, (int, float))},
        "roofline": terms,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "knobs": {"microbatch": microbatch,
                  "torrent_blocks": torrent_blocks,
                  "compress": compress,
                  "cache_dtype": cfg.cache_dtype or cfg.dtype,
                  "overrides": cfg_overrides or {}},
    }
    if verbose:
        gb = mem.get("total_per_device_bytes", 0) / 2**30
        print(f"[{rec['mesh']}] {arch} x {shape_name}: OK "
              f"({compile_s:.0f}s compile, {gb:.2f} GiB/device, "
              f"dominant={terms['dominant']}, "
              f"roofline_frac={terms['roofline_fraction']:.3f})",
              flush=True)
        print(f"  memory_analysis: {mem}", flush=True)
        print(f"  cost_analysis: flops={ca.get('flops')} "
              f"bytes={ca.get('bytes accessed')}", flush=True)
        print(f"  structural: flops/dev={costs.flops:.3e} "
              f"hbm/dev={costs.hbm_bytes:.3e} "
              f"coll/dev={costs.coll_bytes:.3e} "
              f"colls={costs.coll_counts}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--torrent-blocks", type=int, default=4)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--save-hlo", default="")
    ap.add_argument("--cache-dtype", default="")
    ap.add_argument("--sp", action="store_true",
                    help="sequence parallelism: shard the residual stream seq dim over model")
    args = ap.parse_args()

    from repro.configs import all_cells

    if args.all:
        cells = [(a, s) for a, s, _ in all_cells()]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    ok = skipped = failed = 0
    for arch, shape in cells:
        for multi in meshes:
            tag = f"{arch}__{shape}__{'multi' if multi else 'single'}"
            try:
                ov = ({"cache_dtype": args.cache_dtype}
                      if args.cache_dtype else None)
                rec = run_cell(arch, shape, multi, sp=args.sp,
                               microbatch=args.microbatch,
                               torrent_blocks=args.torrent_blocks,
                               compress=args.compress,
                               cfg_overrides=ov,
                               save_hlo=args.save_hlo)
            except Exception as e:   # record failures — they are bugs
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape,
                       "mesh": "multi" if multi else "single",
                       "status": "fail", "error": repr(e)}
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(rec, f, indent=1)
            st = rec["status"]
            ok += st == "ok"
            skipped += st == "skip"
            failed += st == "fail"
            if st == "skip":
                print(f"[{rec['mesh']}] {arch} x {shape}: SKIP "
                      f"({rec['reason']})", flush=True)
            elif st == "fail":
                print(f"[{rec['mesh']}] {arch} x {shape}: FAIL", flush=True)
    print(f"\ndry-run summary: {ok} ok / {skipped} skip / {failed} fail",
          flush=True)
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
