"""Batched serving driver: prefill a prompt batch, decode greedily.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b \
        --reduced --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    from repro.configs import get_config
    from repro.dist.fl_step import make_serve_step
    from repro.models import init_params, prefill

    cfg = get_config(args.arch, reduced=args.reduced)
    assert cfg.causal, "serving requires a causal LM"
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    max_len = args.prompt_len + args.gen

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)
    t0 = time.time()
    logits, caches = jax.jit(
        lambda p, x: prefill(cfg, p, x, max_len=max_len))(params, prompts)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    serve = jax.jit(make_serve_step(cfg))
    for i in range(args.gen - 1):
        tok, logits, caches = serve(params, caches,
                                    tok, jnp.int32(args.prompt_len + i))
        out.append(tok)
    gen = jnp.stack(out, 1)
    dt = time.time() - t0
    print(f"generated {args.batch}x{args.gen} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)", flush=True)
    print(np.asarray(gen)[: min(args.batch, 2)], flush=True)
    return np.asarray(gen)


if __name__ == "__main__":
    main()
