# Launch layer: mesh construction, dry-run, train/serve drivers.
# NOTE: import nothing heavy here — dryrun.py must set XLA_FLAGS before
# any jax initialization.
