"""Layer kinds: attention (global/local/moe), RG-LRU, mLSTM, sLSTM.

Every kind implements

    init_layer(cfg, kind, key)                      -> params
    apply_layer(cfg, kind, p, x, mode, cache, pos)  -> (x, new_cache)
    init_cache(cfg, kind, batch, max_len)           -> cache pytree

``mode`` in {"train", "prefill", "decode"}: train = full-sequence, no
cache; prefill = full-sequence, returns a populated decode cache;
decode = single-token step against the cache (``pos`` = traced scalar
absolute position).  Caches for "local" layers are rolling buffers of
``window`` entries (newest last), so decode attention uses a traced
``kv_offset = pos - window + 1`` and negative key positions are masked.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.sharding.api import logical_constraint, shard_map

from .common import causal_conv1d, dense_init, rms_norm, rope
from .config import ArchConfig

ATTN_KINDS = ("global", "local", "moe")
RGLRU_C = 8.0          # Griffin's fixed recurrence constant


def _act(name: str):
    return jax.nn.silu if name == "silu" else jax.nn.gelu


def _chunked_scan(step, init, xs, *, chunk: int, remat: bool):
    """lax.scan over time in rematerialized chunks.

    Plain scan-of-step saves every per-step residual for backward — for
    mLSTM that is a (B, H, dh, dh) matrix PER TIMESTEP (44 GiB/device at
    4k).  Chunking the scan and checkpointing each chunk stores only the
    chunk-boundary carries and recomputes inside, the standard
    linear-RNN training memory fix.  Falls back to one chunk when the
    sequence length isn't divisible (tiny smoke shapes).
    """
    t = xs[0].shape[0]
    chunk = min(chunk, t)
    while t % chunk:
        chunk -= 1
    nb = t // chunk
    if nb <= 1:
        return jax.lax.scan(step, init, xs)
    xs_b = jax.tree_util.tree_map(
        lambda x: x.reshape((nb, chunk) + x.shape[1:]), xs)

    def outer(carry, xc):
        return jax.lax.scan(step, carry, xc)

    if remat:
        outer = jax.checkpoint(
            outer, policy=jax.checkpoint_policies.nothing_saveable)
    carry, ys = jax.lax.scan(outer, init, xs_b)
    ys = jax.tree_util.tree_map(
        lambda y: y.reshape((t,) + y.shape[2:]), ys)
    return carry, ys


# ======================================================================
# Attention layers (global / local / moe)
# ======================================================================

def _init_attn(cfg: ArchConfig, kind: str, key) -> dict:
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 12)
    p = {
        "ln1": jnp.zeros((d,), dt),
        "ln2": jnp.zeros((d,), dt),
        "wq": dense_init(ks[0], (d, qd), dt),
        "wk": dense_init(ks[1], (d, kvd), dt),
        "wv": dense_init(ks[2], (d, kvd), dt),
        "wo": dense_init(ks[3], (qd, d), dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((cfg.head_dim,), dt)
        p["k_norm"] = jnp.zeros((cfg.head_dim,), dt)
    if cfg.post_norm:
        p["post_ln1"] = jnp.zeros((d,), dt)
        p["post_ln2"] = jnp.zeros((d,), dt)
    if kind == "moe":
        e, fe = cfg.n_experts, cfg.d_expert
        p["router"] = dense_init(ks[4], (d, e), jnp.float32)
        p["moe_gate"] = dense_init(ks[5], (e, d, fe), dt, in_axis=1)
        p["moe_up"] = dense_init(ks[6], (e, d, fe), dt, in_axis=1)
        p["moe_down"] = dense_init(ks[7], (e, fe, d), dt, in_axis=1)
    else:
        f = cfg.d_ff
        p["w_gate"] = dense_init(ks[4], (d, f), dt)
        p["w_up"] = dense_init(ks[5], (d, f), dt)
        p["w_down"] = dense_init(ks[6], (f, d), dt)
    return p


def _attention_mix(cfg: ArchConfig, kind: str, p: dict, h: jnp.ndarray,
                   mode: str, cache: dict | None, pos):
    """Returns (attn_out (B,T,qd), new_cache)."""
    b, t, d = h.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv, cfg.head_dim
    window = cfg.window if kind == "local" else None

    q = (h @ p["wq"]).reshape(b, t, hq, dh).transpose(0, 2, 1, 3)
    k = (h @ p["wk"]).reshape(b, t, hkv, dh).transpose(0, 2, 1, 3)
    v = (h @ p["wv"]).reshape(b, t, hkv, dh).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = logical_constraint(q, "batch", "heads", None, None)
    k = logical_constraint(k, "batch", "kv", None, None)
    v = logical_constraint(v, "batch", "kv", None, None)

    if mode == "decode":
        positions = jnp.full((t,), pos, jnp.int32)
    else:
        positions = jnp.arange(t, dtype=jnp.int32)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if mode == "decode":
        assert t == 1
        if window is not None:                       # rolling buffer
            ck = jnp.concatenate([cache["k"][:, :, 1:],
                                  k.astype(cache["k"].dtype)], axis=2)
            cv = jnp.concatenate([cache["v"][:, :, 1:],
                                  v.astype(cache["v"].dtype)], axis=2)
            new_cache = {"k": ck, "v": cv}
            out = ops.attention(
                q, ck, cv, causal=True, window=window,
                softcap=cfg.attn_softcap, q_offset=pos,
                kv_offset=pos - window + 1, impl=cfg.attn_impl,
                block_q=cfg.block_q, block_k=cfg.block_k)
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, pos, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, pos, 0))
            new_cache = {"k": ck, "v": cv}
            out = ops.attention(
                q, ck, cv, causal=True, window=None,
                softcap=cfg.attn_softcap, q_offset=pos,
                impl=cfg.attn_impl, block_q=cfg.block_q,
                block_k=cfg.block_k)
    else:
        out = ops.attention(
            q, k, v, causal=cfg.causal, window=window,
            softcap=cfg.attn_softcap, impl=cfg.attn_impl,
            block_q=cfg.block_q, block_k=cfg.block_k)
        if mode == "prefill":
            cdt = jnp.dtype(cfg.cache_dtype or cfg.dtype)
            if window is not None:
                w = window
                if t >= w:
                    ck, cv = k[:, :, t - w:], v[:, :, t - w:]
                else:
                    padw = ((0, 0), (0, 0), (w - t, 0), (0, 0))
                    ck, cv = jnp.pad(k, padw), jnp.pad(v, padw)
                new_cache = {"k": ck.astype(cdt), "v": cv.astype(cdt)}
            else:
                new_cache = {"k": k.astype(cdt), "v": v.astype(cdt)}

    out = out.transpose(0, 2, 1, 3).reshape(b, t, hq * dh)
    return out @ p["wo"], new_cache


def _dense_ffn(cfg: ArchConfig, p: dict, h: jnp.ndarray) -> jnp.ndarray:
    act = _act(cfg.act)
    g = act(h @ p["w_gate"]) * (h @ p["w_up"])
    g = logical_constraint(g, "batch", None, "ffn")
    return g @ p["w_down"]


MOE_TOKEN_BLOCK = 8192


def _moe_ffn_shardmap(cfg: ArchConfig, p: dict, h: jnp.ndarray, mesh):
    """Expert-parallel MoE via shard_map (§Perf cell-2).

    The pjit scatter/gather dispatch has data-dependent indices, which
    XLA SPMD can only partition by replicating the token matrix and the
    capacity buffers (537 MB collective-permutes + 268 MB all-reduces
    per layer per microbatch at olmoe train_4k).  Inside shard_map the
    dispatch is a plain LOCAL scatter: every (data, model) shard routes
    its data-shard's tokens to its own expert slice, computes, and one
    psum over ``model`` sums the expert-group partial outputs.  Router
    logits are computed per shard over the FULL expert table (router is
    tiny and replicated), so routing decisions are identical everywhere.

    Returns None when the cell isn't divisible (falls back to the
    blocked pjit path — tiny smoke configs, odd meshes).
    """
    from jax.sharding import PartitionSpec as P

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ms = int(sizes.get("model", 1))
    b, t, d = h.shape
    n = b * t
    ds = int(sizes.get("data", 1))
    if ms <= 1 or cfg.n_experts % ms or n % ds:
        return None
    if int(sizes.get("pod", 1)) > 1:
        # shard_map under the vmap-over-pods FL step trips an XLA
        # partitioner check ("invalid binary instruction opcode copy",
        # jax 0.8.2) -- multi-pod cells keep the pjit dispatch path.
        return None

    def body(x_loc, router, wg, wu, wd):
        g_id = jax.lax.axis_index("model")
        y = _moe_local_block(cfg, x_loc, router, wg, wu, wd, g_id)
        return jax.lax.psum(y, "model")

    try:
        fn = shard_map(
            body, mesh,
            in_specs=(P("data", None), P(None, None),
                      P("model", None, None), P("model", None, None),
                      P("model", None, None)),
            out_specs=P("data", None),
            axis_names={"data", "model"},      # pod (if any) stays auto
            check_rep=False)
        out = fn(h.reshape(n, d), p["router"], p["moe_gate"],
                 p["moe_up"], p["moe_down"])
    except (TypeError, NotImplementedError, ValueError):
        return None
    return out.reshape(b, t, d)


def _moe_local_block(cfg: ArchConfig, x_loc, router, wg, wu, wd, g_id):
    """Route local tokens to the local expert slice (sort-based)."""
    n_loc, d = x_loc.shape
    e, k_top = cfg.n_experts, cfg.top_k
    e_loc = wg.shape[0]
    act = _act(cfg.act)
    logits = x_loc.astype(jnp.float32) @ router        # full expert table
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k_top)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    rel = idx - g_id * e_loc                           # (n, k)
    inb = (rel >= 0) & (rel < e_loc)
    flat_e = jnp.where(inb, rel, e_loc).reshape(-1).astype(jnp.int32)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=e_loc + 1)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(n_loc * k_top) - starts[sorted_e]
    cap = int(np.ceil(n_loc * k_top / e * cfg.capacity_factor))
    cap = max(8, -(-cap // 8) * 8)
    keep = (pos_in_e < cap) & (sorted_e < e_loc)
    dest = jnp.where(keep, sorted_e * cap + pos_in_e, e_loc * cap)
    src = order // k_top

    buf = jnp.zeros((e_loc * cap + 1, d), x_loc.dtype).at[dest].set(
        x_loc[src])
    buf = buf[:-1].reshape(e_loc, cap, d)
    g = jnp.einsum("ecd,edf->ecf", buf, wg)
    u = jnp.einsum("ecd,edf->ecf", buf, wu)
    y = jnp.einsum("ecf,efd->ecd", act(g) * u, wd)
    y = jnp.concatenate([y.reshape(e_loc * cap, d),
                         jnp.zeros((1, d), x_loc.dtype)], axis=0)
    slot = jnp.full((n_loc * k_top,), e_loc * cap, jnp.int32).at[order].set(
        jnp.where(keep, dest, e_loc * cap).astype(jnp.int32))
    yk = y[slot].reshape(n_loc, k_top, d)
    w = (gates * inb.astype(gates.dtype)).astype(x_loc.dtype)
    return (w[..., None] * yk).sum(axis=1)


def _moe_ffn(cfg: ArchConfig, p: dict, h: jnp.ndarray) -> jnp.ndarray:
    """Top-k MoE FFN, processed in token blocks.

    Dispatches to the shard_map expert-parallel path when a production
    mesh is active (§Perf cell-2); otherwise the dispatch/combine
    scatters and the capacity buffers are materialized one token block
    at a time (lax.map + remat), so peak memory is
    O(block * top_k * cf * D) instead of O(B*T*...) — the un-blocked
    version was 134 GiB/device at olmoe prefill_32k.
    """
    from repro.sharding.api import current_rules
    state = current_rules()
    if state is not None and state[1] is not None:
        out = _moe_ffn_shardmap(cfg, p, h, state[1])
        if out is not None:
            return out
    b, t, d = h.shape
    n = b * t
    xf_all = h.reshape(n, d)
    block = MOE_TOKEN_BLOCK
    while n % block:
        block //= 2
    if block >= n or block < 64:
        return _moe_ffn_block(cfg, p, xf_all).reshape(b, t, d)
    nb = n // block
    xb = xf_all.reshape(nb, block, d)

    fn = jax.checkpoint(lambda x_: _moe_ffn_block(cfg, p, x_),
                        policy=jax.checkpoint_policies.nothing_saveable)
    out = jax.lax.map(fn, xb)
    return out.reshape(b, t, d)


def _moe_ffn_block(cfg: ArchConfig, p: dict, xf: jnp.ndarray
                   ) -> jnp.ndarray:
    """Sort-based top-k expert routing with capacity (drop overflow).

    Dispatch/combine are gathers/scatters (no matmul FLOPs); expert
    compute is a batched (E, cap, D) x (E, D, Fe) einsum so HLO FLOPs
    ~= 2*3*N*topk*capacity_factor*D*Fe — honest MoE cost, not the dense
    all-experts expansion.
    """
    n, d = xf.shape
    e, k_top = cfg.n_experts, cfg.top_k
    act = _act(cfg.act)
    logits = (xf.astype(jnp.float32) @ p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k_top)              # (n, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    cap = int(np.ceil(n * k_top / e * cfg.capacity_factor))
    cap = max(8, -(-cap // 8) * 8)
    flat_e = idx.reshape(-1)                              # (n*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(n * k_top) - starts[sorted_e]
    keep = pos_in_e < cap
    dest = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)
    src_token = order // k_top

    buf = jnp.zeros((e * cap + 1, d), xf.dtype).at[dest].set(xf[src_token])
    buf = buf[:-1].reshape(e, cap, d)
    buf = logical_constraint(buf, "expert", None, None)
    g = jnp.einsum("ecd,edf->ecf", buf, p["moe_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["moe_up"])
    y = jnp.einsum("ecf,efd->ecd", act(g) * u, p["moe_down"])
    y = logical_constraint(y, "expert", None, None)
    y = jnp.concatenate([y.reshape(e * cap, d),
                         jnp.zeros((1, d), xf.dtype)], axis=0)

    slot = jnp.full((n * k_top,), e * cap, jnp.int32).at[order].set(
        jnp.where(keep, dest, e * cap).astype(jnp.int32))
    yk = y[slot].reshape(n, k_top, d)
    out = (gates.astype(xf.dtype)[..., None] * yk).sum(axis=1)
    return out


def _apply_attn(cfg: ArchConfig, kind: str, p: dict, x: jnp.ndarray,
                mode: str, cache, pos):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    attn, new_cache = _attention_mix(cfg, kind, p, h, mode, cache, pos)
    if cfg.post_norm:
        attn = rms_norm(attn, p["post_ln1"], cfg.norm_eps)
    x = x + attn
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    ff = _moe_ffn(cfg, p, h) if kind == "moe" else _dense_ffn(cfg, p, h)
    if cfg.post_norm:
        ff = rms_norm(ff, p["post_ln2"], cfg.norm_eps)
    x = x + ff
    x = logical_constraint(x, "batch", "seq", None)
    return x, new_cache


# ======================================================================
# RG-LRU (Griffin recurrent block + GeGLU FFN)
# ======================================================================

def _init_rglru(cfg: ArchConfig, key) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    dr = cfg.d_rnn or d
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    # Lambda init so a = exp(-c * softplus(lam)) ~ U(0.9, 0.999) at r=1.
    u = jax.random.uniform(ks[0], (dr,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / RGLRU_C))
    return {
        "ln1": jnp.zeros((d,), dt),
        "ln2": jnp.zeros((d,), dt),
        "rg_in": dense_init(ks[1], (d, dr), dt),
        "rg_gate": dense_init(ks[2], (d, dr), dt),
        "conv_w": (jax.random.normal(ks[3], (cfg.conv_width, dr),
                                     jnp.float32)
                   * (cfg.conv_width ** -0.5)).astype(dt),
        "lam": lam,
        "a_gate_w": jnp.ones((dr,), jnp.float32),
        "i_gate_w": jnp.ones((dr,), jnp.float32),
        "rg_out": dense_init(ks[4], (dr, d), dt),
        "w_gate": dense_init(ks[5], (d, f), dt),
        "w_up": dense_init(ks[6], (d, f), dt),
        "w_down": dense_init(ks[7], (f, d), dt),
    }


def _apply_rglru(cfg: ArchConfig, p: dict, x: jnp.ndarray, mode: str,
                 cache, pos):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    xr = h @ p["rg_in"]
    xg = jax.nn.gelu(h @ p["rg_gate"])
    xr = logical_constraint(xr, "batch", None, "rnn")
    conv_state = cache["conv"] if mode == "decode" else None
    xc, new_conv = causal_conv1d(xr, p["conv_w"], conv_state)

    xcf = xc.astype(jnp.float32)
    r = jax.nn.sigmoid(xcf * p["a_gate_w"])
    i = jax.nn.sigmoid(xcf * p["i_gate_w"])
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    h0 = cache["h"] if mode == "decode" else None
    y, h_t = ops.rglru(xc, a.astype(xc.dtype), i.astype(xc.dtype), h0,
                       impl=cfg.rnn_impl)
    out = (xg * y) @ p["rg_out"]
    x = x + out
    hh = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + _dense_ffn(cfg, p, hh)
    x = logical_constraint(x, "batch", "seq", None)
    new_cache = None
    if mode in ("decode", "prefill"):
        new_cache = {"h": h_t, "conv": new_conv}
    return x, new_cache


# ======================================================================
# mLSTM (xLSTM matrix-memory block)
# ======================================================================

def _init_mlstm(cfg: ArchConfig, key) -> dict:
    d = cfg.d_model
    di = 2 * d
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 10)
    return {
        "norm": jnp.zeros((d,), dt),
        "up_l": dense_init(ks[0], (d, di), dt),
        "up_r": dense_init(ks[1], (d, di), dt),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, di),
                                     jnp.float32)
                   * (cfg.conv_width ** -0.5)).astype(dt),
        "wq_i": dense_init(ks[3], (di, di), dt),
        "wk_i": dense_init(ks[4], (di, di), dt),
        "wv_i": dense_init(ks[5], (di, di), dt),
        "wi": dense_init(ks[6], (di, cfg.rnn_heads), jnp.float32),
        "wf": dense_init(ks[7], (di, cfg.rnn_heads), jnp.float32),
        "wo_gate": dense_init(ks[8], (di, di), dt),
        "down": dense_init(ks[9], (di, d), dt),
    }


MLSTM_CHUNK = 128


def _mlstm_chunkwise(q, k, v, i_pre, f_pre, state, *, chunk: int,
                     remat: bool):
    """Chunkwise-parallel mLSTM (stabilized exponential gating).

    The sequential scan reads+writes the (B, H, dh, dh) matrix state
    every timestep — O(T * B * H * dh^2) HBM traffic that made xlstm
    train_4k ~7000x memory-bound (EXPERIMENTS.md §Perf).  The chunkwise
    form (the xLSTM paper's training mode) touches the state once per
    chunk and handles the intra-chunk part as an (L, L)-masked
    quadratic, trading a small FLOP increase for a ~chunk-factor
    reduction in state traffic.

    Derivation (per head, relative to chunk start; A = incl-cumsum f):
        m_j = A_j + M_j,          M_j = max(m0, cummax_j(i - A))
        h_j = e^{m0-M_j} C0 q_j + sum_{s<=j} W[j,s] (k_s.q_j) v_s
        W[j,s] = e^{(i_s - A_s) - M_j}
        n_j = e^{m0-M_j} n0 + sum_{s<=j} W[j,s] k_s
        den_j = max(|n_j . q_j|, 1)
    State update uses the same weights at j = L-1.  Verified against
    the per-step recurrence in tests/test_mlstm_chunkwise.py.

    q,k,v: (B, T, H, dh) (q,k pre-scaled); i_pre,f_pre: (B, T, H).
    state: (C (B,H,dh,dh), n (B,H,dh), m (B,H)).  Returns
    (state, h (B, T, H, dh)).
    """
    b, t, hh, dh = q.shape
    chunk = min(chunk, t)
    pad = (-t) % chunk
    if pad:
        zpad = ((0, 0), (0, pad), (0, 0), (0, 0))
        q = jnp.pad(q, zpad)
        k = jnp.pad(k, zpad)
        v = jnp.pad(v, zpad)
        # inert padding: f=0 keeps A flat, i=-inf contributes nothing
        f_pre = jnp.pad(f_pre, ((0, 0), (0, pad), (0, 0)))
        i_pre = jnp.pad(i_pre, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e30)
    nc = (t + pad) // chunk

    def split(x):
        x = x.reshape((b, nc, chunk) + x.shape[2:])
        return jnp.moveaxis(x, 1, 0)          # (nc, b, chunk, ...)

    qs, ks, vs = split(q), split(k), split(v)
    is_, fs = split(i_pre), split(f_pre)

    def chunk_body(state, xs):
        C0, n0, m0 = state                    # (b,h,dh,dh),(b,h,dh),(b,h)
        qc, kc, vc, ic, fc = xs               # (b,chunk,h,...)
        L = qc.shape[1]
        ic = ic.astype(jnp.float32).transpose(0, 2, 1)     # (b,h,L)
        fc = fc.astype(jnp.float32).transpose(0, 2, 1)
        qh = qc.astype(jnp.float32).transpose(0, 2, 1, 3)  # (b,h,L,dh)
        kh = kc.astype(jnp.float32).transpose(0, 2, 1, 3)
        vh = vc.astype(jnp.float32).transpose(0, 2, 1, 3)

        A = jnp.cumsum(fc, axis=-1)                      # (b,h,L)
        gia = ic - A                                      # i_s - A_s
        g = jax.lax.cummax(gia, axis=2)
        M = jnp.maximum(m0[..., None], g)                # (b,h,L)
        c_int = jnp.exp(m0[..., None] - M)               # (b,h,L)
        # W[j,s] = exp(gia_s - M_j), s <= j
        W = jnp.exp(gia[..., None, :] - M[..., :, None])
        mask = jnp.tril(jnp.ones((L, L), bool))
        W = jnp.where(mask, W, 0.0)

        scores = jnp.einsum("bhjd,bhsd->bhjs", qh, kh)
        inter_num = jnp.einsum("bhij,bhsj->bhsi", C0, qh)  # C0 q_j
        h_num = (c_int[..., None] * inter_num
                 + jnp.einsum("bhjs,bhsi->bhji", W * scores, vh))
        nj = (c_int[..., None] * n0[:, :, None, :]
              + jnp.einsum("bhjs,bhsd->bhjd", W, kh))
        den = jnp.abs(jnp.einsum("bhjd,bhjd->bhj", nj, qh))
        h = h_num / jnp.maximum(den, 1.0)[..., None]     # (b,h,L,dh)

        # end-of-chunk state
        AL = A[..., -1]
        MxL = jnp.maximum(m0, g[..., -1])                # (b,h)
        wL = jnp.exp(gia - MxL[..., None])               # (b,h,L)
        C = (jnp.exp(m0 - MxL)[..., None, None] * C0
             + jnp.einsum("bhs,bhsi,bhsj->bhij", wL, vh, kh))
        n = (jnp.exp(m0 - MxL)[..., None] * n0
             + jnp.einsum("bhs,bhsd->bhd", wL, kh))
        m = AL + MxL
        return (C, n, m), h.transpose(0, 2, 1, 3)        # (b,L,h,dh)

    body = chunk_body
    if remat:
        body = jax.checkpoint(
            chunk_body, policy=jax.checkpoint_policies.nothing_saveable)
    state, hs = jax.lax.scan(body, state, (qs, ks, vs, is_, fs))
    hs = jnp.moveaxis(hs, 0, 1).reshape(b, t + pad, hh, dh)
    if pad:
        hs = hs[:, :t]
    return state, hs


def _mlstm_step(state, inputs):
    """One mLSTM cell step (stabilized exponential gating)."""
    C, nrm, m = state
    q_t, k_t, v_t, i_pre, f_pre = inputs
    m_new = jnp.maximum(f_pre + m, i_pre)                  # (B, H)
    fi = jnp.exp(f_pre + m - m_new)
    ii = jnp.exp(i_pre - m_new)
    C = fi[..., None, None] * C + ii[..., None, None] * (
        v_t[..., :, None] * k_t[..., None, :])             # (B,H,dh,dh)
    nrm = fi[..., None] * nrm + ii[..., None] * k_t
    num = jnp.einsum("bhij,bhj->bhi", C, q_t)
    den = jnp.abs(jnp.einsum("bhj,bhj->bh", nrm, q_t))
    h = num / jnp.maximum(den, 1.0)[..., None]
    return (C, nrm, m_new), h


def _apply_mlstm(cfg: ArchConfig, p: dict, x: jnp.ndarray, mode: str,
                 cache, pos):
    b, t, d = x.shape
    di = 2 * d
    hh = cfg.rnn_heads
    dh = di // hh
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    xl = h @ p["up_l"]
    xr = jax.nn.silu(h @ p["up_r"])
    xl = logical_constraint(xl, "batch", None, "rnn")
    conv_state = cache["conv"] if mode == "decode" else None
    xc, new_conv = causal_conv1d(xl, p["conv_w"], conv_state)

    scale = dh ** -0.5
    q = (xc @ p["wq_i"]).reshape(b, t, hh, dh).astype(jnp.float32) * scale
    k = (xc @ p["wk_i"]).reshape(b, t, hh, dh).astype(jnp.float32) * scale
    v = (xl @ p["wv_i"]).reshape(b, t, hh, dh).astype(jnp.float32)
    i_pre = xc.astype(jnp.float32) @ p["wi"]               # (B,T,H)
    f_pre = xc.astype(jnp.float32) @ p["wf"] + 1.0
    o = jax.nn.sigmoid(xc @ p["wo_gate"])

    if mode == "decode":
        state = (cache["C"], cache["n"], cache["m"])
        state, hs = _mlstm_step(
            state, (q[:, 0], k[:, 0], v[:, 0], i_pre[:, 0], f_pre[:, 0]))
        hs = hs[:, None]                                   # (B,1,H,dh)->
        hs = hs.reshape(b, t, di)
    else:
        init = (jnp.zeros((b, hh, dh, dh), jnp.float32),
                jnp.zeros((b, hh, dh), jnp.float32),
                jnp.full((b, hh), -1e30, jnp.float32))
        state, hs = _mlstm_chunkwise(q, k, v, i_pre, f_pre, init,
                                     chunk=MLSTM_CHUNK,
                                     remat=(mode == "train"))
        hs = hs.reshape(b, t, di)

    y = (o * hs.astype(o.dtype)) @ p["down"]
    x = x + y
    x = logical_constraint(x, "batch", "seq", None)
    new_cache = None
    if mode in ("decode", "prefill"):
        new_cache = {"C": state[0], "n": state[1], "m": state[2],
                     "conv": new_conv}
    return x, new_cache


# ======================================================================
# sLSTM (xLSTM scalar-memory block)
# ======================================================================

def _init_slstm(cfg: ArchConfig, key) -> dict:
    d = cfg.d_model
    hh = cfg.rnn_heads
    dh = d // hh
    f = -(-4 * d // 3 // 128) * 128
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    return {
        "norm": jnp.zeros((d,), dt),
        "ln2": jnp.zeros((d,), dt),
        "w4": dense_init(ks[0], (d, 4 * d), jnp.float32),
        "r4": (jax.random.normal(ks[1], (hh, dh, 4 * dh), jnp.float32)
               * (dh ** -0.5)),
        "b4": jnp.zeros((hh, 4 * dh), jnp.float32),
        "w_gate": dense_init(ks[2], (d, f), dt),
        "w_up": dense_init(ks[3], (d, f), dt),
        "w_down": dense_init(ks[4], (f, d), dt),
    }


def _slstm_step(p, state, wx_t):
    """wx_t: (B, H, 4*dh) input pre-activations for one step."""
    c, n, hprev, m = state
    gates = wx_t + jnp.einsum("bhd,hde->bhe", hprev, p["r4"]) + p["b4"]
    dh = c.shape[-1]
    i_pre = gates[..., 0 * dh:1 * dh]
    f_pre = gates[..., 1 * dh:2 * dh] + 1.0
    z_pre = gates[..., 2 * dh:3 * dh]
    o_pre = gates[..., 3 * dh:4 * dh]
    m_new = jnp.maximum(f_pre + m, i_pre)
    ii = jnp.exp(i_pre - m_new)
    ff = jnp.exp(f_pre + m - m_new)
    c = ff * c + ii * jnp.tanh(z_pre)
    n = ff * n + ii
    h = jax.nn.sigmoid(o_pre) * c / jnp.maximum(n, 1.0)
    return (c, n, h, m_new), h


def _apply_slstm(cfg: ArchConfig, p: dict, x: jnp.ndarray, mode: str,
                 cache, pos):
    b, t, d = x.shape
    hh = cfg.rnn_heads
    dh = d // hh
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    wx = (h.astype(jnp.float32) @ p["w4"]).reshape(b, t, hh, 4 * dh)

    if mode == "decode":
        state = (cache["c"], cache["n"], cache["h"], cache["m"])
        state, hs = _slstm_step(p, state, wx[:, 0])
        hs = hs[:, None]
    else:
        zeros = jnp.zeros((b, hh, dh), jnp.float32)
        init = (zeros, zeros, zeros, jnp.full((b, hh, dh), -1e30,
                                              jnp.float32))
        state, hs = _chunked_scan(
            lambda s, w: _slstm_step(p, s, w[0]), init,
            (wx.swapaxes(0, 1),), chunk=256, remat=(mode == "train"))
        hs = hs.swapaxes(0, 1)
    y = hs.reshape(b, t, d).astype(x.dtype)
    x = x + y
    hh2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + _dense_ffn(cfg, p, hh2)
    x = logical_constraint(x, "batch", "seq", None)
    new_cache = None
    if mode in ("decode", "prefill"):
        new_cache = {"c": state[0], "n": state[1], "h": state[2],
                     "m": state[3]}
    return x, new_cache


# ======================================================================
# Dispatch
# ======================================================================

def init_layer(cfg: ArchConfig, kind: str, key) -> dict:
    if kind in ATTN_KINDS:
        return _init_attn(cfg, kind, key)
    if kind == "rglru":
        return _init_rglru(cfg, key)
    if kind == "mlstm":
        return _init_mlstm(cfg, key)
    if kind == "slstm":
        return _init_slstm(cfg, key)
    raise ValueError(kind)


def apply_layer(cfg: ArchConfig, kind: str, p: dict, x: jnp.ndarray,
                mode: str = "train", cache=None, pos=None):
    if kind in ATTN_KINDS:
        return _apply_attn(cfg, kind, p, x, mode, cache, pos)
    if kind == "rglru":
        return _apply_rglru(cfg, p, x, mode, cache, pos)
    if kind == "mlstm":
        return _apply_mlstm(cfg, p, x, mode, cache, pos)
    if kind == "slstm":
        return _apply_slstm(cfg, p, x, mode, cache, pos)
    raise ValueError(kind)


def init_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int,
               dtype=None) -> dict:
    dt = jnp.dtype(dtype or cfg.dtype)
    if kind in ATTN_KINDS:
        cdt = jnp.dtype(dtype or cfg.cache_dtype or cfg.dtype)
        size = cfg.window if kind == "local" else max_len
        shape = (batch, cfg.n_kv, size, cfg.head_dim)
        return {"k": jnp.zeros(shape, cdt), "v": jnp.zeros(shape, cdt)}
    if kind == "rglru":
        dr = cfg.d_rnn or cfg.d_model
        return {"h": jnp.zeros((batch, dr), jnp.float32),
                "conv": jnp.zeros((batch, cfg.conv_width - 1, dr), dt)}
    if kind == "mlstm":
        di = 2 * cfg.d_model
        hh = cfg.rnn_heads
        dh = di // hh
        return {"C": jnp.zeros((batch, hh, dh, dh), jnp.float32),
                "n": jnp.zeros((batch, hh, dh), jnp.float32),
                "m": jnp.full((batch, hh), -1e30, jnp.float32),
                "conv": jnp.zeros((batch, cfg.conv_width - 1, di), dt)}
    if kind == "slstm":
        hh = cfg.rnn_heads
        dh = cfg.d_model // hh
        z = jnp.zeros((batch, hh, dh), jnp.float32)
        return {"c": z, "n": z, "h": z,
                "m": jnp.full((batch, hh, dh), -1e30, jnp.float32)}
    raise ValueError(kind)
