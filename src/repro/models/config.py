"""Architecture configuration shared by all 10 assigned archs.

One ``ArchConfig`` fully determines parameter shapes, layer pattern and
runtime behaviour.  Layer *kinds* (the ``pattern`` cycle):

* ``"global"``  — full causal (or bidirectional) attention + dense FFN
* ``"local"``   — sliding-window attention + dense FFN
* ``"moe"``     — full attention + top-k MoE FFN
* ``"rglru"``   — Griffin recurrent block (conv + RG-LRU), GeGLU FFN
* ``"mlstm"``   — xLSTM matrix-LSTM block (self-contained, no FFN)
* ``"slstm"``   — xLSTM scalar-LSTM block (post-up FFN inside block)

``n_layers = n_cycles * len(pattern) + tail``; the tail reuses the first
``tail`` kinds of the pattern (e.g. gemma3's 34 = 5*6 + 4).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    vocab: int
    pattern: tuple[str, ...] = ("global",)
    window: int | None = None          # sliding-window width ("local")
    attn_softcap: float | None = None  # gemma2 attention logit softcap
    final_softcap: float | None = None  # gemma2 final logit softcap
    qk_norm: bool = False
    causal: bool = True                   # False => encoder-only (hubert)
    has_embedding: bool = True            # False => frame-embedding input
    post_norm: bool = False               # gemma2-style post-layer norms
    tie_embeddings: bool = True
    act: str = "silu"                     # "silu" (SwiGLU) | "gelu" (GeGLU)
    norm_eps: float = 1e-6
    rope_theta: float = 10_000.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    capacity_factor: float = 2.0
    # Recurrent (rglru / xlstm)
    d_rnn: int = 0
    conv_width: int = 4
    rnn_heads: int = 0                    # xLSTM heads
    # Runtime knobs (overridden by shapes / perf iterations)
    dtype: str = "bfloat16"
    cache_dtype: str = ""        # "" = dtype; "float8_e4m3fn" halves KV
    attn_impl: str = "xla"                # "xla" | "pallas" | "interpret"
    rnn_impl: str = "xla"
    remat: bool = True
    scan_layers: bool = True
    block_q: int = 512
    block_k: int = 512

    # ------------------------------------------------------------------
    @property
    def n_cycles(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def tail_kinds(self) -> tuple[str, ...]:
        return self.pattern[: self.n_layers % len(self.pattern)]

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv * self.head_dim

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (all layers + embeddings)."""
        total = 0
        if self.has_embedding:
            total += self.vocab * self.d_model
            if not self.tie_embeddings:
                total += self.vocab * self.d_model
        else:
            total += self.d_model * self.d_model      # frontend adapter
            total += self.d_model * self.vocab        # classifier head
        total += self.d_model                          # final norm
        kinds = (list(self.pattern) * self.n_cycles) + list(self.tail_kinds)
        for kind in kinds:
            total += self._layer_params(kind)
        return total

    def _layer_params(self, kind: str) -> int:
        d = self.d_model
        n = 0
        if kind in ("global", "local", "moe"):
            n += d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
            n += 2 * d                                 # pre norms
            if self.post_norm:
                n += 2 * d
            if self.qk_norm:
                n += 2 * self.head_dim
            if kind == "moe":
                n += d * self.n_experts                # router
                n += self.n_experts * 3 * d * self.d_expert
            else:
                n += 3 * d * self.d_ff                 # SwiGLU/GeGLU
        elif kind == "rglru":
            dr = self.d_rnn or d
            n += 2 * d                                 # norms
            n += 2 * d * dr                            # rec + gate branch in
            n += self.conv_width * dr                  # temporal conv
            n += 3 * dr                                # Lambda, a-gate, i-gate
            n += 2 * dr * d                            # (a,i gates use W) out
            n += 3 * d * self.d_ff                     # GeGLU FFN
        elif kind == "mlstm":
            di = 2 * d                                 # up factor 2
            n += d + 2 * d * di                        # norm + two up projs
            n += self.conv_width * di
            n += 3 * di * di // max(self.rnn_heads, 1) * max(self.rnn_heads, 1)
            n += 3 * di                                # i, f, o gate projs
            n += di * d                                # down proj
        elif kind == "slstm":
            h = self.rnn_heads or 4
            dh = d // h
            n += d                                     # norm
            n += 4 * d * d                             # W gates
            n += 4 * h * dh * dh                       # block-diag R gates
            n += 4 * d                                 # biases
            n += 2 * d * math.ceil(4 * d / 3) // 1     # post-up FFN approx
        else:
            raise ValueError(kind)
        return int(n)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if self.n_experts == 0:
            return self.param_count()
        total = self.param_count()
        kinds = (list(self.pattern) * self.n_cycles) + list(self.tail_kinds)
        n_moe = sum(1 for k in kinds if k == "moe")
        all_exp = n_moe * self.n_experts * 3 * self.d_model * self.d_expert
        act_exp = n_moe * self.top_k * 3 * self.d_model * self.d_expert
        return int(total - all_exp + act_exp)
