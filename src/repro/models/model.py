"""Unified LM: init / forward / train loss / prefill / decode.

Layers are grouped into ``n_cycles`` repetitions of the config's
``pattern`` plus an unrolled tail; the cycle params are *stacked* on a
leading axis and applied with ``lax.scan`` so HLO size is O(pattern),
not O(n_layers) — this is what keeps 512-way SPMD compiles of the 34B
configs tractable.  ``cfg.remat`` wraps the cycle body in
``jax.checkpoint`` (layer-boundary activation checkpointing).

Params tree:
    embed / adapter_in+head (hubert)   — input/output embeddings
    cycles = {"slot<i>": stacked params (leading dim n_cycles)}
    tail   = [per-layer params]        — n_layers % len(pattern) layers
    final_norm

Caches mirror the same structure (stacked per slot + tail list).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.sharding.api import logical_constraint

from .common import chunked_ce_loss, embed_tokens, rms_norm, unembed_logits
from .config import ArchConfig
from .layers import apply_layer, init_cache, init_layer


def init_params(cfg: ArchConfig, key) -> dict:
    dt = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, cfg.n_layers + 4)
    p: dict = {}
    if cfg.has_embedding:
        p["embed"] = (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model),
                                        jnp.float32)
                      * (cfg.d_model ** -0.5)).astype(dt)
        if not cfg.tie_embeddings:
            p["head"] = (jax.random.normal(keys[1],
                                           (cfg.d_model, cfg.vocab),
                                           jnp.float32)
                         * (cfg.d_model ** -0.5)).astype(dt)
    else:
        p["adapter_in"] = (jax.random.normal(
            keys[0], (cfg.d_model, cfg.d_model), jnp.float32)
            * (cfg.d_model ** -0.5)).astype(dt)
        p["head"] = (jax.random.normal(keys[1], (cfg.d_model, cfg.vocab),
                                       jnp.float32)
                     * (cfg.d_model ** -0.5)).astype(dt)
    p["final_norm"] = jnp.zeros((cfg.d_model,), dt)

    nc = cfg.n_cycles
    plen = len(cfg.pattern)
    cycles: dict = {}
    for i, kind in enumerate(cfg.pattern):
        per = [init_layer(cfg, kind, keys[2 + c * plen + i])
               for c in range(nc)]
        cycles[f"slot{i}"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *per) if nc > 1 else \
            jax.tree_util.tree_map(lambda x: x[None], per[0])
    p["cycles"] = cycles
    tail = []
    base = 2 + nc * plen
    for j, kind in enumerate(cfg.tail_kinds):
        tail.append(init_layer(cfg, kind, keys[base + j]))
    p["tail"] = tail
    return p


def _embed_inputs(cfg: ArchConfig, p: dict, inputs) -> jnp.ndarray:
    if cfg.has_embedding:
        return embed_tokens(p["embed"], inputs, cfg.d_model)
    x = inputs.astype(jnp.dtype(cfg.dtype)) @ p["adapter_in"]
    return logical_constraint(x, "batch", "seq", None)


def _run_layers(cfg: ArchConfig, p: dict, x: jnp.ndarray, mode: str,
                caches: dict | None, pos):
    """Scan over cycles + unrolled tail.  Returns (x, new_caches)."""
    plen = len(cfg.pattern)

    def cycle_body(carry, xs):
        h = carry
        cyc_params, cyc_caches = xs
        new_caches = []
        for i, kind in enumerate(cfg.pattern):
            c_in = None if cyc_caches is None else cyc_caches[f"slot{i}"]
            h, c_out = apply_layer(cfg, kind, cyc_params[f"slot{i}"], h,
                                   mode, c_in, pos)
            new_caches.append(c_out)
        if mode == "train":
            return h, None
        return h, {f"slot{i}": c for i, c in enumerate(new_caches)}

    body = cycle_body
    if cfg.remat and mode == "train":
        body = jax.checkpoint(cycle_body,
                              policy=jax.checkpoint_policies.nothing_saveable)

    xs = (p["cycles"],
          None if caches is None or mode == "train" else caches["cycles"])
    if mode == "train":
        # scan needs a matching-None xs pytree; pass params only.
        x, _ = jax.lax.scan(lambda c, cp: body(c, (cp, None)),
                            x, p["cycles"])
        new_caches = None
    else:
        x, cyc_caches = jax.lax.scan(body, x, xs)
        new_caches = {"cycles": cyc_caches, "tail": []}

    for j, kind in enumerate(cfg.tail_kinds):
        c_in = None if caches is None else caches["tail"][j]
        x, c_out = apply_layer(cfg, kind, p["tail"][j], x, mode, c_in, pos)
        if new_caches is not None:
            new_caches["tail"].append(c_out)
    return x, new_caches


def _head_matrix(cfg: ArchConfig, p: dict) -> jnp.ndarray:
    if cfg.has_embedding and cfg.tie_embeddings:
        return p["embed"].T
    return p["head"]


def forward(cfg: ArchConfig, p: dict, inputs) -> jnp.ndarray:
    """Full-sequence logits (small-vocab / test use; see train_loss)."""
    x = _embed_inputs(cfg, p, inputs)
    x, _ = _run_layers(cfg, p, x, "train", None, None)
    x = rms_norm(x, p["final_norm"], cfg.norm_eps)
    return unembed_logits(x, _head_matrix(cfg, p), cfg.final_softcap)


def train_loss(cfg: ArchConfig, p: dict, inputs, labels,
               mask=None, ce_chunk: int = 512) -> jnp.ndarray:
    """Mean next-token (or masked-prediction) CE loss.

    inputs: (B, T) int tokens, or (B, T, D) frame embeddings when
    ``cfg.has_embedding`` is False.  labels: (B, T) int.
    """
    x = _embed_inputs(cfg, p, inputs)
    x, _ = _run_layers(cfg, p, x, "train", None, None)
    x = rms_norm(x, p["final_norm"], cfg.norm_eps)
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    return chunked_ce_loss(x, _head_matrix(cfg, p), labels, mask,
                           softcap=cfg.final_softcap, chunk=ce_chunk)


def init_decode_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    nc = cfg.n_cycles
    cycles = {}
    for i, kind in enumerate(cfg.pattern):
        one = init_cache(cfg, kind, batch, max_len)
        cycles[f"slot{i}"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (nc,) + x.shape), one)
    tail = [init_cache(cfg, kind, batch, max_len)
            for kind in cfg.tail_kinds]
    return {"cycles": cycles, "tail": tail}


def prefill(cfg: ArchConfig, p: dict, inputs, max_len: int):
    """Run the prompt, return (logits_last (B, V), caches).

    Attention caches are allocated at ``max_len`` and the first T
    entries populated; recurrent caches carry (h, conv) state.
    """
    assert cfg.causal, "prefill/decode only for causal LMs"
    b, t = inputs.shape[:2]
    x = _embed_inputs(cfg, p, inputs)
    x, caches = _run_layers(cfg, p, x, "prefill", None, None)
    if max_len > t:
        # Grow global-attention KV caches from T to max_len entries.
        caches = _grow_caches(cfg, caches, t, max_len)
    x = rms_norm(x[:, -1:], p["final_norm"], cfg.norm_eps)
    logits = unembed_logits(x[:, 0], _head_matrix(cfg, p),
                            cfg.final_softcap)
    return logits, caches


def _grow_caches(cfg: ArchConfig, caches: dict, t: int, max_len: int):
    """Pad global-attention KV caches from t to max_len entries."""
    def fix(kind, cache, stacked):
        if kind in ("global", "moe") and cache is not None:
            ax = 3 if stacked else 2

            def pad_leaf(x):
                pw = [(0, 0)] * x.ndim
                pw[ax] = (0, max_len - t)
                return jnp.pad(x, pw)

            return jax.tree_util.tree_map(pad_leaf, cache)
        return cache
    out = {"cycles": {}, "tail": []}
    for i, kind in enumerate(cfg.pattern):
        out["cycles"][f"slot{i}"] = fix(kind, caches["cycles"][f"slot{i}"],
                                        True)
    for j, kind in enumerate(cfg.tail_kinds):
        out["tail"].append(fix(kind, caches["tail"][j], False))
    return out


def decode_step(cfg: ArchConfig, p: dict, caches: dict, tokens, pos):
    """One decode step.  tokens: (B,) int; pos: scalar int32 (traced).

    Returns (logits (B, V), new_caches).
    """
    assert cfg.causal
    x = _embed_inputs(cfg, p, tokens[:, None])
    x, new_caches = _run_layers(cfg, p, x, "decode", caches, pos)
    x = rms_norm(x, p["final_norm"], cfg.norm_eps)
    logits = unembed_logits(x[:, 0], _head_matrix(cfg, p),
                            cfg.final_softcap)
    return logits, new_caches


def param_count(params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))
