"""Shared model components: norms, RoPE, embeddings, chunked CE loss."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.sharding.api import logical_constraint


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray,
         theta: float) -> jnp.ndarray:
    """x: (B, H, T, D); positions: (T,) or (B, T) absolute positions."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[:, None] * freq[None, :]
        ang = ang[None, None]                       # (1, 1, T, half)
    else:
        ang = positions.astype(jnp.float32)[:, None, :, None] * freq
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def embed_tokens(embed: jnp.ndarray, tokens: jnp.ndarray,
                 d_model: int) -> jnp.ndarray:
    x = jnp.take(embed, tokens, axis=0)
    x = x * jnp.asarray(math.sqrt(d_model), x.dtype)
    return logical_constraint(x, "batch", "seq", None)


def unembed_logits(x: jnp.ndarray, embed_t: jnp.ndarray,
                   softcap: float | None) -> jnp.ndarray:
    """x: (..., D) @ embed_t (D, V) with optional final softcap."""
    logits = jnp.einsum("...d,dv->...v", x, embed_t,
                        preferred_element_type=jnp.float32)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


def chunked_ce_loss(x: jnp.ndarray, embed_t: jnp.ndarray,
                    labels: jnp.ndarray, mask: jnp.ndarray, *,
                    softcap: float | None, chunk: int = 512
                    ) -> jnp.ndarray:
    """Cross-entropy without materializing full (B, T, V) logits.

    x: (B, T, D) final hidden states; embed_t: (D, V); labels: (B, T)
    int32; mask: (B, T) float (0 = ignore).  Logits are computed one
    T-chunk at a time (lax.map) with the vocab axis sharding-constrained,
    so peak memory is (B, chunk, V/model_parallel) per device.
    """
    b, t, d = x.shape
    chunk = max(1, min(chunk, t))
    pad = (-t) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = x.shape[1] // chunk
    xc = x.reshape(b, nc, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(b, nc, chunk).swapaxes(0, 1)
    mc = mask.reshape(b, nc, chunk).swapaxes(0, 1)

    def one(args):
        xb, lb, mb = args
        logits = unembed_logits(xb, embed_t, softcap)
        logits = logical_constraint(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        return ((lse - gold) * mb).sum(), mb.sum()

    losses, counts = jax.lax.map(one, (xc, lc, mc))
    return losses.sum() / jnp.maximum(counts.sum(), 1.0)


# ----------------------------------------------------------------------
# Initializers
# ----------------------------------------------------------------------

def dense_init(key, shape, dtype, in_axis: int = 0):
    fan_in = shape[in_axis]
    std = fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray,
                  state: jnp.ndarray | None = None):
    """Depthwise causal conv.  x: (B, T, D); w: (W, D).

    Returns (y (B,T,D), new_state (B, W-1, D)) — state carries the last
    W-1 inputs for decode continuation.
    """
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(width))
    new_state = xp[:, xp.shape[1] - (width - 1):]
    return y.astype(x.dtype), new_state
