"""Model zoo: unified decoder LM / MoE / xLSTM / RecurrentGemma / encoder.

All 10 assigned architectures instantiate through ``ArchConfig`` +
``init_params`` / ``train_loss`` / ``prefill`` / ``decode_step``.
"""
from .config import ArchConfig
from .model import (decode_step, forward, init_decode_cache, init_params,
                    param_count, prefill, train_loss)

__all__ = ["ArchConfig", "init_params", "forward", "train_loss",
           "prefill", "decode_step", "init_decode_cache", "param_count"]
