"""Import-aware name resolution shared by the rule families.

Turns an ``ast`` call target back into a canonical dotted path
(``np.random.default_rng`` -> ``numpy.random.default_rng``;
``from time import perf_counter`` + ``perf_counter()`` ->
``time.perf_counter``) so rules match on what is actually called, not
on whatever alias a module picked.
"""
from __future__ import annotations

import ast


def import_aliases(tree: ast.Module) -> dict:
    """Local name -> canonical dotted origin, from a module's imports.

    ``import numpy as np``            np   -> numpy
    ``import numpy.random``           numpy -> numpy (root binding)
    ``from numpy import random as r`` r    -> numpy.random
    ``from datetime import datetime`` datetime -> datetime.datetime
    Relative imports keep their bare module tail (enough to recognise
    in-package targets like ``.policy``).
    """
    out = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    out[a.asname] = a.name
                else:
                    root = a.name.split(".")[0]
                    out[root] = root
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            for a in node.names:
                if a.name == "*":
                    continue
                local = a.asname or a.name
                out[local] = f"{mod}.{a.name}" if mod else a.name
    return out


def dotted(node: ast.AST, aliases: dict) -> str:
    """Canonical dotted path of a Name/Attribute chain, or ``""``.

    The chain's root is translated through ``aliases``; unknown roots
    pass through verbatim (so ``self.rng.choice`` still yields
    ``self.rng.choice`` for structural matching).
    """
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return ""
    parts.append(node.id)
    parts.reverse()
    parts[0] = aliases.get(parts[0], parts[0])
    return ".".join(parts)


def call_name(call: ast.Call, aliases: dict) -> str:
    return dotted(call.func, aliases)


def is_constant_expr(node: ast.AST) -> bool:
    """Literal-only expression (constants, containers of constants,
    unary minus) — i.e. a hard-coded seed."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.UnaryOp):
        return is_constant_expr(node.operand)
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        return all(is_constant_expr(e) for e in node.elts)
    return False


def unparse_trim(node: ast.AST, width: int = 48) -> str:
    try:
        s = ast.unparse(node)
    except Exception:   # pragma: no cover - very old constructs
        s = type(node).__name__
    s = " ".join(s.split())
    return s if len(s) <= width else s[: width - 1] + "…"
