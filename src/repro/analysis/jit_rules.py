"""Jit-readiness rules (family ``jit``).

ROADMAP item 1 moves the hot O(n·K) paths — bitplane state helpers,
the budgeted-round matching engine, the fair-share water-fill — into
jitted JAX/Pallas kernels at n=5k-50k.  Everything a tracer cannot
stage must surface first: Python ``if``/``while`` branching on array
*values* (concretization error under ``jit``), ``float()``/``int()``/
``bool()``/``.item()`` host round-trips, and data-dependent Python
loops (``while alive.any()``, ``for i in np.flatnonzero(...)``) that
need ``lax.while_loop``/masking rewrites.

Findings here are ``warning`` severity: they are a *worklist* for the
scaling PR (emitted as the scorecard), not bugs — each target function
is correct today and baselined with that justification.
"""
from __future__ import annotations

import ast

from .findings import Finding
from .registry import AnalyzerRule, register_rule
from .resolve import call_name, import_aliases, unparse_trim

# Functions slated for the jitted engine: module-path suffix -> final
# qualname segments.  Extend this table as kernels are promoted.
JIT_TARGETS = {
    "repro/core/state.py": (
        "owner_windows", "eligible_supply", "candidate_columns"),
    "repro/core/schedulers.py": (
        "_schedule_centralized_batched", "_count_rows",
        "_extract_prefix"),
    "repro/net/fairshare.py": ("maxmin_rates", "transport",
                               "_maxmin_fill"),
    # Promoted kernels of the jitted engine (PR 8): staying on the
    # scorecard keeps host-coercion regressions visible.
    "repro/core/jit_engine.py": ("_slot_rounds", "_rank_counts",
                                 "_extract_ranked", "_kth_set_bit"),
}

_ARRAY_METHODS = {"any", "all", "sum", "min", "max", "item", "argmax",
                  "argmin", "nonzero", "prod", "mean"}
_ARRAY_PROPS = {"size", "shape", "ndim"}
_DATA_ITER = {"numpy.flatnonzero", "numpy.nonzero", "numpy.argwhere",
              "numpy.unique", "numpy.where"}


def jit_targets(ctx):
    """Yield (path, qualname, FunctionDef) for every slated function
    present in the analyzed set.  Under ``assume_library`` every module
    is matched against the union of slated names (rule fixtures)."""
    all_names = {n for names in JIT_TARGETS.values() for n in names}
    for path, tree in ctx.modules.items():
        if ctx.assume_library:
            wanted = all_names
        else:
            wanted = {n for suffix, names in JIT_TARGETS.items()
                      if path.endswith(suffix) for n in names}
        if not wanted:
            continue
        for qual, fn in ctx.walk_functions(tree):
            if qual.rsplit(".", 1)[-1] in wanted:
                yield path, qual, fn


def _array_tainted_names(fn, aliases) -> set:
    """One-level taint: locals assigned from an array-smelling
    expression (``t = min(tu.min(), td.min())``)."""
    tainted: set = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and _smells_array(
                node.value, aliases, tainted):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    tainted.add(tgt.id)
    return tainted


def _smells_array(node, aliases, tainted=frozenset()) -> bool:
    """Does this expression read an array value (method reductions,
    shape/size props, numpy calls, or tainted scalars)?"""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute):
            if sub.attr in _ARRAY_PROPS:
                return True
            # reduction METHOD call: x.any() — the Call parent decides,
            # but seeing the attribute inside a call test is enough
            if sub.attr in _ARRAY_METHODS:
                return True
        elif isinstance(sub, ast.Call):
            if call_name(sub, aliases).startswith("numpy."):
                return True
        elif isinstance(sub, ast.Name) and sub.id in tainted:
            return True
    return False


class _JitRuleBase(AnalyzerRule):
    family = "jit"
    severity = "warning"

    def check(self, ctx):
        out = []
        for path, tree in ctx.modules.items():
            aliases = import_aliases(tree)
            seen: set = set()
            for tpath, qual, fn in jit_targets(ctx):
                if tpath != path or (path, qual) in seen:
                    continue
                seen.add((path, qual))
                tainted = _array_tainted_names(fn, aliases)
                out.extend(self.check_function(path, qual, fn, aliases,
                                               tainted))
        return out

    def check_function(self, path, qual, fn, aliases, tainted):
        raise NotImplementedError

    def _finding(self, path, node, qual, kind, message, hint):
        return Finding(
            rule=self.rule, severity=self.severity, path=path,
            line=node.lineno, scope=qual,
            detail=f"{kind}:{unparse_trim(node, 40)}",
            message=message, hint=hint)


@register_rule
class ArrayBranchRule(_JitRuleBase):
    """JIT101: Python ``if`` branching on an array value inside a
    jit-slated function — concretizes the trace."""

    rule = "JIT101"
    title = "Python if on array value in jit-slated function"

    def check_function(self, path, qual, fn, aliases, tainted):
        out = []
        for node in ast.walk(fn):
            if isinstance(node, ast.If) and _smells_array(
                    node.test, aliases, tainted):
                out.append(self._finding(
                    path, node.test, qual, "if",
                    f"{qual}: `if {unparse_trim(node.test)}` branches "
                    f"on an array value — untraceable under jit",
                    "rewrite with jnp.where / lax.cond or hoist the "
                    "branch out of the kernel"))
        return out


@register_rule
class HostCoercionRule(_JitRuleBase):
    """JIT102: ``float()``/``int()``/``bool()``/``.item()`` host
    round-trips of computed (array-derived) values."""

    rule = "JIT102"
    title = "host scalar coercion in jit-slated function"

    def check_function(self, path, qual, fn, aliases, tainted):
        out = []
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node, aliases)
            is_cast = (name in ("float", "int", "bool")
                       and len(node.args) == 1)
            is_item = (isinstance(node.func, ast.Attribute)
                       and node.func.attr == "item")
            if is_cast:
                arg = node.args[0]
                computed = not isinstance(arg, (ast.Constant, ast.Name)) \
                    or (isinstance(arg, ast.Name) and arg.id in tainted)
                if not computed:
                    continue
            elif not is_item:
                continue
            out.append(self._finding(
                path, node, qual, "coerce",
                f"{qual}: `{unparse_trim(node)}` forces a device->host "
                f"sync — blocks tracing/async dispatch",
                "keep the value as a 0-d array inside the kernel; "
                "coerce only at the jit boundary"))
        return out


@register_rule
class DataDependentLoopRule(_JitRuleBase):
    """JIT103: data-dependent Python loops (``while`` on array state,
    ``while True``, ``for`` over nonzero/unique index sets)."""

    rule = "JIT103"
    title = "data-dependent Python loop in jit-slated function"

    def check_function(self, path, qual, fn, aliases, tainted):
        out = []
        for node in ast.walk(fn):
            if isinstance(node, ast.While):
                is_true = (isinstance(node.test, ast.Constant)
                           and node.test.value is True)
                if is_true or _smells_array(node.test, aliases, tainted):
                    out.append(self._finding(
                        path, node.test, qual, "while",
                        f"{qual}: `while {unparse_trim(node.test)}` — "
                        f"trip count depends on array data",
                        "rewrite as lax.while_loop or a bounded "
                        "fori_loop with masking"))
            elif isinstance(node, (ast.For, ast.AsyncFor)):
                it = node.iter
                if (isinstance(it, ast.Call)
                        and call_name(it, aliases) in _DATA_ITER):
                    out.append(self._finding(
                        path, it, qual, "for",
                        f"{qual}: `for … in {unparse_trim(it)}` — "
                        f"iteration set is data-dependent",
                        "vectorize over the full axis with a mask "
                        "instead of gathering indices"))
        return out


def scorecard(ctx, findings) -> list:
    """Per-target jit-readiness rows: (path, qualname, {rule: count},
    ready?).  Functions with zero jit findings are kernel-ready."""
    by_scope: dict = {}
    for f in findings:
        if f.rule.startswith("JIT"):
            by_scope.setdefault((f.path, f.scope), {}).setdefault(
                f.rule, 0)
            by_scope[(f.path, f.scope)][f.rule] += 1
    rows = []
    for path, qual, _fn in sorted(jit_targets(ctx)):
        counts = by_scope.get((path, qual), {})
        rows.append((path, qual, counts, not counts))
    return rows
