"""Visibility-escape rule (family ``visibility``).

``core/policy.py`` gates what a :class:`SlotView` reveals by the
policy's declared tier (``"none"`` < ``"neighborhood"`` < ``"full"``)
— but only at *runtime*, so an over-reaching plugin that no test
executes ships silently.  VIS001 turns the gate into a lint-time
guarantee: it derives the accessor tier table from ``SlotView``'s own
source (every ``self._require(VISIBILITY_X, ...)`` call), resolves
every registered/derived ``SchedulerPolicy`` subclass, and walks the
``schedule()`` call graph with the view object tainted through
assignments, helper calls, and ``self.*`` methods.  Any reachable
accessor whose tier exceeds the declared visibility is a finding.

``_engine_state`` carries no ``_require`` gate (it is the audited
backend door for the equivalence-locked built-in engines) and is
pinned to the ``"full"`` tier here — a plugin reaching it escapes the
tier system entirely.
"""
from __future__ import annotations

import ast
from pathlib import Path

from .findings import Finding
from .registry import AnalyzerRule, register_rule
from .resolve import import_aliases

TIER_LEVELS = {"none": 0, "neighborhood": 1, "full": 2}
_VIS_NAMES = {"VISIBILITY_FULL": "full",
              "VISIBILITY_NEIGHBORHOOD": "neighborhood",
              "VISIBILITY_NONE": "none"}
_ROOT_CLASS = "SchedulerPolicy"
_MAX_DEPTH = 6


def _policy_source(ctx):
    """(path, source) of core/policy.py — from the analyzed set if
    present, else from this package's sibling tree (so analyzing only
    ``examples/`` still gets the real tier table)."""
    for path, src in ctx.sources.items():
        if path.endswith("repro/core/policy.py"):
            return path, src
    p = Path(__file__).resolve().parent.parent / "core" / "policy.py"
    return p.as_posix(), p.read_text(encoding="utf-8")


def _tier_expr(node) -> str:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value if node.value in TIER_LEVELS else ""
    name = ""
    if isinstance(node, ast.Name):
        name = node.id
    elif isinstance(node, ast.Attribute):
        name = node.attr
    return _VIS_NAMES.get(name, "")


def slotview_tiers(src: str) -> dict:
    """Accessor name -> required tier, derived from SlotView's AST.

    A method/property is gated at the tier its ``self._require(...)``
    call names; everything else is ungated (``"none"``).  The audited
    ``_engine_state`` door is pinned ``"full"``.
    """
    tree = ast.parse(src)
    tiers: dict = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == "SlotView":
            for item in node.body:
                if not isinstance(item, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                    continue
                if item.name in ("_require", "__init__"):
                    continue
                tier = "none"
                for call in ast.walk(item):
                    if (isinstance(call, ast.Call)
                            and isinstance(call.func, ast.Attribute)
                            and call.func.attr == "_require"
                            and call.args):
                        got = _tier_expr(call.args[0])
                        if got:
                            tier = got
                tiers[item.name] = tier
    tiers["_engine_state"] = "full"
    return tiers


class _ClassInfo:
    def __init__(self, path, node, bases):
        self.path = path
        self.node = node
        self.bases = bases                    # base-name tails
        self.methods = {m.name: m for m in node.body
                        if isinstance(m, (ast.FunctionDef,
                                          ast.AsyncFunctionDef))}


def _class_table(ctx) -> dict:
    table: dict = {}
    for path, tree in ctx.modules.items():
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef):
                bases = []
                for b in node.bases:
                    if isinstance(b, ast.Name):
                        bases.append(b.id)
                    elif isinstance(b, ast.Attribute):
                        bases.append(b.attr)
                # First definition wins; policy classes have unique
                # names in practice.
                table.setdefault(node.name, _ClassInfo(path, node, bases))
    return table


def _is_policy(name, table, seen=None) -> bool:
    if name == _ROOT_CLASS:
        return True
    seen = seen or set()
    if name in seen or name not in table:
        return False
    seen.add(name)
    return any(_is_policy(b, table, seen) for b in table[name].bases)


def _mro(name, table):
    """Linearized class chain (the class, then bases, breadth-first)."""
    out, queue, seen = [], [name], set()
    while queue:
        cur = queue.pop(0)
        if cur in seen or cur not in table:
            seen.add(cur)
            continue
        seen.add(cur)
        out.append(table[cur])
        queue.extend(table[cur].bases)
    return out


def _declared_visibility(name, table) -> str:
    for info in _mro(name, table):
        for item in info.node.body:
            if isinstance(item, ast.Assign):
                for tgt in item.targets:
                    if (isinstance(tgt, ast.Name)
                            and tgt.id == "visibility"):
                        tier = _tier_expr(item.value)
                        if tier:
                            return tier
            elif (isinstance(item, ast.AnnAssign)
                  and isinstance(item.target, ast.Name)
                  and item.target.id == "visibility"
                  and item.value is not None):
                tier = _tier_expr(item.value)
                if tier:
                    return tier
    return "full"                 # SchedulerPolicy's own default


def _module_functions(tree) -> dict:
    return {f.name: f for f in tree.body
            if isinstance(f, (ast.FunctionDef, ast.AsyncFunctionDef))}


def _resolve_free_function(ctx, cur_path, name, aliases):
    """(path, FunctionDef) for a called module-level function, resolved
    in the current module or across analyzed modules via imports."""
    funcs = _module_functions(ctx.modules[cur_path])
    if name in funcs:
        return cur_path, funcs[name]
    target = aliases.get(name, "")
    if "." in target:
        mod_tail, fn_name = target.rsplit(".", 1)
        mod_file = mod_tail.replace(".", "/") + ".py"
        for path, tree in ctx.modules.items():
            if path.endswith(mod_file) or path.endswith(
                    "/" + mod_tail.split(".")[-1] + ".py"):
                cand = _module_functions(tree)
                if fn_name in cand:
                    return path, cand[fn_name]
    return None, None


@register_rule
class VisibilityEscapeRule(AnalyzerRule):
    """VIS001: a policy's schedule() call graph reaches a SlotView
    accessor above its declared visibility tier."""

    rule = "VIS001"
    family = "visibility"
    severity = "error"
    title = "policy call graph escapes its declared visibility tier"

    def check(self, ctx):
        _, policy_src = _policy_source(ctx)
        tiers = slotview_tiers(policy_src)
        table = _class_table(ctx)
        out = []
        for name, info in table.items():
            if name == _ROOT_CLASS or not _is_policy(name, table):
                continue
            entry = None
            for cls_info in _mro(name, table):
                if "schedule" in cls_info.methods:
                    entry = cls_info
                    break
            if entry is None:
                continue
            # Report on the class that *declares* the tier; inherited
            # schedule() bodies are analyzed in the subclass's context
            # only when the subclass re-declares nothing — skip the
            # duplicate walk when the defining class is itself a policy
            # with the same declared tier (its own row covers it).
            declared = _declared_visibility(name, table)
            if (entry.node.name != name
                    and _declared_visibility(entry.node.name, table)
                    == declared):
                continue
            self._walk_policy(ctx, name, declared, entry, table, tiers,
                              out)
        return out

    # -- call-graph taint walk ------------------------------------------
    def _walk_policy(self, ctx, cls_name, declared, entry, table, tiers,
                     out):
        lvl = TIER_LEVELS[declared]
        visited = set()
        hits: dict = {}     # accessor -> (path, line, func qualname)

        def visit(path, fn, tainted, depth, qual, owner):
            key = (path, fn.lineno, frozenset(tainted))
            if depth > _MAX_DEPTH or key in visited:
                return
            visited.add(key)
            aliases = import_aliases(ctx.modules[path])
            local = set(tainted)
            for node in ast.walk(fn):
                if isinstance(node, ast.Assign):
                    if (isinstance(node.value, ast.Name)
                            and node.value.id in local):
                        for tgt in node.targets:
                            if isinstance(tgt, ast.Name):
                                local.add(tgt.id)
                elif isinstance(node, ast.Attribute):
                    if (isinstance(node.value, ast.Name)
                            and node.value.id in local
                            and node.attr in tiers
                            and TIER_LEVELS[tiers[node.attr]] > lvl):
                        hits.setdefault(
                            node.attr, (path, node.lineno, qual))
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                t_args = [
                    isinstance(a, ast.Name) and a.id in local
                    for a in node.args]
                t_kw = {kw.arg: isinstance(kw.value, ast.Name)
                        and kw.value.id in local
                        for kw in node.keywords if kw.arg}
                callee = path2 = None
                self_call = (isinstance(node.func, ast.Attribute)
                             and isinstance(node.func.value, ast.Name)
                             and node.func.value.id == "self")
                if self_call and owner is not None:
                    for cls_info in _mro(owner, table):
                        if node.func.attr in cls_info.methods:
                            callee = cls_info.methods[node.func.attr]
                            path2 = cls_info.path
                            break
                elif isinstance(node.func, ast.Name):
                    path2, callee = _resolve_free_function(
                        ctx, path, node.func.id, aliases)
                if callee is None:
                    continue
                params = [p.arg for p in (*callee.args.posonlyargs,
                                          *callee.args.args)]
                if self_call and params and params[0] == "self":
                    params = params[1:]
                nxt = {p for p, t in zip(params, t_args) if t}
                nxt |= {p for p, t in t_kw.items() if t}
                if not nxt and not self_call:
                    continue       # no view flows in; nothing to find
                visit(path2, callee, nxt, depth + 1,
                      f"{qual}->{callee.name}",
                      owner if self_call else None)

        sched = entry.methods["schedule"]
        params = [p.arg for p in (*sched.args.posonlyargs,
                                  *sched.args.args)]
        seed = {p for p in params[1:]} & {"view"}
        if not seed and len(params) > 1:
            seed = {params[1]}
        visit(entry.path, sched, seed, 0, f"{cls_name}.schedule",
              cls_name)

        aliases = {v: k for k, v in _VIS_NAMES.items()}
        for accessor, (path, line, qual) in sorted(hits.items()):
            need = tiers[accessor]
            out.append(Finding(
                rule=self.rule, severity=self.severity,
                path=path, line=line, scope=cls_name, detail=accessor,
                message=f"{cls_name} declares visibility "
                        f"{declared!r} but {qual} reaches SlotView."
                        f"{accessor} (requires {need!r})"
                        + (" — the ungated engine door"
                           if accessor == "_engine_state" else ""),
                hint=f"use accessors at or below "
                     f"{aliases.get(declared, declared)} tier "
                     f"(e.g. availability_union/resolve_requests), or "
                     f"declare visibility={need!r} honestly"))
