"""Structured findings + the justified-baseline mechanism (swarmlint).

A :class:`Finding` is one rule hit with a *stable identity*: the
baseline keys on ``rule:path:scope:detail`` (never the line number, so
unrelated edits don't churn the baseline).  A baseline entry suppresses
a finding only when it carries a non-empty human justification — the
baseline is a reviewed ledger of accepted debt, not a mute button.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field

SEVERITIES = ("error", "warning")


@dataclass(frozen=True)
class Finding:
    """One rule violation at a concrete site.

    ``scope``  — enclosing symbol (``Class.method`` / function name),
    ``detail`` — rule-specific stable token (accessor name, call name,
    construct kind) so the baseline key survives line drift.
    """

    rule: str
    severity: str
    path: str          # repo-relative, forward slashes
    line: int
    message: str
    hint: str = ""
    scope: str = ""
    detail: str = ""

    @property
    def key(self) -> str:
        return f"{self.rule}:{self.path}:{self.scope}:{self.detail}"

    def render(self) -> str:
        loc = f"{self.path}:{self.line}"
        out = f"{self.severity:7s} {self.rule} {loc} [{self.scope}] {self.message}"
        if self.hint:
            out += f"\n        hint: {self.hint}"
        return out


@dataclass
class Baseline:
    """Justified suppression ledger (``analysis_baseline.json``).

    Schema::

        {"version": 1,
         "entries": [{"key": "<finding key>",
                      "justification": "<why this is accepted>"}]}
    """

    entries: dict = field(default_factory=dict)   # key -> justification
    path: str = ""

    @classmethod
    def load(cls, path) -> "Baseline":
        with open(path, encoding="utf-8") as fh:
            raw = json.load(fh)
        if not isinstance(raw, dict) or "entries" not in raw:
            raise ValueError(f"{path}: baseline must be an object with "
                             f"an 'entries' list")
        entries = {}
        for i, e in enumerate(raw["entries"]):
            key = e.get("key", "")
            just = str(e.get("justification", "")).strip()
            if not key:
                raise ValueError(f"{path}: entry {i} has no 'key'")
            if not just:
                raise ValueError(
                    f"{path}: entry {key!r} has no justification — a "
                    f"baseline entry must say WHY the finding is "
                    f"accepted")
            entries[key] = just
        return cls(entries=entries, path=str(path))

    def covers(self, finding: Finding) -> bool:
        return finding.key in self.entries

    def unused(self, findings) -> list:
        """Baseline keys no current finding matches (stale entries)."""
        live = {f.key for f in findings}
        return sorted(k for k in self.entries if k not in live)


def split_by_baseline(findings, baseline: Baseline | None):
    """Partition findings into (new, baselined)."""
    if baseline is None:
        return list(findings), []
    new, old = [], []
    for f in findings:
        (old if baseline.covers(f) else new).append(f)
    return new, old


def write_baseline(path, findings, previous: Baseline | None = None):
    """Emit a baseline covering ``findings``; keeps prior justifications
    and stamps ``TODO: justify`` on fresh entries (the CLI refuses a
    baseline whose justifications are still TODO only at load? no — it
    refuses empty ones; TODO is visible debt for the reviewer)."""
    prev = previous.entries if previous is not None else {}
    seen = []
    for f in sorted(findings, key=lambda f: (f.path, f.rule, f.scope,
                                             f.detail)):
        if f.key in (e["key"] for e in seen):
            continue
        seen.append({"key": f.key,
                     "justification": prev.get(f.key, "TODO: justify")})
    with open(path, "w", encoding="utf-8") as fh:
        json.dump({"version": 1, "entries": seen}, fh, indent=2)
        fh.write("\n")
