"""Analyzer rule registry — the ``register_policy`` idiom for lint rules.

A rule is one class with a stable ``rule`` id, a ``family``
(``"rng"`` / ``"visibility"`` / ``"jit"``), a default severity, and a
``check(ctx)`` returning :class:`~repro.analysis.findings.Finding`
lists over the parsed-module :class:`AnalysisContext`.  Rules
self-register under :func:`register_rule`, mirroring
``repro.core.policy.register_policy``, so adding an invariant is one
class — it shows up in the CLI, the baseline keys, and the unit-test
matrix without touching the driver.
"""
from __future__ import annotations

import ast
from pathlib import Path

FAMILIES = ("rng", "visibility", "jit", "obs")


class AnalysisContext:
    """Parsed view of the files under analysis.

    ``modules`` maps repo-relative posix paths to parsed ``ast.Module``
    trees; ``sources`` to raw text.  Helpers classify layers the rule
    families scope to (``core/``, ``net/``, ``fl/`` are the
    simulation-determinism layers; everything under ``src/repro`` is
    library code).
    """

    def __init__(self, root: Path, assume_library: bool = False):
        self.root = Path(root)
        self.modules: dict[str, ast.Module] = {}
        self.sources: dict[str, str] = {}
        self.errors: list[str] = []
        # Treat every analyzed file as library + sim-layer code (rule
        # fixtures and ad-hoc runs outside the src tree).
        self.assume_library = assume_library
        self._scope_cache: dict[str, dict] = {}

    # -- construction ---------------------------------------------------
    def add_paths(self, paths) -> "AnalysisContext":
        for p in paths:
            p = Path(p)
            if not p.is_absolute():
                p = self.root / p
            files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
            for f in files:
                self.add_file(f)
        return self

    def add_file(self, f: Path):
        f = Path(f)
        try:
            rel = f.resolve().relative_to(self.root.resolve()).as_posix()
        except ValueError:
            rel = f.as_posix()
        if rel in self.modules:
            return
        src = f.read_text(encoding="utf-8")
        try:
            self.modules[rel] = ast.parse(src, filename=rel)
        except SyntaxError as e:
            self.errors.append(f"{rel}: syntax error: {e}")
            return
        self.sources[rel] = src

    # -- layer classification -------------------------------------------
    def is_library(self, path: str) -> bool:
        if self.assume_library:
            return True
        return "src/repro/" in f"/{path}" or path.startswith("repro/")

    def is_sim_layer(self, path: str) -> bool:
        """core/, net/, fl/ — the layers whose determinism the slot/event
        parity and golden-schedule tests rely on."""
        if self.assume_library:
            return True
        return any(f"repro/{layer}/" in path
                   for layer in ("core", "net", "fl"))

    def scopes(self, path: str) -> dict:
        """Memoized lineno -> enclosing-qualname map for a module."""
        if path not in self._scope_cache:
            self._scope_cache[path] = self.enclosing_scopes(
                self.modules[path])
        return self._scope_cache[path]

    def walk_functions(self, tree: ast.Module):
        """Yield ``(qualname, FunctionDef)`` for every def in a module."""
        def rec(node, prefix):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    q = f"{prefix}{child.name}"
                    yield q, child
                    yield from rec(child, q + ".")
                elif isinstance(child, ast.ClassDef):
                    yield from rec(child, f"{prefix}{child.name}.")
        yield from rec(tree, "")

    def enclosing_scopes(self, tree: ast.Module) -> dict:
        """lineno -> qualname of the innermost enclosing def/class."""
        spans = []
        for q, fn in self.walk_functions(tree):
            spans.append((fn.lineno, fn.end_lineno, q))
        out = {}
        for lo, hi, q in sorted(spans, key=lambda s: (s[0], -s[1])):
            for ln in range(lo, (hi or lo) + 1):
                out[ln] = q         # inner defs overwrite outer spans
        return out


class AnalyzerRule:
    """One static invariant check (see module docstring)."""

    rule: str = ""
    family: str = ""
    severity: str = "error"
    title: str = ""

    def check(self, ctx: AnalysisContext):
        raise NotImplementedError

    def __repr__(self) -> str:   # pragma: no cover - debugging aid
        return (f"{type(self).__name__}(rule={self.rule!r}, "
                f"family={self.family!r}, severity={self.severity!r})")


_REGISTRY: dict[str, type] = {}


def register_rule(cls: type) -> type:
    """Class decorator: make ``cls`` resolvable by its ``rule`` id."""
    if not issubclass(cls, AnalyzerRule):
        raise TypeError(f"{cls!r} is not an AnalyzerRule")
    if not cls.rule:
        raise ValueError(f"{cls.__name__} must set a non-empty .rule")
    if cls.family not in FAMILIES:
        raise ValueError(f"{cls.__name__}.family must be one of "
                         f"{FAMILIES}, got {cls.family!r}")
    if cls.rule in _REGISTRY and _REGISTRY[cls.rule] is not cls:
        raise ValueError(f"duplicate rule id {cls.rule!r}")
    _REGISTRY[cls.rule] = cls
    return cls


def rule_ids() -> tuple:
    return tuple(sorted(_REGISTRY))


def get_rules(families=None) -> list:
    """Fresh instances of every registered rule (optionally one
    family)."""
    out = []
    for rid in rule_ids():
        cls = _REGISTRY[rid]
        if families is None or cls.family in families:
            out.append(cls())
    return out
