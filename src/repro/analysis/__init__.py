"""repro.analysis — swarmlint: static invariant analysis for the repro.

Four rule families guard the contracts the reproduction's claims rest
on (see ``docs/INVARIANTS.md``):

* ``rng``         — one threaded rng stream (RNG001-RNG007);
* ``visibility``  — SlotView tier discipline at lint time (VIS001);
* ``jit``         — jit-readiness of the kernel-slated hot paths
                    (JIT101-JIT103) + scorecard;
* ``obs``         — telemetry discipline in the sim layers: no print,
                    no inline host-time reads (OBS001-OBS002); route
                    through ``repro.obs`` / the injectable clocks.

Pure stdlib (no numpy/jax import), so ``python -m repro.analysis``
runs anywhere a checkout exists.  Rules self-register via
:func:`register_rule`, mirroring ``repro.core.policy.register_policy``.
"""
from .findings import (Baseline, Finding, split_by_baseline,
                       write_baseline)
from .registry import (FAMILIES, AnalysisContext, AnalyzerRule,
                       get_rules, register_rule, rule_ids)

# Importing the rule modules registers their rules.
from . import jit_rules, obs_rules, rng_rules, visibility
from .cli import collect_findings, main
from .jit_rules import JIT_TARGETS, scorecard
from .visibility import slotview_tiers

__all__ = [
    "AnalysisContext", "AnalyzerRule", "Baseline", "FAMILIES",
    "Finding", "JIT_TARGETS", "collect_findings", "get_rules",
    "jit_rules", "main", "obs_rules", "register_rule", "rng_rules",
    "rule_ids", "scorecard", "slotview_tiers", "split_by_baseline",
    "visibility", "write_baseline",
]
