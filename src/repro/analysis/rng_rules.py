"""Determinism / rng-discipline rules (family ``rng``).

The simulation's headline invariant is ONE threaded
``np.random.Generator`` stream: every draw that can influence a
schedule or a trace flows through an rng parameter seeded exactly once
at an entry point (``SwarmConfig.seed``), with derived streams split
off via salted ``SeedSequence``s.  These rules flag the ways that
contract silently breaks: process-global generators, fresh or
constant-seeded generators inside library code, unordered-set
iteration feeding loop order, identity-based sorts, and wall-clock
reads inside the simulation layers.
"""
from __future__ import annotations

import ast

from .findings import Finding
from .registry import AnalyzerRule, register_rule
from .resolve import (call_name, import_aliases, is_constant_expr,
                      unparse_trim)

# Legacy process-global numpy RNG surface (np.random.<fn> module calls).
_NP_LEGACY = {
    "rand", "randn", "random", "random_sample", "ranf", "sample",
    "choice", "seed", "shuffle", "permutation", "permuted", "randint",
    "random_integers", "uniform", "normal", "standard_normal",
    "binomial", "poisson", "beta", "gamma", "exponential", "bytes",
    "get_state", "set_state",
}

# Generator constructors whose seeding discipline RNG003/RNG004 police.
_GEN_CTORS = {
    "numpy.random.default_rng", "numpy.random.SeedSequence",
    "jax.random.PRNGKey", "jax.random.key",
}

# Parameter names that mark a function as rng-threaded.
_RNG_PARAMS = {"rng", "key", "prng", "prng_key", "rngs", "generator"}

_WALLCLOCK = {
    "time.time", "time.time_ns", "time.perf_counter",
    "time.perf_counter_ns", "time.monotonic", "time.monotonic_ns",
    "time.process_time", "time.process_time_ns",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
}


def _calls(tree: ast.Module):
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            yield node


def _param_names(fn) -> set:
    a = fn.args
    names = [p.arg for p in
             (*a.posonlyargs, *a.args, *a.kwonlyargs)]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return set(names)


@register_rule
class StdlibRandomRule(AnalyzerRule):
    """RNG001: any stdlib ``random.*`` call in library code."""

    rule = "RNG001"
    family = "rng"
    severity = "error"
    title = "stdlib random.* call (process-global, unseeded stream)"

    def check(self, ctx):
        out = []
        for path, tree in ctx.modules.items():
            if not ctx.is_library(path):
                continue
            aliases = import_aliases(tree)
            scopes = ctx.scopes(path)
            for call in _calls(tree):
                name = call_name(call, aliases)
                if name.startswith("random.") and name.count(".") == 1:
                    out.append(Finding(
                        rule=self.rule, severity=self.severity,
                        path=path, line=call.lineno,
                        scope=scopes.get(call.lineno, "<module>"),
                        detail=name,
                        message=f"stdlib {name}() draws from the "
                                f"process-global Mersenne stream",
                        hint="thread the shared np.random.Generator "
                             "(view.rng / a cfg-seeded stream) instead"))
        return out


@register_rule
class NumpyGlobalRngRule(AnalyzerRule):
    """RNG002: legacy ``np.random.<fn>`` module-global calls."""

    rule = "RNG002"
    family = "rng"
    severity = "error"
    title = "legacy numpy global-RNG call"

    def check(self, ctx):
        out = []
        for path, tree in ctx.modules.items():
            if not ctx.is_library(path):
                continue
            aliases = import_aliases(tree)
            scopes = ctx.scopes(path)
            for call in _calls(tree):
                name = call_name(call, aliases)
                if (name.startswith("numpy.random.")
                        and name.rsplit(".", 1)[1] in _NP_LEGACY):
                    out.append(Finding(
                        rule=self.rule, severity=self.severity,
                        path=path, line=call.lineno,
                        scope=scopes.get(call.lineno, "<module>"),
                        detail=name,
                        message=f"{name}() mutates/reads numpy's "
                                f"process-global RNG state",
                        hint="use a threaded np.random.Generator "
                             "method on the shared stream"))
        return out


@register_rule
class UnseededGeneratorRule(AnalyzerRule):
    """RNG003: ``default_rng()`` / ``PRNGKey()`` with no seed in
    library code — fresh OS entropy, unreproducible by construction."""

    rule = "RNG003"
    family = "rng"
    severity = "error"
    title = "unseeded fresh generator in library code"

    def check(self, ctx):
        out = []
        for path, tree in ctx.modules.items():
            if not ctx.is_library(path):
                continue
            aliases = import_aliases(tree)
            scopes = ctx.scopes(path)
            for call in _calls(tree):
                name = call_name(call, aliases)
                if (name in _GEN_CTORS and not call.args
                        and not call.keywords):
                    short = name.rsplit(".", 1)[1]
                    out.append(Finding(
                        rule=self.rule, severity=self.severity,
                        path=path, line=call.lineno,
                        scope=scopes.get(call.lineno, "<module>"),
                        detail=f"{short}()",
                        message=f"{short}() seeds from OS entropy — "
                                f"every run differs",
                        hint="seed from cfg.seed (or a salted "
                             "SeedSequence) or accept a threaded rng "
                             "parameter"))
        return out


@register_rule
class ConstantSeedShadowRule(AnalyzerRule):
    """RNG004: a generator built from a hard-coded constant seed inside
    a function that already takes a threaded rng/key parameter — the
    classic silent-fallback bug: every un-threaded call returns the
    SAME 'random' result while the call site looks seeded."""

    rule = "RNG004"
    family = "rng"
    severity = "error"
    title = "constant-seeded generator shadows a threaded rng param"

    def check(self, ctx):
        out = []
        for path, tree in ctx.modules.items():
            if not ctx.is_library(path):
                continue
            aliases = import_aliases(tree)
            for qual, fn in ctx.walk_functions(tree):
                if not (_param_names(fn) & _RNG_PARAMS):
                    continue
                for call in ast.walk(fn):
                    if not isinstance(call, ast.Call):
                        continue
                    name = call_name(call, aliases)
                    if name not in _GEN_CTORS or not call.args:
                        continue
                    if all(is_constant_expr(a) for a in call.args):
                        short = name.rsplit(".", 1)[1]
                        out.append(Finding(
                            rule=self.rule, severity=self.severity,
                            path=path, line=call.lineno, scope=qual,
                            detail=f"{short}({unparse_trim(call.args[0], 24)})",
                            message=f"{short} built from a constant "
                                    f"seed inside {qual}(), which "
                                    f"takes a threaded rng parameter "
                                    f"— unthreaded calls all produce "
                                    f"identical draws",
                            hint="require the rng parameter (raise "
                                 "when None) instead of a constant-"
                                 "seed fallback"))
        return out


@register_rule
class SetIterationRule(AnalyzerRule):
    """RNG005: iterating a ``set``/``frozenset`` in the simulation
    layers — set order is hash-salt/insertion dependent, so any loop
    over one can reorder scheduling decisions or trace rows."""

    rule = "RNG005"
    family = "rng"
    severity = "error"
    title = "unordered-set iteration in a simulation layer"

    def _is_set_expr(self, node, aliases) -> bool:
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        if isinstance(node, ast.Call):
            return call_name(node, aliases) in ("set", "frozenset")
        return False

    def check(self, ctx):
        out = []
        for path, tree in ctx.modules.items():
            if not ctx.is_sim_layer(path):
                continue
            aliases = import_aliases(tree)
            scopes = ctx.scopes(path)
            # Names bound to set-producing expressions, per enclosing
            # scope (module level keys on "<module>").
            tainted: dict = {}
            for node in ast.walk(tree):
                if (isinstance(node, ast.Assign)
                        and self._is_set_expr(node.value, aliases)):
                    sc = scopes.get(node.lineno, "<module>")
                    for tgt in node.targets:
                        if isinstance(tgt, ast.Name):
                            tainted.setdefault(sc, set()).add(tgt.id)

            def iter_exprs(node):
                if isinstance(node, (ast.For, ast.AsyncFor)):
                    yield node.iter
                elif isinstance(node, (ast.ListComp, ast.SetComp,
                                       ast.DictComp, ast.GeneratorExp)):
                    for gen in node.generators:
                        yield gen.iter

            for node in ast.walk(tree):
                for it in iter_exprs(node):
                    sc = scopes.get(node.lineno, "<module>")
                    bad = self._is_set_expr(it, aliases) or (
                        isinstance(it, ast.Name)
                        and it.id in tainted.get(sc, ()))
                    if bad:
                        what = (it.id if isinstance(it, ast.Name)
                                else "set-literal")
                        out.append(Finding(
                            rule=self.rule, severity=self.severity,
                            path=path, line=node.lineno, scope=sc,
                            detail=what,
                            message=f"iteration over unordered set "
                                    f"{what!r} — order is undefined "
                                    f"across runs/salts",
                            hint="iterate sorted(...) or keep an "
                                 "ordered container (list / np array)"))
        return out


@register_rule
class IdSortRule(AnalyzerRule):
    """RNG006: ``sorted(..., key=id)`` / ``.sort(key=id)`` — object
    addresses vary per process, the order is noise."""

    rule = "RNG006"
    family = "rng"
    severity = "error"
    title = "id()-keyed sort"

    def check(self, ctx):
        out = []
        for path, tree in ctx.modules.items():
            if not ctx.is_library(path):
                continue
            aliases = import_aliases(tree)
            scopes = ctx.scopes(path)
            for call in _calls(tree):
                name = call_name(call, aliases)
                if not (name == "sorted" or name.endswith(".sort")):
                    continue
                for kw in call.keywords:
                    if (kw.arg == "key" and isinstance(kw.value, ast.Name)
                            and kw.value.id == "id"):
                        out.append(Finding(
                            rule=self.rule, severity=self.severity,
                            path=path, line=call.lineno,
                            scope=scopes.get(call.lineno, "<module>"),
                            detail=name,
                            message="sort keyed on id() orders by "
                                    "memory address — different every "
                                    "process",
                            hint="sort on a stable field (index, name, "
                                 "tuple of values)"))
        return out


@register_rule
class WallClockRule(AnalyzerRule):
    """RNG007: wall-clock reads inside ``core/``, ``net/``, ``fl/`` —
    simulated time must come from the slot counter / event engine
    clock, never the host."""

    rule = "RNG007"
    family = "rng"
    severity = "error"
    title = "wall-clock read in a simulation layer"

    def check(self, ctx):
        out = []
        for path, tree in ctx.modules.items():
            if not ctx.is_sim_layer(path):
                continue
            aliases = import_aliases(tree)
            scopes = ctx.scopes(path)
            for call in _calls(tree):
                name = call_name(call, aliases)
                if name in _WALLCLOCK:
                    out.append(Finding(
                        rule=self.rule, severity=self.severity,
                        path=path, line=call.lineno,
                        scope=scopes.get(call.lineno, "<module>"),
                        detail=name,
                        message=f"{name}() reads the host clock inside "
                                f"a simulation layer",
                        hint="use the engine clock (EventEngine.t / "
                             "slot index); wall-clock belongs in "
                             "benchmarks only"))
        return out
