"""Observability-discipline rules (family ``obs``).

repro.obs (ISSUE 10) makes telemetry a typed, recordable stream; these
rules keep the simulation layers from growing ad-hoc side channels
around it.  ``core/`` / ``net/`` / ``fl/`` hot paths must not write to
stdout (``print``) nor read host time directly (``time.*``): stdout
telemetry is unqueryable and breaks the zero-overhead-when-disabled
contract, and direct clock reads bypass both the injectable measurement
clock (``core.simulator.set_clock`` / ``measured_clock``) and the
recorder's injectable span clock — the same hole RNG007 polices for
determinism, policed here for telemetry routing (OBS002 also covers
``time.sleep``/``strftime``-style calls RNG007's wall-clock set does
not).
"""
from __future__ import annotations

import ast

from .findings import Finding
from .registry import AnalyzerRule, register_rule
from .resolve import call_name, import_aliases


def _calls(tree: ast.Module):
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            yield node


@register_rule
class PrintRule(AnalyzerRule):
    """OBS001: ``print(...)`` in a simulation layer — ad-hoc stdout
    telemetry that no exporter, report, or regression gate can see."""

    rule = "OBS001"
    family = "obs"
    severity = "error"
    title = "print() in a simulation layer"

    def check(self, ctx):
        out = []
        for path, tree in ctx.modules.items():
            if not ctx.is_sim_layer(path):
                continue
            aliases = import_aliases(tree)
            scopes = ctx.scopes(path)
            for call in _calls(tree):
                if call_name(call, aliases) == "print":
                    out.append(Finding(
                        rule=self.rule, severity=self.severity,
                        path=path, line=call.lineno,
                        scope=scopes.get(call.lineno, "<module>"),
                        detail="print",
                        message="print() is write-only telemetry in a "
                                "simulation layer — invisible to the "
                                "obs exporters and the regression gate",
                        hint="emit a typed repro.obs event/counter "
                             "(obs.get().event(...)) or raise/warn"))
        return out


@register_rule
class HostTimeRule(AnalyzerRule):
    """OBS002: any direct ``time.*`` call in a simulation layer — host
    time must flow through the injectable clocks (``measured_clock`` /
    the recorder's span clock), never be read inline."""

    rule = "OBS002"
    family = "obs"
    severity = "error"
    title = "direct time.* call in a simulation layer"

    def check(self, ctx):
        out = []
        for path, tree in ctx.modules.items():
            if not ctx.is_sim_layer(path):
                continue
            aliases = import_aliases(tree)
            scopes = ctx.scopes(path)
            for call in _calls(tree):
                name = call_name(call, aliases)
                if name.startswith("time.") and name.count(".") == 1:
                    out.append(Finding(
                        rule=self.rule, severity=self.severity,
                        path=path, line=call.lineno,
                        scope=scopes.get(call.lineno, "<module>"),
                        detail=name,
                        message=f"{name}() reads/uses host time inline "
                                f"in a simulation layer",
                        hint="route through the injectable measurement "
                             "clock (core.simulator.measured_clock) or "
                             "a repro.obs Recorder span"))
        return out
