"""swarmlint CLI — ``python -m repro.analysis [opts] [paths]``.

Runs every registered rule over the given paths (default: ``src``),
subtracts the justified baseline, prints the jit-readiness scorecard,
and exits non-zero on any non-baselined finding.  Pure stdlib: the CI
job needs no third-party installs.

Exit codes: 0 clean (or fully baselined), 1 new findings, 2 usage /
parse / baseline errors.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from .findings import Baseline, split_by_baseline, write_baseline
from .jit_rules import scorecard
from .registry import FAMILIES, AnalysisContext, get_rules, rule_ids

DEFAULT_BASELINE = "analysis_baseline.json"


def collect_findings(ctx, families=None) -> list:
    found = []
    for rule in get_rules(families):
        found.extend(rule.check(ctx))
    found.sort(key=lambda f: (f.path, f.line, f.rule, f.detail))
    return found


def _print_scorecard(rows, out):
    print("\njit-readiness scorecard "
          "(worklist for the jitted-engine PR):", file=out)
    for path, qual, counts, ready in rows:
        if ready:
            status = "READY"
        else:
            status = ", ".join(f"{r}x{n}"
                               for r, n in sorted(counts.items()))
        print(f"  {path}::{qual:34s} {status}", file=out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="swarmlint: rng-discipline, visibility-escape and "
                    "jit-readiness static analysis")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files/directories to analyze (default: src)")
    ap.add_argument("--baseline", default=None,
                    help=f"justified-baseline JSON (default: "
                         f"./{DEFAULT_BASELINE} when present)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore any baseline file")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline to cover current "
                         "findings (keeps existing justifications; new "
                         "entries get 'TODO: justify')")
    ap.add_argument("--families", default=None,
                    help=f"comma list from {','.join(FAMILIES)} "
                         f"(default: all)")
    ap.add_argument("--assume-library", action="store_true",
                    help="treat every analyzed file as library + "
                         "sim-layer code (rule fixtures)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in get_rules():
            print(f"{rule.rule}  [{rule.family:10s}] "
                  f"{rule.severity:7s} {rule.title}")
        return 0

    families = None
    if args.families:
        families = tuple(args.families.split(","))
        bad = set(families) - set(FAMILIES)
        if bad:
            print(f"unknown families: {sorted(bad)}", file=sys.stderr)
            return 2

    ctx = AnalysisContext(Path.cwd(), assume_library=args.assume_library)
    try:
        ctx.add_paths(args.paths)
    except OSError as e:
        print(f"cannot read inputs: {e}", file=sys.stderr)
        return 2
    if ctx.errors:
        for err in ctx.errors:
            print(err, file=sys.stderr)
        return 2
    if not ctx.modules:
        print("no python files found under: "
              f"{' '.join(args.paths)}", file=sys.stderr)
        return 2

    findings = collect_findings(ctx, families)

    baseline = None
    bl_path = args.baseline or DEFAULT_BASELINE
    if not args.no_baseline and Path(bl_path).exists():
        try:
            baseline = Baseline.load(bl_path)
        except (ValueError, OSError) as e:
            print(f"bad baseline: {e}", file=sys.stderr)
            return 2
    elif args.baseline and not args.update_baseline:
        print(f"baseline {args.baseline} not found", file=sys.stderr)
        return 2

    if args.update_baseline:
        write_baseline(bl_path, findings, baseline)
        print(f"wrote {bl_path} covering {len(findings)} finding(s); "
              f"fill in any 'TODO: justify' entries")
        return 0

    new, baselined = split_by_baseline(findings, baseline)
    for f in new:
        print(f.render())

    rows = scorecard(ctx, findings)
    if rows and (families is None or "jit" in families):
        _print_scorecard(rows, sys.stdout)

    stale = baseline.unused(findings) if baseline else []
    if stale:
        print(f"\nnote: {len(stale)} stale baseline entr"
              f"{'y' if len(stale) == 1 else 'ies'} (no longer "
              f"firing) — prune with --update-baseline:")
        for k in stale:
            print(f"  {k}")

    print(f"\n{len(ctx.modules)} files, {len(rule_ids())} rules: "
          f"{len(new)} new finding(s), {len(baselined)} baselined")
    return 1 if new else 0
