"""Torrent collective: chunked ring dissemination + masked FedAvg.

``torrent_fedavg`` is the multi-device form of the paper's round
pipeline (§II-B): every client ships its *full* update to every other
client as fixed-size chunks, then each client aggregates over the
active set it reconstructed.  On the ``pod`` mesh axis that becomes

    stage s in 1..P-1:   pod p sends its circulating update copy to
                         pod (p+1) mod P, one ``ppermute`` per block
                         (the chunk; ``n_blocks`` explicit sends)
    after P-1 stages:    every pod holds all P updates (the paper's
                         "reconstructable set" with a generous
                         deadline = the full swarm)
    on-pod aggregate:    masked FedAvg  sum_u m_u w_u x_u / sum_u m_u w_u
                         over the stacked (P, D) buffer — the
                         ``kernels.fedavg.fedavg_reduce`` hot path.

Mapping to the paper's dissemination schedule: a ring stage is one
round-trip slot of the BT schedule with a full-rate pipe — each pod
*seeds* its own update and *relays* the one it received last stage, so
after P-1 stages chunk ownership is all-ones, exactly the terminal
state of the simulator's ``SwarmState``.  Splitting each stage into
``n_blocks`` independent ``ppermute`` sends is the chunking: the lowered
HLO contains (P-1) x n_blocks (+ scales, when compressed)
``collective-permute`` ops, so the XLA scheduler can overlap block k's
send with block k-1's accumulate the same way the BT pipeline overlaps
chunk transfers.

Wire compression (``compress=True``): each block is quantized int8 +
one f32 scale per block *once at its source* and the codes circulate
losslessly — receivers dequantize to accumulate, so quantization error
is one rounding per element (<2% relative), not per-hop.  Every pod
dequantizes its own blocks through the same path, keeping the aggregate
bit-identical across pods.

Zero active mass (``sum_u m_u w_u == 0``, e.g. every pod failed the
deadline) returns zeros, never NaN — the caller's apply step then
leaves params unchanged.

Single-device fallback: when ``mesh`` is None or has no ``pod`` axis of
matching size, ``torrent_fedavg`` aggregates the (optionally
quantize-roundtripped) blocks directly — the ring's provable terminal
state.  ``ring_allgather_emulated`` implements the full stage/roll
arithmetic on one device so tier-1 tests can check that terminal state
(every dest reconstructs every source, all dests agree) without the
multi-device harness.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.kernels.fedavg import fedavg_reduce, masked_normalized_weights
from repro.kernels.ref import chunk_dequantize, chunk_quantize
from repro.kernels.ref import fedavg_reduce as fedavg_reduce_ref
from repro.sharding.api import shard_map

# Normalized FedAvg weights; all-zero (not NaN) when no active mass.
masked_weights = masked_normalized_weights


def _flatten_updates(updates, n_blocks: int):
    """Pytree of (P, ...) leaves -> ((P, n_blocks, db) f32, meta)."""
    leaves, treedef = jax.tree_util.tree_flatten(updates)
    p = leaves[0].shape[0]
    for l in leaves:
        if l.shape[0] != p:
            raise ValueError("all update leaves need the same leading "
                             f"(client) axis; got {l.shape[0]} vs {p}")
    shapes = [l.shape[1:] for l in leaves]
    dtypes = [l.dtype for l in leaves]
    flat = jnp.concatenate(
        [l.reshape(p, -1).astype(jnp.float32) for l in leaves], axis=1)
    d = flat.shape[1]
    db = -(-d // n_blocks)
    pad = n_blocks * db - d
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    blocks = flat.reshape(p, n_blocks, db)
    return blocks, (treedef, shapes, dtypes, d)


def _unflatten(vec: jnp.ndarray, meta):
    treedef, shapes, dtypes, d = meta
    vec = vec.reshape(-1)[:d]
    out, off = [], 0
    for shp, dt in zip(shapes, dtypes):
        size = int(np.prod(shp, dtype=np.int64)) if shp else 1
        out.append(vec[off:off + size].reshape(shp).astype(dt))
        off += size
    return jax.tree_util.tree_unflatten(treedef, out)


def _pod_size(mesh) -> int:
    return 1 if mesh is None else int(mesh.shape.get("pod", 1))


def _aggregate(flat: jnp.ndarray, weights: jnp.ndarray,
               active: jnp.ndarray) -> jnp.ndarray:
    """On-pod masked FedAvg over the gathered (P, D) buffer.

    Zero-weight rows are selected out (not multiplied) so a pod that
    was masked *because* it diverged (NaN update) cannot poison the
    aggregate via 0 * NaN.
    """
    if jax.default_backend() == "tpu":
        return fedavg_reduce(flat, weights, active)
    return fedavg_reduce_ref(flat, weights, active)


def ring_allgather_emulated(blocks: jnp.ndarray, *, compress: bool = False
                            ) -> jnp.ndarray:
    """Single-device emulation of the P-1 stage ring.

    blocks: (P, n_blocks, db).  Returns gathered[dest, src, block, e] —
    exactly the buffer each pod holds after the ring, so tests can
    assert all-dest agreement without the subprocess harness.
    """
    p, n_blocks, db = blocks.shape
    if compress:
        q, s = chunk_quantize(blocks.reshape(p * n_blocks, db))
        buf_q = q.reshape(p, n_blocks, db)
        buf_s = s.reshape(p, n_blocks, 1)
    else:
        buf = blocks
    gathered = jnp.zeros((p,) + blocks.shape, jnp.float32)
    dest = jnp.arange(p)
    for stage in range(p):
        if compress:
            payload = chunk_dequantize(
                buf_q.reshape(p * n_blocks, db),
                buf_s.reshape(p * n_blocks, 1)).reshape(p, n_blocks, db)
        else:
            payload = buf
        gathered = gathered.at[dest, (dest - stage) % p].set(payload)
        if stage < p - 1:
            # every pod forwards to pod+1 == roll by +1 on the pod axis
            if compress:
                buf_q = jnp.roll(buf_q, 1, axis=0)
                buf_s = jnp.roll(buf_s, 1, axis=0)
            else:
                buf = jnp.roll(buf, 1, axis=0)
    return gathered


def _ring_device_body(p: int, n_blocks: int, compress: bool):
    """shard_map body: local (1, n_blocks, db) -> gathered aggregate."""
    perm = [(i, (i + 1) % p) for i in range(p)]

    def body(xb, weights, active):
        my = xb[0]                                   # (n_blocks, db)
        idx = jax.lax.axis_index("pod")
        if compress:
            buf_q, buf_s = chunk_quantize(my)        # int8 codes + scales
        else:
            buf = my
        gathered = jnp.zeros((p,) + my.shape, jnp.float32)
        src = idx
        for stage in range(p):
            if compress:
                payload = chunk_dequantize(buf_q, buf_s)
            else:
                payload = buf
            gathered = jax.lax.dynamic_update_slice(
                gathered, payload[None].astype(jnp.float32), (src, 0, 0))
            if stage < p - 1:
                # one explicit collective-permute per block = the
                # paper's chunked sends ((P-1) x n_blocks total)
                if compress:
                    buf_q = jnp.stack([
                        jax.lax.ppermute(buf_q[b], "pod", perm)
                        for b in range(n_blocks)])
                    buf_s = jax.lax.ppermute(buf_s, "pod", perm)
                else:
                    buf = jnp.stack([
                        jax.lax.ppermute(buf[b], "pod", perm)
                        for b in range(n_blocks)])
                src = (src - 1) % p
        return _aggregate(gathered.reshape(p, -1), weights, active)

    return body


def take_pods(tree, keep):
    """Slice the leading (pod) axis of every leaf to the surviving pods.

    The elastic re-mesh companion (§III-E): when the active pod count
    changes between rounds, the new P'-ring runs over
    ``take_pods(updates, keep)`` with (P',) weights/active — and its
    aggregate equals the old P-ring with the departed pods masked
    (``active=0``), because masked FedAvg weights renormalize over the
    same surviving mass.  Asserted in tests/test_session.py.
    """
    keep = jnp.asarray(keep, dtype=jnp.int32)
    return jax.tree_util.tree_map(
        lambda l: jnp.take(l, keep, axis=0), tree)


def torrent_fedavg(updates, weights: jnp.ndarray, active: jnp.ndarray, *,
                   mesh=None, n_blocks: int = 4, compress: bool = False):
    """Masked FedAvg of per-pod updates via the torrent ring.

    updates: pytree whose leaves have leading axis P (stacked per-pod
    updates); weights, active: (P,).  Returns the aggregate pytree with
    the leading axis removed — identical on every pod.
    """
    blocks, meta = _flatten_updates(updates, n_blocks)
    p = blocks.shape[0]
    pod = _pod_size(mesh)
    if pod > 1 and pod != p:
        raise ValueError(f"updates leading axis {p} != pod axis size {pod}")
    if pod > 1:
        ring = shard_map(
            _ring_device_body(p, n_blocks, compress), mesh,
            in_specs=(P("pod", None, None), P(None), P(None)),
            out_specs=P(None),
            check_rep=False)
        agg = ring(blocks, jnp.asarray(weights), jnp.asarray(active))
    else:
        # Single-device path: after the ring every dest holds exactly
        # the (optionally quantize-roundtripped) source blocks — see
        # test_ring_emulation_every_dest_reconstructs_all — so skip the
        # O(P^2) stage unroll and aggregate the blocks directly.
        if compress:
            nb, db = blocks.shape[1:]
            q, s = chunk_quantize(blocks.reshape(p * nb, db))
            blocks = chunk_dequantize(q, s).reshape(p, nb, db)
        agg = _aggregate(blocks.reshape(p, -1), weights, active)
    return _unflatten(agg, meta)
