"""Multi-device collective layer: the torrent ring + the FL step.

``torrent.py``  — ``torrent_fedavg``: the paper's chunked dissemination
schedule as an explicit block-wise ``ppermute`` ring over the ``pod``
mesh axis, followed by on-pod masked FedAvg.

``fl_step.py``  — ``make_fl_train_step`` / ``make_serve_step``: the
pod-masked FL training step (per-pod local gradients -> torrent
dissemination -> masked FedAvg -> AdamW) and the decode serving step;
``ElasticFLStep``: the elastic-P wrapper that rebuilds mesh + ring
schedule when the active pod count changes between rounds (§III-E).
"""
from .fl_step import ElasticFLStep, make_fl_train_step, make_serve_step
from .torrent import take_pods, torrent_fedavg

__all__ = ["torrent_fedavg", "take_pods", "make_fl_train_step",
           "make_serve_step", "ElasticFLStep"]
