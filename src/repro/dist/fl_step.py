"""Pod-masked FL training step and the decode serving step.

``make_fl_train_step`` builds the round step the paper's system runs on
a multi-pod mesh (§III):

    1. every pod computes the gradient of ITS batch shard locally
       (vmap over the leading pod axis; within a pod the batch is
       data-parallel over the ``data`` mesh axis),
    2. the per-pod gradients are disseminated with the torrent ring
       (``torrent_fedavg`` — explicit block-wise ppermute schedule),
    3. the masked FedAvg aggregate drives ONE AdamW update, identical
       on every pod.

Fault tolerance is a mask, never a blocked collective: a straggler pod
(``active[p] == 0``) still participates in the fixed ring schedule, but
its contribution is multiplied by exactly 0.0 — its batch provably
cannot influence the result, and no peer waits on it beyond the
constant P-1 stages.  With full participation and equal weights the
step is bit-close to plain data-parallel SGD (the FedAvg of per-pod
mean gradients IS the global mean gradient).

``n_pods == 1`` folds the pod axis into the batch and runs plain DP
SGD — the degenerate ring (P-1 = 0 stages) with no collective.

``ElasticFLStep`` is the cross-round elastic form (§III-E): when the
active pod count changes between rounds (a pod drops, a client
rejoins), it rebuilds the mesh AND the ring schedule for the new P and
re-jits — cached per P, so oscillating P -> P-1 -> P pays the re-mesh
cost once per distinct pod count.
"""
from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.dist.torrent import masked_weights, torrent_fedavg
from repro.models import decode_step, train_loss
from repro.optim import adamw_update
from repro.sharding.api import DEFAULT_RULES, axis_rules


def _microbatched_value_and_grad(loss_fn, params, inp, lab,
                                 microbatch: int):
    """d loss / d params, accumulated over microbatches when enabled.

    ``loss_fn(params, inputs, labels)``; the local batch dim is split
    into ``b // microbatch`` scan steps so activation memory scales
    with the microbatch, not the batch.
    """
    vg = jax.value_and_grad(loss_fn)
    b = inp.shape[0]
    if microbatch <= 0 or b <= microbatch:
        return vg(params, inp, lab)
    if b % microbatch:
        raise ValueError(f"local batch {b} is not divisible by "
                         f"microbatch {microbatch}; the split would "
                         "silently fall back to full-batch memory")
    nmb = b // microbatch
    ib = inp.reshape((nmb, microbatch) + inp.shape[1:])
    lb = lab.reshape((nmb, microbatch) + lab.shape[1:])

    def one(carry, xy):
        loss, grads = vg(params, xy[0], xy[1])
        acc_l, acc_g = carry
        acc_g = jax.tree_util.tree_map(
            lambda a, g: a + g.astype(jnp.float32), acc_g, grads)
        return (acc_l + loss, acc_g), None

    zeros = jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), params)
    (loss, grads), _ = jax.lax.scan(one, (jnp.zeros((), jnp.float32),
                                          zeros), (ib, lb))
    scale = 1.0 / nmb
    return loss * scale, jax.tree_util.tree_map(lambda g: g * scale, grads)


def make_fl_train_step(cfg, mesh, *, lr_schedule, n_pods: int,
                       rules=None, torrent_blocks: int = 4,
                       compress: bool = False, microbatch: int = 0,
                       ce_chunk: int = 512):
    """Returns step(params, opt, batch, weights, active) ->
    (params, opt, {"loss", "lr"}).

    batch: {"inputs": (n_pods, B_local, T[, D]), "labels": (...)} —
    the leading axis is the pod (FL client) axis; weights/active are
    (n_pods,) FedAvg weights and the round's participation mask.
    """
    rules = dict(DEFAULT_RULES if rules is None else rules)
    has_pod_axis = (mesh is not None and "pod" in mesh.axis_names
                    and n_pods > 1)

    def step(params, opt, batch, weights, active):
        with axis_rules(rules, mesh):
            def loss_fn(p, x, y):
                return train_loss(cfg, p, x, y, ce_chunk=ce_chunk)

            if n_pods <= 1:
                inp = batch["inputs"].reshape(
                    (-1,) + batch["inputs"].shape[2:])
                lab = batch["labels"].reshape(
                    (-1,) + batch["labels"].shape[2:])
                loss, agg = _microbatched_value_and_grad(
                    loss_fn, params, inp, lab, microbatch)
            else:
                def pod_grads(inp, lab):
                    return _microbatched_value_and_grad(
                        loss_fn, params, inp, lab, microbatch)

                losses, grads = jax.vmap(pod_grads)(
                    batch["inputs"], batch["labels"])
                if has_pod_axis:
                    # per-pod grads live on their pod (leading axis
                    # sharded); the ring is the only cross-pod traffic
                    grads = jax.tree_util.tree_map(
                        lambda g: jax.lax.with_sharding_constraint(
                            g, NamedSharding(mesh, P("pod"))), grads)
                agg = torrent_fedavg(
                    grads, weights, active,
                    mesh=mesh if has_pod_axis else None,
                    n_blocks=torrent_blocks, compress=compress)
                wn = masked_weights(weights, active)
                # select (don't multiply): a pod masked because it
                # diverged reports a NaN loss, and 0 * NaN == NaN
                loss = jnp.sum(jnp.where(
                    wn > 0, losses.astype(jnp.float32), 0.0) * wn)
            lr = lr_schedule(opt.step)
            new_params, new_opt = adamw_update(agg, opt, params, lr=lr)
            if n_pods > 1:
                # A round with zero active mass is a protocol no-op:
                # params, moments, and the step counter stay untouched
                # (zero grads would still apply weight decay and
                # advance the LR schedule).  Same zero-mass definition
                # as the aggregator's, so they cannot drift.
                has_mass = jnp.any(wn > 0)
                def pick(new, old):
                    return jnp.where(has_mass, new, old)
                new_params = jax.tree_util.tree_map(pick, new_params,
                                                    params)
                new_opt = jax.tree_util.tree_map(pick, new_opt, opt)
        return new_params, new_opt, {"loss": loss, "lr": lr}

    return step


class ElasticFLStep:
    """Elastic-P FL step: re-mesh + ring-schedule rebuild across rounds.

    ``mesh_factory(p)`` returns the mesh to train ``p`` active pods on
    (or None for the single-device path); it is consulted once per
    distinct pod count.  Each call dispatches on the batch's leading
    (pod) axis, so the caller just slices its batch to the surviving
    pods — e.g. with :func:`repro.dist.torrent.take_pods` — and the
    step re-meshes itself:

        step = ElasticFLStep(cfg, lr_schedule=sched, mesh_factory=mf)
        params, opt, m = step(params, opt, batch4, w4, a4)   # P=4 ring
        params, opt, m = step(params, opt, batch3, w3, a3)   # P=3 ring
        params, opt, m = step(params, opt, batch4, w4, a4)   # cached

    The first call at a new P pays one trace/compile (measured as
    ``remesh_ms`` in benchmarks/bench_session.py); revisited pod counts
    hit the cache.  Params/opt state carry across re-meshes unchanged —
    the §III-E recovery contract: a drop shrinks the collective, never
    resets training.
    """

    def __init__(self, cfg, *, lr_schedule, mesh_factory, **step_kw):
        self.cfg = cfg
        self.lr_schedule = lr_schedule
        self.mesh_factory = mesh_factory
        self.step_kw = dict(step_kw)
        self._cache: dict[int, tuple] = {}
        self._last_p: int | None = None

    def step_for(self, n_pods: int):
        """(mesh, jitted step) for ``n_pods`` active pods; cached."""
        if n_pods not in self._cache:
            mesh = self.mesh_factory(n_pods)
            step = make_fl_train_step(
                self.cfg, mesh, lr_schedule=self.lr_schedule,
                n_pods=n_pods, **self.step_kw)
            self._cache[n_pods] = (mesh, jax.jit(step))
        return self._cache[n_pods]

    @property
    def pod_counts(self) -> list[int]:
        """Pod counts a step has been built for (re-mesh history)."""
        return sorted(self._cache)

    def __call__(self, params, opt, batch, weights, active):
        p = int(batch["inputs"].shape[0])
        mesh, jstep = self.step_for(p)
        if mesh is not None and p != self._last_p:
            # Carried state is committed to the PREVIOUS mesh's device
            # set; replicate it onto the new (possibly smaller) one so
            # the re-jitted step can re-shard it internally.
            sh = NamedSharding(mesh, P())
            params, opt = jax.device_put((params, opt), sh)
        self._last_p = p
        ctx = mesh if mesh is not None else contextlib.nullcontext()
        with ctx:
            return jstep(params, opt, batch, jnp.asarray(weights),
                         jnp.asarray(active))


def make_serve_step(cfg):
    """Returns serve(params, caches, tokens, pos) ->
    (next_tokens, logits, new_caches) — one greedy decode step."""

    def serve(params, caches, tokens, pos):
        logits, new_caches = decode_step(cfg, params, caches, tokens, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, logits, new_caches

    return serve
