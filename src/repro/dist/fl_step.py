"""Pod-masked FL training step and the decode serving step.

``make_fl_train_step`` builds the round step the paper's system runs on
a multi-pod mesh (§III):

    1. every pod computes the gradient of ITS batch shard locally
       (vmap over the leading pod axis; within a pod the batch is
       data-parallel over the ``data`` mesh axis),
    2. the per-pod gradients are disseminated with the torrent ring
       (``torrent_fedavg`` — explicit block-wise ppermute schedule),
    3. the masked FedAvg aggregate drives ONE AdamW update, identical
       on every pod.

Fault tolerance is a mask, never a blocked collective: a straggler pod
(``active[p] == 0``) still participates in the fixed ring schedule, but
its contribution is multiplied by exactly 0.0 — its batch provably
cannot influence the result, and no peer waits on it beyond the
constant P-1 stages.  With full participation and equal weights the
step is bit-close to plain data-parallel SGD (the FedAvg of per-pod
mean gradients IS the global mean gradient).

``n_pods == 1`` folds the pod axis into the batch and runs plain DP
SGD — the degenerate ring (P-1 = 0 stages) with no collective.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.dist.torrent import masked_weights, torrent_fedavg
from repro.models import decode_step, train_loss
from repro.optim import adamw_update
from repro.sharding.api import DEFAULT_RULES, axis_rules


def _microbatched_value_and_grad(loss_fn, params, inp, lab,
                                 microbatch: int):
    """d loss / d params, accumulated over microbatches when enabled.

    ``loss_fn(params, inputs, labels)``; the local batch dim is split
    into ``b // microbatch`` scan steps so activation memory scales
    with the microbatch, not the batch.
    """
    vg = jax.value_and_grad(loss_fn)
    b = inp.shape[0]
    if microbatch <= 0 or b <= microbatch:
        return vg(params, inp, lab)
    if b % microbatch:
        raise ValueError(f"local batch {b} is not divisible by "
                         f"microbatch {microbatch}; the split would "
                         "silently fall back to full-batch memory")
    nmb = b // microbatch
    ib = inp.reshape((nmb, microbatch) + inp.shape[1:])
    lb = lab.reshape((nmb, microbatch) + lab.shape[1:])

    def one(carry, xy):
        loss, grads = vg(params, xy[0], xy[1])
        acc_l, acc_g = carry
        acc_g = jax.tree_util.tree_map(
            lambda a, g: a + g.astype(jnp.float32), acc_g, grads)
        return (acc_l + loss, acc_g), None

    zeros = jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), params)
    (loss, grads), _ = jax.lax.scan(one, (jnp.zeros((), jnp.float32),
                                          zeros), (ib, lb))
    scale = 1.0 / nmb
    return loss * scale, jax.tree_util.tree_map(lambda g: g * scale, grads)


def make_fl_train_step(cfg, mesh, *, lr_schedule, n_pods: int,
                       rules=None, torrent_blocks: int = 4,
                       compress: bool = False, microbatch: int = 0,
                       ce_chunk: int = 512):
    """Returns step(params, opt, batch, weights, active) ->
    (params, opt, {"loss", "lr"}).

    batch: {"inputs": (n_pods, B_local, T[, D]), "labels": (...)} —
    the leading axis is the pod (FL client) axis; weights/active are
    (n_pods,) FedAvg weights and the round's participation mask.
    """
    rules = dict(DEFAULT_RULES if rules is None else rules)
    has_pod_axis = (mesh is not None and "pod" in mesh.axis_names
                    and n_pods > 1)

    def step(params, opt, batch, weights, active):
        with axis_rules(rules, mesh):
            def loss_fn(p, x, y):
                return train_loss(cfg, p, x, y, ce_chunk=ce_chunk)

            if n_pods <= 1:
                inp = batch["inputs"].reshape(
                    (-1,) + batch["inputs"].shape[2:])
                lab = batch["labels"].reshape(
                    (-1,) + batch["labels"].shape[2:])
                loss, agg = _microbatched_value_and_grad(
                    loss_fn, params, inp, lab, microbatch)
            else:
                def pod_grads(inp, lab):
                    return _microbatched_value_and_grad(
                        loss_fn, params, inp, lab, microbatch)

                losses, grads = jax.vmap(pod_grads)(
                    batch["inputs"], batch["labels"])
                if has_pod_axis:
                    # per-pod grads live on their pod (leading axis
                    # sharded); the ring is the only cross-pod traffic
                    grads = jax.tree_util.tree_map(
                        lambda g: jax.lax.with_sharding_constraint(
                            g, NamedSharding(mesh, P("pod"))), grads)
                agg = torrent_fedavg(
                    grads, weights, active,
                    mesh=mesh if has_pod_axis else None,
                    n_blocks=torrent_blocks, compress=compress)
                wn = masked_weights(weights, active)
                # select (don't multiply): a pod masked because it
                # diverged reports a NaN loss, and 0 * NaN == NaN
                loss = jnp.sum(jnp.where(
                    wn > 0, losses.astype(jnp.float32), 0.0) * wn)
            lr = lr_schedule(opt.step)
            new_params, new_opt = adamw_update(agg, opt, params, lr=lr)
            if n_pods > 1:
                # A round with zero active mass is a protocol no-op:
                # params, moments, and the step counter stay untouched
                # (zero grads would still apply weight decay and
                # advance the LR schedule).  Same zero-mass definition
                # as the aggregator's, so they cannot drift.
                has_mass = jnp.any(wn > 0)
                pick = lambda new, old: jnp.where(has_mass, new, old)
                new_params = jax.tree_util.tree_map(pick, new_params,
                                                    params)
                new_opt = jax.tree_util.tree_map(pick, new_opt, opt)
        return new_params, new_opt, {"loss": loss, "lr": lr}

    return step


def make_serve_step(cfg):
    """Returns serve(params, caches, tokens, pos) ->
    (next_tokens, logits, new_caches) — one greedy decode step."""

    def serve(params, caches, tokens, pos):
        logits, new_caches = decode_step(cfg, params, caches, tokens, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, logits, new_caches

    return serve
