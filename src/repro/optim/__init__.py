from .adamw import OptState, adamw_init, adamw_update, global_norm
from .schedules import constant_lr, cosine_lr, linear_warmup_cosine

__all__ = ["OptState", "adamw_init", "adamw_update", "global_norm",
           "cosine_lr", "constant_lr", "linear_warmup_cosine"]
