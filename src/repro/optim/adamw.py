"""AdamW with fp32 master weights + global-norm clipping (pure JAX).

Mixed-precision layout (MaxText-style): model params live in the model
dtype (bf16); the optimizer keeps fp32 master weights and fp32 (m, v)
moments.  Under the sharding rules all four trees share the same
PartitionSpecs, so optimizer state is ZeRO-sharded wherever params are.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray          # () int32
    master: dict               # fp32 copy of params
    m: dict
    v: dict


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree_util.tree_leaves(tree)))


def adamw_init(params) -> OptState:
    def f32(t):
        return jax.tree_util.tree_map(
            lambda x: x.astype(jnp.float32), t)
    zeros = jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), master=f32(params),
                    m=zeros, v=jax.tree_util.tree_map(jnp.copy, zeros))


def adamw_update(grads, state: OptState, params, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float = 1.0):
    """Returns (new_params, new_state).  ``lr`` may be a scalar or a
    schedule value computed from ``state.step`` by the caller."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-12))
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    return _repack(params, grads, state, lr, b1, b2, eps, weight_decay,
                   scale, bc1, bc2, step)


def _repack(params, grads, state, lr, b1, b2, eps, weight_decay, scale,
            bc1, bc2, step):
    leaves_g, treedef = jax.tree_util.tree_flatten(grads)
    leaves_m = treedef.flatten_up_to(state.m)
    leaves_v = treedef.flatten_up_to(state.v)
    leaves_w = treedef.flatten_up_to(state.master)
    leaves_p = treedef.flatten_up_to(params)
    new_m, new_v, new_w, new_p = [], [], [], []
    for g, m, v, w, pp in zip(leaves_g, leaves_m, leaves_v, leaves_w,
                              leaves_p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        w = w - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * w)
        new_m.append(m)
        new_v.append(v)
        new_w.append(w)
        new_p.append(w.astype(pp.dtype))
    unf = treedef.unflatten
    return unf(new_p), OptState(step=step, master=unf(new_w),
                                m=unf(new_m), v=unf(new_v))
