"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_lr(base: float):
    return lambda step: jnp.asarray(base, jnp.float32)


def cosine_lr(base: float, total_steps: int, final_frac: float = 0.1):
    def f(step):
        t = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return base * (final_frac + (1 - final_frac) * cos)
    return f


def linear_warmup_cosine(base: float, warmup: int, total_steps: int,
                         final_frac: float = 0.1):
    cos = cosine_lr(base, max(total_steps - warmup, 1), final_frac)

    def f(step):
        s = step.astype(jnp.float32)
        warm = base * s / max(warmup, 1)
        return jnp.where(step < warmup, warm, cos(step - warmup))
    return f
