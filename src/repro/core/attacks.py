"""Observation-only attribution attacks and the ASR metric (paper §IV-C).

All three strategies fit Adversary A (honest-but-curious, possibly
colluding): they read only protocol-visible signals — sender round
pseudonyms, piece indices (mapped to *descriptor ids*, never owner
identities), and arrival order — from warm-up transfers observed by
corrupted receivers.

For each observed sender pseudonym the attacker outputs a descriptor
guess ("this sender is the source of that update").  A guess is correct
when the descriptor is the sender's own update.  Per-observer ASR is the
fraction of its observed senders attributed correctly; the paper's
conservative summary is the **maximum ASR over receivers** (and over
coalition members), which we report alongside the mean.

Descriptor ids: under homogeneous update sizes every update has K
chunks, so piece (c) belongs to descriptor ``c // K``.  The attacker
knows the descriptor partition (public torrent metadata) but not the
descriptor -> client mapping — attributing that mapping is exactly the
attack.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class AttackReport:
    asr_per_observer: dict           # observer -> accuracy
    max_asr: float
    mean_asr: float
    n_decisions: int
    any_correct_rate: float = 0.0    # for coalitions


def _observations(log: dict, observers: np.ndarray, K: int):
    """Group warm-up transfers by (observer, sender) preserving order."""
    mask = (log["phase"] == 1) & np.isin(log["receiver"], observers)
    slots = log["slot"][mask]
    snd = log["sender"][mask]
    rcv = log["receiver"][mask]
    desc = log["chunk"][mask] // K
    order = np.argsort(slots, kind="stable")
    return slots[order], snd[order], rcv[order], desc[order]


def _score(guesses: dict[tuple[int, int], int]) -> tuple[dict, float, float, int]:
    """guesses: (observer, sender) -> descriptor guess."""
    per_obs_total: dict[int, int] = {}
    per_obs_correct: dict[int, int] = {}
    for (obs, snd), g in guesses.items():
        per_obs_total[obs] = per_obs_total.get(obs, 0) + 1
        if g == snd:   # descriptor id == owner index by construction
            per_obs_correct[obs] = per_obs_correct.get(obs, 0) + 1
    asr = {o: per_obs_correct.get(o, 0) / t for o, t in per_obs_total.items()}
    if not asr:
        return {}, 0.0, 0.0, 0
    vals = np.array(list(asr.values()))
    return asr, float(vals.max()), float(vals.mean()), int(sum(per_obs_total.values()))


# ----------------------------------------------------------------------
# (1) Sequential Greedy: first chunk from each sender is labeled its own.
# ----------------------------------------------------------------------

def sequential_greedy(log: dict, observers, K: int, pooled: bool = False) -> AttackReport:
    observers = np.asarray(observers)
    slots, snd, rcv, desc = _observations(log, observers, K)
    guesses: dict[tuple[int, int], int] = {}
    seen: set[tuple[int, int]] = set()
    for i in range(len(snd)):
        key = (int(rcv[i]) if not pooled else -1, int(snd[i]))
        if key in seen:
            continue
        seen.add(key)
        guesses[key] = int(desc[i])
    # In pooled (coalition) mode all observations share one virtual
    # observer key (-1), modeling pooled evidence (§IV-B).
    asr, mx, mean, nd = _score(guesses)
    return AttackReport(asr, mx, mean, nd,
                        any_correct_rate=_any_correct(guesses))


# ----------------------------------------------------------------------
# (2) Amount Greedy: most frequent descriptor among a sender's early
#     transfers.
# ----------------------------------------------------------------------

def amount_greedy(log: dict, observers, K: int, pooled: bool = False) -> AttackReport:
    observers = np.asarray(observers)
    slots, snd, rcv, desc = _observations(log, observers, K)
    counts: dict[tuple[int, int], dict[int, int]] = {}
    first_seen: dict[tuple[int, int], int] = {}
    for i in range(len(snd)):
        key = (int(rcv[i]) if not pooled else -1, int(snd[i]))
        c = counts.setdefault(key, {})
        d = int(desc[i])
        c[d] = c.get(d, 0) + 1
        first_seen.setdefault((key, d), i)  # earliness tiebreak
    guesses = {}
    for key, c in counts.items():
        best = min(c.items(), key=lambda kv: (-kv[1], first_seen[(key, kv[0])]))
        guesses[key] = best[0]
    asr, mx, mean, nd = _score(guesses)
    return AttackReport(asr, mx, mean, nd,
                        any_correct_rate=_any_correct(guesses))


# ----------------------------------------------------------------------
# (3) Clustering: temporal + frequency feature matching.
# ----------------------------------------------------------------------

def clustering(log: dict, observers, K: int, pooled: bool = False) -> AttackReport:
    """Match sender pseudonyms to descriptors on a joint score combining
    (i) frequency of each descriptor among the sender's transfers and
    (ii) earliness (inverse arrival rank) — then take the best match per
    sender (greedy assignment, senders ordered by confidence)."""
    observers = np.asarray(observers)
    slots, snd, rcv, desc = _observations(log, observers, K)
    guesses: dict[tuple[int, int], int] = {}
    # Build per-(observer, sender) feature table.
    feats: dict[tuple[int, int], dict[int, list]] = {}
    for i in range(len(snd)):
        key = (int(rcv[i]) if not pooled else -1, int(snd[i]))
        f = feats.setdefault(key, {})
        d = int(desc[i])
        if d not in f:
            f[d] = [0, i]          # [count, first arrival rank]
        f[d][0] += 1
    n_obs = max(len(snd), 1)
    # Greedy assignment per observer: senders with the most confident
    # (count, earliness) signal pick first; a descriptor is used once.
    by_observer: dict[int, list] = {}
    for (obs, s), f in feats.items():
        scored = [
            (d, cnt + (1.0 - rank / n_obs)) for d, (cnt, rank) in f.items()
        ]
        scored.sort(key=lambda kv: -kv[1])
        by_observer.setdefault(obs, []).append((s, scored))
    for obs, senders in by_observer.items():
        senders.sort(key=lambda it: -(it[1][0][1] if it[1] else 0.0))
        used: set[int] = set()
        for s, scored in senders:
            pick = None
            for d, sc in scored:
                if d not in used:
                    pick = d
                    break
            if pick is None and scored:
                pick = scored[0][0]
            if pick is not None:
                used.add(pick)
                guesses[(obs, s)] = pick
    asr, mx, mean, nd = _score(guesses)
    return AttackReport(asr, mx, mean, nd,
                        any_correct_rate=_any_correct(guesses))


def _any_correct(guesses: dict[tuple[int, int], int]) -> float:
    if not guesses:
        return 0.0
    return float(any(g == s for (_, s), g in guesses.items()))


ATTACKS = {
    "sequence": sequential_greedy,
    "count": amount_greedy,
    "cluster": clustering,
}


def run_all_attacks(log: dict, observers, K: int, pooled: bool = False):
    return {name: fn(log, observers, K, pooled) for name, fn in ATTACKS.items()}


def random_guess_baseline(avg_degree: float) -> float:
    """Neighborhood-level random guessing ~ 1/m (paper §V-D)."""
    return 1.0 / max(avg_degree, 1.0)
