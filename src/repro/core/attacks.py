"""Observation-only attribution attacks and the ASR metric (paper §IV-C).

All strategies fit Adversary A (honest-but-curious, possibly colluding):
they read only protocol-visible signals — sender round pseudonyms, piece
indices (mapped to *descriptor ids*, never owner identities), and
arrival order — from warm-up transfers observed by corrupted receivers,
i.e. from a :class:`~repro.core.trace.TransferTrace` masked with
:meth:`~repro.core.trace.TransferTrace.observed_by`.

For each observed sender pseudonym the attacker outputs a descriptor
guess ("this sender is the source of that update").  A guess is correct
when the descriptor is the sender's own update.  Per-observer ASR is the
fraction of its observed senders attributed correctly; the paper's
conservative summary is the **maximum ASR over receivers** (and over
coalition members), which we report alongside the mean.

Descriptor ids: under homogeneous update sizes every update has K
chunks, so piece (c) belongs to descriptor ``c // K``.  The attacker
knows the descriptor partition (public torrent metadata) but not the
descriptor -> client mapping — attributing that mapping is exactly the
attack.

Implementations
---------------
The three single-round scorers are **vectorized** over the trace
columns (grouped ``np.unique`` / ``np.lexsort`` statistics instead of a
Python loop per observation) and reproduce the historical
per-observation reference implementations decision-for-decision; the
references are kept (``*_reference``) for the equivalence tests and the
``benchmarks/bench_attacks.py`` speedup baseline.

Cross-round adversary: :func:`persistent_neighbor_linkage` is the first
attack that exploits §III-E session persistence — an observer that stays
adjacent to the same physical sender across rounds
(``SwarmSession.pair_exposure()``) pools its per-round observations:
round-invariant evidence features (count share, earliness) of the
provisional per-round winners form a cross-round profile that re-ranks
noisy rounds, so accuracy grows with exposure instead of resetting at
every round boundary.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .trace import TransferTrace


@dataclass
class AttackReport:
    asr_per_observer: dict           # observer -> accuracy
    max_asr: float
    mean_asr: float
    n_decisions: int
    any_correct_rate: float = 0.0    # for coalitions


def _as_trace(log, K: int | None = None) -> TransferTrace:
    return TransferTrace.from_log(log, K=K)


def _observations(log, observers: np.ndarray, K: int):
    """Warm-up transfers visible to the coalition, in arrival order
    (stable slot sort, preserving within-slot log order).

    Gathers only the four observation columns (observer-membership via
    an O(1)-lookup table, not a sorted ``isin``) — this boundary is
    shared by the vectorized and reference scorers, so it must stay off
    the critical path of both.
    """
    tr = _as_trace(log, K)
    observers = np.asarray(observers, np.int64).ravel()
    rcv_all = tr.receiver
    mx = int(rcv_all.max(initial=-1))
    lut = np.zeros(mx + 2, dtype=bool)
    lut[observers[(observers >= 0) & (observers <= mx)]] = True
    mask = (tr.phase == 1) & lut[rcv_all]
    slots = tr.slot[mask]
    order = np.argsort(slots, kind="stable")
    return (slots[order], tr.sender[mask][order].astype(np.int64),
            rcv_all[mask][order].astype(np.int64),
            (tr.chunk[mask] // tr.K)[order])


def _empty_report() -> AttackReport:
    return AttackReport({}, 0.0, 0.0, 0)


def _report(g_obs: np.ndarray, g_snd: np.ndarray, g: np.ndarray,
            correct: np.ndarray | None = None,
            obs_stream: np.ndarray | None = None) -> AttackReport:
    """Score a batch of (observer, sender) -> descriptor guesses.

    ``obs_stream`` (the observer key of every raw observation, in
    arrival order) fixes the observer ordering used for the mean-ASR
    reduction to first-appearance order — bit-identical to the
    reference scorers' dict-insertion-order ``np.mean``.
    """
    if len(g) == 0:
        return _empty_report()
    if correct is None:
        correct = g == g_snd   # descriptor id == owner index in-round
    g_obs = np.asarray(g_obs, np.int64)
    ou, inv = np.unique(g_obs, return_inverse=True)
    tot = np.bincount(inv)
    cor = np.bincount(inv, weights=correct.astype(np.float64))
    vals = cor / tot
    if obs_stream is not None:
        su, sf = np.unique(np.asarray(obs_stream, np.int64),
                           return_index=True)
        stream_order = su[np.argsort(sf)]       # first-appearance order
        stream_order = stream_order[np.isin(stream_order, ou)]
        pos = np.searchsorted(ou, stream_order)
        vals = vals[pos]
        ou = ou[pos]
    asr = {int(o): float(v) for o, v in zip(ou, vals)}
    return AttackReport(asr, float(vals.max()), float(vals.mean()),
                        int(tot.sum()),
                        any_correct_rate=float(bool(correct.any())))


def _obs_key(rcv: np.ndarray, pooled: bool) -> np.ndarray:
    """Observer key per observation: the receiver, or one virtual
    pooled observer (-1) modeling coalition evidence (§IV-B)."""
    if pooled:
        return np.full(len(rcv), -1, dtype=np.int64)
    return rcv.astype(np.int64)


# ----------------------------------------------------------------------
# (1) Sequential Greedy: first chunk from each sender is labeled its own.
# ----------------------------------------------------------------------

def sequential_greedy(log, observers, K: int,
                      pooled: bool = False) -> AttackReport:
    slots, snd, rcv, desc = _observations(log, observers, K)
    if len(snd) == 0:
        return _empty_report()
    obs = _obs_key(rcv, pooled)
    pk = (obs + 1) * (int(snd.max()) + 2) + snd
    _, first = np.unique(pk, return_index=True)   # first occurrence
    return _report(obs[first], snd[first], desc[first], obs_stream=obs)


# ----------------------------------------------------------------------
# (2) Amount Greedy: most frequent descriptor among a sender's
#     transfers, earliest-first tiebreak.
# ----------------------------------------------------------------------

def amount_greedy(log, observers, K: int,
                  pooled: bool = False) -> AttackReport:
    slots, snd, rcv, desc = _observations(log, observers, K)
    if len(snd) == 0:
        return _empty_report()
    obs = _obs_key(rcv, pooled)
    pk = (obs + 1) * (int(snd.max()) + 2) + snd
    dk = pk * (int(desc.max()) + 2) + desc
    _, first, cnt = np.unique(dk, return_index=True, return_counts=True)
    u_pk = pk[first]
    # best candidate per pair: max count, earliest first-appearance
    order = np.lexsort((first, -cnt, u_pk))
    lead = np.ones(order.size, dtype=bool)
    lead[1:] = u_pk[order][1:] != u_pk[order][:-1]
    sel = first[order[lead]]
    return _report(obs[sel], snd[sel], desc[sel], obs_stream=obs)


# ----------------------------------------------------------------------
# (3) Clustering: temporal + frequency feature matching.
# ----------------------------------------------------------------------

def clustering(log, observers, K: int,
               pooled: bool = False) -> AttackReport:
    """Match sender pseudonyms to descriptors on a joint score combining
    (i) frequency of each descriptor among the sender's transfers and
    (ii) earliness (inverse arrival rank) — then take the best match per
    sender (greedy assignment, senders ordered by confidence; a
    descriptor is used once per observer)."""
    slots, snd, rcv, desc = _observations(log, observers, K)
    if len(snd) == 0:
        return _empty_report()
    obs = _obs_key(rcv, pooled)
    n_obs = max(len(snd), 1)
    pk = (obs + 1) * (int(snd.max()) + 2) + snd
    dk = pk * (int(desc.max()) + 2) + desc
    _, first, cnt = np.unique(dk, return_index=True, return_counts=True)
    u_pk, u_obs, u_snd, u_desc = pk[first], obs[first], snd[first], \
        desc[first]
    score = cnt + (1.0 - first / n_obs)
    # Candidate lists per pair in confidence order (score desc, ties by
    # first appearance — the reference's insertion-order stable sort).
    o1 = np.lexsort((first, -score, u_pk))
    pk1, desc1, score1 = u_pk[o1], u_desc[o1], score[o1]
    starts = np.flatnonzero(np.r_[True, pk1[1:] != pk1[:-1]])
    ends = np.r_[starts[1:], pk1.size]
    top = score1[starts]
    pair_obs, pair_snd = u_obs[o1][starts], u_snd[o1][starts]
    # Pair confidence order per observer: top score desc, ties by pair
    # first appearance (reference inserts pairs in observation order).
    o2 = np.lexsort((first, u_pk))
    pk2 = u_pk[o2]
    s2 = np.flatnonzero(np.r_[True, pk2[1:] != pk2[:-1]])
    pair_first = first[o2][s2]        # min first index per pair

    g_obs_l, g_snd_l, g_l = [], [], []
    for ob in np.unique(pair_obs):
        pidx = np.flatnonzero(pair_obs == ob)
        order = pidx[np.lexsort((pair_first[pidx], -top[pidx]))]
        used: set[int] = set()
        for p in order:
            pick = -1
            for j in range(starts[p], ends[p]):
                d = int(desc1[j])
                if d not in used:
                    pick = d
                    break
            if pick < 0:
                pick = int(desc1[starts[p]])
            used.add(pick)
            g_obs_l.append(int(ob))
            g_snd_l.append(int(pair_snd[p]))
            g_l.append(pick)
    return _report(np.asarray(g_obs_l, np.int64),
                   np.asarray(g_snd_l, np.int64),
                   np.asarray(g_l, np.int64), obs_stream=obs)


# ----------------------------------------------------------------------
# Timing side-channel: continuous-time attribution (repro.net traces)
# ----------------------------------------------------------------------

def timing_attribution(log, observers, K: int | None = None,
                       pooled: bool = False) -> AttackReport:
    """Attribute senders by transfer *instants* — the network-layer
    timing side-channel the event engine's trace exposes.

    The slot world hands an adversary only stage indices; the
    continuous-time trace (``t_start``/``t_end``, stamped by
    ``RoundSimulator(time_engine="event")``) leaks strictly more: flows
    pipeline chunks serially, so within a directive cycle the wire
    order of a sender's transfers is visible in their start instants,
    and a sender's *release instant* (its earliest observed activity —
    the lag expiry §III-B randomizes) is measurable to sub-slot
    precision.  This attacker exploits both: per observed sender it
    (i) estimates the release instant as ``min t_start``, then
    (ii) attributes the sender to the descriptor of the transfer
    nearest that release — the continuous-time sharpening of Sequential
    Greedy (UnlinkableDFL's network-layer observer model).

    Without the warm-up stack the first bytes a sender emits are its
    own chunks and the attack attributes near-perfectly; the full stack
    (spray fills buffers *before* release, cover-set gating holds owner
    chunks back, randomized lags decorrelate release order from data
    order) drives it back toward the 1/m guessing floor — the
    acceptance pair in ``tests/test_timing_attacks.py``.

    ``AttackReport.asr_per_observer`` keys and ASR semantics match the
    other scorers; inferred release instants are a deliberate protocol
    observable here, not ground truth.
    """
    tr = _as_trace(log, K)
    observers = np.asarray(observers, np.int64).ravel()
    rcv_all = tr.receiver
    mx = int(rcv_all.max(initial=-1))
    lut = np.zeros(mx + 2, dtype=bool)
    lut[observers[(observers >= 0) & (observers <= mx)]] = True
    mask = (tr.phase == 1) & lut[rcv_all]
    if not mask.any():
        return _empty_report()
    t0 = tr.t_start[mask]
    order = np.argsort(t0, kind="stable")       # arrival instants
    snd = tr.sender[mask][order].astype(np.int64)
    rcv = rcv_all[mask][order].astype(np.int64)
    desc = (tr.chunk[mask] // tr.K)[order]
    obs = _obs_key(rcv, pooled)
    # Earliest-instant observation per (observer, sender): with the
    # rows in t_start order, the first occurrence of each pair is the
    # transfer nearest the sender's inferred release.
    pk = (obs + 1) * (int(snd.max()) + 2) + snd
    _, first = np.unique(pk, return_index=True)
    return _report(obs[first], snd[first], desc[first], obs_stream=obs)


def release_instants(log, observers, K: int | None = None) -> dict:
    """Inferred per-sender release instants (seconds): the side-channel
    artifact itself — ``min t_start`` over each sender's observed
    warm-up transfers.  Under randomized lags these spread over
    ``~lag_slots`` directive cycles; without lags they collapse onto
    the first cycle (tested as the channel's existence proof)."""
    tr = _as_trace(log, K)
    observers = np.asarray(observers, np.int64).ravel()
    mask = (tr.phase == 1) & np.isin(tr.receiver, observers)
    snd = tr.sender[mask].astype(np.int64)
    ts = tr.t_start[mask]
    if snd.size == 0:
        return {}
    us, inv = np.unique(snd, return_inverse=True)
    rel = np.full(us.size, np.inf)
    np.minimum.at(rel, inv, ts)
    return {int(s): float(r) for s, r in zip(us, rel)}


# ----------------------------------------------------------------------
# Cross-round adversary: persistent-neighbor linkage (§III-E sessions)
# ----------------------------------------------------------------------

def persistent_neighbor_linkage(
    trace, observers, K: int | None = None, *,
    min_rounds: int = 3,
    exposure: np.ndarray | None = None,
    pooled: bool = False,
    vote_anchor: float = 4.0,
) -> AttackReport:
    """Cross-round linkage over a session trace (global peer ids).

    The first adversary that exploits §III-E session persistence.  An
    observer links the per-round pseudonyms of a *physically persistent*
    neighbor (same network-layer identity across rounds — feed
    ``SwarmSession.pair_exposure()`` as ``exposure`` to restrict to
    pairs with at least ``min_rounds`` co-resident rounds, the pairs the
    session-layer follow-up flags as linkable).  Each observed round it
    casts a vote: the sequential-greedy-anchored best descriptor for the
    sender (first-seen descriptor; count share + earliness break
    degenerate ties — ``vote_anchor`` scales the first-seen term).
    Votes then aggregate per (observer, sender) pair by **majority**
    into one sender-level attribution, so accuracy *amplifies* with
    exposure whenever the per-round rule is better than a coin flip —
    which is exactly the regime the paper's full defense stack avoids:
    with per-round ASR pushed to the 1/m guessing floor the majority
    vote de-amplifies instead, i.e. the single-round defenses also
    protect the multi-round session (tested in
    ``tests/test_cross_round_attacks.py``).

    One decision per linked pair; per-observer ASR is the fraction of
    its linked senders whose majority vote is correct.  Grading uses
    each round's ground-truth descriptor -> owner mapping (descriptors
    are re-keyed per round torrent); like every ASR metric here, ground
    truth is touched only to *grade* guesses.
    """
    tr = _as_trace(trace, K)
    view = tr.warmup().observed_by(np.asarray(observers))
    if len(view) == 0:
        return _empty_report()
    order = np.lexsort((view.slot, view.round))
    rnd = view.round[order].astype(np.int64)
    snd = view.sender[order].astype(np.int64)
    rcv = view.receiver[order].astype(np.int64)
    desc = view.desc()[order]
    obs = _obs_key(rcv, pooled)
    if exposure is not None:
        # Pair persistence is a property of the physical (receiver,
        # sender) edge, so the filter applies in pooled mode too — the
        # coalition pools evidence, but only over linkable pairs.
        keep = np.asarray(exposure)[rcv, snd] >= min_rounds
        if not keep.any():
            return _empty_report()
        rnd, snd, obs, desc = rnd[keep], snd[keep], obs[keep], desc[keep]

    base_s = int(snd.max()) + 2
    base_r = int(rnd.max()) + 2
    base_d = int(desc.max()) + 2
    pk = (obs + 1) * base_s + snd                 # (observer, sender)
    pr = pk * base_r + rnd                        # (o, s, round)
    prd = pr * base_d + desc                      # (o, s, round, desc)

    _, first, cnt = np.unique(prd, return_index=True, return_counts=True)
    c_pr, c_pk = pr[first], pk[first]
    c_obs, c_snd, c_rnd, c_desc = obs[first], snd[first], rnd[first], \
        desc[first]
    # per-(o,s,r) observation totals
    upr, pr_inv = np.unique(pr, return_inverse=True)
    tot = np.bincount(pr_inv)[np.searchsorted(upr, c_pr)].astype(
        np.float64)
    # earliness: first-appearance rank within the (o,s,r) group
    o2 = np.lexsort((first, c_pr))
    grp_lead = np.r_[True, c_pr[o2][1:] != c_pr[o2][:-1]]
    grp_id = np.cumsum(grp_lead) - 1
    pos = np.arange(o2.size) - np.flatnonzero(grp_lead)[grp_id]
    rank = np.empty(o2.size, np.int64)
    rank[o2] = pos
    early = 1.0 - rank / np.maximum(tot, 1.0)
    frac = cnt / np.maximum(tot, 1.0)
    score = vote_anchor * (rank == 0) + frac + early

    # one vote per (o, s, round): the top-scored candidate
    ow = np.lexsort((first, -score, c_pr))
    win = ow[np.r_[True, c_pr[ow][1:] != c_pr[ow][:-1]]]

    grade = tr.desc_owner_lookup()
    vote_ok = grade(c_rnd[win], c_desc[win]) == c_snd[win]

    # majority aggregation per (observer, sender) pair
    w_pk, w_obs, w_snd = c_pk[win], c_obs[win], c_snd[win]
    w_rnd, w_desc = c_rnd[win], c_desc[win]
    o3 = np.lexsort((w_rnd, w_pk))
    p_lead = np.r_[True, w_pk[o3][1:] != w_pk[o3][:-1]]
    p_id = np.cumsum(p_lead) - 1
    n_votes = np.bincount(p_id)
    n_ok = np.bincount(p_id, weights=vote_ok[o3].astype(np.float64))
    linked = n_votes >= min_rounds
    if not linked.any():
        return _empty_report()
    starts = np.flatnonzero(p_lead)
    last = np.r_[starts[1:], o3.size] - 1          # latest-round vote
    g_obs = w_obs[o3][starts][linked]
    g_snd = w_snd[o3][starts][linked]
    g = w_desc[o3][last][linked]   # representative guess: latest round
    correct = (n_ok > 0.5 * n_votes)[linked]       # strict majority
    return _report(g_obs, g_snd, g, correct=correct, obs_stream=obs)


# ----------------------------------------------------------------------
# Reference per-observation implementations (kept for equivalence tests
# and the BENCH_attacks vectorization baseline — see module docstring)
# ----------------------------------------------------------------------

def _score_reference(guesses: dict) -> tuple[dict, float, float, int]:
    per_obs_total: dict[int, int] = {}
    per_obs_correct: dict[int, int] = {}
    for (o, s), g in guesses.items():
        per_obs_total[o] = per_obs_total.get(o, 0) + 1
        if g == s:
            per_obs_correct[o] = per_obs_correct.get(o, 0) + 1
    asr = {o: per_obs_correct.get(o, 0) / t
           for o, t in per_obs_total.items()}
    if not asr:
        return {}, 0.0, 0.0, 0
    vals = np.array(list(asr.values()))
    return asr, float(vals.max()), float(vals.mean()), \
        int(sum(per_obs_total.values()))


def _any_correct_reference(guesses: dict) -> float:
    if not guesses:
        return 0.0
    return float(any(g == s for (_, s), g in guesses.items()))


def sequential_greedy_reference(log, observers, K: int,
                                pooled: bool = False) -> AttackReport:
    slots, snd, rcv, desc = _observations(log, observers, K)
    guesses: dict = {}
    seen: set = set()
    for i in range(len(snd)):
        key = (int(rcv[i]) if not pooled else -1, int(snd[i]))
        if key in seen:
            continue
        seen.add(key)
        guesses[key] = int(desc[i])
    asr, mx, mean, nd = _score_reference(guesses)
    return AttackReport(asr, mx, mean, nd,
                        any_correct_rate=_any_correct_reference(guesses))


def amount_greedy_reference(log, observers, K: int,
                            pooled: bool = False) -> AttackReport:
    slots, snd, rcv, desc = _observations(log, observers, K)
    counts: dict = {}
    first_seen: dict = {}
    for i in range(len(snd)):
        key = (int(rcv[i]) if not pooled else -1, int(snd[i]))
        c = counts.setdefault(key, {})
        d = int(desc[i])
        c[d] = c.get(d, 0) + 1
        first_seen.setdefault((key, d), i)
    guesses = {}
    for key, c in counts.items():
        best = min(c.items(),
                   key=lambda kv: (-kv[1], first_seen[(key, kv[0])]))
        guesses[key] = best[0]
    asr, mx, mean, nd = _score_reference(guesses)
    return AttackReport(asr, mx, mean, nd,
                        any_correct_rate=_any_correct_reference(guesses))


def clustering_reference(log, observers, K: int,
                         pooled: bool = False) -> AttackReport:
    slots, snd, rcv, desc = _observations(log, observers, K)
    guesses: dict = {}
    feats: dict = {}
    for i in range(len(snd)):
        key = (int(rcv[i]) if not pooled else -1, int(snd[i]))
        f = feats.setdefault(key, {})
        d = int(desc[i])
        if d not in f:
            f[d] = [0, i]
        f[d][0] += 1
    n_obs = max(len(snd), 1)
    by_observer: dict = {}
    for (o, s), f in feats.items():
        scored = [(d, c + (1.0 - r / n_obs)) for d, (c, r) in f.items()]
        scored.sort(key=lambda kv: -kv[1])
        by_observer.setdefault(o, []).append((s, scored))
    for o, senders in by_observer.items():
        senders.sort(key=lambda it: -(it[1][0][1] if it[1] else 0.0))
        used: set = set()
        for s, scored in senders:
            pick = None
            for d, sc in scored:
                if d not in used:
                    pick = d
                    break
            if pick is None and scored:
                pick = scored[0][0]
            if pick is not None:
                used.add(pick)
                guesses[(o, s)] = pick
    asr, mx, mean, nd = _score_reference(guesses)
    return AttackReport(asr, mx, mean, nd,
                        any_correct_rate=_any_correct_reference(guesses))


ATTACKS = {
    "sequence": sequential_greedy,
    "count": amount_greedy,
    "cluster": clustering,
}

ATTACKS_REFERENCE = {
    "sequence": sequential_greedy_reference,
    "count": amount_greedy_reference,
    "cluster": clustering_reference,
}


def run_all_attacks(log, observers, K: int, pooled: bool = False):
    return {name: fn(log, observers, K, pooled)
            for name, fn in ATTACKS.items()}


def random_guess_baseline(avg_degree: float) -> float:
    """Neighborhood-level random guessing ~ 1/m (paper §V-D)."""
    return 1.0 / max(avg_degree, 1.0)
