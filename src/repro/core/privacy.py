"""Unlinkability bounds (paper §IV-A/B) and empirical posterior checks.

Implements every closed-form bound in the analysis:

* Eq. (1)  per-transfer cap:          O_u/B_u <= kappa_u / k
* spray mean mu_u and its Chernoff lower tail
* lag lead probability p_lead = (T_lag - 1) / (2 T_lag)
* Eq. (2)  high-probability mixing bound
* Eq. (3)  alliance-filtering bound (collusion, recognition phi)
* Eq. (4)  high-probability collusion bound
* Eq. (5)  repeated-observation union bound

Empirical counterparts read the simulator's transfer log, which records
(B_u, O_u) at every send, so tests can assert the caps transfer-by-
transfer (tests/test_privacy_bounds.py uses hypothesis sweeps).
"""
from __future__ import annotations

import numpy as np

from .trace import TransferTrace


# ----------------------------------------------------------------------
# Closed-form bounds
# ----------------------------------------------------------------------

def per_transfer_cap(kappa: int, k_gate: int) -> float:
    """Eq. (1): posterior cap kappa_u / k for any honest-sender transfer."""
    if k_gate <= 0:
        return 1.0
    return min(1.0, kappa / k_gate)


def spray_mean(sigma: int, n: int) -> float:
    """Near-regular approximation mu_u ~= sigma (paper §IV-A).

    Each of the other sources sprays sigma copies uniformly over its
    ~n-1-m non-neighbors; summing over ~n-1-m eligible sources whose
    non-neighborhood contains u gives mu_u -> sigma as n grows."""
    return float(sigma) if n > 1 else 0.0


def spray_mean_adj(sigma: int, adj: np.ndarray, u: int) -> float:
    """Exact mu_u given the overlay adjacency."""
    n = adj.shape[0]
    mu = 0.0
    for v in range(n):
        if v == u or adj[v, u]:
            continue  # u must be a NON-neighbor of the source v
        denom = n - 1 - int(adj[v].sum())
        if denom > 0:
            mu += sigma / denom
    return mu


def chernoff_lower_tail(mu: float, eps: float) -> float:
    """Pr[Z <= (1-eps) mu] <= exp(-eps^2 mu / 2)  (Poisson-binomial)."""
    if mu <= 0:
        return 1.0
    return float(np.exp(-eps * eps * mu / 2.0))


def lead_probability(t_lag: int) -> float:
    """p_lead = Pr[l_v < l_u] = (T_lag - 1) / (2 T_lag) for iid uniform."""
    if t_lag <= 1:
        return 0.0
    return (t_lag - 1) / (2.0 * t_lag)


def lag_mass_mean(m: float, t_lag: int, q: float) -> float:
    """E[Z_T(u)] >= m * p_lead * q  (availability factor q in (0,1])."""
    return m * lead_probability(t_lag) * q


def high_prob_posterior_bound(
    kappa: int, mu_u: float, m: float, t_lag: int, q: float, eps: float,
) -> tuple[float, float]:
    """Eq. (2): (bound, eta).  With prob >= 1 - eta,
    O_u/B_u <= kappa / (kappa + (1-eps)(mu_u + m (T_lag-1)/(2 T_lag) q))."""
    zt = lag_mass_mean(m, t_lag, q)
    eta = chernoff_lower_tail(mu_u, eps) + chernoff_lower_tail(zt, eps)
    denom = kappa + (1.0 - eps) * (mu_u + zt)
    return kappa / denom if denom > 0 else 1.0, min(eta, 1.0)


def alliance_filter_bound(
    kappa: int, k_gate: int, x_u: float, rho_u: float, phi: float,
) -> float:
    """Eq. (3): theta_u^AF <= min{kappa/k, kappa/(kappa + (1-phi rho) X_u)}."""
    x_eff = (1.0 - phi * rho_u) * x_u
    cap = per_transfer_cap(kappa, k_gate)
    mixed = kappa / (kappa + x_eff) if (kappa + x_eff) > 0 else 1.0
    return min(cap, mixed)


def collusion_high_prob_bound(
    kappa: int, k_gate: int, sigma: int, m: float, t_lag: int, q: float,
    rho_u: float, phi: float, eps: float,
) -> tuple[float, float]:
    """Eq. (4): high-probability version of the alliance-filtered bound."""
    zt = lag_mass_mean(m, t_lag, q)
    eta = chernoff_lower_tail(float(sigma), eps) + chernoff_lower_tail(zt, eps)
    x = (1.0 - phi * rho_u) * (1.0 - eps) * (sigma + zt)
    cap = per_transfer_cap(kappa, k_gate)
    mixed = kappa / (kappa + x) if (kappa + x) > 0 else 1.0
    return min(cap, mixed), min(eta, 1.0)


def repeated_observation_bound(
    s_u: int, kappa: int, k_gate: int, x_u: float, rho_u: float, phi: float,
) -> float:
    """Eq. (5): union bound over s_u observations from the same sender."""
    per = alliance_filter_bound(kappa, k_gate, x_u, rho_u, phi)
    return min(1.0, s_u * per)


def unlinkability_level(kappa: int, k_gate: int) -> float:
    """P >= k / kappa (§II-D / §IV-A)."""
    return k_gate / max(kappa, 1)


# ----------------------------------------------------------------------
# Empirical accounting from a simulated round
# ----------------------------------------------------------------------

def empirical_posteriors(log, warmup_only: bool = True) -> np.ndarray:
    """Per-transfer empirical O_u/B_u for honest-sender transfers.

    ``log`` is a :class:`~repro.core.trace.TransferTrace` (legacy log
    dicts are coerced at the boundary).
    """
    tr = TransferTrace.from_log(log)
    view = tr.warmup() if warmup_only else tr
    b = np.maximum(view.b_size.astype(np.float64), 1.0)
    return view.o_size.astype(np.float64) / b


def check_eq1(log, kappa: int, k_gate: int) -> bool:
    """Every gated warm-up transfer satisfies O_u/B_u <= kappa/k_gate."""
    post = empirical_posteriors(log, warmup_only=True)
    return bool((post <= per_transfer_cap(kappa, k_gate) + 1e-12).all())
