"""SchedulerPolicy plugin API — the scheduling half of the contract.

The paper's scheduler family (§III-C) and its privacy evaluation (§IV-C)
are two halves of one contract: what a sender may legally *do* per slot
and what an adversary may legally *see*.  This module is the doing half.
A :class:`SchedulerPolicy` turns a per-slot :class:`SlotView` into a
batch of transfers; the view encodes exactly what the policy may
observe:

* ``"full"``          — the tracker's centralized modes (§III-C.3-5):
  the complete eligible-supply matrix, per-sender.
* ``"neighborhood"``  — the distributed mode (§III-C.6): only the
  neighborhood-level availability union C^T A(v, s); requests may miss.
* ``"none"``          — flooding (§III-C.7): sender-local eligibility
  only, no receiver state at all.

Accessors on :class:`SlotView` are gated by the policy's declared
visibility; a ``"neighborhood"`` policy calling :meth:`SlotView.supply`
raises :class:`VisibilityError` — new network-layer attack/defense
pairs (UnlinkableDFL-style) plug in without being able to cheat.

Both slot engines (``SwarmConfig.scheduler_impl``: the paper-scale
``"batched"`` engine and the ``"loop"`` reference) sit *behind* this
protocol as interchangeable backends: a policy's :meth:`schedule` is
engine-agnostic, and the six built-in policies are equivalence-locked
byte-for-byte against the historical string dispatch
(``tests/golden_schedules.json``).

Registry: policies self-register under :data:`register_policy`;
``SwarmConfig.scheduler`` accepts a registered name *or* a policy
instance, so a new policy is one class — it works unchanged in
single-round (``simulate_round``), multi-round-churn (``SwarmSession``),
and figure-reproduction paths.

Write your own policy in ~20 lines
----------------------------------
::

    import numpy as np
    from repro.core.policy import SchedulerPolicy, register_policy

    @register_policy
    class EagerMirror(SchedulerPolicy):
        '''Receivers request every missing chunk the neighborhood
        union advertises, from uniformly random neighbors.'''
        name = "eager_mirror"
        visibility = "neighborhood"

        def schedule(self, view):
            cand, union = view.availability_union()
            snd, rcv, chk = [], [], []
            for v in np.flatnonzero(view.receivers_open()):
                ids = np.flatnonzero(union[v])
                if ids.size == 0:
                    continue
                take = ids[:int(view.down[v])]
                nbr = np.flatnonzero(view.adj[v])
                tgt = view.rng.choice(nbr, size=take.size)
                ok = view.resolve_requests(tgt, cand[take])
                snd.append(tgt[ok]); chk.append(cand[take[ok]])
                rcv.append(np.full(int(ok.sum()), v, np.int64))
            if not snd:
                return view.empty()
            return (np.concatenate(snd), np.concatenate(rcv),
                    np.concatenate(chk))

    cfg = SwarmConfig(scheduler="eager_mirror")      # or an instance

(the runnable version lives in ``examples/custom_policy.py``).
"""
from __future__ import annotations

import numpy as np

VISIBILITY_FULL = "full"
VISIBILITY_NEIGHBORHOOD = "neighborhood"
VISIBILITY_NONE = "none"
_LEVELS = {VISIBILITY_NONE: 0, VISIBILITY_NEIGHBORHOOD: 1,
           VISIBILITY_FULL: 2}


class VisibilityError(PermissionError):
    """A policy touched state its declared visibility does not grant."""


def _empty():
    return (np.zeros(0, np.int64), np.zeros(0, np.int64),
            np.zeros(0, np.int64))


class SlotView:
    """What one scheduling policy may see of the swarm this slot.

    Wraps a :class:`~repro.core.state.SwarmState` and exposes it in
    three tiers.  *Ungated* protocol facts (topology, budgets, slot
    clock, activity, the shared rng stream) are visible to everyone —
    the tracker publishes them.  *Scoped* accessors are gated by the
    policy's declared visibility level and raise
    :class:`VisibilityError` when over-reached.  *Mechanics* accessors
    (:meth:`resolve_requests`, :meth:`my_eligible`) model the transfer
    medium / a sender's self-knowledge and are visibility-free: issuing
    a request that may miss is precisely the distributed mode's handicap
    (§III-C.6), not an observation.

    The six built-in backends additionally reach the raw state through
    :meth:`_engine_state` — an audited door for the equivalence-locked
    engine implementations (they are trusted to *use* only what their
    policy's visibility grants; the lock is the byte-identity test
    against the historical dispatch).  Plugin policies should use the
    scoped accessors instead.
    """

    def __init__(self, state, visibility: str = VISIBILITY_FULL):
        if visibility not in _LEVELS:
            raise ValueError(f"unknown visibility {visibility!r}")
        self._state = state
        self.visibility = visibility

    # -- ungated protocol facts ---------------------------------------
    @property
    def cfg(self):
        return self._state.cfg

    @property
    def rng(self) -> np.random.Generator:
        return self._state.rng

    @property
    def n(self) -> int:
        return self._state.cfg.n

    @property
    def slot(self) -> int:
        return self._state.slot

    @property
    def phase(self) -> str:
        return self._state.phase

    @property
    def adj(self) -> np.ndarray:
        return self._state.adj

    @property
    def active(self) -> np.ndarray:
        return self._state.active

    @property
    def up(self) -> np.ndarray:
        return self._state.up

    @property
    def down(self) -> np.ndarray:
        return self._state.down

    @property
    def hold(self) -> np.ndarray:
        """Per-client chunk counts (tracker-published progress)."""
        return self._state.hold

    def senders_active(self) -> np.ndarray:
        return self._state.senders_active()

    def receivers_open(self) -> np.ndarray:
        """Clients still requesting this slot: active, downlink left,
        and (during warm-up) below the k_term cover threshold."""
        st = self._state
        ok = st.active & (st.down > 0)
        if st.phase != "bt":
            ok = ok & (st.hold < st.cfg.k_term)
        return ok

    @staticmethod
    def empty():
        """The canonical empty transfer batch."""
        return _empty()

    # -- gating --------------------------------------------------------
    def _require(self, level: str, what: str):
        if _LEVELS[self.visibility] < _LEVELS[level]:
            raise VisibilityError(
                f"{what} requires visibility >= {level!r}; this policy "
                f"declared {self.visibility!r}")

    # -- full (centralized tracker view) -------------------------------
    def _engine_state(self):
        """Audited backend door: raw state for the built-in engines."""
        return self._state

    @property
    def state(self):
        """Raw swarm state — centralized (``"full"``) policies only."""
        self._require(VISIBILITY_FULL, "SlotView.state")
        return self._state

    def candidate_columns(self) -> np.ndarray:
        """Chunk ids any active sender could serve this slot."""
        self._require(VISIBILITY_FULL, "candidate_columns()")
        return self._state.candidate_columns(self._state.senders_active())

    def supply(self, cand: np.ndarray | None = None):
        """(cand, (n, len(cand)) bool): the full eligible-supply matrix
        — who can serve which candidate chunk, gating applied."""
        self._require(VISIBILITY_FULL, "supply()")
        st = self._state
        if cand is None:
            cand = st.candidate_columns(st.senders_active())
        return cand, st.eligible_supply(cand)

    # -- neighborhood (distributed announcements, §III-C.6) -------------
    def availability_union(self):
        """(cand, (n, m) bool): per-receiver neighborhood availability
        union C^T A(v, s) over *missing* chunks — the tracker never
        reveals which neighbor holds what."""
        self._require(VISIBILITY_NEIGHBORHOOD, "availability_union()")
        st = self._state
        cand = st.candidate_columns(st.senders_active())
        if cand.size == 0:
            return cand, np.zeros((self.n, 0), dtype=bool)
        sup = st.eligible_supply(cand)
        union = np.zeros((self.n, cand.size), dtype=bool)
        for u in range(self.n):
            row = sup[u]
            if row.any():
                union[st.adj[u]] |= row[None, :]
        union &= ~st.have[:, cand]
        return cand, union

    # -- mechanics (visibility-free) ------------------------------------
    def my_eligible(self, u: int) -> np.ndarray:
        """Sender u's own eligible buffer (self-knowledge)."""
        return self._state.eligible_row(int(u))

    def resolve_requests(self, senders: np.ndarray,
                         chunks: np.ndarray) -> np.ndarray:
        """Did each (sender, chunk) request land on a holder that may
        serve it?  Models the transfer medium: the requester learns the
        outcome, never the sender's inventory."""
        senders = np.asarray(senders, np.int64)
        chunks = np.asarray(chunks, np.int64)
        if senders.size == 0:
            return np.zeros(0, dtype=bool)
        ucand, cinv = np.unique(chunks, return_inverse=True)
        sup = self._state.eligible_supply(ucand)
        return sup[senders, cinv]


# ----------------------------------------------------------------------
# The policy protocol
# ----------------------------------------------------------------------

class SchedulerPolicy:
    """One slot-scheduling strategy (§III-C) as a pluggable class.

    Subclasses declare:

    * ``name``        — registry key (``SwarmConfig.scheduler`` string);
    * ``visibility``  — the :class:`SlotView` tier the policy's
      decisions may read (enforced by the view's scoped accessors);
    * ``phases``      — protocol phases the policy may drive
      (``"warmup"`` and/or ``"bt"``); the simulator refuses a policy
      outside its phase applicability;

    and implement :meth:`schedule`.  :meth:`reset` is called once per
    round before the first slot; per-round mutable state (e.g. the
    flooding pair memory) belongs to the instance and is re-created
    there — no caller-threaded dicts.
    """

    name: str = ""
    visibility: str = VISIBILITY_FULL
    phases: tuple = ("warmup",)

    # -- lifecycle -----------------------------------------------------
    def reset(self, cfg) -> None:
        """Per-round state reset (called before slot 0)."""

    def applies_to(self, phase: str) -> bool:
        return phase in self.phases

    # -- the contract ----------------------------------------------------
    def schedule(self, view: SlotView):
        """Return ``(senders, receivers, chunks)`` int64 arrays for this
        slot.  Budgets (uplink/downlink/tau) are the policy's duty; the
        state layer additionally enforces delivery-exactly-once."""
        raise NotImplementedError

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"{type(self).__name__}(name={self.name!r}, "
                f"visibility={self.visibility!r}, phases={self.phases})")


# ----------------------------------------------------------------------
# Registry
# ----------------------------------------------------------------------

_REGISTRY: dict[str, type] = {}


def register_policy(cls: type) -> type:
    """Class decorator: make ``cls`` resolvable by its ``name``."""
    if not issubclass(cls, SchedulerPolicy):
        raise TypeError(f"{cls!r} is not a SchedulerPolicy")
    if not cls.name:
        raise ValueError(f"{cls.__name__} must set a non-empty .name")
    _REGISTRY[cls.name] = cls
    return cls


def policy_names() -> tuple:
    return tuple(sorted(_REGISTRY))


def get_policy(spec) -> SchedulerPolicy:
    """Resolve ``SwarmConfig.scheduler`` to a policy instance.

    ``spec`` may be a registered name (fresh instance per call), a
    policy class, or an instance (returned as-is — the caller owns its
    lifecycle; the simulator resets it at every round start).
    """
    if isinstance(spec, SchedulerPolicy):
        return spec
    if isinstance(spec, type) and issubclass(spec, SchedulerPolicy):
        return spec()
    if isinstance(spec, str) and spec in _REGISTRY:
        return _REGISTRY[spec]()
    raise ValueError(
        f"unknown scheduler {spec!r}; registered: {policy_names()}")
