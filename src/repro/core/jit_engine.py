"""Jitted slot engine: fixed-shape budgeted-round matching in JAX.

The third interchangeable slot engine (``SwarmConfig.scheduler_impl=
"jit"``) runs the inner budgeted-round matching of the batched engine —
feasible-sender selection, GFF loser-retry, grouped-cumsum uplink
splits, tau concurrency gating, non-owner-first two-tier grants and
rarest-first prefix extraction — as ONE ``lax.while_loop`` over packed
uint32 bitplanes, with masked convergence flags in place of the batched
engine's ``if array.any()`` branches and ``while True`` retry loop.

Contract (docs/INVARIANTS.md "jit-engine contract"):

* **fixed shapes** — candidate columns pad to a power-of-two count and
  pack into ``W = m_pad/32`` uint32 words; per-grant batches extract
  into a ``t_cap``-wide buffer and rounds run under a static ``r_max``
  bound.  Pad bits are zero in both the supply and the need planes, so
  padding can never add a transfer; ``r_max``/``t_cap`` are sized from
  the slot budgets so they never truncate a legal grant sequence.
* **masked convergence** — every round updates all receivers under
  boolean masks; the loop exits early through its carry flag the first
  round that finds no feasible (receiver, sender) pair.
* **schedule legality is engine-independent** — uplink/downlink
  budgets, tau concurrency, adjacency, duplicate-freedom and the Eq. 1
  eligibility gate (the same owner-window maths as
  :meth:`SwarmState.eligible_supply`, staged on device) hold exactly as
  in the loop and batched engines; the three engines are
  aggregate-equivalent, not byte-identical (each consumes randomness
  differently).

Scaling: the swarm-wide inventory lives on device as a packed
``(n, ceil(nK/32))`` uint32 plane, synced incrementally from the
transfer log by a buffer-donating scatter (delivery-exactly-once makes
bitwise-or and add interchangeable), so a slot never re-reads the
O(n * nK) boolean ``have`` matrix.  Per-slot host work is limited to
candidate selection, two O(m) gating vectors and decoding the kernel's
fixed-shape grant grids back into (sender, receiver, chunk) triples.

Randomness: exactly two host draws per slot — the rarest-first
tie-break and one 31-bit seed that keys the kernel's own hash-derived
noise streams — so a fixed ``SwarmConfig.seed`` replays the same
schedule byte for byte (tests/test_scheduler_equivalence.py).
"""
from __future__ import annotations

import functools

import numpy as np

from .state import SwarmState

try:                                    # CPU jax is a hard dependency of
    import jax                          # the dist/ stack, but the slot
    import jax.numpy as jnp             # engines degrade gracefully so
    from jax import lax                 # core/ stays importable without it
    _HAS_JAX = True
except Exception:                       # pragma: no cover - env-specific
    _HAS_JAX = False

_MODE_IDS = {"random_fifo": 0, "random_fastest_first": 1,
             "greedy_fastest_first": 2}
_GFF_RETRIES = 3          # loser re-picks per round, as the batched engine
_BIG = 1 << 30            # "unbounded" batch cap for the BT phase


# Host-observed wall seconds per engine phase, accumulated across slots
# (benchmarks/bench_scheduler.py breakdown; jax dispatch is async, so
# "matching" includes the blocking device->host fetch of the grids).
# The measurement clock is injected by the benchmarks (set_clock with
# time.perf_counter); simulated time never reads the host clock, so by
# default the accumulators stay zero (RNG007).
PHASE_S = {"bitplane_s": 0.0, "matching_s": 0.0, "extraction_s": 0.0}


def _zero_clock() -> float:
    return 0.0


_clock = _zero_clock


def set_clock(fn) -> None:
    """Install a wall-clock source for the PHASE_S accumulators (pass
    ``None`` to restore the zero clock).  Benchmark-only."""
    global _clock
    _clock = fn if fn is not None else _zero_clock


def reset_phase_timers() -> dict:
    """Zero the accumulators, returning the values they held."""
    held = dict(PHASE_S)
    for k in PHASE_S:
        PHASE_S[k] = 0.0
    return held


def _empty():
    return (np.zeros(0, np.int64), np.zeros(0, np.int64),
            np.zeros(0, np.int64))


def _pow2(x: int) -> int:
    """Smallest power of two >= x (>= 1): pads every data-dependent
    extent to a small set of static shapes so jit recompiles O(log)
    times per run instead of once per slot."""
    return 1 << max(int(x) - 1, 0).bit_length()


def _pack_words(bits: np.ndarray, w: int) -> np.ndarray:
    """(n, m) bool -> (n, w) uint32, bit ``c & 31`` of word ``c >> 5``
    is column ``c`` (little-endian bit order; pad bits stay zero)."""
    p = np.packbits(bits, axis=1, bitorder="little")
    buf = np.zeros((bits.shape[0], w * 4), dtype=np.uint8)
    buf[:, :p.shape[1]] = p
    words = buf.view(np.uint32)
    if not np.little_endian:            # pragma: no cover - x86/arm are LE
        words = words.byteswap()
    return words


def _neighbor_lists(state: SwarmState) -> np.ndarray:
    """Padded (n, d_pad) neighbor lists (-1 pad) for the round's static
    overlay, device-cached so every slot reuses one upload."""
    cached = getattr(state, "_jit_nbr_cache", None)
    if cached is not None:
        return cached
    adj = state.adj
    n = adj.shape[0]
    deg = adj.sum(axis=1)
    d_pad = _pow2(max(int(deg.max(initial=1)), 1))
    nbr = np.full((n, d_pad), -1, dtype=np.int32)
    rows, cols = np.nonzero(adj)
    first = np.searchsorted(rows, np.arange(n))
    nbr[rows, np.arange(rows.size) - first[rows]] = cols
    dev = jnp.asarray(nbr)
    state._jit_nbr_cache = dev
    return dev


if _HAS_JAX:
    @functools.partial(jax.jit, donate_argnums=(0,))
    def _scatter_bits(words, rows, wcol, vals):
        # Delivery-exactly-once (state.apply_transfers de-dups against
        # ``have``) keeps every (row, chunk) bit unique for the whole
        # round, so add == bitwise-or; pad entries carry vals == 0.
        return words.at[rows, wcol].add(vals)


def _log_scatter(state: SwarmState, pos: int, nb: int):
    """Scatter operands (rows, word column, bit value) for transfer-log
    batches ``[pos:nb)``, padded to a power of two with zero values."""
    if pos < nb:
        rcv = np.concatenate(state.log.receivers[pos:nb])
        chk = np.concatenate(state.log.chunks[pos:nb])
    else:
        rcv = np.zeros(0, np.int32)
        chk = np.zeros(0, np.int64)
    pad = _pow2(rcv.size)
    rows = np.zeros(pad, dtype=np.int32)
    wcol = np.zeros(pad, dtype=np.int32)
    vals = np.zeros(pad, dtype=np.uint32)
    rows[:rcv.size] = rcv
    wcol[:rcv.size] = chk >> 5
    vals[:rcv.size] = np.left_shift(
        np.uint32(1), (chk & 31).astype(np.uint32))
    return jnp.asarray(rows), jnp.asarray(wcol), jnp.asarray(vals)


def _diag_words(state: SwarmState, w_full: int) -> np.ndarray:
    """Packed owner-diagonal inventory (client v holds exactly chunks
    [vK, vK+K)) — the analytic post-construction state, built directly
    in the bit domain."""
    n = state.cfg.n
    K = state.cfg.chunks_per_update
    v = np.arange(n, dtype=np.int64)
    lo = (v * K)[:, None]
    wj = lo // 32 + np.arange(K // 32 + 2)[None, :]
    s = np.clip(lo - 32 * wj, 0, 32).astype(np.uint64)
    e = np.clip(lo + K - 32 * wj, 0, 32).astype(np.uint64)
    mask = (((np.uint64(1) << e) - 1)
            ^ ((np.uint64(1) << s) - 1)).astype(np.uint32)
    words = np.zeros((n, w_full), dtype=np.uint32)
    np.bitwise_or.at(
        words,
        (np.broadcast_to(v[:, None], wj.shape),
         np.minimum(wj, w_full - 1)),
        np.where(wj < w_full, mask, np.uint32(0)))
    return words


def _sync_have_dev(state: SwarmState):
    """Device copy of the packed swarm inventory, synced incrementally.

    The transfer log is the single write path for ``state.have`` after
    construction, so replaying batches appended since the last call
    reproduces the matrix bit for bit.  A swapped ``have`` identity
    (Byzantine claimed inventories) falls back to a full repack.
    """
    nb = len(state.log.receivers)
    cache = getattr(state, "_jit_have_cache", None)
    if cache is not None and cache[0] is state.have:
        dev, pos = cache[1], cache[2]
        if pos < nb:
            dev = _scatter_bits(dev, *_log_scatter(state, pos, nb))
        state._jit_have_cache = (state.have, dev, nb)
        return dev
    w_full = -(-state.have.shape[1] // 32)
    if cache is None and state.have is getattr(
            state, "_have_pristine", None):
        # First build of the genuine inventory: the owner diagonal is
        # analytic and the log already records every later delivery, so
        # packing in the bit domain skips an np.packbits pass over the
        # multi-GB bool matrix.
        dev = _scatter_bits(jnp.asarray(_diag_words(state, w_full)),
                            *_log_scatter(state, 0, nb))
        state._jit_have_cache = (state.have, dev, nb)
        return dev
    dev = jnp.asarray(_pack_words(state.have, w_full))
    state._jit_have_cache = (state.have, dev, nb)
    return dev


# ----------------------------------------------------------------------
# Kernel-side helpers (jit-slated: JIT_TARGETS tracks them)
# ----------------------------------------------------------------------

def _mix32(x):
    """32-bit finalizer hash: one fresh tie-break lattice per round and
    retry from a single per-slot seed, without a per-round PRNG walk."""
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def _kth_set_bit(word, k):
    """Bit index of the ``k``-th (0-based) set bit of each uint32 word.

    Five-level binary descent over word halves (16/8/4/2/1) — no loop
    over bit positions, undefined when ``k >= popcount(word)`` (callers
    mask those lanes).
    """
    w = word
    kk = k.astype(jnp.int32)
    bit = jnp.zeros_like(kk)
    for half in (16, 8, 4, 2, 1):
        lo = w & jnp.uint32((1 << half) - 1)
        c = lax.population_count(lo).astype(jnp.int32)
        hi = kk >= c
        kk = kk - jnp.where(hi, c, 0)
        bit = bit + jnp.where(hi, half, 0)
        w = jnp.where(hi, w >> half, lo)
    return bit


def _rank_counts(rows):
    """Per-superblock inclusive popcount cumsum of a packed plane.

    One fused pass over the (n, W) plane; the (n, S) cumsum (S = 16
    superblocks, or 1 when W is not divisible) is everything
    :func:`_extract_ranked` needs to locate ranks without touching the
    plane again, and its last column is each row's total popcount.
    """
    n, W = rows.shape
    S = 16 if W % 16 == 0 else 1           # superblocks per row
    B = W // S                             # words per superblock
    sb = jnp.sum(lax.population_count(rows.reshape(n, S, B)), axis=2,
                 dtype=jnp.int32)
    return jnp.cumsum(sb, axis=1)          # (n, S) inclusive


def _extract_ranked(rows, sb_cum, want, t_cap: int):
    """First ``want[i]`` set bits of each packed row, rarest first.

    ``sb_cum`` is the plane's :func:`_rank_counts`.  Returns ``(sel,
    cols)``: the selected bits as a plane of the same shape and the
    (n, t_cap) word*32+bit column ids (-1 past the batch).
    Hierarchical rank search, the staged form of the batched engine's
    block/byte/bit prefix extraction: rank k's superblock falls out of
    the tiny cumsum, only that superblock's words are gathered, and a
    binary descent (:func:`_kth_set_bit`) finds the bit — no further
    full-plane pass.
    """
    n, W = rows.shape
    S = sb_cum.shape[1]
    B = W // S
    ridx = jnp.arange(n)
    total = sb_cum[:, -1]
    ks = jnp.arange(t_cap, dtype=jnp.int32)
    # superblock holding rank k: first s with sb_cum[s] > k
    sbk = jnp.sum((sb_cum[:, None, :] <= ks[None, :, None]).astype(
        jnp.int32), axis=2)
    sbk = jnp.minimum(sbk, S - 1)
    prev_sb = jnp.where(
        sbk > 0,
        jnp.take_along_axis(sb_cum, jnp.maximum(sbk - 1, 0), axis=1), 0)
    k_in = ks[None, :] - prev_sb           # rank within superblock
    widx = sbk[:, :, None] * B + jnp.arange(B)[None, None, :]
    words = rows[ridx[:, None, None], widx]          # (n, t_cap, B)
    wcum = jnp.cumsum(lax.population_count(words).astype(jnp.int32),
                      axis=2)
    wk_in = jnp.sum((wcum <= k_in[:, :, None]).astype(jnp.int32),
                    axis=2)
    wk_in = jnp.minimum(wk_in, B - 1)
    prev_w = jnp.where(
        wk_in > 0,
        jnp.take_along_axis(
            wcum, jnp.maximum(wk_in - 1, 0)[..., None],
            axis=2)[..., 0], 0)
    word = jnp.take_along_axis(words, wk_in[..., None], axis=2)[..., 0]
    bit = _kth_set_bit(word, k_in - prev_w)
    wk = sbk * B + wk_in
    valid = (ks[None, :] < want[:, None]) & (ks[None, :] < total[:, None])
    cols = jnp.where(valid, wk * 32 + bit, -1)
    sel = jnp.zeros_like(rows).at[ridx[:, None], wk].add(
        jnp.where(valid,
                  jnp.left_shift(jnp.uint32(1), bit.astype(jnp.uint32)),
                  jnp.uint32(0)))
    return sel, cols


def _first_bits(rows, want, t_cap: int):
    """:func:`_extract_ranked` with the rank pass folded in (tests and
    one-shot callers)."""
    return _extract_ranked(rows, _rank_counts(rows), want, t_cap)


def _slot_rounds(mode_id: int, nonowner: bool, ungated: bool,
                 t_cap: int, r_max: int, have_dev, cand, owner_row,
                 own_allowed, m_cnt, recv_ok, nbr, rem_up, rem_down,
                 batch_cap, tau, seed):
    """One slot — plane build plus budgeted-round matching, fully staged.

    Stage 1 gathers the candidate columns out of the device-resident
    packed inventory, repacks them in rarest-first bit order and applies
    the owner-window gate (the :meth:`SwarmState.eligible_supply`
    single-owner-cell fix-up) on device.  Stage 2 is the
    ``lax.while_loop`` over grant rounds: it carries the need planes,
    the remaining uplink/downlink and tau budgets, the serving and
    tombstone pair masks and the fixed-shape output grids; every round
    is fully masked so the trace stays shape-stable.  Returns
    ``(out_snd, out_col)``: per (round, receiver) the granted sender
    (-1 none) and its rarest-first column batch (-1 pad), non-owner
    tier first within each grant.
    """
    n = have_dev.shape[0]
    m_pad = cand.shape[0]
    w_words = m_pad // 32
    shifts = jnp.arange(32, dtype=jnp.uint32)
    cidx = jnp.arange(m_pad)
    valid = cidx < m_cnt
    col_w = (cidx >> 5).astype(jnp.int32)
    col_b = (cidx & 31).astype(jnp.uint32)
    col_bit = jnp.where(valid, jnp.left_shift(jnp.uint32(1), col_b),
                        jnp.uint32(0))

    # ---- stage 1: candidate planes from the packed inventory ----
    bits = ((have_dev[:, (cand >> 5).astype(jnp.int32)]
             >> (cand & 31).astype(jnp.uint32)[None, :]) & jnp.uint32(1))
    bits = jnp.where(valid[None, :], bits, jnp.uint32(0))
    hv_w = jnp.sum(bits.reshape(n, w_words, 32) << shifts, axis=2,
                   dtype=jnp.uint32)
    valid_w = jnp.sum(valid.reshape(w_words, 32).astype(jnp.uint32)
                      << shifts, axis=1, dtype=jnp.uint32)
    if nonowner or not ungated:
        own_w = jnp.zeros((n, w_words), dtype=jnp.uint32).at[
            owner_row, col_w].add(col_bit)
    else:
        own_w = None
    if ungated:
        sup_w = hv_w
    else:
        # eligible_supply's owner fix-up: each column has exactly one
        # owner cell — clear it, then restore iff the window is open
        # and the owner actually holds the chunk.
        have_own = (hv_w[owner_row, col_w] >> col_b) & jnp.uint32(1)
        set_bit = jnp.where(own_allowed & (have_own > 0), col_bit,
                            jnp.uint32(0))
        own_set = jnp.zeros((n, w_words), dtype=jnp.uint32).at[
            owner_row, col_w].add(set_bit)
        sup_w = (hv_w & ~own_w) | own_set
    if nonowner:
        # Tier planes once per slot: the round body then pays one
        # gather per tier instead of re-deriving them from own_w.
        sup_no_w = sup_w & ~own_w
        sup_ow_w = sup_w & own_w
    need_w0 = jnp.where(recv_ok[:, None], ~hv_w & valid_w[None, :],
                        jnp.uint32(0))
    sup_any = jnp.sum(lax.population_count(sup_w), axis=1) > 0
    nbrc = jnp.maximum(nbr, 0)
    valid_nbr = nbr >= 0
    live0 = valid_nbr & sup_any[nbrc]
    need_cnt0 = jnp.sum(lax.population_count(need_w0), axis=1,
                        dtype=jnp.int32)

    vidx = jnp.arange(n)
    key = jax.random.PRNGKey(seed)
    k_noise, k_tie, k_prio = jax.random.split(key, 3)
    noise_base = jax.random.bits(k_noise, nbr.shape, dtype=jnp.uint32)
    tie_base = jax.random.bits(k_tie, (n,), dtype=jnp.uint32)
    prio_base = jax.random.bits(k_prio, (n,), dtype=jnp.uint32)
    u01 = jnp.float32(2.0 ** -32)

    # ---- stage 2: budgeted grant rounds ----
    def cond(carry):
        return (carry[0] < r_max) & ~carry[1]

    def body(carry):
        (r, _stop, need_w, need_cnt, rem_up, rem_down, recv_slots,
         serving, live, out_snd, out_col) = carry
        ru = r.astype(jnp.uint32)

        needy = (rem_down > 0) & (need_cnt > 0)
        feas = (live & valid_nbr & needy[:, None]
                & (rem_up[nbrc] > 0)
                & ((recv_slots[nbrc] > 0) | serving))
        noise = _mix32(noise_base ^ (ru * jnp.uint32(0x9E3779B9))
                       ).astype(jnp.float32) * u01
        if mode_id == 2:                 # GFF: fastest remaining uplink
            score = rem_up[nbrc].astype(jnp.float32) + noise
        else:
            score = noise
        score = jnp.where(feas, score, -jnp.inf)

        if mode_id == 2:
            # One receiver per sender; losers re-pick among untaken
            # senders (the batched engine's masked retry loop).
            d_sel = jnp.argmax(score, axis=1).astype(jnp.int32)
            act = jnp.take_along_axis(feas, d_sel[:, None], 1)[:, 0]
            pair = jnp.zeros(n, dtype=bool)
            d_v = jnp.zeros(n, dtype=jnp.int32)
            taken = jnp.zeros(n, dtype=jnp.int32)
            for it in range(_GFF_RETRIES):
                salt = jnp.uint32((it * 0xC2B2AE35) & 0xFFFFFFFF)
                tie = _mix32(tie_base ^ (ru * jnp.uint32(0x85EBCA6B)
                                         + salt)
                             ).astype(jnp.float32) * u01
                tie = jnp.where(act, tie, -1.0)
                u_sel = nbrc[vidx, d_sel]
                wkey = jnp.full(n, -2.0).at[u_sel].max(tie)
                win = act & (tie >= 0.0) & (tie == wkey[u_sel])
                pair = pair | win
                d_v = jnp.where(win, d_sel, d_v)
                taken = taken.at[u_sel].max(win.astype(jnp.int32))
                score = jnp.where(taken[nbrc] > 0, -jnp.inf, score)
                act = act & ~win
                d_sel = jnp.argmax(score, axis=1).astype(jnp.int32)
                best = jnp.take_along_axis(score, d_sel[:, None], 1)[:, 0]
                act = act & jnp.isfinite(best)
        else:
            # Sender multi-serve: every receiver keeps its chosen
            # sender; the grouped split below divides each uplink.
            d_v = jnp.argmax(score, axis=1).astype(jnp.int32)
            best = jnp.take_along_axis(score, d_v[:, None], 1)[:, 0]
            pair = jnp.isfinite(best)

        u_v = jnp.where(pair, nbrc[vidx, d_v], n)     # n = no pair
        u_c = jnp.minimum(u_v, n - 1)
        # Unpaired rows gather garbage (clamped sender n-1); every
        # consumer below is masked on pair/take, so no plane-wide
        # where() is spent zeroing them.
        if nonowner:
            rows_no = sup_no_w[u_c] & need_w
            sbc_no = _rank_counts(rows_no)
            cnt_no = jnp.where(pair, sbc_no[:, -1], 0)
            # owner-tier overlap: fused gather+and+popcount reduction,
            # the plane itself only materializes under the lax.cond
            cnt_ow = jnp.where(pair, jnp.sum(
                lax.population_count(sup_ow_w[u_c] & need_w), axis=1,
                dtype=jnp.int32), 0)
            cnt = cnt_no + cnt_ow
        else:
            rows = sup_w[u_c] & need_w
            sbc = _rank_counts(rows)
            cnt = jnp.where(pair, sbc[:, -1], 0)
        dead = pair & (cnt == 0)                      # tombstone
        live = live.at[vidx, d_v].set(live[vidx, d_v] & ~dead)

        req = jnp.minimum(jnp.minimum(rem_down, cnt), batch_cap)
        req = jnp.where(pair, req, 0)
        # Mode-priority order within each sender group: fastest
        # downlink first for RFF, random arrival otherwise.
        pn = _mix32(prio_base ^ (ru * jnp.uint32(0x27D4EB2F))
                    ).astype(jnp.float32) * u01
        if mode_id == 1:
            recv_prio = -(rem_down.astype(jnp.float32) + pn)
        else:
            recv_prio = pn
        order = jnp.lexsort((recv_prio, u_v))
        us = u_v[order]
        us_c = jnp.minimum(us, n - 1)
        reqs = req[order]
        is_new = pair & ~serving[vidx, d_v]
        isn = is_new[order]
        first = jnp.searchsorted(us, us)
        # tau gate: only the first recv_slots[u] NEW pairs of each
        # sender group may open a serve slot this round.
        cn = jnp.cumsum(isn)
        excl_new = cn - isn
        new_rank = excl_new - excl_new[first]
        reqs = jnp.where((us < n) & (~isn | (new_rank < recv_slots[us_c])),
                         reqs, 0)
        # uplink split: grouped exclusive cumsum of requests caps each
        # pair at what its sender has left after earlier pairs.
        cq = jnp.cumsum(reqs)
        excl = cq - reqs
        take_s = jnp.minimum(reqs, jnp.maximum(
            rem_up[us_c] - (excl - excl[first]), 0))
        take = jnp.zeros(n, dtype=jnp.int32).at[order].set(
            take_s.astype(jnp.int32))
        granted = take > 0

        if nonowner:
            # Non-owner-first WITHIN each grant: fill from the
            # non-owner overlap, owner chunks only for the remainder.
            t_no = jnp.minimum(take, cnt_no)
            t_ow = take - t_no
            sel_no, cols_no = _extract_ranked(rows_no, sbc_no, t_no,
                                              t_cap)

            def owner_tier(_):
                return _first_bits(sup_ow_w[u_c] & need_w, t_ow, t_cap)

            def no_owner_tier(_):
                return (jnp.zeros_like(need_w),
                        jnp.full((n, t_cap), -1, dtype=jnp.int32))

            sel_ow, cols_ow = lax.cond(jnp.any(t_ow > 0), owner_tier,
                                       no_owner_tier, None)
            sel = sel_no | sel_ow
            ks = jnp.arange(t_cap)[None, :]
            shift = jnp.clip(ks - t_no[:, None], 0, t_cap - 1)
            cols = jnp.where(ks < t_no[:, None], cols_no,
                             jnp.take_along_axis(cols_ow, shift, axis=1))
            cols = jnp.where(ks < take[:, None], cols, -1)
        else:
            sel, cols = _extract_ranked(rows, sbc, take, t_cap)

        need_w = need_w & ~sel
        need_cnt = need_cnt - take
        rem_down = rem_down - take
        rem_up = rem_up.at[u_c].add(jnp.where(granted, -take, 0))
        fresh = granted & is_new
        serving = serving.at[vidx, d_v].set(serving[vidx, d_v] | fresh)
        recv_slots = recv_slots.at[u_c].add(-fresh.astype(jnp.int32))
        out_snd = out_snd.at[r].set(
            jnp.where(granted, u_v.astype(jnp.int32), jnp.int32(-1)))
        out_col = out_col.at[r].set(cols)
        stop = ~jnp.any(pair)
        return (r + 1, stop, need_w, need_cnt, rem_up, rem_down,
                recv_slots, serving, live, out_snd, out_col)

    init = (jnp.int32(0), jnp.bool_(False), need_w0, need_cnt0,
            rem_up, rem_down, jnp.full((n,), tau, dtype=jnp.int32),
            jnp.zeros_like(live0), live0,
            jnp.full((r_max, n), -1, dtype=jnp.int32),
            jnp.full((r_max, n, t_cap), -1, dtype=jnp.int32))
    out = lax.while_loop(cond, body, init)
    return out[-2], out[-1]


@functools.lru_cache(maxsize=64)
def _compiled(mode_id: int, nonowner: bool, ungated: bool, t_cap: int,
              r_max: int):
    return jax.jit(functools.partial(_slot_rounds, mode_id, nonowner,
                                     ungated, t_cap, r_max))


# ----------------------------------------------------------------------
# Host boundary: candidate prep, kernel dispatch, grant-grid decode
# ----------------------------------------------------------------------

def schedule_centralized_jit(state: SwarmState, mode: str):
    """One slot of the centralized family on the jitted engine."""
    if not _HAS_JAX:                     # pragma: no cover - env-specific
        from .schedulers import _schedule_centralized_batched
        return _schedule_centralized_batched(state, mode)
    cfg = state.cfg
    rng = state.rng
    n = cfg.n

    sactive = state.senders_active()
    rem_up = np.where(sactive, state.up, 0).astype(np.int32)
    rem_down = np.where(state.active, state.down, 0).astype(np.int32)

    cand = state.candidate_columns(sactive)
    if cand.size == 0:
        return _empty()
    # Same rarest-first priority draw as the batched engine, then one
    # seed draw for the kernel streams: two draws per slot, always in
    # this order (rng discipline: the twin tests replay on it).
    prio = state.replicas[cand].astype(np.float32)
    prio += rng.random(cand.size, dtype=np.float32)
    cand = cand[np.argsort(prio)]
    seed = int(rng.integers(0, 2 ** 31 - 1))
    m = cand.size

    max_up = int(rem_up.max(initial=0))
    max_down = int(rem_down.max(initial=0))
    if max_up == 0 or max_down == 0:
        return _empty()
    warm = state.phase != "bt"
    recv_ok = state.active & (rem_down > 0)
    if warm:
        recv_ok = recv_ok & (state.hold < cfg.k_term)
    if not recv_ok.any():
        return _empty()

    # Static-shape buckets: the candidate count pads to a power of two
    # (floored near the universe size so small swarms compile once).
    universe = state.have.shape[1]
    m_pad = max(_pow2(max(m, min(universe, 512))), 32)
    cand_p = np.zeros(m_pad, dtype=np.int32)
    cand_p[:m] = cand
    owner_p = np.zeros(m_pad, dtype=np.int32)
    owner_p[:m] = state.owners[cand]
    ungated = (not warm) or (not cfg.enable_gating)
    allowed_p = np.zeros(m_pad, dtype=bool)
    if not ungated:
        K = cfg.chunks_per_update
        kappa = cfg.owner_throttle
        _, starts, gated = state.owner_windows()
        co = state.owners[cand]
        off = cand - co * K
        allowed_p[:m] = (((off - starts[co]) % K) < kappa) & ~gated[co]
    nonowner_pass = bool(cfg.enable_nonowner_first) and warm

    # Warm-up grants carry the batched engine's fan-in cap (§IV-C: the
    # attack surface depends on receivers fanning in from ~all feasible
    # neighbors); BT batches stay budget-bound.
    batch_cap = max(max_up // 4, 1) if warm else _BIG
    t_cap = _pow2(min(batch_cap, max_down, max_up))
    r_max = min(_pow2(-(-max_down // min(batch_cap, max_down)) + 8), 64)

    _t0 = _clock()
    have_dev = _sync_have_dev(state)
    nbr_dev = _neighbor_lists(state)
    _t1 = _clock()
    kernel = _compiled(_MODE_IDS[mode], nonowner_pass, ungated, t_cap,
                       r_max)
    out_snd, out_col = kernel(
        have_dev, jnp.asarray(cand_p), jnp.asarray(owner_p),
        jnp.asarray(allowed_p), jnp.int32(m), jnp.asarray(recv_ok),
        nbr_dev, jnp.asarray(rem_up), jnp.asarray(rem_down),
        jnp.int32(min(batch_cap, _BIG)), jnp.int32(cfg.tau_concurrent),
        seed)
    out_snd = np.asarray(out_snd)
    out_col = np.asarray(out_col)
    _t2 = _clock()

    # Decode the grant grids in (round, receiver, pick) order: within a
    # grant picks are rarity-ordered with the non-owner tier first.
    r_i, v_i, k_i = np.nonzero(out_col >= 0)
    if r_i.size == 0:
        snd, rcv, chk = _empty()
    else:
        snd = out_snd[r_i, v_i].astype(np.int64)
        rcv = v_i.astype(np.int64)
        chk = cand[out_col[r_i, v_i, k_i]]
    _t3 = _clock()
    PHASE_S["bitplane_s"] += _t1 - _t0
    PHASE_S["matching_s"] += _t2 - _t1
    PHASE_S["extraction_s"] += _t3 - _t2
    return snd, rcv, chk
