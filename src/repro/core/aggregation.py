"""FedAvg over the reconstructable active set (paper §II-B).

    g_v^agg,r = sum_{u in A_v^r} w_u / (sum_{j in A_v^r} w_j) * g_u^r

with A_v^r = {u : C_u^r subset of C_v^r[s_max]} and |A_v^r| >= 1.  When
every update is reconstructable by the deadline, all clients compute the
*identical* aggregate — the same value as server-based FedAvg — which is
the paper's core aggregation-semantics claim.

The computation is a masked weighted reduction over stacked flat
updates; the Pallas kernel in ``repro.kernels.fedavg_reduce`` implements
the fused version and this module is its jnp fallback/dispatch point.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def fedavg_weights(weights: jnp.ndarray, active: jnp.ndarray) -> jnp.ndarray:
    """Normalized FedAvg weights restricted to the active set."""
    w = jnp.asarray(weights, jnp.float32) * jnp.asarray(active, jnp.float32)
    total = jnp.sum(w)
    return jnp.where(total > 0, w / jnp.maximum(total, 1e-12), w)


def fedavg_flat(updates: jnp.ndarray, weights: jnp.ndarray,
                active: jnp.ndarray, use_kernel: bool = False) -> jnp.ndarray:
    """Masked weighted average of stacked flat updates (n, D) -> (D,)."""
    wn = fedavg_weights(weights, active)
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.fedavg(updates, weights, active, impl="interpret")
    return jnp.einsum("n,nd->d", wn, updates.astype(jnp.float32))


def fedavg_pytree(updates: list, weights, active, use_kernel: bool = False):
    """FedAvg over a list of update pytrees (same treedef)."""
    weights = jnp.asarray(weights, jnp.float32)
    active = jnp.asarray(active, jnp.float32)
    wn = fedavg_weights(weights, active)

    def combine(*leaves):
        stacked = jnp.stack([l.astype(jnp.float32) for l in leaves])
        return jnp.einsum("n,n...->...", wn, stacked)

    return jax.tree_util.tree_map(combine, *updates)


def per_client_aggregates(updates: jnp.ndarray, weights: np.ndarray,
                          reconstructable: np.ndarray) -> jnp.ndarray:
    """Each client v aggregates over its own A_v^r: (n, D) -> (n, D).

    ``reconstructable[v, u]`` says update u is reconstructable at v by
    the deadline.  Rows with an empty active set return zeros (the
    protocol requires |A_v^r| >= 1; callers treat such clients as
    dropped for the round)."""
    recon = jnp.asarray(reconstructable, jnp.float32)        # (n, n)
    w = jnp.asarray(weights, jnp.float32)[None, :] * recon   # (n, n)
    denom = jnp.sum(w, axis=1, keepdims=True)
    wn = jnp.where(denom > 0, w / jnp.maximum(denom, 1e-12), 0.0)
    return wn @ updates.astype(jnp.float32)


def agreement_check(aggregates, atol: float = 1e-6) -> bool:
    """True when all per-client aggregates agree (full dissemination).

    Accepts a stacked (n, D) array or a list of same-treedef pytrees."""
    if isinstance(aggregates, (list, tuple)):
        flats = [jnp.concatenate([jnp.ravel(l).astype(jnp.float32)
                                  for l in jax.tree_util.tree_leaves(a)])
                 for a in aggregates]
        aggregates = jnp.stack(flats)
    ref = aggregates[0]
    return bool(jnp.max(jnp.abs(aggregates - ref[None])) <= atol)
