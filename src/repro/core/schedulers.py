"""Warm-up chunk schedulers (paper §III-C) + vanilla-BT slot scheduling.

Implements the paper's scheduler family:

* ``random_fifo``            — §III-C.3: random feasible sender, FIFO-ish
                               (random receiver processing order).
* ``random_fastest_first``   — §III-C.4: senders prioritize the fastest
                               requesters (receivers processed by
                               remaining downlink, senders random).
* ``greedy_fastest_first``   — §III-C.5: each request assigned to the
                               fastest feasible sender (max remaining
                               uplink); the paper's default.
* ``distributed``            — §III-C.6: clients only see the
                               neighborhood-level availability union
                               C^TA(v); requests may miss.
* ``flooding``               — §III-C.7: random push without receiver
                               state; wastes bandwidth.

All centralized schedulers apply the **non-owner-first** refinement
(§III-C): a sender that is not the chunk's original source is preferred;
the source is a fallback.  During warm-up, senders only serve chunks
from their *eligible* buffer (cover-set gating + owner throttling,
state.py), so every emitted transfer honors Eq. (1).

Budgets per slot: sender u uploads <= up[u] chunks to <= tau distinct
receivers; receiver v downloads <= down[v] chunks; duplicate deliveries
of a (receiver, chunk) pair are never scheduled.

The per-slot assignment is vectorized over a *supply-restricted* column
set (chunks with >1 replica plus the eligible owner windows), which is
small early in warm-up and keeps large-n simulation tractable.
"""
from __future__ import annotations

import numpy as np

from .state import SwarmState

BIG = 1 << 40


# ----------------------------------------------------------------------
# Supply-restricted candidate columns
# ----------------------------------------------------------------------

def _candidate_columns(state: SwarmState, sactive: np.ndarray) -> np.ndarray:
    """Chunk ids that at least one sender could serve this slot."""
    cfg = state.cfg
    if state.phase == "bt" or not cfg.enable_gating:
        # Everything any active client holds is eligible; cheapest
        # over-approximation is "all chunks" (every chunk has an owner).
        return np.arange(cfg.total_chunks)
    mask = state.replicas > 1          # replicated => some non-owner holds it
    for u in np.flatnonzero(sactive):
        win = state.eligible_owner_slice(int(u))
        if win.size:
            mask[win] = True
    cand = np.flatnonzero(mask)
    cap = cfg.cand_cap
    if cap and cand.size > cap:
        # keep the rarest `cap` candidates (rarest-first priority
        # would pick them anyway; large-n Table III runs)
        sel = np.argpartition(state.replicas[cand], cap - 1)[:cap]
        cand = np.sort(cand[sel])
    return cand


def _supply_matrix(state: SwarmState, nbr_idx: np.ndarray,
                   cand: np.ndarray, cand_owner: np.ndarray) -> np.ndarray:
    """(len(nbrs), len(cand)) bool: can neighbor j serve candidate chunk?"""
    sup = state.have[np.ix_(nbr_idx, cand)]
    if state.phase != "bt" and state.cfg.enable_gating:
        for j, u in enumerate(nbr_idx):
            own = cand_owner == u
            if not own.any():
                continue
            win = state.eligible_owner_slice(int(u))
            allowed = np.isin(cand, win, assume_unique=True)
            sup[j] &= (~own) | allowed
    return sup


# ----------------------------------------------------------------------
# Centralized scheduler family
# ----------------------------------------------------------------------

def schedule_centralized(state: SwarmState, mode: str):
    """One stage of tracker-assigned transfers.  Returns (snd, rcv, chk)."""
    cfg = state.cfg
    rng = state.rng
    n = cfg.n

    sactive = state.senders_active()
    rem_up = np.where(sactive, state.up, 0).astype(np.int64)
    rem_down = np.where(state.active, state.down, 0).astype(np.int64)
    recv_slots = np.full(n, cfg.tau_concurrent, dtype=np.int64)
    serving = np.zeros((n, n), dtype=bool)   # sender already serving recv

    cand = _candidate_columns(state, sactive)
    if cand.size == 0:
        return (np.zeros(0, np.int64),) * 3
    cand_owner = state.owners[cand]
    # Rarest-first priority with random tie-break (recomputed per slot).
    prio = state.replicas[cand].astype(np.float64)
    prio += rng.random(cand.size)

    if mode == "random_fastest_first":
        # sender-side "tau fastest requesters": fast receivers get
        # first claim on the per-sender serving slots
        recv_order = np.argsort(-(rem_down + rng.random(n)))
    else:
        # request arrival order is random; GFF greediness lives in
        # the per-request fastest-SENDER assignment below
        recv_order = rng.permutation(n)

    out_s, out_r, out_c = [], [], []

    warm = state.phase != "bt"
    for v in recv_order:
        v = int(v)
        if rem_down[v] <= 0 or not state.active[v]:
            continue
        # Warm-up serves only clients still below the cover-set
        # threshold (§III-B: "until all active clients reach the k-chunk
        # threshold"); satisfied clients stop issuing warm-up requests.
        if warm and state.hold[v] >= cfg.k_term:
            continue
        nbr_mask = state.adj[v] & (rem_up > 0) & (recv_slots > 0)
        nbr_mask |= state.adj[v] & (rem_up > 0) & serving[:, v]
        nbr_idx = np.flatnonzero(nbr_mask)
        if nbr_idx.size == 0:
            continue

        sup = _supply_matrix(state, nbr_idx, cand, cand_owner)
        need_v = ~state.have[v, cand]
        sup &= need_v[None, :]
        if not sup.any():
            continue

        taken = np.zeros(cand.size, dtype=bool)
        budget = int(rem_down[v])
        # pass 0: non-owner-first; pass 1: owner fallback
        passes = (0, 1) if cfg.enable_nonowner_first else (1,)
        if mode == "greedy_fastest_first":
            # Per-REQUEST assignment (§III-C.5): every missing chunk goes
            # to the currently-fastest feasible sender; rem_up decrements
            # re-rank senders between requests, spreading load instead of
            # letting one receiver drain the fastest sender's uplink+tau.
            # Per-sender rarest-first queues with lazy deletion keep each
            # request O(log)-ish instead of rescanning all candidates.
            queues = []
            qcap = max(4 * int(rem_down[v]) + 8, 64)
            for jj in range(nbr_idx.size):
                ids = np.flatnonzero(sup[jj])
                if ids.size > qcap:   # only ever need ~rem_down picks
                    sel = np.argpartition(prio[ids], qcap - 1)[:qcap]
                    ids = ids[sel]
                queues.append(ids[np.argsort(prio[ids])])
            ptr = np.zeros(nbr_idx.size, dtype=np.int64)
            deferred: list = [[] for _ in range(nbr_idx.size)]
            for pass_id in passes:
                while budget > 0:
                    feas = (rem_up[nbr_idx] > 0) & (
                        (recv_slots[nbr_idx] > 0) | serving[nbr_idx, v])
                    if not feas.any():
                        break
                    jidx = np.flatnonzero(feas)
                    jorder = jidx[np.argsort(-(rem_up[nbr_idx[jidx]]
                                               + rng.random(jidx.size)))]
                    progressed = False
                    for jj in jorder:
                        if budget <= 0:
                            break
                        u = int(nbr_idx[jj])
                        q = queues[jj]
                        p = int(ptr[jj])
                        pick = -1
                        if pass_id != 0:     # owner chunks deferred first
                            while deferred[jj]:
                                c = deferred[jj].pop(0)
                                if not taken[c]:
                                    pick = c
                                    break
                        while pick < 0 and p < len(q):
                            c = int(q[p])
                            p += 1
                            if taken[c]:
                                continue
                            if pass_id == 0 and cand_owner[c] == u:
                                deferred[jj].append(c)  # wait for pass 1
                                continue
                            pick = c
                        ptr[jj] = p
                        if pick < 0:
                            continue
                        taken[pick] = True
                        rem_up[u] -= 1
                        budget -= 1
                        if not serving[u, v]:
                            serving[u, v] = True
                            recv_slots[u] -= 1
                        out_s.append(np.full(1, u, dtype=np.int64))
                        out_r.append(np.full(1, v, dtype=np.int64))
                        out_c.append(cand[pick:pick + 1])
                        progressed = True
                    if not progressed:
                        break
        else:
            sender_order = rng.permutation(nbr_idx.size)
            for pass_id in passes:
                if budget <= 0:
                    break
                for jj in sender_order:
                    if budget <= 0:
                        break
                    u = int(nbr_idx[jj])
                    cap = int(rem_up[u])
                    if cap <= 0:
                        continue
                    if recv_slots[u] <= 0 and not serving[u, v]:
                        continue
                    row = sup[jj] & ~taken
                    if pass_id == 0:
                        row = row & (cand_owner != u)
                    ids = np.flatnonzero(row)
                    if ids.size == 0:
                        continue
                    take_n = min(cap, budget, ids.size)
                    if take_n < ids.size:
                        sel = np.argpartition(prio[ids],
                                              take_n - 1)[:take_n]
                        ids = ids[sel]
                    taken[ids] = True
                    rem_up[u] -= len(ids)
                    budget -= len(ids)
                    if not serving[u, v]:
                        serving[u, v] = True
                        recv_slots[u] -= 1
                    out_s.append(np.full(len(ids), u, dtype=np.int64))
                    out_r.append(np.full(len(ids), v, dtype=np.int64))
                    out_c.append(cand[ids])
        rem_down[v] = budget

    if not out_s:
        return (np.zeros(0, np.int64),) * 3
    return (np.concatenate(out_s), np.concatenate(out_r),
            np.concatenate(out_c))


# ----------------------------------------------------------------------
# Distributed scheduling (neighborhood-level announcements, §III-C.6)
# ----------------------------------------------------------------------

def schedule_distributed(state: SwarmState):
    """Clients request random missing chunks from random neighbors.

    The tracker only publishes the neighborhood union C^TA(v, s), so a
    request may land on a neighbor that cannot serve it (wasted).
    """
    cfg = state.cfg
    rng = state.rng
    n = cfg.n
    sactive = state.senders_active()
    rem_up = np.where(sactive, state.up, 0).astype(np.int64)
    rem_down = np.where(state.active, state.down, 0).astype(np.int64)

    cand = _candidate_columns(state, sactive)
    if cand.size == 0:
        return (np.zeros(0, np.int64),) * 3
    cand_owner = state.owners[cand]

    warm = state.phase != "bt"
    req_s, req_r, req_c = [], [], []
    for v in range(n):
        v = int(v)
        if rem_down[v] <= 0 or not state.active[v]:
            continue
        if warm and state.hold[v] >= cfg.k_term:
            continue
        nbr_idx = np.flatnonzero(state.adj[v])
        if nbr_idx.size == 0:
            continue
        # Neighborhood-level availability: union over neighbors, no map.
        sup = _supply_matrix(state, nbr_idx, cand, cand_owner)
        union = sup.any(axis=0) & ~state.have[v, cand]
        ids = np.flatnonzero(union)
        if ids.size == 0:
            continue
        want = min(int(rem_down[v]), ids.size)
        pick = rng.choice(ids, size=want, replace=False)
        # Random neighbor per request (client cannot target the holder).
        tgt = rng.choice(nbr_idx, size=want, replace=True)
        ok = sup[np.searchsorted(nbr_idx, tgt), pick]  # request hit?
        req_s.append(tgt[ok])
        req_r.append(np.full(int(ok.sum()), v, dtype=np.int64))
        req_c.append(cand[pick[ok]])

    if not req_s:
        return (np.zeros(0, np.int64),) * 3
    snd = np.concatenate(req_s)
    rcv = np.concatenate(req_r)
    chk = np.concatenate(req_c)
    # Senders serve FIFO up to their uplink budget.
    order = rng.permutation(len(snd))
    snd, rcv, chk = snd[order], rcv[order], chk[order]
    keep = np.zeros(len(snd), dtype=bool)
    for i in range(len(snd)):
        u = snd[i]
        if rem_up[u] > 0:
            keep[i] = True
            rem_up[u] -= 1
    return snd[keep], rcv[keep], chk[keep]


# ----------------------------------------------------------------------
# Flooding (§III-C.7)
# ----------------------------------------------------------------------

def schedule_flooding(state: SwarmState, sent_pairs: dict):
    """Push random eligible chunks to random neighbors, no repetition.

    ``sent_pairs`` maps (sender, receiver) -> set of already-pushed chunk
    ids; receivers may already hold the chunk (wasted bandwidth), which
    is exactly why flooding under-performs coordinated warm-up (§III-C).
    """
    cfg = state.cfg
    rng = state.rng
    n = cfg.n
    sactive = state.senders_active()
    rem_down = np.where(state.active, state.down, 0).astype(np.int64)

    out_s, out_r, out_c = [], [], []
    for u in np.flatnonzero(sactive):
        u = int(u)
        budget = int(state.up[u])
        elig = np.flatnonzero(state.eligible_row(u))
        nbr_idx = np.flatnonzero(state.adj[u] & state.active)
        if elig.size == 0 or nbr_idx.size == 0:
            continue
        tgts = rng.choice(nbr_idx, size=budget, replace=True)
        picks = rng.choice(elig, size=budget, replace=True)
        for t, c in zip(tgts, picks):
            key = (u, int(t))
            seen = sent_pairs.setdefault(key, set())
            if int(c) in seen or rem_down[t] <= 0:
                continue
            seen.add(int(c))
            rem_down[t] -= 1
            out_s.append(u)
            out_r.append(int(t))
            out_c.append(int(c))
    if not out_s:
        return (np.zeros(0, np.int64),) * 3
    return (np.asarray(out_s, np.int64), np.asarray(out_r, np.int64),
            np.asarray(out_c, np.int64))


CENTRALIZED = {"random_fifo", "random_fastest_first", "greedy_fastest_first"}


def run_scheduler(state: SwarmState, flood_state: dict | None = None):
    name = state.cfg.scheduler
    if name in CENTRALIZED:
        return schedule_centralized(state, name)
    if name == "distributed":
        return schedule_distributed(state)
    if name == "flooding":
        assert flood_state is not None
        return schedule_flooding(state, flood_state)
    raise ValueError(f"unknown scheduler {name!r}")
