"""Warm-up chunk schedulers (paper §III-C) + vanilla-BT slot scheduling.

The family ships as :class:`~repro.core.policy.SchedulerPolicy` classes
registered under their paper names (``SwarmConfig.scheduler`` accepts a
name or an instance; see core/policy.py for the plugin API and
examples/custom_policy.py for a 20-line custom policy).  The policy
layer is a thin declaration of *what the mode may see*; the slot
*engines* below do the work and remain interchangeable backends behind
every policy (``SwarmConfig.scheduler_impl``).

Implements the paper's scheduler family:

* ``random_fifo``            — §III-C.3: random feasible sender, FIFO-ish
                               (random receiver processing order).
* ``random_fastest_first``   — §III-C.4: senders prioritize the fastest
                               requesters (receivers processed by
                               remaining downlink, senders random).
* ``greedy_fastest_first``   — §III-C.5: each request assigned to the
                               fastest feasible sender (max remaining
                               uplink); the paper's default.
* ``distributed``            — §III-C.6: clients only see the
                               neighborhood-level availability union
                               C^TA(v); requests may miss.
* ``flooding``               — §III-C.7: random push without receiver
                               state; wastes bandwidth.

All centralized schedulers apply the **non-owner-first** refinement
(§III-C): a sender that is not the chunk's original source is preferred;
the source is a fallback.  During warm-up, senders only serve chunks
from their *eligible* buffer (cover-set gating + owner throttling,
state.py), so every emitted transfer honors Eq. (1).

Budgets per slot: sender u uploads <= up[u] chunks to <= tau distinct
receivers; receiver v downloads <= down[v] chunks; duplicate deliveries
of a (receiver, chunk) pair are never scheduled.

Two slot-engine implementations are provided (``SwarmConfig
.scheduler_impl``):

* ``"batched"`` (default) — the paper-scale engine.  Per slot it builds
  the (sender x candidate-chunk) supply ONCE via the vectorized
  eligibility helpers in :class:`SwarmState` and resolves the
  assignment with budgeted rounds over ALL receivers at once: every
  round each needy receiver picks a feasible sender (mode-dependent
  score), senders grant rarest-first chunk batches under uplink /
  downlink / tau budgets, with non-owner-first applied inside every
  grant (non-owner overlap is extracted first, owner fallback fills
  the remainder — the loop engine's per-receiver pass structure).
* ``"loop"`` — the original per-receiver reference engine, kept
  byte-for-byte so equivalence tests can assert the batched engine
  schedules legally and matches its aggregate throughput.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .policy import (SchedulerPolicy, SlotView, VISIBILITY_FULL,
                     VISIBILITY_NEIGHBORHOOD, VISIBILITY_NONE,
                     get_policy, register_policy)
from .state import SwarmState

BIG = 1 << 40


def _empty():
    return (np.zeros(0, np.int64), np.zeros(0, np.int64),
            np.zeros(0, np.int64))


# Byte-prefix lookup for the bitpacked engine: _PREFIX[b, r] keeps only
# the first r set bits of byte b (MSB-first, matching np.packbits).
def _build_prefix() -> np.ndarray:
    bits = np.unpackbits(np.arange(256, dtype=np.uint8)[:, None], axis=1)
    csum = bits.cumsum(axis=1)
    out = np.zeros((256, 9), dtype=np.uint8)
    for r in range(9):
        out[:, r] = np.packbits(bits & (csum <= r), axis=1)[:, 0]
    return out


_PREFIX = _build_prefix()

_BLK = 32          # bytes per extraction block; plane widths pad to this


def _pad_cols(a: np.ndarray, pad: int) -> np.ndarray:
    """Zero-pad packed planes on the right (np.pad's overhead hurts in
    the per-slot path)."""
    out = np.zeros((a.shape[0], a.shape[1] + pad), dtype=a.dtype)
    out[:, :a.shape[1]] = a
    return out


def _count_rows(rows_p: np.ndarray):
    """Block-level popcount cumsum of packed rows.

    Returns ``(bcum, cnt)``: (G, nblk) cumulative set-bit counts per
    _BLK-byte block and the (G,) row totals.  Avoids a full byte-wise
    cumsum over the plane width — only each grant's single boundary
    block is later refined byte-by-byte in :func:`_extract_prefix`.
    """
    g, mb = rows_p.shape
    nblk = mb // _BLK
    if nblk == 0:
        return np.zeros((g, 0), np.int64), np.zeros(g, np.int64)
    w64 = np.bitwise_count(rows_p.view(np.uint64))
    bcnt = w64.reshape(g, nblk, _BLK // 8) @ np.ones(_BLK // 8, np.int64)
    bcum = np.cumsum(bcnt, axis=1)
    return bcum, bcum[:, -1]


def _extract_prefix(rows_p: np.ndarray, bcum: np.ndarray,
                    take: np.ndarray):
    """Keep only the first ``take[i]`` set bits of each packed row.

    Hierarchical: whole blocks below the boundary are copied, the
    boundary block gets a byte-wise cumsum, and its boundary byte is
    trimmed with the _PREFIX lookup.  Returns ``(sel_p, gi, ci)`` where
    (gi, ci) are the selected (row, bit-column) pairs in row-major
    (i.e. rarest-first) order.
    """
    g, mb = rows_p.shape
    nblk = mb // _BLK
    fullb = bcum <= take[:, None]
    blk = fullb.sum(axis=1)
    sel_p = np.where(np.repeat(fullb, _BLK, axis=1), rows_p, np.uint8(0))
    # Every step below is empty-safe, so the boundary-block refinement
    # and the decode run unconditionally (jit-clean: no `if arr.size`
    # branches on array values).
    gb = np.flatnonzero(blk < nblk)
    blkb = blk[gb]
    prevb = np.where(
        blkb > 0,
        np.take_along_axis(bcum[gb], np.maximum(blkb - 1, 0)[:, None],
                           axis=1)[:, 0], 0)
    rblk = take[gb] - prevb                 # bits wanted in boundary
    bb = np.take_along_axis(rows_p[gb].reshape(gb.size, nblk, _BLK),
                            blkb[:, None, None], axis=1)[:, 0]
    wcum = np.cumsum(np.bitwise_count(bb), axis=1, dtype=np.int16)
    fullw = wcum <= rblk[:, None]
    selb = np.where(fullw, bb, np.uint8(0))
    cut = fullw.sum(axis=1)
    g2 = np.flatnonzero(cut < _BLK)
    cb = cut[g2]
    prev = np.where(cb > 0, wcum[g2, np.maximum(cb - 1, 0)], 0)
    r = np.minimum(rblk[g2] - prev, 8)
    selb[g2, cb] = _PREFIX[bb[g2, cb], r]
    sel_p.reshape(g, nblk, _BLK)[gb, blkb] = selb
    # Decode: uint64 words -> set bytes -> set bits, scanning only the
    # packed plane and then only its populated pieces.
    w64 = sel_p.view(np.uint64)
    g64, i64 = np.nonzero(w64)
    b8 = sel_p.reshape(g, mb // 8, 8)[g64, i64]     # (H, 8) bytes
    hz, bz = np.nonzero(b8)
    vals = b8[hz, bz]
    bits = np.unpackbits(vals[:, None], axis=1).view(bool)
    gi8 = np.broadcast_to(g64[hz][:, None], (hz.size, 8))
    ci8 = (i64[hz] * 8 + bz)[:, None] * 8 + np.arange(8)
    return sel_p, gi8[bits], ci8[bits]


# ----------------------------------------------------------------------
# Supply-restricted candidate columns (loop-engine legacy helpers;
# max-flow and the batched engine use the vectorized SwarmState API)
# ----------------------------------------------------------------------

def _candidate_columns(state: SwarmState, sactive: np.ndarray) -> np.ndarray:
    """Chunk ids that at least one sender could serve this slot."""
    cfg = state.cfg
    if state.phase == "bt" or not cfg.enable_gating:
        # Everything any active client holds is eligible; cheapest
        # over-approximation is "all chunks" (every chunk has an owner).
        return np.arange(cfg.total_chunks)
    mask = state.replicas > 1          # replicated => some non-owner holds it
    for u in np.flatnonzero(sactive):
        win = state.eligible_owner_slice(int(u))
        if win.size:
            mask[win] = True
    cand = np.flatnonzero(mask)
    cap = cfg.cand_cap
    if cap and cand.size > cap:
        # Rarity-stratified cap, mirroring SwarmState.candidate_columns:
        # the rarest cap/2 plus an even stride over the rest, so large
        # swarms keep servable supply in every neighborhood.
        half = cap // 2
        sel = np.argpartition(state.replicas[cand], half - 1)[:half]
        covered = np.zeros(cand.size, dtype=bool)
        covered[sel] = True
        rest = np.flatnonzero(~covered)
        take = cap - half
        pos = (np.arange(take, dtype=np.int64) * rest.size) // max(take, 1)
        cand = np.sort(cand[np.concatenate([sel, rest[pos]])])
    return cand


def _supply_matrix(state: SwarmState, nbr_idx: np.ndarray,
                   cand: np.ndarray, cand_owner: np.ndarray) -> np.ndarray:
    """(len(nbrs), len(cand)) bool: can neighbor j serve candidate chunk?"""
    sup = state.have[np.ix_(nbr_idx, cand)]
    if state.phase != "bt" and state.cfg.enable_gating:
        for j, u in enumerate(nbr_idx):
            own = cand_owner == u
            if not own.any():
                continue
            win = state.eligible_owner_slice(int(u))
            allowed = np.isin(cand, win, assume_unique=True)
            sup[j] &= (~own) | allowed
    return sup


# ----------------------------------------------------------------------
# Centralized scheduler family — loop (reference) engine
# ----------------------------------------------------------------------

def _schedule_centralized_loop(state: SwarmState, mode: str):
    """One stage of tracker-assigned transfers.  Returns (snd, rcv, chk)."""
    cfg = state.cfg
    rng = state.rng
    n = cfg.n

    sactive = state.senders_active()
    rem_up = np.where(sactive, state.up, 0).astype(np.int64)
    rem_down = np.where(state.active, state.down, 0).astype(np.int64)
    recv_slots = np.full(n, cfg.tau_concurrent, dtype=np.int64)
    serving = np.zeros((n, n), dtype=bool)   # sender already serving recv

    cand = _candidate_columns(state, sactive)
    if cand.size == 0:
        return _empty()
    cand_owner = state.owners[cand]
    # Rarest-first priority with random tie-break (recomputed per slot).
    prio = state.replicas[cand].astype(np.float64)
    prio += rng.random(cand.size)

    if mode == "random_fastest_first":
        # sender-side "tau fastest requesters": fast receivers get
        # first claim on the per-sender serving slots
        recv_order = np.argsort(-(rem_down + rng.random(n)))
    else:
        # request arrival order is random; GFF greediness lives in
        # the per-request fastest-SENDER assignment below
        recv_order = rng.permutation(n)

    out_s, out_r, out_c = [], [], []

    warm = state.phase != "bt"
    for v in recv_order:
        v = int(v)
        if rem_down[v] <= 0 or not state.active[v]:
            continue
        # Warm-up serves only clients still below the cover-set
        # threshold (§III-B: "until all active clients reach the k-chunk
        # threshold"); satisfied clients stop issuing warm-up requests.
        if warm and state.hold[v] >= cfg.k_term:
            continue
        nbr_mask = state.adj[v] & (rem_up > 0) & (recv_slots > 0)
        nbr_mask |= state.adj[v] & (rem_up > 0) & serving[:, v]
        nbr_idx = np.flatnonzero(nbr_mask)
        if nbr_idx.size == 0:
            continue

        sup = _supply_matrix(state, nbr_idx, cand, cand_owner)
        need_v = ~state.have[v, cand]
        sup &= need_v[None, :]
        if not sup.any():
            continue

        taken = np.zeros(cand.size, dtype=bool)
        budget = int(rem_down[v])
        # pass 0: non-owner-first; pass 1: owner fallback
        passes = (0, 1) if cfg.enable_nonowner_first else (1,)
        if mode == "greedy_fastest_first":
            # Per-REQUEST assignment (§III-C.5): every missing chunk goes
            # to the currently-fastest feasible sender; rem_up decrements
            # re-rank senders between requests, spreading load instead of
            # letting one receiver drain the fastest sender's uplink+tau.
            # Per-sender rarest-first queues with lazy deletion keep each
            # request O(log)-ish instead of rescanning all candidates.
            queues = []
            qcap = max(4 * int(rem_down[v]) + 8, 64)
            for jj in range(nbr_idx.size):
                ids = np.flatnonzero(sup[jj])
                if ids.size > qcap:   # only ever need ~rem_down picks
                    sel = np.argpartition(prio[ids], qcap - 1)[:qcap]
                    ids = ids[sel]
                queues.append(ids[np.argsort(prio[ids])])
            ptr = np.zeros(nbr_idx.size, dtype=np.int64)
            deferred: list = [[] for _ in range(nbr_idx.size)]
            for pass_id in passes:
                while budget > 0:
                    feas = (rem_up[nbr_idx] > 0) & (
                        (recv_slots[nbr_idx] > 0) | serving[nbr_idx, v])
                    if not feas.any():
                        break
                    jidx = np.flatnonzero(feas)
                    jorder = jidx[np.argsort(-(rem_up[nbr_idx[jidx]]
                                               + rng.random(jidx.size)))]
                    progressed = False
                    for jj in jorder:
                        if budget <= 0:
                            break
                        u = int(nbr_idx[jj])
                        q = queues[jj]
                        p = int(ptr[jj])
                        pick = -1
                        if pass_id != 0:     # owner chunks deferred first
                            while deferred[jj]:
                                c = deferred[jj].pop(0)
                                if not taken[c]:
                                    pick = c
                                    break
                        while pick < 0 and p < len(q):
                            c = int(q[p])
                            p += 1
                            if taken[c]:
                                continue
                            if pass_id == 0 and cand_owner[c] == u:
                                deferred[jj].append(c)  # wait for pass 1
                                continue
                            pick = c
                        ptr[jj] = p
                        if pick < 0:
                            continue
                        taken[pick] = True
                        rem_up[u] -= 1
                        budget -= 1
                        if not serving[u, v]:
                            serving[u, v] = True
                            recv_slots[u] -= 1
                        out_s.append(np.full(1, u, dtype=np.int64))
                        out_r.append(np.full(1, v, dtype=np.int64))
                        out_c.append(cand[pick:pick + 1])
                        progressed = True
                    if not progressed:
                        break
        else:
            sender_order = rng.permutation(nbr_idx.size)
            for pass_id in passes:
                if budget <= 0:
                    break
                for jj in sender_order:
                    if budget <= 0:
                        break
                    u = int(nbr_idx[jj])
                    cap = int(rem_up[u])
                    if cap <= 0:
                        continue
                    if recv_slots[u] <= 0 and not serving[u, v]:
                        continue
                    row = sup[jj] & ~taken
                    if pass_id == 0:
                        row = row & (cand_owner != u)
                    ids = np.flatnonzero(row)
                    if ids.size == 0:
                        continue
                    take_n = min(cap, budget, ids.size)
                    if take_n < ids.size:
                        sel = np.argpartition(prio[ids],
                                              take_n - 1)[:take_n]
                        ids = ids[sel]
                    taken[ids] = True
                    rem_up[u] -= len(ids)
                    budget -= len(ids)
                    if not serving[u, v]:
                        serving[u, v] = True
                        recv_slots[u] -= 1
                    out_s.append(np.full(len(ids), u, dtype=np.int64))
                    out_r.append(np.full(len(ids), v, dtype=np.int64))
                    out_c.append(cand[ids])
        rem_down[v] = budget

    if not out_s:
        return _empty()
    return (np.concatenate(out_s), np.concatenate(out_r),
            np.concatenate(out_c))


# ----------------------------------------------------------------------
# Centralized scheduler family — batched (paper-scale) engine
# ----------------------------------------------------------------------

def _schedule_centralized_batched(state: SwarmState, mode: str):
    """Vectorized budgeted-round slot assignment over all receivers.

    Per slot: candidate columns and the full (sender x candidate)
    eligible supply are built ONCE via the vectorized SwarmState
    helpers, and columns are pre-sorted by rarest-first priority so the
    first set bits of any supply&need row are the rarest feasible picks.

    Assignment then proceeds in fully vectorized budgeted rounds.  Each
    round every needy receiver selects one feasible sender (fastest
    remaining uplink for GFF, random otherwise) among neighbors with a
    known serveable overlap (an edge-wise popcount prior computed once
    per slot).  For the random modes each sender then splits its uplink
    over all its requesters in mode-priority order (fastest-downlink
    first for RandomFastestFirst) via grouped exclusive cumsums; for
    GFF one receiver wins each sender (it may drain the fastest sender,
    as loop-GFF receivers do) and losers re-pick among untaken senders.
    All (sender, receiver) grants extract their rarest-first chunk
    batches in one shot through the hierarchical block/byte/bit
    popcount machinery (:func:`_count_rows` / :func:`_extract_prefix`)
    — no per-transfer Python.  Batches are bounded by remaining
    uplink/downlink and the tau concurrency slots.  Non-owner-first
    runs as a masked first pass during warm-up; pairs whose overlap was
    consumed mid-slot are tombstoned so rounds terminate after at most
    O(degree) retries per receiver.
    """
    cfg = state.cfg
    rng = state.rng
    n = cfg.n

    sactive = state.senders_active()
    rem_up = np.where(sactive, state.up, 0).astype(np.int64)
    rem_down = np.where(state.active, state.down, 0).astype(np.int64)
    recv_slots = np.full(n, cfg.tau_concurrent, dtype=np.int64)
    serving = np.zeros((n, n), dtype=bool)    # (receiver, sender)

    cand = state.candidate_columns(sactive)
    if cand.size == 0:
        return _empty()
    # float32 keeps the jitter resolving ties without flipping distinct
    # replica counts (< 2^23 in any feasible swarm) and sorts faster.
    prio = state.replicas[cand].astype(np.float32)
    prio += rng.random(cand.size, dtype=np.float32)
    cand = cand[np.argsort(prio)]              # columns in rarity order
    m = cand.size

    # Bitpacked (n, ceil(m/8)) supply and need planes, built ONCE per
    # slot from a single priority-ordered gather of ``have``; all round
    # bookkeeping below runs in the packed domain so per-round work is
    # ~m/8 bytes per touched row.  np.take keeps the gather result
    # C-contiguous (a fancy ``have[:, cand]`` yields a transposed view
    # that makes every downstream byte op ~25x slower).
    hv = np.take(state.have, cand, axis=1)
    sup_p = np.packbits(state.eligible_supply(cand, have_cols=hv), axis=1)
    warm = state.phase != "bt"
    recv_ok = state.active & (rem_down > 0)
    if warm:
        recv_ok &= state.hold < cfg.k_term
    need_p = np.packbits(~hv, axis=1)
    pad = (-need_p.shape[1]) % _BLK            # block-align the planes
    if pad:
        sup_p = _pad_cols(sup_p, pad)
        need_p = _pad_cols(need_p, pad)
    need_p[~recv_ok] = 0                       # row mask, packed domain
    need_cnt = np.bitwise_count(
        need_p.view(np.uint64)).sum(axis=1).astype(np.int64)

    # Non-owner-first is a warm-up privacy refinement (§III-C): during
    # BT swarming transfers are not attack-observed and the ungated
    # supply is dense, so the preference is aggregate-neutral there and
    # BT grants extract single-tier.
    nonowner_pass = cfg.enable_nonowner_first and warm
    if nonowner_pass:
        # Per-sender packed mask of NON-owned candidate columns, built
        # once per slot directly in the packed domain: each column has
        # exactly one owner row, so clearing m bits in an all-ones
        # plane beats materializing the dense (n, m) complement (~25x
        # at the n=500/K=206 point).  Pad bytes stay 0xFF, which is
        # harmless: every use ANDs against rows whose pad bits are 0.
        cand_owner = state.owners[cand]
        cols = np.arange(m)
        nonown_p = np.full((n, need_p.shape[1]), 255, dtype=np.uint8)
        np.bitwise_and.at(nonown_p, (cand_owner, cols >> 3),
                          (255 ^ (128 >> (cols & 7))).astype(np.uint8))

    if not need_cnt.any():
        return _empty()

    # Warm-up grants are capped to a fraction of the fastest uplink so
    # every receiver fans in from ~all feasible neighbors within a slot,
    # matching the loop engine's per-request spreading — the attack
    # surface (§IV-C reads warm-up logs) depends on that fan-in: a
    # receiver served by only a handful of full-drain senders would see
    # first-contact chunk mixes the paper's ablation ASR curves never
    # see.  BT batches stay budget-bound (attacks never read them).
    if warm:
        # np scalar end to end: no host coercion (device->host sync
        # under a jitted build), same value in every integer op below.
        batch_cap = np.maximum(np.max(rem_up, initial=0) // 4, 1)
    else:
        batch_cap = BIG

    out_s, out_r, out_c = [], [], []

    # live (receiver, sender) pairs: sender-supply prior minus
    # mid-slot tombstones.  During warm-up most senders are still
    # gated with nothing serveable, so the receiver-independent
    # mask removes almost all blind retries; the rare empty pair is
    # tombstoned when its grant comes back empty.
    live = state.adj & sup_p.any(axis=1)[None, :]
    while True:
        ridx = np.flatnonzero((rem_down > 0) & (need_cnt > 0))
        if ridx.size == 0:
            break
        # Feasible sender matrix for the needy receivers (R, n).
        feas = (live[ridx]
                & (rem_up > 0)[None, :]
                & ((recv_slots > 0)[None, :] | serving[ridx]))
        if mode == "greedy_fastest_first":
            score = rem_up.astype(np.float32)[None, :] \
                + rng.random((ridx.size, n), dtype=np.float32)
        else:
            score = rng.random((ridx.size, n), dtype=np.float32)
        score = np.where(feas, score, -np.inf)
        choice = np.argmax(score, axis=1)
        has = feas[np.arange(ridx.size), choice]
        ridx, choice, score = ridx[has], choice[has], score[has]
        if ridx.size == 0:
            break
        # --- pair selection ---
        if mode == "greedy_fastest_first":
            # One receiver per sender (the winner may drain the
            # fastest sender, as loop-GFF receivers do); losing
            # receivers re-pick among still-untaken senders a few
            # times so one round builds a near-maximal matching.
            u_parts, v_parts = [], []
            pos = np.arange(ridx.size)
            cur = choice
            for _ in range(3):
                order = rng.permutation(pos.size)
                _, first = np.unique(cur[order], return_index=True)
                winpos = order[first]
                u_parts.append(cur[winpos])
                v_parts.append(ridx[pos[winpos]])
                score[:, cur[winpos]] = -np.inf
                lose = np.ones(pos.size, dtype=bool)
                lose[winpos] = False
                pos, cur = pos[lose], None
                if pos.size == 0:
                    break
                cur = np.argmax(score[pos], axis=1)
                ok = score[pos, cur] > -np.inf
                pos, cur = pos[ok], cur[ok]
                if pos.size == 0:
                    break
            u_a = np.concatenate(u_parts)
            v_a = np.concatenate(v_parts)
            po = np.argsort(u_a, kind="stable")
            u_a, v_a = u_a[po], v_a[po]
        else:
            # Sender multi-serve: every receiver keeps its chosen
            # sender; each sender splits its uplink over its
            # requesters in mode-priority order.
            if mode == "random_fastest_first":
                order = np.argsort(-(rem_down[ridx]
                                     + rng.random(ridx.size)))
            else:
                order = rng.permutation(ridx.size)
            po = order[np.argsort(choice[order], kind="stable")]
            u_a, v_a = choice[po], ridx[po]

        # Rarest-first batch extraction for all grants at once, in
        # the packed domain: byte-popcount cumsum locates each
        # grant's boundary byte; _PREFIX trims it to the exact
        # batch size; one unpack+nonzero yields all chunk picks.
        rows_p = sup_p[u_a] & need_p[v_a]
        bcum, cnt = _count_rows(rows_p)
        empty_pair = cnt == 0
        if empty_pair.any():
            live[v_a[empty_pair], u_a[empty_pair]] = False
        req = np.minimum(np.minimum(rem_down[v_a], cnt), batch_cap)
        # tau gate: within each sender group (u_a is sorted) only
        # the first recv_slots[u] NEW pairs may open a serve slot.
        first_pos = np.searchsorted(u_a, u_a)
        is_new = ~serving[v_a, u_a]
        cn = np.cumsum(is_new)
        excl_new = cn - is_new
        new_rank = excl_new - excl_new[first_pos]
        req = np.where(~is_new | (new_rank < recv_slots[u_a]), req, 0)
        # uplink split: grouped exclusive cumsum of requests caps
        # each pair at what its sender has left after earlier pairs.
        cq = np.cumsum(req)
        excl = cq - req
        take = np.minimum(req, np.maximum(
            rem_up[u_a] - (excl - excl[first_pos]), 0))
        granted = take > 0
        if not granted.any():
            continue                       # tombstones grew; retry
        u_g, v_g, take_g = u_a[granted], v_a[granted], take[granted]
        rows_g = rows_p[granted]
        bcum_g = bcum[granted]
        if nonowner_pass:
            # Non-owner-first WITHIN each grant (the loop engine's
            # per-receiver pass structure): fill from the non-owner
            # part of the overlap first, fall back to the sender's
            # own chunks only for the remainder of this grant.
            rows_no = rows_g & nonown_p[u_g]
            bcum_no, cnt_no = _count_rows(rows_no)
            take_no = np.minimum(take_g, cnt_no)
            sel_no, gi0, ci0 = _extract_prefix(rows_no, bcum_no,
                                               take_no)
            rows_ow = rows_g & ~nonown_p[u_g]
            take_ow = take_g - take_no
            sel_ow, gi1, ci1 = _extract_prefix(
                rows_ow, bcum_g - bcum_no, take_ow)
            sel_p = sel_no | sel_ow
            # non-owner picks are appended first so each (v, u)
            # pair's earliest logged chunk mirrors the loop order
            out_s.append(u_g[gi0])
            out_r.append(v_g[gi0])
            out_c.append(cand[ci0])
            out_s.append(u_g[gi1])
            out_r.append(v_g[gi1])
            out_c.append(cand[ci1])
        else:
            sel_p, gi, ci = _extract_prefix(rows_g, bcum_g, take_g)
            out_s.append(u_g[gi])
            out_r.append(v_g[gi])
            out_c.append(cand[ci])
        need_p[v_g] &= ~sel_p
        need_cnt[v_g] -= take_g
        np.subtract.at(rem_up, u_g, take_g)
        rem_down[v_g] -= take_g
        fresh = is_new[granted]
        if fresh.any():
            serving[v_g[fresh], u_g[fresh]] = True
            np.subtract.at(recv_slots, u_g[fresh], 1)

    if not out_s:
        return _empty()
    return (np.concatenate(out_s), np.concatenate(out_r),
            np.concatenate(out_c))


# ----------------------------------------------------------------------
# Distributed scheduling (neighborhood-level announcements, §III-C.6)
# ----------------------------------------------------------------------

def _schedule_distributed_loop(state: SwarmState):
    """Clients request random missing chunks from random neighbors.

    The tracker only publishes the neighborhood union C^TA(v, s), so a
    request may land on a neighbor that cannot serve it (wasted).
    """
    cfg = state.cfg
    rng = state.rng
    n = cfg.n
    sactive = state.senders_active()
    rem_up = np.where(sactive, state.up, 0).astype(np.int64)
    rem_down = np.where(state.active, state.down, 0).astype(np.int64)

    cand = _candidate_columns(state, sactive)
    if cand.size == 0:
        return _empty()
    cand_owner = state.owners[cand]

    warm = state.phase != "bt"
    req_s, req_r, req_c = [], [], []
    for v in range(n):
        v = int(v)
        if rem_down[v] <= 0 or not state.active[v]:
            continue
        if warm and state.hold[v] >= cfg.k_term:
            continue
        nbr_idx = np.flatnonzero(state.adj[v])
        if nbr_idx.size == 0:
            continue
        # Neighborhood-level availability: union over neighbors, no map.
        sup = _supply_matrix(state, nbr_idx, cand, cand_owner)
        union = sup.any(axis=0) & ~state.have[v, cand]
        ids = np.flatnonzero(union)
        if ids.size == 0:
            continue
        want = min(int(rem_down[v]), ids.size)
        pick = rng.choice(ids, size=want, replace=False)
        # Random neighbor per request (client cannot target the holder).
        tgt = rng.choice(nbr_idx, size=want, replace=True)
        ok = sup[np.searchsorted(nbr_idx, tgt), pick]  # request hit?
        req_s.append(tgt[ok])
        req_r.append(np.full(int(ok.sum()), v, dtype=np.int64))
        req_c.append(cand[pick[ok]])

    if not req_s:
        return _empty()
    snd = np.concatenate(req_s)
    rcv = np.concatenate(req_r)
    chk = np.concatenate(req_c)
    # Senders serve FIFO up to their uplink budget.
    order = rng.permutation(len(snd))
    snd, rcv, chk = snd[order], rcv[order], chk[order]
    keep = np.zeros(len(snd), dtype=bool)
    for i in range(len(snd)):
        u = snd[i]
        if rem_up[u] > 0:
            keep[i] = True
            rem_up[u] -= 1
    return snd[keep], rcv[keep], chk[keep]


def _schedule_distributed_batched(state: SwarmState):
    """Batched distributed mode: one supply build, vectorized requests.

    The eligible supply is built once; the per-receiver neighborhood
    union is accumulated sender-major (each sender ORs its row into its
    neighbors), request chunks are drawn per receiver via random-score
    top-k over the union, targets are uniform random neighbors, and the
    sender-side FIFO uplink trim is resolved with a stable grouped rank
    instead of a per-request Python loop.
    """
    cfg = state.cfg
    rng = state.rng
    n = cfg.n
    sactive = state.senders_active()
    rem_up = np.where(sactive, state.up, 0).astype(np.int64)
    rem_down = np.where(state.active, state.down, 0).astype(np.int64)

    cand = state.candidate_columns(sactive)
    if cand.size == 0:
        return _empty()
    m = cand.size

    sup = state.eligible_supply(cand)          # (n, m), built once
    warm = state.phase != "bt"
    recv_ok = state.active & (rem_down > 0)
    if warm:
        recv_ok &= state.hold < cfg.k_term
    deg = state.adj.sum(axis=1)
    recv_ok &= deg > 0

    # Neighborhood availability union, sender-major accumulation.
    union = np.zeros((n, m), dtype=bool)
    for u in range(n):
        row = sup[u]
        if row.any():
            union[state.adj[u]] |= row[None, :]
    union &= ~state.have[:, cand]
    union &= recv_ok[:, None]

    ridx = np.flatnonzero(union.any(axis=1))
    if ridx.size == 0:
        return _empty()
    avail = union[ridx]
    counts = avail.sum(axis=1)
    want = np.minimum(rem_down[ridx], counts).astype(np.int64)

    # Distinct random picks per receiver: random scores, row-wise sort,
    # take the first want[i] columns of each row.
    scores = np.where(avail, rng.random((ridx.size, m)), np.inf)
    order = np.argsort(scores, axis=1)
    take_mask = np.arange(m)[None, :] < want[:, None]
    rows = np.repeat(np.arange(ridx.size), want)
    cols = order[take_mask]
    rcv = ridx[rows]
    chk = cand[cols]

    # Uniform random neighbor per request via padded neighbor lists.
    nz_r, nz_c = np.nonzero(state.adj)
    starts = np.searchsorted(nz_r, np.arange(n))
    pick = (rng.random(len(rcv)) * deg[rcv]).astype(np.int64)
    snd = nz_c[starts[rcv] + pick]
    hit = sup[snd, cols]                       # request hit the holder?
    snd, rcv, chk = snd[hit], rcv[hit], chk[hit]
    if len(snd) == 0:
        return _empty()

    # FIFO uplink trim: random arrival order, then rank within each
    # sender group (stable sort preserves arrival order).
    arrival = rng.permutation(len(snd))
    snd, rcv, chk = snd[arrival], rcv[arrival], chk[arrival]
    grp = np.argsort(snd, kind="stable")
    ss = snd[grp]
    first = np.searchsorted(ss, ss)            # start index of own group
    rank = np.arange(len(ss)) - first
    keep_sorted = rank < rem_up[ss]
    keep = np.zeros(len(snd), dtype=bool)
    keep[grp] = keep_sorted
    return snd[keep], rcv[keep], chk[keep]


# ----------------------------------------------------------------------
# Flooding (§III-C.7) — shared by both engines (stateful pair memory)
# ----------------------------------------------------------------------

@dataclass
class FloodRoundState:
    """Typed per-round flooding memory, owned by the policy instance.

    ``sent`` maps the directed pair (sender, receiver) to the set of
    chunk ids already pushed over it; flooding never repeats a push, but
    receivers may already hold the chunk (wasted bandwidth), which is
    exactly why flooding under-performs coordinated warm-up (§III-C).
    """

    sent: dict = field(default_factory=dict)   # (u, v) -> set[int]

    def seen(self, u: int, v: int) -> set:
        return self.sent.setdefault((u, v), set())


def schedule_flooding(state: SwarmState, sent_pairs: dict):
    """Push random eligible chunks to random neighbors, no repetition.

    ``sent_pairs`` is the :class:`FloodRoundState` pair memory (legacy
    callers may still pass the raw dict it wraps).
    """
    if isinstance(sent_pairs, FloodRoundState):
        sent_pairs = sent_pairs.sent
    cfg = state.cfg
    rng = state.rng
    n = cfg.n
    sactive = state.senders_active()
    rem_down = np.where(state.active, state.down, 0).astype(np.int64)

    out_s, out_r, out_c = [], [], []
    for u in np.flatnonzero(sactive):
        u = int(u)
        budget = int(state.up[u])
        elig = np.flatnonzero(state.eligible_row(u))
        nbr_idx = np.flatnonzero(state.adj[u] & state.active)
        if elig.size == 0 or nbr_idx.size == 0:
            continue
        tgts = rng.choice(nbr_idx, size=budget, replace=True)
        picks = rng.choice(elig, size=budget, replace=True)
        for t, c in zip(tgts, picks):
            key = (u, int(t))
            seen = sent_pairs.setdefault(key, set())
            if int(c) in seen or rem_down[t] <= 0:
                continue
            seen.add(int(c))
            rem_down[t] -= 1
            out_s.append(u)
            out_r.append(int(t))
            out_c.append(int(c))
    if not out_s:
        return _empty()
    return (np.asarray(out_s, np.int64), np.asarray(out_r, np.int64),
            np.asarray(out_c, np.int64))


# ----------------------------------------------------------------------
# Policy classes: the §III-C family on the SchedulerPolicy protocol.
# Both slot engines stay interchangeable backends behind each policy
# (``SwarmConfig.scheduler_impl``); schedules are byte-identical to the
# historical string dispatch (tests/golden_schedules.json).
# ----------------------------------------------------------------------

CENTRALIZED = {"random_fifo", "random_fastest_first", "greedy_fastest_first"}


def _impl(state: SwarmState) -> str:
    impl = getattr(state.cfg, "scheduler_impl", "batched")
    if impl not in ("batched", "loop", "jit"):
        raise ValueError(f"unknown scheduler_impl {impl!r}")
    return impl


class CentralizedPolicy(SchedulerPolicy):
    """Tracker-assigned modes (§III-C.3-5): full supply-matrix view."""

    visibility = VISIBILITY_FULL
    mode: str = ""

    def schedule(self, view: SlotView):
        state = view._engine_state()
        impl = _impl(state)
        if impl == "loop":
            return _schedule_centralized_loop(state, self.mode)
        if impl == "jit":
            from .jit_engine import schedule_centralized_jit
            return schedule_centralized_jit(state, self.mode)
        return _schedule_centralized_batched(state, self.mode)


@register_policy
class RandomFIFOPolicy(CentralizedPolicy):
    """§III-C.3: random feasible sender, random receiver order."""

    name = mode = "random_fifo"


@register_policy
class RandomFastestFirstPolicy(CentralizedPolicy):
    """§III-C.4: senders prioritize the fastest requesters."""

    name = mode = "random_fastest_first"


@register_policy
class GreedyFastestFirstPolicy(CentralizedPolicy):
    """§III-C.5: each request to the fastest feasible sender (paper
    default)."""

    name = mode = "greedy_fastest_first"


@register_policy
class VanillaBTPolicy(CentralizedPolicy):
    """Vanilla BitTorrent swarming slot (§III-A step 4): ungated
    rarest-first with random feasible senders — the BT-phase backend
    behind :func:`repro.core.bittorrent.bt_exact_slot`."""

    name = "bt_vanilla"
    mode = "random_fifo"
    phases = ("bt",)


@register_policy
class DistributedPolicy(SchedulerPolicy):
    """§III-C.6: clients see only the neighborhood availability union
    C^T A(v); requests target random neighbors and may miss."""

    name = "distributed"
    visibility = VISIBILITY_NEIGHBORHOOD

    def schedule(self, view: SlotView):
        state = view._engine_state()
        # "jit" routes to the batched backend here: the distributed
        # mode's hot path is already one-shot vectorized (no budgeted
        # round loop to stage), so a separate kernel would buy nothing.
        if _impl(state) == "loop":
            return _schedule_distributed_loop(state)
        return _schedule_distributed_batched(state)


@register_policy
class FloodingPolicy(SchedulerPolicy):
    """§III-C.7: random push without receiver state; the per-round pair
    memory is typed policy-owned state, reset every round."""

    name = "flooding"
    visibility = VISIBILITY_NONE

    def __init__(self):
        self.round_state = FloodRoundState()

    def reset(self, cfg) -> None:
        self.round_state = FloodRoundState()

    def schedule(self, view: SlotView):
        return schedule_flooding(view._engine_state(), self.round_state)


# ----------------------------------------------------------------------
# Legacy entry points (pre-policy API), kept for external callers
# ----------------------------------------------------------------------

def schedule_centralized(state: SwarmState, mode: str):
    impl = _impl(state)
    if impl == "loop":
        return _schedule_centralized_loop(state, mode)
    if impl == "jit":
        from .jit_engine import schedule_centralized_jit
        return schedule_centralized_jit(state, mode)
    return _schedule_centralized_batched(state, mode)


def schedule_distributed(state: SwarmState):
    if _impl(state) == "loop":
        return _schedule_distributed_loop(state)
    return _schedule_distributed_batched(state)


def run_scheduler(state: SwarmState, flood_state: dict | None = None):
    """One slot of ``state.cfg.scheduler`` via the policy registry.

    Shim for the historical string-dispatch signature: resolves the
    configured policy and schedules a single slot.  ``flood_state`` (a
    raw pair-memory dict) is honored for flooding so old callers keep
    their cross-slot no-repeat semantics; policy-native callers use
    :class:`FloodingPolicy`'s own round state instead.
    """
    pol = get_policy(state.cfg.scheduler)
    if isinstance(pol, FloodingPolicy):
        # The shim builds a fresh policy per call, so a legacy caller
        # MUST thread the pair memory or the cross-slot no-repeat
        # invariant silently breaks (the historical contract).
        assert flood_state is not None, \
            "flooding via run_scheduler() needs a caller-held flood_state"
        pol.round_state = FloodRoundState(sent=flood_state)
    return pol.schedule(SlotView(state, pol.visibility))
