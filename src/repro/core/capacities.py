"""Access-link capacity models (paper §V-A, §V-E).

The paper samples heterogeneous uplink/downlink capacities from European
residential broadband statistics: uplink 15.5-25.3 Mbps and downlink
36.5-121 Mbps, i.e. roughly [7, 12] and [18, 60] chunks/s for 256 KiB
chunks.  LLM-scale stress tests instead use datacenter-class 7-10 Gbps
links (§V-E).

Two time domains consume these rates:

* the **slot engines** quantize to integer chunks/slot
  (:func:`quantize_rates`, paper §II-B: ``u_v = floor(U_v Δ / C)``) —
  the historical path;
* the **event engine** (:mod:`repro.net`) takes the raw bytes/s and
  never quantizes — transfer times are real-valued.

The slot-path ``max(1, floor(...))`` clamp guarantees liveness (a
zero-budget client could never finish a round), but when it binds it
silently *inflates* a slow uplink to a full chunk per slot — at small
``slot_seconds`` that can overstate slow-link throughput by orders of
magnitude.  :func:`quantize_rates` therefore warns when the clamp
binds; the event engine is the honest alternative.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass

import numpy as np

MBPS = 1e6 / 8.0          # bytes/s per Mbps
GBPS = 1e9 / 8.0          # bytes/s per Gbps


def quantize_rates(
    up: np.ndarray,
    down: np.ndarray,
    chunk_bytes: int,
    slot_seconds: float,
    *,
    warn: bool = True,
) -> tuple[np.ndarray, np.ndarray]:
    """Raw bytes/s -> integer chunks/slot budgets (paper §II-B).

    The ``max(1, ...)`` liveness clamp is kept, but when it binds (some
    link moves less than one chunk per slot) the quantization is no
    longer faithful to the sampled rate — the slot engine will credit
    the link with up to ``chunk_bytes / (rate * slot_seconds)`` times
    its real throughput.  A ``RuntimeWarning`` flags it; runs that need
    honest slow links should use ``RoundSimulator(time_engine="event")``
    which consumes the raw rates.
    """
    uf = np.floor(np.asarray(up) * slot_seconds / chunk_bytes)
    df = np.floor(np.asarray(down) * slot_seconds / chunk_bytes)
    if warn:
        n_bind = int((uf < 1).sum() + (df < 1).sum())
        if n_bind:
            warnings.warn(
                f"chunks-per-slot clamp binds on {n_bind} link(s): "
                f"rate * slot_seconds < chunk_bytes, so the slot "
                f"engine inflates them to 1 chunk/slot; use "
                f"time_engine='event' (repro.net) for honest "
                f"slow-link timing",
                RuntimeWarning, stacklevel=2)
    u = np.maximum(1, uf).astype(np.int64)
    d = np.maximum(1, df).astype(np.int64)
    return u, d


@dataclass(frozen=True)
class LinkModel:
    """Uniform ranges for per-client up/down capacities in bytes/s."""

    up_lo: float
    up_hi: float
    down_lo: float
    down_hi: float

    def sample_rates(
        self,
        n: int,
        rng: np.random.Generator,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Per-client (uplink, downlink) raw rates in bytes/s.

        Draw order (all uplinks, then all downlinks) is part of the
        reproducibility contract: :meth:`sample_chunks_per_slot` is a
        quantizing wrapper over the same stream, so a slot run and an
        event run at the same seed see the same physical links.
        """
        up = rng.uniform(self.up_lo, self.up_hi, size=n)
        down = rng.uniform(self.down_lo, self.down_hi, size=n)
        return up, down

    def sample_chunks_per_slot(
        self,
        n: int,
        chunk_bytes: int,
        slot_seconds: float,
        rng: np.random.Generator,
        *,
        warn: bool = True,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Per-client (uplink, downlink) budgets in chunks/slot (§II-B)."""
        up, down = self.sample_rates(n, rng)
        return quantize_rates(up, down, chunk_bytes, slot_seconds,
                              warn=warn)


@dataclass(frozen=True)
class StragglerLinkModel(LinkModel):
    """A :class:`LinkModel` with a slow-link straggler cohort.

    A ``straggler_frac`` Bernoulli subset of clients gets its uplink
    divided by ``up_slowdown`` (and downlink by ``down_slowdown``) —
    the bursty-residential regime where a few peers seed their update
    orders of magnitude slower than the swarm disseminates everyone
    else's.  Under synchronous deadlines these peers gate every round;
    under the async runner (fl/asyncfl.py) they deliver late and are
    down-weighted instead.

    Draw-order contract: the base draws come FIRST (identical to the
    parent model at the same seed), the straggler coin flips AFTER — so
    swapping a model for its straggler variant perturbs no downstream
    stream, and the non-straggler cohort keeps its exact base rates.
    """

    straggler_frac: float = 0.25
    up_slowdown: float = 8.0
    down_slowdown: float = 1.0

    def sample_rates(
        self,
        n: int,
        rng: np.random.Generator,
    ) -> tuple[np.ndarray, np.ndarray]:
        up, down = super().sample_rates(n, rng)
        slow = rng.random(n) < self.straggler_frac
        return (np.where(slow, up / self.up_slowdown, up),
                np.where(slow, down / self.down_slowdown, down))


# Paper defaults -------------------------------------------------------
RESIDENTIAL = LinkModel(
    up_lo=15.5 * MBPS, up_hi=25.3 * MBPS,
    down_lo=36.5 * MBPS, down_hi=121.0 * MBPS,
)

# Straggler-heavy residential: a quarter of the peers seed at 1/8 the
# uplink (asymmetric — upload is the scarce residential direction).
# The regime the async frontier (benchmarks/bench_async.py) measures.
RESIDENTIAL_STRAGGLER = StragglerLinkModel(
    up_lo=15.5 * MBPS, up_hi=25.3 * MBPS,
    down_lo=36.5 * MBPS, down_hi=121.0 * MBPS,
    straggler_frac=0.25, up_slowdown=8.0,
)

DATACENTER = LinkModel(      # LLM-scale stress tests (§V-E): 7-10 Gbps
    up_lo=7.0 * GBPS, up_hi=10.0 * GBPS,
    down_lo=7.0 * GBPS, down_hi=10.0 * GBPS,
)
