"""Access-link capacity models (paper §V-A, §V-E).

The paper samples heterogeneous uplink/downlink capacities from European
residential broadband statistics: uplink 15.5-25.3 Mbps and downlink
36.5-121 Mbps, i.e. roughly [7, 12] and [18, 60] chunks/s for 256 KiB
chunks.  LLM-scale stress tests instead use datacenter-class 7-10 Gbps
links (§V-E).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

MBPS = 1e6 / 8.0          # bytes/s per Mbps
GBPS = 1e9 / 8.0          # bytes/s per Gbps


@dataclass(frozen=True)
class LinkModel:
    """Uniform ranges for per-client up/down capacities in bytes/s."""

    up_lo: float
    up_hi: float
    down_lo: float
    down_hi: float

    def sample_chunks_per_slot(
        self,
        n: int,
        chunk_bytes: int,
        slot_seconds: float,
        rng: np.random.Generator,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Per-client (uplink, downlink) budgets in chunks/slot (§II-B)."""
        up = rng.uniform(self.up_lo, self.up_hi, size=n)
        down = rng.uniform(self.down_lo, self.down_hi, size=n)
        u = np.maximum(1, np.floor(up * slot_seconds / chunk_bytes)).astype(np.int64)
        d = np.maximum(1, np.floor(down * slot_seconds / chunk_bytes)).astype(np.int64)
        return u, d


# Paper defaults -------------------------------------------------------
RESIDENTIAL = LinkModel(
    up_lo=15.5 * MBPS, up_hi=25.3 * MBPS,
    down_lo=36.5 * MBPS, down_hi=121.0 * MBPS,
)

DATACENTER = LinkModel(      # LLM-scale stress tests (§V-E): 7-10 Gbps
    up_lo=7.0 * GBPS, up_hi=10.0 * GBPS,
    down_lo=7.0 * GBPS, down_hi=10.0 * GBPS,
)
