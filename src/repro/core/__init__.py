"""FLTorrent core — the paper's contribution.

Public API:

* ``SwarmConfig`` / ``simulate_round`` — one privacy-hardened
  dissemination round (spray -> warm-up -> BitTorrent -> deadline).
* ``SwarmSession`` / ``ChurnModel`` — the persistent multi-round swarm:
  cross-round churn (leave/join/rejoin at round boundaries), evolving
  overlay with incremental edge repair, capacity persistence (§III-E).
* ``schedulers`` — RandomFIFO / RandomFastestFirst / GreedyFastestFirst /
  distributed / flooding (+ max-flow stage upper bound).
* ``privacy`` — Eq. (1)-(5) unlinkability bounds + empirical checks.
* ``attacks`` — Sequential/Amount Greedy + Clustering, ASR metrics.
* ``aggregation`` — FedAvg over the reconstructable active set.
* ``chunking`` — update <-> chunks + torrent descriptors.
* ``audit`` — commit-then-reveal tracker accountability.
"""
from . import (aggregation, attacks, audit, bittorrent, byzantine,
               capacities, chunking, maxflow, overlay, privacy,
               schedulers, session, simulator, state, types)
from .session import ChurnModel, SessionRound, SwarmSession
from .simulator import RoundResult, RoundSimulator, simulate_round
from .types import RoundMetrics, SwarmConfig

__all__ = [
    "SwarmConfig", "RoundMetrics", "RoundSimulator", "RoundResult",
    "SwarmSession", "ChurnModel", "SessionRound",
    "simulate_round", "aggregation", "attacks", "audit", "bittorrent",
    "byzantine", "capacities", "chunking", "maxflow", "overlay",
    "privacy", "schedulers", "session", "simulator", "state", "types",
]
