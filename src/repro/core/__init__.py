"""FLTorrent core — the paper's contribution.

Public API:

* ``SwarmConfig`` / ``simulate_round`` — one privacy-hardened
  dissemination round (spray -> warm-up -> BitTorrent -> deadline),
  on either time engine: the synchronous slot clock or the
  continuous-time event transport of :mod:`repro.net`
  (``simulate_round(cfg, time_engine="event")`` — wall-clock seconds,
  fair-share flows, per-transfer ``t_start``/``t_end``).
* ``SwarmSession`` / ``ChurnModel`` — the persistent multi-round swarm:
  cross-round churn (leave/join/rejoin at round boundaries), evolving
  overlay with incremental edge repair, capacity persistence (§III-E).
* ``policy`` — the SchedulerPolicy plugin API: SlotView (visibility-
  scoped per-slot observation), register_policy/get_policy registry;
  ``SwarmConfig.scheduler`` accepts a name or an instance.
* ``schedulers`` — the §III-C family (RandomFIFO / RandomFastestFirst /
  GreedyFastestFirst / distributed / flooding + vanilla-BT) as
  registered policies over two interchangeable slot engines
  (+ max-flow stage upper bound).
* ``trace`` — typed columnar TransferTrace: the observation contract
  consumed by attacks/privacy/audit (round/phase slicing, observer
  masking, cross-round concatenation via ``SwarmSession.trace()``).
* ``privacy`` — Eq. (1)-(5) unlinkability bounds + empirical checks.
* ``attacks`` — vectorized Sequential/Amount Greedy + Clustering, the
  cross-round persistent-neighbor linkage adversary, and the timing
  side-channel attribution over event-engine traces; ASR metrics.
* ``aggregation`` — FedAvg over the reconstructable active set.
* ``chunking`` — update <-> chunks + torrent descriptors.
* ``audit`` — commit-then-reveal tracker accountability.
"""
from . import (aggregation, attacks, audit, bittorrent, byzantine,
               capacities, chunking, maxflow, overlay, policy, privacy,
               schedulers, session, simulator, state, trace, types)
from .policy import (SchedulerPolicy, SlotView, VisibilityError,
                     get_policy, policy_names, register_policy)
from .session import (ChurnModel, ChurnAwareSpray, SessionRound,
                      SprayPlan, SwarmSession)
from .simulator import RoundResult, RoundSimulator, simulate_round
from .trace import TransferTrace
from .types import RoundMetrics, SwarmConfig

__all__ = [
    "SwarmConfig", "RoundMetrics", "RoundSimulator", "RoundResult",
    "SwarmSession", "ChurnModel", "SessionRound",
    "SchedulerPolicy", "SlotView", "VisibilityError", "get_policy",
    "policy_names", "register_policy", "TransferTrace",
    "ChurnAwareSpray", "SprayPlan",
    "simulate_round", "aggregation", "attacks", "audit", "bittorrent",
    "byzantine", "capacities", "chunking", "maxflow", "overlay",
    "policy", "privacy", "schedulers", "session", "simulator", "state",
    "trace", "types",
]
