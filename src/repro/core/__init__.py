"""FLTorrent core — the paper's contribution.

Public API:

* ``SwarmConfig`` / ``simulate_round`` — one privacy-hardened
  dissemination round (spray -> warm-up -> BitTorrent -> deadline).
* ``schedulers`` — RandomFIFO / RandomFastestFirst / GreedyFastestFirst /
  distributed / flooding (+ max-flow stage upper bound).
* ``privacy`` — Eq. (1)-(5) unlinkability bounds + empirical checks.
* ``attacks`` — Sequential/Amount Greedy + Clustering, ASR metrics.
* ``aggregation`` — FedAvg over the reconstructable active set.
* ``chunking`` — update <-> chunks + torrent descriptors.
* ``audit`` — commit-then-reveal tracker accountability.
"""
from . import (aggregation, attacks, audit, bittorrent, byzantine,
               capacities, chunking, maxflow, overlay, privacy,
               schedulers, simulator, state, types)
from .simulator import RoundResult, RoundSimulator, simulate_round
from .types import RoundMetrics, SwarmConfig

__all__ = [
    "SwarmConfig", "RoundMetrics", "RoundSimulator", "RoundResult",
    "simulate_round", "aggregation", "attacks", "audit", "bittorrent",
    "byzantine", "capacities", "chunking", "maxflow", "overlay",
    "privacy", "schedulers", "simulator", "state", "types",
]
