"""Mutable swarm state for one FLTorrent round (paper §II-B, §IV-A).

Holds the per-client chunk inventories, link budgets, activity masks and
the transfer event log.  Encodes the two warm-up enforcement knobs from
§IV-A exactly:

* **cover-set gating** — an honest sender's owner chunks become eligible
  for upload only once its eligible buffer would reach ``k_gate``
  (equivalently: non-owner mass ``X_u >= k_gate - kappa``), and
* **owner throttling** — at any instant at most ``kappa`` owner chunks
  are eligible (``O_u <= kappa_u``), rotated over slots so every owner
  chunk can eventually circulate.

With both in force, every warm-up transfer from an honest sender has
per-transfer attribution posterior ``O_u / B_u <= kappa / k_gate``
(Eq. 1) — asserted empirically in tests/test_privacy_bounds.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .types import SwarmConfig


@dataclass
class TransferLog:
    """Struct-of-arrays event log; grown per-slot, finalized once."""

    slots: list = field(default_factory=list)
    senders: list = field(default_factory=list)
    receivers: list = field(default_factory=list)
    chunks: list = field(default_factory=list)
    b_sizes: list = field(default_factory=list)   # B_u at send time
    o_sizes: list = field(default_factory=list)   # O_u at send time
    phases: list = field(default_factory=list)    # 0=spray 1=warmup 2=bt

    def append(self, slot, snd, rcv, chk, b, o, phase):
        if len(snd) == 0:
            return
        self.slots.append(np.full(len(snd), slot, dtype=np.int32))
        self.senders.append(np.asarray(snd, dtype=np.int32))
        self.receivers.append(np.asarray(rcv, dtype=np.int32))
        self.chunks.append(np.asarray(chk, dtype=np.int64))
        self.b_sizes.append(np.asarray(b, dtype=np.int64))
        self.o_sizes.append(np.asarray(o, dtype=np.int64))
        self.phases.append(np.full(len(snd), phase, dtype=np.int8))

    def finalize(self, chunks_per_update: int) -> dict:
        if not self.slots:
            empty = np.zeros(0, dtype=np.int64)
            return {k: empty for k in
                    ("slot", "sender", "receiver", "chunk", "owner",
                     "b_size", "o_size", "phase")}
        out = {
            "slot": np.concatenate(self.slots),
            "sender": np.concatenate(self.senders),
            "receiver": np.concatenate(self.receivers),
            "chunk": np.concatenate(self.chunks),
            "b_size": np.concatenate(self.b_sizes),
            "o_size": np.concatenate(self.o_sizes),
            "phase": np.concatenate(self.phases),
        }
        out["owner"] = out["chunk"] // chunks_per_update
        return out


class SwarmState:
    """Vectorized round state: inventories, budgets, eligibility."""

    def __init__(self, cfg: SwarmConfig, adj: np.ndarray,
                 up: np.ndarray, down: np.ndarray,
                 rng: np.random.Generator):
        n, K = cfg.n, cfg.chunks_per_update
        self.cfg = cfg
        self.adj = adj
        self.up = up.astype(np.int64)
        self.down = down.astype(np.int64)
        self.rng = rng

        C = cfg.total_chunks
        self.have = np.zeros((n, C), dtype=bool)
        for v in range(n):
            self.have[v, v * K:(v + 1) * K] = True
        # Per-chunk replication count (rarity), maintained incrementally.
        self.replicas = np.ones(C, dtype=np.int64)
        # Non-owner chunks held per client (X_u in §IV-A).
        self.nonowner = np.zeros(n, dtype=np.int64)
        # Total chunks held per client, maintained incrementally.
        self.hold = np.full(n, K, dtype=np.int64)

        self.active = np.ones(n, dtype=bool)
        if cfg.enable_timelag and cfg.lag_slots > 1:
            self.lag = rng.integers(0, cfg.lag_slots, size=n)
        else:
            self.lag = np.zeros(n, dtype=np.int64)

        self.slot = 0
        self.phase = "warmup"
        self.any_nonowner = False      # swarm-wide non-owner mass exists
        self.log = TransferLog()
        self.warmup_sent = 0
        self.bt_sent = 0
        self.per_slot_sent: list[int] = []
        self.owners = np.arange(C, dtype=np.int64) // K

    # -- activity ------------------------------------------------------
    def senders_active(self) -> np.ndarray:
        """Clients allowed to *initiate* transmissions this slot (lags)."""
        return self.active & (self.lag <= self.slot)

    # -- eligibility (paper §IV-A) --------------------------------------
    def eligible_owner_slice(self, u: int) -> np.ndarray:
        """Global chunk ids of u's currently eligible owner chunks.

        Cover-set gating (§IV-A): owner chunks unlock once the eligible
        buffer reaches ``k_gate``.  Bootstrap exception: when the swarm
        holds zero non-owner mass anywhere (K-only ablation, no spray),
        the throttled window is permitted — someone must seed the first
        copies, exactly the owner-revealing sends pre-round obfuscation
        exists to remove (Fig. 4/6).
        """
        cfg = self.cfg
        K = cfg.chunks_per_update
        if self.phase == "bt" or not cfg.enable_gating:
            return np.arange(u * K, (u + 1) * K)
        kappa = cfg.owner_throttle
        if self.nonowner[u] + kappa < cfg.k_gate and self.any_nonowner:
            return np.zeros(0, dtype=np.int64)  # gated: buffer too small
        # Per-sender de-synchronized rotation: a shared phase would make
        # every sender expose the SAME chunk index each slot, destroying
        # early chunk diversity (visible as a longer BT phase in Fig. 4).
        start = (self.slot * kappa + (u * 2654435761) % K) % K
        idx = (start + np.arange(kappa)) % K
        return u * K + idx

    def eligible_row(self, u: int) -> np.ndarray:
        """Bool mask over all chunks that u may serve right now."""
        row = self.have[u].copy()
        K = self.cfg.chunks_per_update
        if self.phase != "bt" and self.cfg.enable_gating:
            row[u * K:(u + 1) * K] = False
            row[self.eligible_owner_slice(u)] = True
        return row

    def buffer_stats(self, u: int) -> tuple[int, int]:
        """(B_u, O_u): eligible buffer size and eligible owner count."""
        K = self.cfg.chunks_per_update
        if self.phase == "bt" or not self.cfg.enable_gating:
            return int(self.have[u].sum()), K
        o = len(self.eligible_owner_slice(u))
        return int(self.nonowner[u]) + o, o

    # -- transfer application -------------------------------------------
    def apply_transfers(self, snd: np.ndarray, rcv: np.ndarray,
                        chk: np.ndarray, phase_code: int):
        """Mark chunks delivered; update rarity, X_u and the event log."""
        if len(snd) == 0:
            self.per_slot_sent.append(0)
            return
        snd = np.asarray(snd)
        rcv = np.asarray(rcv)
        chk = np.asarray(chk)
        # De-dup (receiver, chunk) within the slot (schedulers should
        # already avoid this, but enforce delivery-exactly-once).
        order = np.lexsort((chk, rcv))
        snd, rcv, chk = snd[order], rcv[order], chk[order]
        keep = np.ones(len(snd), dtype=bool)
        keep[1:] = ~((rcv[1:] == rcv[:-1]) & (chk[1:] == chk[:-1]))
        already = self.have[rcv, chk]
        keep &= ~already
        snd, rcv, chk = snd[keep], rcv[keep], chk[keep]

        b = np.empty(len(snd), dtype=np.int64)
        o = np.empty(len(snd), dtype=np.int64)
        if len(snd):
            uniq = np.unique(snd)
            bs = {int(u): self.buffer_stats(int(u)) for u in uniq}
            for i, u in enumerate(snd):
                b[i], o[i] = bs[int(u)]

        self.have[rcv, chk] = True
        np.add.at(self.replicas, chk, 1)
        np.add.at(self.hold, rcv, 1)
        owner_mask = self.owners[chk] != rcv
        np.add.at(self.nonowner, rcv[owner_mask], 1)
        if owner_mask.any():
            self.any_nonowner = True

        self.log.append(self.slot, snd, rcv, chk, b, o, phase_code)
        cnt = len(snd)
        self.per_slot_sent.append(cnt)
        if phase_code == 1:
            self.warmup_sent += cnt
        elif phase_code == 2:
            self.bt_sent += cnt

    # -- progress queries -------------------------------------------------
    def holdings(self) -> np.ndarray:
        return self.hold.copy()

    def warmup_done(self) -> bool:
        """s_BT condition: every *active* client holds >= k_term chunks."""
        if not self.active.any():
            return True
        return bool((self.hold[self.active] >= self.cfg.k_term).all())

    def all_done(self) -> bool:
        if not self.active.any():
            return True
        return bool((self.hold[self.active] >= self.cfg.total_chunks).all())

    def reconstructable_sets(self) -> np.ndarray:
        """A_v^r as a bool matrix (n_clients, n_updates) at current slot."""
        n, K = self.cfg.n, self.cfg.chunks_per_update
        per_update = self.have.reshape(n, n, K)
        return per_update.all(axis=2)
