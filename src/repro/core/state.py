"""Mutable swarm state for one FLTorrent round (paper §II-B, §IV-A).

Holds the per-client chunk inventories, link budgets, activity masks and
the transfer event log.  Encodes the two warm-up enforcement knobs from
§IV-A exactly:

* **cover-set gating** — an honest sender's owner chunks become eligible
  for upload only once its eligible buffer would reach ``k_gate``
  (equivalently: non-owner mass ``X_u >= k_gate - kappa``), and
* **owner throttling** — at any instant at most ``kappa`` owner chunks
  are eligible (``O_u <= kappa_u``), rotated over slots so every owner
  chunk can eventually circulate.

With both in force, every warm-up transfer from an honest sender has
per-transfer attribution posterior ``O_u / B_u <= kappa / k_gate``
(Eq. 1) — asserted empirically in tests/test_privacy_bounds.py.
"""
from __future__ import annotations

import ctypes
import sys
from dataclasses import dataclass, field

import numpy as np

from .trace import TransferTrace
from .types import SwarmConfig

_MADV_HUGEPAGE = 14          # asm-generic/mman-common.h
_HUGE_2M = 2 * 1024 * 1024


def hint_hugepages(arr: np.ndarray) -> bool:
    """Best-effort ``madvise(MADV_HUGEPAGE)`` over ``arr``'s 2 MiB-aligned
    interior.  With THP in ``madvise`` mode (the common server default)
    this collapses first-touch faulting of a multi-GB mapping from one
    4 KiB fault per page to one per 2 MiB — the difference between a
    ~30 s and a ~3 s inventory fill at n=5000 (BENCH_scheduler.json
    ``setup_s``).  Returns False (harmless no-op) off Linux, on small
    arrays, or when the kernel refuses the hint."""
    if not sys.platform.startswith("linux") or arr.nbytes < _HUGE_2M:
        return False
    try:
        libc = ctypes.CDLL(None, use_errno=True)
        addr = arr.ctypes.data
        start = (addr + _HUGE_2M - 1) & ~(_HUGE_2M - 1)
        end = (addr + arr.nbytes) & ~(_HUGE_2M - 1)
        if end <= start:
            return False
        return libc.madvise(ctypes.c_void_p(start),
                            ctypes.c_size_t(end - start),
                            _MADV_HUGEPAGE) == 0
    except Exception:  # pragma: no cover - exotic libc
        return False


@dataclass
class TransferLog:
    """Struct-of-arrays event log; grown per-slot, finalized once."""

    slots: list = field(default_factory=list)
    senders: list = field(default_factory=list)
    receivers: list = field(default_factory=list)
    chunks: list = field(default_factory=list)
    b_sizes: list = field(default_factory=list)   # B_u at send time
    o_sizes: list = field(default_factory=list)   # O_u at send time
    phases: list = field(default_factory=list)    # 0=spray 1=warmup 2=bt
    t_starts: list = field(default_factory=list)  # wall-clock (event eng.)
    t_ends: list = field(default_factory=list)

    def append(self, slot, snd, rcv, chk, b, o, phase,
               t_start=None, t_end=None):
        if len(snd) == 0:
            return
        self.slots.append(np.full(len(snd), slot, dtype=np.int32))
        self.senders.append(np.asarray(snd, dtype=np.int32))
        self.receivers.append(np.asarray(rcv, dtype=np.int32))
        self.chunks.append(np.asarray(chk, dtype=np.int64))
        self.b_sizes.append(np.asarray(b, dtype=np.int64))
        self.o_sizes.append(np.asarray(o, dtype=np.int64))
        self.phases.append(np.full(len(snd), phase, dtype=np.int8))
        if t_start is not None:
            self.t_starts.append(np.asarray(t_start, dtype=np.float64))
            self.t_ends.append(np.asarray(t_end, dtype=np.float64))

    def finalize(self, chunks_per_update: int,
                 slot_seconds: float = 1.0) -> TransferTrace:
        """Concatenate the per-slot pieces into one typed trace.

        Wall-clock columns: when the event engine stamped every batch,
        its real-valued instants are used; otherwise (slot engines) the
        trace carries slot-boundary stamps in seconds.  Mixing is a
        caller error — one round runs on exactly one time engine.
        """
        if not self.slots:
            return TransferTrace(K=chunks_per_update)
        chunk = np.concatenate(self.chunks)
        times = {}
        if self.t_starts:
            if len(self.t_starts) != len(self.slots):
                raise ValueError(
                    "wall-clock stamps cover only part of the log: "
                    f"{len(self.t_starts)} of {len(self.slots)} batches")
            times = {"t_start": np.concatenate(self.t_starts),
                     "t_end": np.concatenate(self.t_ends)}
        return TransferTrace.from_arrays(
            K=chunks_per_update,
            slot_seconds=slot_seconds,
            slot=np.concatenate(self.slots),
            sender=np.concatenate(self.senders),
            receiver=np.concatenate(self.receivers),
            chunk=chunk,
            owner=(chunk // chunks_per_update).astype(np.int32),
            b_size=np.concatenate(self.b_sizes),
            o_size=np.concatenate(self.o_sizes),
            phase=np.concatenate(self.phases),
            **times,
        )


class SwarmState:
    """Vectorized round state: inventories, budgets, eligibility."""

    def __init__(self, cfg: SwarmConfig, adj: np.ndarray,
                 up: np.ndarray, down: np.ndarray,
                 rng: np.random.Generator):
        n, K = cfg.n, cfg.chunks_per_update
        self.cfg = cfg
        self.adj = adj
        self.up = up.astype(np.int64)
        self.down = down.astype(np.int64)
        self.rng = rng

        C = cfg.total_chunks
        # calloc'd zero pages + a transparent-huge-page hint: with 2 MiB
        # mappings, eagerly faulting the inventory in sequentially costs
        # ~0.5 GB/s-of-zeroing instead of one 4 KiB fault per page (a
        # 10x setup_s cut at n=5000 — BENCH_scheduler.json).  Without
        # the hint (non-Linux / THP disabled) skip the eager fill: lazy
        # zero pages spread the fault cost over apply_transfers writes,
        # which beats an up-front 4 KiB-page fill by ~5x.
        self.have = np.zeros((n, C), dtype=bool)
        if hint_hugepages(self.have):
            flat = self.have.reshape(-1)
            flat[: flat.size - flat.size % 8].view(np.uint64).fill(0)
        self.have[np.repeat(np.arange(n), K),
                  np.arange(n * K, dtype=np.int64)] = True
        # Log-replay invariant marker (see jit_engine._sync_have_dev):
        # after construction, apply_transfers is the only writer of
        # *this* array; schedulers seeing a different object (Byzantine
        # claimed inventories) must repack from scratch.
        self._have_pristine = self.have
        # Per-chunk replication count (rarity), maintained incrementally.
        self.replicas = np.ones(C, dtype=np.int64)
        # Non-owner chunks held per client (X_u in §IV-A).
        self.nonowner = np.zeros(n, dtype=np.int64)
        # Total chunks held per client, maintained incrementally.
        self.hold = np.full(n, K, dtype=np.int64)

        self.active = np.ones(n, dtype=bool)
        if cfg.enable_timelag and cfg.lag_slots > 1:
            self.lag = rng.integers(0, cfg.lag_slots, size=n)
        else:
            self.lag = np.zeros(n, dtype=np.int64)

        self.slot = 0
        self.phase = "warmup"
        self.any_nonowner = False      # swarm-wide non-owner mass exists
        self._win_cache: tuple | None = None   # per-slot owner windows
        self.log = TransferLog()
        self.warmup_sent = 0
        self.bt_sent = 0
        self.per_slot_sent: list[int] = []
        self.owners = np.arange(C, dtype=np.int64) // K

    # -- activity ------------------------------------------------------
    def senders_active(self) -> np.ndarray:
        """Clients allowed to *initiate* transmissions this slot (lags)."""
        return self.active & (self.lag <= self.slot)

    # -- eligibility (paper §IV-A) --------------------------------------
    def eligible_owner_slice(self, u: int) -> np.ndarray:
        """Global chunk ids of u's currently eligible owner chunks.

        Cover-set gating (§IV-A): owner chunks unlock once the eligible
        buffer reaches ``k_gate``.  Bootstrap exception: when the swarm
        holds zero non-owner mass anywhere (K-only ablation, no spray),
        the throttled window is permitted — someone must seed the first
        copies, exactly the owner-revealing sends pre-round obfuscation
        exists to remove (Fig. 4/6).
        """
        cfg = self.cfg
        K = cfg.chunks_per_update
        if self.phase == "bt" or not cfg.enable_gating:
            return np.arange(u * K, (u + 1) * K)
        kappa = cfg.owner_throttle
        if self.nonowner[u] + kappa < cfg.k_gate and self.any_nonowner:
            return np.zeros(0, dtype=np.int64)  # gated: buffer too small
        # Per-sender de-synchronized rotation: a shared phase would make
        # every sender expose the SAME chunk index each slot, destroying
        # early chunk diversity (visible as a longer BT phase in Fig. 4).
        start = (self.slot * kappa + (u * 2654435761) % K) % K
        idx = (start + np.arange(kappa)) % K
        return u * K + idx

    def owner_windows(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Vectorized per-sender owner windows for the current slot.

        Returns ``(ids, starts, gated)``: ``ids`` is (n, kappa) global
        chunk ids of every sender's throttled rotation window, ``starts``
        the (n,) within-update offsets, and ``gated`` the (n,) mask of
        senders whose cover-set gate is closed (empty eligible window).
        Mirrors :meth:`eligible_owner_slice` for all senders at once so
        the batched slot engine never loops over clients; cached per
        (slot, phase) — ``nonowner`` only changes after transfers apply.
        """
        cfg = self.cfg
        K = cfg.chunks_per_update
        kappa = cfg.owner_throttle
        key = (self.slot, self.phase)
        if self._win_cache is not None and self._win_cache[0] == key:
            return self._win_cache[1:]
        u = np.arange(cfg.n, dtype=np.int64)
        starts = (self.slot * kappa + (u * 2654435761) % K) % K
        idx = (starts[:, None] + np.arange(kappa, dtype=np.int64)) % K
        ids = u[:, None] * K + idx
        gated = (self.nonowner + kappa < cfg.k_gate) & self.any_nonowner
        self._win_cache = (key, ids, starts, gated)
        return ids, starts, gated

    def eligible_supply(self, cand: np.ndarray,
                        rows: np.ndarray | None = None,
                        have_cols: np.ndarray | None = None) -> np.ndarray:
        """(len(rows), len(cand)) bool: may row-client serve chunk c?

        Built once per slot by the batched engine (and per receiver-
        neighborhood by max-flow); applies cover-set gating + owner
        throttling fully vectorized via :meth:`owner_windows`.
        ``have_cols`` lets a caller that already gathered
        ``have[:, cand]`` (all rows) share that buffer; in the ungated
        phases it is returned as-is, so callers must not mutate it.
        """
        cfg = self.cfg
        ungated = self.phase == "bt" or not cfg.enable_gating
        K = cfg.chunks_per_update
        kappa = cfg.owner_throttle
        if rows is None:
            # All-rows path: each candidate column has exactly ONE owner
            # row, so gating touches m cells — no (n, m) broadcast.
            sup = (np.take(self.have, cand, axis=1)
                   if have_cols is None else have_cols)
            if ungated:
                return sup
            if have_cols is not None:
                sup = sup.copy()
            cand_owner = self.owners[cand]
            _, starts, gated = self.owner_windows()
            off = cand - cand_owner * K
            # chunk c (offset in its update) is in its owner's rotation
            # window iff (offset - start_u) mod K < kappa, gate open.
            allowed = (((off - starts[cand_owner]) % K) < kappa)
            allowed &= ~gated[cand_owner]
            sup[cand_owner, np.arange(cand.size)] &= allowed
            return sup
        sup = self.have[np.ix_(rows, cand)]
        if ungated:
            return sup
        cand_owner = self.owners[cand]
        _, starts, gated = self.owner_windows()
        own = cand_owner[None, :] == rows[:, None]
        off = cand - cand_owner * K
        in_win = ((off[None, :] - starts[rows][:, None]) % K) < kappa
        allowed = in_win & ~gated[rows][:, None]
        sup &= (~own) | allowed
        return sup

    def candidate_columns(self, sactive: np.ndarray) -> np.ndarray:
        """Chunk ids any active sender could serve this slot (vectorized).

        Replicated chunks (some non-owner holds them) plus the open
        owner windows of ungated active senders; optionally capped to
        ``cand_cap`` columns for large-n runs, stratified across
        rarity bands so no replication level is starved.
        """
        cfg = self.cfg
        if self.phase == "bt" or not cfg.enable_gating:
            # Chunks already held by every client are needed nowhere, so
            # dropping them changes no schedule; the BT tail shrinks its
            # working set as the swarm completes.
            return np.flatnonzero(self.replicas < cfg.n)
        mask = self.replicas > 1
        ids, _, gated = self.owner_windows()
        ok = sactive & ~gated
        mask[ids[ok].ravel()] = True
        cand = np.flatnonzero(mask)
        cap = cfg.cand_cap
        if cap:
            # Rarity-stratified cap (jit-clean, branchless): the rarest
            # ``cap/2`` plus an even stride over the remaining
            # candidates.  A pure rarest-first cap starves large swarms
            # — the few holders of the rarest chunks saturate while the
            # plentiful mid-rarity supply sits outside the cap — so the
            # coverage half keeps every neighborhood servable.
            # Sentinel-padding the rarity keys up to ``cap`` entries
            # keeps argpartition legal for any cand size; when the cap
            # does not bind, the halves tile all of cand and np.sort
            # restores it exactly — schedules are unchanged either way.
            half = cap // 2
            pad = max(cap - cand.size, 0)
            key = np.concatenate([self.replicas[cand],
                                  np.full(pad, np.iinfo(np.int64).max)])
            sel = np.argpartition(key, half - 1)[:half]
            covered = np.zeros(key.size, dtype=bool)
            covered[sel] = True
            rest = np.flatnonzero(~covered)
            take = cap - half
            pos = (np.arange(take, dtype=np.int64)
                   * rest.size) // max(take, 1)
            sel = np.concatenate([sel, rest[pos]])
            sel = sel[sel < cand.size]
            cand = np.sort(cand[sel])
        return cand

    def eligible_row(self, u: int) -> np.ndarray:
        """Bool mask over all chunks that u may serve right now."""
        row = self.have[u].copy()
        K = self.cfg.chunks_per_update
        if self.phase != "bt" and self.cfg.enable_gating:
            row[u * K:(u + 1) * K] = False
            row[self.eligible_owner_slice(u)] = True
        return row

    def buffer_stats(self, u: int) -> tuple[int, int]:
        """(B_u, O_u): eligible buffer size and eligible owner count."""
        K = self.cfg.chunks_per_update
        if self.phase == "bt" or not self.cfg.enable_gating:
            return int(self.have[u].sum()), K
        o = len(self.eligible_owner_slice(u))
        return int(self.nonowner[u]) + o, o

    # -- transfer application -------------------------------------------
    def apply_transfers(self, snd: np.ndarray, rcv: np.ndarray,
                        chk: np.ndarray, phase_code: int,
                        consume_slot: bool = True,
                        t_start: np.ndarray | None = None,
                        t_end: np.ndarray | None = None):
        """Mark chunks delivered; update rarity, X_u and the event log.

        ``consume_slot=False`` applies the transfers without charging a
        round slot to ``per_slot_sent`` — used by the pre-round spray,
        which happens over ephemeral tunnels before slot 0.

        ``t_start``/``t_end`` (aligned with the input arrays) are the
        event engine's wall-clock stamps; they ride through the
        de-dup/reorder below so every *delivered* row keeps its instant.
        """
        if len(snd) == 0:
            if consume_slot:
                self.per_slot_sent.append(0)
            return
        snd = np.asarray(snd)
        rcv = np.asarray(rcv)
        chk = np.asarray(chk)
        # De-dup (receiver, chunk) within the slot (schedulers should
        # already avoid this, but enforce delivery-exactly-once).
        order = np.lexsort((chk, rcv))
        snd, rcv, chk = snd[order], rcv[order], chk[order]
        keep = np.ones(len(snd), dtype=bool)
        keep[1:] = ~((rcv[1:] == rcv[:-1]) & (chk[1:] == chk[:-1]))
        already = self.have[rcv, chk]
        keep &= ~already
        snd, rcv, chk = snd[keep], rcv[keep], chk[keep]
        if t_start is not None:
            t_start = np.asarray(t_start, np.float64)[order][keep]
            t_end = np.asarray(t_end, np.float64)[order][keep]

        # (B_u, O_u) at send time, vectorized (see buffer_stats):
        # ungated phases expose the whole inventory; gated warm-up
        # exposes X_u non-owner chunks plus the open kappa-window.
        K = self.cfg.chunks_per_update
        if self.phase == "bt" or not self.cfg.enable_gating:
            b = self.hold[snd].astype(np.int64)
            o = np.full(len(snd), K, dtype=np.int64)
        else:
            _, _, gated = self.owner_windows()
            o = np.where(gated[snd], 0, self.cfg.owner_throttle)
            o = o.astype(np.int64)
            b = self.nonowner[snd].astype(np.int64) + o

        self.have[rcv, chk] = True
        np.add.at(self.replicas, chk, 1)
        np.add.at(self.hold, rcv, 1)
        owner_mask = self.owners[chk] != rcv
        np.add.at(self.nonowner, rcv[owner_mask], 1)
        if owner_mask.any():
            self.any_nonowner = True
        self._win_cache = None    # gating state changed mid-slot

        self.log.append(self.slot, snd, rcv, chk, b, o, phase_code,
                        t_start=t_start, t_end=t_end)
        cnt = len(snd)
        if consume_slot:
            self.per_slot_sent.append(cnt)
        if phase_code == 1:
            self.warmup_sent += cnt
        elif phase_code == 2:
            self.bt_sent += cnt

    # -- progress queries -------------------------------------------------
    def holdings(self) -> np.ndarray:
        return self.hold.copy()

    def warmup_done(self) -> bool:
        """s_BT condition: every *active* client holds >= k_term chunks."""
        if not self.active.any():
            return True
        return bool((self.hold[self.active] >= self.cfg.k_term).all())

    def all_done(self) -> bool:
        if not self.active.any():
            return True
        return bool((self.hold[self.active] >= self.cfg.total_chunks).all())

    def reconstructable_sets(self) -> np.ndarray:
        """A_v^r as a bool matrix (n_clients, n_updates) at current slot."""
        n, K = self.cfg.n, self.cfg.chunks_per_update
        per_update = self.have.reshape(n, n, K)
        return per_update.all(axis=2)
