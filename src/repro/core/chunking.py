"""Update chunking, torrent descriptors, and reassembly (paper §II-A/B).

The data plane: a client's model update (a pytree of arrays) is
serialized into a flat byte view, padded, and split into fixed-size
chunks (BitTorrent pieces).  A *torrent descriptor* carries per-chunk
hashes so receivers can verify integrity and discard corrupted payloads
(BEP-0003).  Under homogeneous update sizes, descriptors reveal only
chunk hashes and piece counts — not the owner identity (§II-B).

The pack/unpack path is implemented in JAX (it is the on-device side of
dissemination); hashing is host-side (it operates on wire bytes).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np


# ----------------------------------------------------------------------
# Flatten / unflatten pytrees <-> single fp32 vector
# ----------------------------------------------------------------------

def flatten_update(tree) -> tuple[jnp.ndarray, list]:
    """Flatten a pytree of arrays into one fp32 vector + shape spec."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    spec = [(l.shape, l.dtype) for l in leaves]
    flat = jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves]) \
        if leaves else jnp.zeros((0,), jnp.float32)
    return flat, (treedef, spec)


def unflatten_update(flat: jnp.ndarray, spec) -> "jax.Array":
    treedef, shapes = spec
    leaves = []
    off = 0
    for shape, dtype in shapes:
        size = int(np.prod(shape)) if shape else 1
        leaves.append(flat[off:off + size].reshape(shape).astype(dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ----------------------------------------------------------------------
# Chunk pack / unpack (JAX data plane)
# ----------------------------------------------------------------------

def chunk_count(num_bytes: int, chunk_bytes: int) -> int:
    """K_v^r = ceil(S_v^r / C)  (paper §II-B)."""
    return int(-(-num_bytes // chunk_bytes))


def pack_chunks(flat: jnp.ndarray, chunk_bytes: int) -> jnp.ndarray:
    """(num_elems,) fp32 -> (K, C/4) fp32 chunk matrix with zero padding."""
    elems_per_chunk = chunk_bytes // 4
    n = flat.shape[0]
    k = chunk_count(n * 4, chunk_bytes)
    pad = k * elems_per_chunk - n
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(k, elems_per_chunk)


def unpack_chunks(chunks: jnp.ndarray, num_elems: int) -> jnp.ndarray:
    """(K, C/4) chunk matrix -> (num_elems,) fp32 vector."""
    return chunks.reshape(-1)[:num_elems]


# ----------------------------------------------------------------------
# Torrent descriptors (host-side integrity metadata)
# ----------------------------------------------------------------------

@dataclass(frozen=True)
class TorrentDescriptor:
    """Metadata published per update: chunk hashes + aggregation weight.

    ``desc_id`` is the public identity of the update in a round (attacks
    see desc ids, never owner indices).  ``weight`` is the FedAvg scalar
    (e.g. local sample count, §II-B).
    """

    desc_id: str
    num_chunks: int
    chunk_bytes: int
    total_bytes: int
    weight: float
    chunk_hashes: tuple = field(default_factory=tuple)

    @staticmethod
    def build(chunks: np.ndarray, weight: float, salt: bytes = b"") -> "TorrentDescriptor":
        arr = np.ascontiguousarray(np.asarray(chunks, dtype=np.float32))
        hashes = tuple(
            hashlib.sha256(arr[i].tobytes()).hexdigest() for i in range(arr.shape[0])
        )
        root = hashlib.sha256(("".join(hashes)).encode() + salt).hexdigest()[:16]
        return TorrentDescriptor(
            desc_id=root,
            num_chunks=arr.shape[0],
            chunk_bytes=arr.shape[1] * 4,
            total_bytes=arr.size * 4,
            weight=float(weight),
            chunk_hashes=hashes,
        )

    def verify_chunk(self, index: int, payload: np.ndarray) -> bool:
        """Hash-check one received piece (Byzantine integrity, §III-E)."""
        h = hashlib.sha256(
            np.ascontiguousarray(np.asarray(payload, np.float32)).tobytes()
        ).hexdigest()
        return h == self.chunk_hashes[index]


def make_update_torrent(tree, weight: float, chunk_bytes: int):
    """Convenience: pytree -> (chunks, descriptor, spec) for one client."""
    flat, spec = flatten_update(tree)
    chunks = pack_chunks(flat, chunk_bytes)
    desc = TorrentDescriptor.build(np.asarray(chunks), weight)
    return chunks, desc, (spec, flat.shape[0])


def reassemble_update(chunks: jnp.ndarray, spec_and_len):
    spec, num_elems = spec_and_len
    return unflatten_update(unpack_chunks(chunks, num_elems), spec)
