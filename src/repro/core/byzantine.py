"""Byzantine peer behaviours + operational handling (paper §II-C
Adversary B, §III-E).

The paper distinguishes (a) integrity-violating deviations — payload
tampering, detectable via the descriptor hash check, discarded on
receipt — and (b) liveness-degrading deviations — lying in bitfields,
withholding/delaying service.  Handling is operational: per-peer
progress timeouts mark non-serving peers inactive for *scheduling*;
warm-up completion is evaluated over the remaining active set; if
warm-up cannot finish by s_max the round fails open to vanilla BT.

Behaviours:

* ``"lie"``      — advertises chunks it does not hold; scheduled
                   transfers of those chunks deliver garbage that fails
                   the hash check and is discarded (wasted budget).
* ``"withhold"`` — accepts assignments but never transmits (silent
                   drop; pure timeout pressure).
* ``"slow"``     — serves at ~1/4 of its advertised uplink.

Unlinkability (§IV-A) is only claimed for transfers sent by HONEST
senders; tests/test_byzantine.py asserts Eq. (1) continues to hold on
exactly that set while the round stays live.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class ByzantineModel:
    """behaviour per corrupted client + tracker timeout policy."""

    behaviours: dict = field(default_factory=dict)   # client -> behaviour
    timeout_slots: int = 5       # consecutive failed serves -> inactive
    lie_fraction: float = 0.5    # fraction of missing chunks advertised

    def __post_init__(self):
        for b in self.behaviours.values():
            assert b in ("lie", "withhold", "slow"), b

    def corrupt(self):
        return np.asarray(sorted(self.behaviours), dtype=np.int64)


def claimed_inventory(model: ByzantineModel, state, rng) -> np.ndarray:
    """Bitfields as reported to the tracker: liars over-claim."""
    claimed = state.have.copy()
    for u, b in model.behaviours.items():
        if b != "lie":
            continue
        missing = np.flatnonzero(~state.have[u])
        if missing.size == 0:
            continue
        k = int(len(missing) * model.lie_fraction)
        if k:
            fake = rng.choice(missing, size=k, replace=False)
            claimed[u, fake] = True
    return claimed


def filter_transfers(model: ByzantineModel, state, rng,
                     snd: np.ndarray, rcv: np.ndarray, chk: np.ndarray):
    """Apply behaviour to scheduled transfers.

    Returns (delivered mask, failed-serve counts per sender).  Lies
    surface as hash-check failures at the receiver (chunk discarded);
    withheld/slow transfers simply never arrive this slot.
    """
    n = state.cfg.n
    ok = np.ones(len(snd), dtype=bool)
    fails = np.zeros(n, dtype=np.int64)
    for i, (u, c) in enumerate(zip(snd, chk)):
        b = model.behaviours.get(int(u))
        if b is None:
            continue
        if b == "lie" and not state.have[int(u), int(c)]:
            ok[i] = False                      # garbage payload discarded
            fails[int(u)] += 1
        elif b == "withhold":
            ok[i] = False
            fails[int(u)] += 1
        elif b == "slow" and rng.random() > 0.25:
            ok[i] = False
            fails[int(u)] += 1
    return ok, fails
