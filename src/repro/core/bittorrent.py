"""Vanilla BitTorrent swarming phase (paper §III-A step 4).

Two fidelity modes:

* **exact** — chunk-level rarest-first receiver-driven swarming using the
  same vectorized stage assignment as the warm-up schedulers, but with
  every held chunk eligible (no gating) and random holder selection
  (vanilla BitTorrent does not globally optimize sender choice).  Used
  for small/medium swarms and wherever per-chunk ground truth matters
  (dropout/reconstructable-set tests).

* **fluid** — capacity-bound transport approximation for large swarms
  (n x K beyond exact-sim budgets): per slot, receiver demand is spread
  over neighbors by remaining uplink with an availability cap
  ``|have_u \\ have_v| ~= got_u * (1 - got_v / C)`` (well-mixed chunk
  spread, accurate after warm-up).  Tracks only chunk *counts*; BT-phase
  chunk identities are never consumed by the privacy attacks (§IV-C
  observes warm-up transfers), so this loses no attack fidelity.

The paper's wall-clock results (Fig. 4, Table III, Fig. 8) are
capacity-dominated, which both modes reproduce.
"""
from __future__ import annotations

import numpy as np

from .policy import SlotView
from .state import SwarmState
from .schedulers import VanillaBTPolicy

_BT_POLICY = VanillaBTPolicy()          # stateless; shared singleton


def bt_exact_slot(state: SwarmState):
    """One slot of vanilla BT: rarest-first, random feasible senders.

    Drives the ``bt_vanilla`` policy (phase applicability ``("bt",)``)
    through the configured slot engine (``scheduler_impl``): with the
    default batched engine the whole-universe supply matrix is built
    once per slot and all receivers are matched in vectorized budgeted
    rounds, which is what makes chunk-level exact BT viable at paper
    scale (n x K in the millions).
    """
    return _BT_POLICY.schedule(SlotView(state, _BT_POLICY.visibility))


def run_bt_fluid(state: SwarmState, s_max: int) -> float:
    """Run the fluid BT phase to completion; returns *effective* slots.

    The return value is real-valued: the final iteration usually moves
    less than a full slot's worth of chunks, so it is credited
    fractionally (``sent_last / peak_sent``) — the event engine
    (:mod:`repro.net`) books ``effective_slots * slot_seconds`` of wall
    clock instead of rounding the tail up to a whole slot.  The integer
    state (``state.slot``, ``per_slot_sent``) keeps the historical
    whole-slot accounting.

    Mutates ``state.bt_sent`` and ``state.per_slot_sent`` only (count
    space).  ``state.have`` is left at its warm-up value; callers that
    complete the fluid phase should treat dissemination as complete for
    all active clients.
    """
    cfg = state.cfg
    C = float(cfg.total_chunks)
    active = state.active.copy()
    got = state.hold.astype(np.float64).copy()
    up = np.where(active, state.up, 0).astype(np.float64)
    down = np.where(active, state.down, 0).astype(np.float64)
    adj = state.adj

    slots = 0
    sent_hist: list[float] = []
    while slots < s_max:
        need = np.where(active, C - got, 0.0)
        if (need <= 1e-9).all():
            break
        demand = np.minimum(down, need)
        # Availability cap per (sender u -> receiver v):
        #   got_u * (1 - got_v / C), the expected |have_u \ have_v|
        # under well-mixed spread; elementwise outer product form.
        avail = got[:, None] * (1.0 - got[None, :] / C)    # (u, v)
        avail = np.where(adj, avail, 0.0)
        rem_up = up.copy()
        inflow = np.zeros_like(got)
        # Proportional water-filling, a few rounds.
        for _ in range(4):
            want = demand - inflow
            if (want <= 1e-9).all() or rem_up.sum() <= 1e-9:
                break
            # Receiver v asks each neighbor u proportionally to rem_up.
            weight = np.where(adj, rem_up[:, None], 0.0)
            wsum = weight.sum(axis=0)
            wsum = np.where(wsum > 0, wsum, 1.0)
            ask = weight * (want[None, :] / wsum)          # (u, v)
            # Clamp at zero: fp drift can push ``avail`` (and with a
            # tiny ``tot`` the rescale below) negative, which used to
            # explode ``inflow`` into huge negative "transfers" that
            # the integer slot accounting silently swallowed.
            ask = np.clip(ask, 0.0, np.maximum(avail, 0.0))
            # Senders scale down if oversubscribed.
            tot = ask.sum(axis=1)
            scale = np.where(tot > rem_up,
                             np.maximum(rem_up, 0.0)
                             / np.maximum(tot, 1e-12), 1.0)
            give = ask * scale[:, None]
            inflow += give.sum(axis=0)
            rem_up -= give.sum(axis=1)
            avail -= give
        got += inflow
        sent = float(inflow.sum())
        sent_hist.append(sent)
        state.per_slot_sent.append(int(round(sent)))
        state.bt_sent += int(round(sent))
        slots += 1
        state.slot += 1
        if sent <= 1e-9:
            break  # no progress possible (disconnected leftovers)
    # Mark logical completion for active clients.
    state.hold = np.where(active, np.maximum(state.hold, np.round(got).astype(np.int64)),
                          state.hold)
    eff = float(slots)
    if sent_hist:
        peak = max(sent_hist)
        if peak > 0:
            eff = slots - 1 + sent_hist[-1] / peak
    return eff
