"""Core datatypes for FLTorrent (paper §II).

Conventions
-----------
* ``n`` clients, each producing one update of ``K`` chunks of ``C`` bytes
  (homogeneous update sizes, as assumed by the paper's analysis §II-B).
* Global chunk ids are ``owner * K + i`` for ``i in [0, K)``; the owner of
  chunk ``c`` is ``c // K``.  These are *analysis labels* — the wire
  protocol exchanges (descriptor-id, piece-index) which do not encode the
  owner, and attacks only ever see descriptor ids (see attacks.py).
* Time is slotted (Δ = 1 s by default).  Capacities are expressed in
  chunks/slot (paper §II-B: ``u_v = floor(U_v Δ / C)``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import TYPE_CHECKING

import numpy as np

if TYPE_CHECKING:  # pragma: no cover - annotation only
    from .policy import SchedulerPolicy  # noqa: F401


@dataclass(frozen=True)
class SwarmConfig:
    """Static configuration of one FLTorrent round (paper Table I)."""

    n: int = 100                 # number of clients |V|
    chunks_per_update: int = 206  # K (GoogLeNet default: 206 x 256 KiB)
    chunk_bytes: int = 256 * 1024  # C
    min_degree: int = 10         # m, overlay minimum degree
    extra_edge_frac: float = 0.1  # heterogeneous neighbor counts above m
    slot_seconds: float = 1.0    # Δ
    s_max: int = 1_000_000       # round deadline (slots); large default

    # --- warm-up knobs (paper §II-D, §III-B) ---
    # Termination threshold: warm-up ends when every active client holds
    # at least ``k_term`` chunks.  The paper reports it as K = percentage
    # of the swarm-wide chunk universe |C^r| = n*K (§V-A).
    warmup_threshold_pct: float = 0.10   # "K" in the paper's figures
    # Analysis / gating knob: an honest sender enables owner chunks only
    # once its eligible buffer reaches ``k_gate`` = ceil(alpha * K)
    # (paper §II-D uses alpha = 10% of a single update's chunk count).
    gate_alpha: float = 0.10
    owner_throttle: int = 1      # kappa_u (default 1, paper §IV-A)

    spray_ratio: float = 0.2     # R, pre-round obfuscation strength
    lag_slots: int = 3           # T_lag; lags ~ Unif{0..T_lag-1}
    tau_concurrent: int = 4      # tau, max distinct receivers per sender/slot

    # Feature toggles (for the paper's ablations, Fig. 4/6):
    enable_preround: bool = True     # PR
    enable_timelag: bool = True      # TL
    enable_gating: bool = True       # K (cover-set gating + throttle)
    enable_nonowner_first: bool = True

    # Warm-up scheduling policy: a name registered in core/policy.py
    # ("greedy_fastest_first", "random_fifo", "random_fastest_first",
    # "distributed", "flooding", or any plugin) or a SchedulerPolicy
    # instance — `cfg.replace(scheduler=MyPolicy())` round-trips.
    scheduler: "str | SchedulerPolicy" = "greedy_fastest_first"
    # Slot-engine implementation: "batched" resolves the per-slot
    # assignment with vectorized budgeted rounds over all receivers at
    # once (paper-scale swarms, default); "loop" is the reference
    # per-receiver engine both others are equivalence-tested against;
    # "jit" runs the same matching as fixed-shape jitted JAX kernels
    # over packed uint32 bitplanes (core/jit_engine.py) for n>=~500
    # scaling sweeps.  All three are legality- and parity-locked in
    # tests/test_scheduler_equivalence.py.
    scheduler_impl: str = "batched"
    seed: int = 0
    # Large-n performance knob: cap the per-slot candidate-chunk set
    # to ``cand_cap`` chunks, stratified across rarity bands so every
    # replication level stays represented (0 = exact).  The per-slot
    # budget (sum of downlinks) is far below the cap, so utilization
    # is essentially unchanged (validated at n=100).
    cand_cap: int = 0

    # ------------------------------------------------------------------
    @property
    def total_chunks(self) -> int:
        """|C^r| — the swarm-wide chunk universe."""
        return self.n * self.chunks_per_update

    @property
    def k_term(self) -> int:
        """Warm-up termination threshold in chunks (universe fraction)."""
        return int(np.ceil(self.warmup_threshold_pct * self.total_chunks))

    @property
    def k_gate(self) -> int:
        """Cover-set gating threshold (per-update fraction, §II-D)."""
        return int(np.ceil(self.gate_alpha * self.chunks_per_update))

    @property
    def spray_copies(self) -> int:
        """sigma = floor(R * K) chunks sprayed per source (§III-B.1)."""
        return int(np.floor(self.spray_ratio * self.chunks_per_update))

    def replace(self, **kw) -> "SwarmConfig":
        return dataclasses.replace(self, **kw)


@dataclass
class Transfer:
    """One observed chunk transmission (an event-log row)."""

    slot: int
    sender: int      # round pseudonym == client index within the round sim
    receiver: int
    chunk: int       # global chunk id
    owner: int       # ground-truth source (= chunk // K); hidden from attacks
    phase: str       # "spray" | "warmup" | "bt"
    # Eligible-buffer accounting at send time, for empirical bound checks:
    eligible_size: int = 0   # B_u
    eligible_owner: int = 0  # O_u


@dataclass
class RoundMetrics:
    """Aggregate outcome of one simulated round (paper §II-D, §V)."""

    t_warm: int = 0            # warm-up duration (slots)
    t_round: int = 0           # total round duration (slots)
    # Wall-clock round times (seconds).  The slot engine stamps the
    # slot grid (t = slots * Δ); the event engine (repro.net) reports
    # realized transport makespans + tracker control time, which is
    # what the paper's §V-E seconds claims are about.
    t_warm_s: float = 0.0      # spray + warm-up cycles + control time
    t_round_s: float = 0.0     # total realized round duration
    t_spray_s: float = 0.0     # pre-round obfuscation transport
    control_s: float = 0.0     # tracker control plane (directive RTTs)
    warmup_share_s: float = 0.0   # t_warm_s / t_round_s
    warmup_chunks_sent: int = 0
    bt_chunks_sent: int = 0
    warmup_utilization: float = 0.0   # Util(pi; H) during warm-up
    overall_utilization: float = 0.0
    warmup_share: float = 0.0         # t_warm / t_round
    failed_open: bool = False         # warm-up could not complete by s_max
    per_slot_warmup_util: np.ndarray | None = None
    active_at_deadline: np.ndarray | None = None  # bool (n,)

    def as_dict(self) -> dict:
        d = {k: v for k, v in dataclasses.asdict(self).items()
             if not isinstance(v, np.ndarray)}
        return d


def owner_of(chunk_ids: np.ndarray, chunks_per_update: int) -> np.ndarray:
    """Ground-truth source of each global chunk id."""
    return np.asarray(chunk_ids) // chunks_per_update


def chunk_range(owner: int, chunks_per_update: int) -> np.ndarray:
    return np.arange(owner * chunks_per_update, (owner + 1) * chunks_per_update)
