"""SwarmSession: a persistent multi-round swarm with cross-round churn.

The paper's §III-E semantics — clients join and leave *between* rounds,
leavers rejoin at a later round boundary, and every round's aggregation
proceeds over whatever active set reconstructs — need state that
outlives a single :class:`~repro.core.simulator.RoundSimulator`.  This
module carries that state:

* a **persistent peer population** with stable global ids: capacities
  are sampled once when a peer joins and stick for its lifetime,
* a **churn model** applied at round boundaries: Bernoulli leaves,
  Poisson joins of fresh peers, and planned rejoins ``rejoin_after``
  rounds later (the paper's rejoin-at-round-boundary rule),
* **incremental overlay evolution**: instead of re-rolling the whole
  graph every round, edges of departed peers go dormant, joiners attach
  with ``min_degree`` repair edges, and survivors whose active degree
  dropped get repair edges — so cross-round attack and privacy metrics
  (``edge_persistence``, ``pair_exposure``) can be computed against the
  topology as it actually *evolves*, which is what topology-dependent
  privacy bounds are a function of.

Usage
-----
::

    from repro.core import SwarmConfig
    from repro.core.session import ChurnModel, SwarmSession

    cfg = SwarmConfig(n=40, chunks_per_update=16, min_degree=5)
    ses = SwarmSession(cfg, churn=ChurnModel(leave_prob=0.1,
                                             join_rate=1.0,
                                             rejoin_after=2))
    for _ in range(10):
        rec = ses.next_round()
        rec.result.metrics          # RoundMetrics of this round's sub-swarm
        rec.active_ids              # local index i <-> global peer rec.active_ids[i]
    ses.edge_persistence()          # cross-round edge overlap in [0, 1]
    ses.pair_exposure().max()       # most-exposed neighbor pair (rounds)

Zero churn (the default, ``SwarmSession(cfg)``) reproduces today's
per-round ``simulate_round`` loop **bit-identically**: every round
re-rolls overlay and capacities from ``round_seed(r)`` exactly like
``RoundSimulator(cfg.replace(seed=round_seed(r)))`` — asserted
seed-for-seed in ``tests/test_session.py``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from collections.abc import Callable

import numpy as np

from repro import obs

from . import capacities as cap
from .overlay import _components, random_overlay
from .simulator import RoundResult, RoundSimulator
from .trace import TransferTrace
from .types import SwarmConfig


def _locate(ids: np.ndarray, g: np.ndarray):
    """Global -> local positions over the sorted active-id array;
    returns (positions, present-mask)."""
    g = np.asarray(g, np.int64)
    pos = np.searchsorted(ids, g)
    posc = np.minimum(pos, max(ids.size - 1, 0))
    ok = (pos < ids.size) & (ids.size > 0)
    if ids.size:
        ok &= ids[posc] == g
    return posc.astype(np.int64), ok


def _group_counts(gen: np.ndarray, owner: np.ndarray):
    """Yield (gen, owner, count) per distinct (generation, owner) pair."""
    key = np.asarray(gen, np.int64) * (2 ** 32) + np.asarray(owner,
                                                             np.int64)
    uk, cnt = np.unique(key, return_counts=True)
    for k, c in zip(uk, cnt):
        yield int(k >> 32), int(k & 0xFFFFFFFF), int(c)


@dataclass(frozen=True)
class ChurnModel:
    """Cross-round membership dynamics (paper §III-E).

    ``leave_prob``  — per-active-peer Bernoulli leave probability at each
    round boundary; ``join_rate`` — Poisson mean of *fresh* peers joining
    per boundary; ``rejoin_after`` — a leaver rejoins at the boundary
    this many rounds later (0 = leavers never come back).

    ``rejoin_dist`` selects the rejoin-delay law: ``"fixed"`` is the
    historical deterministic delay; ``"geometric"`` samples each
    leaver's delay from Geometric(1/rejoin_after) (mean
    ``rejoin_after``), modelling heterogeneous absence durations.
    ``participation()`` stays exact either way — it is computed from the
    realized membership history, not the delay law.
    """

    leave_prob: float = 0.0
    join_rate: float = 0.0
    rejoin_after: int = 2
    rejoin_dist: str = "fixed"      # "fixed" | "geometric"

    def __post_init__(self):
        if self.rejoin_dist not in ("fixed", "geometric"):
            raise ValueError(
                f"unknown rejoin_dist {self.rejoin_dist!r}")

    @property
    def enabled(self) -> bool:
        return self.leave_prob > 0.0 or self.join_rate > 0.0


@dataclass
class SprayPlan:
    """Explicit pre-round spray directives for one round (local ids).

    Produced by a :class:`SprayPolicy` at the round boundary and applied
    verbatim by the simulator's spray step.  ``fresh`` marks directives
    that open a NEW ephemeral tunnel (a true re-spray); unset rows reuse
    a tunnel that survived from an earlier round — the cost churn-aware
    budgeting saves.
    """

    src: np.ndarray                 # local source indices
    tgt: np.ndarray                 # local target indices (non-neighbors)
    offset: np.ndarray              # within-update chunk offsets
    fresh: np.ndarray               # bool: new tunnel vs reused

    def as_local_arrays(self):
        return (np.asarray(self.src, np.int64),
                np.asarray(self.tgt, np.int64),
                np.asarray(self.offset, np.int64))

    def fresh_counts(self, n: int) -> np.ndarray:
        """(n,) fresh-tunnel count per local source."""
        src = np.asarray(self.src, np.int64)
        return np.bincount(src[np.asarray(self.fresh, bool)], minlength=n)


class SprayPolicy:
    """Policy hook on :meth:`SwarmSession.begin_round`: decide what each
    source sprays this round.  Returning ``None`` keeps the historical
    full re-spray path (byte-identical; the simulator draws its own
    targets)."""

    def plan(self, session: "SwarmSession",
             ids: np.ndarray) -> SprayPlan | None:
        return None


class ChurnAwareSpray(SprayPolicy):
    """Churn-aware spray budgets (§III-B.1 under §III-E churn).

    The session tracks, per source, which sprayed chunk offsets still
    have a *live* tunnel: the holder is active and remains a
    non-neighbor of the source under the evolving overlay.  At every
    round boundary each active source re-sprays ONLY the offsets whose
    replication dropped below the per-offset target (holder left,
    dropped mid-round, or became a neighbor) — in particular a rejoiner
    re-sprays exactly the coverage it lost while absent — and reuses the
    surviving tunnels for the rest, so the per-round obfuscation mass
    (sigma chunks per source, Eq. 1's mixing input) is preserved while
    fresh tunnel setups shrink to the churn-induced delta.

    Requires an evolving-overlay session (``SwarmSession`` with churn or
    ``evolve_overlay=True``): tunnel validity is a statement about the
    persistent topology.
    """

    def __init__(self):
        # (n_peers, m) ledgers, -1 = dead slot; grown lazily with joins.
        self._offs: np.ndarray | None = None
        self._holds: np.ndarray | None = None

    def _grown(self, P: int, m: int):
        if self._offs is None:
            self._offs = np.full((P, m), -1, np.int64)
            self._holds = np.full((P, m), -1, np.int64)
        elif self._offs.shape[0] < P:
            pad = np.full((P - self._offs.shape[0], m), -1, np.int64)
            self._offs = np.vstack([self._offs, pad])
            self._holds = np.vstack([self._holds, pad])
        return self._offs, self._holds

    def plan(self, ses: "SwarmSession",
             ids: np.ndarray) -> SprayPlan | None:
        """Fully vectorized over the (source, tunnel-slot) ledger — no
        per-peer Python loop at the round boundary (the boundary is on
        the per-round critical path at paper-scale populations)."""
        if not ses.evolve:
            raise ValueError(
                "ChurnAwareSpray needs an evolving-overlay session "
                "(enable churn or evolve_overlay=True)")
        cfg = ses.cfg
        K = cfg.chunks_per_update
        m = min(cfg.spray_copies, K)
        if m == 0 or ids.size == 0:
            return None
        rng = ses.rng
        P = ses.n_peers
        all_offs, all_holds = self._grown(P, m)
        R = ids.size
        rr = np.arange(R)[:, None]
        offs = all_offs[ids]
        holds = all_holds[ids]
        # Tunnel survival: holder in this round's active set and still
        # a non-neighbor (overlay repair may have linked them).
        in_round = np.zeros(P, dtype=bool)
        in_round[ids] = True
        hsafe = np.clip(holds, 0, P - 1)
        valid = (holds >= 0) & in_round[hsafe] \
            & ~ses.adj[ids[:, None], hsafe]
        # Compact surviving tunnels to the front; invalid slots trail
        # and become the fresh re-spray positions.
        order = np.argsort(~valid, axis=1, kind="stable")
        offs, holds = offs[rr, order], holds[rr, order]
        keep = valid[rr, order]
        fresh_slot = ~keep
        # Fresh offsets: per row, distinct draws from the complement of
        # the kept offsets — kept keys pinned to +inf, row-sorted, the
        # j-th fresh slot takes the j-th cheapest complement offset.
        keys = rng.random((R, K))
        rk, ck = np.nonzero(keep)
        keys[rk, offs[rk, ck]] = np.inf
        oorder = np.argsort(keys, axis=1)
        j = np.cumsum(fresh_slot, axis=1) - 1
        offs = np.where(fresh_slot, oorder[rr, np.clip(j, 0, K - 1)],
                        offs)
        # Fresh targets: one uniform active non-neighbor per fresh slot
        # (rank-pick into the stable-sorted non-neighbor columns, the
        # RoundSimulator._spray technique).
        nn = ~ses.adj[np.ix_(ids, ids)]
        nn[np.arange(R), np.arange(R)] = False
        cnt = nn.sum(axis=1)
        can = cnt > 0
        torder = np.argsort(~nn, axis=1, kind="stable")
        pick = (rng.random((R, m))
                * np.maximum(cnt, 1)[:, None]).astype(np.int64)
        tglob = ids[torder[rr, pick]]
        holds = np.where(fresh_slot & can[:, None], tglob, holds)
        live = keep | (fresh_slot & can[:, None])
        all_offs[ids] = np.where(live, offs, -1)
        all_holds[ids] = np.where(live, holds, -1)
        rsel, csel = np.nonzero(live)
        if rsel.size == 0:
            return None
        return SprayPlan(src=rsel.astype(np.int64),
                         tgt=np.searchsorted(ids, holds[rsel, csel]),
                         offset=offs[rsel, csel],
                         fresh=fresh_slot[rsel, csel])


@dataclass
class SessionRound:
    """One session round: the sub-swarm result plus membership events.

    ``active_ids`` maps the round simulator's local client indices to
    stable global peer ids (``local i <-> global active_ids[i]``); all
    event arrays hold global ids.
    """

    round_idx: int
    active_ids: np.ndarray
    result: RoundResult
    joined: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    left: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    rejoined: np.ndarray = field(
        default_factory=lambda: np.zeros(0, np.int64))
    dropped_midround: np.ndarray = field(
        default_factory=lambda: np.zeros(0, np.int64))
    spray_plan: SprayPlan | None = None
    # Async deliveries (fl/asyncfl.py; empty on sync rounds):
    late_log: TransferTrace | None = None   # late rows, global ids
    drain_s: float = 0.0                    # boundary drain wall time
    late_ready: list = field(default_factory=list)   # (gen, owner) done
    dead_updates: list = field(default_factory=list)  # (gen, owner) lost

    @property
    def t_warm_s(self) -> float:
        """Wall-clock warm-up duration (spray + cycles + control)."""
        return self.result.metrics.t_warm_s

    @property
    def t_round_s(self) -> float:
        return self.result.metrics.t_round_s

    @property
    def warmup_share_s(self) -> float:
        return self.result.metrics.warmup_share_s

    def global_log(self) -> TransferTrace:
        """The round's transfer trace with sender/receiver/owner re-keyed
        to global peer ids and the session ``round`` column stamped
        (chunk/descriptor ids stay local to the round's torrent;
        ``t_start``/``t_end`` stay round-relative — the ``round`` column
        is the cross-round clock)."""
        tr = self.result.log
        ids = self.active_ids
        n = len(tr)
        return TransferTrace(
            K=tr.K,
            slot=tr.slot,
            sender=ids[np.asarray(tr.sender, np.int64)].astype(np.int32),
            receiver=ids[np.asarray(tr.receiver,
                                    np.int64)].astype(np.int32),
            chunk=tr.chunk,
            owner=ids[np.asarray(tr.owner, np.int64)].astype(np.int32),
            b_size=tr.b_size, o_size=tr.o_size, phase=tr.phase,
            round=np.full(n, self.round_idx, dtype=np.int32),
            t_start=tr.t_start, t_end=tr.t_end,
            # A round's own rows always carry its own generation, on
            # time; late deliveries live in ``late_log``.
            generation=np.full(n, self.round_idx, dtype=np.int32),
            staleness=np.zeros(n, dtype=np.int32))


class SwarmSession:
    """Persistent peer population carried across FL rounds.

    Parameters
    ----------
    cfg : SwarmConfig
        Template round config; ``cfg.n`` is the *initial* population.
        Each round runs with ``n`` = current active count and
        ``seed = round_seed(r)``.
    churn_rate : float
        Shorthand for ``ChurnModel(leave_prob=churn_rate)`` —
        ``churn_rate=0`` is the exact single-round-loop back-compat mode.
    churn : ChurnModel, optional
        Full churn spec; overrides ``churn_rate``.
    round_seed : callable(int) -> int, optional
        Per-round seed schedule; defaults to ``cfg.seed * 1000 + r``
        (the convention ``fl/runner.py`` has always used).
    evolve_overlay : bool, optional
        Force incremental topology evolution on/off.  Default: evolve
        exactly when churn is enabled, so the zero-churn session stays
        bit-identical to the historical per-round re-roll.
    """

    def __init__(self, cfg: SwarmConfig, *,
                 churn_rate: float = 0.0,
                 churn: ChurnModel | None = None,
                 link_model: cap.LinkModel = cap.RESIDENTIAL,
                 bt_mode: str = "auto",
                 round_seed: Callable[[int], int] | None = None,
                 evolve_overlay: bool | None = None,
                 spray_policy: SprayPolicy | None = None,
                 time_engine: str = "slot",
                 net=None):
        if churn is None:
            churn = ChurnModel(leave_prob=float(churn_rate))
        self.cfg = cfg
        self.churn = churn
        self.link_model = link_model
        self.bt_mode = bt_mode
        self.spray_policy = spray_policy
        # Time engine (§repro.net): "event" runs every round on the
        # continuous-time transport — wall-clock metrics (t_warm_s,
        # t_round_s, warmup_share_s) then persist across churn like
        # every other per-round metric.
        if time_engine not in ("slot", "event"):
            raise ValueError(f"unknown time_engine {time_engine!r}")
        self.time_engine = time_engine
        self.net = net
        self.round_seed = (round_seed if round_seed is not None
                           else lambda r: cfg.seed * 1000 + r)
        self.evolve = (churn.enabled if evolve_overlay is None
                       else bool(evolve_overlay))
        # Session-level stream (churn + overlay evolution), independent
        # of the per-round simulator streams so adding churn never
        # perturbs the in-round schedules of unaffected rounds.
        self.rng = np.random.default_rng(np.random.SeedSequence(
            [int(cfg.seed), 0x5E5510]))

        self.n_peers = cfg.n
        self.active = np.ones(cfg.n, dtype=bool)
        self.rejoin_at = np.full(cfg.n, -1, dtype=np.int64)
        self.round_idx = 0
        self.history: list[SessionRound] = []
        self._pending: tuple | None = None   # begun-but-not-run round
        # Async state (fl/asyncfl.py): wall-clock start of each round
        # (offsets[r] -> round r; a trailing entry marks the session
        # end), the carry-mode backlog of undelivered tail transfers
        # (global-id arrays), and per-(gen, owner) outstanding-chunk
        # counts for late-completion bookkeeping.
        self.offsets: list[float] = [0.0]
        self._backlog: dict | None = None
        self._outstanding: dict[tuple[int, int], int] = {}
        # Relay-replan state (carry mode): (gen, chunk) -> global ids of
        # peers holding that chunk (grown by background deliveries), and
        # (gen, owner) -> that update's outstanding chunk ids (for GC).
        self._holders: dict[tuple[int, int], np.ndarray] = {}
        self._update_chunks: dict[tuple[int, int], np.ndarray] = {}

        if self.evolve:
            self.adj = random_overlay(cfg.n, cfg.min_degree,
                                      cfg.extra_edge_frac, self.rng)
            # Persist RAW rates alongside the quantized budgets: the
            # same draws feed both time domains (see capacities.py), so
            # swapping time_engine never perturbs the session streams.
            self.up_bps, self.down_bps = link_model.sample_rates(
                cfg.n, self.rng)
            self.up, self.down = cap.quantize_rates(
                self.up_bps, self.down_bps, cfg.chunk_bytes,
                cfg.slot_seconds, warn=(time_engine == "slot"))
            self._exposure = np.zeros((cfg.n, cfg.n), dtype=np.int64)
        else:
            self.adj = None
            self.up = self.down = None
            self.up_bps = self.down_bps = None
            self._exposure = None

    # -- membership (round boundaries) ----------------------------------
    @property
    def min_active(self) -> int:
        """Leave-clamp floor: a round needs min_degree+1 peers to mesh."""
        return self.cfg.min_degree + 1

    def _rejoin_delays(self, k: int) -> np.ndarray:
        """Per-leaver rejoin delay (rounds) under ``churn.rejoin_dist``.

        ``"fixed"`` keeps the historical deterministic delay (and draws
        nothing, so existing seeds are unperturbed); ``"geometric"``
        samples Geometric(1/rejoin_after), mean ``rejoin_after``.
        """
        ra = max(self.churn.rejoin_after, 1)
        if self.churn.rejoin_dist == "geometric":
            return self.rng.geometric(1.0 / ra, size=k).astype(np.int64)
        return np.full(k, ra, dtype=np.int64)

    def _step_membership(self, r: int):
        """Apply the churn model at the boundary before round ``r``."""
        rejoined = np.flatnonzero(self.rejoin_at == r)
        if rejoined.size:
            self.active[rejoined] = True
            self.rejoin_at[rejoined] = -1

        # Bernoulli leaves over peers active before this boundary (a
        # peer that just rejoined is exempt for one boundary).
        candidates = np.flatnonzero(self.active)
        candidates = np.setdiff1d(candidates, rejoined,
                                  assume_unique=True)
        leaving = candidates[self.rng.random(candidates.size)
                             < self.churn.leave_prob]
        # Clamp: never let the active count fall below the floor —
        # a leave may shrink the collective but must never block it.
        # (Mid-round drops may already have us below the floor, so cap
        # the cancellation at the whole leave set.)
        budget = int(self.active.sum()) - leaving.size - self.min_active
        if budget < 0:
            keep = self.rng.choice(leaving.size,
                                   size=min(-budget, leaving.size),
                                   replace=False)
            leaving = np.delete(leaving, keep)
        if leaving.size:
            self.active[leaving] = False
            if self.churn.rejoin_after > 0:
                self.rejoin_at[leaving] = r + self._rejoin_delays(
                    leaving.size)

        # Poisson fresh joins: new global ids, sticky capacities.
        n_new = (int(self.rng.poisson(self.churn.join_rate))
                 if self.churn.join_rate > 0 else 0)
        joined = np.arange(self.n_peers, self.n_peers + n_new,
                           dtype=np.int64)
        if n_new:
            self._grow(n_new)
        newly_active = np.concatenate([rejoined, joined])
        if self.evolve:
            self._repair_overlay(newly_active)
        return joined, leaving, rejoined

    def _grow(self, n_new: int):
        """Extend all per-peer arrays for ``n_new`` fresh joiners."""
        cfg = self.cfg
        old = self.n_peers
        self.n_peers += n_new
        self.active = np.concatenate(
            [self.active, np.ones(n_new, dtype=bool)])
        self.rejoin_at = np.concatenate(
            [self.rejoin_at, np.full(n_new, -1, dtype=np.int64)])
        if not self.evolve:
            # Re-roll mode samples overlay + capacities fresh each
            # round anyway; only the membership arrays persist.
            return
        ub, db = self.link_model.sample_rates(n_new, self.rng)
        u, d = cap.quantize_rates(ub, db, cfg.chunk_bytes,
                                  cfg.slot_seconds,
                                  warn=(self.time_engine == "slot"))
        self.up_bps = np.concatenate([self.up_bps, ub])
        self.down_bps = np.concatenate([self.down_bps, db])
        self.up = np.concatenate([self.up, u])
        self.down = np.concatenate([self.down, d])
        adj = np.zeros((self.n_peers, self.n_peers), dtype=bool)
        adj[:old, :old] = self.adj
        self.adj = adj
        exp = np.zeros((self.n_peers, self.n_peers), dtype=np.int64)
        exp[:old, :old] = self._exposure
        self._exposure = exp

    # -- incremental overlay evolution ----------------------------------
    def _attach(self, v: int, need: int, ids: np.ndarray):
        """Add ``need`` edges from ``v`` to random active non-neighbors."""
        cands = ids[~self.adj[v, ids]]
        cands = cands[cands != v]
        if cands.size == 0 or need <= 0:
            return
        pick = self.rng.choice(cands, size=min(need, cands.size),
                               replace=False)
        self.adj[v, pick] = True
        self.adj[pick, v] = True

    def _repair_overlay(self, newly_active: np.ndarray):
        """Incremental edge repair instead of a full per-round re-roll.

        Joiners/rejoiners attach up to ``min_degree`` edges (rejoiners
        keep whatever edges survived); survivors whose *active* degree
        fell below ``min_degree`` get repair edges; finally the active
        subgraph is re-connected if churn split it.  Dormant edges of
        inactive peers are retained for their possible rejoin.
        """
        m = self.cfg.min_degree
        ids = np.flatnonzero(self.active)
        if ids.size <= 1:
            return
        for v in newly_active:
            deg = int(self.adj[v, ids].sum())
            self._attach(int(v), m - deg, ids)
        # Survivors under-degreed because their neighbors left.
        deg_active = self.adj[np.ix_(ids, ids)].sum(axis=1)
        for v in ids[deg_active < min(m, ids.size - 1)]:
            deg = int(self.adj[v, ids].sum())
            self._attach(int(v), m - deg, ids)
        # Heterogeneous extras for fresh joiners (mirrors the full
        # generator's extra_edge_frac so degree spread survives churn).
        n_extra = int(self.cfg.extra_edge_frac * newly_active.size * m / 2)
        for _ in range(n_extra):
            v = int(self.rng.choice(newly_active))
            self._attach(v, 1, ids)
        # Churn can disconnect the active subgraph; bridge components.
        sub = self.adj[np.ix_(ids, ids)]
        comp = _components(sub)
        while comp.max() > 0:
            a = int(self.rng.choice(np.flatnonzero(comp == 0)))
            b = int(self.rng.choice(np.flatnonzero(comp != 0)))
            ga, gb = int(ids[a]), int(ids[b])
            self.adj[ga, gb] = self.adj[gb, ga] = True
            sub = self.adj[np.ix_(ids, ids)]
            comp = _components(sub)

    # -- round execution -------------------------------------------------
    def begin_round(self) -> np.ndarray:
        """Apply boundary churn for the upcoming round; return the
        round's active set as global peer ids (ascending — local client
        index ``i`` of the round maps to ``ids[i]``).

        Splitting the boundary from the dissemination lets a caller (the
        FL runner) decide *who trains* before the round runs: rejoiners
        re-download the current model here, absent clients sit out.
        Idempotent until :meth:`run_round` consumes the begun round.
        """
        if self._pending is None:
            r = self.round_idx
            joined = left = rejoined = np.zeros(0, dtype=np.int64)
            if r > 0 and self.churn.enabled:
                joined, left, rejoined = self._step_membership(r)
            ids = np.flatnonzero(self.active)
            # Spray-policy hook: with the boundary applied, the policy
            # decides what each source sprays (churn-aware budgets);
            # None keeps the simulator's full re-spray byte-identical.
            plan = (self.spray_policy.plan(self, ids)
                    if self.spray_policy is not None else None)
            self._pending = (r, ids, joined, left, rejoined, plan)
        return self._pending[1]

    def next_round(self, **kw) -> SessionRound:
        """Advance membership (boundary churn) and run one round."""
        self.begin_round()
        return self.run_round(**kw)

    def run_round(self, *, dropouts: dict | None = None,
                  byzantine=None,
                  collect_maxflow: bool = False,
                  quorum_k: int | None = None,
                  tail_mode: str = "none",
                  bt_budget: int | None = None) -> SessionRound:
        """Run the dissemination round begun by :meth:`begin_round`.

        ``quorum_k``/``tail_mode``/``bt_budget`` are the async hooks
        (fl/asyncfl.py): a FedBuff quorum cuts the BT phase once
        ``quorum_k`` updates are swarm-complete (or after ``bt_budget``
        directive cycles — the deadline whose *masking* the async
        runner removes), and the undelivered tail is either drained at
        the boundary (``"drain"``, serialized wall clock) or carried as
        background flows into the NEXT round's event engine
        (``"carry"``, overlapped dissemination).  The defaults leave the
        sync path byte-identical.
        """
        self.begin_round()
        r, ids, joined, left, rejoined, plan = self._pending
        self._pending = None
        orec = obs.get()
        if orec.enabled:
            # Rows recorded inside this round carry the session round
            # index and land on the session wall clock (offsets[r]).
            orec.set_ctx(round=int(r))
            orec.time_base = float(self.offsets[-1])
            orec.event("session.round_start", t=0.0,
                       active=int(ids.size), joined=int(joined.size),
                       left=int(left.size), rejoined=int(rejoined.size),
                       population=int(self.n_peers))
        background, bmeta, dead_updates = self._map_backlog(r, ids,
                                                            tail_mode)
        if orec.enabled and background is not None:
            orec.gauge("session.carry_backlog", int(background[0].size))
        cfg_r = self.cfg.replace(n=int(ids.size),
                                 seed=int(self.round_seed(r)))
        if self.evolve:
            sub_adj = self.adj[np.ix_(ids, ids)]
            sim = RoundSimulator(
                cfg_r, self.link_model, dropouts=dropouts,
                byzantine=byzantine, bt_mode=self.bt_mode,
                overlay=sub_adj, up=self.up[ids], down=self.down[ids],
                up_bps=self.up_bps[ids], down_bps=self.down_bps[ids],
                rng=np.random.default_rng(cfg_r.seed),
                spray_plan=plan, time_engine=self.time_engine,
                net=self.net, background=background)
            self._exposure[np.ix_(ids, ids)] += sub_adj
        else:
            # Back-compat path: bit-identical to the historical
            # ``simulate_round(cfg.replace(seed=round_seed(r)))`` loop.
            sim = RoundSimulator(cfg_r, self.link_model,
                                 dropouts=dropouts, byzantine=byzantine,
                                 bt_mode=self.bt_mode, spray_plan=plan,
                                 time_engine=self.time_engine,
                                 net=self.net, background=background)
        res = sim.run(collect_maxflow=collect_maxflow,
                      quorum_k=quorum_k, tail_mode=tail_mode,
                      bt_budget=bt_budget)

        dropped = ids[~res.active]
        if self.evolve and dropped.size:
            # A mid-round dropout is a leave observed at the deadline:
            # it sits out and rejoins at a later round boundary.
            self.active[dropped] = False
            if self.churn.rejoin_after > 0:
                self.rejoin_at[dropped] = r + 1 + self._rejoin_delays(
                    dropped.size)
        rec = SessionRound(round_idx=r, active_ids=ids, result=res,
                           joined=joined, left=left, rejoined=rejoined,
                           dropped_midround=dropped, spray_plan=plan,
                           drain_s=res.drain_s)
        rec.dead_updates.extend(dead_updates)
        self._settle_async(rec, r, ids, res, bmeta, tail_mode)
        self.offsets.append(self.offsets[-1] + res.metrics.t_round_s
                            + res.drain_s)
        orec = obs.get()
        if orec.enabled:
            orec.event("session.round_end",
                       t=res.metrics.t_round_s + res.drain_s,
                       dropped_midround=int(dropped.size),
                       cut=bool(res.cut),
                       late_ready=len(rec.late_ready),
                       dead_updates=len(rec.dead_updates))
            orec.counter("session.rounds")
            orec.gauge("session.backlog_rows",
                       int(len(self._backlog["snd"]))
                       if self._backlog is not None else 0)
        self.history.append(rec)
        self.round_idx += 1
        return rec

    # -- async tail bookkeeping (fl/asyncfl.py) ---------------------------
    def _map_backlog(self, r: int, ids: np.ndarray, tail_mode: str):
        """Re-key the carry backlog from global ids to round-``r`` local
        ids and RE-PLAN every row's sender from the current holder set.

        A row whose RECEIVER departed is no longer needed (the absent
        peer re-syncs via the FL catch-up path on rejoin).  Senders are
        not fixed at extraction: each boundary every surviving row gets
        the least-loaded ACTIVE holder of its chunk — background
        deliveries grow the holder sets (:meth:`_settle_async`), so a
        chunk seeded once relays through fast peers in later rounds
        (exponential spread) instead of fanning out of its original
        holder forever.  An update none of whose holders remain active
        is dead and reported."""
        if tail_mode != "carry" or self._backlog is None:
            return None, None, []
        b = self._backlog
        self._backlog = None
        lr, r_ok = _locate(ids, b["rcv"])
        # Receiver-departed entries shrink the outstanding counts: the
        # update completes over the peers still active.
        for g, o in zip(b["gen"][~r_ok], b["owner"][~r_ok]):
            key = (int(g), int(o))
            if key in self._outstanding:
                self._outstanding[key] -= 1
        keep = r_ok.copy()
        snd_local = np.zeros(len(keep), np.int64)
        # Per-holder service-time estimate: queued rows / uplink rate.
        # Without the rate term a straggler uplink (32x slower) draws
        # the same share of rows as a fast peer and every update strands
        # a few rows behind it for an extra round.
        if self.up_bps is not None:
            inv_up = {int(v): 1.0 / float(self.up_bps[g])
                      for v, g in enumerate(ids)}
        else:
            inv_up = None
        load: dict[int, int] = {}
        hcache: dict[tuple[int, int], np.ndarray] = {}
        dead_set: set[tuple[int, int]] = set()
        for i in np.flatnonzero(keep):
            ckey = (int(b["gen"][i]), int(b["chunk"][i]))
            hs = hcache.get(ckey)
            if hs is None:
                hg = self._holders.get(ckey)
                if hg is None:
                    hs = np.zeros(0, np.int64)
                else:
                    lp, ok = _locate(ids, hg)
                    hs = lp[ok]
                hcache[ckey] = hs
            if hs.size == 0:
                dead_set.add((int(b["gen"][i]), int(b["owner"][i])))
                keep[i] = False
                continue
            # Least-finish-time active holder, ties to the lowest local
            # id — deterministic, and balances scarce-chunk fan-out
            # across the holder set as it grows.
            if inv_up is not None:
                best = int(min(hs, key=lambda v: (
                    (load.get(int(v), 0) + 1) * inv_up[int(v)], int(v))))
            else:
                best = int(min(hs, key=lambda v: (load.get(int(v), 0),
                                                  int(v))))
            load[best] = load.get(best, 0) + 1
            snd_local[i] = best
        dead = []
        if dead_set:
            for i in np.flatnonzero(keep):
                if (int(b["gen"][i]), int(b["owner"][i])) in dead_set:
                    keep[i] = False
            for key in dead_set:
                if self._outstanding.pop(key, None) is not None:
                    dead.append(key)
                self._gc_update(key)
        if not keep.any():
            return None, None, dead
        bmeta = {k: v[keep] for k, v in b.items()}
        bmeta["snd"] = ids[snd_local[keep]]
        # Queue order is delivery priority (per-flow pipelines follow
        # it): oldest generation first, then OWNER-MAJOR within a
        # generation — completing one update everywhere before starting
        # the next turns "87% of every update delivered" (zero merges)
        # into "87% of updates delivered completely" (staleness-1
        # merges).
        order = np.lexsort((bmeta["chunk"], bmeta["owner"],
                            bmeta["gen"]))
        bmeta = {k: v[order] for k, v in bmeta.items()}
        background = (snd_local[keep][order], lr[keep][order],
                      np.arange(order.size, dtype=np.int64))
        return background, bmeta, dead

    def _gc_update(self, key: tuple[int, int]):
        """Drop the holder-tracking state of a finished/dead update."""
        gen = key[0]
        for c in np.asarray(self._update_chunks.pop(key, ()), np.int64):
            self._holders.pop((gen, int(c)), None)

    def _settle_async(self, rec: SessionRound, r: int, ids: np.ndarray,
                      res: RoundResult, bmeta: dict | None,
                      tail_mode: str):
        """Assemble the round's late-delivery trace, update outstanding
        counts, queue the fresh tail, and mark newly-complete updates."""
        K = self.cfg.chunks_per_update
        delivered: list[tuple[int, int]] = []
        if tail_mode == "drain" and res.late is not None:
            la = res.late
            n = len(la["snd"])
            gen = np.full(n, r, dtype=np.int32)
            # Boundary-drain rows belong to the NEXT round's timeline at
            # negative offsets: wall time = offsets[r+1] + t, with
            # t in [-drain_s, 0] — strictly before round r+1's own rows.
            rec.late_log = TransferTrace.from_arrays(
                K=K, slot=la["slot"].astype(np.int32),
                sender=ids[la["snd"]].astype(np.int32),
                receiver=ids[la["rcv"]].astype(np.int32),
                chunk=la["chunk"],
                owner=ids[la["chunk"] // K].astype(np.int32),
                b_size=np.zeros(n, np.int64), o_size=np.zeros(n, np.int64),
                phase=np.full(n, 2, dtype=np.int8),
                round=np.full(n, r + 1, dtype=np.int32),
                t_start=la["t_start"] - res.drain_s,
                t_end=la["t_end"] - res.drain_s,
                generation=gen, staleness=np.ones(n, dtype=np.int32))
            delivered = [(r, int(o))
                         for o in np.unique(ids[la["chunk"] // K])]
        if tail_mode == "carry":
            if bmeta is not None and res.bg_delivered is not None \
                    and len(res.bg_delivered["meta"]):
                d = res.bg_delivered
                mi = np.asarray(d["meta"], np.int64)
                n = mi.size
                gen = bmeta["gen"][mi].astype(np.int32)
                rec.late_log = TransferTrace.from_arrays(
                    K=K, slot=np.zeros(n, np.int32),
                    sender=bmeta["snd"][mi].astype(np.int32),
                    receiver=bmeta["rcv"][mi].astype(np.int32),
                    chunk=bmeta["chunk"][mi],
                    owner=bmeta["owner"][mi].astype(np.int32),
                    b_size=np.zeros(n, np.int64),
                    o_size=np.zeros(n, np.int64),
                    phase=np.full(n, 2, dtype=np.int8),
                    round=np.full(n, r, dtype=np.int32),
                    t_start=d["t_start"], t_end=d["t_end"],
                    generation=gen,
                    staleness=(r - gen).astype(np.int32))
                for g, o, c in _group_counts(bmeta["gen"][mi],
                                             bmeta["owner"][mi]):
                    key = (g, o)
                    left_n = self._outstanding.get(key)
                    if left_n is None:
                        continue
                    self._outstanding[key] = left_n - c
                # Delivered receivers become holders: the relay replanner
                # picks them as senders at the next boundary.
                for g, c2 in sorted({(int(g_), int(c_)) for g_, c_ in
                                     zip(bmeta["gen"][mi],
                                         bmeta["chunk"][mi])}):
                    got = bmeta["rcv"][mi][
                        (bmeta["gen"][mi] == g)
                        & (bmeta["chunk"][mi] == c2)]
                    old = self._holders.get((g, c2))
                    if old is not None:
                        self._holders[(g, c2)] = np.union1d(old, got)
            # Requeue the survivors plus this round's fresh tail (older
            # generations first: queue order is pipeline priority).
            parts = []
            if bmeta is not None and res.bg_remaining is not None \
                    and res.bg_remaining.size:
                rm = np.asarray(res.bg_remaining, np.int64)
                parts.append({k: v[rm] for k, v in bmeta.items()})
            if res.tail is not None:
                t = res.tail
                for o in np.asarray(t["dead_owners"], np.int64):
                    rec.dead_updates.append((r, int(ids[o])))
                nt = len(t["snd"])
                if nt:
                    owner_g = ids[t["chunk"] // K]
                    parts.append({"snd": ids[t["snd"]],
                                  "rcv": ids[t["rcv"]],
                                  "chunk": t["chunk"],
                                  "owner": owner_g,
                                  "gen": np.full(nt, r, dtype=np.int64)})
                    for g, o, c in _group_counts(
                            np.full(nt, r, dtype=np.int64), owner_g):
                        self._outstanding[(g, o)] = \
                            self._outstanding.get((g, o), 0) + c
                    # Seed the relay state with cut-time holder sets.
                    ucols = np.asarray(t["ucols"], np.int64)
                    hmask = t["holder_mask"]
                    for j, c2 in enumerate(ucols):
                        self._holders[(r, int(c2))] = ids[hmask[:, j]]
                    uown = np.unique(ids[ucols // K])
                    for o in uown:
                        self._update_chunks[(r, int(o))] = \
                            ucols[ids[ucols // K] == o]
            if parts:
                self._backlog = {k: np.concatenate([p[k] for p in parts])
                                 for k in ("snd", "rcv", "chunk",
                                           "owner", "gen")}
            # Updates whose last outstanding chunk landed this round are
            # ready for the round-r merge (staleness r - gen > 0).
            done = [k for k, v in self._outstanding.items() if v <= 0]
            for k in done:
                del self._outstanding[k]
                self._gc_update(k)
            rec.late_ready.extend(done)
        elif tail_mode == "drain":
            if res.tail is not None:
                for o in np.asarray(res.tail["dead_owners"], np.int64):
                    rec.dead_updates.append((r, int(ids[o])))
            rec.late_ready.extend(delivered)

    # -- cross-round wall clock (async) -----------------------------------
    def wall_trace(self, include_late: bool = True) -> TransferTrace:
        """The session trace on ONE wall clock: every row's time columns
        shifted by its round's start offset, so cross-round orderings
        (overlap, boundary drains) are directly comparable."""
        parts = [rec.global_log() for rec in self.history]
        if include_late:
            parts += [rec.late_log for rec in self.history
                      if rec.late_log is not None]
        tr = TransferTrace.concat([p for p in parts if len(p)])
        if not len(tr):
            return tr
        S = np.asarray(self.offsets, np.float64)
        shift = S[np.minimum(tr.round, len(S) - 1)]
        tr.t_start = tr.t_start + shift
        tr.t_end = tr.t_end + shift
        return tr

    def run(self, rounds: int, **kw) -> list[SessionRound]:
        return [self.next_round(**kw) for _ in range(rounds)]

    # -- cross-round observation surface ---------------------------------
    def trace(self, include_late: bool = False) -> TransferTrace:
        """The session-wide :class:`TransferTrace`: every round's log in
        global peer ids with the ``round`` column stamped — the input
        cross-round adversaries (``attacks.persistent_neighbor_linkage``)
        consume together with :meth:`pair_exposure`.

        ``include_late`` appends the async late-delivery rows
        (generation < round, staleness > 0).  They keep their
        round-local chunk ids, so descriptor-keyed grading
        (``desc_owner_lookup``) over a mixed trace should use
        :func:`repro.fl.asyncfl.adversary_view`, which band-shifts late
        descriptors into a disjoint range per generation."""
        parts = [rec.global_log() for rec in self.history]
        if include_late:
            parts += [rec.late_log for rec in self.history
                      if rec.late_log is not None]
        return TransferTrace.concat(parts)

    # -- cross-round topology metrics (privacy §III-E) -------------------
    def _round_edges(self, rec: SessionRound) -> set:
        ids = rec.active_ids
        iu, iv = np.nonzero(np.triu(rec.result.adj, 1))
        return set(zip(ids[iu].tolist(), ids[iv].tolist()))

    def edge_persistence(self) -> float:
        """Mean Jaccard overlap of consecutive rounds' edge sets (global
        ids).  0 = fully re-rolled topology (today's per-round loop);
        1 = frozen topology.  The quantity topology-dependent privacy
        bounds grow with: persistent neighbor pairs accumulate linkable
        observations across rounds."""
        if len(self.history) < 2:
            return 0.0
        vals = []
        prev = self._round_edges(self.history[0])
        for rec in self.history[1:]:
            cur = self._round_edges(rec)
            union = len(prev | cur)
            vals.append(len(prev & cur) / union if union else 0.0)
            prev = cur
        return float(np.mean(vals))

    def pair_exposure(self) -> np.ndarray:
        """(n_peers, n_peers) count of rounds each pair was adjacent."""
        if self._exposure is not None:
            return self._exposure.copy()
        exp = np.zeros((self.n_peers, self.n_peers), dtype=np.int64)
        for rec in self.history:
            ids = rec.active_ids
            exp[np.ix_(ids, ids)] += rec.result.adj
        return exp

    def wall_clock(self) -> dict:
        """Per-round wall-clock metrics across churn (seconds).

        Keys: ``t_warm_s``, ``t_round_s``, ``warmup_share_s``,
        ``control_s`` — arrays of length ``len(history)``.  Under the
        slot engine these are the slot grid in seconds; under the event
        engine they are realized transport makespans plus tracker
        control time.
        """
        ms = [rec.result.metrics for rec in self.history]
        return {
            "t_warm_s": np.array([m.t_warm_s for m in ms]),
            "t_round_s": np.array([m.t_round_s for m in ms]),
            "warmup_share_s": np.array([m.warmup_share_s for m in ms]),
            "control_s": np.array([m.control_s for m in ms]),
        }

    def participation(self) -> np.ndarray:
        """Per-round active fraction relative to the current population."""
        return np.array([rec.active_ids.size
                         / max(1, self._pop_at(rec)) for rec in
                         self.history])

    def _pop_at(self, rec: SessionRound) -> int:
        joined_later = sum(r.joined.size for r in self.history
                           if r.round_idx > rec.round_idx)
        return self.n_peers - joined_later
