"""SwarmSession: a persistent multi-round swarm with cross-round churn.

The paper's §III-E semantics — clients join and leave *between* rounds,
leavers rejoin at a later round boundary, and every round's aggregation
proceeds over whatever active set reconstructs — need state that
outlives a single :class:`~repro.core.simulator.RoundSimulator`.  This
module carries that state:

* a **persistent peer population** with stable global ids: capacities
  are sampled once when a peer joins and stick for its lifetime,
* a **churn model** applied at round boundaries: Bernoulli leaves,
  Poisson joins of fresh peers, and planned rejoins ``rejoin_after``
  rounds later (the paper's rejoin-at-round-boundary rule),
* **incremental overlay evolution**: instead of re-rolling the whole
  graph every round, edges of departed peers go dormant, joiners attach
  with ``min_degree`` repair edges, and survivors whose active degree
  dropped get repair edges — so cross-round attack and privacy metrics
  (``edge_persistence``, ``pair_exposure``) can be computed against the
  topology as it actually *evolves*, which is what topology-dependent
  privacy bounds are a function of.

Usage
-----
::

    from repro.core import SwarmConfig
    from repro.core.session import ChurnModel, SwarmSession

    cfg = SwarmConfig(n=40, chunks_per_update=16, min_degree=5)
    ses = SwarmSession(cfg, churn=ChurnModel(leave_prob=0.1,
                                             join_rate=1.0,
                                             rejoin_after=2))
    for _ in range(10):
        rec = ses.next_round()
        rec.result.metrics          # RoundMetrics of this round's sub-swarm
        rec.active_ids              # local index i <-> global peer rec.active_ids[i]
    ses.edge_persistence()          # cross-round edge overlap in [0, 1]
    ses.pair_exposure().max()       # most-exposed neighbor pair (rounds)

Zero churn (the default, ``SwarmSession(cfg)``) reproduces today's
per-round ``simulate_round`` loop **bit-identically**: every round
re-rolls overlay and capacities from ``round_seed(r)`` exactly like
``RoundSimulator(cfg.replace(seed=round_seed(r)))`` — asserted
seed-for-seed in ``tests/test_session.py``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from collections.abc import Callable

import numpy as np

from . import capacities as cap
from .overlay import _components, random_overlay
from .simulator import RoundResult, RoundSimulator
from .trace import TransferTrace
from .types import SwarmConfig


@dataclass(frozen=True)
class ChurnModel:
    """Cross-round membership dynamics (paper §III-E).

    ``leave_prob``  — per-active-peer Bernoulli leave probability at each
    round boundary; ``join_rate`` — Poisson mean of *fresh* peers joining
    per boundary; ``rejoin_after`` — a leaver rejoins at the boundary
    this many rounds later (0 = leavers never come back).

    ``rejoin_dist`` selects the rejoin-delay law: ``"fixed"`` is the
    historical deterministic delay; ``"geometric"`` samples each
    leaver's delay from Geometric(1/rejoin_after) (mean
    ``rejoin_after``), modelling heterogeneous absence durations.
    ``participation()`` stays exact either way — it is computed from the
    realized membership history, not the delay law.
    """

    leave_prob: float = 0.0
    join_rate: float = 0.0
    rejoin_after: int = 2
    rejoin_dist: str = "fixed"      # "fixed" | "geometric"

    def __post_init__(self):
        if self.rejoin_dist not in ("fixed", "geometric"):
            raise ValueError(
                f"unknown rejoin_dist {self.rejoin_dist!r}")

    @property
    def enabled(self) -> bool:
        return self.leave_prob > 0.0 or self.join_rate > 0.0


@dataclass
class SprayPlan:
    """Explicit pre-round spray directives for one round (local ids).

    Produced by a :class:`SprayPolicy` at the round boundary and applied
    verbatim by the simulator's spray step.  ``fresh`` marks directives
    that open a NEW ephemeral tunnel (a true re-spray); unset rows reuse
    a tunnel that survived from an earlier round — the cost churn-aware
    budgeting saves.
    """

    src: np.ndarray                 # local source indices
    tgt: np.ndarray                 # local target indices (non-neighbors)
    offset: np.ndarray              # within-update chunk offsets
    fresh: np.ndarray               # bool: new tunnel vs reused

    def as_local_arrays(self):
        return (np.asarray(self.src, np.int64),
                np.asarray(self.tgt, np.int64),
                np.asarray(self.offset, np.int64))

    def fresh_counts(self, n: int) -> np.ndarray:
        """(n,) fresh-tunnel count per local source."""
        src = np.asarray(self.src, np.int64)
        return np.bincount(src[np.asarray(self.fresh, bool)], minlength=n)


class SprayPolicy:
    """Policy hook on :meth:`SwarmSession.begin_round`: decide what each
    source sprays this round.  Returning ``None`` keeps the historical
    full re-spray path (byte-identical; the simulator draws its own
    targets)."""

    def plan(self, session: "SwarmSession",
             ids: np.ndarray) -> SprayPlan | None:
        return None


class ChurnAwareSpray(SprayPolicy):
    """Churn-aware spray budgets (§III-B.1 under §III-E churn).

    The session tracks, per source, which sprayed chunk offsets still
    have a *live* tunnel: the holder is active and remains a
    non-neighbor of the source under the evolving overlay.  At every
    round boundary each active source re-sprays ONLY the offsets whose
    replication dropped below the per-offset target (holder left,
    dropped mid-round, or became a neighbor) — in particular a rejoiner
    re-sprays exactly the coverage it lost while absent — and reuses the
    surviving tunnels for the rest, so the per-round obfuscation mass
    (sigma chunks per source, Eq. 1's mixing input) is preserved while
    fresh tunnel setups shrink to the churn-induced delta.

    Requires an evolving-overlay session (``SwarmSession`` with churn or
    ``evolve_overlay=True``): tunnel validity is a statement about the
    persistent topology.
    """

    def __init__(self):
        # (n_peers, m) ledgers, -1 = dead slot; grown lazily with joins.
        self._offs: np.ndarray | None = None
        self._holds: np.ndarray | None = None

    def _grown(self, P: int, m: int):
        if self._offs is None:
            self._offs = np.full((P, m), -1, np.int64)
            self._holds = np.full((P, m), -1, np.int64)
        elif self._offs.shape[0] < P:
            pad = np.full((P - self._offs.shape[0], m), -1, np.int64)
            self._offs = np.vstack([self._offs, pad])
            self._holds = np.vstack([self._holds, pad])
        return self._offs, self._holds

    def plan(self, ses: "SwarmSession",
             ids: np.ndarray) -> SprayPlan | None:
        """Fully vectorized over the (source, tunnel-slot) ledger — no
        per-peer Python loop at the round boundary (the boundary is on
        the per-round critical path at paper-scale populations)."""
        if not ses.evolve:
            raise ValueError(
                "ChurnAwareSpray needs an evolving-overlay session "
                "(enable churn or evolve_overlay=True)")
        cfg = ses.cfg
        K = cfg.chunks_per_update
        m = min(cfg.spray_copies, K)
        if m == 0 or ids.size == 0:
            return None
        rng = ses.rng
        P = ses.n_peers
        all_offs, all_holds = self._grown(P, m)
        R = ids.size
        rr = np.arange(R)[:, None]
        offs = all_offs[ids]
        holds = all_holds[ids]
        # Tunnel survival: holder in this round's active set and still
        # a non-neighbor (overlay repair may have linked them).
        in_round = np.zeros(P, dtype=bool)
        in_round[ids] = True
        hsafe = np.clip(holds, 0, P - 1)
        valid = (holds >= 0) & in_round[hsafe] \
            & ~ses.adj[ids[:, None], hsafe]
        # Compact surviving tunnels to the front; invalid slots trail
        # and become the fresh re-spray positions.
        order = np.argsort(~valid, axis=1, kind="stable")
        offs, holds = offs[rr, order], holds[rr, order]
        keep = valid[rr, order]
        fresh_slot = ~keep
        # Fresh offsets: per row, distinct draws from the complement of
        # the kept offsets — kept keys pinned to +inf, row-sorted, the
        # j-th fresh slot takes the j-th cheapest complement offset.
        keys = rng.random((R, K))
        rk, ck = np.nonzero(keep)
        keys[rk, offs[rk, ck]] = np.inf
        oorder = np.argsort(keys, axis=1)
        j = np.cumsum(fresh_slot, axis=1) - 1
        offs = np.where(fresh_slot, oorder[rr, np.clip(j, 0, K - 1)],
                        offs)
        # Fresh targets: one uniform active non-neighbor per fresh slot
        # (rank-pick into the stable-sorted non-neighbor columns, the
        # RoundSimulator._spray technique).
        nn = ~ses.adj[np.ix_(ids, ids)]
        nn[np.arange(R), np.arange(R)] = False
        cnt = nn.sum(axis=1)
        can = cnt > 0
        torder = np.argsort(~nn, axis=1, kind="stable")
        pick = (rng.random((R, m))
                * np.maximum(cnt, 1)[:, None]).astype(np.int64)
        tglob = ids[torder[rr, pick]]
        holds = np.where(fresh_slot & can[:, None], tglob, holds)
        live = keep | (fresh_slot & can[:, None])
        all_offs[ids] = np.where(live, offs, -1)
        all_holds[ids] = np.where(live, holds, -1)
        rsel, csel = np.nonzero(live)
        if rsel.size == 0:
            return None
        return SprayPlan(src=rsel.astype(np.int64),
                         tgt=np.searchsorted(ids, holds[rsel, csel]),
                         offset=offs[rsel, csel],
                         fresh=fresh_slot[rsel, csel])


@dataclass
class SessionRound:
    """One session round: the sub-swarm result plus membership events.

    ``active_ids`` maps the round simulator's local client indices to
    stable global peer ids (``local i <-> global active_ids[i]``); all
    event arrays hold global ids.
    """

    round_idx: int
    active_ids: np.ndarray
    result: RoundResult
    joined: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    left: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    rejoined: np.ndarray = field(
        default_factory=lambda: np.zeros(0, np.int64))
    dropped_midround: np.ndarray = field(
        default_factory=lambda: np.zeros(0, np.int64))
    spray_plan: SprayPlan | None = None

    @property
    def t_warm_s(self) -> float:
        """Wall-clock warm-up duration (spray + cycles + control)."""
        return self.result.metrics.t_warm_s

    @property
    def t_round_s(self) -> float:
        return self.result.metrics.t_round_s

    @property
    def warmup_share_s(self) -> float:
        return self.result.metrics.warmup_share_s

    def global_log(self) -> TransferTrace:
        """The round's transfer trace with sender/receiver/owner re-keyed
        to global peer ids and the session ``round`` column stamped
        (chunk/descriptor ids stay local to the round's torrent;
        ``t_start``/``t_end`` stay round-relative — the ``round`` column
        is the cross-round clock)."""
        tr = self.result.log
        ids = self.active_ids
        return TransferTrace(
            K=tr.K,
            slot=tr.slot,
            sender=ids[np.asarray(tr.sender, np.int64)].astype(np.int32),
            receiver=ids[np.asarray(tr.receiver,
                                    np.int64)].astype(np.int32),
            chunk=tr.chunk,
            owner=ids[np.asarray(tr.owner, np.int64)].astype(np.int32),
            b_size=tr.b_size, o_size=tr.o_size, phase=tr.phase,
            round=np.full(len(tr), self.round_idx, dtype=np.int32),
            t_start=tr.t_start, t_end=tr.t_end)


class SwarmSession:
    """Persistent peer population carried across FL rounds.

    Parameters
    ----------
    cfg : SwarmConfig
        Template round config; ``cfg.n`` is the *initial* population.
        Each round runs with ``n`` = current active count and
        ``seed = round_seed(r)``.
    churn_rate : float
        Shorthand for ``ChurnModel(leave_prob=churn_rate)`` —
        ``churn_rate=0`` is the exact single-round-loop back-compat mode.
    churn : ChurnModel, optional
        Full churn spec; overrides ``churn_rate``.
    round_seed : callable(int) -> int, optional
        Per-round seed schedule; defaults to ``cfg.seed * 1000 + r``
        (the convention ``fl/runner.py`` has always used).
    evolve_overlay : bool, optional
        Force incremental topology evolution on/off.  Default: evolve
        exactly when churn is enabled, so the zero-churn session stays
        bit-identical to the historical per-round re-roll.
    """

    def __init__(self, cfg: SwarmConfig, *,
                 churn_rate: float = 0.0,
                 churn: ChurnModel | None = None,
                 link_model: cap.LinkModel = cap.RESIDENTIAL,
                 bt_mode: str = "auto",
                 round_seed: Callable[[int], int] | None = None,
                 evolve_overlay: bool | None = None,
                 spray_policy: SprayPolicy | None = None,
                 time_engine: str = "slot",
                 net=None):
        if churn is None:
            churn = ChurnModel(leave_prob=float(churn_rate))
        self.cfg = cfg
        self.churn = churn
        self.link_model = link_model
        self.bt_mode = bt_mode
        self.spray_policy = spray_policy
        # Time engine (§repro.net): "event" runs every round on the
        # continuous-time transport — wall-clock metrics (t_warm_s,
        # t_round_s, warmup_share_s) then persist across churn like
        # every other per-round metric.
        if time_engine not in ("slot", "event"):
            raise ValueError(f"unknown time_engine {time_engine!r}")
        self.time_engine = time_engine
        self.net = net
        self.round_seed = (round_seed if round_seed is not None
                           else lambda r: cfg.seed * 1000 + r)
        self.evolve = (churn.enabled if evolve_overlay is None
                       else bool(evolve_overlay))
        # Session-level stream (churn + overlay evolution), independent
        # of the per-round simulator streams so adding churn never
        # perturbs the in-round schedules of unaffected rounds.
        self.rng = np.random.default_rng(np.random.SeedSequence(
            [int(cfg.seed), 0x5E5510]))

        self.n_peers = cfg.n
        self.active = np.ones(cfg.n, dtype=bool)
        self.rejoin_at = np.full(cfg.n, -1, dtype=np.int64)
        self.round_idx = 0
        self.history: list[SessionRound] = []
        self._pending: tuple | None = None   # begun-but-not-run round

        if self.evolve:
            self.adj = random_overlay(cfg.n, cfg.min_degree,
                                      cfg.extra_edge_frac, self.rng)
            # Persist RAW rates alongside the quantized budgets: the
            # same draws feed both time domains (see capacities.py), so
            # swapping time_engine never perturbs the session streams.
            self.up_bps, self.down_bps = link_model.sample_rates(
                cfg.n, self.rng)
            self.up, self.down = cap.quantize_rates(
                self.up_bps, self.down_bps, cfg.chunk_bytes,
                cfg.slot_seconds, warn=(time_engine == "slot"))
            self._exposure = np.zeros((cfg.n, cfg.n), dtype=np.int64)
        else:
            self.adj = None
            self.up = self.down = None
            self.up_bps = self.down_bps = None
            self._exposure = None

    # -- membership (round boundaries) ----------------------------------
    @property
    def min_active(self) -> int:
        """Leave-clamp floor: a round needs min_degree+1 peers to mesh."""
        return self.cfg.min_degree + 1

    def _rejoin_delays(self, k: int) -> np.ndarray:
        """Per-leaver rejoin delay (rounds) under ``churn.rejoin_dist``.

        ``"fixed"`` keeps the historical deterministic delay (and draws
        nothing, so existing seeds are unperturbed); ``"geometric"``
        samples Geometric(1/rejoin_after), mean ``rejoin_after``.
        """
        ra = max(self.churn.rejoin_after, 1)
        if self.churn.rejoin_dist == "geometric":
            return self.rng.geometric(1.0 / ra, size=k).astype(np.int64)
        return np.full(k, ra, dtype=np.int64)

    def _step_membership(self, r: int):
        """Apply the churn model at the boundary before round ``r``."""
        rejoined = np.flatnonzero(self.rejoin_at == r)
        if rejoined.size:
            self.active[rejoined] = True
            self.rejoin_at[rejoined] = -1

        # Bernoulli leaves over peers active before this boundary (a
        # peer that just rejoined is exempt for one boundary).
        candidates = np.flatnonzero(self.active)
        candidates = np.setdiff1d(candidates, rejoined,
                                  assume_unique=True)
        leaving = candidates[self.rng.random(candidates.size)
                             < self.churn.leave_prob]
        # Clamp: never let the active count fall below the floor —
        # a leave may shrink the collective but must never block it.
        # (Mid-round drops may already have us below the floor, so cap
        # the cancellation at the whole leave set.)
        budget = int(self.active.sum()) - leaving.size - self.min_active
        if budget < 0:
            keep = self.rng.choice(leaving.size,
                                   size=min(-budget, leaving.size),
                                   replace=False)
            leaving = np.delete(leaving, keep)
        if leaving.size:
            self.active[leaving] = False
            if self.churn.rejoin_after > 0:
                self.rejoin_at[leaving] = r + self._rejoin_delays(
                    leaving.size)

        # Poisson fresh joins: new global ids, sticky capacities.
        n_new = (int(self.rng.poisson(self.churn.join_rate))
                 if self.churn.join_rate > 0 else 0)
        joined = np.arange(self.n_peers, self.n_peers + n_new,
                           dtype=np.int64)
        if n_new:
            self._grow(n_new)
        newly_active = np.concatenate([rejoined, joined])
        if self.evolve:
            self._repair_overlay(newly_active)
        return joined, leaving, rejoined

    def _grow(self, n_new: int):
        """Extend all per-peer arrays for ``n_new`` fresh joiners."""
        cfg = self.cfg
        old = self.n_peers
        self.n_peers += n_new
        self.active = np.concatenate(
            [self.active, np.ones(n_new, dtype=bool)])
        self.rejoin_at = np.concatenate(
            [self.rejoin_at, np.full(n_new, -1, dtype=np.int64)])
        if not self.evolve:
            # Re-roll mode samples overlay + capacities fresh each
            # round anyway; only the membership arrays persist.
            return
        ub, db = self.link_model.sample_rates(n_new, self.rng)
        u, d = cap.quantize_rates(ub, db, cfg.chunk_bytes,
                                  cfg.slot_seconds,
                                  warn=(self.time_engine == "slot"))
        self.up_bps = np.concatenate([self.up_bps, ub])
        self.down_bps = np.concatenate([self.down_bps, db])
        self.up = np.concatenate([self.up, u])
        self.down = np.concatenate([self.down, d])
        adj = np.zeros((self.n_peers, self.n_peers), dtype=bool)
        adj[:old, :old] = self.adj
        self.adj = adj
        exp = np.zeros((self.n_peers, self.n_peers), dtype=np.int64)
        exp[:old, :old] = self._exposure
        self._exposure = exp

    # -- incremental overlay evolution ----------------------------------
    def _attach(self, v: int, need: int, ids: np.ndarray):
        """Add ``need`` edges from ``v`` to random active non-neighbors."""
        cands = ids[~self.adj[v, ids]]
        cands = cands[cands != v]
        if cands.size == 0 or need <= 0:
            return
        pick = self.rng.choice(cands, size=min(need, cands.size),
                               replace=False)
        self.adj[v, pick] = True
        self.adj[pick, v] = True

    def _repair_overlay(self, newly_active: np.ndarray):
        """Incremental edge repair instead of a full per-round re-roll.

        Joiners/rejoiners attach up to ``min_degree`` edges (rejoiners
        keep whatever edges survived); survivors whose *active* degree
        fell below ``min_degree`` get repair edges; finally the active
        subgraph is re-connected if churn split it.  Dormant edges of
        inactive peers are retained for their possible rejoin.
        """
        m = self.cfg.min_degree
        ids = np.flatnonzero(self.active)
        if ids.size <= 1:
            return
        for v in newly_active:
            deg = int(self.adj[v, ids].sum())
            self._attach(int(v), m - deg, ids)
        # Survivors under-degreed because their neighbors left.
        deg_active = self.adj[np.ix_(ids, ids)].sum(axis=1)
        for v in ids[deg_active < min(m, ids.size - 1)]:
            deg = int(self.adj[v, ids].sum())
            self._attach(int(v), m - deg, ids)
        # Heterogeneous extras for fresh joiners (mirrors the full
        # generator's extra_edge_frac so degree spread survives churn).
        n_extra = int(self.cfg.extra_edge_frac * newly_active.size * m / 2)
        for _ in range(n_extra):
            v = int(self.rng.choice(newly_active))
            self._attach(v, 1, ids)
        # Churn can disconnect the active subgraph; bridge components.
        sub = self.adj[np.ix_(ids, ids)]
        comp = _components(sub)
        while comp.max() > 0:
            a = int(self.rng.choice(np.flatnonzero(comp == 0)))
            b = int(self.rng.choice(np.flatnonzero(comp != 0)))
            ga, gb = int(ids[a]), int(ids[b])
            self.adj[ga, gb] = self.adj[gb, ga] = True
            sub = self.adj[np.ix_(ids, ids)]
            comp = _components(sub)

    # -- round execution -------------------------------------------------
    def begin_round(self) -> np.ndarray:
        """Apply boundary churn for the upcoming round; return the
        round's active set as global peer ids (ascending — local client
        index ``i`` of the round maps to ``ids[i]``).

        Splitting the boundary from the dissemination lets a caller (the
        FL runner) decide *who trains* before the round runs: rejoiners
        re-download the current model here, absent clients sit out.
        Idempotent until :meth:`run_round` consumes the begun round.
        """
        if self._pending is None:
            r = self.round_idx
            joined = left = rejoined = np.zeros(0, dtype=np.int64)
            if r > 0 and self.churn.enabled:
                joined, left, rejoined = self._step_membership(r)
            ids = np.flatnonzero(self.active)
            # Spray-policy hook: with the boundary applied, the policy
            # decides what each source sprays (churn-aware budgets);
            # None keeps the simulator's full re-spray byte-identical.
            plan = (self.spray_policy.plan(self, ids)
                    if self.spray_policy is not None else None)
            self._pending = (r, ids, joined, left, rejoined, plan)
        return self._pending[1]

    def next_round(self, **kw) -> SessionRound:
        """Advance membership (boundary churn) and run one round."""
        self.begin_round()
        return self.run_round(**kw)

    def run_round(self, *, dropouts: dict | None = None,
                  byzantine=None,
                  collect_maxflow: bool = False) -> SessionRound:
        """Run the dissemination round begun by :meth:`begin_round`."""
        self.begin_round()
        r, ids, joined, left, rejoined, plan = self._pending
        self._pending = None
        cfg_r = self.cfg.replace(n=int(ids.size),
                                 seed=int(self.round_seed(r)))
        if self.evolve:
            sub_adj = self.adj[np.ix_(ids, ids)]
            sim = RoundSimulator(
                cfg_r, self.link_model, dropouts=dropouts,
                byzantine=byzantine, bt_mode=self.bt_mode,
                overlay=sub_adj, up=self.up[ids], down=self.down[ids],
                up_bps=self.up_bps[ids], down_bps=self.down_bps[ids],
                rng=np.random.default_rng(cfg_r.seed),
                spray_plan=plan, time_engine=self.time_engine,
                net=self.net)
            self._exposure[np.ix_(ids, ids)] += sub_adj
        else:
            # Back-compat path: bit-identical to the historical
            # ``simulate_round(cfg.replace(seed=round_seed(r)))`` loop.
            sim = RoundSimulator(cfg_r, self.link_model,
                                 dropouts=dropouts, byzantine=byzantine,
                                 bt_mode=self.bt_mode, spray_plan=plan,
                                 time_engine=self.time_engine,
                                 net=self.net)
        res = sim.run(collect_maxflow=collect_maxflow)

        dropped = ids[~res.active]
        if self.evolve and dropped.size:
            # A mid-round dropout is a leave observed at the deadline:
            # it sits out and rejoins at a later round boundary.
            self.active[dropped] = False
            if self.churn.rejoin_after > 0:
                self.rejoin_at[dropped] = r + 1 + self._rejoin_delays(
                    dropped.size)
        rec = SessionRound(round_idx=r, active_ids=ids, result=res,
                           joined=joined, left=left, rejoined=rejoined,
                           dropped_midround=dropped, spray_plan=plan)
        self.history.append(rec)
        self.round_idx += 1
        return rec

    def run(self, rounds: int, **kw) -> list[SessionRound]:
        return [self.next_round(**kw) for _ in range(rounds)]

    # -- cross-round observation surface ---------------------------------
    def trace(self) -> TransferTrace:
        """The session-wide :class:`TransferTrace`: every round's log in
        global peer ids with the ``round`` column stamped — the input
        cross-round adversaries (``attacks.persistent_neighbor_linkage``)
        consume together with :meth:`pair_exposure`."""
        return TransferTrace.concat(
            [rec.global_log() for rec in self.history])

    # -- cross-round topology metrics (privacy §III-E) -------------------
    def _round_edges(self, rec: SessionRound) -> set:
        ids = rec.active_ids
        iu, iv = np.nonzero(np.triu(rec.result.adj, 1))
        return set(zip(ids[iu].tolist(), ids[iv].tolist()))

    def edge_persistence(self) -> float:
        """Mean Jaccard overlap of consecutive rounds' edge sets (global
        ids).  0 = fully re-rolled topology (today's per-round loop);
        1 = frozen topology.  The quantity topology-dependent privacy
        bounds grow with: persistent neighbor pairs accumulate linkable
        observations across rounds."""
        if len(self.history) < 2:
            return 0.0
        vals = []
        prev = self._round_edges(self.history[0])
        for rec in self.history[1:]:
            cur = self._round_edges(rec)
            union = len(prev | cur)
            vals.append(len(prev & cur) / union if union else 0.0)
            prev = cur
        return float(np.mean(vals))

    def pair_exposure(self) -> np.ndarray:
        """(n_peers, n_peers) count of rounds each pair was adjacent."""
        if self._exposure is not None:
            return self._exposure.copy()
        exp = np.zeros((self.n_peers, self.n_peers), dtype=np.int64)
        for rec in self.history:
            ids = rec.active_ids
            exp[np.ix_(ids, ids)] += rec.result.adj
        return exp

    def wall_clock(self) -> dict:
        """Per-round wall-clock metrics across churn (seconds).

        Keys: ``t_warm_s``, ``t_round_s``, ``warmup_share_s``,
        ``control_s`` — arrays of length ``len(history)``.  Under the
        slot engine these are the slot grid in seconds; under the event
        engine they are realized transport makespans plus tracker
        control time.
        """
        ms = [rec.result.metrics for rec in self.history]
        return {
            "t_warm_s": np.array([m.t_warm_s for m in ms]),
            "t_round_s": np.array([m.t_round_s for m in ms]),
            "warmup_share_s": np.array([m.warmup_share_s for m in ms]),
            "control_s": np.array([m.control_s for m in ms]),
        }

    def participation(self) -> np.ndarray:
        """Per-round active fraction relative to the current population."""
        return np.array([rec.active_ids.size
                         / max(1, self._pop_at(rec)) for rec in
                         self.history])

    def _pop_at(self, rec: SessionRound) -> int:
        joined_later = sum(r.joined.size for r in self.history
                           if r.round_idx > rec.round_idx)
        return self.n_peers - joined_later
