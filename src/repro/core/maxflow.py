"""Stage-wise max-flow upper bound on warm-up throughput (paper §III-C.1).

A bandwidth-optimal stage schedule maximizes the number of chunks moved
within a stage given current inventories and per-stage chunk budgets.
Following the paper, we do NOT run max-flow online — it is an *offline*
upper bound computed with full knowledge of the stage state (Fig. 1).

Network construction (tripartite relaxation):

    S --(u_cap[u])--> sender u --(supply(u,v))--> receiver v --(d_cap[v])--> T

where ``supply(u, v)`` counts distinct chunks u could deliver to v this
stage (eligible at u, missing at v, adjacency).  The relaxation drops
cross-sender chunk-distinctness at a receiver, so the value is a valid
upper bound on any integral chunk assignment; heuristic utilization
reported against it is therefore conservative (the paper's ≈92% claim is
measured the same way: heuristic throughput / max-flow UB).

The paper's Lemma 1 / Appendix A show *makespan-optimal* warm-up
scheduling is (strongly) NP-complete via P|prec|C_max and 3-Partition,
which is why the system ships heuristics; the bound here is the
throughput-side companion used in Fig. 3.
"""
from __future__ import annotations

import networkx as nx
import numpy as np

from .state import SwarmState


def stage_upper_bound(state: SwarmState) -> int:
    """Max chunks transferable in the current stage (offline UB)."""
    cfg = state.cfg
    n = cfg.n
    sactive = state.senders_active()
    up = np.where(sactive, state.up, 0).astype(np.int64)
    down = np.where(state.active, state.down, 0).astype(np.int64)

    cand = state.candidate_columns(sactive)
    if cand.size == 0:
        return 0
    # Shared vectorized supply helper: one (n, m) eligibility build,
    # per-receiver rows are then plain slices (same path the batched
    # slot engine uses, so the UB sees exactly the engine's supply).
    sup_all = state.eligible_supply(cand)

    g = nx.DiGraph()
    for v in range(n):
        if down[v] <= 0 or not state.active[v]:
            continue
        nbr_idx = np.flatnonzero(state.adj[v] & (up > 0))
        if nbr_idx.size == 0:
            continue
        sup = sup_all[nbr_idx] & (~state.have[v, cand])[None, :]
        counts = sup.sum(axis=1)
        for j, u in enumerate(nbr_idx):
            if counts[j] > 0:
                g.add_edge(f"s{int(u)}", f"r{v}", capacity=int(counts[j]))
        if g.has_node(f"r{v}"):
            g.add_edge(f"r{v}", "T", capacity=int(down[v]))
    for u in range(n):
        if up[u] > 0 and g.has_node(f"s{u}"):
            g.add_edge("S", f"s{u}", capacity=int(up[u]))
    if not g.has_node("S") or not g.has_node("T"):
        return 0
    value, _ = nx.maximum_flow(g, "S", "T")
    return int(value)


# ----------------------------------------------------------------------
# Time-domain companion (repro.net): bandwidth-optimal seconds
# ----------------------------------------------------------------------

def stage_time_lower_bound(snd: np.ndarray, rcv: np.ndarray,
                           chunk_bytes: float,
                           up_bps: np.ndarray,
                           down_bps: np.ndarray) -> float:
    """Congestion lower bound (seconds) on transporting one cycle's
    scheduled transfers: no transport discipline can beat the busiest
    access link, ``max(bytes_out_u / up_u, bytes_in_v / down_v)``.

    The event engine's realized cycle makespan measured against this
    bound is the time-domain analogue of the paper's "~92% of the
    max-flow bound" claim: count-space max-flow bounds *what* could
    move per stage (:func:`stage_upper_bound`); this bounds *how fast*
    the chosen schedule could possibly move.
    """
    snd = np.asarray(snd, np.int64)
    rcv = np.asarray(rcv, np.int64)
    if snd.size == 0:
        return 0.0
    from repro.net.fairshare import congestion_bound
    return congestion_bound(
        snd, rcv, np.full(snd.size, float(chunk_bytes)),
        np.asarray(up_bps, np.float64),
        np.asarray(down_bps, np.float64))


def warmup_time_bounds(trace, chunk_bytes: float, up_bps: np.ndarray,
                       down_bps: np.ndarray):
    """Per-cycle (lower-bound, realized) warm-up transport seconds.

    ``realized`` is measured from the trace's wall-clock stamps
    (``max t_end - min t_start`` per cycle — exact for zero-latency
    event runs, a tight proxy otherwise); ``lb`` from
    :func:`stage_time_lower_bound` on the same cycle's transfers.
    ``sum(lb) / sum(realized)`` is the time-domain bandwidth
    efficiency reported by ``benchmarks/fig3_utilization.py``.
    """
    warm = trace.phase_slice("warmup")
    # One grouped pass over the trace (sort by cycle, slice at cycle
    # boundaries) instead of a full-trace mask per cycle — this runs
    # per scheduler per seed at n=500 bench scale.
    order = np.argsort(warm.slot, kind="stable")
    slot_s = warm.slot[order]
    slots, starts = np.unique(slot_s, return_index=True)
    ends = np.r_[starts[1:], slot_s.size]
    snd_s, rcv_s = warm.sender[order], warm.receiver[order]
    ts_s, te_s = warm.t_start[order], warm.t_end[order]
    lbs = np.zeros(slots.size)
    real = np.zeros(slots.size)
    for i, (a, b) in enumerate(zip(starts, ends)):
        lbs[i] = stage_time_lower_bound(snd_s[a:b], rcv_s[a:b],
                                        chunk_bytes, up_bps, down_bps)
        real[i] = float(te_s[a:b].max() - ts_s[a:b].min())
    return lbs, real
