"""Typed columnar transfer trace — the observation half of the contract.

The scheduler family (§III-C) decides what a sender may legally *do* per
slot; the privacy evaluation (§IV-C) decides what an adversary may
legally *see*.  :class:`TransferTrace` is the seeing half: one typed
struct-of-arrays event record shared by the simulator, the multi-round
:class:`~repro.core.session.SwarmSession`, the attack suite, the
empirical privacy-bound checks, and the tracker audit — replacing the
untyped ``log: dict`` that used to be threaded through all of them.

Columns (equal-length numpy arrays)
-----------------------------------
``slot``       int32   stage index within the round (spray rows use 0)
``sender``     int32   round pseudonym; global peer id in session traces
``receiver``   int32   likewise
``chunk``      int64   round-local global chunk id (``owner_local*K+i``)
``owner``      int32   ground-truth source — scoring only, never a
                       protocol observable (attacks read ``desc()``)
``b_size``     int64   sender's eligible buffer B_u at send time (Eq. 1)
``o_size``     int64   eligible owner count O_u at send time (Eq. 1)
``phase``      int8    0 = spray, 1 = warm-up, 2 = BT
``round``      int32   session round index (0 for single-round traces)
``t_start``    float64 wall-clock start of the transfer (seconds)
``t_end``      float64 wall-clock completion instant (seconds)
``generation`` int32   model generation the payload belongs to; equals
                       ``round`` for on-time rows, lags it for the late
                       deliveries of the async runner (fl/asyncfl.py)
``staleness``  int32   ``delivery_round - generation`` (0 = on time)

The two time columns are the continuous-time observation surface the
event engine (:mod:`repro.net`) opens: per-transfer start/finish
instants over max-min fair-share flows, i.e. the network-layer timing
side-channel (``attacks.timing_attribution``).  The slot engine stamps
slot boundaries (``t_start = slot * Δ``, ``t_end = (slot+1) * Δ``), so
ordering by ``t_start`` is always consistent with slot order and every
existing consumer keeps working unchanged.

Views are cheap: slicing helpers (:meth:`rounds_slice`,
:meth:`phase_slice`, :meth:`observed_by`) return new traces over
sub-arrays, and :meth:`desc` maps piece ids to torrent *descriptor* ids
— the only identity an attacker ever sees (§IV-C).

Backwards compatibility: the trace implements the mapping protocol
(``trace["slot"]``, ``dict(trace)``), so legacy consumers of the raw
log dict keep working; :meth:`from_log` coerces either representation.

Write your own adversary in ~20 lines
-------------------------------------
::

    def latecomer(trace, observers, K):
        view = trace.observed_by(observers).phase_slice("warmup")
        # last descriptor seen from each sender pseudonym
        order = np.argsort(view.slot, kind="stable")
        snd, desc = view.sender[order], view.desc()[order]
        guesses = {int(s): int(d) for s, d in zip(snd, desc)}
        hits = [g == s for s, g in guesses.items()]
        return float(np.mean(hits)) if hits else 0.0

(see ``examples/custom_policy.py`` for the runnable version).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from collections.abc import Iterator, Sequence

import numpy as np

PHASE_CODES = {"spray": 0, "warmup": 1, "bt": 2}

_KEYS = ("slot", "sender", "receiver", "chunk", "owner",
         "b_size", "o_size", "phase", "round", "t_start", "t_end",
         "generation", "staleness")
_DTYPES = {"slot": np.int32, "sender": np.int32, "receiver": np.int32,
           "chunk": np.int64, "owner": np.int32, "b_size": np.int64,
           "o_size": np.int64, "phase": np.int8, "round": np.int32,
           "t_start": np.float64, "t_end": np.float64,
           "generation": np.int32, "staleness": np.int32}


def _empty_cols(n: int = 0) -> dict:
    return {k: np.zeros(n, dtype=_DTYPES[k]) for k in _KEYS}


@dataclass
class TransferTrace:
    """Struct-of-arrays transfer record (one row per delivered chunk)."""

    generation: np.ndarray = field(
        default_factory=lambda: np.zeros(0, np.int32))
    staleness: np.ndarray = field(
        default_factory=lambda: np.zeros(0, np.int32))
    slot: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int32))
    sender: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int32))
    receiver: np.ndarray = field(
        default_factory=lambda: np.zeros(0, np.int32))
    chunk: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    owner: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int32))
    b_size: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    o_size: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    phase: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int8))
    round: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int32))
    t_start: np.ndarray = field(
        default_factory=lambda: np.zeros(0, np.float64))
    t_end: np.ndarray = field(
        default_factory=lambda: np.zeros(0, np.float64))
    K: int = 0          # chunks per update — the descriptor partition

    # -- construction --------------------------------------------------
    @classmethod
    def from_arrays(cls, *, K: int = 0, round_idx: int = 0,
                    slot_seconds: float = 1.0, **cols) -> "TransferTrace":
        n = len(cols["slot"]) if "slot" in cols else 0
        out = _empty_cols(n)
        for k, v in cols.items():
            out[k] = np.asarray(v)
        if "round" not in cols:
            out["round"] = np.full(n, round_idx, dtype=np.int32)
        if "t_start" not in cols:
            # Slot-boundary stamps: the slot engine's (and any legacy
            # log's) time columns are the slot grid in seconds.
            s = out["slot"].astype(np.float64) * slot_seconds
            out["t_start"] = s
            out["t_end"] = s + slot_seconds
        if "generation" not in cols:
            # Synchronous default: every row carries the model of its
            # own round, delivered on time.  The async session stamps
            # lagging generations (and staleness > 0) explicitly.
            out["generation"] = out["round"].astype(np.int32)
        if "staleness" not in cols:
            out["staleness"] = np.zeros(n, dtype=np.int32)
        return cls(K=K, **out)

    @classmethod
    def from_log(cls, log, K: int | None = None,
                 round_idx: int = 0) -> "TransferTrace":
        """Coerce a legacy log dict (or a trace) into a TransferTrace.

        Ground-truth ``owner`` is taken verbatim when present (so tests
        that corrupt it still exercise owner-independence) and derived
        from ``chunk // K`` otherwise.
        """
        if isinstance(log, cls):
            if K is not None and K != log.K:
                return replace(log, K=int(K))
            return log
        cols = {k: np.asarray(log[k]) for k in _KEYS
                if k in log and len(np.asarray(log[k]).shape) == 1}
        kk = int(K if K is not None else log.get("K", 0) or 0)
        if "owner" not in cols and kk:
            cols["owner"] = np.asarray(cols["chunk"]) // kk
        return cls.from_arrays(K=kk, round_idx=round_idx, **cols)

    @classmethod
    def concat(cls, traces: Sequence["TransferTrace"]) -> "TransferTrace":
        """Cross-round concatenation (each part keeps its ``round``)."""
        traces = [t for t in traces if len(t)]
        if not traces:
            return cls()
        K = max(t.K for t in traces)
        cols = {k: np.concatenate([getattr(t, k) for t in traces])
                for k in _KEYS}
        return cls(K=K, **cols)

    # -- mapping protocol (legacy dict consumers) ----------------------
    def __getitem__(self, key: str) -> np.ndarray:
        if key not in _KEYS:
            raise KeyError(key)
        return getattr(self, key)

    def keys(self) -> tuple:
        return _KEYS

    def __iter__(self) -> Iterator[str]:
        return iter(_KEYS)

    def __contains__(self, key) -> bool:
        return key in _KEYS

    def get(self, key, default=None):
        return getattr(self, key) if key in _KEYS else default

    def as_dict(self) -> dict:
        return {k: getattr(self, k) for k in _KEYS}

    def __len__(self) -> int:
        return int(len(self.slot))

    @property
    def n_events(self) -> int:
        return len(self)

    # -- views ---------------------------------------------------------
    def select(self, mask: np.ndarray) -> "TransferTrace":
        return TransferTrace(
            K=self.K, **{k: getattr(self, k)[mask] for k in _KEYS})

    def phase_slice(self, phase) -> "TransferTrace":
        """Rows of one protocol phase (name or code)."""
        code = PHASE_CODES.get(phase, phase)
        return self.select(self.phase == code)

    def warmup(self) -> "TransferTrace":
        """The attack surface: §IV-C adversaries observe warm-up only."""
        return self.phase_slice("warmup")

    def rounds_slice(self, r) -> "TransferTrace":
        return self.select(np.isin(self.round, np.atleast_1d(r)))

    def rounds(self) -> np.ndarray:
        return np.unique(self.round)

    def observed_by(self, observers) -> "TransferTrace":
        """Observer masking: the sub-trace a (coalition of) corrupted
        receiver(s) legally sees — rows it received, nothing else."""
        return self.select(np.isin(self.receiver,
                                   np.asarray(observers)))

    # -- protocol-visible identities ------------------------------------
    def desc(self) -> np.ndarray:
        """Torrent descriptor id of each piece (``chunk // K``) — the
        identity attacks see; owner identities are never exposed."""
        if self.K <= 0:
            raise ValueError("TransferTrace.K not set; pass K to "
                             "from_log() for descriptor mapping")
        return self.chunk // self.K

    def desc_owner_lookup(self):
        """Ground-truth (round, descriptor) -> owner mapping for SCORING
        cross-round attacks (the per-round torrent re-keys descriptors,
        so guesses must be graded against each round's mapping).

        Returns ``grade(rounds, descs) -> owner`` vectorized; unknown
        pairs grade as -1 (never correct).
        """
        base = int(self.desc().max(initial=0)) + 1
        code = self.round.astype(np.int64) * base + self.desc()
        ucode, first = np.unique(code, return_index=True)
        uowner = self.owner[first].astype(np.int64)

        def grade(rounds: np.ndarray, descs: np.ndarray) -> np.ndarray:
            q = np.asarray(rounds, np.int64) * base + np.asarray(descs,
                                                                 np.int64)
            if ucode.size == 0:
                return np.full(q.shape, -1, dtype=np.int64)
            pos = np.clip(np.searchsorted(ucode, q), 0, len(ucode) - 1)
            return np.where(ucode[pos] == q, uowner[pos], -1)

        return grade

    # -- summaries -------------------------------------------------------
    def counts_by_phase(self) -> dict:
        return {name: int((self.phase == code).sum())
                for name, code in PHASE_CODES.items()}

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"TransferTrace(n={len(self)}, K={self.K}, "
                f"rounds={len(self.rounds())}, {self.counts_by_phase()})")
