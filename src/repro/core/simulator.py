"""Round simulator: spray -> warm-up -> BitTorrent -> deadline (§III-A).

Orchestrates one FLTorrent round end to end and produces the metrics the
paper reports (T_warm, T_round, utilization, warm-up share) plus the
transfer log consumed by the attack suite (§IV-C) and the empirical
privacy-bound checks (§IV-A).

Two interchangeable *time engines* sit behind the same scheduling
contract (``time_engine=``):

* ``"slot"``  — the historical synchronous world: every stage costs one
  slot of ``cfg.slot_seconds``, capacities are integer chunks/slot, and
  the trace carries slot-boundary time stamps.
* ``"event"`` — the continuous-time transport of :mod:`repro.net`: the
  SAME policies issue the SAME schedules (same rng stream, same integer
  budgets), but each directive cycle's transfers become max-min
  fair-share flows over raw bytes/s links, every trace row gets real
  ``t_start``/``t_end`` instants, warm-up cycles pay tracker directive
  RTTs, and the metrics report realized wall-clock seconds
  (``t_warm_s``/``t_round_s``/``warmup_share_s``).

Fault model (§III-E): ``dropouts`` maps slot -> list of clients that
disconnect at that slot.  Dropped clients are excluded from all further
scheduling (tracker behaviour); chunks they uniquely held may leave some
updates unreconstructable, in which case aggregation proceeds over the
reconstructable active set — standard partial-participation semantics.
"""
from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass

import numpy as np

from repro import obs

from . import capacities as cap
from .bittorrent import bt_exact_slot, run_bt_fluid
from .byzantine import ByzantineModel, claimed_inventory, filter_transfers
from .maxflow import stage_upper_bound
from .overlay import random_overlay
from .policy import SlotView, get_policy
from .state import SwarmState
from .trace import TransferTrace
from .types import RoundMetrics, SwarmConfig


def _zero_clock() -> float:
    return 0.0


# Simulated time never reads the host clock (RNG007); the *measurement*
# clock behind RoundResult.timings is injected by the benchmarks via
# set_clock(time.perf_counter) and stays a constant zero otherwise.
_clock = _zero_clock


def set_clock(fn) -> None:
    """Install a wall-clock source for ``RoundResult.timings`` (pass
    ``None`` to restore the zero clock).  Benchmark-only: phase timings
    are diagnostics and never feed back into simulated time."""
    global _clock
    _clock = fn if fn is not None else _zero_clock


@contextlib.contextmanager
def measured_clock(fn=None):
    """Scoped measurement clock: install ``fn`` (default
    ``time.perf_counter``) as the phase-timing clock of BOTH this
    module and :mod:`repro.core.jit_engine`, yield it, and ALWAYS
    restore the previous clocks on exit.

    This replaces the paired ``set_clock(...)`` / ``set_clock(None)``
    benchmark idiom, which leaked: an exception between the calls left
    the perf clock installed for subsequent determinism-sensitive code
    (timings are diagnostics, but a surviving host-clock hook is
    exactly what RNG007 exists to keep out of the sim layer).
    """
    from . import jit_engine
    if fn is None:
        fn = time.perf_counter
    prev, prev_jit = _clock, jit_engine._clock
    set_clock(fn)
    jit_engine.set_clock(fn)
    try:
        yield fn
    finally:
        set_clock(prev)
        jit_engine.set_clock(prev_jit)


@dataclass
class RoundResult:
    metrics: RoundMetrics
    log: TransferTrace             # typed transfer trace (dict-compatible)
    reconstructable: np.ndarray    # (n, n) bool: A_v^r membership
    active: np.ndarray             # (n,) bool at deadline
    adj: np.ndarray
    up: np.ndarray
    down: np.ndarray
    maxflow_ub: np.ndarray | None = None   # per warm-up slot
    warmup_sent_per_slot: np.ndarray | None = None
    fluid_bt: bool = False
    tracker_log: dict | None = None
    timings: dict | None = None    # wall seconds per run() phase (bench)
    # Async extensions (fl/asyncfl.py; all None/default on sync runs):
    cut: bool = False              # quorum cut fired before all_done
    tail: dict | None = None       # undelivered (snd, rcv, chunk) at cut
    late: dict | None = None       # drain-mode boundary deliveries
    drain_s: float = 0.0           # wall seconds of the boundary drain
    bg_delivered: dict | None = None   # prior-generation rows delivered
    bg_remaining: np.ndarray | None = None  # meta ids still queued


class RoundSimulator:
    """One FL round of FLTorrent dissemination."""

    def __init__(
        self,
        cfg: SwarmConfig,
        link_model: cap.LinkModel = cap.RESIDENTIAL,
        dropouts: dict[int, list[int]] | None = None,
        byzantine: ByzantineModel | None = None,
        bt_mode: str = "auto",          # "exact" | "fluid" | "auto"
        exact_limit: int = 4_000_000,   # n * total_chunks budget for exact
        *,
        overlay: np.ndarray | None = None,
        up: np.ndarray | None = None,
        down: np.ndarray | None = None,
        up_bps: np.ndarray | None = None,
        down_bps: np.ndarray | None = None,
        rng: np.random.Generator | None = None,
        spray_plan=None,
        time_engine: str = "slot",      # "slot" | "event"
        net=None,                       # repro.net.NetConfig (event only)
        background=None,                # (snd, rcv, meta) carried tail
    ):
        """``overlay``/``up``/``down``/``rng`` let a :class:`SwarmSession`
        inject a persistent population (evolving topology, sticky
        capacities) instead of re-rolling everything from ``cfg.seed``.
        When omitted, construction is exactly the historical single-round
        path: seed the rng, sample a fresh overlay, sample capacities —
        in that order, so existing seeds reproduce bit-identically.

        ``time_engine="event"`` swaps the synchronous slot clock for the
        continuous-time transport of :mod:`repro.net` (same schedules,
        wall-clock seconds, fair-share flow timing); ``net`` is its
        :class:`~repro.net.NetConfig`.  ``up_bps``/``down_bps`` inject
        raw link rates alongside the integer budgets (sessions persist
        them); when omitted they are sampled from ``link_model`` via the
        same rng draws that produce the slot budgets, so both engines
        see the same physical links at the same seed.
        """
        if time_engine not in ("slot", "event"):
            raise ValueError(f"unknown time_engine {time_engine!r}")
        self.time_engine = time_engine
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed) if rng is None else rng
        self.adj = (random_overlay(cfg.n, cfg.min_degree,
                                   cfg.extra_edge_frac, self.rng)
                    if overlay is None else np.asarray(overlay, dtype=bool))
        if self.adj.shape != (cfg.n, cfg.n):
            raise ValueError(f"overlay shape {self.adj.shape} != "
                             f"({cfg.n}, {cfg.n})")
        if up is None or down is None:
            # One pair of uniform draws feeds BOTH time domains: raw
            # bytes/s for the event engine, quantized chunks/slot for
            # the slot engines (the historical draw order, so existing
            # seeds reproduce bit-identically).
            self.up_bps, self.down_bps = link_model.sample_rates(
                cfg.n, self.rng)
            self.up, self.down = cap.quantize_rates(
                self.up_bps, self.down_bps, cfg.chunk_bytes,
                cfg.slot_seconds, warn=(time_engine == "slot"))
        else:
            self.up = np.asarray(up, dtype=np.int64)
            self.down = np.asarray(down, dtype=np.int64)
            if up_bps is not None and down_bps is not None:
                self.up_bps = np.asarray(up_bps, np.float64)
                self.down_bps = np.asarray(down_bps, np.float64)
            else:
                # Budget-faithful fallback: rates that reproduce the
                # injected integer budgets exactly.
                self.up_bps = self.up * (cfg.chunk_bytes
                                         / cfg.slot_seconds)
                self.down_bps = self.down * (cfg.chunk_bytes
                                             / cfg.slot_seconds)
        if net is None and time_engine == "event":
            from repro.net import NetConfig
            net = NetConfig()
        self.net = net
        self.dropouts = dropouts or {}
        if bt_mode == "auto":
            bt_mode = ("exact" if cfg.n * cfg.total_chunks <= exact_limit
                       else "fluid")
        self.bt_mode = bt_mode
        self.byz = byzantine
        self._fail_run = np.zeros(cfg.n, dtype=np.int64)
        self.state = SwarmState(cfg, self.adj, self.up, self.down, self.rng)
        # Warm-up scheduling policy: a registered name or a
        # SchedulerPolicy instance (core/policy.py).  Resolved once per
        # simulator; per-round mutable policy state is reset in run().
        self.policy = get_policy(cfg.scheduler)
        if not self.policy.applies_to("warmup"):
            raise ValueError(
                f"policy {self.policy.name!r} does not apply to the "
                f"warm-up phase (phases={self.policy.phases})")
        # Session-computed spray plan (churn-aware spray budgets); None
        # keeps the historical full re-spray path byte-identical.
        self.spray_plan = spray_plan
        # Carried background tail (async overlap): (snd, rcv, meta)
        # local-id arrays queued onto the event engine before the spray,
        # so the previous generation's tail contends with this round.
        if background is not None and time_engine != "event":
            raise ValueError("background tails need time_engine='event'")
        self.background = background

    # ------------------------------------------------------------------
    def _spray(self, engine=None):
        """Pre-round obfuscation (§III-B.1): sigma chunks per source to
        random non-neighbors over ephemeral tracker-coordinated tunnels.
        Happens before slot 0 and is not attributed to round pseudonyms
        (tunnels are torn down; attacks read phase==1 only).  Under the
        event engine the sprays are transported as fair-share flows and
        the tunnel brokering is charged to the control plane."""
        cfg = self.cfg
        st = self.state
        sigma = cfg.spray_copies
        if sigma == 0:
            return
        K = cfg.chunks_per_update
        if self.spray_plan is not None:
            # Session-provided plan (e.g. ChurnAwareSpray): explicit
            # (source, target, offset) triples, drawn from the session
            # stream — the simulator stream is left untouched.
            src, tgt, off = self.spray_plan.as_local_arrays()
            snd, tgts, chk = src, tgt, src * K + off
        else:
            # Vectorized over all sources at once: no per-client loop.
            nn = ~self.adj      # fresh array; safe to edit the diagonal
            np.fill_diagonal(nn, False)
            counts = nn.sum(axis=1)
            rows = np.flatnonzero(counts > 0)
            if rows.size == 0:
                return    # complete overlay: no non-neighbors
            m = min(sigma, K)
            # m distinct chunk offsets per source: top-m of a random
            # matrix (unordered-sample-without-replacement).
            keys = self.rng.random((rows.size, K))
            ids = (np.argpartition(keys, m - 1, axis=1)[:, :m] if m < K
                   else np.argsort(keys, axis=1))
            # One uniform non-neighbor per sprayed chunk (with
            # replacement): the pick-th non-neighbor in ascending
            # column order (the rank a stable argsort of ~nn yields).
            # Solved as an order-statistic fixed point over the row's
            # BLOCKED columns (neighbors + self, ~min_degree of them):
            # c = pick + |{blocked <= c}| converges from below in
            # O(deg) tiny iterations — no O(n^2 log n) sort and no
            # O(n^2) scan-sized temporaries, which dominated spray
            # setup at n=5000 (BENCH_scheduler.json before/after).
            pick = (self.rng.random((rows.size, m))
                    * counts[rows, None]).astype(np.int64)
            blk = self.adj[rows].copy()
            blk[np.arange(rows.size), rows] = True
            ri, ci = np.nonzero(blk)
            nblk = np.bincount(ri, minlength=rows.size)
            off = np.cumsum(nblk) - nblk
            maxb = int(nblk.max(initial=0))
            B = np.full((rows.size, maxb), cfg.n, dtype=np.int64)
            B[ri, np.arange(ri.size) - off[ri]] = ci
            tgts = pick.copy()
            for _ in range(maxb + 2):
                bumped = (pick
                          + (B[:, None, :] <= tgts[:, :, None]).sum(2))
                if np.array_equal(bumped, tgts):
                    break
                tgts = bumped
            tgts = tgts.ravel().astype(np.int64)
            snd = np.repeat(rows, m).astype(np.int64)
            chk = (rows[:, None] * K + ids).ravel()
        if engine is None:
            st.apply_transfers(snd, tgts, chk, phase_code=0,
                               consume_slot=False)
        else:
            ts, te = engine.spray(snd, tgts, chk)
            st.apply_transfers(snd, tgts, chk, phase_code=0,
                               consume_slot=False, t_start=ts, t_end=te)

    # ------------------------------------------------------------------
    def _schedule_filtered(self, scheduler_fn):
        """Run a slot scheduler against CLAIMED bitfields, then apply
        Byzantine behaviour + per-peer progress timeouts (SIII-E)."""
        st = self.state
        if self.byz is None:
            return scheduler_fn()
        real = st.have
        st.have = claimed_inventory(self.byz, st, self.rng)
        try:
            snd, rcv, chk = scheduler_fn()
        finally:
            st.have = real
        ok, fails = filter_transfers(self.byz, st, self.rng,
                                     snd, rcv, chk)
        served = np.zeros(self.cfg.n, dtype=bool)
        if len(snd):
            served[np.unique(np.asarray(snd)[ok])] = True
        self._fail_run = np.where(served, 0,
                                  self._fail_run + (fails > 0))
        timed_out = self._fail_run >= self.byz.timeout_slots
        if timed_out.any():
            st.active[timed_out] = False   # excluded from scheduling
        return (np.asarray(snd)[ok], np.asarray(rcv)[ok],
                np.asarray(chk)[ok])

    # ------------------------------------------------------------------
    def _apply_dropouts(self):
        for v in self.dropouts.get(self.state.slot, []):
            self.state.active[v] = False

    # ------------------------------------------------------------------
    def _quorum_met(self, k: int) -> bool:
        """FedBuff quorum (fl/asyncfl.py): >= k updates are swarm-
        complete — held in full by EVERY active peer, so a merge over
        them is identical at every peer (sole-writer consistency)."""
        st = self.state
        if not st.active.any():
            return True
        complete = st.reconstructable_sets()[st.active].all(axis=0)
        return int(complete.sum()) >= k

    def _extract_tail(self):
        """Undelivered (snd, rcv, chunk) work at the quorum cut.

        One row per missing (active receiver, chunk) pair; the sender is
        the active holder with the fastest uplink (ties break to the
        lowest id) — deterministic, zero rng draws, so the sync path's
        streams are untouched.  Chunks no active peer holds are
        unservable: their rows are dropped and the owning updates
        reported in ``dead_owners`` (they can never complete).

        ``ucols``/``holder_mask`` expose the cut-time holder sets of the
        missing chunks (local peer x unique chunk) — the carry path's
        relay replanner (session._map_backlog) re-picks senders from the
        *growing* holder set each round, so a scarce chunk spreads
        exponentially through background deliveries instead of fanning
        out of its sole original holder.
        """
        st = self.state
        K = self.cfg.chunks_per_update
        act = st.active
        rcv, chk = np.nonzero(act[:, None] & ~st.have)
        if rcv.size == 0:
            return None
        ucols, cinv = np.unique(chk, return_inverse=True)
        holder_mask = st.have[:, ucols] & act[:, None]
        score = np.where(holder_mask, self.up_bps[:, None], -1.0)
        best = np.argmax(score, axis=0)
        servable = score[best, np.arange(ucols.size)] > 0
        keep = servable[cinv]
        dead = np.unique(ucols[~servable] // K)
        if not keep.any():
            return {"snd": np.zeros(0, np.int64),
                    "rcv": np.zeros(0, np.int64),
                    "chunk": np.zeros(0, np.int64),
                    "dead_owners": dead,
                    "ucols": ucols[servable],
                    "holder_mask": holder_mask[:, servable]}
        return {"snd": best[cinv][keep].astype(np.int64),
                "rcv": rcv[keep].astype(np.int64),
                "chunk": chk[keep].astype(np.int64),
                "dead_owners": dead,
                "ucols": ucols[servable],
                "holder_mask": holder_mask[:, servable]}

    def _drain_tail(self, tail: dict, engine):
        """Deliver the whole tail at the round boundary (serialized
        wall clock — the no-overlap ablation).  Event engine: a solo
        fair-share drain.  Slot engine: a receiver-paced schedule on the
        slot grid (downlink budgets; an idealized lower bound — the
        event engine is the honest timing path).  Stamps are relative
        to the drain start."""
        cfg = self.cfg
        T = len(tail["snd"])
        if T == 0:
            return None, 0.0
        if engine is not None:
            engine.set_background(tail["snd"], tail["rcv"],
                                  np.arange(T, dtype=np.int64))
            t0 = engine.t
            meta, ts, te = engine.drain_background()
            ts_full = np.empty(T, np.float64)
            te_full = np.empty(T, np.float64)
            ts_full[meta] = ts
            te_full[meta] = te
            slot_idx = np.zeros(T, np.int64)
            drain_s = engine.t - t0
        else:
            rcv = tail["rcv"]          # receiver-major (nonzero order)
            first = np.searchsorted(rcv, rcv)
            posr = np.arange(T) - first
            slot_idx = posr // np.maximum(self.state.down[rcv], 1)
            ts_full = slot_idx * cfg.slot_seconds
            te_full = ts_full + cfg.slot_seconds
            drain_s = float((int(slot_idx.max()) + 1) * cfg.slot_seconds)
        late = {"snd": tail["snd"], "rcv": tail["rcv"],
                "chunk": tail["chunk"], "slot": slot_idx,
                "t_start": ts_full, "t_end": te_full}
        return late, float(drain_s)

    # ------------------------------------------------------------------
    def run(self, collect_maxflow: bool = False,
            warmup_only: bool = False,
            quorum_k: int | None = None,
            tail_mode: str = "none",
            bt_budget: int | None = None) -> RoundResult:
        cfg = self.cfg
        st = self.state
        if tail_mode not in ("none", "drain", "carry"):
            raise ValueError(f"unknown tail_mode {tail_mode!r}")
        if tail_mode == "carry" and self.time_engine != "event":
            raise ValueError("tail_mode='carry' needs time_engine="
                             "'event' (overlap is a flow-level notion)")
        if quorum_k is not None and (warmup_only
                                     or self.bt_mode == "fluid"):
            raise ValueError("quorum cuts need the exact BT engine")
        if bt_budget is not None and quorum_k is None:
            raise ValueError("bt_budget is an async deadline: it needs "
                             "quorum_k/tail_mode so the cut has a tail "
                             "path (otherwise it would silently mask)")
        engine = None
        rec = obs.get()
        _clk = _clock
        _t0 = _clk()
        if self.time_engine == "event":
            from repro.net import EventEngine
            engine = EventEngine(cfg.n, cfg.chunk_bytes, self.up_bps,
                                 self.down_bps, self.net, cfg.seed)
            if self.background is not None:
                # Previous generation's tail: contends with this round's
                # spray/warm-up/BT from t=0 (overlapped dissemination).
                engine.set_background(*self.background)
        if cfg.enable_preround:
            self._spray(engine)
        t_spray_s = engine.t if engine is not None else 0.0
        _t_spray = _clk()

        ubs: list[int] = []
        # ---- warm-up (§III-B) ----
        pol = self.policy
        pol.reset(cfg)               # per-round policy state (flooding)
        view = SlotView(st, pol.visibility)
        idle = 0
        while not st.warmup_done() and st.slot < cfg.s_max:
            self._apply_dropouts()
            if collect_maxflow:
                ubs.append(stage_upper_bound(st))
            snd, rcv, chk = self._schedule_filtered(
                lambda: pol.schedule(view))
            if engine is None:
                st.apply_transfers(snd, rcv, chk, phase_code=1)
            else:
                ts, te = engine.warmup_cycle(st.slot, snd, rcv, chk)
                st.apply_transfers(snd, rcv, chk, phase_code=1,
                                   t_start=ts, t_end=te)
            if rec.enabled:
                rec.hist("sched.warmup_grants_per_slot", len(snd))
            st.slot += 1
            # Stall guard: lags leave early slots empty, and a receiver
            # whose only missing chunks are unreplicated owner chunks
            # may legally wait up to ~K/kappa slots for the owner's
            # throttled window to rotate around (state.owner_windows).
            # Only an idle run longer than both means no legal warm-up
            # transfer exists (e.g. sole suppliers dropped); fail open
            # to BT instead of spinning to s_max (liveness, §III-E).
            idle = idle + 1 if len(snd) == 0 else 0
            rotation = -(-cfg.chunks_per_update // max(cfg.owner_throttle, 1))
            if idle >= cfg.lag_slots + rotation + 8:
                break
        t_warm = st.slot
        _t_warmup = _clk()
        failed_open = not st.warmup_done()
        t_warm_s = (engine.t if engine is not None
                    else t_warm * cfg.slot_seconds)

        warm_sent_arr = np.asarray(st.per_slot_sent, dtype=np.int64)

        # ---- vanilla BitTorrent (§III-A step 4) ----
        st.phase = "bt"
        # warmup_only stops at the warm-up boundary (bench/scaling runs
        # where only the scheduled phase is under measurement); the
        # round result then reports the exact post-warm-up state.
        fluid = self.bt_mode == "fluid" and not warmup_only
        if warmup_only:
            pass
        elif fluid:
            eff_slots = run_bt_fluid(st, cfg.s_max - st.slot)
            if engine is not None:
                # Fluid BT is count-space; its realized duration is the
                # (fractional) capacity-bound slot count in seconds.
                engine.advance(eff_slots * cfg.slot_seconds)
        else:
            idle = 0
            bt_base = st.slot
            while not st.all_done() and st.slot < cfg.s_max:
                # FedBuff quorum (async): stop swarming the moment >= k
                # updates are swarm-complete; the rest become the tail.
                if quorum_k is not None and self._quorum_met(quorum_k):
                    break
                # Async round deadline: the directive-cycle budget after
                # warm-up.  Sync rounds idle-wait the stretched barrier
                # of every straggler cycle; the async cut bounds that
                # and hands the rest to the tail path.
                if bt_budget is not None and st.slot - bt_base >= bt_budget:
                    break
                self._apply_dropouts()
                snd, rcv, chk = self._schedule_filtered(
                    lambda: bt_exact_slot(st))
                if engine is None:
                    st.apply_transfers(snd, rcv, chk, phase_code=2)
                else:
                    ts, te = engine.bt_cycle(snd, rcv, chk)
                    st.apply_transfers(snd, rcv, chk, phase_code=2,
                                       t_start=ts, t_end=te)
                if rec.enabled:
                    rec.hist("sched.bt_grants_per_slot", len(snd))
                st.slot += 1
                idle = idle + 1 if len(snd) == 0 else 0
                if idle >= 3:
                    # No transfer possible for several slots (e.g. sole
                    # holders dropped): the round completes over the
                    # remaining reconstructable set (§III-E).
                    break
        t_round = st.slot
        _t_bt = _clk()
        t_round_s = (engine.t if engine is not None
                     else t_round * cfg.slot_seconds)

        # ---- async tail (quorum cut; fl/asyncfl.py) ----
        cut = quorum_k is not None and not st.all_done()
        tail = late = None
        drain_s = 0.0
        if cut and tail_mode != "none":
            tail = self._extract_tail()
            if tail is not None and tail_mode == "drain":
                late, drain_s = self._drain_tail(tail, engine)
        bg_delivered = bg_remaining = None
        if self.background is not None:
            bg_delivered = engine.background_log()
            bg_remaining = engine.background_remaining()

        # ---- metrics ----
        total_up = float(self.up.sum())
        m = RoundMetrics(
            t_warm=t_warm,
            t_round=t_round,
            t_warm_s=float(t_warm_s),
            t_round_s=float(t_round_s),
            t_spray_s=float(t_spray_s),
            control_s=(float(engine.tracker.control_s)
                       if engine is not None else 0.0),
            warmup_share_s=(float(t_warm_s / t_round_s)
                            if t_round_s else 0.0),
            warmup_chunks_sent=st.warmup_sent,
            bt_chunks_sent=st.bt_sent,
            warmup_utilization=(st.warmup_sent / (t_warm * total_up))
            if t_warm else 0.0,
            overall_utilization=((st.warmup_sent + st.bt_sent)
                                 / (t_round * total_up)) if t_round else 0.0,
            warmup_share=(t_warm / t_round) if t_round else 0.0,
            failed_open=failed_open,
            per_slot_warmup_util=(warm_sent_arr / total_up) if t_warm else None,
            active_at_deadline=st.active.copy(),
        )

        # ---- reconstructable sets (aggregation semantics §II-B) ----
        if fluid:
            # Fluid BT runs to completion: all updates reconstructable by
            # every active client (count-space equivalence).
            recon = np.tile(st.active[None, :], (cfg.n, 1))
            recon &= st.active[:, None]
        else:
            recon = st.reconstructable_sets()
            recon &= st.active[:, None]

        log = st.log.finalize(cfg.chunks_per_update, cfg.slot_seconds)
        _t_emit = _clk()
        # Per-phase instrumentation: one (name, sim start, sim end, host
        # wall) record per run() phase.  The obs spans are the
        # first-class stream; the legacy ``timings`` dict is derived
        # from the same checkpoints for back-compat consumers.
        phases = (("spray", 0.0, float(t_spray_s), _t_spray - _t0),
                  ("warmup", float(t_spray_s), float(t_warm_s),
                   _t_warmup - _t_spray),
                  ("bt", float(t_warm_s), float(t_round_s),
                   _t_bt - _t_warmup),
                  ("emit", float(t_round_s), float(t_round_s),
                   _t_emit - _t_bt))
        if rec.enabled:
            for name, s0, s1, wall in phases:
                rec.span_at(f"round.{name}", s0, s1, wall_s=wall)
            rec.span_at("round.total", 0.0, float(t_round_s),
                        wall_s=_t_emit - _t0, n=cfg.n,
                        engine=self.time_engine,
                        impl=cfg.scheduler_impl, cut=bool(cut),
                        failed_open=bool(failed_open))
            if drain_s:
                rec.span_at("round.drain", float(t_round_s),
                            float(t_round_s) + float(drain_s))
        return RoundResult(
            metrics=m, log=log, reconstructable=recon,
            active=st.active.copy(), adj=self.adj, up=self.up,
            down=self.down,
            maxflow_ub=np.asarray(ubs, dtype=np.int64) if collect_maxflow else None,
            warmup_sent_per_slot=warm_sent_arr,
            fluid_bt=fluid,
            tracker_log=(engine.control_log()
                         if engine is not None else None),
            timings={f"{name}_s": wall
                     for name, _, _, wall in phases},
            cut=cut, tail=tail, late=late, drain_s=drain_s,
            bg_delivered=bg_delivered, bg_remaining=bg_remaining,
        )


def simulate_round(cfg: SwarmConfig, collect_maxflow: bool = False,
                   warmup_only: bool = False, **kw) -> RoundResult:
    return RoundSimulator(cfg, **kw).run(collect_maxflow=collect_maxflow,
                                         warmup_only=warmup_only)
