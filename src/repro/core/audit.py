"""Commit-then-reveal tracker accountability (paper §III-D).

Before seeing per-round inputs, the tracker commits to a seed hash
``h^r = H(seed^r)``.  After the round it reveals the seed and a log of
the overlay + warm-up directives.  Clients recompute the overlay from
the seed and verify the *verifiable hard constraints*:

  (i)   the revealed seed matches the commitment,
  (ii)  the overlay equals the seed-derived overlay (adjacency),
  (iii) every warm-up directive respects adjacency,
  (iv)  per-stage capacity caps are not exceeded,
  (v)   no redundant deliveries (a (receiver, chunk) pair scheduled
        at most once) except logged retries.

On any violation clients fail open to vanilla BitTorrent and void that
round's unlinkability guarantee (§IV-A "conditionality").
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field

import numpy as np

from .overlay import random_overlay


def _h(b: bytes) -> str:
    return hashlib.sha256(b).hexdigest()


@dataclass
class TrackerCommitment:
    round_id: int
    commitment: str                     # H(seed)

    @staticmethod
    def commit(round_id: int, seed: int) -> "TrackerCommitment":
        return TrackerCommitment(round_id, _h(f"{round_id}:{seed}".encode()))


@dataclass
class RoundLog:
    """What an auditable tracker reveals post-round."""
    round_id: int
    seed: int
    n: int
    min_degree: int
    extra_edge_frac: float
    adjacency_digest: str
    directives: list = field(default_factory=list)  # (slot, snd, rcv, chunk)
    retries: set = field(default_factory=set)       # logged retry pairs

    def digest(self) -> str:
        body = json.dumps(
            [self.round_id, self.seed, self.n, self.adjacency_digest,
             len(self.directives)], sort_keys=True).encode()
        return _h(body)


def adjacency_digest(adj: np.ndarray) -> str:
    return _h(np.packbits(adj).tobytes())


@dataclass
class AuditResult:
    ok: bool
    violations: list

    @property
    def fail_open(self) -> bool:
        return not self.ok


def verify_round(
    commitment: TrackerCommitment,
    log: RoundLog,
    up_budget: np.ndarray,
    down_budget: np.ndarray,
) -> AuditResult:
    """Client-side verification of the revealed round log (§III-D)."""
    violations: list[str] = []

    # (i) seed opens the commitment
    if _h(f"{log.round_id}:{log.seed}".encode()) != commitment.commitment:
        violations.append("seed does not match commitment")

    # (ii) overlay is the seed-derived overlay
    rng = np.random.default_rng(log.seed)
    adj = random_overlay(log.n, log.min_degree, log.extra_edge_frac, rng)
    if adjacency_digest(adj) != log.adjacency_digest:
        violations.append("overlay does not match seed derivation")

    # (iii)-(v) directive checks
    per_stage_up: dict[tuple[int, int], int] = {}
    per_stage_down: dict[tuple[int, int], int] = {}
    delivered: set[tuple[int, int]] = set()
    for (slot, snd, rcv, chunk) in log.directives:
        if not adj[snd, rcv]:
            violations.append(f"non-adjacent directive {snd}->{rcv}@{slot}")
            break
        ku = (slot, snd)
        kv = (slot, rcv)
        per_stage_up[ku] = per_stage_up.get(ku, 0) + 1
        per_stage_down[kv] = per_stage_down.get(kv, 0) + 1
        if per_stage_up[ku] > up_budget[snd]:
            violations.append(f"uplink cap exceeded for {snd}@{slot}")
            break
        if per_stage_down[kv] > down_budget[rcv]:
            violations.append(f"downlink cap exceeded for {rcv}@{slot}")
            break
        pair = (rcv, chunk)
        if pair in delivered and pair not in log.retries:
            violations.append(f"redundant delivery {pair}")
            break
        delivered.add(pair)

    return AuditResult(ok=not violations, violations=violations)
