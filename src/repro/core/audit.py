"""Commit-then-reveal tracker accountability (paper §III-D).

Before seeing per-round inputs, the tracker commits to a seed hash
``h^r = H(seed^r)``.  After the round it reveals the seed and a log of
the overlay + warm-up directives.  Clients recompute the overlay from
the seed and verify the *verifiable hard constraints*:

  (i)   the revealed seed matches the commitment,
  (ii)  the overlay equals the seed-derived overlay (adjacency),
  (iii) every warm-up directive respects adjacency,
  (iv)  per-stage capacity caps are not exceeded,
  (v)   no redundant deliveries (a (receiver, chunk) pair scheduled
        at most once) except logged retries.

On any violation clients fail open to vanilla BitTorrent and void that
round's unlinkability guarantee (§IV-A "conditionality").
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field

import numpy as np

from .overlay import random_overlay
from .trace import TransferTrace


def _h(b: bytes) -> str:
    return hashlib.sha256(b).hexdigest()


@dataclass
class TrackerCommitment:
    round_id: int
    commitment: str                     # H(seed)

    @staticmethod
    def commit(round_id: int, seed: int) -> "TrackerCommitment":
        return TrackerCommitment(round_id, _h(f"{round_id}:{seed}".encode()))


@dataclass
class RoundLog:
    """What an auditable tracker reveals post-round."""
    round_id: int
    seed: int
    n: int
    min_degree: int
    extra_edge_frac: float
    adjacency_digest: str
    directives: list = field(default_factory=list)  # (slot, snd, rcv, chunk)
    retries: set = field(default_factory=set)       # logged retry pairs

    def digest(self) -> str:
        body = json.dumps(
            [self.round_id, self.seed, self.n, self.adjacency_digest,
             len(self.directives)], sort_keys=True).encode()
        return _h(body)


def adjacency_digest(adj: np.ndarray) -> str:
    return _h(np.packbits(adj).tobytes())


@dataclass
class AuditResult:
    ok: bool
    violations: list

    @property
    def fail_open(self) -> bool:
        return not self.ok


def directives_from_trace(trace) -> list:
    """Warm-up rows of a :class:`TransferTrace` as revealable tracker
    directives ``(slot, sender, receiver, chunk)`` — what an auditable
    tracker logs for the commit-then-reveal check (§III-D)."""
    tr = TransferTrace.from_log(trace)
    view = tr.warmup()
    return list(zip(view.slot.tolist(), view.sender.tolist(),
                    view.receiver.tolist(), view.chunk.tolist()))


def verify_directives(
    adj: np.ndarray,
    directives,
    up_budget: np.ndarray,
    down_budget: np.ndarray,
    retries: set | None = None,
) -> list:
    """Checks (iii)-(v) on a directive batch, vectorized.

    ``directives`` is a list of ``(slot, snd, rcv, chunk)`` tuples (see
    :func:`directives_from_trace`) or a :class:`TransferTrace`.  Returns
    the violation messages (empty = clean); one representative message
    per violated check, anchored at its first offending directive.
    """
    retries = retries or set()
    if isinstance(directives, TransferTrace):
        directives = directives_from_trace(directives)
    if not directives:
        return []
    arr = np.asarray(directives, dtype=np.int64)
    slot, snd, rcv, chk = arr.T
    violations: list[str] = []

    # (iii) adjacency
    bad = ~adj[snd, rcv]
    if bad.any():
        i = int(np.flatnonzero(bad)[0])
        violations.append(
            f"non-adjacent directive {snd[i]}->{rcv[i]}@{slot[i]}")

    # (iv) per-stage capacity caps: grouped counts per (slot, client)
    n = len(up_budget)
    for who, budget, label in ((snd, up_budget, "uplink"),
                               (rcv, down_budget, "downlink")):
        code = slot * n + who
        uc, cnt = np.unique(code, return_counts=True)
        over = cnt > np.asarray(budget)[uc % n]
        if over.any():
            i = int(np.flatnonzero(over)[0])
            violations.append(
                f"{label} cap exceeded for {uc[i] % n}@{uc[i] // n}")

    # (v) no redundant (receiver, chunk) deliveries except logged retries
    code = rcv * (chk.max() + 1) + chk
    order = np.argsort(code, kind="stable")
    dup = np.zeros(len(code), dtype=bool)
    dup[order[1:]] = code[order][1:] == code[order][:-1]
    if dup.any():
        for i in np.flatnonzero(dup):
            pair = (int(rcv[i]), int(chk[i]))
            if pair not in retries:
                violations.append(f"redundant delivery {pair}")
                break
    return violations


def verify_round(
    commitment: TrackerCommitment,
    log: RoundLog,
    up_budget: np.ndarray,
    down_budget: np.ndarray,
) -> AuditResult:
    """Client-side verification of the revealed round log (§III-D)."""
    violations: list[str] = []

    # (i) seed opens the commitment
    if _h(f"{log.round_id}:{log.seed}".encode()) != commitment.commitment:
        violations.append("seed does not match commitment")

    # (ii) overlay is the seed-derived overlay
    rng = np.random.default_rng(log.seed)
    adj = random_overlay(log.n, log.min_degree, log.extra_edge_frac, rng)
    if adjacency_digest(adj) != log.adjacency_digest:
        violations.append("overlay does not match seed derivation")

    # (iii)-(v) directive checks, vectorized over the batch
    violations += verify_directives(adj, log.directives, up_budget,
                                    down_budget, log.retries)
    return AuditResult(ok=not violations, violations=violations)
