"""Overlay graph generation (paper §II-B, §V-A).

The tracker samples a fresh overlay ``G^r`` every round: a random graph
with *minimum* degree ``m`` and heterogeneous neighbor counts above ``m``
(§V-A).  Regenerating per round prevents long-lived neighbor
relationships that could amplify cross-round linkage (§III-E).
"""
from __future__ import annotations

import numpy as np


def random_overlay(
    n: int,
    min_degree: int,
    extra_edge_frac: float = 0.1,
    rng: np.random.Generator | None = None,
) -> np.ndarray:
    """Sample a connected overlay with minimum degree ``min_degree``.

    Construction: a random ``m``-regular backbone (configuration-model
    style with retry) plus a fraction of extra random edges so neighbor
    counts are heterogeneous above ``m``.  Returns a dense symmetric bool
    adjacency matrix with zero diagonal.

    ``rng`` must be the caller's threaded generator: a constant-seed
    fallback here would hand every un-threaded caller the SAME overlay
    while looking random (swarmlint RNG004).
    """
    if rng is None:
        raise ValueError(
            "random_overlay requires a threaded np.random.Generator; "
            "pass the round's rng (e.g. default_rng(cfg.seed))")
    m = min_degree
    if m >= n:
        raise ValueError(f"min_degree {m} must be < n {n}")
    adj = _regular_backbone(n, m, rng)
    # Heterogeneous extras: add ~extra_edge_frac * n * m / 2 random edges.
    n_extra = int(extra_edge_frac * n * m / 2)
    if n_extra > 0:
        us = rng.integers(0, n, size=4 * n_extra)
        vs = rng.integers(0, n, size=4 * n_extra)
        keep = us != vs
        us, vs = us[keep][:n_extra], vs[keep][:n_extra]
        adj[us, vs] = True
        adj[vs, us] = True
    # Ensure connectivity (rare for m >= 3; repair by linking components).
    adj = _ensure_connected(adj, rng)
    np.fill_diagonal(adj, False)
    return adj


def _regular_backbone(n: int, m: int, rng: np.random.Generator) -> np.ndarray:
    """Near-m-regular random graph via stub matching with local repair."""
    if (n * m) % 2 == 1:
        m_eff = m + 1  # need even stub count; overshoot keeps min degree
    else:
        m_eff = m
    for _ in range(50):
        stubs = np.repeat(np.arange(n), m_eff)
        rng.shuffle(stubs)
        a, b = stubs[0::2], stubs[1::2]
        ok = a != b
        adj = np.zeros((n, n), dtype=bool)
        adj[a[ok], b[ok]] = True
        adj[b[ok], a[ok]] = True
        deg = adj.sum(1)
        if (deg >= m).all():
            return adj
        # Repair: connect deficient nodes to random others.
        for v in np.flatnonzero(deg < m):
            need = int(m - adj[v].sum())
            if need <= 0:
                continue
            cands = np.flatnonzero(~adj[v])
            cands = cands[cands != v]
            pick = rng.choice(cands, size=min(need, cands.size), replace=False)
            adj[v, pick] = True
            adj[pick, v] = True
        if (adj.sum(1) >= m).all():
            return adj
    raise RuntimeError("failed to build overlay backbone")


def _ensure_connected(adj: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    n = adj.shape[0]
    comp = _components(adj)
    n_comp = comp.max() + 1
    while n_comp > 1:
        # Link a random node of component 0 with one of another component.
        a = rng.choice(np.flatnonzero(comp == 0))
        b = rng.choice(np.flatnonzero(comp != 0))
        adj[a, b] = adj[b, a] = True
        comp = _components(adj)
        n_comp = comp.max() + 1
    return adj


def _components(adj: np.ndarray) -> np.ndarray:
    """Connected-component labels via BFS over the bool adjacency."""
    n = adj.shape[0]
    comp = np.full(n, -1, dtype=np.int64)
    cur = 0
    for s in range(n):
        if comp[s] >= 0:
            continue
        frontier = np.zeros(n, dtype=bool)
        frontier[s] = True
        comp[s] = cur
        while frontier.any():
            nxt = (adj[frontier].any(0)) & (comp < 0)
            comp[nxt] = cur
            frontier = nxt
        cur += 1
    return comp


def neighbors(adj: np.ndarray, v: int) -> np.ndarray:
    return np.flatnonzero(adj[v])


def average_degree(adj: np.ndarray) -> float:
    return float(adj.sum(1).mean())
