"""BENCH_async: deadline-free asynchronous FL vs the synchronous
baseline under straggler-heavy links.

Three panels, one artifact (results/bench/BENCH_async.json):

* **learning** — time-to-target-accuracy, sync vs FedBuff-style carry
  (``AsyncConfig(overlap=True)``).  The async runner cuts each round's
  BT phase after ``round_slots`` directive cycles and carries the tail
  as strict-lower-priority background flows into the next round's
  engine; under a heavy-tailed uplink distribution (8% of peers 32x
  slower) the sync barrier idles the fast majority every cycle, so the
  cut + carry reaches the same accuracy in ≥20% less wall clock.
* **budget** — session-only wall-clock sweep over ``round_slots``: the
  win and the merge staleness histogram vs how aggressively the
  deadline cuts (no training, dissemination only).
* **privacy** — what overlap costs/buys an observer: ASR of the two
  cross-round adversaries (``persistent_neighbor_linkage``,
  ``timing_attribution``) over :func:`repro.fl.asyncfl.adversary_view`
  with the tail carried (``overlap=True``) vs boundary-drained
  (``overlap=False``) — once over the defended FL sessions (ASR at the
  1/m floor both ways) and once with warm-up defenses ablated, where
  the carried cross-generation traffic visibly enlarges the cover set
  and DROPS both attacks below the drain baseline.

    PYTHONPATH=src python benchmarks/bench_async.py           # full
    PYTHONPATH=src python benchmarks/bench_async.py --smoke   # CI
"""
from __future__ import annotations

import os
import sys
from dataclasses import replace as dc_replace

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import numpy as np  # noqa: E402

from common import Timer, banner, save  # noqa: E402
from repro.core import SwarmConfig, SwarmSession  # noqa: E402
from repro.core.attacks import (persistent_neighbor_linkage,  # noqa: E402
                                timing_attribution)
from repro.core.capacities import MBPS, StragglerLinkModel  # noqa: E402
from repro.fl.asyncfl import (AsyncConfig, adversary_view,  # noqa: E402
                              run_async_experiment)
from repro.fl.client import LocalSpec  # noqa: E402
from repro.fl.runner import FLConfig  # noqa: E402
from repro.net.engine import RESIDENTIAL_NET  # noqa: E402

# Heavy-tailed residential uplinks: the straggler regime where the sync
# barrier actually hurts.  8% of peers upload 32x slower; the BT cycle
# stretches to the slowest in-flight flow while the fast majority idles
# (cf. capacities.RESIDENTIAL_STRAGGLER, whose 8x tail the swarm absorbs
# without stretching — no async win exists there, see ROADMAP).
SLOW32 = StragglerLinkModel(
    up_lo=15.5 * MBPS, up_hi=25.3 * MBPS,
    down_lo=36.5 * MBPS, down_hi=121.0 * MBPS,
    straggler_frac=0.08, up_slowdown=32.0)


def _tta(accs, walls, target):
    """Wall clock at which the accuracy trajectory first hits target."""
    for a, w in zip(accs, walls):
        if a >= target:
            return float(w)
    return None


def _learning(fast: bool):
    base = dict(time_engine="event", net=RESIDENTIAL_NET,
                link_model=SLOW32, evolve_overlay=True)
    if fast:
        cfg = FLConfig(dataset="synth-mnist", dist="dir0.1",
                       n_clients=16, rounds=10, min_degree=5,
                       n_train=3000, n_test=800,
                       local=LocalSpec(epochs=1, lr=0.001))
        acfg = AsyncConfig(buffer_k=4, max_staleness=3, overlap=True,
                           round_slots=7, **base)
    else:
        cfg = FLConfig(dataset="synth-cifar", dist="dir0.1",
                       n_clients=32, rounds=20, min_degree=6,
                       local=LocalSpec(epochs=1, lr=0.0005))
        acfg = AsyncConfig(buffer_k=8, max_staleness=3, overlap=True,
                           round_slots=24, **base)
    with Timer() as t_sync:
        sync = run_async_experiment(cfg, AsyncConfig(**base))
    with Timer() as t_async:
        asy = run_async_experiment(cfg, acfg)
    drain = run_async_experiment(cfg, dc_replace(acfg, overlap=False))

    target = 0.95 * sync.accuracy[-1]
    tta_s = _tta(sync.accuracy, sync.wall_s, target)
    tta_a = _tta(asy.accuracy, asy.wall_s, target)
    tta_d = _tta(drain.accuracy, drain.wall_s, target)
    win = (None if not (tta_s and tta_a)
           else 100.0 * (1.0 - tta_a / tta_s))
    K = sync.session.cfg.chunks_per_update
    print(f"regime: n={cfg.n_clients} K={K} {cfg.dataset}/{cfg.dist} "
          f"lr={cfg.local.lr} round_slots={acfg.round_slots} "
          f"buffer_k={acfg.buffer_k} S={acfg.max_staleness}")
    print(f"sync : final={sync.accuracy[-1]:.3f} "
          f"wall={sync.wall_s[-1]:.0f}s  ({t_sync.seconds:.0f}s cpu)")
    print(f"async: final={asy.accuracy[-1]:.3f} "
          f"wall={asy.wall_s[-1]:.0f}s  stale_hist={asy.staleness_hist} "
          f"dropped={asy.dropped}  ({t_async.seconds:.0f}s cpu)")
    print(f"time-to-target (acc >= {target:.3f}): "
          f"sync={tta_s and round(tta_s)}s "
          f"async={tta_a and round(tta_a)}s "
          f"drain={tta_d and round(tta_d)}s "
          f"win={win and f'{win:.1f}%'}")
    out = {
        "config": {"dataset": cfg.dataset, "dist": cfg.dist,
                   "n_clients": cfg.n_clients, "rounds": cfg.rounds,
                   "K": K, "lr": cfg.local.lr,
                   "round_slots": acfg.round_slots,
                   "buffer_k": acfg.buffer_k,
                   "max_staleness": acfg.max_staleness},
        "sync": {"accuracy": sync.accuracy, "wall_s": sync.wall_s},
        "async": {"accuracy": asy.accuracy, "wall_s": asy.wall_s,
                  "staleness_hist": asy.staleness_hist,
                  "dropped": asy.dropped,
                  "merged": asy.merged},
        "drain": {"accuracy": drain.accuracy, "wall_s": drain.wall_s},
        "target": target, "tta_sync_s": tta_s, "tta_async_s": tta_a,
        "tta_drain_s": tta_d, "win_pct": win,
    }
    return out, asy.session, drain.session, win


def _budget_sweep(fast: bool):
    n, K, md = (16, 4, 5) if fast else (32, 13, 6)
    rounds = 6 if fast else 8
    buds = (5, 6, 8) if fast else (24, 30, 36)

    def sess_wall(bud):
        cfg = SwarmConfig(n=n, chunks_per_update=K, min_degree=md,
                          seed=0)
        ses = SwarmSession(cfg, link_model=SLOW32, time_engine="event",
                           net=RESIDENTIAL_NET, evolve_overlay=True)
        hist: dict[int, int] = {}
        late = 0
        for r in range(rounds):
            rec = ses.next_round(quorum_k=n, tail_mode="carry",
                                 bt_budget=bud)
            for g, _ in rec.late_ready:
                hist[r - g] = hist.get(r - g, 0) + 1
            late += len(rec.late_ready)
        return float(ses.offsets[-1]), late, hist

    wall_sync, _, _ = sess_wall(10 ** 9)     # never cuts: sync barrier
    print(f"budget sweep (n={n} K={K}, {rounds} rounds, session-only); "
          f"sync wall={wall_sync:.0f}s")
    out = {"sync_wall_s": wall_sync, "budgets": {}}
    for bud in buds:
        wall, late, hist = sess_wall(bud)
        win = 100.0 * (1.0 - wall / wall_sync)
        out["budgets"][bud] = {
            "wall_s": wall, "win_pct": win, "late_merged": late,
            "staleness_hist": {int(k): v for k, v in sorted(
                hist.items())}}
        print(f"  round_slots={bud}: wall={wall:6.0f}s win={win:+5.1f}% "
              f"stale_hist={dict(sorted(hist.items()))}")
    return out


def _asr_row(ses):
    view = adversary_view(ses)
    K = ses.cfg.chunks_per_update
    obs = np.arange(max(ses.n_peers // 4, 3))
    link = persistent_neighbor_linkage(
        view, obs, K, exposure=ses.pair_exposure(), min_rounds=3)
    timing = timing_attribution(view, obs, K)
    return {"linkage_max_asr": link.max_asr,
            "linkage_mean_asr": link.mean_asr,
            "timing_max_asr": timing.max_asr,
            "timing_mean_asr": timing.mean_asr,
            "observers": int(len(obs))}


def _privacy(fast: bool, carry_ses, drain_ses):
    # Panel A — the FL sessions themselves (full warm-up defenses):
    # ASR sits at/near the 1/m floor either way; recorded to show the
    # defenses survive the async surface.
    out = {"defended": {}, "undefended": {}}
    for name, ses in (("overlap_on", carry_ses),
                      ("overlap_off", drain_ses)):
        row = _asr_row(ses)
        out["defended"][name] = row
        print(f"  defended   {name:12s} linkage={row['linkage_max_asr']:.3f} "
              f"timing={row['timing_max_asr']:.3f} (max ASR, "
              f"{row['observers']} observers)")
    # Panel B — defenses ablated, where the overlap mechanism itself is
    # visible: carried cross-generation traffic ENLARGES the descriptor
    # cover set an observer must disambiguate, so carry-mode ASR drops
    # below the boundary-drain baseline.
    n, K, md, bud, rounds = ((16, 4, 5, 6, 5) if fast
                             else (32, 13, 6, 24, 6))
    for name, kw in (("overlap_on", {"tail_mode": "carry"}),
                     ("overlap_off", {"tail_mode": "drain"})):
        cfg = SwarmConfig(n=n, chunks_per_update=K, min_degree=md,
                          seed=0, enable_preround=False,
                          enable_timelag=False, enable_gating=False,
                          enable_nonowner_first=False)
        ses = SwarmSession(cfg, link_model=SLOW32, time_engine="event",
                           net=RESIDENTIAL_NET, evolve_overlay=True)
        for _ in range(rounds):
            ses.next_round(quorum_k=n, bt_budget=bud, **kw)
        row = _asr_row(ses)
        out["undefended"][name] = row
        print(f"  undefended {name:12s} linkage={row['linkage_max_asr']:.3f}"
              f"/{row['linkage_mean_asr']:.3f} "
              f"timing={row['timing_max_asr']:.3f}"
              f"/{row['timing_mean_asr']:.3f} (max/mean ASR)")
    return out


def run(fast: bool = False):
    banner("BENCH_async — deadline-free async FL vs the sync barrier")
    learning, carry_ses, drain_ses, win = _learning(fast)
    budget = _budget_sweep(fast)
    print("privacy: cross-round ASR over the async adversary view")
    privacy = _privacy(fast, carry_ses, drain_ses)
    payload = {"mode": "fast" if fast else "full",
               "link_model": {"straggler_frac": 0.08,
                              "up_slowdown": 32.0},
               "learning": learning, "budget": budget,
               "privacy": privacy}
    path = save("BENCH_async", payload)
    print(f"saved {path}")
    if win is None or win <= 0.0:
        raise SystemExit("async reached target no faster than sync "
                         f"(win={win})")
    return payload


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (small swarm, few rounds)")
    args = ap.parse_args()
    run(fast=args.smoke)
