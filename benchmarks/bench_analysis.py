"""swarmlint benchmark + smoke gate: the shipped tree must be clean.

Two measurements, one contract:

* **smoke** — ``python -m repro.analysis src`` (and ``examples``) must
  exit 0 under the justified baseline: zero non-baselined findings in
  the shipped tree.  This is the benchmarks-side twin of the CI
  ``analysis`` job (ISSUE 6 satellite).
* **speed** — wall-clock of a full analyzer pass over ``src`` +
  ``examples`` (the CI job budget is < 60 s; this records the actual
  cost) and the per-family finding counts, including the jit-readiness
  scorecard totals that feed the jitted-engine PR's worklist.

    python benchmarks/bench_analysis.py [--quick]

Emits ``results/bench/BENCH_analysis.json``.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from common import banner, save  # noqa: E402
from repro.analysis import (AnalysisContext, Baseline,  # noqa: E402
                            collect_findings, scorecard)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run(fast: bool = True):
    banner("swarmlint: static invariant analysis of the shipped tree")

    # -- smoke: the CI contract, exercised exactly as CI runs it ------
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    t0 = time.time()
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "src", "examples"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=300)
    cli_s = time.time() - t0
    clean = proc.returncode == 0
    print(f"  python -m repro.analysis src examples -> "
          f"exit {proc.returncode} in {cli_s:.2f}s "
          f"({'clean' if clean else 'NEW FINDINGS'})")
    if not clean:
        print(proc.stdout)

    # -- speed + finding anatomy (in-process, no subprocess cost) -----
    t0 = time.time()
    ctx = AnalysisContext(REPO)
    ctx.add_paths([os.path.join(REPO, "src"),
                   os.path.join(REPO, "examples")])
    findings = collect_findings(ctx)
    analyze_s = time.time() - t0
    by_family: dict = {}
    for f in findings:
        fam = ("visibility" if f.rule.startswith("VIS")
               else "jit" if f.rule.startswith("JIT")
               else "obs" if f.rule.startswith("OBS") else "rng")
        by_family[fam] = by_family.get(fam, 0) + 1
    rows = scorecard(ctx, findings)
    ready = sum(1 for *_x, ok in rows if ok)
    bl = Baseline.load(os.path.join(REPO, "analysis_baseline.json"))
    print(f"  {len(ctx.modules)} files in {analyze_s:.2f}s; findings "
          f"by family: {by_family or '{}'}; baseline entries: "
          f"{len(bl.entries)}")
    print(f"  jit scorecard: {ready}/{len(rows)} slated functions "
          f"kernel-ready")

    payload = {
        "smoke_exit_code": proc.returncode,
        "smoke_clean": clean,
        "cli_wall_s": round(cli_s, 3),
        "analyze_wall_s": round(analyze_s, 3),
        "files_analyzed": len(ctx.modules),
        "findings_by_family": by_family,
        "baseline_entries": len(bl.entries),
        "stale_baseline_keys": bl.unused(findings),
        "jit_targets_total": len(rows),
        "jit_targets_ready": ready,
        "under_ci_budget_60s": cli_s < 60.0,
    }
    save("BENCH_analysis", payload)
    if not clean:
        raise AssertionError(
            "shipped tree has non-baselined findings (see above)")
    return payload


if __name__ == "__main__":
    run(fast="--quick" in sys.argv[1:])
