"""Fig. 3: warm-up bandwidth utilization — online heuristics vs the
stage-wise max-flow upper bound.  Paper claim: GreedyFastestFirst
attains ~92% of the max-flow UB in the high-utilization regime."""
from __future__ import annotations

import numpy as np

from repro.core import SwarmConfig, simulate_round

from .common import banner, save

SCHEDULERS = ["greedy_fastest_first", "random_fastest_first",
              "random_fifo", "distributed", "flooding"]


def run(n: int = 60, K: int = 64, seeds=(0, 1, 2), fast: bool = False):
    banner("Fig. 3 — warm-up utilization vs max-flow upper bound")
    if fast:
        n, K, seeds = 60, 64, (0, 1)
    rows = {}
    for sched in SCHEDULERS:
        fracs, utils = [], []
        for seed in seeds:
            cfg = SwarmConfig(n=n, chunks_per_update=K, s_max=50_000,
                              seed=seed, scheduler=sched)
            res = simulate_round(cfg, collect_maxflow=True,
                                 bt_mode="fluid")
            sent = res.warmup_sent_per_slot[:len(res.maxflow_ub)]
            ub = max(int(res.maxflow_ub.sum()), 1)
            fracs.append(sent.sum() / ub)
            utils.append(res.metrics.warmup_utilization)
        rows[sched] = {"maxflow_fraction": float(np.mean(fracs)),
                       "utilization": float(np.mean(utils))}
        print(f"{sched:22s} util={rows[sched]['utilization']:.3f} "
              f"of-maxflow-UB={rows[sched]['maxflow_fraction']:.3f}")
    best = max(rows, key=lambda s: rows[s]["maxflow_fraction"])
    print(f"\nbest scheduler: {best} "
          f"({rows[best]['maxflow_fraction']:.1%} of max-flow UB; "
          f"paper reports ~92% for GreedyFastestFirst)")
    save("fig3_utilization", {"n": n, "K": K, "rows": rows})
    return rows


if __name__ == "__main__":
    run()
