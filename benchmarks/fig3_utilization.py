"""Fig. 3: warm-up bandwidth utilization — online heuristics vs the
stage-wise max-flow upper bound.  Paper claim: GreedyFastestFirst
attains ~92% of the max-flow UB in the high-utilization regime.

Two domains per scheduler:

* **count space** (slot engine) — chunks moved vs the stage-wise
  max-flow upper bound, the paper's original measurement;
* **time domain** (event engine, :mod:`repro.net`) — realized warm-up
  transport seconds vs the per-cycle congestion lower bound
  (:func:`repro.core.maxflow.warmup_time_bounds`): how close the
  fair-share transport of each scheduler's cycles comes to
  bandwidth-optimal wall-clock.
"""
from __future__ import annotations

import numpy as np

from repro.core import SwarmConfig, simulate_round
from repro.core.maxflow import warmup_time_bounds
from repro.core.simulator import RoundSimulator
from repro.net import NetConfig

from .common import banner, save

SCHEDULERS = ["greedy_fastest_first", "random_fastest_first",
              "random_fifo", "distributed", "flooding"]


def run(n: int = 60, K: int = 64, seeds=(0, 1, 2), fast: bool = False):
    banner("Fig. 3 — warm-up utilization vs max-flow upper bound")
    if fast:
        n, K, seeds = 60, 64, (0, 1)
    net = NetConfig(tracker_rtt_s=0.0)   # pure transport time
    rows = {}
    for sched in SCHEDULERS:
        fracs, utils, teffs = [], [], []
        for seed in seeds:
            cfg = SwarmConfig(n=n, chunks_per_update=K, s_max=50_000,
                              seed=seed, scheduler=sched)
            res = simulate_round(cfg, collect_maxflow=True,
                                 bt_mode="fluid")
            sent = res.warmup_sent_per_slot[:len(res.maxflow_ub)]
            ub = max(int(res.maxflow_ub.sum()), 1)
            fracs.append(sent.sum() / ub)
            utils.append(res.metrics.warmup_utilization)
            # Time domain: same schedule, transported by the event
            # engine; realized seconds vs the congestion lower bound.
            sim = RoundSimulator(cfg, time_engine="event", net=net,
                                 bt_mode="fluid")
            ev = sim.run()
            lbs, real = warmup_time_bounds(ev.log, cfg.chunk_bytes,
                                           sim.up_bps, sim.down_bps)
            teffs.append(float(lbs.sum() / max(real.sum(), 1e-12)))
        rows[sched] = {"maxflow_fraction": float(np.mean(fracs)),
                       "utilization": float(np.mean(utils)),
                       "time_domain_efficiency": float(np.mean(teffs))}
        print(f"{sched:22s} util={rows[sched]['utilization']:.3f} "
              f"of-maxflow-UB={rows[sched]['maxflow_fraction']:.3f} "
              f"time-eff={rows[sched]['time_domain_efficiency']:.3f}")
    best = max(rows, key=lambda s: rows[s]["maxflow_fraction"])
    print(f"\nbest scheduler: {best} "
          f"({rows[best]['maxflow_fraction']:.1%} of max-flow UB; "
          f"paper reports ~92% for GreedyFastestFirst)")
    save("fig3_utilization", {"n": n, "K": K, "rows": rows})
    return rows


if __name__ == "__main__":
    run()
