"""repro.obs benchmark + smoke gate: recording integrity and overhead.

Records a two-round n=100 event-engine session on residential links
(quorum-cut rounds with a boundary tail drain, so the timeline carries
all four flow tracks: spray, warm-up, BT, and the carried background
tail) and gates on the ISSUE 10 acceptance surface:

* **export integrity** — the JSONL recording is schema-valid and the
  Perfetto conversion yields loadable chrome-tracing JSON covering the
  phase, peer, and tracker-control-plane tracks;
* **report fidelity** — ``python -m repro.obs report`` numbers
  (``t_warm_s`` / ``t_round_s`` / ``warmup_share_s`` per round) are
  reproduced from the recording alone, within float tolerance of
  ``RoundMetrics``;
* **overhead bound** — the disabled-recorder hook cost against a
  measured n=100 warm-up stays under 2%.

    python benchmarks/bench_obs.py [--smoke]

Emits ``results/bench/BENCH_obs.json`` plus the recording/timeline side
artifacts (``obs_round.jsonl``, ``obs_timeline.json``).
"""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from common import RESULTS_DIR, banner, save  # noqa: E402

from repro import obs  # noqa: E402
from repro.core import SwarmConfig, SwarmSession  # noqa: E402
from repro.core.simulator import RoundSimulator  # noqa: E402
from repro.net.engine import RESIDENTIAL_NET  # noqa: E402

N = 100
CFG = SwarmConfig(n=N, chunks_per_update=8, min_degree=6,
                  s_max=3000, seed=0)
QUORUM_K = 90       # cut on quorum; the 10-update tail drains late
OVERHEAD_BOUND = 0.02
REPORT_TOL = 1e-6


def _record_session(rounds: int):
    t0 = time.perf_counter()
    with obs.recording(meta={"bench": "obs", "n": N,
                             "rounds": rounds}) as rec:
        ses = SwarmSession(CFG, time_engine="event", net=RESIDENTIAL_NET,
                           evolve_overlay=True)
        ses.run(rounds, quorum_k=QUORUM_K, tail_mode="drain")
    return rec, ses, time.perf_counter() - t0


def _overhead_frac() -> tuple[float, float, float]:
    """Disabled-recorder hook cost vs a measured n=100 warm-up."""
    sim = RoundSimulator(CFG, time_engine="event", net=RESIDENTIAL_NET)
    t0 = time.perf_counter()
    res = sim.run(warmup_only=True)
    warm_wall = time.perf_counter() - t0
    n_sites = max(20 * int(res.metrics.t_warm), 1000)
    assert obs.get().enabled is False
    t0 = time.perf_counter()
    for _ in range(n_sites):
        r = obs.get()
        if r.enabled:
            r.counter("x")           # never taken on the disabled path
    hook_s = time.perf_counter() - t0
    return hook_s / warm_wall, hook_s, warm_wall


def run(fast: bool = True):
    banner("repro.obs: recording integrity, report fidelity, overhead")
    rounds = 2 if fast else 4

    rec, ses, record_wall_s = _record_session(rounds)
    rows = obs.to_jsonl_rows(rec)
    violations = obs.validate_rows(rows)
    export_valid = not violations
    os.makedirs(RESULTS_DIR, exist_ok=True)
    jsonl_path = os.path.join(RESULTS_DIR, "obs_round.jsonl")
    obs.write_jsonl(rows, jsonl_path)

    trace_path = os.path.join(RESULTS_DIR, "obs_timeline.json")
    n_events = obs.write_perfetto(rows, trace_path)
    with open(trace_path) as f:
        trace = json.load(f)         # must load as valid trace JSON
    pids = {e.get("pid") for e in trace["traceEvents"]}
    tracks = {r["track"] for r in rows if r.get("kind") == "flows"}
    tracks_covered = {"spray", "warmup", "bt", "background"} <= tracks
    perfetto_valid = (len(trace["traceEvents"]) == n_events
                      and {0, 1, 2} <= pids)

    summary = obs.summarize(rows)
    wc = ses.wall_clock()
    report_err = 0.0
    for r in range(rounds):
        sr = summary["rounds"][r]
        report_err = max(
            report_err,
            float(abs(sr["t_warm_s"] - wc["t_warm_s"][r])),
            float(abs(sr["t_round_s"] - wc["t_round_s"][r])),
            float(abs(sr["warmup_share_s"] - wc["warmup_share_s"][r])))
    report_matches = bool(report_err < REPORT_TOL)
    # Per-round control_s is float-exact (tests/test_obs.py); across
    # rounds the counter's single accumulator associates differently
    # than summing per-round totals, so gate at float tolerance.
    control_total = float(wc["control_s"].sum())
    control_matches = bool(abs(summary["totals"]["control_s"]
                               - control_total) < REPORT_TOL)

    overhead_frac, hook_s, warm_wall = _overhead_frac()

    n_flow_rows = sum(r["n"] for r in rows if r.get("kind") == "flows")
    print(f"  recorded {rounds} rounds (n={N}, event engine) in "
          f"{record_wall_s:.1f}s: {len(rows)} rows, "
          f"{n_flow_rows} flows on tracks {sorted(tracks)}")
    print(f"  export: jsonl {'valid' if export_valid else 'INVALID'} "
          f"({len(violations)} violations); perfetto {n_events} events "
          f"-> {trace_path}")
    print(f"  report vs RoundMetrics: max err {report_err:.2e} "
          f"({'ok' if report_matches else 'MISMATCH'}); control_s "
          f"{'ok' if control_matches else 'DRIFTED'}")
    print(f"  disabled-recorder hooks: {hook_s * 1e3:.2f}ms against a "
          f"{warm_wall:.2f}s warm-up = {overhead_frac:.3%} "
          f"(bound {OVERHEAD_BOUND:.0%})")

    payload = {
        "n": N,
        "rounds": rounds,
        "record_wall_s": round(record_wall_s, 3),
        "rows": len(rows),
        "flow_rows": n_flow_rows,
        "trace_events": n_events,
        "export_valid": export_valid,
        "perfetto_valid": perfetto_valid,
        "tracks_covered": tracks_covered,
        "report_max_err": report_err,
        "report_matches_metrics": report_matches,
        "control_s_matches": control_matches,
        "overhead_frac": round(overhead_frac, 5),
        "overhead_under_bound": overhead_frac < OVERHEAD_BOUND,
        "warmup_wall_s": round(warm_wall, 3),
    }
    save("BENCH_obs", payload)

    failures = [k for k in ("export_valid", "perfetto_valid",
                            "tracks_covered", "report_matches_metrics",
                            "control_s_matches", "overhead_under_bound")
                if not payload[k]]
    if failures:
        raise AssertionError(f"obs smoke gate failed: {failures}")
    return payload


if __name__ == "__main__":
    try:
        run(fast=True)
    except AssertionError as e:
        print(f"FAILED: {e}")
        sys.exit(1)
