"""Table II: learning utility — CFL vs GossipDFL vs FLTorrent on
synthetic classification datasets under IID + Dirichlet non-IID splits.

Paper claim pattern (validated here at reduced scale; the container is
offline so MNIST/CIFAR are replaced by deterministic synthetic
datasets, DESIGN.md §7): FLTorrent tracks CFL nearly exactly (identical
FedAvg semantics, full reconstruction) and beats GossipDFL, with the
gap growing as heterogeneity increases (smaller Dirichlet alpha)."""
from __future__ import annotations

import numpy as np

from repro.fl.client import LocalSpec
from repro.fl.runner import FLConfig, run_experiment

from .common import banner, save


def compression_error_sweep(rounds=(1, 2, 4, 8, 16), n_pods: int = 8,
                            dim: int = 4096, seed: int = 0):
    """Compressed-ring error growth vs round count (ROADMAP follow-up).

    Runs R successive ring-FedAvg aggregations with int8 wire
    compression on vs off over the same synthetic update stream and
    reports the relative drift of the running model.  Quantization is
    one rounding per element per round (codes circulate losslessly —
    see ``repro.dist.torrent``), so the drift after R rounds is bounded
    by R x the per-round error (~2% worst case); in practice rounding
    errors partially cancel and growth is sublinear.
    """
    import jax.numpy as jnp

    from repro.dist.torrent import torrent_fedavg

    rng = np.random.default_rng(seed)
    p_exact = jnp.zeros(dim, jnp.float32)
    p_comp = jnp.zeros(dim, jnp.float32)
    w = jnp.ones(n_pods)
    a = jnp.ones(n_pods)
    rows = []
    targets = sorted(rounds)
    for r in range(1, targets[-1] + 1):
        upd = jnp.asarray(
            rng.normal(size=(n_pods, dim)).astype(np.float32))
        p_exact = p_exact + torrent_fedavg(upd, w, a, compress=False)
        p_comp = p_comp + torrent_fedavg(upd, w, a, compress=True)
        if r in targets:
            rel = float(jnp.linalg.norm(p_comp - p_exact)
                        / jnp.maximum(jnp.linalg.norm(p_exact), 1e-12))
            rows.append({"rounds": r, "rel_err": round(rel, 6),
                         "linear_bound": round(0.02 * r, 4)})
    bound_ok = all(row["rel_err"] <= row["linear_bound"]
                   for row in rows)
    print("\ncompressed-ring drift vs rounds (int8 wire codes):")
    for row in rows:
        print(f"  R={row['rounds']:3d}  rel_err={row['rel_err']:.4f}  "
              f"(<= {row['linear_bound']:.3f} linear bound)")
    print(f"linear error bound: {'HELD' if bound_ok else 'VIOLATED'}")
    return rows, bound_ok


def async_frontier(fast: bool = False):
    """Async-vs-sync accuracy/wall-clock frontier (fl/asyncfl.py).

    Each ``round_slots`` budget is one operating point on the
    deadline -> latency/staleness trade-off curve: a tighter cut lowers
    wall clock per round while the carried tail merges late at
    staleness >= 1.  Under heavy-tailed uplinks the whole async branch
    sits left of the sync point at equal accuracy; BENCH_async.json
    holds the full time-to-target analysis."""
    from repro.core.capacities import MBPS, StragglerLinkModel
    from repro.fl.asyncfl import AsyncConfig, run_async_experiment
    from repro.net.engine import RESIDENTIAL_NET

    slow = StragglerLinkModel(
        up_lo=15.5 * MBPS, up_hi=25.3 * MBPS,
        down_lo=36.5 * MBPS, down_hi=121.0 * MBPS,
        straggler_frac=0.08, up_slowdown=32.0)
    base = dict(time_engine="event", net=RESIDENTIAL_NET,
                link_model=slow, evolve_overlay=True)
    cfg = FLConfig(dataset="synth-mnist", dist="dir0.1", n_clients=16,
                   rounds=8 if fast else 12, min_degree=5,
                   n_train=3000, n_test=800, seed=0,
                   local=LocalSpec(epochs=1, lr=0.001))
    sync = run_async_experiment(cfg, AsyncConfig(**base))
    pts = [{"mode": "sync", "round_slots": None,
            "wall_s": round(sync.wall_s[-1], 1),
            "acc": round(float(np.mean(sync.accuracy[-3:])), 4)}]
    print("\nasync frontier (straggler links, n=16/K=4, "
          f"{cfg.rounds} rounds):")
    print(f"  sync           wall={pts[0]['wall_s']:7.1f}s "
          f"acc={pts[0]['acc']:.3f}")
    for bud in ((6, 8) if fast else (5, 6, 7, 9)):
        asy = run_async_experiment(cfg, AsyncConfig(
            buffer_k=4, max_staleness=3, overlap=True,
            round_slots=bud, **base))
        pts.append({"mode": "async", "round_slots": bud,
                    "wall_s": round(asy.wall_s[-1], 1),
                    "acc": round(float(np.mean(asy.accuracy[-3:])), 4),
                    "staleness_hist": asy.staleness_hist,
                    "dropped": asy.dropped})
        print(f"  round_slots={bud:2d} wall={pts[-1]['wall_s']:7.1f}s "
              f"acc={pts[-1]['acc']:.3f} "
              f"stale={asy.staleness_hist} dropped={asy.dropped}")
    return pts


def run(fast: bool = False):
    banner("Table II — CFL vs GossipDFL vs FLTorrent")
    n_clients = 10 if fast else 20
    rounds = 6 if fast else 15
    dists = ("dir0.1", "dir0.5", "iid") if not fast else ("dir0.1", "iid")
    datasets = ("synth-mnist", "synth-cifar") if not fast \
        else ("synth-cifar",)
    rows = {}
    for ds in datasets:
        for dist in dists:
            cfg = FLConfig(
                dataset=ds, model="mlp", dist=dist, n_clients=n_clients,
                rounds=rounds,
                local=LocalSpec(epochs=1, batch_size=32, lr=0.03),
                n_train=4000, n_test=1000, seed=0, min_degree=5)
            accs = {}
            for method in ("cfl", "gossip", "fltorrent"):
                r = run_experiment(method, cfg)
                accs[method] = round(float(np.mean(r.accuracy[-3:])), 4)
                if method == "fltorrent":
                    accs["agreement"] = bool(r.agreement)
                    accs["reconstruct_frac"] = float(r.reconstruct_frac)
            rows[f"{ds}/{dist}"] = accs
            print(f"{ds:12s} {dist:8s} CFL={accs['cfl']:.3f} "
                  f"Gossip={accs['gossip']:.3f} "
                  f"FLTorrent={accs['fltorrent']:.3f} "
                  f"agree={accs['agreement']}")
    ok = all(r["fltorrent"] >= r["gossip"] - 0.03 and
             abs(r["fltorrent"] - r["cfl"]) < 0.05 for r in rows.values())
    print(f"\nclaim pattern (FLTorrent ~= CFL >= Gossip): "
          f"{'CONFIRMED' if ok else 'VIOLATED'}")
    comp_rows, comp_ok = compression_error_sweep(
        rounds=(1, 2, 4, 8) if fast else (1, 2, 4, 8, 16, 32))
    frontier = async_frontier(fast)
    save("table2_learning", {"rows": rows, "pattern_ok": ok,
                             "compression_sweep": comp_rows,
                             "compression_bound_ok": comp_ok,
                             "async_frontier": frontier})
    return rows


if __name__ == "__main__":
    run()
