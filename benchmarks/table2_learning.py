"""Table II: learning utility — CFL vs GossipDFL vs FLTorrent on
synthetic classification datasets under IID + Dirichlet non-IID splits.

Paper claim pattern (validated here at reduced scale; the container is
offline so MNIST/CIFAR are replaced by deterministic synthetic
datasets, DESIGN.md §7): FLTorrent tracks CFL nearly exactly (identical
FedAvg semantics, full reconstruction) and beats GossipDFL, with the
gap growing as heterogeneity increases (smaller Dirichlet alpha)."""
from __future__ import annotations

import numpy as np

from repro.fl.client import LocalSpec
from repro.fl.runner import FLConfig, run_experiment

from .common import banner, save


def run(fast: bool = False):
    banner("Table II — CFL vs GossipDFL vs FLTorrent")
    n_clients = 10 if fast else 20
    rounds = 6 if fast else 15
    dists = ("dir0.1", "dir0.5", "iid") if not fast else ("dir0.1", "iid")
    datasets = ("synth-mnist", "synth-cifar") if not fast \
        else ("synth-cifar",)
    rows = {}
    for ds in datasets:
        for dist in dists:
            cfg = FLConfig(
                dataset=ds, model="mlp", dist=dist, n_clients=n_clients,
                rounds=rounds,
                local=LocalSpec(epochs=1, batch_size=32, lr=0.03),
                n_train=4000, n_test=1000, seed=0, min_degree=5)
            accs = {}
            for method in ("cfl", "gossip", "fltorrent"):
                r = run_experiment(method, cfg)
                accs[method] = round(float(np.mean(r.accuracy[-3:])), 4)
                if method == "fltorrent":
                    accs["agreement"] = bool(r.agreement)
                    accs["reconstruct_frac"] = float(r.reconstruct_frac)
            rows[f"{ds}/{dist}"] = accs
            print(f"{ds:12s} {dist:8s} CFL={accs['cfl']:.3f} "
                  f"Gossip={accs['gossip']:.3f} "
                  f"FLTorrent={accs['fltorrent']:.3f} "
                  f"agree={accs['agreement']}")
    ok = all(r["fltorrent"] >= r["gossip"] - 0.03 and
             abs(r["fltorrent"] - r["cfl"]) < 0.05 for r in rows.values())
    print(f"\nclaim pattern (FLTorrent ~= CFL >= Gossip): "
          f"{'CONFIRMED' if ok else 'VIOLATED'}")
    save("table2_learning", {"rows": rows, "pattern_ok": ok})
    return rows


if __name__ == "__main__":
    run()
